//! Certificate fingerprint-cache effectiveness on a repeated-layer GPT
//! workload.
//!
//! The throughput claim this measures: a production model's L identical
//! transformer layers should verify once, not L times. Four runs over the
//! same L=8 tensor+sequence-parallel GPT pair:
//!   gpt8_nocache     — cache disabled (the pre-cache baseline)
//!   gpt8_cold        — fresh cache; repeated layers replay *within* the run
//!   gpt8_warm        — same cache again; every region replays
//!   gpt8_warm_jobs4  — warm cache + 4-worker parallel walk
//!
//! Hard assertions (the ISSUE-7 acceptance gate, also enforced on
//! BENCH_cache.json by CI): warm hit-rate ≥ (L−1)/L, and the cold run's
//! miss count is bounded by one layer's regions plus the embedding/head
//! epilogue — i.e. repeated layers really do verify once.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{fmt_dur, write_bench_json, BenchRecord};
use graphguard::cache::FingerprintCache;
use graphguard::infer::{InferConfig, Verdict};
use graphguard::Verifier;
use graphguard::models::gpt::{self, GptConfig};
use std::sync::Arc;
use std::time::Instant;

const LAYERS: usize = 8;

fn main() {
    let _ = graphguard::lemmas::standard_rewrites();
    println!("Fingerprint-cache effectiveness — GPT TP+SP, {LAYERS} layers, 2 ranks\n");
    let model_cfg = GptConfig::default();
    let (gs, gd, ri) = gpt::tp_sp_pair(2, LAYERS, &model_cfg).expect("build L=8 workload");
    let gs_one_layer = gpt::seq(1, &model_cfg);
    let ops = gs.num_nodes() + gd.num_nodes();

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut run = |name: &'static str, cfg: &InferConfig| -> (u64, u64) {
        let t0 = Instant::now();
        let v = Verifier::with_config(cfg.clone()).isolated(true).run(&gs, &gd, &ri);
        let wall = t0.elapsed();
        let Verdict::Verified(out) = v else {
            panic!("{name}: expected verified, got {}", v.tag());
        };
        println!(
            "{name:>16}: {:>9}  hits {:>3}  misses {:>3}",
            fmt_dur(wall),
            out.cache_hits,
            out.cache_misses
        );
        records.push(
            BenchRecord::new(name, ops, wall, out.stats.total_applications())
                .with_cache(out.cache_hits, out.cache_misses),
        );
        (out.cache_hits, out.cache_misses)
    };

    run("gpt8_nocache", &InferConfig::default());

    let cache = Arc::new(FingerprintCache::new());
    let cached = InferConfig { cache: Some(Arc::clone(&cache)), ..InferConfig::default() };
    let (cold_hits, cold_misses) = run("gpt8_cold", &cached);
    let (warm_hits, warm_misses) = run("gpt8_warm", &cached);
    let parallel =
        InferConfig { jobs: 4, cache: Some(Arc::clone(&cache)), ..InferConfig::default() };
    run("gpt8_warm_jobs4", &parallel);

    // Cold-run reuse: repeated layers replay within a single walk, so
    // misses are bounded by one layer's regions plus the embedding/LM-head
    // epilogue (the +5 slack).
    let per_layer_bound = gs_one_layer.num_nodes() + 5;
    assert!(
        (cold_misses as usize) <= per_layer_bound,
        "cold run must reuse repeated layers: {cold_misses} misses > bound {per_layer_bound}"
    );
    assert!(cold_hits > 0, "cold run must replay at least the repeated layers");

    // The acceptance bound: warm hit-rate ≥ (L−1)/L.
    let warm_rate = warm_hits as f64 / ((warm_hits + warm_misses).max(1)) as f64;
    let floor = (LAYERS - 1) as f64 / LAYERS as f64;
    assert!(
        warm_rate >= floor,
        "warm hit-rate {warm_rate:.3} below acceptance floor {floor:.3}"
    );
    println!(
        "\nwarm hit-rate {:.1}% (acceptance floor {:.1}%), cold misses {} (bound {})",
        warm_rate * 100.0,
        floor * 100.0,
        cold_misses,
        per_layer_bound
    );

    let path = write_bench_json("cache", &records).expect("write BENCH_cache.json");
    println!("wrote {}", path.display());
}
