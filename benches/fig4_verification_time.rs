//! Figure 4: end-to-end verification time across models (parallelism 2,
//! one layer — the paper's setup). The paper's shape to reproduce: times
//! positively correlated with operator count; all models well under the
//! 3-minute envelope; ByteDance bwd > fwd.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{write_bench_json, BenchRecord};
use graphguard::coordinator::{report_table, Coordinator};
use graphguard::models;

fn main() {
    // warm the shared lemma library so the first (smallest) workload's row
    // doesn't absorb the one-time construction cost
    let _ = graphguard::lemmas::standard_rewrites();
    println!("Figure 4 — end-to-end verification time (parallelism 2, 1 layer)\n");
    let mut jobs = models::table2_workloads(2);
    let (gs, gd, ri) = models::bytedance::bwd_pair(2).unwrap();
    jobs.push(models::Workload {
        name: "bytedance_bwd_2".into(),
        gs,
        gd,
        ri,
        strategies: vec!["ep"],
    });
    // Certificate fingerprint cache on, as in CLI suite runs: Table-2
    // models share layer structure, so cross-model replays show up in the
    // recorded hit/miss columns.
    let cfg = graphguard::infer::InferConfig {
        cache: Some(graphguard::cache::FingerprintCache::global().clone()),
        ..Default::default()
    };
    let coord = Coordinator { cfg, ..Coordinator::default() };
    // serial run_one for per-model timing fidelity (no scheduler noise)
    let results: Vec<_> = jobs.iter().map(|w| coord.run_one(w)).collect();
    print!("{}", report_table(&results));
    println!("\n(paper: 6–167 s on CloudLab; shape to match = monotone in #operators)");
    // correlation check printed for EXPERIMENTS.md
    let mut pairs: Vec<(usize, f64)> = results
        .iter()
        .map(|r| (r.gs_ops + r.gd_ops, r.duration.as_secs_f64()))
        .collect();
    pairs.sort_by_key(|p| p.0);
    println!("ops→time series: {:?}", pairs);
    assert!(results.iter().all(|r| r.ok), "all Table-2 workloads must refine");

    let records: Vec<BenchRecord> = results
        .iter()
        .map(|r| {
            BenchRecord::new(
                r.name.clone(),
                r.gs_ops + r.gd_ops,
                r.duration,
                r.lemma_applications,
            )
            .with_verdict(r.verdict.tag())
            .with_cache(r.cache_hits, r.cache_misses)
        })
        .collect();
    let path = write_bench_json("fig4", &records).expect("write BENCH_fig4.json");
    println!("wrote {}", path.display());
}
