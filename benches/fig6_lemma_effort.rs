//! Figure 6: the effort to support customized operators — (a) #operators,
//! #lemmas, avg operators-per-lemma for each model's custom ops; (b) the
//! CDF of lines-of-code per lemma (paper: all < 55 LoC, most simple).

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{write_bench_json, BenchRecord};
use graphguard::lemmas;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let lib = lemmas::metadata();
    let build_time = t0.elapsed();

    println!("Figure 6a — custom-operator lemma effort per model/frontend");
    println!("{:<12} {:>8} {:>8} {:>16}", "origin", "#lemmas", "#ops", "avg ops/lemma");
    for (group, label) in [("pallas", "pallas (L1)"), ("v", "vllm/qwen2"), ("h", "hlo/llama3")] {
        let lems: Vec<_> = lib.iter().filter(|m| m.group == group).collect();
        let ops: u32 = lems.iter().map(|m| m.complexity).sum();
        println!(
            "{:<12} {:>8} {:>8} {:>16.2}",
            label,
            lems.len(),
            ops,
            ops as f64 / lems.len().max(1) as f64
        );
    }
    let builtin = lib.iter().filter(|m| matches!(m.group, "c" | "core")).count();
    println!("(+ {builtin} built-in ATen-style lemmas, {} total)", lib.len());

    println!("\nFigure 6b — CDF of LoC per lemma");
    let mut locs: Vec<u32> = lib.iter().map(|m| m.loc).collect();
    locs.sort_unstable();
    for pct in [10usize, 25, 50, 75, 90, 100] {
        let idx = (pct * locs.len()).div_ceil(100).saturating_sub(1);
        println!("  p{pct:<3} ≤ {:>3} LoC", locs[idx]);
    }
    let max = *locs.last().unwrap();
    assert!(max < 60, "paper: every lemma under ~55 LoC (max here {max})");
    println!("  max = {max} LoC (paper: < 55)");

    println!("\ncomplexity histogram (#operators per lemma):");
    let maxc = lib.iter().map(|m| m.complexity).max().unwrap();
    for c in 1..=maxc {
        let n = lib.iter().filter(|m| m.complexity == c).count();
        println!("  {c} ops: {}", "#".repeat(n));
    }

    // machine-readable record: per-group lemma counts (ops = #lemmas,
    // lemma_applications = summed complexity) plus library build time
    let mut records: Vec<BenchRecord> = Vec::new();
    for group in ["c", "core", "v", "h", "pallas"] {
        let lems: Vec<_> = lib.iter().filter(|m| m.group == group).collect();
        let ops: u32 = lems.iter().map(|m| m.complexity).sum();
        records.push(BenchRecord::new(
            format!("group_{group}"),
            lems.len(),
            std::time::Duration::ZERO,
            ops as u64,
        ));
    }
    records.push(BenchRecord::new("library_build", lib.len(), build_time, 0));
    let path = write_bench_json("fig6", &records).expect("write BENCH_fig6.json");
    println!("\nwrote {}", path.display());
}
