//! Figure 5: verification time vs parallelism size {2,4,6,8} and #layers
//! {1,2,3,4}, for GPT (TP+SP+VP) and Llama-3 (TP). Shapes to reproduce:
//! growth with parallelism degree dominates growth with layer count, and
//! Llama-3 has NO size-6 point (uneven partition).

use graphguard::bench::fmt_dur;
use graphguard::coordinator::Coordinator;
use graphguard::models::{gpt, llama, Workload};
use std::time::Duration;

fn time_workload(coord: &Coordinator, name: String, build: impl FnOnce() -> anyhow::Result<(graphguard::ir::Graph, graphguard::ir::Graph, graphguard::relation::Relation)>) -> Option<(Duration, usize)> {
    match build() {
        Ok((gs, gd, ri)) => {
            let ops = gs.num_nodes() + gd.num_nodes();
            let r = coord.run_one(&Workload { name, gs, gd, ri, strategies: vec![] });
            assert!(r.ok, "{}: {:?}", r.name, r.error);
            Some((r.duration, ops))
        }
        Err(_) => None, // uneven partition (the Llama-3 size-6 hole)
    }
}

fn main() {
    let coord = Coordinator::default();
    let gpt_cfg = gpt::GptConfig::sweep();
    let llama_cfg = llama::LlamaConfig::default();

    println!("Figure 5a — time vs parallelism size (1 layer)");
    println!("{:<6} {:>14} {:>14}", "size", "gpt(tp+sp+vp)", "llama3(tp)");
    for ranks in [2usize, 3, 4, 6] {
        let g = time_workload(&coord, format!("gpt_p{ranks}"), || {
            gpt::tp_sp_vp_pair(ranks, 1, &gpt_cfg)
        });
        let l = time_workload(&coord, format!("llama_p{ranks}"), || {
            llama::tp_pair(ranks, 1, &llama_cfg)
        });
        println!(
            "{:<6} {:>14} {:>14}",
            ranks,
            g.map(|(d, _)| fmt_dur(d)).unwrap_or_else(|| "—".into()),
            l.map(|(d, _)| fmt_dur(d)).unwrap_or_else(|| "— (uneven)".into()),
        );
    }

    println!("\nFigure 5b — time vs #layers (parallelism 2)");
    println!("{:<7} {:>14} {:>14}", "layers", "gpt(tp+sp+vp)", "llama3(tp)");
    for layers in [1usize, 2, 3, 4] {
        let g = time_workload(&coord, format!("gpt_l{layers}"), || {
            gpt::tp_sp_vp_pair(2, layers, &gpt_cfg)
        });
        let l = time_workload(&coord, format!("llama_l{layers}"), || {
            llama::tp_pair(2, layers, &llama_cfg)
        });
        println!(
            "{:<7} {:>14} {:>14}",
            layers,
            g.map(|(d, _)| fmt_dur(d)).unwrap(),
            l.map(|(d, _)| fmt_dur(d)).unwrap(),
        );
    }
    println!("\n(paper shape: parallelism degree has the bigger impact; layers ~linear)");
}
