//! Figure 5: verification time vs parallelism size {2,4,6,8} and #layers
//! {1,2,3,4}, for GPT (TP+SP+VP) and Llama-3 (TP). Shapes to reproduce:
//! growth with parallelism degree dominates growth with layer count, and
//! Llama-3 has NO size-6 point (uneven partition).
//!
//! Besides the printed table this writes `BENCH_fig5.json` (workload, ops,
//! wall-clock ns, lemma applications) so the perf trajectory is tracked
//! across PRs — see EXPERIMENTS.md §Perf.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{fmt_dur, write_bench_json, BenchRecord};
use graphguard::coordinator::Coordinator;
use graphguard::models::{gpt, llama, Workload};
use std::time::Duration;

fn time_workload(
    coord: &Coordinator,
    records: &mut Vec<BenchRecord>,
    name: String,
    build: impl FnOnce() -> anyhow::Result<(
        graphguard::ir::Graph,
        graphguard::ir::Graph,
        graphguard::relation::Relation,
    )>,
) -> Option<(Duration, usize)> {
    match build() {
        Ok((gs, gd, ri)) => {
            let ops = gs.num_nodes() + gd.num_nodes();
            let r = coord.run_one(&Workload { name, gs, gd, ri, strategies: vec![] });
            assert!(r.ok, "{}: {:?}", r.name, r.error);
            records.push(BenchRecord::new(r.name, ops, r.duration, r.lemma_applications));
            Some((r.duration, ops))
        }
        // Only an uneven partition may be skipped — that is the expected
        // Llama-3 size-6 hole. Any other build error is a genuine
        // model-construction bug and must fail the bench loudly instead of
        // being swallowed as a missing data point.
        Err(e) if format!("{e:#}").contains("not divisible by") => None,
        Err(e) => panic!("{name}: unexpected model-construction failure: {e:#}"),
    }
}

fn main() {
    // warm the shared lemma library so the first row doesn't absorb the
    // one-time construction cost
    let _ = graphguard::lemmas::standard_rewrites();
    let coord = Coordinator::default();
    let gpt_cfg = gpt::GptConfig::sweep();
    let llama_cfg = llama::LlamaConfig::default();
    let mut records: Vec<BenchRecord> = Vec::new();

    println!("Figure 5a — time vs parallelism size (1 layer)");
    println!("{:<6} {:>14} {:>14}", "size", "gpt(tp+sp+vp)", "llama3(tp)");
    for ranks in [2usize, 3, 4, 6] {
        let g = time_workload(&coord, &mut records, format!("gpt_p{ranks}"), || {
            gpt::tp_sp_vp_pair(ranks, 1, &gpt_cfg)
        });
        let l = time_workload(&coord, &mut records, format!("llama_p{ranks}"), || {
            llama::tp_pair(ranks, 1, &llama_cfg)
        });
        println!(
            "{:<6} {:>14} {:>14}",
            ranks,
            g.map(|(d, _)| fmt_dur(d)).unwrap_or_else(|| "—".into()),
            l.map(|(d, _)| fmt_dur(d)).unwrap_or_else(|| "— (uneven)".into()),
        );
    }

    println!("\nFigure 5b — time vs #layers (parallelism 2)");
    println!("{:<7} {:>14} {:>14}", "layers", "gpt(tp+sp+vp)", "llama3(tp)");
    for layers in [1usize, 2, 3, 4] {
        let g = time_workload(&coord, &mut records, format!("gpt_l{layers}"), || {
            gpt::tp_sp_vp_pair(2, layers, &gpt_cfg)
        });
        let l = time_workload(&coord, &mut records, format!("llama_l{layers}"), || {
            llama::tp_pair(2, layers, &llama_cfg)
        });
        println!(
            "{:<7} {:>14} {:>14}",
            layers,
            g.map(|(d, _)| fmt_dur(d)).unwrap(),
            l.map(|(d, _)| fmt_dur(d)).unwrap(),
        );
    }
    println!("\n(paper shape: parallelism degree has the bigger impact; layers ~linear)");

    // total printed for the ≥25%-improvement acceptance check; the JSON
    // keeps one row per real workload so consumers can sum it themselves
    let total: Duration = records.iter().map(|r| Duration::from_nanos(r.wall_ns as u64)).sum();
    let path = write_bench_json("fig5", &records).expect("write BENCH_fig5.json");
    println!("wrote {} (total wall-clock {})", path.display(), fmt_dur(total));
}
