//! Patch-driven incremental re-verification on a repeated-layer GPT
//! workload (ISSUE 10).
//!
//! The throughput claim this measures: after a local edit to one layer of
//! an already-verified L-layer model, re-verification should cost one
//! dirty cone, not L layers. Three runs over the L=8 tensor+sequence-
//! parallel GPT pair and a single-node identity splice near the LM head:
//!   patch_full_cold     — full from-scratch verification of the patched
//!                         pair, no cache (the non-incremental baseline)
//!   patch_warmup        — full verification of the *original* pair into a
//!                         fresh cache (the run that "already happened")
//!   patch_reverify_warm — `Verifier::reverify` against that warm cache:
//!                         Clean regions replay, the dirty cone re-saturates
//!
//! Hard assertions (the ISSUE-10 acceptance gate, also enforced on
//! BENCH_patch.json by CI): the impact analysis proves a strict majority
//! of regions Clean (dirty cone < total), the incremental certificate is
//! byte-identical to the full run's, and Clean regions replay as cache
//! hits rather than re-saturating.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::analysis::remap_relation;
use graphguard::bench::{fmt_dur, write_bench_json, BenchRecord};
use graphguard::cache::FingerprintCache;
use graphguard::infer::{InferConfig, Verdict};
use graphguard::ir::{GraphPatch, Op};
use graphguard::models::gpt::{self, GptConfig};
use graphguard::Verifier;
use std::sync::Arc;
use std::time::Instant;

const LAYERS: usize = 8;

fn main() {
    let _ = graphguard::lemmas::standard_rewrites();
    println!("Patch impact + incremental re-verification — GPT TP+SP, {LAYERS} layers, 2 ranks");
    println!();
    let model_cfg = GptConfig::default();
    let (gs, gd, ri) = gpt::tp_sp_pair(2, LAYERS, &model_cfg).expect("build L=8 workload");
    let ops = gs.num_nodes() + gd.num_nodes();

    // A strictly local, semantics-preserving edit: splice an identity in
    // front of slot 0 of the topologically last G_d node.
    let last = gd.topo_order().last().expect("nonempty graph");
    let node = gd.node(last);
    let src = gd.tensor(node.inputs[0]).name.clone();
    let tgt = gd.tensor(node.output).name.clone();
    let patch = GraphPatch::new("late_identity")
        .add("late_id", Op::Identity, vec![src])
        .rewire(tgt, 0, "late_id");
    let patched = patch.apply(&gd).expect("identity splice applies");
    // the splice shifts TensorIds, so the full-verification baseline needs
    // R_i re-keyed by name — exactly what reverify does internally
    let ri_patched = remap_relation(&ri, &gd, &patched).expect("relation survives the splice");

    let mut records: Vec<BenchRecord> = Vec::new();
    fn record(
        name: &'static str,
        ops: usize,
        wall: std::time::Duration,
        out: &graphguard::infer::InferOutput,
        records: &mut Vec<BenchRecord>,
    ) {
        println!(
            "{name:>20}: {:>9}  hits {:>3}  misses {:>3}",
            fmt_dur(wall),
            out.cache_hits,
            out.cache_misses
        );
        records.push(
            BenchRecord::new(name, ops, wall, out.stats.total_applications())
                .with_cache(out.cache_hits, out.cache_misses),
        );
    }

    // 1. the non-incremental baseline: full verification of the patched pair
    let t0 = Instant::now();
    let v = Verifier::new().isolated(true).run(&gs, &patched, &ri_patched);
    let wall_full = t0.elapsed();
    let Verdict::Verified(full) = v else {
        panic!("patch_full_cold: expected verified, got {}", v.tag());
    };
    record("patch_full_cold", ops, wall_full, &full, &mut records);

    // 2. the run that "already happened": original pair into a fresh cache
    let cache = Arc::new(FingerprintCache::new());
    let cached = InferConfig { cache: Some(Arc::clone(&cache)), ..InferConfig::default() };
    let warm_verifier = Verifier::with_config(cached).isolated(true);
    let t0 = Instant::now();
    let v = warm_verifier.run(&gs, &gd, &ri);
    let wall_warmup = t0.elapsed();
    let Verdict::Verified(warmup) = v else {
        panic!("patch_warmup: expected verified, got {}", v.tag());
    };
    record("patch_warmup", ops, wall_warmup, &warmup, &mut records);

    // 3. the incremental path: reverify against the warm cache
    let t0 = Instant::now();
    let rv = warm_verifier.reverify(&gs, &gd, &ri, &patch).expect("reverify runs");
    let wall_rv = t0.elapsed();
    let Verdict::Verified(inc) = &rv.verdict else {
        panic!("patch_reverify_warm: expected verified, got {}", rv.verdict.tag());
    };
    record("patch_reverify_warm", ops, wall_rv, inc, &mut records);

    // ---- acceptance gates ----
    let (clean, total) = (rv.impact.clean(), rv.impact.regions.len());
    let dirty = rv.impact.dirty_cone();
    assert!(dirty >= 1, "the patched tail must be re-verified");
    assert!(
        dirty < total,
        "impact analysis must prove reuse: dirty cone {dirty} covers all {total} regions"
    );
    assert!(
        clean * LAYERS >= (LAYERS - 1) * total,
        "single-layer patch proved only {clean}/{total} regions Clean \
         (acceptance floor is {}/{LAYERS})",
        LAYERS - 1
    );
    assert!(
        inc.cache_hits as usize >= clean,
        "Clean regions must replay: {} hits < {clean} clean regions",
        inc.cache_hits
    );
    let a = full.relation.to_json(&gs, &patched).to_string_pretty();
    let b = inc.relation.to_json(&gs, &rv.patched).to_string_pretty();
    assert!(a == b, "incremental certificate diverged from full verification");

    println!(
        "\nimpact: {clean}/{total} regions clean ({dirty} dirty), \
         acceptance floor {}/{LAYERS}; certificates byte-identical",
        LAYERS - 1
    );

    let path = write_bench_json("patch", &records).expect("write BENCH_patch.json");
    println!("wrote {}", path.display());
}
