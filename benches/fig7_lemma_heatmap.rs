//! Figure 7: lemma-application heatmap (log scale) — how many times each
//! lemma fires per model × parallelism degree. Shapes to reproduce:
//! clean-op ("c" group) lemmas dominate, counts grow with parallelism,
//! HLO/vLLM/Pallas custom-op lemmas appear only for their models.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{write_bench_json, BenchRecord};
use graphguard::coordinator::Coordinator;
use graphguard::models;
use rustc_hash::FxHashMap;

fn main() {
    // warm the shared lemma library so the first row doesn't absorb the
    // one-time construction cost
    let _ = graphguard::lemmas::standard_rewrites();
    let coord = Coordinator::default();
    let mut rows: Vec<(String, FxHashMap<&'static str, u64>)> = Vec::new();
    let mut records: Vec<BenchRecord> = Vec::new();
    for ranks in [2usize, 4] {
        for w in models::table2_workloads(ranks) {
            let r = coord.run_one(&w);
            assert!(r.ok, "{}: {:?}", r.name, r.error);
            records.push(BenchRecord::new(
                w.name.clone(),
                r.gs_ops + r.gd_ops,
                r.duration,
                r.lemma_applications,
            ));
            rows.push((w.name.clone(), r.lemma_counts.into_iter().collect()));
        }
    }
    // columns: lemmas that fired anywhere, grouped c-first (paper x-axis)
    let meta: FxHashMap<&'static str, &'static str> =
        graphguard::lemmas::metadata().iter().map(|m| (m.name, m.group)).collect();
    let mut cols: Vec<&'static str> = rows
        .iter()
        .flat_map(|(_, c)| c.keys().copied())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    cols.sort_by_key(|l| (meta.get(l).copied().unwrap_or("?"), *l));

    println!("Figure 7 — lemma applications (log10 buckets: . <10, + <100, * <1000, # ≥1000)\n");
    print!("{:<26}", "model(parallelism)");
    for (i, _) in cols.iter().enumerate() {
        print!("{}", (b'a' + (i % 26) as u8) as char);
    }
    println!();
    for (name, counts) in &rows {
        print!("{:<26}", name);
        for c in &cols {
            let n = counts.get(c).copied().unwrap_or(0);
            let ch = match n {
                0 => ' ',
                1..=9 => '.',
                10..=99 => '+',
                100..=999 => '*',
                _ => '#',
            };
            print!("{ch}");
        }
        println!();
    }
    println!("\nlegend (column → lemma [group]):");
    for (i, c) in cols.iter().enumerate() {
        println!(
            "  {} = {} [{}]",
            (b'a' + (i % 26) as u8) as char,
            c,
            meta.get(c).copied().unwrap_or("?")
        );
    }
    // the paper's headline observations, asserted:
    let total_c: u64 = rows
        .iter()
        .flat_map(|(_, m)| m.iter())
        .filter(|(l, _)| meta.get(*l) == Some(&"c"))
        .map(|(_, &n)| n)
        .sum();
    let total_all: u64 = rows.iter().flat_map(|(_, m)| m.values()).sum();
    println!(
        "\nclean-op lemma share: {:.0}% (paper: clean-expression lemmas dominate)",
        100.0 * total_c as f64 / total_all as f64
    );

    let path = write_bench_json("fig7", &records).expect("write BENCH_fig7.json");
    println!("wrote {}", path.display());
}
