//! Fuzz-harness throughput: wall-clock per fuzz case (generate + verify
//! clean pair + mutate + differential oracle). Tracks the cost of the
//! adversarial test bed so CI fuzz budgets can be sized; writes
//! `BENCH_fuzz.json` like every other bench target.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{measure, table, BenchRecord};
use graphguard::fuzz::{run_fuzz, FuzzConfig};

fn main() {
    let mut results = Vec::new();
    let mut records = Vec::new();
    for (label, seeds) in [("fuzz_8", 8u64), ("fuzz_16", 16u64)] {
        let cfg = FuzzConfig {
            seeds,
            base_seed: 0,
            ranks: 0,
            mutants_per_model: 3,
            write_files: false,
            ..FuzzConfig::default()
        };
        let (report, r) = measure(label, || run_fuzz(&cfg).expect("fuzz run"));
        assert!(report.sound(), "bench fuzz run found counterexamples:\n{}", report.table());
        // ops = mutants judged; lemma_applications is not a fuzz metric
        // (kill counts live in FUZZ_REPORT.json) so record 0, not a proxy
        records.push(BenchRecord::new(label, report.mutants_attempted() as usize, r.mean, 0));
        results.push(r);
    }
    print!("{}", table("fuzz throughput (clean verify + mutants per case)", &results));
    match graphguard::bench::write_bench_json("fuzz", &records) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_fuzz.json: {e}"),
    }
}
