//! ShardFlow static-analysis overhead on the Table-2 workloads.
//!
//! The analysis runs before every saturation (each `Verifier` run attaches
//! its findings to the report), so its cost rides on every verification.
//! The claim this bench tracks: the lint is a single O(|G_d|) pass —
//! microseconds against the paper's seconds-scale saturation — and stays
//! linear as the parallelism degree grows. Each row is the mean wall time
//! of `ITERS` analyze() calls over one workload; verdict is "verified"
//! when the clean workload produced zero findings (the soundness
//! contract), "refuted" if any finding fired.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::analysis;
use graphguard::bench::{fmt_dur, write_bench_json, BenchRecord};
use graphguard::models;
use std::time::{Duration, Instant};

const ITERS: u32 = 100;

fn main() {
    println!("ShardFlow lint overhead — Table-2 workloads, {ITERS} iterations each\n");
    let mut records: Vec<BenchRecord> = Vec::new();
    for ranks in [2usize, 4, 8] {
        for w in models::table2_workloads(ranks) {
            // warm-up + correctness: the clean workload must lint clean
            let report = analysis::analyze(&w.gd, Some(&w.ri));
            let t0 = Instant::now();
            for _ in 0..ITERS {
                std::hint::black_box(analysis::analyze(
                    std::hint::black_box(&w.gd),
                    Some(std::hint::black_box(&w.ri)),
                ));
            }
            let mean = t0.elapsed() / ITERS;
            let ops = w.gs.num_nodes() + w.gd.num_nodes();
            println!(
                "{:<24} ops {:>5}  {:>9}/analyze  findings {}",
                w.name,
                ops,
                fmt_dur(mean),
                report.findings.len()
            );
            let verdict = if report.is_clean() { "verified" } else { "refuted" };
            records.push(
                BenchRecord::new(w.name.clone(), ops, mean, 0).with_verdict(verdict),
            );
        }
    }
    let total: Duration = records
        .iter()
        .map(|r| Duration::from_nanos(r.wall_ns as u64))
        .sum();
    println!("\ntotal mean analyze() time across the suite: {}", fmt_dur(total));
    match write_bench_json("lint", &records) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_lint.json: {e}");
            std::process::exit(2);
        }
    }
}
