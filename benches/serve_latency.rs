//! `graphguard serve` request latency on a repeated-layer GPT workload.
//!
//! The service claim this measures: a long-lived server amortizes
//! verification across requests through its shared fingerprint cache, so a
//! warm request is a replay, not a re-verification. One cold request over
//! the L=8 tensor+sequence-parallel GPT pair, then a stream of warm
//! requests against the same server options:
//!   serve_cold      — first request, fresh shared cache
//!   serve_warm_p50  — warm request latency, 50th percentile
//!   serve_warm_p95  — warm request latency, 95th percentile
//!
//! Hard assertion (the ISSUE-9 acceptance gate, also enforced on
//! BENCH_serve.json by CI): warm hit-rate ≥ (L−1)/L. Each measured request
//! runs the full service path — request parse, verifier run, lint pass,
//! response serialization — over an in-memory pipe.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::bench::{bench, fmt_dur, write_bench_json, BenchRecord};
use graphguard::ir::json_io;
use graphguard::models::gpt::{self, GptConfig};
use graphguard::serve::{serve_loop, ServeOptions};
use graphguard::util::json::Json;
use std::io::Cursor;
use std::time::Instant;

const LAYERS: usize = 8;
const WARM_ITERS: usize = 20;

/// One request through the in-process serve loop; returns the parsed
/// response line.
fn serve_one(line: &str, opts: &ServeOptions) -> Json {
    let mut out = Vec::new();
    serve_loop(Cursor::new(line.as_bytes()), &mut out, opts).expect("serve transport");
    let text = String::from_utf8(out).expect("utf-8 response");
    Json::parse(text.lines().next().expect("one response")).expect("valid response json")
}

fn cache_counters(resp: &Json) -> (u64, u64) {
    (
        resp.get("cache_hits").as_f64().unwrap_or(0.0) as u64,
        resp.get("cache_misses").as_f64().unwrap_or(0.0) as u64,
    )
}

fn main() {
    let _ = graphguard::lemmas::standard_rewrites();
    println!("graphguard serve latency — GPT TP+SP, {LAYERS} layers, 2 ranks\n");
    let model_cfg = GptConfig::default();
    let (gs, gd, ri) = gpt::tp_sp_pair(2, LAYERS, &model_cfg).expect("build L=8 workload");
    let request = Json::obj(vec![
        ("id", Json::str("bench")),
        ("gs", json_io::to_json(&gs)),
        ("gd", json_io::to_json(&gd)),
        ("ri", ri.to_json(&gs, &gd)),
    ]);
    let line = format!("{request}\n");
    let ops = gs.num_nodes() + gd.num_nodes();

    let mut records: Vec<BenchRecord> = Vec::new();
    let opts = ServeOptions::default(); // one fresh shared cache for the session

    let t0 = Instant::now();
    let cold = serve_one(&line, &opts);
    let cold_wall = t0.elapsed();
    assert_eq!(cold.get("verdict").as_str(), Some("verified"), "cold request must verify");
    let (cold_hits, cold_misses) = cache_counters(&cold);
    println!(
        "{:>14}: {:>9}  hits {:>3}  misses {:>3}",
        "serve_cold",
        fmt_dur(cold_wall),
        cold_hits,
        cold_misses
    );
    records.push(
        BenchRecord::new("serve_cold", ops, cold_wall, 0).with_cache(cold_hits, cold_misses),
    );

    let mut last = Json::Null;
    let warm = bench("serve_warm", 2, WARM_ITERS, || last = serve_one(&line, &opts));
    assert_eq!(last.get("verdict").as_str(), Some("verified"), "warm request must verify");
    let (warm_hits, warm_misses) = cache_counters(&last);

    // The acceptance bound: warm hit-rate ≥ (L−1)/L.
    let rate = warm_hits as f64 / ((warm_hits + warm_misses).max(1)) as f64;
    let floor = (LAYERS - 1) as f64 / LAYERS as f64;
    assert!(rate >= floor, "warm hit-rate {rate:.3} below acceptance floor {floor:.3}");
    println!(
        "{:>14}: p50 {:>9}  p95 {:>9}  hit-rate {:.1}% (floor {:.1}%)",
        "serve_warm",
        fmt_dur(warm.p50),
        fmt_dur(warm.p95),
        rate * 100.0,
        floor * 100.0
    );
    records.push(
        BenchRecord::new("serve_warm_p50", ops, warm.p50, 0).with_cache(warm_hits, warm_misses),
    );
    records.push(
        BenchRecord::new("serve_warm_p95", ops, warm.p95, 0).with_cache(warm_hits, warm_misses),
    );

    let path = write_bench_json("serve", &records).expect("write BENCH_serve.json");
    println!("wrote {}", path.display());
}
