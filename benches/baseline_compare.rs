//! The §7 scalability claim: GraphGuard's iterative per-operator inference
//! vs the monolithic whole-graph equality-saturation baseline
//! (Aerify/Tensat-style). Shape to reproduce: iterative wins, and the gap
//! (and the baseline's e-graph size) grows with model size.

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use graphguard::baseline::check_refinement_monolithic;
use graphguard::bench::{fmt_dur, write_bench_json, BenchRecord};
use graphguard::egraph::SaturationLimits;
use graphguard::Verifier;
use graphguard::models::llama::{self, LlamaConfig};
use std::time::Instant;

fn main() {
    // warm the shared lemma library so the first row doesn't absorb the
    // one-time construction cost
    let _ = graphguard::lemmas::standard_rewrites();
    println!("iterative (GraphGuard) vs monolithic whole-graph baseline — llama TP=2\n");
    println!(
        "{:<7} {:>7} {:>12} {:>12} {:>10} {:>9}",
        "layers", "ops", "iterative", "monolithic", "speedup", "mono-nodes"
    );
    let cfg = LlamaConfig::default();
    let mut records: Vec<BenchRecord> = Vec::new();
    for layers in [1usize, 2, 3] {
        let (gs, gd, ri) = llama::tp_pair(2, layers, &cfg).unwrap();
        let ops = gs.num_nodes() + gd.num_nodes();

        let t0 = Instant::now();
        let it = Verifier::new().expect(&gs, &gd, &ri);
        let iterative = t0.elapsed();
        let it = match it {
            Ok(out) => out,
            Err(e) => panic!("iterative failed: {e}"),
        };
        records.push(BenchRecord::new(
            format!("llama_l{layers}_iterative"),
            ops,
            iterative,
            it.stats.total_applications(),
        ));

        let t1 = Instant::now();
        let mono = check_refinement_monolithic(
            &gs,
            &gd,
            &ri,
            SaturationLimits::new(14, 400_000),
        );
        let monolithic = t1.elapsed();
        let (mono_str, nodes) = match &mono {
            Ok(out) => (fmt_dur(monolithic), out.egraph_nodes),
            Err(_) => (format!("{} (gave up)", fmt_dur(monolithic)), 0),
        };
        records.push(BenchRecord::new(
            format!("llama_l{layers}_monolithic"),
            ops,
            monolithic,
            mono.as_ref().map(|o| o.stats.total_applications()).unwrap_or(0),
        ));
        println!(
            "{:<7} {:>7} {:>12} {:>12} {:>9.1}x {:>9}",
            layers,
            ops,
            fmt_dur(iterative),
            mono_str,
            monolithic.as_secs_f64() / iterative.as_secs_f64(),
            nodes,
        );
    }
    println!("\n(paper §7: per-operator e-graphs stay small; whole-model saturation does not scale)");
    let path = write_bench_json("baseline_compare", &records).expect("write bench json");
    println!("wrote {}", path.display());
}
