//! Property tests for the e-graph invariants the incremental saturation
//! engine relies on: congruence closure after `rebuild`, memo
//! canonicalization, parent-index completeness, and tag-index consistency —
//! all under randomized `add_op`/`union` sequences (ISSUE 1 satellite).

use graphguard::egraph::{EGraph, ELang, Id};
use graphguard::expr::TensorRef;
use graphguard::ir::Op;
use graphguard::prop_assert;
use graphguard::util::proptest::Prop;
use graphguard::util::rng::Rng;

/// Apply a random interleaving of `add_op`s, `union`s, worklist drains, and
/// `rebuild`s to a fresh e-graph; return it rebuilt.
fn random_egraph(rng: &mut Rng) -> EGraph {
    let shapes: [Vec<i64>; 2] = [vec![4, 4], vec![8]];
    let mut eg = EGraph::new();
    let mut pool: Vec<Id> = Vec::new();
    for i in 0..(3 + rng.below(4)) {
        let sh = shapes[rng.below(2) as usize].clone();
        pool.push(eg.add_leaf(TensorRef::d(i as u32), sh));
    }
    let same_shape = |eg: &EGraph, pool: &[Id], rng: &mut Rng| -> Option<(Id, Id)> {
        for _ in 0..8 {
            let a = pool[rng.below(pool.len() as u64) as usize];
            let b = pool[rng.below(pool.len() as u64) as usize];
            if eg.shape(a).is_some()
                && eg.shape(a).map(|s| s.to_vec()) == eg.shape(b).map(|s| s.to_vec())
            {
                return Some((a, b));
            }
        }
        None
    };
    for _ in 0..(24 + rng.below(40)) {
        match rng.below(10) {
            0..=2 => {
                let x = pool[rng.below(pool.len() as u64) as usize];
                if let Ok(id) = eg.add_op(Op::Neg, vec![x]) {
                    pool.push(id);
                }
            }
            3..=4 => {
                if let Some((a, b)) = same_shape(&eg, &pool, rng) {
                    if let Ok(id) = eg.add_op(Op::Add, vec![a, b]) {
                        pool.push(id);
                    }
                }
            }
            5 => {
                if let Some((a, b)) = same_shape(&eg, &pool, rng) {
                    if let Ok(id) = eg.add_op(Op::SumN, vec![a, b]) {
                        pool.push(id);
                    }
                }
            }
            6 => {
                if let Some((a, b)) = same_shape(&eg, &pool, rng) {
                    if let Ok(id) = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]) {
                        pool.push(id);
                    }
                }
            }
            7..=8 => {
                if let Some((a, b)) = same_shape(&eg, &pool, rng) {
                    let _ = eg.union(a, b);
                    if rng.below(2) == 0 {
                        eg.rebuild();
                    }
                }
            }
            _ => {
                // the worklist drain must never disturb graph state
                let _ = eg.take_dirty_closure();
            }
        }
    }
    eg.rebuild();
    eg
}

#[test]
fn invariants_survive_random_mutation() {
    Prop::new("e-graph invariants under random add_op/union").cases(64).check(|rng| {
        let eg = random_egraph(rng);
        eg.debug_check_invariants()?;
        Ok(())
    });
}

#[test]
fn hashcons_is_stable_after_mutation() {
    Prop::new("re-adding any existing node returns its class").cases(48).check(|rng| {
        let mut eg = random_egraph(rng);
        // snapshot (class, op, children) triples, then re-add each op node
        let mut nodes: Vec<(Id, Op, Vec<Id>)> = Vec::new();
        for id in eg.class_ids() {
            for node in &eg.class(id).nodes {
                if let ELang::Op(op) = &node.lang {
                    nodes.push((id, op.clone(), node.children.clone()));
                }
            }
        }
        let before = eg.n_nodes;
        for (class, op, children) in nodes {
            let got = eg
                .add_op(op.clone(), children.clone())
                .map_err(|e| format!("re-adding {op:?} failed: {e}"))?;
            prop_assert!(
                eg.same(got, class),
                "re-adding {op:?} of class {class} produced distinct class {got}"
            );
        }
        prop_assert!(
            eg.n_nodes == before,
            "memo canonicalization broken: re-adds allocated {} nodes",
            eg.n_nodes - before
        );
        Ok(())
    });
}

#[test]
fn congruence_closes_random_towers() {
    Prop::new("congruence closure after rebuild").cases(48).check(|rng| {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(TensorRef::d(0), vec![4]);
        let b = eg.add_leaf(TensorRef::d(1), vec![4]);
        let depth = 1 + rng.below(5) as usize;
        let ops = [Op::Neg, Op::Gelu, Op::Tanh];
        let tower: Vec<Op> =
            (0..depth).map(|_| ops[rng.below(ops.len() as u64) as usize].clone()).collect();
        let (mut x, mut y) = (a, b);
        for op in &tower {
            x = eg.add_op(op.clone(), vec![x]).unwrap();
            y = eg.add_op(op.clone(), vec![y]).unwrap();
        }
        prop_assert!(!eg.same(x, y), "towers distinct before union");
        eg.union(a, b).map_err(|e| format!("{e}"))?;
        eg.rebuild();
        prop_assert!(eg.same(x, y), "congruence must merge parallel towers (depth {depth})");
        eg.debug_check_invariants()?;
        Ok(())
    });
}
