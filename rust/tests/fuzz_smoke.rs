//! Deterministic-seed smoke tests for the bug-injection fuzzer.
//!
//! The CI job runs the full `graphguard fuzz --seeds 50 --seed 0`; these
//! tests keep a smaller deterministic slice in `cargo test` so a checker
//! or generator regression is caught before the fuzz job.

use graphguard::fuzz::{
    self, applicable_sites, apply_mutation_by_name, build_pair, run_fuzz, sample_spec, Block,
    Flavor, FuzzConfig, ModelSpec, MutKind, NormKind, UnaryKind,
};
use graphguard::Verifier;
use graphguard::util::rng::Rng;

fn smoke_cfg(seeds: u64, base_seed: u64) -> FuzzConfig {
    FuzzConfig {
        seeds,
        base_seed,
        ranks: 0,
        mutants_per_model: 3,
        write_files: false,
        ..FuzzConfig::default()
    }
}

/// The core acceptance property on a deterministic slice: zero false
/// alarms, zero false proofs, zero localization misses.
#[test]
fn fuzz_slice_is_sound() {
    let report = run_fuzz(&smoke_cfg(12, 0)).unwrap();
    assert_eq!(report.models, 12);
    assert!(
        report.sound(),
        "fuzz found counterexamples:\n{}",
        report.table()
    );
    assert_eq!(report.clean_verified, report.models, "all clean pairs verify");
    assert!(report.mutants_attempted() > 0, "sites must exist");
    assert!(
        report.killed_in_region() > 0,
        "at least some behavioral mutants must be killed:\n{}",
        report.table()
    );
    assert_eq!(
        report.lint_false_alarms, 0,
        "static analysis flagged a clean pair:\n{}",
        report.table()
    );
    assert_eq!(
        report.lint_flagged() + report.lint_silent_refuted(),
        report.killed_in_region() + report.locus_misses() + report.silent_rejected(),
        "every rejected mutant must be lint-triaged exactly once:\n{}",
        report.table()
    );
}

/// Same seed → byte-identical report JSON (the reproducibility contract).
#[test]
fn fuzz_is_deterministic_per_seed() {
    let a = run_fuzz(&smoke_cfg(6, 42)).unwrap();
    let b = run_fuzz(&smoke_cfg(6, 42)).unwrap();
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "same seed must reproduce the identical report"
    );
    let c = run_fuzz(&smoke_cfg(6, 43)).unwrap();
    assert_ne!(
        a.to_json().to_string_pretty(),
        c.to_json().to_string_pretty(),
        "different seeds should explore different cases"
    );
}

/// Spec sampling is a pure function of the rng stream.
#[test]
fn sampled_specs_are_deterministic() {
    for seed in [0u64, 7, 99] {
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        let a = sample_spec(&mut r1, 2, seed);
        let b = sample_spec(&mut r2, 2, seed);
        assert_eq!(a, b);
    }
}

/// A hand-picked behavioral mutant on every flavor is rejected with an
/// in-region localization (gs node of the same block, or downstream).
#[test]
fn known_mutants_killed_across_flavors() {
    let cases = [
        (
            Flavor::Sp,
            vec![Block::Linear, Block::Unary(UnaryKind::Gelu)],
            MutKind::WrongUnary,
            "b1_act_r0",
            1usize,
        ),
        (
            Flavor::Tp,
            vec![Block::Mlp(UnaryKind::Silu), Block::Norm(NormKind::Softmax)],
            MutKind::DropAggregation,
            "b0_ar",
            0usize,
        ),
        (
            Flavor::Dp,
            vec![Block::Attention, Block::Unary(UnaryKind::Tanh)],
            MutKind::ScaleDrop,
            "b0_ss",
            0usize,
        ),
        // crossed pipeline boundary: recv of micro-batch 1 reads micro-batch
        // 0's send — stage 2 runs on duplicated data
        (
            Flavor::Pp,
            vec![Block::Linear, Block::Unary(UnaryKind::Gelu)],
            MutKind::CrossedSendRecv,
            "b0_mm_mb1_recv",
            0usize,
        ),
        // dropped boundary: the recv buffer was never written, stage 2 reads
        // the raw stage input
        (
            Flavor::Pp,
            vec![Block::Linear, Block::Unary(UnaryKind::Gelu)],
            MutKind::DroppedBoundary,
            "b0_mm_mb0_recv",
            0usize,
        ),
        // stale ZeRO/FSDP shard: the W1 re-gather picks up a chunk of W0
        (
            Flavor::Fsdp,
            vec![Block::Linear, Block::Mlp(UnaryKind::Silu)],
            MutKind::StaleShardGather,
            "b1_w1a_ag",
            1usize,
        ),
        // off-by-one micro-batch combine factor (1/2 -> 1/3)
        (
            Flavor::Dp,
            vec![Block::Scale(0.5), Block::Norm(NormKind::Softmax)],
            MutKind::MicrobatchScaleOffby,
            "b0_scale",
            0usize,
        ),
        // wrong-expert dispatch: tokens scattered to expert 0 while the
        // combine gathers under expert 1's gates
        (
            Flavor::Moe,
            vec![Block::Moe(UnaryKind::Silu), Block::Unary(UnaryKind::Gelu)],
            MutKind::WrongExpertDispatch,
            "b0_disp1",
            0usize,
        ),
    ];
    for (flavor, blocks, kind, node, min_block) in cases {
        let spec = ModelSpec { seed: 5, ranks: 2, seq: 4, hidden: 4, flavor, blocks };
        let (gs, gd, ri) = build_pair(&spec).unwrap();
        Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("clean {flavor:?} pair must refine: {e}"));
        let (gd_mut, _m) = apply_mutation_by_name(&gd, kind, node)
            .unwrap_or_else(|e| panic!("{flavor:?}: {e:#}"));
        let err = Verifier::new().expect(&gs, &gd_mut, &ri)
            .err()
            .unwrap_or_else(|| panic!("{flavor:?} mutant {kind:?}@{node} must be rejected"));
        let block = fuzz::parse_block(&err.node_name)
            .unwrap_or_else(|| panic!("{flavor:?}: locus '{}' not block-named", err.node_name));
        assert!(
            block >= min_block,
            "{flavor:?}: failure at '{}' (block {block}) precedes mutated block {min_block}",
            err.node_name
        );
    }
}

/// The three buffer-hazard operators on schedule-lowered pipeline graphs:
/// each rewired recv keeps its intended `(boundary, slot, epoch)` tag while
/// reading another micro-batch's buffer, so the crossed tag stays opaque
/// and the failure localizes inside the receiving stage (the first G_s
/// operator after the mutated boundary, never upstream of it).
#[test]
fn buffer_hazard_mutants_killed_with_in_stage_loci() {
    use graphguard::schedule::SchedKind;
    let linear4 =
        vec![Block::Linear, Block::Linear, Block::Linear, Block::Linear];
    let cases = [
        // stale reuse: micro-batch 2's recv reads slot 0 one epoch early
        (
            Flavor::PpSched(SchedKind::OneFOneB),
            vec![Block::Linear, Block::Unary(UnaryKind::Gelu)],
            MutKind::BufferReuseEarly,
            "b0_mm_mb2_recv",
            0usize,
        ),
        // double-buffering index bug: micro-batch 1 reads the wrong slot
        (
            Flavor::PpSched(SchedKind::GPipe),
            vec![Block::Linear, Block::Unary(UnaryKind::Gelu)],
            MutKind::DoubleBufferSwap,
            "b0_mm_mb1_recv",
            0usize,
        ),
        // interleaved misbinding: chunk boundary 1 reads boundary 0's buffer
        (
            Flavor::PpSched(SchedKind::Interleaved),
            linear4,
            MutKind::VirtualStageMisbind,
            "b1_mm_mb0_recv",
            1usize,
        ),
    ];
    for (flavor, blocks, kind, node, min_block) in cases {
        let spec = ModelSpec { seed: 6, ranks: 2, seq: 8, hidden: 4, flavor, blocks };
        let (gs, gd, ri) = build_pair(&spec).unwrap_or_else(|e| panic!("{flavor:?}: {e:#}"));
        Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("clean {flavor:?} pair must refine: {e}"));
        let (gd_mut, _m) = apply_mutation_by_name(&gd, kind, node)
            .unwrap_or_else(|e| panic!("{flavor:?}: {e:#}"));
        let err = Verifier::new().expect(&gs, &gd_mut, &ri)
            .err()
            .unwrap_or_else(|| panic!("{flavor:?} mutant {kind:?}@{node} must be rejected"));
        let block = fuzz::parse_block(&err.node_name)
            .unwrap_or_else(|| panic!("{flavor:?}: locus '{}' not block-named", err.node_name));
        assert!(
            block >= min_block,
            "{flavor:?}: failure at '{}' (block {block}) precedes mutated block {min_block}",
            err.node_name
        );
    }
}

/// The SP rope construction reproduces bug 1 under the slice_shift
/// operator: the mutant's wrong table offset is rejected at the rope.
#[test]
fn rope_slice_shift_reproduces_bug1() {
    let spec = ModelSpec {
        seed: 9,
        ranks: 2,
        seq: 4,
        hidden: 4,
        flavor: Flavor::Sp,
        blocks: vec![Block::Rope, Block::Unary(UnaryKind::Relu)],
    };
    let (gs, gd, ri) = build_pair(&spec).unwrap();
    Verifier::new().expect(&gs, &gd, &ri)
        .unwrap_or_else(|e| panic!("clean rope pair must refine: {e}"));
    let (gd_mut, _) = apply_mutation_by_name(&gd, MutKind::SliceShift, "b0_cos_r1").unwrap();
    let err = Verifier::new().expect(&gs, &gd_mut, &ri)
        .err()
        .expect("shifted rope table offset must be rejected");
    assert!(
        err.node_name.contains("b0_rope") || format!("{err}").contains("b0_rope"),
        "expected rope localization, got '{}'",
        err.node_name
    );
}

/// Counterexample JSON replays: fabricate one via the public replay entry
/// point from a spec + mutation pair.
#[test]
fn replay_roundtrip_reports_outcome() {
    let spec = ModelSpec {
        seed: 4,
        ranks: 2,
        seq: 4,
        hidden: 4,
        flavor: Flavor::Sp,
        blocks: vec![Block::Linear, Block::Norm(NormKind::Softmax)],
    };
    let (_gs, gd, _ri) = build_pair(&spec).unwrap();
    let sites = applicable_sites(&gd);
    assert!(!sites.is_empty());
    let j = graphguard::util::json::Json::obj(vec![
        ("case_seed", graphguard::util::json::Json::str("0x0000000000000004")),
        ("spec", spec.to_json()),
        (
            "mutation",
            graphguard::util::json::Json::obj(vec![
                ("kind", graphguard::util::json::Json::str("softmax_dim_swap")),
                ("node", graphguard::util::json::Json::str("b1_sm_r0")),
            ]),
        ),
    ]);
    let verdict = fuzz::replay_counterexample(&j).unwrap();
    assert!(
        verdict.contains("killed_in_region"),
        "expected the replayed mutant to be killed in-region, got: {verdict}"
    );
}
