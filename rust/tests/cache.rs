//! Fingerprint-cache + parallel-walk contract tests (ISSUE 7).
//!
//! The invariant under test everywhere: the cache and the `jobs > 1`
//! wavefront walk change *wall time only*. Verdicts, relations
//! (certificates), cumulative lemma stats, failure loci, and error text are
//! byte-identical across {cold, warm} × {cache, no-cache} × jobs ∈ {1, 4}.

use graphguard::cache::FingerprintCache;
use graphguard::coordinator::{canonical_report, Coordinator};
use graphguard::egraph::SaturationLimits;
use graphguard::infer::{
    verify_numeric, EscalationPolicy, InconclusiveReason, InferConfig, Verdict,
};
use graphguard::ir::Graph;
use graphguard::relation::Relation;
use graphguard::Verifier;
use graphguard::models::gpt::{self, GptConfig};
use graphguard::models::{regression, table2_workloads};
use std::sync::Arc;
use std::time::Duration;

/// Render everything verdict-relevant about an outcome — and nothing
/// timing- or counter-dependent — so runs can be compared byte for byte.
fn render(v: &Verdict, gs: &Graph, gd: &Graph) -> String {
    match v {
        Verdict::Verified(o) => {
            let mut counts: Vec<(&str, u64)> =
                o.stats.applied.iter().map(|(&k, &v)| (k, v)).collect();
            counts.sort_unstable();
            let per_node: Vec<String> = o
                .per_node
                .iter()
                .map(|t| format!("{}:{}:{}", t.node_name, t.egraph_nodes, t.explored_gd))
                .collect();
            format!(
                "verified\nRo={}\nRfull={}\niters={} saturated={} counts={:?}\nper_node={:?}",
                o.relation.to_json(gs, gd).to_string_pretty(),
                o.relation_full.to_json(gs, gd).to_string_pretty(),
                o.stats.iterations,
                o.stats.saturated,
                counts,
                per_node,
            )
        }
        Verdict::Refuted(e) => format!("refuted\nnode={}\n{e}", e.node),
        Verdict::Inconclusive(i) => format!(
            "{}\nregion={}\ndetail={}\npartial={}",
            v.tag(),
            i.region,
            i.detail,
            i.partial_relation.to_json(gs, gd).to_string_pretty(),
        ),
    }
}

/// The retired free-function shapes, routed through the [`Verifier`]
/// builder (migration table in EXPERIMENTS.md §Serve) — these tests
/// exercise every (cfg, policy) combination, so the thin adapters keep
/// each call site readable.
fn isolated(gs: &Graph, gd: &Graph, ri: &Relation, cfg: &InferConfig) -> Verdict {
    Verifier::with_config(cfg.clone()).isolated(true).run(gs, gd, ri)
}

fn escalating(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
    policy: &EscalationPolicy,
) -> (Verdict, usize) {
    Verifier::with_config(cfg.clone()).escalation(policy.clone()).run_counted(gs, gd, ri)
}

fn cached_cfg(cache: &Arc<FingerprintCache>) -> InferConfig {
    InferConfig { cache: Some(Arc::clone(cache)), ..InferConfig::default() }
}

#[test]
fn cache_is_off_by_default_and_counters_stay_zero() {
    let cfg = InferConfig::default();
    assert!(cfg.cache.is_none(), "library default must be uncached");
    let (gs, gd, ri) = gpt::tp_pair(2, 1);
    match isolated(&gs, &gd, &ri, &cfg) {
        Verdict::Verified(o) => {
            assert_eq!((o.cache_hits, o.cache_misses), (0, 0));
        }
        v => panic!("clean pair must verify, got {}", v.tag()),
    }
}

/// Satellite: cold-vs-warm byte-identical verdicts and certificates across
/// the Table-2 suite (same escalation policy the coordinator uses).
#[test]
fn cold_and_warm_table2_outcomes_are_byte_identical() {
    let cache = Arc::new(FingerprintCache::new());
    let cfg = cached_cfg(&cache);
    let nocache = InferConfig::default();
    let policy = EscalationPolicy::default();
    for w in table2_workloads(2) {
        let base = escalating(&w.gs, &w.gd, &w.ri, &nocache, &policy).0;
        let cold = escalating(&w.gs, &w.gd, &w.ri, &cfg, &policy).0;
        let warm = escalating(&w.gs, &w.gd, &w.ri, &cfg, &policy).0;
        let b = render(&base, &w.gs, &w.gd);
        let c = render(&cold, &w.gs, &w.gd);
        let h = render(&warm, &w.gs, &w.gd);
        assert_eq!(b, c, "{}: cold cached run diverged from uncached", w.name);
        assert_eq!(c, h, "{}: warm run diverged from cold", w.name);
    }
    assert!(cache.stats().hits > 0, "warm pass must have replayed regions");
}

/// Acceptance: on an L=8 repeated-layer GPT workload the warm run reports
/// hit-rate ≥ (L−1)/L, and already the cold run verifies each repeated
/// layer only once (misses bounded by one layer plus the embed/head
/// epilogue).
#[test]
fn l8_gpt_meets_the_hit_rate_floor() {
    const LAYERS: usize = 8;
    let model_cfg = GptConfig::default();
    let (gs, gd, ri) = gpt::tp_sp_pair(2, LAYERS, &model_cfg).expect("build workload");
    let cache = Arc::new(FingerprintCache::new());
    let cfg = cached_cfg(&cache);
    let policy = EscalationPolicy::default();

    let (cold, _) = escalating(&gs, &gd, &ri, &cfg, &policy);
    let Verdict::Verified(cold) = cold else { panic!("cold run must verify") };
    let bound = gpt::seq(1, &model_cfg).num_nodes() as u64 + 5;
    assert!(
        cold.cache_misses <= bound,
        "cold run recomputed repeated layers: {} misses > bound {bound}",
        cold.cache_misses
    );
    assert!(cold.cache_hits > 0, "cold run must replay repeated layers");

    let (warm, _) = escalating(&gs, &gd, &ri, &cfg, &policy);
    let Verdict::Verified(warm) = warm else { panic!("warm run must verify") };
    let rate =
        warm.cache_hits as f64 / (warm.cache_hits + warm.cache_misses).max(1) as f64;
    let floor = (LAYERS - 1) as f64 / LAYERS as f64;
    assert!(rate >= floor, "warm hit-rate {rate:.3} < acceptance floor {floor:.3}");

    // A replayed certificate must still hold numerically (§3.3).
    verify_numeric(&gs, &gd, &ri, &warm.relation, 1234).expect("cached certificate replays");
}

/// Soundness: exhausted regions are never cached. A deadline-truncated
/// result is a wall-clock artifact and the deadline is deliberately not
/// part of the fingerprint key, so storing one could replay a truncated
/// answer under a config with no deadline at all. Under a zero deadline
/// every region exhausts before completing (see the
/// `elapsed_deadline_marks_exhaustion_before_any_work` e-graph unit test),
/// so the walk is `Inconclusive` and the cache must stay empty.
#[test]
fn inconclusive_regions_are_never_cached() {
    let w = table2_workloads(2).remove(0);
    let cache = Arc::new(FingerprintCache::new());
    let starved = InferConfig {
        region_deadline: Some(Duration::ZERO),
        cache: Some(Arc::clone(&cache)),
        ..InferConfig::default()
    };
    match isolated(&w.gs, &w.gd, &w.ri, &starved) {
        Verdict::Inconclusive(i) => assert_eq!(i.reason, InconclusiveReason::Timeout),
        v => panic!("zero deadline must starve the walk, got {}", v.tag()),
    }
    assert_eq!(cache.len(), 0, "an exhausted walk must not leave entries behind");
    assert_eq!(cache.stats().inserts, 0);

    // The same cache object then serves a real run: a fresh verification
    // (misses, not stale replays) that still reaches Verified.
    match isolated(&w.gs, &w.gd, &w.ri, &cached_cfg(&cache)) {
        Verdict::Verified(o) => {
            assert!(o.cache_misses > 0, "nothing stale may have been replayed")
        }
        v => panic!("clean pair must verify at defaults, got {}", v.tag()),
    }

    // NodeBudget starvation likewise never stores the starved region: a
    // warm rerun through the same cache reproduces the identical verdict
    // instead of replaying anything stale.
    let w = table2_workloads(2).remove(0);
    let cache = Arc::new(FingerprintCache::new());
    let tiny = InferConfig {
        limits: SaturationLimits::new(8, 10),
        cache: Some(Arc::clone(&cache)),
        ..InferConfig::default()
    };
    let a = isolated(&w.gs, &w.gd, &w.ri, &tiny);
    let b = isolated(&w.gs, &w.gd, &w.ri, &tiny);
    match &a {
        Verdict::Inconclusive(i) => assert_eq!(i.reason, InconclusiveReason::NodeBudget),
        v => panic!("a 10-node budget must starve, got {}", v.tag()),
    }
    assert_eq!(render(&a, &w.gs, &w.gd), render(&b, &w.gs, &w.gd));
}

/// Soundness: refuted regions are never cached either, and a refutation is
/// byte-identical with and without the cache (the successful prefix MAY be
/// cached — those are genuine proofs).
#[test]
fn refutations_are_cache_invariant() {
    let (gs, gd, ri) = regression::grad_accum_buggy_pair(2).unwrap();
    let cache = Arc::new(FingerprintCache::new());
    let cfg = cached_cfg(&cache);
    let policy = EscalationPolicy::default();
    let plain = escalating(&gs, &gd, &ri, &InferConfig::default(), &policy).0;
    let cold = escalating(&gs, &gd, &ri, &cfg, &policy).0;
    let warm = escalating(&gs, &gd, &ri, &cfg, &policy).0;
    assert!(matches!(plain, Verdict::Refuted(_)), "pair is buggy by construction");
    let p = render(&plain, &gs, &gd);
    assert_eq!(p, render(&cold, &gs, &gd), "cache must not change a refutation");
    assert_eq!(p, render(&warm, &gs, &gd), "warm cache must not change a refutation");
}

/// Acceptance: `jobs = 4` produces byte-identical outcomes to `jobs = 1`
/// across the Table-2 suite — with and without the cache — and the
/// coordinator's canonical suite report is identical too.
#[test]
fn jobs_4_is_byte_identical_to_jobs_1_across_table2() {
    let policy = EscalationPolicy::default();
    for w in table2_workloads(2) {
        let seq_cfg = InferConfig::default();
        let par_cfg = InferConfig { jobs: 4, ..InferConfig::default() };
        let seq = escalating(&w.gs, &w.gd, &w.ri, &seq_cfg, &policy).0;
        let par = escalating(&w.gs, &w.gd, &w.ri, &par_cfg, &policy).0;
        assert_eq!(
            render(&seq, &w.gs, &w.gd),
            render(&par, &w.gs, &w.gd),
            "{}: jobs=4 diverged from jobs=1",
            w.name
        );
        // cached parallel run against a fresh private cache
        let cache = Arc::new(FingerprintCache::new());
        let par_cached =
            InferConfig { jobs: 4, cache: Some(Arc::clone(&cache)), ..InferConfig::default() };
        let pc = escalating(&w.gs, &w.gd, &w.ri, &par_cached, &policy).0;
        assert_eq!(
            render(&seq, &w.gs, &w.gd),
            render(&pc, &w.gs, &w.gd),
            "{}: jobs=4+cache diverged from jobs=1",
            w.name
        );
    }
}

/// The suite-level determinism gate the CI step scripts drive through the
/// CLI: coordinator batches at (threads, jobs) ∈ {(1,1), (4,4)} with a
/// shared cache render identical canonical reports.
#[test]
fn canonical_suite_report_is_invariant_across_threads_and_jobs() {
    let mk = |threads: usize, jobs: usize, cache: Option<Arc<FingerprintCache>>| {
        let cfg = InferConfig { jobs, cache, ..InferConfig::default() };
        let coord = Coordinator::new(threads, cfg);
        canonical_report(&coord.run_batch(table2_workloads(2)))
    };
    let baseline = mk(1, 1, None);
    let cache = Arc::new(FingerprintCache::new());
    let parallel = mk(4, 4, Some(Arc::clone(&cache)));
    assert_eq!(baseline, parallel, "threads=4/jobs=4/cache must not change the report");
    let warm = mk(4, 4, Some(cache));
    assert_eq!(baseline, warm, "a warm shared cache must not change the report");
}

/// Failure localization is jobs-invariant: the buggy grad-accum pair
/// refutes at the same operator with the same error text under the
/// parallel walk.
#[test]
fn refutation_locus_is_jobs_invariant() {
    let (gs, gd, ri) = regression::grad_accum_buggy_pair(2).unwrap();
    let policy = EscalationPolicy::default();
    let seq = escalating(&gs, &gd, &ri, &InferConfig::default(), &policy).0;
    let par = escalating(
        &gs,
        &gd,
        &ri,
        &InferConfig { jobs: 4, ..InferConfig::default() },
        &policy,
    )
    .0;
    let (Verdict::Refuted(a), Verdict::Refuted(b)) = (&seq, &par) else {
        panic!("both walks must refute: {} / {}", seq.tag(), par.tag());
    };
    assert_eq!(a.node, b.node, "locus node must match");
    assert_eq!(a.node_name, b.node_name);
    assert_eq!(format!("{a}"), format!("{b}"), "error text must match byte for byte");
}

/// Resource verdicts are jobs-invariant too: a starved budget yields the
/// same Inconclusive(NodeBudget) region and detail under the parallel walk.
#[test]
fn node_budget_verdict_is_jobs_invariant() {
    let w = table2_workloads(2).remove(0);
    let starve = |jobs: usize| {
        let cfg = InferConfig {
            limits: SaturationLimits::new(8, 10),
            jobs,
            ..InferConfig::default()
        };
        escalating(&w.gs, &w.gd, &w.ri, &cfg, &EscalationPolicy::single_shot())
            .0
    };
    let seq = starve(1);
    let par = starve(4);
    let (Verdict::Inconclusive(a), Verdict::Inconclusive(b)) = (&seq, &par) else {
        panic!("both walks must starve: {} / {}", seq.tag(), par.tag());
    };
    assert_eq!(a.reason, InconclusiveReason::NodeBudget);
    assert_eq!(a.reason, b.reason);
    assert_eq!(a.region, b.region, "starved region must match");
    assert_eq!(a.detail, b.detail);
    assert_eq!(render(&seq, &w.gs, &w.gd), render(&par, &w.gs, &w.gd));
}
