//! ShardFlow static-analysis integration tests.
//!
//! Three contracts pinned here:
//!
//! 1. **Soundness** — the lint is silent on every correct pair: all clean
//!    Table-2 workloads (at two parallelism degrees) and the clean fuzz
//!    fixtures produce zero findings. A finding on a correct graph is a
//!    false alarm, which `FuzzReport::sound` counts as a soundness
//!    violation.
//! 2. **Coverage** — every `*_killed` regression fixture (the wiring-bug
//!    families: crossed/stale stage boundaries, stale FSDP shards, MoE
//!    dispatch/gate bugs, schedule buffer hazards) is flagged by the lint
//!    alone, before any saturation runs.
//! 3. **Separation** — the lint rides along with verification as
//!    diagnostics only: the verdict and the canonical report are computed
//!    exactly as without it (see `coordinator` unit tests for the
//!    canonical-report exclusion; here we pin that the `Verifier`'s
//!    verdict tag is unchanged on a clean pair and a mutant).

use graphguard::analysis;
use graphguard::fuzz::{self, build_pair, ModelSpec};
use graphguard::Verifier;
use graphguard::models;
use graphguard::util::json::Json;

// ---------------------------------------------------------------------------
// 1. Soundness: silent on clean pairs
// ---------------------------------------------------------------------------

#[test]
fn clean_table2_workloads_have_zero_findings() {
    for ranks in [2usize, 4] {
        for w in models::table2_workloads(ranks) {
            let r = analysis::analyze(&w.gd, Some(&w.ri));
            assert!(
                r.is_clean(),
                "{} (ranks {ranks}): lint false alarm on a clean workload:\n{}",
                w.name,
                r.render()
            );
        }
    }
}

fn lint_fixture(text: &str) -> (String, analysis::LintReport) {
    let j = Json::parse(text).unwrap_or_else(|e| panic!("fixture must parse: {e}"));
    fuzz::lint_counterexample(&j).unwrap_or_else(|e| panic!("fixture must lint: {e:#}"))
}

#[test]
fn clean_fixtures_have_zero_findings() {
    for text in [
        include_str!("fixtures/pp_clean_verifies.json"),
        include_str!("fixtures/pp_sched_clean_verifies.json"),
        include_str!("fixtures/moe_clean_verifies.json"),
    ] {
        let (name, r) = lint_fixture(text);
        assert!(r.is_clean(), "{name}: lint false alarm on a clean fixture:\n{}", r.render());
    }
}

// ---------------------------------------------------------------------------
// 2. Coverage: every killed wiring-bug fixture is flagged pre-saturation
// ---------------------------------------------------------------------------

#[test]
fn killed_fixtures_are_flagged() {
    for text in [
        include_str!("fixtures/pp_crossed_send_recv_killed.json"),
        include_str!("fixtures/fsdp_stale_shard_killed.json"),
        include_str!("fixtures/moe_wrong_expert_dispatch_killed.json"),
        include_str!("fixtures/moe_gate_unnormalized_killed.json"),
        include_str!("fixtures/pp_sched_buffer_reuse_early_killed.json"),
        include_str!("fixtures/pp_sched_double_buffer_swap_killed.json"),
        include_str!("fixtures/pp_sched_virtual_stage_misbind_killed.json"),
    ] {
        let (name, r) = lint_fixture(text);
        assert!(
            !r.is_clean(),
            "{name}: wiring-bug fixture must be flagged by the static analysis alone"
        );
        for f in &r.findings {
            assert!(!f.node.is_empty(), "{name}: every finding needs a locus");
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Separation: lint findings never move the verdict
// ---------------------------------------------------------------------------

/// The analysis is deterministic: same graph, same (normalized) report.
#[test]
fn analysis_is_deterministic() {
    let j = Json::parse(include_str!("fixtures/pp_crossed_send_recv_killed.json")).unwrap();
    let (_, a) = fuzz::lint_counterexample(&j).unwrap();
    let (_, b) = fuzz::lint_counterexample(&j).unwrap();
    assert_eq!(a, b, "lint report must be byte-stable per graph");
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}

/// On a clean pair the verdict stays Verified and the attached lint is
/// empty; on a wiring mutant the verdict stays Refuted with the same
/// e-graph locus discipline as before — the lint adds diagnostics, the
/// e-graph stays the oracle.
#[test]
fn lint_rides_along_without_moving_the_verdict() {
    let j = Json::parse(include_str!("fixtures/pp_clean_verifies.json")).unwrap();
    let spec = ModelSpec::from_json(j.get("spec")).unwrap();
    let (gs, gd, ri) = build_pair(&spec).unwrap();
    match Verifier::new().run(&gs, &gd, &ri) {
        graphguard::infer::Verdict::Verified(out) => {
            assert!(out.lint.is_empty(), "clean pair must carry an empty lint list");
        }
        v => panic!("clean fixture pair must verify, got {}", v.tag()),
    }
}
