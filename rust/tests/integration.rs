//! Cross-module integration tests: graph JSON interchange, strategy sweep,
//! HLO frontend on real JAX artifacts (when built), and soundness
//! properties over randomized workloads.

use graphguard::infer::{verify_numeric, InferConfig};
use graphguard::Verifier;
use graphguard::ir::{json_io, Graph, Op};
use graphguard::models;
use graphguard::relation::Relation;
use graphguard::util::json::Json;
use graphguard::util::proptest::Prop;

/// Every Table-2 workload must refine at degrees 2 and 4, and the inferred
/// relation must numerically reconstruct the sequential outputs (soundness
/// certificate replay).
#[test]
fn suite_refines_across_degrees_with_certificates() {
    for ranks in [2usize, 4] {
        for w in models::table2_workloads(ranks) {
            let out = Verifier::new().expect(&w.gs, &w.gd, &w.ri)
                .unwrap_or_else(|e| panic!("{} @ {ranks}: {e}", w.name));
            verify_numeric(&w.gs, &w.gd, &w.ri, &out.relation, ranks as u64 * 131)
                .unwrap_or_else(|e| panic!("{} @ {ranks} numeric: {e:#}", w.name));
        }
    }
}

/// Graphs survive the JSON round trip and verify identically.
#[test]
fn json_roundtrip_preserves_verification() {
    let (gs, gd, ri) = models::llama::tp_pair(2, 1, &models::llama::LlamaConfig::default()).unwrap();
    let gs2 = json_io::from_json(&json_io::to_json(&gs)).unwrap();
    let gd2 = json_io::from_json(&json_io::to_json(&gd)).unwrap();
    let ri2 = Relation::from_json(&ri.to_json(&gs, &gd), &gs2, &gd2).unwrap();
    let out = Verifier::new().expect(&gs2, &gd2, &ri2)
        .unwrap_or_else(|e| panic!("{e}"));
    assert!(out.relation.is_complete_for(&gs2.outputs));
}

/// Property: sequence-sharding any randomly-built elementwise pipeline is
/// a refinement, and randomly corrupting one slice offset breaks it.
#[test]
fn property_random_elementwise_pipelines() {
    Prop::new("sp elementwise pipelines refine").cases(24).check(|rng| {
        let depth = 1 + rng.below(4) as usize;
        let rows = 4 * (1 + rng.below(3)) as i64; // divisible by 2
        let cols = 2 + rng.below(6) as i64;
        let unaries = [Op::Gelu, Op::Tanh, Op::Silu, Op::Relu, Op::Sigmoid, Op::Neg];

        let mut gs = Graph::new("gs");
        let x = gs.input("x", vec![rows * 2, cols]);
        let mut cur = x;
        let ops: Vec<Op> =
            (0..depth).map(|_| unaries[rng.below(unaries.len() as u64) as usize].clone()).collect();
        for (i, op) in ops.iter().enumerate() {
            cur = gs.op(&format!("u{i}"), op.clone(), vec![cur]);
        }
        gs.mark_output(cur);

        let mut gd = Graph::new("gd");
        let x0 = gd.input("x_r0", vec![rows, cols]);
        let x1 = gd.input("x_r1", vec![rows, cols]);
        let mut shards = vec![x0, x1];
        for (i, op) in ops.iter().enumerate() {
            shards = shards
                .iter()
                .enumerate()
                .map(|(r, &s)| gd.op(&format!("u{i}_r{r}"), op.clone(), vec![s]))
                .collect();
        }
        let y = gd.all_gather("y", shards, 0);
        gd.mark_output(y);

        let ri = Relation::from_json(
            &Json::parse(r#"{"x": ["concat(x_r0, x_r1; dim=0)"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .map_err(|e| format!("{e}"))?;
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .map_err(|e| format!("depth {depth}: {e}"))?;
        verify_numeric(&gs, &gd, &ri, &out.relation, rng.next_u64()).map_err(|e| format!("{e:#}"))?;
        Ok(())
    });
}

/// Property: a corrupted distributed matmul (wrong shard pairing) is always
/// detected — soundness means no false "refines" verdicts.
#[test]
fn property_corrupted_matmul_detected() {
    Prop::new("wrong shard pairing detected").cases(16).check(|rng| {
        let m = 2 + rng.below(4) as i64;
        let k = 2 * (1 + rng.below(3)) as i64;
        let n = 2 + rng.below(4) as i64;
        let mut gs = Graph::new("gs");
        let a = gs.input("A", vec![m, 2 * k]);
        let b = gs.input("B", vec![2 * k, n]);
        let c = gs.matmul("C", a, b);
        gs.mark_output(c);

        let mut gd = Graph::new("gd");
        let a1 = gd.input("A_1", vec![m, k]);
        let a2 = gd.input("A_2", vec![m, k]);
        let b1 = gd.input("B_1", vec![k, n]);
        let _b2 = gd.input("B_2", vec![k, n]);
        let c1 = gd.matmul("C_1", a1, b1);
        // BUG: both partial products use B_1
        let c2 = gd.matmul("C_2", a2, b1);
        let s = gd.all_reduce("C_sum", vec![c1, c2]);
        gd.mark_output(s);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{"A": ["concat(A_1, A_2; dim=1)"], "B": ["concat(B_1, B_2; dim=0)"]}"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .map_err(|e| format!("{e}"))?;
        match Verifier::new().expect(&gs, &gd, &ri) {
            Err(_) => Ok(()),
            Ok(_) => Err("corrupted pairing verified as refinement!".into()),
        }
    });
}

/// HLO frontend end-to-end. When the JAX artifact exists (after
/// `make artifacts`) the real regression module is parsed; otherwise an
/// embedded module exercises the same parse → IR → eval path so this test
/// always asserts something instead of silently skipping (ISSUE-2 triage:
/// the artifact-less skip used to pass vacuously on fresh checkouts).
#[test]
fn hlo_frontend_parses_jax_artifact_or_fallback() {
    let path = "artifacts/regression_seq.hlo.txt";
    if let Ok(text) = std::fs::read_to_string(path) {
        let g = graphguard::hlo::parse_hlo_text(&text, "regression_seq").unwrap();
        assert_eq!(g.inputs.len(), 4, "x, y, w, b");
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.shape(g.outputs[0]), &[] as &[i64], "scalar loss");
        return;
    }
    // fallback: embedded module covering dot/transpose/slice/concat/add
    let text = r#"HloModule fallback

ENTRY main {
  x = f32[4,6]{1,0} parameter(0)
  w = f32[6,4]{1,0} parameter(1)
  mm = f32[4,4]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  t = f32[4,4]{1,0} transpose(mm), dimensions={1,0}
  s = f32[2,4]{1,0} slice(t), slice={[0:2], [0:4]}
  c = f32[4,4]{1,0} concatenate(s, s), dimensions={0}
  a = f32[4,4]{1,0} add(c, mm)
  ROOT out = (f32[4,4]{1,0}) tuple(a)
}
"#;
    let g = graphguard::hlo::parse_hlo_text(text, "fallback").unwrap();
    assert_eq!(g.inputs.len(), 2);
    assert_eq!(g.outputs.len(), 1);
    assert_eq!(g.shape(g.outputs[0]), &[4, 4]);
    // the parsed graph must evaluate (shapes and ops are all concrete)
    let inputs = graphguard::expr::eval::random_inputs(&g, 3);
    let vals = graphguard::expr::eval::eval_graph(&g, &inputs).unwrap();
    assert_eq!(vals[g.outputs[0] as usize].shape(), &[4, 4]);
}

/// Captured graphs (JSON interchange) verify — the same check
/// `examples/cross_validate.rs` performs, minus the PJRT execution. With
/// artifacts present the real Llama capture is used; otherwise a
/// fuzz-generated SP pair is round-tripped through the same JSON text
/// format, so the "captured JSON verifies" contract is always asserted
/// (ISSUE-2 triage: previously a silent skip without artifacts).
#[test]
fn captured_graphs_refine_from_json() {
    let load = |p: &str| -> Option<Json> {
        std::fs::read_to_string(p).ok().and_then(|t| Json::parse(&t).ok())
    };
    let (gs_j, gd_j, ri_j, check_numeric) = match (
        load("artifacts/graphs/llama_seq.json"),
        load("artifacts/graphs/llama_tp2.json"),
        load("artifacts/graphs/llama_ri.json"),
    ) {
        // real captures carry token-id inputs whose replication relation is
        // asserted elsewhere; numeric replay is only run on the fallback
        (Some(gs_j), Some(gd_j), Some(ri_j)) => (gs_j, gd_j, ri_j, false),
        _ => {
            // artifact-less fallback: capture a generated pair to JSON text
            use graphguard::fuzz::{build_pair, Block, Flavor, ModelSpec, NormKind, UnaryKind};
            let spec = ModelSpec {
                seed: 21,
                ranks: 2,
                seq: 4,
                hidden: 4,
                flavor: Flavor::Sp,
                blocks: vec![
                    Block::Linear,
                    Block::Unary(UnaryKind::Gelu),
                    Block::Norm(NormKind::RmsNorm),
                ],
            };
            let (gs, gd, ri) = build_pair(&spec).unwrap();
            (
                Json::parse(&json_io::to_json(&gs).to_string()).unwrap(),
                Json::parse(&json_io::to_json(&gd).to_string()).unwrap(),
                Json::parse(&ri.to_json(&gs, &gd).to_string()).unwrap(),
                true,
            )
        }
    };
    let gs = json_io::from_json(&gs_j).unwrap();
    let gd = json_io::from_json(&gd_j).unwrap();
    let ri = Relation::from_json(&ri_j, &gs, &gd).unwrap();
    let out = Verifier::new().expect(&gs, &gd, &ri)
        .unwrap_or_else(|e| panic!("{e}"));
    assert!(out.relation.is_complete_for(&gs.outputs));
    if check_numeric {
        verify_numeric(&gs, &gd, &ri, &out.relation, 55).unwrap();
    }
}

/// Coordinator invariants under random batch sizes/thread counts.
#[test]
fn property_coordinator_order_and_determinism() {
    Prop::new("coordinator preserves order").cases(6).check(|rng| {
        let threads = 1 + rng.below(8) as usize;
        let coord = graphguard::coordinator::Coordinator::new(threads, InferConfig::default());
        let jobs = models::table2_workloads(2);
        let names: Vec<String> = jobs.iter().map(|w| w.name.clone()).collect();
        let results = coord.run_batch(jobs);
        for (r, n) in results.iter().zip(&names) {
            if &r.name != n {
                return Err(format!("order broken: {} vs {}", r.name, n));
            }
            if !r.ok {
                return Err(format!("{} failed: {:?}", r.name, r.error));
            }
        }
        Ok(())
    });
}
