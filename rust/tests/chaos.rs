//! Chaos-injection tests (compiled only with `--features chaos`): arm
//! named lemma appliers to panic or spin, then prove the verification
//! stack degrades to `Inconclusive` on exactly the poisoned jobs and keeps
//! going — no unwinding into the coordinator, no budget blowup reported as
//! a refutation, no aborted suite.
//!
//! Chaos state is process-global, so every test serializes on [`LOCK`]
//! and pins `threads = 1` for a deterministic workload order.

use graphguard::cache::FingerprintCache;
use graphguard::chaos::{arm, disarm_all, fired, FaultAction};
use graphguard::coordinator::{Coordinator, JobVerdict};
use graphguard::fuzz::{self, Flavor, FuzzConfig};
use graphguard::infer::{EscalationPolicy, InconclusiveReason, InferConfig, Verdict};
use graphguard::models;
use graphguard::Verifier;
use std::sync::{Arc, Mutex};
use std::time::Duration;

static LOCK: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    // A panicking chaos test poisons the mutex by design; later tests
    // still need exclusive access, not a propagated failure.
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    disarm_all();
    guard
}

/// The whole Table-2 suite survives one panicking applier and one spinning
/// applier: the two poisoned jobs come back `Inconclusive` (with the right
/// reasons), every other workload still verifies, and the batch completes.
#[test]
fn suite_survives_injected_panic_and_spin() {
    let _guard = serialized();
    // `recv_of_send_identity` first matches in the first pipeline-parallel
    // workload; `allgather_of_chunks_identity` pattern-matches any
    // AllGather, so its (fire-once) spin lands in the first workload whose
    // saturation reaches an AllGather applier.
    arm("recv_of_send_identity", 1, FaultAction::Panic);
    arm("allgather_of_chunks_identity", 1, FaultAction::Spin(Duration::from_secs(1)));

    let cfg = InferConfig {
        region_deadline: Some(Duration::from_millis(500)),
        ..InferConfig::default()
    };
    // single-shot: Timeout/Panic are terminal anyway, but an escalating
    // NodeBudget retry must not mask a chaos fault either.
    let coord = Coordinator::new(1, cfg).with_escalation(EscalationPolicy::single_shot());
    let jobs = models::table2_workloads(2);
    let n_jobs = jobs.len();
    let results = coord.run_batch(jobs);
    disarm_all();

    assert_eq!(results.len(), n_jobs, "a chaos fault must not abort the batch");
    assert!(fired("recv_of_send_identity"), "panic fault never fired");
    assert!(fired("allgather_of_chunks_identity"), "spin fault never fired");

    let panicked: Vec<_> = results
        .iter()
        .filter(|r| r.verdict == JobVerdict::Inconclusive(InconclusiveReason::Panic))
        .collect();
    let timed_out: Vec<_> = results
        .iter()
        .filter(|r| r.verdict == JobVerdict::Inconclusive(InconclusiveReason::Timeout))
        .collect();
    assert_eq!(
        panicked.len(),
        1,
        "exactly one fire-once panic: {:?}",
        results.iter().map(|r| (&r.name, r.verdict.tag())).collect::<Vec<_>>()
    );
    assert_eq!(
        timed_out.len(),
        1,
        "exactly one fire-once spin: {:?}",
        results.iter().map(|r| (&r.name, r.verdict.tag())).collect::<Vec<_>>()
    );
    assert!(
        panicked[0].error.as_deref().unwrap_or("").contains("chaos: injected panic"),
        "panic payload must survive isolation: {:?}",
        panicked[0].error
    );
    for r in &results {
        if matches!(r.verdict, JobVerdict::Inconclusive(_)) {
            continue;
        }
        assert_eq!(
            r.verdict,
            JobVerdict::Verified,
            "unpoisoned workload {} must still verify",
            r.name
        );
    }
}

/// An injected panic must never poison the fingerprint cache. While any
/// fault is armed the cache is bypassed entirely (no lookups, no inserts —
/// see `chaos::any_armed`), so the poisoned run stores nothing; after
/// disarming, the same cache object serves a fresh, fully verified run
/// whose warm rerun replays it.
#[test]
fn injected_panic_never_poisons_the_cache() {
    let _guard = serialized();
    let (gs, gd, ri) = models::gpt::pp_tp_pair(2, 2, 2).unwrap();
    let cache = Arc::new(FingerprintCache::new());
    let cfg = InferConfig { cache: Some(Arc::clone(&cache)), ..InferConfig::default() };

    arm("recv_of_send_identity", 1, FaultAction::Panic);
    let v = Verifier::with_config(cfg.clone()).isolated(true).run(&gs, &gd, &ri);
    disarm_all();
    assert!(fired("recv_of_send_identity"), "panic fault never fired");
    match v {
        Verdict::Inconclusive(i) => assert_eq!(i.reason, InconclusiveReason::Panic),
        v => panic!("poisoned run must be Inconclusive(Panic), got {}", v.tag()),
    }
    assert_eq!(cache.len(), 0, "an armed-chaos run must bypass the cache entirely");
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.inserts), (0, 0, 0), "no lookups while armed");

    // Disarmed, the same cache object serves a fresh verification (misses,
    // not stale replays of anything the poisoned run touched)...
    match Verifier::with_config(cfg.clone()).isolated(true).run(&gs, &gd, &ri) {
        Verdict::Verified(o) => {
            assert!(o.cache_misses > 0, "disarmed run must verify from scratch")
        }
        v => panic!("disarmed run must verify, got {}", v.tag()),
    }
    // ...and a warm rerun replays it.
    match Verifier::with_config(cfg.clone()).isolated(true).run(&gs, &gd, &ri) {
        Verdict::Verified(o) => assert!(o.cache_hits > 0, "warm rerun must hit"),
        v => panic!("warm rerun must verify, got {}", v.tag()),
    }
}

/// A fuzz campaign survives a panicking applier mid-campaign: the poisoned
/// clean pair is scored `clean_inconclusive` (a soundness-of-service
/// violation, so the report is unsound), the campaign still completes, and
/// the remaining seeds are unaffected.
#[test]
fn fuzz_campaign_survives_injected_panic() {
    let _guard = serialized();
    arm("recv_of_send_identity", 1, FaultAction::Panic);

    let report = fuzz::run_fuzz(&FuzzConfig {
        seeds: 2,
        base_seed: 11,
        ranks: 2,
        mutants_per_model: 1,
        write_files: false,
        flavor: Some(Flavor::Pp), // every case exercises recv_of_send
        ..FuzzConfig::default()
    })
    .expect("chaos panic must not abort the campaign");
    disarm_all();

    assert!(fired("recv_of_send_identity"));
    assert_eq!(report.models, 2, "both seeds must be processed");
    assert_eq!(report.clean_inconclusive, 1, "the poisoned seed is inconclusive");
    assert_eq!(report.clean_verified, 1, "the fault fires once; seed 2 is clean");
    assert_eq!(report.false_alarms, 0, "a crash must never read as a refutation");
    assert!(!report.sound(), "a starved clean pair is a soundness-of-service violation");
    assert!(
        report.counterexamples.iter().any(|c| c.kind == "clean_inconclusive"),
        "the inconclusive clean pair must be recorded for triage"
    );
}
