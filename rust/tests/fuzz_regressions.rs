//! Replayable regression fixtures for the PP/FSDP/MoE/schedule strategy
//! families.
//!
//! Each fixture under `fixtures/` uses the exact JSON schema the fuzzer's
//! `record_cex` writes for minimized counterexamples, so `graphguard fuzz
//! --replay <file>` accepts them verbatim. They pin down the intended
//! verdicts for the new strategy families: clean pipeline pairs verify, and
//! the stage-wiring / stale-shard bug operators are rejected with an
//! in-region localization. If a future checker or lemma change flips one of
//! these verdicts, the corresponding soundness property has regressed.

use graphguard::fuzz;
use graphguard::util::json::Json;

fn replay(text: &str) -> String {
    let j = Json::parse(text).unwrap_or_else(|e| panic!("fixture must parse: {e}"));
    fuzz::replay_counterexample(&j).unwrap_or_else(|e| panic!("fixture must replay: {e:#}"))
}

#[test]
fn pp_clean_pair_fixture_verifies() {
    let verdict = replay(include_str!("fixtures/pp_clean_verifies.json"));
    assert!(
        verdict.contains("clean pair verifies"),
        "clean PP pair regressed into a false alarm: {verdict}"
    );
}

#[test]
fn pp_crossed_boundary_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/pp_crossed_send_recv_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "crossed send/recv must stay detected with an in-stage locus"
    );
}

#[test]
fn fsdp_stale_shard_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/fsdp_stale_shard_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "stale FSDP shard must stay detected with an in-block locus"
    );
}

#[test]
fn pp_sched_clean_pair_fixture_verifies() {
    let verdict = replay(include_str!("fixtures/pp_sched_clean_verifies.json"));
    assert!(
        verdict.contains("clean pair verifies"),
        "clean buffer-lowered 1F1B pair regressed into a false alarm: {verdict}"
    );
}

#[test]
fn pp_sched_buffer_reuse_early_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/pp_sched_buffer_reuse_early_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "stale buffer reuse must stay detected with an in-stage locus"
    );
}

#[test]
fn pp_sched_double_buffer_swap_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/pp_sched_double_buffer_swap_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "double-buffer slot swap must stay detected with an in-stage locus"
    );
}

#[test]
fn pp_sched_virtual_stage_misbind_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/pp_sched_virtual_stage_misbind_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "virtual-stage misbinding must stay detected with an in-stage locus"
    );
}

#[test]
fn moe_clean_pair_fixture_verifies() {
    let verdict = replay(include_str!("fixtures/moe_clean_verifies.json"));
    assert!(
        verdict.contains("clean pair verifies"),
        "clean expert-parallel MoE pair regressed into a false alarm: {verdict}"
    );
}

#[test]
fn moe_wrong_expert_dispatch_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/moe_wrong_expert_dispatch_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "wrong-expert dispatch must stay detected with an in-block locus"
    );
}

#[test]
fn moe_gate_unnormalized_fixture_is_killed_in_region() {
    let verdict = replay(include_str!("fixtures/moe_gate_unnormalized_killed.json"));
    assert_eq!(
        verdict, "mutant outcome: killed_in_region",
        "unnormalized gate weights must stay detected at the gate operator"
    );
}
