//! Golden regression tests for the six §6.2 case studies.
//!
//! Unlike the unit tests inside `bugs.rs` (which iterate `all_cases`),
//! these pin an explicit golden table: every buggy variant must be
//! rejected with its documented localization substring, every fixed
//! variant must verify (and, except bug 5, carry a replaying numeric
//! certificate). A drift in either direction — a case silently passing,
//! or the localization moving — fails loudly with the case name.

use graphguard::bugs::{self, BugCase};
use graphguard::infer::verify_numeric;
use graphguard::Verifier;

/// (bug id, case name, expected localization substring for the buggy
/// variant; None = refinement passes and the bug is found by relation
/// inspection).
const GOLDEN: [(usize, &str, Option<&str>); 6] = [
    (1, "rope_sp_offset", Some("roped")),
    (2, "aux_loss_tp_scaling", Some("aux")),
    (3, "pad_slice_mismatch", Some("act")),
    (4, "sp_sharded_expert_weights", Some("h1")),
    (5, "missing_layernorm_aggregation", None),
    (6, "grad_accum_scaling", Some("loss")),
];

fn case_by_name(cases: Vec<BugCase>, name: &str) -> BugCase {
    cases
        .into_iter()
        .find(|c| c.name == name)
        .unwrap_or_else(|| panic!("case '{name}' missing from bugs::all_cases"))
}

#[test]
fn golden_table_matches_all_cases_metadata() {
    let cases = bugs::all_cases(true);
    assert_eq!(cases.len(), GOLDEN.len(), "case count drifted");
    for (id, name, locus) in GOLDEN {
        let case = cases.iter().find(|c| c.id == id).unwrap_or_else(|| panic!("bug {id} missing"));
        assert_eq!(case.name, name, "bug {id} renamed");
        assert_eq!(case.expected_locus, locus, "bug {id} localization drifted");
    }
}

#[test]
fn each_buggy_variant_rejected_with_golden_locus() {
    for (id, name, locus) in GOLDEN {
        let case = case_by_name(bugs::all_cases(true), name);
        let (detected, report) = case.run();
        match locus {
            Some(substr) => {
                assert!(detected, "bug {id} ({name}) not detected; report:\n{report}");
                assert!(
                    report.contains(substr),
                    "bug {id} ({name}): expected locus '{substr}' not in report:\n{report}"
                );
            }
            None => {
                // bug 5: refinement holds; the implementation trace must
                // expose the unaggregated rank-0 gradient
                assert!(!detected, "bug {id} ({name}) unexpectedly rejected:\n{report}");
                assert!(
                    report.contains("g_ln_r0") && !report.contains("g_ln_ar"),
                    "bug {id} ({name}) trace must show the unaggregated gradient:\n{report}"
                );
            }
        }
    }
}

/// The three-valued verdict layer must not soften the golden table: at
/// default budgets every buggy variant is `Refuted` (never `Inconclusive`)
/// with its documented locus, and bug 5 still `Verified` — the budget
/// machinery is invisible on workloads the defaults comfortably cover.
#[test]
fn golden_mutants_still_refuted_under_three_valued_api() {
    use graphguard::infer::Verdict;
    for (id, name, locus) in GOLDEN {
        let case = case_by_name(bugs::all_cases(true), name);
        let v = Verifier::new().isolated(true).run(&case.gs, &case.gd, &case.ri);
        match locus {
            Some(substr) => match v {
                Verdict::Refuted(e) => assert!(
                    format!("{e}").contains(substr),
                    "bug {id} ({name}): locus '{substr}' drifted:\n{e}"
                ),
                v => panic!("bug {id} ({name}) must stay Refuted, got {}", v.tag()),
            },
            None => assert!(
                v.is_verified(),
                "bug {id} ({name}) is refinement-invisible, got {}",
                v.tag()
            ),
        }
    }
}

#[test]
fn each_fixed_variant_verifies_with_certificate() {
    for (id, name, _locus) in GOLDEN {
        let case = case_by_name(bugs::all_cases(false), name);
        let out = Verifier::new().expect(&case.gs, &case.gd, &case.ri)
            .unwrap_or_else(|e| panic!("fixed bug {id} ({name}) failed refinement: {e}"));
        if id != 5 {
            // bug 5's user-assumed replication of partial gradients is not
            // numerically faithful; every other fixed case must replay
            verify_numeric(&case.gs, &case.gd, &case.ri, &out.relation, id as u64 * 977)
                .unwrap_or_else(|e| panic!("fixed bug {id} ({name}) certificate: {e:#}"));
        }
    }
}

// ---- MoE routing golden cases (router-conditioned verification) ----

/// A clean expert-parallel MoE pair — top-k gating (k = 2), 4 experts,
/// 2 ranks — verifies, and its inferred relation replays numerically.
#[test]
fn moe_clean_ep_pair_verifies_with_certificate() {
    let (gs, gd, ri) = graphguard::models::gpt::moe_ep_pair(2, 1).unwrap();
    let out = Verifier::new().expect(&gs, &gd, &ri)
        .unwrap_or_else(|e| panic!("clean top-k EP pair must verify: {e}"));
    verify_numeric(&gs, &gd, &ri, &out.relation, 4999)
        .unwrap_or_else(|e| panic!("EP certificate must replay: {e:#}"));
}

/// Each of the four routing bug operators is rejected with a localization
/// in the mutated block or downstream (bug effects only flow forward).
/// These verdicts are static — they do not depend on sampled numerics.
#[test]
fn each_routing_mutant_rejected_with_in_region_locus() {
    use graphguard::fuzz::{
        apply_mutation_by_name, build_pair, parse_block, Block, Flavor, ModelSpec, MutKind,
        UnaryKind,
    };
    let spec = ModelSpec {
        seed: 31,
        ranks: 2,
        seq: 4,
        hidden: 4,
        flavor: Flavor::Moe,
        blocks: vec![Block::Linear, Block::Moe(UnaryKind::Silu)],
    };
    let (gs, gd, ri) = build_pair(&spec).unwrap();
    Verifier::new().expect(&gs, &gd, &ri)
        .unwrap_or_else(|e| panic!("clean moe pair must refine: {e}"));
    let cases = [
        (MutKind::WrongExpertDispatch, "b1_disp0"),
        (MutKind::DroppedTokenCombine, "b1_moe_r0"),
        (MutKind::GateWeightUnnormalized, "b1_gates"),
        (MutKind::CapacityTruncateSilent, "b1_disp1"),
    ];
    for (kind, node) in cases {
        let (gd_mut, m) = apply_mutation_by_name(&gd, kind, node)
            .unwrap_or_else(|e| panic!("{kind:?}@{node}: {e:#}"));
        let err = Verifier::new().expect(&gs, &gd_mut, &ri)
            .err()
            .unwrap_or_else(|| panic!("{kind:?}@{node} must be rejected"));
        let block = parse_block(&err.node_name)
            .unwrap_or_else(|| panic!("{kind:?}: locus '{}' not block-named", err.node_name));
        let mutated = m.block.expect("routing sites carry block names");
        assert!(
            block >= mutated,
            "{kind:?}: failure at '{}' (block {block}) precedes mutated block {mutated}",
            err.node_name
        );
    }
}

#[test]
fn taxonomy_bridge_names_real_fuzz_operators() {
    use graphguard::fuzz::MutKind;
    for (id, _name, locus) in GOLDEN {
        match bugs::fuzz_operator_for(id) {
            Some(op) => {
                assert!(
                    MutKind::parse(op).is_some(),
                    "bug {id} maps to unknown mutation operator '{op}'"
                );
            }
            None => assert!(
                locus.is_none(),
                "only the refinement-invisible case (bug 5) may lack an operator"
            ),
        }
    }
}

// ---- ShardFlow lint triage golden table (all 23 mutation operators) ----

/// Per-kind pin of the static-analysis triage classification: for each
/// mutation operator, its first applicable site on a representative spec is
/// either `lint_flagged` (the distribution lattice / channel lints see the
/// bug pre-saturation, with a locus in or downstream of the mutated block)
/// or `lint_silent_refuted` (a numerics-only bug only the e-graph can
/// catch). A FLAGGED kind regressing to silent means lost static coverage;
/// a SILENT kind starting to fire means the lattice got a new definite
/// contradiction — either way this table must be updated consciously.
#[test]
fn lint_triage_classification_is_pinned() {
    use graphguard::analysis;
    use graphguard::fuzz::{
        applicable_sites, apply_mutation, build_pair, parse_block, Block, Flavor, ModelSpec,
        MutKind, NormKind, UnaryKind, MUT_KINDS,
    };
    use graphguard::schedule::SchedKind;

    fn spec(seed: u64, seq: i64, flavor: Flavor, blocks: Vec<Block>) -> ModelSpec {
        ModelSpec { seed, ranks: 2, seq, hidden: 4, flavor, blocks }
    }
    let sp3 = spec(
        3,
        4,
        Flavor::Sp,
        vec![Block::Linear, Block::Unary(UnaryKind::Gelu), Block::Norm(NormKind::Softmax)],
    );
    let sp_sm =
        spec(11, 4, Flavor::Sp, vec![Block::Unary(UnaryKind::Tanh), Block::Norm(NormKind::Softmax)]);
    let sp_scale = spec(5, 4, Flavor::Sp, vec![Block::Linear, Block::Scale(0.5)]);
    let tp_mlp =
        spec(7, 4, Flavor::Tp, vec![Block::Mlp(UnaryKind::Tanh), Block::Unary(UnaryKind::Tanh)]);
    let tp_rs =
        spec(9, 4, Flavor::Tp, vec![Block::LinearRs, Block::Unary(UnaryKind::Tanh)]);
    let pp = spec(21, 4, Flavor::Pp, vec![Block::Linear, Block::Unary(UnaryKind::Tanh)]);
    let fsdp = spec(22, 4, Flavor::Fsdp, vec![Block::Linear, Block::Mlp(UnaryKind::Gelu)]);
    let moe = spec(31, 4, Flavor::Moe, vec![Block::Linear, Block::Moe(UnaryKind::Silu)]);
    let sched_1f1b = spec(
        41,
        8,
        Flavor::PpSched(SchedKind::OneFOneB),
        vec![Block::Linear, Block::Unary(UnaryKind::Gelu)],
    );
    let sched_inter = spec(
        42,
        8,
        Flavor::PpSched(SchedKind::Interleaved),
        vec![Block::Linear, Block::Linear, Block::Linear, Block::Linear],
    );

    // (operator, spec to probe it on, expected: true = lint_flagged)
    let table: [(MutKind, &ModelSpec, bool); 23] = [
        (MutKind::GatherReorder, &sp3, true),
        (MutKind::DropAggregation, &tp_mlp, true),
        (MutKind::GatherToReduceScatter, &sp3, true),
        (MutKind::ScatterIndexPerturb, &tp_rs, true),
        (MutKind::SliceShift, &tp_rs, false),
        (MutKind::SliceDimSwap, &tp_rs, false),
        (MutKind::ScalePerturb, &sp_scale, false),
        (MutKind::ScaleDrop, &sp_scale, false),
        (MutKind::MatMulSwap, &moe, false),
        (MutKind::WrongUnary, &sp3, false),
        (MutKind::DupShardInput, &sp3, true),
        (MutKind::SoftmaxDimSwap, &sp_sm, true),
        (MutKind::CrossedSendRecv, &pp, true),
        (MutKind::DroppedBoundary, &pp, true),
        (MutKind::StaleShardGather, &fsdp, true),
        (MutKind::MicrobatchScaleOffby, &sp_scale, false),
        (MutKind::WrongExpertDispatch, &moe, true),
        (MutKind::DroppedTokenCombine, &moe, true),
        (MutKind::GateWeightUnnormalized, &moe, true),
        (MutKind::CapacityTruncateSilent, &moe, true),
        (MutKind::BufferReuseEarly, &sched_1f1b, true),
        (MutKind::DoubleBufferSwap, &sched_1f1b, true),
        (MutKind::VirtualStageMisbind, &sched_inter, true),
    ];
    assert_eq!(table.len(), MUT_KINDS.len(), "a mutation operator is missing from the pin");

    for (kind, spec, expect_flagged) in &table {
        let (_gs, gd, ri) = build_pair(spec).unwrap_or_else(|e| panic!("{kind:?}: {e:#}"));
        assert!(
            analysis::analyze(&gd, Some(&ri)).is_clean(),
            "{kind:?}: probe spec must lint clean before mutation"
        );
        let site = applicable_sites(&gd)
            .into_iter()
            .find(|s| s.kind == *kind)
            .unwrap_or_else(|| panic!("{kind:?}: no applicable site on its probe spec"));
        let (gd_mut, m) = apply_mutation(&gd, site)
            .unwrap_or_else(|e| panic!("{kind:?}: mutation must build: {e:#}"));
        let r = analysis::analyze(&gd_mut, Some(&ri));
        if *expect_flagged {
            assert!(
                !r.is_clean(),
                "{kind:?}@{}: pinned lint_flagged, but the analysis stayed silent",
                m.node_name
            );
            let mutated = m.block.unwrap_or(0);
            assert!(
                r.findings.iter().any(|f| parse_block(&f.node).is_some_and(|b| b >= mutated)),
                "{kind:?}@{}: no finding in or downstream of mutated block {mutated}:\n{}",
                m.node_name,
                r.render()
            );
        } else {
            assert!(
                r.is_clean(),
                "{kind:?}@{}: pinned lint_silent_refuted, but the analysis fired:\n{}",
                m.node_name,
                r.render()
            );
        }
    }
}
