//! In-process pipe tests for `graphguard serve` (ISSUE-9 acceptance).
//!
//! Drives [`graphguard::serve::serve_loop`] over an in-memory reader/writer
//! pair — the same code path `graphguard serve` runs on stdin/stdout — and
//! checks the service contract end to end:
//!   - a mixed request stream (named workloads + an inline refuted pair)
//!     answers with verdict/locus content byte-identical to the one-shot
//!     CLI path (a single panic-isolated [`Verifier`] run);
//!   - a repeated-layer stream meets the warm hit-rate floor (L−1)/L on
//!     the shared fingerprint cache;
//!   - malformed lines, version mismatches, unknown workloads, and missing
//!     payloads produce structured error responses and never stop the loop;
//!   - (with `--features chaos`) an armed fault yields `inconclusive_panic`
//!     and never populates the shared cache.

use graphguard::infer::Verdict;
use graphguard::ir::{json_io, Graph};
use graphguard::models::{self, gpt, gpt::GptConfig};
use graphguard::relation::Relation;
use graphguard::serve::{serve_loop, ServeOptions, ServeStats};
use graphguard::util::json::Json;
use graphguard::util::schema::SCHEMA_VERSION;
use graphguard::Verifier;
use std::io::Cursor;
use std::sync::{Mutex, MutexGuard};

/// Chaos state is process-global; when this binary is compiled with the
/// chaos feature, every test serializes here so an armed fault (which
/// bypasses the fingerprint cache globally) can't leak into a neighbouring
/// test's cache assertions. Without the feature this is a no-op guard.
static LOCK: Mutex<()> = Mutex::new(());

fn serialized() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// One serve session over an in-memory pipe: feed `input` (NDJSON request
/// lines), collect one parsed response per line plus the session stats.
fn run_serve(input: &str, opts: &ServeOptions) -> (Vec<Json>, ServeStats) {
    let mut out = Vec::new();
    let stats = serve_loop(Cursor::new(input.as_bytes()), &mut out, opts).expect("transport ok");
    let text = String::from_utf8(out).expect("responses are utf-8");
    let responses =
        text.lines().map(|l| Json::parse(l).expect("response is valid json")).collect();
    (responses, stats)
}

fn inline_request(id: &str, gs: &Graph, gd: &Graph, ri: &Relation) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("gs", json_io::to_json(gs)),
        ("gd", json_io::to_json(gd)),
        ("ri", ri.to_json(gs, gd)),
    ])
    .to_string()
}

/// Three mixed requests — verified workload, inline refuted pair, second
/// verified workload — each answered with the relation JSON / error text /
/// locus the one-shot CLI produces, byte for byte.
#[test]
fn mixed_stream_matches_the_one_shot_cli_byte_for_byte() {
    let _guard = serialized();
    let workloads = models::table2_workloads(2);
    let gpt_w = workloads.iter().find(|w| w.name == "gpt_tp_sp_2").expect("gpt workload");
    let qwen_w = workloads.iter().find(|w| w.name == "qwen2_tp_2").expect("qwen2 workload");
    let (bgs, bgd, bri) = models::regression::grad_accum_buggy_pair(2).expect("buggy pair");

    let input = format!(
        "{}\n{}\n{}\n",
        r#"{"id":"r1","workload":"gpt_tp_sp_2","ranks":2}"#,
        inline_request("r2", &bgs, &bgd, &bri),
        r#"{"id":"r3","workload":"qwen2_tp_2","ranks":2}"#,
    );
    let (rs, stats) = run_serve(&input, &ServeOptions::default());
    assert_eq!(rs.len(), 3, "one response per request line");
    assert_eq!((stats.verified, stats.refuted, stats.errors), (2, 1, 0));

    for (resp, w) in [(&rs[0], gpt_w), (&rs[2], qwen_w)] {
        assert_eq!(resp.get("verdict").as_str(), Some("verified"), "{}", w.name);
        assert_eq!(resp.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
        let one_shot = match Verifier::new().isolated(true).run(&w.gs, &w.gd, &w.ri) {
            Verdict::Verified(out) => out.relation.to_json(&w.gs, &w.gd).to_string(),
            v => panic!("{} must verify one-shot, got {}", w.name, v.tag()),
        };
        assert_eq!(
            resp.get("relation").to_string(),
            one_shot,
            "{}: serve relation must match the one-shot CLI byte for byte",
            w.name
        );
    }

    assert_eq!(rs[1].get("id").as_str(), Some("r2"));
    assert_eq!(rs[1].get("verdict").as_str(), Some("refuted"));
    match Verifier::new().isolated(true).run(&bgs, &bgd, &bri) {
        Verdict::Refuted(e) => {
            assert_eq!(rs[1].get("error").as_str(), Some(format!("{e}").as_str()));
            assert_eq!(rs[1].get("locus").as_str(), Some(e.node_name.as_str()));
        }
        v => panic!("buggy pair must refute one-shot, got {}", v.tag()),
    }
}

const LAYERS: usize = 8;

/// The amortization the service exists for: the second request over the
/// same L=8 repeated-layer pair replays from the shared cache at a hit-rate
/// of at least (L−1)/L, and even the cold request's misses are bounded by
/// one layer plus the embedding/LM-head epilogue.
#[test]
fn repeated_layer_stream_meets_the_warm_hit_rate_floor() {
    let _guard = serialized();
    let model_cfg = GptConfig::default();
    let (gs, gd, ri) = gpt::tp_sp_pair(2, LAYERS, &model_cfg).expect("build L=8 workload");
    let line = inline_request("rep", &gs, &gd, &ri);
    let opts = ServeOptions::default(); // fresh shared cache
    let (rs, stats) = run_serve(&format!("{line}\n{line}\n"), &opts);
    assert_eq!(rs.len(), 2);
    for r in &rs {
        assert_eq!(r.get("verdict").as_str(), Some("verified"));
    }

    let cold_misses = rs[0].get("cache_misses").as_usize().expect("cold misses");
    let bound = gpt::seq(1, &model_cfg).num_nodes() + 5;
    assert!(
        cold_misses <= bound,
        "cold request must reuse repeated layers: {cold_misses} misses > bound {bound}"
    );

    let hits = rs[1].get("cache_hits").as_f64().expect("warm hits");
    let misses = rs[1].get("cache_misses").as_f64().expect("warm misses");
    let rate = hits / (hits + misses).max(1.0);
    let floor = (LAYERS - 1) as f64 / LAYERS as f64;
    assert!(rate >= floor, "warm hit-rate {rate:.3} below acceptance floor {floor:.3}");
    assert!(stats.cache_hits > 0, "session stats must see the shared-cache hits");
}

/// Every request-level failure — unparseable bytes, a future schema
/// version, an unknown workload, a missing payload — answers with a
/// structured `verdict: "error"` response (id echoed whenever the line was
/// valid JSON) and the loop keeps serving.
#[test]
fn request_errors_answer_structurally_and_never_stop_the_loop() {
    let _guard = serialized();
    let input = "not json at all\n\
                 {\"id\":\"v\",\"workload\":\"gpt_tp_sp_2\",\"schema_version\":99}\n\
                 {\"id\":\"u\",\"workload\":\"no_such_model\",\"ranks\":2}\n\
                 {\"id\":\"m\"}\n\
                 {\"id\":3,\"workload\":\"gpt_tp_sp_3\",\"ranks\":3}\n\
                 {\"id\":\"big\",\"workload\":\"gpt_tp_sp_2\",\"ranks\":100000}\n\
                 {\"id\":\"ok\",\"workload\":\"gpt_tp_sp_2\",\"ranks\":2}\n";
    let (rs, stats) = run_serve(input, &ServeOptions::default());
    assert_eq!(rs.len(), 7, "one response per request line");
    for r in &rs[..6] {
        assert_eq!(r.get("verdict").as_str(), Some("error"));
        assert!(r.get("error").as_str().is_some(), "error responses carry a message");
        assert_eq!(r.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
    }
    assert!(matches!(rs[0].get("id"), Json::Null), "unparseable line has no id to echo");
    assert_eq!(rs[1].get("id").as_str(), Some("v"));
    let msg = rs[1].get("error").as_str().expect("version error");
    assert!(
        msg.contains("99") && msg.contains(&SCHEMA_VERSION.to_string()),
        "version mismatch must name both versions: {msg}"
    );
    assert_eq!(rs[2].get("id").as_str(), Some("u"));
    assert_eq!(rs[3].get("id").as_str(), Some("m"));
    // a degree the model builders reject (heads 4 % ranks 3) is a request
    // error, not a server panic; a non-string id echoes as its own type
    assert_eq!(rs[4].get("id"), &Json::Num(3.0), "numeric id round-trips as a number");
    assert!(rs[4].get("error").as_str().expect("builder error").contains("ranks=3"));
    // absurd degrees are rejected at parse time, before any graph building
    assert_eq!(rs[5].get("id").as_str(), Some("big"));
    assert!(rs[5].get("error").as_str().expect("ranks bound error").contains("100000"));
    assert_eq!(rs[6].get("verdict").as_str(), Some("verified"));
    assert_eq!((stats.errors, stats.verified), (6, 1));
}

#[cfg(feature = "chaos")]
mod chaos {
    use super::*;
    use graphguard::cache::FingerprintCache;
    use graphguard::chaos::{arm, disarm_all, fired, FaultAction};
    use std::sync::Arc;

    /// A chaos-armed request degrades to `inconclusive_panic` and must
    /// never populate the cache shared with every other client; once
    /// disarmed, the same server options verify and warm it normally.
    #[test]
    fn armed_request_never_populates_the_shared_cache() {
        let _guard = serialized();
        disarm_all();
        let (gs, gd, ri) = models::gpt::pp_tp_pair(2, 2, 2).expect("build pp workload");
        let line = inline_request("poisoned", &gs, &gd, &ri);
        let cache = Arc::new(FingerprintCache::new());
        let opts = ServeOptions { cache: Some(Arc::clone(&cache)), ..ServeOptions::default() };

        arm("recv_of_send_identity", 1, FaultAction::Panic);
        let (rs, stats) = run_serve(&format!("{line}\n"), &opts);
        disarm_all();
        assert!(fired("recv_of_send_identity"), "panic fault never fired");
        assert_eq!(rs[0].get("verdict").as_str(), Some("inconclusive_panic"));
        assert!(cache.is_empty(), "armed request must never populate the shared cache");
        assert_eq!((stats.cache_hits, stats.cache_misses), (0, 0), "no lookups while armed");

        let (rs, _) = run_serve(&format!("{line}\n"), &opts);
        assert_eq!(rs[0].get("verdict").as_str(), Some("verified"));
        assert!(!cache.is_empty(), "disarmed request populates the shared cache");
    }
}
