//! GraphPatch + impact-analysis integration tests (ISSUE 10).
//!
//! The contract under test: `Verifier::reverify` (patch-driven incremental
//! re-verification) is an *optimization only*. Its verdict, certificate
//! relation, and failure locus are byte-identical to a full from-scratch
//! verification of the patched pair; the impact analysis merely decides
//! which cached region certificates may be reused soundly.
//!
//! Fixture files under `fixtures/patch/` carry the paper's Fig-1 running
//! example plus three patches (clean identity splice, semantic bug,
//! structurally invalid) — the same files the CI determinism gate drives
//! through the CLI (`scripts/ci-local.sh`).

use graphguard::analysis::{self, analyze_patch, impact::relint, remap_relation, RegionClass};
use graphguard::infer::Verdict;
use graphguard::ir::{json_io, Graph, GraphPatch, Op};
use graphguard::models::gpt::{self, GptConfig};
use graphguard::models::table2_workloads;
use graphguard::relation::Relation;
use graphguard::util::json::Json;
use graphguard::Verifier;

const GS: &str = include_str!("fixtures/patch/fig1_gs.json");
const GD: &str = include_str!("fixtures/patch/fig1_gd.json");
const RI: &str = include_str!("fixtures/patch/fig1_ri.json");
const CLEAN_PATCH: &str = include_str!("fixtures/patch/fig1_clean.patch.json");
const BUG_PATCH: &str = include_str!("fixtures/patch/fig1_bug.patch.json");
const INVALID_PATCH: &str = include_str!("fixtures/patch/fig1_invalid.patch.json");

fn fig1() -> (Graph, Graph, Relation) {
    let gs = json_io::from_json(&Json::parse(GS).expect("gs parses")).expect("gs loads");
    let gd = json_io::from_json(&Json::parse(GD).expect("gd parses")).expect("gd loads");
    let ri = Relation::from_json(&Json::parse(RI).expect("ri parses"), &gs, &gd)
        .expect("ri loads");
    (gs, gd, ri)
}

fn patch(text: &str) -> GraphPatch {
    GraphPatch::from_json(&Json::parse(text).expect("patch parses")).expect("patch loads")
}

fn relation_bytes(v: &Verdict, gs: &Graph, gd: &Graph) -> String {
    match v {
        Verdict::Verified(o) => o.relation.to_json(gs, gd).to_string_pretty(),
        other => panic!("expected Verified, got {}", other.tag()),
    }
}

// ---------------------------------------------------------------------------
// Fixture hygiene: the JSON files the CI gate replays must parse, apply,
// and round-trip through the patch codec.
// ---------------------------------------------------------------------------

#[test]
fn fixture_patches_parse_and_roundtrip() {
    for (name, text) in
        [("clean", CLEAN_PATCH), ("bug", BUG_PATCH), ("invalid", INVALID_PATCH)]
    {
        let p = patch(text);
        let p2 = GraphPatch::from_json(&p.to_json())
            .unwrap_or_else(|e| panic!("{name}: roundtrip failed: {e:#}"));
        assert_eq!(p, p2, "{name}: codec roundtrip changed the patch");
    }
}

#[test]
fn clean_and_bug_fixture_patches_apply() {
    let (_gs, gd, _ri) = fig1();
    let spliced = patch(CLEAN_PATCH).apply(&gd).expect("clean patch applies");
    assert_eq!(spliced.num_nodes(), gd.num_nodes() + 1, "identity splice adds one node");
    let buggy = patch(BUG_PATCH).apply(&gd).expect("bug patch is shape-valid");
    assert_eq!(buggy.num_nodes(), gd.num_nodes());
}

#[test]
fn invalid_fixture_patch_is_a_structured_error() {
    let (_gs, gd, _ri) = fig1();
    let e = patch(INVALID_PATCH).apply(&gd).expect_err("dangling rewire must fail");
    let msg = format!("{e:#}");
    assert!(msg.contains("no_such_tensor"), "error must name the tensor: {msg}");
}

// ---------------------------------------------------------------------------
// Impact classification pinned on hand-built diffs.
// ---------------------------------------------------------------------------

#[test]
fn bug_patch_impact_classes_are_pinned() {
    let (gs, gd, ri) = fig1();
    let patched = patch(BUG_PATCH).apply(&gd).expect("applies");
    let ri2 = remap_relation(&ri, &gd, &patched).expect("noop remap");
    let imp = analyze_patch(&gs, &gd, &patched, &ri, &ri2, &[]);
    assert_eq!(imp.regions.len(), gs.num_nodes());
    for r in &imp.regions {
        let want = match r.node_name.as_str() {
            "C" => RegionClass::Clean, // cone ends at D_1/D_2, before the edit
            "F" => RegionClass::Dirty,
            other => panic!("unexpected region '{other}'"),
        };
        assert_eq!(r.class, want, "region {}", r.node_name);
    }
    assert_eq!(imp.changed, vec!["F_1".to_string()]);
}

#[test]
fn identity_splice_impact_dirties_only_the_tail() {
    let (gs, gd, ri) = fig1();
    let patched = patch(CLEAN_PATCH).apply(&gd).expect("applies");
    let ri2 = remap_relation(&ri, &gd, &patched).expect("remap survives the splice");
    let imp = analyze_patch(&gs, &gd, &patched, &ri, &ri2, &[]);
    // the spliced F_1_id + rewired F_full taint only region F's cone
    assert_eq!(imp.class_of_name(&gs, "C"), Some(RegionClass::Clean));
    assert_eq!(imp.class_of_name(&gs, "F"), Some(RegionClass::Dirty));
}

/// Name-based region lookup for tests (regions are keyed by `G_s` node id).
trait ClassOfName {
    fn class_of_name(&self, gs: &Graph, name: &str) -> Option<RegionClass>;
}

impl ClassOfName for graphguard::analysis::ImpactReport {
    fn class_of_name(&self, gs: &Graph, name: &str) -> Option<RegionClass> {
        let t = gs.tensor_by_name(name)?;
        self.class_of(gs.tensor(t).producer?)
    }
}

// ---------------------------------------------------------------------------
// Differential: incremental == full, across every Table-2 workload.
// ---------------------------------------------------------------------------

/// A noop patch re-verifies every workload to the byte-identical
/// certificate, with every region certificate replayed from the warm-up
/// run (zero misses) and an all-Clean impact report.
#[test]
fn noop_reverify_is_byte_identical_across_table2() {
    let noop = GraphPatch::new("noop");
    for w in table2_workloads(2) {
        let v = Verifier::new().isolated(true);
        let full = v.run(&w.gs, &w.gd, &w.ri);
        let rv = v
            .reverify(&w.gs, &w.gd, &w.ri, &noop)
            .unwrap_or_else(|e| panic!("{}: noop reverify failed: {e:#}", w.name));
        assert_eq!(rv.impact.dirty_cone(), 0, "{}: noop patch dirtied regions", w.name);
        assert_eq!(
            relation_bytes(&full, &w.gs, &w.gd),
            relation_bytes(&rv.verdict, &w.gs, &rv.patched),
            "{}: incremental certificate diverged from full verification",
            w.name
        );
        let Verdict::Verified(o) = &rv.verdict else { unreachable!() };
        assert_eq!(o.cache_misses, 0, "{}: clean region re-saturated", w.name);
        assert!(o.cache_hits > 0, "{}: nothing was reused", w.name);
    }
}

/// A real (but semantics-preserving) splice: incremental verification of
/// the patched pair matches a cold full verification of the same pair.
#[test]
fn clean_splice_reverify_matches_full_verification() {
    let (gs, gd, ri) = fig1();
    let v = Verifier::new().isolated(true);
    let rv = v.reverify(&gs, &gd, &ri, &patch(CLEAN_PATCH)).expect("reverify runs");
    let cold = v.run(&gs, &rv.patched, &rv.ri);
    assert_eq!(
        relation_bytes(&cold, &gs, &rv.patched),
        relation_bytes(&rv.verdict, &gs, &rv.patched),
        "incremental certificate diverged from full verification of the patched pair"
    );
    let Verdict::Verified(o) = &rv.verdict else { unreachable!() };
    assert!(o.cache_hits >= 1, "region C's certificate must be replayed");
}

/// A semantic bug refutes, and the failure locus lies inside the dirty
/// cone the impact analysis predicted.
#[test]
fn bug_patch_refutes_inside_the_dirty_cone() {
    let (gs, gd, ri) = fig1();
    let v = Verifier::new().isolated(true);
    let rv = v.reverify(&gs, &gd, &ri, &patch(BUG_PATCH)).expect("reverify runs");
    let Verdict::Refuted(e) = &rv.verdict else {
        panic!("sub→add must refute, got {}", rv.verdict.tag());
    };
    assert_eq!(
        rv.impact.class_of(e.node),
        Some(RegionClass::Dirty),
        "refutation at '{}' fell outside the predicted dirty cone",
        e.node_name
    );
    // and the full run of the patched pair refutes at the same locus
    let cold = v.run(&gs, &rv.patched, &rv.ri);
    let Verdict::Refuted(c) = &cold else { panic!("full run must refute too") };
    assert_eq!(c.node, e.node);
    assert_eq!(format!("{c}"), format!("{e}"), "error text must match byte for byte");
}

/// A structurally invalid patch is a structured error from `reverify` —
/// never a panic, never a verdict.
#[test]
fn invalid_patch_reverify_is_a_structured_error() {
    let (gs, gd, ri) = fig1();
    let e = Verifier::new()
        .isolated(true)
        .reverify(&gs, &gd, &ri, &patch(INVALID_PATCH))
        .expect_err("invalid patch must not produce a verdict");
    assert!(format!("{e:#}").contains("no_such_tensor"), "{e:#}");
}

// ---------------------------------------------------------------------------
// Acceptance: a single-layer patch of the L=8 GPT workload leaves at
// least (L-1)/L of the regions Clean, and those certificates replay.
// ---------------------------------------------------------------------------

#[test]
fn gpt8_single_layer_patch_keeps_most_regions_clean() {
    const LAYERS: usize = 8;
    let (gs, gd, ri) =
        gpt::tp_sp_pair(2, LAYERS, &GptConfig::default()).expect("build workload");
    // splice an identity in front of slot 0 of the topologically last G_d
    // node — a strictly local, semantics-preserving single-layer edit
    let last = gd.topo_order().last().expect("nonempty graph");
    let node = gd.node(last);
    let src = gd.tensor(node.inputs[0]).name.clone();
    let tgt = gd.tensor(node.output).name.clone();
    let p = GraphPatch::new("late_identity")
        .add("late_id", Op::Identity, vec![src])
        .rewire(tgt, 0, "late_id");

    let v = Verifier::new().isolated(true);
    let rv = v.reverify(&gs, &gd, &ri, &p).expect("reverify runs");
    let Verdict::Verified(o) = &rv.verdict else {
        panic!("identity splice must still verify, got {}", rv.verdict.tag());
    };

    let (clean, total) = (rv.impact.clean(), rv.impact.regions.len());
    assert!(clean < total, "the patched tail must be re-verified, not reused");
    assert!(
        clean * LAYERS >= (LAYERS - 1) * total,
        "single-layer patch proved only {clean}/{total} regions Clean \
         (acceptance floor is {}/{LAYERS})",
        LAYERS - 1
    );
    assert!(
        o.cache_hits as usize >= clean,
        "every Clean region must replay its certificate: {} hits < {clean} clean",
        o.cache_hits
    );
}

// ---------------------------------------------------------------------------
// Lint integration: relint over the dirty cone only, zero false alarms.
// ---------------------------------------------------------------------------

#[test]
fn relint_is_false_alarm_free_on_clean_patched_pairs() {
    // fig1 + the clean splice
    let (gs, gd, ri) = fig1();
    let patched = patch(CLEAN_PATCH).apply(&gd).expect("applies");
    let ri2 = remap_relation(&ri, &gd, &patched).expect("remap");
    let imp = analyze_patch(&gs, &gd, &patched, &ri, &ri2, &[]);
    let old_lint = analysis::analyze(&gd, Some(&ri));
    let new_lint = analysis::analyze(&patched, Some(&ri2));
    let merged = relint(&old_lint, &new_lint, &gd, &patched, &imp)
        .expect("impact cone must cover every lint change");
    assert!(merged.is_clean(), "false alarm on a clean patched pair:\n{}", merged.render());

    // every Table-2 workload under the noop patch: relint reduces to the
    // (empty) full report, with zero findings migrating across the cone
    let noop = GraphPatch::new("noop");
    for w in table2_workloads(2) {
        let patched = noop.apply(&w.gd).expect("noop applies");
        let ri2 = remap_relation(&w.ri, &w.gd, &patched).expect("remap");
        let imp = analyze_patch(&w.gs, &w.gd, &patched, &w.ri, &ri2, &[]);
        let old_lint = analysis::analyze(&w.gd, Some(&w.ri));
        let new_lint = analysis::analyze(&patched, Some(&ri2));
        let merged = relint(&old_lint, &new_lint, &w.gd, &patched, &imp)
            .unwrap_or_else(|e| panic!("{}: {e:#}", w.name));
        assert!(
            merged.is_clean(),
            "{}: lint false alarm under noop patch:\n{}",
            w.name,
            merged.render()
        );
    }
}
