//! Differential tests for the incremental saturation engine: dirty-class
//! matching must be observationally identical to the full-rescan oracle —
//! the same final e-class partition over all seeded ids and the same
//! per-rule application counts — on real workloads (GPT TP+SP+VP, Llama-3
//! TP, Qwen2 TP, and the paper's Fig-1 running example).

use graphguard::egraph::{
    saturate, saturate_full_rescan, EGraph, Id, RewriteCtx, SaturationLimits,
};
use graphguard::expr::{Side, TensorRef};
use graphguard::ir::Graph;
use graphguard::lemmas;
use graphguard::models::{gpt, llama, qwen2};
use graphguard::relation::Relation;
use graphguard::util::json::Json;

/// Build the monolithic e-graph for (gs, gd, ri) — both graphs' definitional
/// equalities plus the input relation — and return it with the seeded ids.
/// Construction is deterministic, so two calls yield identical id layouts.
fn seed_egraph(gs: &Graph, gd: &Graph, ri: &Relation) -> (EGraph, Vec<Id>) {
    let mut eg = EGraph::new();
    let mut seeded: Vec<Id> = Vec::new();
    let mut s_class = vec![0u32; gs.num_tensors()];
    for &i in &gs.inputs {
        s_class[i as usize] = eg.add_leaf(TensorRef::s(i), gs.shape(i).to_vec());
        seeded.push(s_class[i as usize]);
    }
    for nid in gs.topo_order() {
        let node = gs.node(nid);
        let children = node.inputs.iter().map(|&t| s_class[t as usize]).collect();
        s_class[node.output as usize] =
            eg.add_op(node.op.clone(), children).expect("well-shaped G_s");
        seeded.push(s_class[node.output as usize]);
    }
    for nid in gd.topo_order() {
        let node = gd.node(nid);
        let children: Vec<Id> = node
            .inputs
            .iter()
            .map(|&t| eg.add_leaf(TensorRef::d(t), gd.shape(t).to_vec()))
            .collect();
        seeded.extend(&children);
        let out = eg.add_leaf(TensorRef::d(node.output), gd.shape(node.output).to_vec());
        seeded.push(out);
        if let Ok(def) = eg.add_op(node.op.clone(), children) {
            let _ = eg.union(out, def);
        }
    }
    let gd_leaf_shape = |t: TensorRef| (t.side == Side::D).then(|| gd.shape(t.id).to_vec());
    for t in ri.tensors() {
        for cand in ri.get(t) {
            if let Ok(root) = eg.add_expr(&cand.expr, &gd_leaf_shape) {
                let _ = eg.union(s_class[t as usize], root);
            }
        }
    }
    eg.rebuild();
    seeded.sort_unstable();
    seeded.dedup();
    (eg, seeded)
}

fn assert_differential(name: &str, gs: &Graph, gd: &Graph, ri: &Relation) {
    let limits = SaturationLimits::new(12, 200_000);
    let ctx = RewriteCtx::default();
    let rules = lemmas::standard_rewrites();

    let (mut inc, seeded) = seed_egraph(gs, gd, ri);
    let (mut full, seeded2) = seed_egraph(gs, gd, ri);
    assert_eq!(seeded, seeded2, "{name}: seeding must be deterministic");

    let si = saturate(&mut inc, &rules, &ctx, limits);
    let sf = saturate_full_rescan(&mut full, &rules, &ctx, limits);
    assert!(si.total_applications() > 0, "{name}: workload exercises lemmas");

    // identical per-rule application counts
    let mut ai: Vec<(&str, u64)> = si.applied.iter().map(|(&k, &v)| (k, v)).collect();
    let mut af: Vec<(&str, u64)> = sf.applied.iter().map(|(&k, &v)| (k, v)).collect();
    ai.sort_unstable();
    af.sort_unstable();
    assert_eq!(ai, af, "{name}: per-rule application counts diverge");

    // identical final partition over every seeded id pair
    for (i, &a) in seeded.iter().enumerate() {
        for &b in &seeded[i + 1..] {
            assert_eq!(
                inc.same(a, b),
                full.same(a, b),
                "{name}: partition diverges on seeded pair ({a}, {b})"
            );
        }
    }
}

/// Fig-1/2 running example: matsub(matmul(A,B), E) vs TP with
/// reduce-scatter + all-gather.
fn running_example() -> (Graph, Graph, Relation) {
    let mut gs = Graph::new("fig1_gs");
    let a = gs.input("A", vec![4, 6]);
    let b = gs.input("B", vec![6, 4]);
    let e = gs.input("E", vec![4, 4]);
    let c = gs.matmul("C", a, b);
    let f = gs.sub2("F", c, e);
    gs.mark_output(f);

    let mut gd = Graph::new("fig1_gd");
    let a1 = gd.input("A_1", vec![4, 3]);
    let a2 = gd.input("A_2", vec![4, 3]);
    let b1 = gd.input("B_1", vec![3, 4]);
    let b2 = gd.input("B_2", vec![3, 4]);
    let e1 = gd.input("E_1", vec![2, 4]);
    let e2 = gd.input("E_2", vec![2, 4]);
    let c1 = gd.matmul("C_1", a1, b1);
    let c2 = gd.matmul("C_2", a2, b2);
    let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
    let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
    let f1 = gd.sub2("F_1", d1, e1);
    let f2 = gd.sub2("F_2", d2, e2);
    let f = gd.all_gather("F_full", vec![f1, f2], 0);
    gd.mark_output(f);

    let ri = Relation::from_json(
        &Json::parse(
            r#"{"A": ["concat(A_1, A_2; dim=1)"],
                "B": ["concat(B_1, B_2; dim=0)"],
                "E": ["concat(E_1, E_2; dim=0)"]}"#,
        )
        .unwrap(),
        &gs,
        &gd,
    )
    .unwrap();
    (gs, gd, ri)
}

#[test]
fn differential_running_example() {
    let (gs, gd, ri) = running_example();
    assert_differential("fig1_running_example", &gs, &gd, &ri);
}

#[test]
fn differential_gpt_tp_sp_vp() {
    let (gs, gd, ri) =
        gpt::tp_sp_vp_pair(2, 1, &gpt::GptConfig::default()).expect("gpt tp+sp+vp builds");
    assert_differential("gpt_tp_sp_vp_2", &gs, &gd, &ri);
}

#[test]
fn differential_llama3_tp() {
    let (gs, gd, ri) =
        llama::tp_pair(2, 1, &llama::LlamaConfig::default()).expect("llama tp builds");
    assert_differential("llama3_tp_2", &gs, &gd, &ri);
}

#[test]
fn differential_qwen2_tp() {
    let (gs, gd, ri) = qwen2::tp_pair(2, 1).expect("qwen2 tp builds");
    assert_differential("qwen2_tp_2", &gs, &gd, &ri);
}

#[test]
fn differential_gpt_pp_tp() {
    let (gs, gd, ri) = gpt::pp_tp_pair(2, 2, 2).expect("gpt pp×tp builds");
    assert_differential("gpt_pp2_tp_2", &gs, &gd, &ri);
}

#[test]
fn differential_llama3_fsdp() {
    let (gs, gd, ri) =
        llama::fsdp_pair(2, 1, &llama::LlamaConfig::default()).expect("llama fsdp builds");
    assert_differential("llama3_fsdp_2", &gs, &gd, &ri);
}

/// Routing lemma family: incremental and full-rescan saturation must agree
/// on the expert-parallel MoE workload (partial-combine collapse,
/// dispatch desugaring, router-conditioned congruences).
#[test]
fn differential_gpt_moe_ep() {
    let (gs, gd, ri) = gpt::moe_ep_pair(2, 1).expect("gpt moe ep builds");
    assert_differential("gpt_moe_ep_2", &gs, &gd, &ri);
}

/// Buffer-tagged boundary collapse: incremental and full-rescan saturation
/// must agree on the schedule-lowered 1F1B pipeline workload (large channel
/// tags exercise the same recv_of_send path as logical ones).
#[test]
fn differential_gpt_pp_1f1b() {
    let sched = graphguard::schedule::Schedule::one_f_one_b(2, 4);
    let (gs, gd, ri) = gpt::pp_sched_pair(&sched, 2).expect("gpt 1f1b builds");
    assert_differential("gpt_pp2_1f1b_2", &gs, &gd, &ri);
}

/// Same, across the three boundaries of the interleaved 2x2 lowering.
#[test]
fn differential_gpt_pp_interleaved() {
    let sched = graphguard::schedule::Schedule::interleaved(2, 4, 2);
    let (gs, gd, ri) = gpt::pp_sched_pair(&sched, 4).expect("gpt interleaved builds");
    assert_differential("gpt_pp2x2_intlv_2", &gs, &gd, &ri);
}
