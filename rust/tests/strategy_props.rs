//! Property tests for the distribution-strategy primitives.
//!
//! `strategies::chunks` must partition `[0, total)` exactly (no gap, no
//! overlap) for uneven divisors, `ranks == 1`, and degenerate sizes; the
//! shard/replicate helpers must record input relations that numerically
//! round-trip: evaluating the recorded `R_i` expression on the shards
//! reconstructs the original tensor. The same coverage discipline extends
//! to pipeline stage splits (every block lands in exactly one non-empty
//! stage) and to FSDP parameter gathers (shards re-concatenate to the
//! stored parameter bit-for-bit).

use graphguard::expr::eval::{eval_expr, eval_graph, Env};
use graphguard::expr::TensorRef;
use graphguard::ir::{Graph, Op};
use graphguard::strategies::{
    chunks, fsdp_shard_params, pipeline_stage_split, replicate_input, shard_input, stage_ends,
    RiBuilder,
};
use graphguard::util::ndarray::NdArray;
use graphguard::util::proptest::Prop;
use graphguard::util::rng::Rng;
use rustc_hash::FxHashMap;

#[test]
fn chunks_partition_covers_range_without_overlap() {
    Prop::new("chunks partitions [0,total)").cases(128).check(|rng| {
        let total = rng.below(97) as i64; // includes 0 and non-divisible sizes
        let ranks = 1 + rng.below(8) as usize; // includes ranks == 1, ranks > total
        let parts = chunks(total, ranks);
        if parts.len() != ranks {
            return Err(format!("expected {ranks} chunks, got {}", parts.len()));
        }
        let mut cursor = 0i64;
        for (i, &(lo, hi)) in parts.iter().enumerate() {
            if lo != cursor {
                return Err(format!(
                    "chunk {i} starts at {lo}, expected {cursor} (total={total}, ranks={ranks})"
                ));
            }
            if hi < lo {
                return Err(format!("chunk {i} is negative: ({lo}, {hi})"));
            }
            cursor = hi;
        }
        if cursor != total {
            return Err(format!(
                "partition covers [0,{cursor}) instead of [0,{total}) at ranks={ranks}"
            ));
        }
        // balanced: chunk lengths differ by at most one
        let lens: Vec<i64> = parts.iter().map(|&(lo, hi)| hi - lo).collect();
        let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        if max - min > 1 {
            return Err(format!("unbalanced chunks {lens:?}"));
        }
        Ok(())
    });
}

/// Build a random full tensor, shard it along `dim`, and check that the
/// recorded `R_i` expression (a concat over the per-rank inputs) rebuilds
/// the full tensor exactly.
#[test]
fn shard_input_roundtrips_numerically() {
    Prop::new("shard_input concat round-trip").cases(48).check(|rng| {
        let ranks = [1usize, 2, 2, 4][rng.below(4) as usize];
        let rows = ranks as i64 * (1 + rng.below(3) as i64);
        let cols = 1 + rng.below(5) as i64;
        let dim = rng.below(2) as usize;
        let mut shape = vec![rows, cols];
        // shard dim must be divisible; force it
        if dim == 1 {
            shape[1] = ranks as i64 * (1 + rng.below(3) as i64);
        }

        let mut gs = Graph::new("gs");
        gs.input("X", shape.clone());
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let ids = shard_input(&mut gd, &mut ri, "X", &shape, dim, ranks)
            .map_err(|e| format!("{e:#}"))?;
        if ids.len() != ranks {
            return Err(format!("expected {ranks} shards, got {}", ids.len()));
        }
        let rel = ri.finish(&gs, &gd).map_err(|e| format!("{e:#}"))?;
        let x = gs.tensor_by_name("X").unwrap();
        let cands = rel.get(x);
        if cands.len() != 1 {
            return Err(format!("expected one mapping, got {}", cands.len()));
        }

        // numeric round-trip: full tensor -> shards -> R_i expr -> full
        let mut r2 = Rng::new(rng.next_u64());
        let n: i64 = shape.iter().product();
        let full = NdArray::new(shape.clone(), r2.buf(n as usize, 1.0)).unwrap();
        let mut env: Env = Env::default();
        for (rk, &(lo, hi)) in chunks(shape[dim], ranks).iter().enumerate() {
            let shard = full.slice(dim, lo, hi).map_err(|e| format!("{e:#}"))?;
            env.insert(TensorRef::d(ids[rk]), shard);
        }
        let rebuilt = eval_expr(&cands[0].expr, &env).map_err(|e| format!("{e:#}"))?;
        if rebuilt.shape() != full.shape() || !rebuilt.allclose(&full, 0.0, 0.0) {
            return Err("R_i expression does not reconstruct the full tensor".into());
        }
        Ok(())
    });
}

#[test]
fn replicate_input_roundtrips_identically() {
    Prop::new("replicate_input identity round-trip").cases(32).check(|rng| {
        let rows = 1 + rng.below(6) as i64;
        let cols = 1 + rng.below(6) as i64;
        let shape = vec![rows, cols];
        let mut gs = Graph::new("gs");
        gs.input("W", shape.clone());
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let id = replicate_input(&mut gd, &mut ri, "W", &shape);
        let rel = ri.finish(&gs, &gd).map_err(|e| format!("{e:#}"))?;
        let w = gs.tensor_by_name("W").unwrap();
        let cands = rel.get(w);
        if cands.len() != 1 || cands[0].cost != 0 {
            return Err(format!("replication must record one leaf mapping, got {cands:?}"));
        }
        let mut r2 = Rng::new(rng.next_u64());
        let full =
            NdArray::new(shape.clone(), r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let mut env: Env = Env::default();
        env.insert(TensorRef::d(id), full.clone());
        let rebuilt = eval_expr(&cands[0].expr, &env).map_err(|e| format!("{e:#}"))?;
        if !rebuilt.allclose(&full, 0.0, 0.0) {
            return Err("identity mapping must be exact".into());
        }
        Ok(())
    });
}

#[test]
fn uneven_shard_degrees_are_rejected() {
    Prop::new("indivisible shard rejected").cases(32).check(|rng| {
        let ranks = 2 + rng.below(4) as usize; // 2..=5
        let offset = 1 + rng.below(ranks as u64 - 1) as i64;
        let extent = ranks as i64 * (1 + rng.below(3) as i64) + offset;
        if extent % ranks as i64 == 0 {
            return Err(format!("test setup bug: {extent} divisible by {ranks}"));
        }
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        if shard_input(&mut gd, &mut ri, "X", &[extent, 4], 0, ranks).is_ok() {
            return Err(format!("sharding {extent} rows over {ranks} ranks must fail"));
        }
        Ok(())
    });
}

/// `stage_ends` places exactly `stages - 1` boundaries, strictly
/// increasing, strictly inside `(0, layers)` (so no stage is empty), and
/// consistent with the `chunks` partition of the layer range.
#[test]
fn stage_split_covers_blocks_without_empty_stages() {
    Prop::new("stage boundary placement").cases(96).check(|rng| {
        let layers = 1 + rng.below(12) as usize; // 1..=12
        let stages = 1 + rng.below(layers as u64) as usize; // 1..=layers
        let ends = stage_ends(layers, stages);
        if ends.len() != stages - 1 {
            return Err(format!(
                "{stages} stages over {layers} layers need {} boundaries, got {:?}",
                stages - 1,
                ends
            ));
        }
        let mut prev = 0usize;
        for &e in &ends {
            if e <= prev || e >= layers {
                return Err(format!(
                    "boundary {e} out of range (prev {prev}, layers {layers}): {ends:?}"
                ));
            }
            prev = e;
        }
        // consistent with the chunks partition: boundary k ends stage k
        let parts = chunks(layers as i64, stages);
        for (k, &e) in ends.iter().enumerate() {
            if parts[k].1 != e as i64 {
                return Err(format!("boundary {k} at {e} disagrees with chunks {parts:?}"));
            }
        }
        Ok(())
    });
}

/// `pipeline_stage_split` numeric round-trip: for random micro-batch
/// degrees and chain shapes, the gathered micro-batched output equals the
/// sequential output on `R_i`-consistent inputs.
#[test]
fn pipeline_split_roundtrips_numerically() {
    Prop::new("pipeline split preserves chain semantics").cases(24).check(|rng| {
        let micro = [1usize, 2, 2, 4][rng.below(4) as usize];
        let rows = micro as i64 * (1 + rng.below(3) as i64);
        let cols = 2 * (1 + rng.below(3) as i64);
        let mut gs = Graph::new("chain");
        let x = gs.input("x", vec![rows, cols]);
        let w = gs.input("w", vec![cols, cols]);
        let mm = gs.matmul("b0_mm", x, w);
        let act = gs.op("b1_act", Op::Gelu, vec![mm]);
        let sc = gs.scale("b2_scale", act, 0.5);
        gs.mark_output(sc);
        let (gd, ri) = pipeline_stage_split(&gs, &[0], micro, "b3_out")
            .map_err(|e| format!("{e:#}"))?;
        gd.validate().map_err(|e| format!("{e:#}"))?;
        ri.validate_shapes(&gs, &gd).map_err(|e| format!("{e:#}"))?;

        let mut r2 = Rng::new(rng.next_u64());
        let full = NdArray::new(vec![rows, cols], r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let wv = NdArray::new(vec![cols, cols], r2.buf((cols * cols) as usize, 1.0)).unwrap();
        let mut gs_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        gs_in.insert(x, full.clone());
        gs_in.insert(w, wv.clone());
        let mut gd_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        for (m, &(lo, hi)) in chunks(rows, micro).iter().enumerate() {
            let name = format!("x_r{m}");
            let id = gd.tensor_by_name(&name).ok_or_else(|| format!("missing input {name}"))?;
            gd_in.insert(id, full.slice(0, lo, hi).map_err(|e| format!("{e:#}"))?);
        }
        let wid = gd.tensor_by_name("w_rep").ok_or_else(|| "missing w_rep".to_string())?;
        gd_in.insert(wid, wv);
        let a = eval_graph(&gs, &gs_in).map_err(|e| format!("{e:#}"))?;
        let b = eval_graph(&gd, &gd_in).map_err(|e| format!("{e:#}"))?;
        let (ga, gb) = (&a[gs.outputs[0] as usize], &b[gd.outputs[0] as usize]);
        if ga.shape() != gb.shape() || !ga.allclose(gb, 1e-5, 1e-6) {
            return Err(format!(
                "pipeline output diverges at micro={micro} rows={rows} cols={cols}"
            ));
        }
        Ok(())
    });
}

/// FSDP parameter gathers re-concatenate the stored shards exactly.
#[test]
fn fsdp_gather_roundtrips_numerically() {
    Prop::new("fsdp shard/gather round-trip").cases(32).check(|rng| {
        let ranks = [1usize, 2, 2, 4][rng.below(4) as usize];
        let rows = ranks as i64 * (1 + rng.below(3) as i64);
        let cols = 1 + rng.below(4) as i64;
        let mut gs = Graph::new("gs");
        gs.input("W", vec![rows, cols]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let gathered = fsdp_shard_params(&mut gd, &mut ri, "W", "W_ag", &[rows, cols], ranks)
            .map_err(|e| format!("{e:#}"))?;
        gd.mark_output(gathered);
        ri.finish(&gs, &gd).map_err(|e| format!("{e:#}"))?;

        let mut r2 = Rng::new(rng.next_u64());
        let full = NdArray::new(vec![rows, cols], r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let mut gd_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        for (rk, &(lo, hi)) in chunks(rows, ranks).iter().enumerate() {
            let id = gd
                .tensor_by_name(&format!("W_r{rk}"))
                .ok_or_else(|| format!("missing shard W_r{rk}"))?;
            gd_in.insert(id, full.slice(0, lo, hi).map_err(|e| format!("{e:#}"))?);
        }
        let vals = eval_graph(&gd, &gd_in).map_err(|e| format!("{e:#}"))?;
        let got = &vals[gathered as usize];
        if !got.allclose(&full, 0.0, 0.0) {
            return Err("gathered param must equal the stored param exactly".into());
        }
        Ok(())
    });
}

#[test]
fn single_rank_shard_is_an_identity_concat() {
    // ranks == 1 degenerates to a one-part concat that still validates and
    // round-trips
    let mut gs = Graph::new("gs");
    gs.input("X", vec![3, 5]);
    let mut gd = Graph::new("gd");
    let mut ri = RiBuilder::new();
    let ids = shard_input(&mut gd, &mut ri, "X", &[3, 5], 0, 1).unwrap();
    assert_eq!(ids.len(), 1);
    assert_eq!(gd.shape(ids[0]), &[3, 5]);
    let rel = ri.finish(&gs, &gd).unwrap();
    let x = gs.tensor_by_name("X").unwrap();
    let mut rng = Rng::new(17);
    let full = NdArray::new(vec![3, 5], rng.buf(15, 1.0)).unwrap();
    let mut env: Env = Env::default();
    env.insert(TensorRef::d(ids[0]), full.clone());
    let rebuilt = eval_expr(&rel.get(x)[0].expr, &env).unwrap();
    assert!(rebuilt.allclose(&full, 0.0, 0.0));
}
