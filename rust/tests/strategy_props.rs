//! Property tests for the distribution-strategy primitives.
//!
//! `strategies::chunks` must partition `[0, total)` exactly (no gap, no
//! overlap) for uneven divisors, `ranks == 1`, and degenerate sizes; the
//! shard/replicate helpers must record input relations that numerically
//! round-trip: evaluating the recorded `R_i` expression on the shards
//! reconstructs the original tensor. The same coverage discipline extends
//! to pipeline stage splits (every block lands in exactly one non-empty
//! stage) and to FSDP parameter gathers (shards re-concatenate to the
//! stored parameter bit-for-bit).

use graphguard::expr::eval::{eval_expr, eval_graph, Env};
use graphguard::expr::TensorRef;
use graphguard::ir::{Graph, Op};
use graphguard::schedule::{decode_buffer_tag, lower_buffers, SchedKind, Schedule};
use graphguard::strategies::{
    chunks, fsdp_shard_params, pipeline_stage_split, pipeline_stage_split_scheduled,
    replicate_input, shard_input, stage_ends, RiBuilder,
};
use graphguard::util::ndarray::NdArray;
use graphguard::util::proptest::Prop;
use graphguard::util::rng::Rng;
use rustc_hash::FxHashMap;

#[test]
fn chunks_partition_covers_range_without_overlap() {
    Prop::new("chunks partitions [0,total)").cases(128).check(|rng| {
        let total = rng.below(97) as i64; // includes 0 and non-divisible sizes
        let ranks = 1 + rng.below(8) as usize; // includes ranks == 1, ranks > total
        let parts = chunks(total, ranks);
        if parts.len() != ranks {
            return Err(format!("expected {ranks} chunks, got {}", parts.len()));
        }
        let mut cursor = 0i64;
        for (i, &(lo, hi)) in parts.iter().enumerate() {
            if lo != cursor {
                return Err(format!(
                    "chunk {i} starts at {lo}, expected {cursor} (total={total}, ranks={ranks})"
                ));
            }
            if hi < lo {
                return Err(format!("chunk {i} is negative: ({lo}, {hi})"));
            }
            cursor = hi;
        }
        if cursor != total {
            return Err(format!(
                "partition covers [0,{cursor}) instead of [0,{total}) at ranks={ranks}"
            ));
        }
        // balanced: chunk lengths differ by at most one
        let lens: Vec<i64> = parts.iter().map(|&(lo, hi)| hi - lo).collect();
        let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        if max - min > 1 {
            return Err(format!("unbalanced chunks {lens:?}"));
        }
        Ok(())
    });
}

/// Build a random full tensor, shard it along `dim`, and check that the
/// recorded `R_i` expression (a concat over the per-rank inputs) rebuilds
/// the full tensor exactly.
#[test]
fn shard_input_roundtrips_numerically() {
    Prop::new("shard_input concat round-trip").cases(48).check(|rng| {
        let ranks = [1usize, 2, 2, 4][rng.below(4) as usize];
        let rows = ranks as i64 * (1 + rng.below(3) as i64);
        let cols = 1 + rng.below(5) as i64;
        let dim = rng.below(2) as usize;
        let mut shape = vec![rows, cols];
        // shard dim must be divisible; force it
        if dim == 1 {
            shape[1] = ranks as i64 * (1 + rng.below(3) as i64);
        }

        let mut gs = Graph::new("gs");
        gs.input("X", shape.clone());
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let ids = shard_input(&mut gd, &mut ri, "X", &shape, dim, ranks)
            .map_err(|e| format!("{e:#}"))?;
        if ids.len() != ranks {
            return Err(format!("expected {ranks} shards, got {}", ids.len()));
        }
        let rel = ri.finish(&gs, &gd).map_err(|e| format!("{e:#}"))?;
        let x = gs.tensor_by_name("X").unwrap();
        let cands = rel.get(x);
        if cands.len() != 1 {
            return Err(format!("expected one mapping, got {}", cands.len()));
        }

        // numeric round-trip: full tensor -> shards -> R_i expr -> full
        let mut r2 = Rng::new(rng.next_u64());
        let n: i64 = shape.iter().product();
        let full = NdArray::new(shape.clone(), r2.buf(n as usize, 1.0)).unwrap();
        let mut env: Env = Env::default();
        for (rk, &(lo, hi)) in chunks(shape[dim], ranks).iter().enumerate() {
            let shard = full.slice(dim, lo, hi).map_err(|e| format!("{e:#}"))?;
            env.insert(TensorRef::d(ids[rk]), shard);
        }
        let rebuilt = eval_expr(&cands[0].expr, &env).map_err(|e| format!("{e:#}"))?;
        if rebuilt.shape() != full.shape() || !rebuilt.allclose(&full, 0.0, 0.0) {
            return Err("R_i expression does not reconstruct the full tensor".into());
        }
        Ok(())
    });
}

#[test]
fn replicate_input_roundtrips_identically() {
    Prop::new("replicate_input identity round-trip").cases(32).check(|rng| {
        let rows = 1 + rng.below(6) as i64;
        let cols = 1 + rng.below(6) as i64;
        let shape = vec![rows, cols];
        let mut gs = Graph::new("gs");
        gs.input("W", shape.clone());
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let id = replicate_input(&mut gd, &mut ri, "W", &shape);
        let rel = ri.finish(&gs, &gd).map_err(|e| format!("{e:#}"))?;
        let w = gs.tensor_by_name("W").unwrap();
        let cands = rel.get(w);
        if cands.len() != 1 || cands[0].cost != 0 {
            return Err(format!("replication must record one leaf mapping, got {cands:?}"));
        }
        let mut r2 = Rng::new(rng.next_u64());
        let full =
            NdArray::new(shape.clone(), r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let mut env: Env = Env::default();
        env.insert(TensorRef::d(id), full.clone());
        let rebuilt = eval_expr(&cands[0].expr, &env).map_err(|e| format!("{e:#}"))?;
        if !rebuilt.allclose(&full, 0.0, 0.0) {
            return Err("identity mapping must be exact".into());
        }
        Ok(())
    });
}

#[test]
fn uneven_shard_degrees_are_rejected() {
    Prop::new("indivisible shard rejected").cases(32).check(|rng| {
        let ranks = 2 + rng.below(4) as usize; // 2..=5
        let offset = 1 + rng.below(ranks as u64 - 1) as i64;
        let extent = ranks as i64 * (1 + rng.below(3) as i64) + offset;
        if extent % ranks as i64 == 0 {
            return Err(format!("test setup bug: {extent} divisible by {ranks}"));
        }
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        if shard_input(&mut gd, &mut ri, "X", &[extent, 4], 0, ranks).is_ok() {
            return Err(format!("sharding {extent} rows over {ranks} ranks must fail"));
        }
        Ok(())
    });
}

/// `stage_ends` places exactly `stages - 1` boundaries, strictly
/// increasing, strictly inside `(0, layers)` (so no stage is empty), and
/// consistent with the `chunks` partition of the layer range.
#[test]
fn stage_split_covers_blocks_without_empty_stages() {
    Prop::new("stage boundary placement").cases(96).check(|rng| {
        let layers = 1 + rng.below(12) as usize; // 1..=12
        let stages = 1 + rng.below(layers as u64) as usize; // 1..=layers
        let ends = stage_ends(layers, stages);
        if ends.len() != stages - 1 {
            return Err(format!(
                "{stages} stages over {layers} layers need {} boundaries, got {:?}",
                stages - 1,
                ends
            ));
        }
        let mut prev = 0usize;
        for &e in &ends {
            if e <= prev || e >= layers {
                return Err(format!(
                    "boundary {e} out of range (prev {prev}, layers {layers}): {ends:?}"
                ));
            }
            prev = e;
        }
        // consistent with the chunks partition: boundary k ends stage k
        let parts = chunks(layers as i64, stages);
        for (k, &e) in ends.iter().enumerate() {
            if parts[k].1 != e as i64 {
                return Err(format!("boundary {k} at {e} disagrees with chunks {parts:?}"));
            }
        }
        Ok(())
    });
}

/// `pipeline_stage_split` numeric round-trip: for random micro-batch
/// degrees and chain shapes, the gathered micro-batched output equals the
/// sequential output on `R_i`-consistent inputs.
#[test]
fn pipeline_split_roundtrips_numerically() {
    Prop::new("pipeline split preserves chain semantics").cases(24).check(|rng| {
        let micro = [1usize, 2, 2, 4][rng.below(4) as usize];
        let rows = micro as i64 * (1 + rng.below(3) as i64);
        let cols = 2 * (1 + rng.below(3) as i64);
        let mut gs = Graph::new("chain");
        let x = gs.input("x", vec![rows, cols]);
        let w = gs.input("w", vec![cols, cols]);
        let mm = gs.matmul("b0_mm", x, w);
        let act = gs.op("b1_act", Op::Gelu, vec![mm]);
        let sc = gs.scale("b2_scale", act, 0.5);
        gs.mark_output(sc);
        let (gd, ri) = pipeline_stage_split(&gs, &[0], micro, "b3_out")
            .map_err(|e| format!("{e:#}"))?;
        gd.validate().map_err(|e| format!("{e:#}"))?;
        ri.validate_shapes(&gs, &gd).map_err(|e| format!("{e:#}"))?;

        let mut r2 = Rng::new(rng.next_u64());
        let full = NdArray::new(vec![rows, cols], r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let wv = NdArray::new(vec![cols, cols], r2.buf((cols * cols) as usize, 1.0)).unwrap();
        let mut gs_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        gs_in.insert(x, full.clone());
        gs_in.insert(w, wv.clone());
        let mut gd_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        for (m, &(lo, hi)) in chunks(rows, micro).iter().enumerate() {
            let name = format!("x_r{m}");
            let id = gd.tensor_by_name(&name).ok_or_else(|| format!("missing input {name}"))?;
            gd_in.insert(id, full.slice(0, lo, hi).map_err(|e| format!("{e:#}"))?);
        }
        let wid = gd.tensor_by_name("w_rep").ok_or_else(|| "missing w_rep".to_string())?;
        gd_in.insert(wid, wv);
        let a = eval_graph(&gs, &gs_in).map_err(|e| format!("{e:#}"))?;
        let b = eval_graph(&gd, &gd_in).map_err(|e| format!("{e:#}"))?;
        let (ga, gb) = (&a[gs.outputs[0] as usize], &b[gd.outputs[0] as usize]);
        if ga.shape() != gb.shape() || !ga.allclose(gb, 1e-5, 1e-6) {
            return Err(format!(
                "pipeline output diverges at micro={micro} rows={rows} cols={cols}"
            ));
        }
        Ok(())
    });
}

/// FSDP parameter gathers re-concatenate the stored shards exactly.
#[test]
fn fsdp_gather_roundtrips_numerically() {
    Prop::new("fsdp shard/gather round-trip").cases(32).check(|rng| {
        let ranks = [1usize, 2, 2, 4][rng.below(4) as usize];
        let rows = ranks as i64 * (1 + rng.below(3) as i64);
        let cols = 1 + rng.below(4) as i64;
        let mut gs = Graph::new("gs");
        gs.input("W", vec![rows, cols]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let gathered = fsdp_shard_params(&mut gd, &mut ri, "W", "W_ag", &[rows, cols], ranks)
            .map_err(|e| format!("{e:#}"))?;
        gd.mark_output(gathered);
        ri.finish(&gs, &gd).map_err(|e| format!("{e:#}"))?;

        let mut r2 = Rng::new(rng.next_u64());
        let full = NdArray::new(vec![rows, cols], r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let mut gd_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        for (rk, &(lo, hi)) in chunks(rows, ranks).iter().enumerate() {
            let id = gd
                .tensor_by_name(&format!("W_r{rk}"))
                .ok_or_else(|| format!("missing shard W_r{rk}"))?;
            gd_in.insert(id, full.slice(0, lo, hi).map_err(|e| format!("{e:#}"))?);
        }
        let vals = eval_graph(&gd, &gd_in).map_err(|e| format!("{e:#}"))?;
        let got = &vals[gathered as usize];
        if !got.allclose(&full, 0.0, 0.0) {
            return Err("gathered param must equal the stored param exactly".into());
        }
        Ok(())
    });
}

/// A random legal schedule. Stages 2..=3 with enough micro-batches to
/// exercise multi-epoch slot reuse; interleaved degrees keep
/// `micro % stages == 0`.
fn random_schedule(rng: &mut Rng) -> Schedule {
    let stages = 2 + rng.below(2) as usize; // 2..=3
    let kind = [SchedKind::GPipe, SchedKind::OneFOneB, SchedKind::Interleaved]
        [rng.below(3) as usize];
    match kind {
        SchedKind::GPipe => Schedule::gpipe(stages, stages * (1 + rng.below(3) as usize)),
        SchedKind::OneFOneB => {
            Schedule::one_f_one_b(stages, stages * (1 + rng.below(3) as usize))
        }
        SchedKind::Interleaved => {
            Schedule::interleaved(stages, stages * (1 + rng.below(3) as usize), 2)
        }
    }
}

/// A single-output matmul chain with exactly one node per block, so cut
/// nodes are just `0..boundaries`.
fn matmul_chain(blocks: usize, rows: i64, cols: i64) -> Graph {
    let mut gs = Graph::new("chain");
    let mut x = gs.input("x", vec![rows, cols]);
    for i in 0..blocks {
        let w = gs.input(&format!("w{i}"), vec![cols, cols]);
        x = gs.matmul(&format!("b{i}_mm"), x, w);
    }
    gs.mark_output(x);
    gs
}

/// Every legal (schedule, safe depth) assignment covers the full logical
/// channel grid with pairwise-equal, globally-distinct buffer tags, and no
/// two users of one physical buffer have overlapping live ranges — checked
/// all-pairs against the timetable, a strictly stronger statement than the
/// adjacent-user audit `lower_buffers` itself runs.
#[test]
fn buffer_assignment_covers_channels_without_live_range_overlap() {
    Prop::new("buffer assignment coverage + liveness").cases(48).check(|rng| {
        let sched = random_schedule(rng);
        let chunks_n = sched.chunks();
        let rows = sched.micro as i64 * (1 + rng.below(2) as i64);
        let gs = matmul_chain(chunks_n, rows, 4);
        let cuts: Vec<u32> = (0..chunks_n as u32 - 1).collect();
        let depth = sched.min_safe_depth().map_err(|e| format!("{e:#}"))?;
        let (gd, _ri) = pipeline_stage_split(&gs, &cuts, sched.micro, "out")
            .map_err(|e| format!("{e:#}"))?;
        let low = lower_buffers(&gd, &sched, depth).map_err(|e| format!("{e:#}"))?;
        low.validate().map_err(|e| format!("{e:#}"))?;

        // coverage: decoded (boundary, slot, epoch) tags reconstruct the
        // full (boundary, micro) grid exactly once, send/recv tags paired
        let mut grid: Vec<(usize, usize)> = Vec::new();
        for nid in low.topo_order() {
            let node = low.node(nid);
            if let Op::Send { chan } = node.op {
                let (b, slot, epoch) =
                    decode_buffer_tag(chan).ok_or("send not buffer-tagged")?;
                if slot >= depth {
                    return Err(format!("slot {slot} outside pool depth {depth}"));
                }
                let m = epoch * depth + slot;
                grid.push((b, m));
                let rcv = low.consumers(node.output);
                let rc = match low.node(rcv[0]).op {
                    Op::Recv { chan } => chan,
                    ref o => return Err(format!("send feeds {o:?}")),
                };
                if rc != chan {
                    return Err(format!("unpaired tags send={chan} recv={rc}"));
                }
            }
        }
        grid.sort_unstable();
        let want: Vec<(usize, usize)> = (0..sched.boundaries())
            .flat_map(|b| (0..sched.micro).map(move |m| (b, m)))
            .collect();
        if grid != want {
            return Err(format!("channel grid not covered: {grid:?}"));
        }

        // all-pairs live-range disjointness per physical buffer
        let tt = sched.timetable().map_err(|e| format!("{e:#}"))?;
        for b in 0..sched.boundaries() {
            for m1 in 0..sched.micro {
                for m2 in m1 + 1..sched.micro {
                    if m1 % depth != m2 % depth {
                        continue; // different physical buffers
                    }
                    // buffer live for m1 from its write to its read; m2's
                    // write must land strictly after m1's read completes
                    if tt.fwd_tick(b, m2) <= tt.fwd_tick(b + 1, m1) {
                        return Err(format!(
                            "{:?} depth {depth}: users {m1},{m2} of boundary {b} slot {} \
                             overlap",
                            sched,
                            m1 % depth
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// An undersized pool is rejected at construction — never silently lowered.
#[test]
fn undersized_buffer_pools_are_rejected_at_construction() {
    Prop::new("undersized pool rejected").cases(32).check(|rng| {
        let sched = random_schedule(rng);
        let depth = sched.min_safe_depth().map_err(|e| format!("{e:#}"))?;
        if depth == 1 {
            return Ok(()); // nothing smaller to reject
        }
        let chunks_n = sched.chunks();
        let gs = matmul_chain(chunks_n, sched.micro as i64, 4);
        let cuts: Vec<u32> = (0..chunks_n as u32 - 1).collect();
        let (gd, _ri) = pipeline_stage_split(&gs, &cuts, sched.micro, "out")
            .map_err(|e| format!("{e:#}"))?;
        match lower_buffers(&gd, &sched, depth - 1) {
            Ok(_) => Err(format!("{sched:?}: depth {} must be rejected", depth - 1)),
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains("unsafe") {
                    Ok(())
                } else {
                    Err(format!("wrong rejection: {msg}"))
                }
            }
        }
    });
}

/// The scheduled lowering is numerics-preserving: the buffer-tagged graph
/// computes exactly what the logical split computes.
#[test]
fn scheduled_pipeline_split_roundtrips_numerically() {
    Prop::new("scheduled split preserves chain semantics").cases(24).check(|rng| {
        let sched = random_schedule(rng);
        let chunks_n = sched.chunks();
        let rows = sched.micro as i64 * (1 + rng.below(2) as i64);
        let cols = 4;
        let gs = matmul_chain(chunks_n, rows, cols);
        let cuts: Vec<u32> = (0..chunks_n as u32 - 1).collect();
        let depth = sched.min_safe_depth().map_err(|e| format!("{e:#}"))?;
        let (gd, ri) = pipeline_stage_split_scheduled(&gs, &cuts, "out", &sched, depth)
            .map_err(|e| format!("{e:#}"))?;
        gd.validate().map_err(|e| format!("{e:#}"))?;
        ri.validate_shapes(&gs, &gd).map_err(|e| format!("{e:#}"))?;

        let mut r2 = Rng::new(rng.next_u64());
        let full = NdArray::new(vec![rows, cols], r2.buf((rows * cols) as usize, 1.0)).unwrap();
        let mut gs_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        gs_in.insert(gs.tensor_by_name("x").unwrap(), full.clone());
        let mut gd_in: FxHashMap<u32, NdArray> = FxHashMap::default();
        for (m, &(lo, hi)) in chunks(rows, sched.micro).iter().enumerate() {
            let id = gd
                .tensor_by_name(&format!("x_r{m}"))
                .ok_or_else(|| format!("missing input x_r{m}"))?;
            gd_in.insert(id, full.slice(0, lo, hi).map_err(|e| format!("{e:#}"))?);
        }
        for i in 0..chunks_n {
            let wv = NdArray::new(vec![cols, cols], r2.buf((cols * cols) as usize, 1.0)).unwrap();
            gs_in.insert(gs.tensor_by_name(&format!("w{i}")).unwrap(), wv.clone());
            let id = gd
                .tensor_by_name(&format!("w{i}_rep"))
                .ok_or_else(|| format!("missing input w{i}_rep"))?;
            gd_in.insert(id, wv);
        }
        let a = eval_graph(&gs, &gs_in).map_err(|e| format!("{e:#}"))?;
        let b = eval_graph(&gd, &gd_in).map_err(|e| format!("{e:#}"))?;
        let (ga, gb) = (&a[gs.outputs[0] as usize], &b[gd.outputs[0] as usize]);
        if ga.shape() != gb.shape() || !ga.allclose(gb, 1e-5, 1e-6) {
            return Err(format!("scheduled pipeline output diverges under {sched:?}"));
        }
        Ok(())
    });
}

#[test]
fn single_rank_shard_is_an_identity_concat() {
    // ranks == 1 degenerates to a one-part concat that still validates and
    // round-trips
    let mut gs = Graph::new("gs");
    gs.input("X", vec![3, 5]);
    let mut gd = Graph::new("gd");
    let mut ri = RiBuilder::new();
    let ids = shard_input(&mut gd, &mut ri, "X", &[3, 5], 0, 1).unwrap();
    assert_eq!(ids.len(), 1);
    assert_eq!(gd.shape(ids[0]), &[3, 5]);
    let rel = ri.finish(&gs, &gd).unwrap();
    let x = gs.tensor_by_name("X").unwrap();
    let mut rng = Rng::new(17);
    let full = NdArray::new(vec![3, 5], rng.buf(15, 1.0)).unwrap();
    let mut env: Env = Env::default();
    env.insert(TensorRef::d(ids[0]), full.clone());
    let rebuilt = eval_expr(&rel.get(x)[0].expr, &env).unwrap();
    assert!(rebuilt.allclose(&full, 0.0, 0.0));
}
