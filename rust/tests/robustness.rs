//! Fault-tolerance integration tests: resource budgets, three-valued
//! verdicts, coordinator determinism, and crash-safe resumable fuzz
//! campaigns. The chaos-injection counterparts (which need the `chaos`
//! feature) live in `rust/tests/chaos.rs`.

use graphguard::coordinator::Coordinator;
use graphguard::egraph::SaturationLimits;
use graphguard::fuzz::{self, FuzzConfig, Journal};
use graphguard::infer::{EscalationPolicy, InconclusiveReason, InferConfig, Verdict};
use graphguard::models;
use graphguard::Verifier;
use std::path::PathBuf;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gg_rob_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------------------
// Verdict taxonomy: each exhaustion mode maps to its own Inconclusive reason,
// and neither starvation nor deadlines ever masquerade as a refutation.
// ---------------------------------------------------------------------------

#[test]
fn starved_node_budget_is_inconclusive_node_budget() {
    let w = models::table2_workloads(2).remove(0);
    let cfg = InferConfig {
        limits: SaturationLimits::new(8, 10),
        ..InferConfig::default()
    };
    match Verifier::with_config(cfg).isolated(true).run(&w.gs, &w.gd, &w.ri) {
        Verdict::Inconclusive(i) => {
            assert_eq!(i.reason, InconclusiveReason::NodeBudget, "{i}");
            assert!(!i.region.is_empty(), "exhaustion must name its region");
        }
        v => panic!("a 10-node budget must starve, got {}", v.tag()),
    }
}

#[test]
fn elapsed_deadline_is_inconclusive_timeout() {
    let w = models::table2_workloads(2).remove(0);
    let cfg = InferConfig {
        region_deadline: Some(Duration::ZERO),
        ..InferConfig::default()
    };
    match Verifier::with_config(cfg).isolated(true).run(&w.gs, &w.gd, &w.ri) {
        Verdict::Inconclusive(i) => assert_eq!(i.reason, InconclusiveReason::Timeout, "{i}"),
        v => panic!("a zero deadline must time out, got {}", v.tag()),
    }
}

#[test]
fn genuine_bug_still_refutes_at_default_budgets() {
    let (gs, gd, ri) = models::regression::grad_accum_buggy_pair(2).unwrap();
    match Verifier::new().isolated(true).run(&gs, &gd, &ri) {
        Verdict::Refuted(e) => {
            assert!(!e.node_name.is_empty(), "refutation must carry a locus")
        }
        v => panic!("known-buggy pair must be Refuted, got {}", v.tag()),
    }
}

/// The default budgets are part of the soundness-of-service contract: no
/// clean Table-2 workload may regress into `Inconclusive` at defaults.
#[test]
fn clean_table2_workloads_never_inconclusive_at_defaults() {
    for w in models::table2_workloads(2) {
        let v = Verifier::new().isolated(true).run(&w.gs, &w.gd, &w.ri);
        assert!(v.is_verified(), "{}: expected verified, got {}", w.name, v.tag());
    }
}

#[test]
fn verdict_tags_are_stable() {
    // Journals, FUZZ_REPORT.json, and CI log-scrapers key on these strings.
    assert_eq!(InconclusiveReason::Timeout.tag(), "timeout");
    assert_eq!(InconclusiveReason::NodeBudget.tag(), "node_budget");
    assert_eq!(InconclusiveReason::Panic.tag(), "panic");
}

// ---------------------------------------------------------------------------
// Escalation: a retryable starvation at a small initial budget must converge
// to the same Verified verdict the defaults produce.
// ---------------------------------------------------------------------------

#[test]
fn escalation_recovers_from_starved_initial_budget() {
    let w = models::table2_workloads(2).remove(0);
    let cfg = InferConfig {
        limits: SaturationLimits::new(8, 60_000),
        ..InferConfig::default()
    };
    let policy = EscalationPolicy {
        max_attempts: 3,
        initial: SaturationLimits::new(4, 10),
        ..EscalationPolicy::default()
    };
    let (v, attempts) =
        Verifier::with_config(cfg).escalation(policy).run_counted(&w.gs, &w.gd, &w.ri);
    assert!(v.is_verified(), "escalation should reach Verified, got {}", v.tag());
    assert!(attempts > 1, "a 10-node initial budget cannot succeed on attempt 1");
}

// ---------------------------------------------------------------------------
// Coordinator determinism: threads=1 twice and threads=4 once must agree on
// every verdict, mapping count, and lemma-application count.
// ---------------------------------------------------------------------------

#[test]
fn coordinator_results_are_thread_count_invariant() {
    let cfg = InferConfig::default();
    let a = Coordinator::new(1, cfg.clone()).run_batch(models::table2_workloads(2));
    let b = Coordinator::new(1, cfg.clone()).run_batch(models::table2_workloads(2));
    let c = Coordinator::new(4, cfg).run_batch(models::table2_workloads(2));
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), c.len());
    for ((ra, rb), rc) in a.iter().zip(&b).zip(&c) {
        for r in [rb, rc] {
            assert_eq!(ra.name, r.name, "submission order must be preserved");
            assert_eq!(ra.verdict, r.verdict, "{}", ra.name);
            assert_eq!(ra.mappings, r.mappings, "{}", ra.name);
            assert_eq!(ra.lemma_applications, r.lemma_applications, "{}", ra.name);
            assert_eq!(ra.attempts, r.attempts, "{}", ra.name);
        }
    }
}

// ---------------------------------------------------------------------------
// Crash-safe fuzz campaigns: a campaign killed mid-run and resumed from its
// journal must reproduce the byte-identical final report.
// ---------------------------------------------------------------------------

fn drill_cfg(out_dir: PathBuf) -> FuzzConfig {
    FuzzConfig {
        seeds: 8,
        base_seed: 7,
        ranks: 2,
        mutants_per_model: 2,
        out_dir,
        write_files: true,
        ..FuzzConfig::default()
    }
}

#[test]
fn resumed_campaign_reproduces_byte_identical_report() {
    // Reference: one uninterrupted run.
    let full_dir = tmpdir("full");
    let full = fuzz::run_fuzz(&drill_cfg(full_dir.clone())).unwrap();
    assert!(!full.aborted);
    assert_eq!(full.models, 8);

    // Crash drill: abort after 3 fresh seeds, then resume from the journal.
    let dir = tmpdir("resume");
    let aborted = fuzz::run_fuzz(&FuzzConfig {
        abort_after: Some(3),
        ..drill_cfg(dir.clone())
    })
    .unwrap();
    assert!(aborted.aborted, "--abort-after must stop the campaign early");
    assert_eq!(aborted.models, 3, "exactly the journaled prefix is counted");
    assert!(Journal::path_in(&dir).exists(), "journal must survive the crash");

    let resumed_cfg = fuzz::resume_config(&dir).unwrap();
    assert!(resumed_cfg.resume);
    assert_eq!(resumed_cfg.seeds, 8);
    assert_eq!(resumed_cfg.base_seed, 7);
    let resumed = fuzz::run_fuzz(&resumed_cfg).unwrap();
    assert!(!resumed.aborted);

    assert_eq!(
        full.to_json().to_string_pretty(),
        resumed.to_json().to_string_pretty(),
        "resumed campaign must be byte-identical to an uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&full_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_rejects_mismatched_campaign_config() {
    let dir = tmpdir("mismatch");
    let aborted = fuzz::run_fuzz(&FuzzConfig {
        abort_after: Some(2),
        ..drill_cfg(dir.clone())
    })
    .unwrap();
    assert!(aborted.aborted);

    let mut cfg = fuzz::resume_config(&dir).unwrap();
    cfg.base_seed = 99; // a different campaign's seeds must not be mixed in
    let err = fuzz::run_fuzz(&cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("journal"),
        "mismatch error should point at the journal: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_without_journal_is_an_error() {
    let dir = tmpdir("nojournal");
    assert!(fuzz::resume_config(&dir).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
