//! Decision procedure for comparisons over linear integer expressions under
//! user constraints (the SMT-LIB role in the paper, §5.2).
//!
//! Constraints are equalities `e = 0` and inequalities `e ≥ 0` over
//! [`LinExpr`]s. Queries ask whether `a ⋈ b` (for ⋈ ∈ {=, ≠, ≤, <, ≥, >}) is
//! implied, refuted, or unknown. The procedure:
//!
//! 1. substitutes equality constraints (solved for a pivot symbol with unit
//!    coefficient — the common "sym = value" shape capture produces),
//! 2. then bounds the residual `a - b` using interval arithmetic derived from
//!    the inequality constraints.
//!
//! This is sound (never answers True/False unless implied) and complete for
//! the shape arithmetic our lemmas generate; anything beyond returns
//! [`Truth::Unknown`], which conditions treat as "lemma does not fire" —
//! preserving GraphGuard's soundness at the cost of completeness, exactly the
//! paper's trade-off.

use super::linexpr::{LinExpr, SymId};
use rustc_hash::FxHashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Truth {
    True,
    False,
    Unknown,
}

impl Truth {
    pub fn is_true(self) -> bool {
        self == Truth::True
    }
}

#[derive(Debug, Default, Clone)]
pub struct Solver {
    /// Substitutions sym -> expression (from equality constraints).
    subst: FxHashMap<SymId, LinExpr>,
    /// Inequality constraints `e ≥ 0` (post-substitution).
    ge_zero: Vec<LinExpr>,
    /// Per-symbol concrete bounds derived from single-symbol inequalities.
    bounds: FxHashMap<SymId, (Option<i64>, Option<i64>)>,
}

impl Solver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assert `a = b`.
    pub fn assert_eq(&mut self, a: &LinExpr, b: &LinExpr) {
        let e = self.substitute(&a.sub(b));
        // Find a pivot symbol with coefficient ±1 to solve for.
        if let Some(&(s, c)) = e.terms.iter().find(|&&(_, c)| c == 1 || c == -1) {
            // e = 0  =>  c*s = -(e - c*s)  =>  s = -(e - c*s)/c
            let rest = e.sub(&LinExpr { k: 0, terms: vec![(s, c)] });
            let solved = rest.scale(-c); // c is ±1 so this divides exactly
            self.add_subst(s, solved);
        } else if !e.is_const() {
            // Keep as a pair of inequalities e >= 0 and -e >= 0.
            self.ge_zero.push(e.clone());
            self.ge_zero.push(e.scale(-1));
        }
    }

    /// Assert `a ≥ b`.
    pub fn assert_ge(&mut self, a: &LinExpr, b: &LinExpr) {
        let e = self.substitute(&a.sub(b));
        if let Some((s, c, rest)) = single_symbol(&e) {
            // c*s + rest >= 0 with rest constant
            let (lo, hi) = self.bounds.entry(s).or_insert((None, None));
            if c > 0 {
                // s >= ceil(-rest / c)
                let bound = div_ceil(-rest, c);
                *lo = Some(lo.map_or(bound, |old: i64| old.max(bound)));
            } else {
                // s <= floor(rest / -c)
                let bound = div_floor(rest, -c);
                *hi = Some(hi.map_or(bound, |old: i64| old.min(bound)));
            }
        }
        self.ge_zero.push(e);
    }

    fn add_subst(&mut self, s: SymId, e: LinExpr) {
        // Apply to existing substitutions to keep them triangular.
        let keys: Vec<SymId> = self.subst.keys().copied().collect();
        for k in keys {
            let v = self.subst[&k].clone();
            self.subst.insert(k, subst_one(&v, s, &e));
        }
        self.subst.insert(s, e);
        for g in &mut self.ge_zero {
            *g = subst_one(g, s, &self.subst[&s]);
        }
    }

    /// Fully substitute known equalities into `e`.
    pub fn substitute(&self, e: &LinExpr) -> LinExpr {
        let mut cur = e.clone();
        // Triangular substitution terminates in ≤ |subst| passes.
        for _ in 0..=self.subst.len() {
            let mut next = LinExpr::constant(cur.k);
            let mut changed = false;
            for &(s, c) in &cur.terms {
                if let Some(rep) = self.subst.get(&s) {
                    next = next.add(&rep.scale(c));
                    changed = true;
                } else {
                    next = next.add(&LinExpr { k: 0, terms: vec![(s, c)] });
                }
            }
            cur = next;
            if !changed {
                break;
            }
        }
        cur
    }

    /// Bound `e` over the constraint store: (min, max), None = unbounded.
    fn interval(&self, e: &LinExpr) -> (Option<i64>, Option<i64>) {
        let mut lo = Some(e.k);
        let mut hi = Some(e.k);
        for &(s, c) in &e.terms {
            let (slo, shi) = self.bounds.get(&s).copied().unwrap_or((None, None));
            let (tlo, thi) = if c >= 0 {
                (slo.map(|v| v * c), shi.map(|v| v * c))
            } else {
                (shi.map(|v| v * c), slo.map(|v| v * c))
            };
            lo = match (lo, tlo) {
                (Some(a), Some(b)) => Some(a + b),
                _ => None,
            };
            hi = match (hi, thi) {
                (Some(a), Some(b)) => Some(a + b),
                _ => None,
            };
        }
        (lo, hi)
    }

    /// Is `a = b` implied / refuted / unknown?
    pub fn check_eq(&self, a: &LinExpr, b: &LinExpr) -> Truth {
        let d = self.substitute(&a.sub(b));
        if let Some(k) = d.as_const() {
            return if k == 0 { Truth::True } else { Truth::False };
        }
        let (lo, hi) = self.interval(&d);
        if lo == Some(0) && hi == Some(0) {
            return Truth::True;
        }
        if lo.is_some_and(|l| l > 0) || hi.is_some_and(|h| h < 0) {
            return Truth::False;
        }
        Truth::Unknown
    }

    /// Is `a ≥ b` implied / refuted / unknown?
    pub fn check_ge(&self, a: &LinExpr, b: &LinExpr) -> Truth {
        let d = self.substitute(&a.sub(b));
        if let Some(k) = d.as_const() {
            return if k >= 0 { Truth::True } else { Truth::False };
        }
        // Direct constraint hit: d ≥ 0 asserted verbatim?
        if self.ge_zero.iter().any(|g| g == &d) {
            return Truth::True;
        }
        let (lo, hi) = self.interval(&d);
        if lo.is_some_and(|l| l >= 0) {
            return Truth::True;
        }
        if hi.is_some_and(|h| h < 0) {
            return Truth::False;
        }
        Truth::Unknown
    }

    pub fn check_le(&self, a: &LinExpr, b: &LinExpr) -> Truth {
        self.check_ge(b, a)
    }

    pub fn check_lt(&self, a: &LinExpr, b: &LinExpr) -> Truth {
        self.check_ge(b, &a.add(&LinExpr::constant(1)))
    }

    /// Resolve `e` to a concrete value if the constraints pin it down.
    pub fn concretize(&self, e: &LinExpr) -> Option<i64> {
        let d = self.substitute(e);
        if let Some(k) = d.as_const() {
            return Some(k);
        }
        let (lo, hi) = self.interval(&d);
        match (lo, hi) {
            (Some(a), Some(b)) if a == b => Some(a),
            _ => None,
        }
    }
}

/// If `e` has exactly one symbolic term, return (sym, coeff, constant).
fn single_symbol(e: &LinExpr) -> Option<(SymId, i64, i64)> {
    if e.terms.len() == 1 {
        let (s, c) = e.terms[0];
        Some((s, c, e.k))
    } else {
        None
    }
}

fn subst_one(e: &LinExpr, s: SymId, rep: &LinExpr) -> LinExpr {
    let mut out = LinExpr::constant(e.k);
    for &(t, c) in &e.terms {
        if t == s {
            out = out.add(&rep.scale(c));
        } else {
            out = out.add(&LinExpr { k: 0, terms: vec![(t, c)] });
        }
    }
    out
}

fn div_ceil(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    (a + b - 1).div_euclid(b)
}

fn div_floor(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    a.div_euclid(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::linexpr::SymTable;

    fn setup() -> (SymTable, Solver) {
        (SymTable::new(), Solver::new())
    }

    #[test]
    fn concrete_comparisons() {
        let (_, s) = setup();
        assert_eq!(s.check_eq(&LinExpr::constant(3), &LinExpr::constant(3)), Truth::True);
        assert_eq!(s.check_eq(&LinExpr::constant(3), &LinExpr::constant(4)), Truth::False);
        assert_eq!(s.check_ge(&LinExpr::constant(3), &LinExpr::constant(3)), Truth::True);
        assert_eq!(s.check_lt(&LinExpr::constant(3), &LinExpr::constant(4)), Truth::True);
    }

    #[test]
    fn equality_substitution() {
        let (mut t, mut s) = setup();
        let a = t.intern("a");
        let b = t.intern("b");
        // a = b + 2
        s.assert_eq(&LinExpr::sym(a), &LinExpr::sym(b).add(&LinExpr::constant(2)));
        assert_eq!(
            s.check_eq(&LinExpr::sym(a).sub(&LinExpr::sym(b)), &LinExpr::constant(2)),
            Truth::True
        );
        assert_eq!(s.check_ge(&LinExpr::sym(a), &LinExpr::sym(b)), Truth::True);
        assert_eq!(s.check_eq(&LinExpr::sym(a), &LinExpr::sym(b)), Truth::False);
    }

    #[test]
    fn chained_equalities() {
        let (mut t, mut s) = setup();
        let a = t.intern("a");
        let b = t.intern("b");
        let c = t.intern("c");
        s.assert_eq(&LinExpr::sym(a), &LinExpr::sym(b));
        s.assert_eq(&LinExpr::sym(b), &LinExpr::sym(c).add(&LinExpr::constant(1)));
        assert_eq!(
            s.check_eq(&LinExpr::sym(a), &LinExpr::sym(c).add(&LinExpr::constant(1))),
            Truth::True
        );
    }

    #[test]
    fn inequality_bounds() {
        let (mut t, mut s) = setup();
        let n = t.intern("n");
        // n >= 4
        s.assert_ge(&LinExpr::sym(n), &LinExpr::constant(4));
        assert_eq!(s.check_ge(&LinExpr::sym(n), &LinExpr::constant(2)), Truth::True);
        assert_eq!(s.check_lt(&LinExpr::sym(n), &LinExpr::constant(3)), Truth::False);
        assert_eq!(s.check_ge(&LinExpr::sym(n), &LinExpr::constant(5)), Truth::Unknown);
        // 2n >= 8 is implied
        assert_eq!(s.check_ge(&LinExpr::sym(n).scale(2), &LinExpr::constant(8)), Truth::True);
    }

    #[test]
    fn pinned_by_two_sided_bounds() {
        let (mut t, mut s) = setup();
        let n = t.intern("n");
        s.assert_ge(&LinExpr::sym(n), &LinExpr::constant(7));
        s.assert_ge(&LinExpr::constant(7), &LinExpr::sym(n));
        assert_eq!(s.concretize(&LinExpr::sym(n)), Some(7));
        assert_eq!(s.check_eq(&LinExpr::sym(n), &LinExpr::constant(7)), Truth::True);
    }

    #[test]
    fn unknown_stays_unknown() {
        let (mut t, s) = setup();
        let a = t.intern("a");
        let b = t.intern("b");
        assert_eq!(s.check_eq(&LinExpr::sym(a), &LinExpr::sym(b)), Truth::Unknown);
        assert_eq!(s.check_ge(&LinExpr::sym(a), &LinExpr::sym(b)), Truth::Unknown);
    }

    #[test]
    fn direct_constraint_hit_multisymbol() {
        let (mut t, mut s) = setup();
        let a = t.intern("a");
        let b = t.intern("b");
        let c = t.intern("c");
        // a + b - c >= 0 (three symbols: interval arithmetic can't bound it,
        // the verbatim-store lookup must).
        let e = LinExpr::sym(a).add(&LinExpr::sym(b)).sub(&LinExpr::sym(c));
        s.assert_ge(&e, &LinExpr::constant(0));
        assert_eq!(s.check_ge(&e, &LinExpr::constant(0)), Truth::True);
    }
}
