//! Linear integer expressions over interned symbols.
//!
//! `Scalar` is the attribute type used throughout the IR and the e-graph
//! language: a normalized linear combination `k + Σ cᵢ·sᵢ`. Concrete values
//! are the common case (`terms` empty); symbolic values appear when capture
//! records data-dependent scalars. Normalization (sorted terms, no zero
//! coefficients) makes `Eq`/`Hash` structural equality decide syntactic
//! identity, and the [`solver`](super::solver) decides semantic comparisons
//! under constraints.

use std::fmt;

/// Interned symbol identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SymId(pub u32);

/// Symbol interner. One per verification session; symbol names come from the
/// capture layer (e.g. `seq_len`, `pad`).
#[derive(Debug, Default, Clone)]
pub struct SymTable {
    names: Vec<String>,
}

impl SymTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn intern(&mut self, name: &str) -> SymId {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return SymId(i as u32);
        }
        self.names.push(name.to_string());
        SymId(self.names.len() as u32 - 1)
    }

    pub fn name(&self, id: SymId) -> &str {
        &self.names[id.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// Normalized linear integer expression: `k + Σ cᵢ·sᵢ`, terms sorted by
/// symbol, all coefficients non-zero.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinExpr {
    pub k: i64,
    pub terms: Vec<(SymId, i64)>,
}

impl LinExpr {
    pub fn constant(k: i64) -> Self {
        LinExpr { k, terms: vec![] }
    }

    pub fn sym(s: SymId) -> Self {
        LinExpr { k: 0, terms: vec![(s, 1)] }
    }

    pub fn is_const(&self) -> bool {
        self.terms.is_empty()
    }

    pub fn as_const(&self) -> Option<i64> {
        if self.is_const() {
            Some(self.k)
        } else {
            None
        }
    }

    fn normalize(mut self) -> Self {
        self.terms.sort_by_key(|&(s, _)| s);
        let mut out: Vec<(SymId, i64)> = Vec::with_capacity(self.terms.len());
        for (s, c) in self.terms {
            match out.last_mut() {
                Some((ls, lc)) if *ls == s => *lc += c,
                _ => out.push((s, c)),
            }
        }
        out.retain(|&(_, c)| c != 0);
        LinExpr { k: self.k, terms: out }
    }

    pub fn add(&self, other: &LinExpr) -> LinExpr {
        let mut terms = self.terms.clone();
        terms.extend_from_slice(&other.terms);
        LinExpr { k: self.k + other.k, terms }.normalize()
    }

    pub fn sub(&self, other: &LinExpr) -> LinExpr {
        self.add(&other.scale(-1))
    }

    pub fn scale(&self, c: i64) -> LinExpr {
        LinExpr { k: self.k * c, terms: self.terms.iter().map(|&(s, co)| (s, co * c)).collect() }
            .normalize()
    }

    /// Multiply two linear expressions if at least one is constant.
    pub fn mul(&self, other: &LinExpr) -> Option<LinExpr> {
        if let Some(c) = self.as_const() {
            Some(other.scale(c))
        } else {
            other.as_const().map(|c| self.scale(c))
        }
    }

    pub fn display<'a>(&'a self, syms: &'a SymTable) -> LinExprDisplay<'a> {
        LinExprDisplay { e: self, syms }
    }
}

pub struct LinExprDisplay<'a> {
    e: &'a LinExpr,
    syms: &'a SymTable,
}

impl fmt::Display for LinExprDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.e.is_const() {
            return write!(f, "{}", self.e.k);
        }
        let mut first = true;
        if self.e.k != 0 {
            write!(f, "{}", self.e.k)?;
            first = false;
        }
        for &(s, c) in &self.e.terms {
            if !first {
                write!(f, "{}", if c >= 0 { "+" } else { "-" })?;
            } else if c < 0 {
                write!(f, "-")?;
            }
            if c.abs() != 1 {
                write!(f, "{}*", c.abs())?;
            }
            write!(f, "{}", self.syms.name(s))?;
            first = false;
        }
        Ok(())
    }
}

/// A scalar attribute: concrete or symbolic. Thin wrapper so IR code reads
/// `Scalar::from(4)` at call sites and symbolic paths stay explicit.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Scalar(pub LinExpr);

impl Scalar {
    pub fn constant(k: i64) -> Self {
        Scalar(LinExpr::constant(k))
    }
    pub fn sym(s: SymId) -> Self {
        Scalar(LinExpr::sym(s))
    }
    pub fn as_const(&self) -> Option<i64> {
        self.0.as_const()
    }
    /// Concrete value or panic — callers on graph-construction paths where
    /// attrs are always concrete.
    pub fn expect_const(&self) -> i64 {
        self.as_const().expect("symbolic scalar where a concrete value is required")
    }
    pub fn add(&self, o: &Scalar) -> Scalar {
        Scalar(self.0.add(&o.0))
    }
    pub fn sub(&self, o: &Scalar) -> Scalar {
        Scalar(self.0.sub(&o.0))
    }
    pub fn scale(&self, c: i64) -> Scalar {
        Scalar(self.0.scale(c))
    }
}

impl From<i64> for Scalar {
    fn from(k: i64) -> Self {
        Scalar::constant(k)
    }
}
impl From<i32> for Scalar {
    fn from(k: i32) -> Self {
        Scalar::constant(k as i64)
    }
}
impl From<usize> for Scalar {
    fn from(k: usize) -> Self {
        Scalar::constant(k as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_merges_and_drops_zeros() {
        let mut t = SymTable::new();
        let a = t.intern("a");
        let x = LinExpr::sym(a).add(&LinExpr::sym(a)); // 2a
        assert_eq!(x.terms, vec![(a, 2)]);
        let z = x.sub(&LinExpr::sym(a).scale(2)); // 0
        assert!(z.is_const());
        assert_eq!(z.k, 0);
    }

    #[test]
    fn interning_is_stable() {
        let mut t = SymTable::new();
        let a = t.intern("seq");
        let b = t.intern("pad");
        assert_eq!(t.intern("seq"), a);
        assert_ne!(a, b);
        assert_eq!(t.name(b), "pad");
    }

    #[test]
    fn arithmetic() {
        let mut t = SymTable::new();
        let a = t.intern("a");
        let b = t.intern("b");
        // (2a + 3) + (b - a) = a + b + 3
        let e = LinExpr::sym(a).scale(2).add(&LinExpr::constant(3));
        let f = LinExpr::sym(b).sub(&LinExpr::sym(a));
        let g = e.add(&f);
        assert_eq!(g.k, 3);
        assert_eq!(g.terms, vec![(a, 1), (b, 1)]);
        // const * symbolic
        assert_eq!(g.mul(&LinExpr::constant(2)).unwrap().terms, vec![(a, 2), (b, 2)]);
        // symbolic * symbolic unsupported
        assert!(LinExpr::sym(a).mul(&LinExpr::sym(b)).is_none());
    }

    #[test]
    fn display_formats() {
        let mut t = SymTable::new();
        let a = t.intern("a");
        let e = LinExpr::sym(a).scale(-2).add(&LinExpr::constant(5));
        assert_eq!(format!("{}", e.display(&t)), "5-2*a");
        assert_eq!(format!("{}", LinExpr::constant(7).display(&t)), "7");
    }
}
