//! Symbolic scalars (paper §5.2).
//!
//! Computation graphs carry only metadata, but operators like `select` can
//! extract scalars that later appear in shape arithmetic (slice bounds,
//! offsets, pad amounts). Lemma conditions must then compare quantities that
//! are not concrete. The paper encodes these in SMT-LIB; all conditions that
//! actually arise are shape arithmetic — linear integer expressions — so we
//! implement a normalizing linear-integer-arithmetic solver with a user
//! constraint store instead of shelling out to an SMT solver.

pub mod linexpr;
pub mod solver;

pub use linexpr::{LinExpr, Scalar, SymId, SymTable};
pub use solver::{Solver, Truth};
