//! The unified verification entry point.
//!
//! Every consumer of the inference engine — the CLI one-shot commands, the
//! long-lived [`crate::serve`] loop, the [`crate::coordinator`] batch
//! service, and the fuzz oracle — builds a [`Verifier`] and calls
//! [`Verifier::run`]. The builder replaces the four historical free
//! functions, which survive only as `#[deprecated]` shims in
//! [`crate::infer`]:
//!
//! | deprecated free function      | builder form                                       |
//! |-------------------------------|----------------------------------------------------|
//! | `check_refinement(…, cfg)`    | `Verifier::with_config(cfg).expect(gs, gd, ri)`    |
//! | `check_refinement_verdict`    | `Verifier::with_config(cfg).run(gs, gd, ri)`       |
//! | `check_refinement_isolated`   | `…with_config(cfg).isolated(true).run(…)`          |
//! | `check_refinement_escalating` | `…with_config(cfg).escalation(p).run_counted(…)`   |
//!
//! Semantics are layered, not orthogonal: an [`EscalationPolicy`] implies
//! panic isolation (every attempt runs `catch_unwind`-wrapped), and
//! `isolated(true)` without a policy is a single panic-isolated attempt at
//! the configured limits. `run` with neither knob is the bare three-valued
//! walk of Listing 1 — panics propagate, exactly as the old
//! `check_refinement_verdict` behaved.

use crate::analysis::impact::{analyze_patch, remap_relation, ImpactReport};
use crate::cache::FingerprintCache;
use crate::egraph::SaturationLimits;
use crate::infer::{
    self, EscalationPolicy, InferConfig, InferOutput, RefinementError, Verdict,
};
use crate::ir::{Graph, GraphPatch};
use crate::relation::Relation;
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Duration;

/// Builder-style verification front end. Construct, set knobs, then call
/// [`run`](Verifier::run) / [`run_counted`](Verifier::run_counted) /
/// [`expect`](Verifier::expect) any number of times — the builder borrows
/// nothing and can be reused across requests (the serve loop keeps one per
/// connection).
#[derive(Debug, Clone, Default)]
pub struct Verifier {
    cfg: InferConfig,
    isolated: bool,
    escalation: Option<EscalationPolicy>,
}

impl Verifier {
    /// Default config, no isolation, no escalation.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start from an existing [`InferConfig`] (limits, deadline, jobs,
    /// cache, quarantined channels).
    pub fn with_config(cfg: InferConfig) -> Self {
        Verifier { cfg, ..Self::default() }
    }

    /// The effective inference config.
    pub fn config(&self) -> &InferConfig {
        &self.cfg
    }

    /// Mutable access for knobs without a dedicated setter.
    pub fn config_mut(&mut self) -> &mut InferConfig {
        &mut self.cfg
    }

    /// Saturation budgets (`max_iters` / `max_nodes`).
    pub fn limits(mut self, limits: SaturationLimits) -> Self {
        self.cfg.limits = limits;
        self
    }

    /// Per-region wall-clock budget; `None` disables the deadline.
    pub fn deadline(mut self, deadline: Option<Duration>) -> Self {
        self.cfg.region_deadline = deadline;
        self
    }

    /// Worker threads for the region walk (min 1). Verdicts are identical
    /// for every value — see the determinism contract in EXPERIMENTS.md.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.cfg.jobs = jobs.max(1);
        self
    }

    /// Certificate fingerprint cache shared across regions/requests;
    /// `None` disables memoization. Never changes verdicts, only wall time.
    pub fn cache(mut self, cache: Option<Arc<FingerprintCache>>) -> Self {
        self.cfg.cache = cache;
        self
    }

    /// Pipeline channels quarantined by the schedule liveness audit.
    pub fn quarantined_channels(mut self, channels: Vec<usize>) -> Self {
        self.cfg.quarantined_channels = channels;
        self
    }

    /// Catch panics from lemma appliers and report them as
    /// `Inconclusive(Panic)` instead of unwinding into the caller.
    pub fn isolated(mut self, isolated: bool) -> Self {
        self.isolated = isolated;
        self
    }

    /// Iterative-deepening retry policy. Implies isolation: every attempt
    /// is panic-caught, and `Timeout`/`Panic` outcomes stay terminal.
    pub fn escalation(mut self, policy: EscalationPolicy) -> Self {
        self.escalation = Some(policy);
        self
    }

    /// Run inference, returning the three-valued [`Verdict`].
    pub fn run(&self, gs: &Graph, gd: &Graph, ri: &Relation) -> Verdict {
        self.run_counted(gs, gd, ri).0
    }

    /// Like [`run`](Verifier::run), also reporting the number of
    /// escalation attempts spent (always 1 without a policy).
    pub fn run_counted(&self, gs: &Graph, gd: &Graph, ri: &Relation) -> (Verdict, usize) {
        match &self.escalation {
            Some(policy) => infer::escalating_core(gs, gd, ri, &self.cfg, policy),
            None if self.isolated => (infer::isolated_core(gs, gd, ri, &self.cfg), 1),
            None => (infer::verdict_core(gs, gd, ri, &self.cfg), 1),
        }
    }

    /// Incrementally re-verify a patched implementation.
    ///
    /// Applies `patch` to `old_gd`, re-keys `ri` onto the patched graph
    /// (by tensor name — see [`remap_relation`]), runs the static impact
    /// analysis, and then verifies the patched pair with a certificate
    /// cache warmed on the *old* pair. Regions the impact pass proves
    /// [`Clean`](crate::analysis::RegionClass::Clean) hit the cache and
    /// reuse their certificates without re-saturating; dirty regions
    /// re-saturate. The verdict, relation, and locus are byte-identical
    /// under `--canonical` to a cold full verification of the patched
    /// pair — the cache never changes verdicts, and the impact analysis
    /// makes the reuse *sound* rather than fingerprint-lucky.
    ///
    /// If the builder already carries a non-empty cache (e.g. the serve
    /// loop's), it is reused as-is; otherwise a fresh cache is warmed by
    /// verifying the old pair first (the "cold" half of the bench).
    ///
    /// Errors are *structural* — invalid patch, shape re-inference
    /// failure, or a relation leaf the patch deleted. Verification
    /// outcomes, including refutations, come back inside
    /// [`Reverified::verdict`].
    pub fn reverify(
        &self,
        gs: &Graph,
        old_gd: &Graph,
        ri: &Relation,
        patch: &GraphPatch,
    ) -> Result<Reverified> {
        let patched = patch
            .apply(old_gd)
            .with_context(|| format!("applying patch '{}'", patch.name))?;
        let ri_new = remap_relation(ri, old_gd, &patched)
            .with_context(|| format!("re-keying R_i after patch '{}'", patch.name))?;
        let impact =
            analyze_patch(gs, old_gd, &patched, ri, &ri_new, &self.cfg.quarantined_channels);

        let mut warm = self.clone();
        let needs_warmup = match &self.cfg.cache {
            Some(c) => c.is_empty(),
            None => {
                warm.cfg.cache = Some(Arc::new(FingerprintCache::new()));
                true
            }
        };
        if needs_warmup {
            // Certificate source: one full pass over the old pair. Its
            // verdict is irrelevant here — refuted/inconclusive regions
            // are simply not memoized, so the patched run re-derives them.
            let _ = warm.run(gs, old_gd, ri);
        }
        let (verdict, attempts) = warm.run_counted(gs, &patched, &ri_new);
        Ok(Reverified { verdict, attempts, impact, patched, ri: ri_new })
    }

    /// Two-valued convenience for callers running at budgets where
    /// exhaustion cannot occur (most tests and benches).
    ///
    /// Panics on `Inconclusive`: silently mapping a resource verdict onto
    /// either `Ok` (false proof) or `Err` (false alarm) would be exactly
    /// the misreporting the three-valued layer exists to prevent.
    pub fn expect(
        &self,
        gs: &Graph,
        gd: &Graph,
        ri: &Relation,
    ) -> Result<InferOutput, RefinementError> {
        match self.run(gs, gd, ri) {
            Verdict::Verified(out) => Ok(*out),
            Verdict::Refuted(e) => Err(*e),
            Verdict::Inconclusive(i) => panic!(
                "Verifier::expect: {i}\n(two-valued API cannot express Inconclusive — \
                 switch this caller to Verifier::run)"
            ),
        }
    }
}

/// Result of [`Verifier::reverify`]: the verification outcome plus the
/// artifacts incremental callers need (patched graph, re-keyed relation,
/// impact classification).
#[derive(Debug)]
pub struct Reverified {
    /// Three-valued outcome for the patched pair — byte-identical under
    /// `--canonical` to a cold full verification.
    pub verdict: Verdict,
    /// Escalation attempts spent on the patched run (1 without a policy).
    pub attempts: usize,
    /// Pre-saturation impact classification of every region.
    pub impact: ImpactReport,
    /// The patched implementation graph.
    pub patched: Graph,
    /// `R_i` re-keyed onto the patched graph's tensor ids.
    pub ri: Relation,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;
    use crate::models::gpt::{self, GptConfig};

    #[test]
    fn builder_modes_agree_on_a_clean_pair() {
        let (gs, gd, ri) = gpt::tp_sp_pair(2, 1, &GptConfig::default()).unwrap();
        let plain = Verifier::new().run(&gs, &gd, &ri);
        let isolated = Verifier::new().isolated(true).run(&gs, &gd, &ri);
        let (escalated, attempts) = Verifier::new()
            .escalation(EscalationPolicy::default())
            .run_counted(&gs, &gd, &ri);
        assert!(plain.is_verified() && isolated.is_verified() && escalated.is_verified());
        assert!(attempts >= 1);
    }

    #[test]
    fn knobs_land_in_the_config() {
        let v = Verifier::new()
            .jobs(0) // clamped to 1
            .deadline(None)
            .limits(SaturationLimits::new(3, 500))
            .quarantined_channels(vec![7]);
        assert_eq!(v.config().jobs, 1);
        assert!(v.config().region_deadline.is_none());
        assert_eq!(v.config().limits.max_iters, 3);
        assert_eq!(v.config().quarantined_channels, vec![7]);
        assert!(v.config().cache.is_none());
    }

    /// fig1 running example (same workload as `infer::tests::running_example`).
    fn fig1() -> (Graph, Graph, Relation) {
        let mut gs = Graph::new("fig1_gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let e = gs.input("E", vec![4, 4]);
        let c = gs.matmul("C", a, b);
        let f = gs.sub2("F", c, e);
        gs.mark_output(f);

        let mut gd = Graph::new("fig1_gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let b2 = gd.input("B_2", vec![3, 4]);
        let e1 = gd.input("E_1", vec![2, 4]);
        let e2 = gd.input("E_2", vec![2, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        let c2 = gd.matmul("C_2", a2, b2);
        let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
        let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
        let f1 = gd.sub2("F_1", d1, e1);
        let f2 = gd.sub2("F_2", d2, e2);
        let f = gd.all_gather("F_full", vec![f1, f2], 0);
        gd.mark_output(f);

        let ri = Relation::from_json(
            &crate::util::json::Json::parse(
                r#"{
                "A": ["concat(A_1, A_2; dim=1)"],
                "B": ["concat(B_1, B_2; dim=0)"],
                "E": ["concat(E_1, E_2; dim=0)"]
            }"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        (gs, gd, ri)
    }

    #[test]
    fn reverify_noop_patch_reuses_every_certificate() {
        let (gs, gd, ri) = fig1();
        let rv = Verifier::new()
            .reverify(&gs, &gd, &ri, &GraphPatch::new("noop"))
            .unwrap();
        assert_eq!(rv.impact.clean(), gs.num_nodes(), "{:?}", rv.impact);
        let Verdict::Verified(out) = rv.verdict else { panic!("noop patch must verify") };
        assert_eq!(
            out.cache_hits as usize,
            gs.num_nodes(),
            "every region must replay its certificate"
        );
        assert_eq!(out.cache_misses, 0);
    }

    #[test]
    fn reverify_matches_full_verification_of_the_patched_pair() {
        let (gs, gd, ri) = fig1();
        // clean splice: identity inserted between F_1 and the gather
        let patch = GraphPatch::new("id_splice")
            .add("F_1_id", Op::Identity, vec!["F_1".into()])
            .rewire("F_full", 0, "F_1_id");
        let rv = Verifier::new().reverify(&gs, &gd, &ri, &patch).unwrap();
        let Verdict::Verified(warm) = rv.verdict else { panic!("clean patch must verify") };
        // cold full verification of the same patched pair
        let Verdict::Verified(cold) = Verifier::new().run(&gs, &rv.patched, &rv.ri) else {
            panic!("cold run must verify")
        };
        assert_eq!(
            warm.relation.to_json(&gs, &rv.patched).to_string(),
            cold.relation.to_json(&gs, &rv.patched).to_string(),
            "incremental and full relations must be byte-identical"
        );
        // the untouched matmul region reused its certificate
        assert!(warm.cache_hits >= 1, "clean region must hit the warm cache");
    }

    #[test]
    fn reverify_refutes_inside_the_dirty_cone() {
        let (gs, gd, ri) = fig1();
        let patch = GraphPatch::new("bug").replace("F_1", Op::Add);
        let rv = Verifier::new().reverify(&gs, &gd, &ri, &patch).unwrap();
        let Verdict::Refuted(e) = rv.verdict else { panic!("bug patch must refute") };
        let class = rv.impact.class_of(e.node).unwrap();
        assert_eq!(
            class,
            crate::analysis::RegionClass::Dirty,
            "locus '{}' must lie inside the dirty cone",
            e.node_name
        );
    }

    #[test]
    fn reverify_rejects_invalid_patches_structurally() {
        let (gs, gd, ri) = fig1();
        let patch = GraphPatch::new("bad").rewire("F_full", 0, "no_such_tensor");
        let err = Verifier::new().reverify(&gs, &gd, &ri, &patch).unwrap_err();
        assert!(format!("{err:#}").contains("no_such_tensor"), "{err:#}");
    }

    #[test]
    fn cache_knob_threads_through_to_counters() {
        let cache = Arc::new(FingerprintCache::new());
        let (gs, gd, ri) = gpt::tp_sp_pair(2, 2, &GptConfig::default()).unwrap();
        let v = Verifier::new().cache(Some(Arc::clone(&cache)));
        let Verdict::Verified(out) = v.run(&gs, &gd, &ri) else {
            panic!("clean pair must verify")
        };
        assert!(out.cache_hits + out.cache_misses > 0, "cache was consulted");
        assert!(!cache.is_empty(), "regions were memoized");
    }
}
