//! The unified verification entry point.
//!
//! Every consumer of the inference engine — the CLI one-shot commands, the
//! long-lived [`crate::serve`] loop, the [`crate::coordinator`] batch
//! service, and the fuzz oracle — builds a [`Verifier`] and calls
//! [`Verifier::run`]. The builder replaces the four historical free
//! functions, which survive only as `#[deprecated]` shims in
//! [`crate::infer`]:
//!
//! | deprecated free function      | builder form                                       |
//! |-------------------------------|----------------------------------------------------|
//! | `check_refinement(…, cfg)`    | `Verifier::with_config(cfg).expect(gs, gd, ri)`    |
//! | `check_refinement_verdict`    | `Verifier::with_config(cfg).run(gs, gd, ri)`       |
//! | `check_refinement_isolated`   | `…with_config(cfg).isolated(true).run(…)`          |
//! | `check_refinement_escalating` | `…with_config(cfg).escalation(p).run_counted(…)`   |
//!
//! Semantics are layered, not orthogonal: an [`EscalationPolicy`] implies
//! panic isolation (every attempt runs `catch_unwind`-wrapped), and
//! `isolated(true)` without a policy is a single panic-isolated attempt at
//! the configured limits. `run` with neither knob is the bare three-valued
//! walk of Listing 1 — panics propagate, exactly as the old
//! `check_refinement_verdict` behaved.

use crate::cache::FingerprintCache;
use crate::egraph::SaturationLimits;
use crate::infer::{
    self, EscalationPolicy, InferConfig, InferOutput, RefinementError, Verdict,
};
use crate::ir::Graph;
use crate::relation::Relation;
use std::sync::Arc;
use std::time::Duration;

/// Builder-style verification front end. Construct, set knobs, then call
/// [`run`](Verifier::run) / [`run_counted`](Verifier::run_counted) /
/// [`expect`](Verifier::expect) any number of times — the builder borrows
/// nothing and can be reused across requests (the serve loop keeps one per
/// connection).
#[derive(Debug, Clone, Default)]
pub struct Verifier {
    cfg: InferConfig,
    isolated: bool,
    escalation: Option<EscalationPolicy>,
}

impl Verifier {
    /// Default config, no isolation, no escalation.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start from an existing [`InferConfig`] (limits, deadline, jobs,
    /// cache, quarantined channels).
    pub fn with_config(cfg: InferConfig) -> Self {
        Verifier { cfg, ..Self::default() }
    }

    /// The effective inference config.
    pub fn config(&self) -> &InferConfig {
        &self.cfg
    }

    /// Mutable access for knobs without a dedicated setter.
    pub fn config_mut(&mut self) -> &mut InferConfig {
        &mut self.cfg
    }

    /// Saturation budgets (`max_iters` / `max_nodes`).
    pub fn limits(mut self, limits: SaturationLimits) -> Self {
        self.cfg.limits = limits;
        self
    }

    /// Per-region wall-clock budget; `None` disables the deadline.
    pub fn deadline(mut self, deadline: Option<Duration>) -> Self {
        self.cfg.region_deadline = deadline;
        self
    }

    /// Worker threads for the region walk (min 1). Verdicts are identical
    /// for every value — see the determinism contract in EXPERIMENTS.md.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.cfg.jobs = jobs.max(1);
        self
    }

    /// Certificate fingerprint cache shared across regions/requests;
    /// `None` disables memoization. Never changes verdicts, only wall time.
    pub fn cache(mut self, cache: Option<Arc<FingerprintCache>>) -> Self {
        self.cfg.cache = cache;
        self
    }

    /// Pipeline channels quarantined by the schedule liveness audit.
    pub fn quarantined_channels(mut self, channels: Vec<usize>) -> Self {
        self.cfg.quarantined_channels = channels;
        self
    }

    /// Catch panics from lemma appliers and report them as
    /// `Inconclusive(Panic)` instead of unwinding into the caller.
    pub fn isolated(mut self, isolated: bool) -> Self {
        self.isolated = isolated;
        self
    }

    /// Iterative-deepening retry policy. Implies isolation: every attempt
    /// is panic-caught, and `Timeout`/`Panic` outcomes stay terminal.
    pub fn escalation(mut self, policy: EscalationPolicy) -> Self {
        self.escalation = Some(policy);
        self
    }

    /// Run inference, returning the three-valued [`Verdict`].
    pub fn run(&self, gs: &Graph, gd: &Graph, ri: &Relation) -> Verdict {
        self.run_counted(gs, gd, ri).0
    }

    /// Like [`run`](Verifier::run), also reporting the number of
    /// escalation attempts spent (always 1 without a policy).
    pub fn run_counted(&self, gs: &Graph, gd: &Graph, ri: &Relation) -> (Verdict, usize) {
        match &self.escalation {
            Some(policy) => infer::escalating_core(gs, gd, ri, &self.cfg, policy),
            None if self.isolated => (infer::isolated_core(gs, gd, ri, &self.cfg), 1),
            None => (infer::verdict_core(gs, gd, ri, &self.cfg), 1),
        }
    }

    /// Two-valued convenience for callers running at budgets where
    /// exhaustion cannot occur (most tests and benches).
    ///
    /// Panics on `Inconclusive`: silently mapping a resource verdict onto
    /// either `Ok` (false proof) or `Err` (false alarm) would be exactly
    /// the misreporting the three-valued layer exists to prevent.
    pub fn expect(
        &self,
        gs: &Graph,
        gd: &Graph,
        ri: &Relation,
    ) -> Result<InferOutput, RefinementError> {
        match self.run(gs, gd, ri) {
            Verdict::Verified(out) => Ok(*out),
            Verdict::Refuted(e) => Err(*e),
            Verdict::Inconclusive(i) => panic!(
                "Verifier::expect: {i}\n(two-valued API cannot express Inconclusive — \
                 switch this caller to Verifier::run)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::gpt::{self, GptConfig};

    #[test]
    fn builder_modes_agree_on_a_clean_pair() {
        let (gs, gd, ri) = gpt::tp_sp_pair(2, 1, &GptConfig::default()).unwrap();
        let plain = Verifier::new().run(&gs, &gd, &ri);
        let isolated = Verifier::new().isolated(true).run(&gs, &gd, &ri);
        let (escalated, attempts) = Verifier::new()
            .escalation(EscalationPolicy::default())
            .run_counted(&gs, &gd, &ri);
        assert!(plain.is_verified() && isolated.is_verified() && escalated.is_verified());
        assert!(attempts >= 1);
    }

    #[test]
    fn knobs_land_in_the_config() {
        let v = Verifier::new()
            .jobs(0) // clamped to 1
            .deadline(None)
            .limits(SaturationLimits::new(3, 500))
            .quarantined_channels(vec![7]);
        assert_eq!(v.config().jobs, 1);
        assert!(v.config().region_deadline.is_none());
        assert_eq!(v.config().limits.max_iters, 3);
        assert_eq!(v.config().quarantined_channels, vec![7]);
        assert!(v.config().cache.is_none());
    }

    #[test]
    fn cache_knob_threads_through_to_counters() {
        let cache = Arc::new(FingerprintCache::new());
        let (gs, gd, ri) = gpt::tp_sp_pair(2, 2, &GptConfig::default()).unwrap();
        let v = Verifier::new().cache(Some(Arc::clone(&cache)));
        let Verdict::Verified(out) = v.run(&gs, &gd, &ri) else {
            panic!("clean pair must verify")
        };
        assert!(out.cache_hits + out.cache_misses > 0, "cache was consulted");
        assert!(!cache.is_empty(), "regions were memoized");
    }
}
