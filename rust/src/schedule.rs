//! Schedule-aware pipeline verification: the buffer-assignment layer.
//!
//! `strategies::pipeline_stage_split` models pipeline parallelism in its
//! schedule-agnostic single-program dataflow form: one *logical* channel per
//! (stage boundary × micro-batch), so `recv_of_send_identity` verifies the
//! wiring but says nothing about *when* each transfer lands. Real runtimes
//! execute a schedule (GPipe, 1F1B, interleaved virtual stages) and back
//! every boundary with a finite pool of physical activation buffers; the
//! numerics-silent bug class that matters in practice is a buffer being
//! overwritten before its last reader has consumed it (stale buffer reuse —
//! the real-world shape behind the `dropped_boundary` mutation operator).
//!
//! This module lowers logical channels onto explicit buffers:
//!
//! 1. [`Schedule`] describes the execution order (kind × stages ×
//!    micro-batches × virtual chunks) and derives a deterministic
//!    [`Timetable`] by discrete-event simulation: unit-time ops, one op per
//!    physical stage per tick, forwards gated on the upstream chunk's
//!    forward, backwards gated on the downstream chunk's backward (backwards
//!    carry no activation transfers here — they exist to throttle forwards
//!    exactly the way 1F1B/interleaved schedules do).
//! 2. A buffer pool of `depth` slots per boundary assigns logical channel
//!    `(b, m)` the slot `m % depth` with write epoch `m / depth` (the
//!    standard round-robin double-buffering discipline).
//! 3. [`Schedule::hazards`] audits slot liveness against the timetable: the
//!    write of micro-batch `m` lands at the end of its producer tick; if it
//!    lands at-or-before the tick in which slot-predecessor `m - depth` is
//!    still being read, the buffer was reused too early.
//! 4. [`lower_buffers`] re-tags every Send/Recv with its *buffer* tag
//!    `(boundary, slot, epoch)` — rejecting hazardous (schedule, depth)
//!    combinations at construction. A correct assignment keeps tags equal
//!    pairwise, so the existing `recv_of_send_identity` machinery verifies
//!    the lowered graph unchanged. [`lower_buffers_unchecked`] instead
//!    materializes what a buggy runtime delivers: a hazard victim's recv
//!    keeps its *intended* epoch tag while its send carries the epoch the
//!    schedule actually wrote — the crossed tag never collapses, so
//!    refinement fails at the first in-stage consumer.
//!
//! Tags are also the hook for the slot-liveness lemma side condition:
//! [`quarantined_channels`] lists the victim tags of a hazardous lowering,
//! and `recv_of_send_identity` refuses to collapse a quarantined channel
//! even when its tags match (`RewriteCtx::channel_quarantined`) — defense in
//! depth against a lowering that tags both sides with the occupant epoch.

use crate::ir::{Graph, NodeId, Op};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// Pipeline execution schedule family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedKind {
    /// All forwards per stage, then all backwards (synchronous GPipe).
    GPipe,
    /// One-forward-one-backward with the standard `S - 1 - s` warmup.
    OneFOneB,
    /// Megatron-style interleaved 1F1B over virtual stage chunks: physical
    /// stage `s` hosts chunks `s, s + S, ..`; forwards run in micro-batch
    /// groups of `S`, chunk-major inside a group (backwards chunk-reversed).
    Interleaved,
}

impl SchedKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedKind::GPipe => "gpipe",
            SchedKind::OneFOneB => "1f1b",
            SchedKind::Interleaved => "interleaved",
        }
    }

    pub fn parse(s: &str) -> Option<SchedKind> {
        match s {
            "gpipe" => Some(SchedKind::GPipe),
            "1f1b" => Some(SchedKind::OneFOneB),
            "interleaved" => Some(SchedKind::Interleaved),
            _ => None,
        }
    }
}

/// A concrete pipeline schedule: kind × physical stages × micro-batches ×
/// virtual chunks per stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schedule {
    pub kind: SchedKind,
    /// Physical pipeline stages (devices).
    pub stages: usize,
    /// Micro-batches per step.
    pub micro: usize,
    /// Virtual model chunks per stage (1 unless interleaved).
    pub virt: usize,
}

impl Schedule {
    pub fn gpipe(stages: usize, micro: usize) -> Schedule {
        Schedule { kind: SchedKind::GPipe, stages, micro, virt: 1 }
    }

    pub fn one_f_one_b(stages: usize, micro: usize) -> Schedule {
        Schedule { kind: SchedKind::OneFOneB, stages, micro, virt: 1 }
    }

    pub fn interleaved(stages: usize, micro: usize, virt: usize) -> Schedule {
        Schedule { kind: SchedKind::Interleaved, stages, micro, virt }
    }

    /// Model chunks in pipeline order (= stage count unless interleaved).
    pub fn chunks(&self) -> usize {
        self.stages * self.virt
    }

    /// Stage boundaries (one between each adjacent chunk pair).
    pub fn boundaries(&self) -> usize {
        self.chunks().saturating_sub(1)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.stages >= 2, "a pipeline schedule needs >= 2 stages");
        ensure!(self.micro >= 1, "a pipeline schedule needs >= 1 micro-batch");
        ensure!(self.micro <= 1000, "micro-batch count {} exceeds the tag budget", self.micro);
        ensure!(self.boundaries() < 1000, "chunk count {} exceeds the tag budget", self.chunks());
        match self.kind {
            SchedKind::GPipe | SchedKind::OneFOneB => {
                ensure!(self.virt == 1, "{} has no virtual chunks", self.kind.name())
            }
            SchedKind::Interleaved => {
                ensure!(self.virt >= 2, "interleaving needs >= 2 virtual chunks per stage");
                ensure!(
                    self.micro % self.stages == 0,
                    "interleaved schedule needs micro-batches ({}) divisible by stages ({})",
                    self.micro,
                    self.stages
                );
            }
        }
        Ok(())
    }

    /// Per-stage op sequence (program order on that device).
    fn stage_ops(&self, s: usize) -> Vec<PipeOp> {
        let m = self.micro;
        match self.kind {
            SchedKind::GPipe => {
                let mut ops: Vec<PipeOp> =
                    (0..m).map(|mb| PipeOp { chunk: s, micro: mb, fwd: true }).collect();
                ops.extend((0..m).map(|mb| PipeOp { chunk: s, micro: mb, fwd: false }));
                ops
            }
            SchedKind::OneFOneB => {
                let w = (self.stages - 1 - s).min(m);
                let mut ops: Vec<PipeOp> =
                    (0..w).map(|mb| PipeOp { chunk: s, micro: mb, fwd: true }).collect();
                for k in 0..m - w {
                    ops.push(PipeOp { chunk: s, micro: w + k, fwd: true });
                    ops.push(PipeOp { chunk: s, micro: k, fwd: false });
                }
                ops.extend((m - w..m).map(|mb| PipeOp { chunk: s, micro: mb, fwd: false }));
                ops
            }
            SchedKind::Interleaved => {
                let (groups, v) = (m / self.stages, self.virt);
                let mut fwd = Vec::with_capacity(m * v);
                let mut bwd = Vec::with_capacity(m * v);
                for g in 0..groups {
                    for ci in 0..v {
                        for j in 0..self.stages {
                            let micro = g * self.stages + j;
                            fwd.push(PipeOp { chunk: ci * self.stages + s, micro, fwd: true });
                            bwd.push(PipeOp {
                                chunk: (v - 1 - ci) * self.stages + s,
                                micro,
                                fwd: false,
                            });
                        }
                    }
                }
                let total = m * v;
                let w = ((self.stages - 1 - s) * 2 + (v - 1) * self.stages).min(total);
                let mut ops: Vec<PipeOp> = fwd[..w].to_vec();
                let (mut fi, mut bi) = (w, 0);
                while fi < total || bi < total {
                    if fi < total {
                        ops.push(fwd[fi]);
                        fi += 1;
                    }
                    if bi < total {
                        ops.push(bwd[bi]);
                        bi += 1;
                    }
                }
                ops
            }
        }
    }

    /// Simulate the schedule into per-(chunk, micro-batch) forward ticks.
    pub fn timetable(&self) -> Result<Timetable> {
        self.validate()?;
        let chunks = self.chunks();
        let seqs: Vec<Vec<PipeOp>> = (0..self.stages).map(|s| self.stage_ops(s)).collect();
        let total: usize = seqs.iter().map(Vec::len).sum();
        let mut ptr = vec![0usize; self.stages];
        let mut fwd = vec![vec![u64::MAX; self.micro]; chunks];
        let mut bwd = vec![vec![u64::MAX; self.micro]; chunks];
        let mut done = 0usize;
        let mut tick: u64 = 0;
        while done < total {
            ensure!(
                tick <= total as u64 * 4 + 16,
                "schedule deadlock: {} S={} M={} v={} stalled at tick {tick} ({done}/{total} ops)",
                self.kind.name(),
                self.stages,
                self.micro,
                self.virt
            );
            for s in 0..self.stages {
                let Some(op) = seqs[s].get(ptr[s]).copied() else { continue };
                let ready = if op.fwd {
                    op.chunk == 0 || fwd[op.chunk - 1][op.micro] < tick
                } else {
                    fwd[op.chunk][op.micro] < tick
                        && (op.chunk == chunks - 1 || bwd[op.chunk + 1][op.micro] < tick)
                };
                if ready {
                    if op.fwd {
                        fwd[op.chunk][op.micro] = tick;
                    } else {
                        bwd[op.chunk][op.micro] = tick;
                    }
                    ptr[s] += 1;
                    done += 1;
                }
            }
            tick += 1;
        }
        Ok(Timetable { fwd })
    }

    /// Slot-liveness audit of the round-robin buffer assignment at `depth`
    /// buffers per boundary: micro-batch `m`'s write lands at the end of
    /// its producer tick and must come strictly after its slot-predecessor
    /// `m - depth` finished reading (same-tick overlap is a race — the
    /// transfer and the consumer run concurrently with no sync).
    pub fn hazards(&self, tt: &Timetable, depth: usize) -> Vec<Hazard> {
        let mut out = Vec::new();
        if depth == 0 {
            return out;
        }
        for b in 0..self.boundaries() {
            for m in depth..self.micro {
                let victim = m - depth;
                if tt.fwd_tick(b, m) <= tt.fwd_tick(b + 1, victim) {
                    out.push(Hazard { boundary: b, slot: m % depth, writer: m, victim });
                }
            }
        }
        out
    }

    /// Smallest per-boundary pool depth with no liveness hazard (`micro`
    /// buffers — one slot per micro-batch — is always safe).
    pub fn min_safe_depth(&self) -> Result<usize> {
        let tt = self.timetable()?;
        for depth in 1..=self.micro {
            if self.hazards(&tt, depth).is_empty() {
                return Ok(depth);
            }
        }
        Ok(self.micro)
    }
}

/// One scheduled operation: forward or backward of (chunk, micro-batch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PipeOp {
    chunk: usize,
    micro: usize,
    fwd: bool,
}

/// Forward execution ticks per (chunk, micro-batch).
#[derive(Debug, Clone)]
pub struct Timetable {
    fwd: Vec<Vec<u64>>,
}

impl Timetable {
    pub fn fwd_tick(&self, chunk: usize, micro: usize) -> u64 {
        self.fwd[chunk][micro]
    }
}

/// A slot-liveness violation: `writer`'s transfer into `(boundary, slot)`
/// lands before (or during) `victim`'s read of the same buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hazard {
    pub boundary: usize,
    pub slot: usize,
    /// Micro-batch whose write reuses the buffer too early.
    pub writer: usize,
    /// Micro-batch whose pending read gets overwritten.
    pub victim: usize,
}

/// Buffer-tag channel space — disjoint from the small logical channel ids
/// `boundary * micro + m` that `pipeline_stage_split` emits, so mutation
/// operators and audits can tell a lowered graph from a logical one.
pub const SCHED_TAG_BASE: usize = 1_000_000_000;
const BOUNDARY_STRIDE: usize = 1_000_000;
const SLOT_STRIDE: usize = 1_000;

/// Channel tag of write `epoch` into physical buffer `(boundary, slot)`.
pub fn buffer_tag(boundary: usize, slot: usize, epoch: usize) -> usize {
    debug_assert!(boundary < 1000 && slot < 1000 && epoch < 1000);
    SCHED_TAG_BASE + boundary * BOUNDARY_STRIDE + slot * SLOT_STRIDE + epoch
}

/// Inverse of [`buffer_tag`]; `None` for logical (un-lowered) channels.
pub fn decode_buffer_tag(chan: usize) -> Option<(usize, usize, usize)> {
    let v = chan.checked_sub(SCHED_TAG_BASE)?;
    let boundary = v / BOUNDARY_STRIDE;
    if boundary >= 1000 {
        return None;
    }
    let rest = v % BOUNDARY_STRIDE;
    Some((boundary, rest / SLOT_STRIDE, rest % SLOT_STRIDE))
}

/// The complete logical channel grid of a `pipeline_stage_split` graph:
/// `(boundary, micro) -> (send node, recv node)`, validated against the
/// schedule's dimensions (every channel present exactly once, every recv
/// wired to its own send, nothing already buffer-tagged).
fn logical_channels(
    gd: &Graph,
    sched: &Schedule,
) -> Result<BTreeMap<(usize, usize), (NodeId, NodeId)>> {
    let micro = sched.micro;
    let nb = sched.boundaries();
    let mut sends: BTreeMap<usize, NodeId> = BTreeMap::new();
    let mut recvs: BTreeMap<usize, NodeId> = BTreeMap::new();
    for nid in gd.topo_order() {
        let node = gd.node(nid);
        let (chan, map) = match node.op {
            Op::Send { chan } => (chan, &mut sends),
            Op::Recv { chan } => (chan, &mut recvs),
            _ => continue,
        };
        ensure!(
            chan < SCHED_TAG_BASE,
            "'{}' is already buffer-tagged (chan {chan}) — lower a logical graph",
            node.name
        );
        ensure!(
            chan < nb * micro,
            "'{}' uses channel {chan}, outside the {} boundaries x {} micro-batches grid",
            node.name,
            nb,
            micro
        );
        ensure!(
            map.insert(chan, nid).is_none(),
            "duplicate {} on channel {chan}",
            node.op.name()
        );
    }
    let mut out = BTreeMap::new();
    for b in 0..nb {
        for m in 0..micro {
            let chan = b * micro + m;
            let (Some(&snd), Some(&rcv)) = (sends.get(&chan), recvs.get(&chan)) else {
                bail!(
                    "incomplete channel grid: boundary {b} micro-batch {m} (chan {chan}) \
                     is missing its send/recv pair"
                );
            };
            ensure!(
                gd.node(rcv).inputs[0] == gd.node(snd).output,
                "recv '{}' is not wired to send '{}' on channel {chan}",
                gd.node(rcv).name,
                gd.node(snd).name
            );
            out.insert((b, m), (snd, rcv));
        }
    }
    Ok(out)
}

/// Lower the logical channels of a `pipeline_stage_split` graph onto a
/// per-boundary pool of `depth` physical buffers, re-tagging every
/// Send/Recv with its `(boundary, slot, epoch)` buffer tag. A hazardous
/// (schedule, depth) combination — any buffer overwritten before its last
/// reader — is rejected here, at construction, rather than silently
/// mis-verified downstream.
pub fn lower_buffers(gd: &Graph, sched: &Schedule, depth: usize) -> Result<Graph> {
    ensure!(depth >= 1, "buffer pool depth must be >= 1");
    ensure!(depth <= 1000, "buffer pool depth {depth} exceeds the tag budget");
    let chans = logical_channels(gd, sched)?;
    let tt = sched.timetable()?;
    let hz = sched.hazards(&tt, depth);
    if let Some(h) = hz.first() {
        bail!(
            "buffer pool of depth {depth} is unsafe under {} (S={}, M={}, v={}): boundary {} \
             slot {}: micro-batch {}'s send overwrites the buffer micro-batch {} is still \
             reading ({} hazard(s) total; smallest safe depth is {})",
            sched.kind.name(),
            sched.stages,
            sched.micro,
            sched.virt,
            h.boundary,
            h.slot,
            h.writer,
            h.victim,
            hz.len(),
            sched.min_safe_depth()?
        );
    }
    retag(gd, sched, depth, &chans, &tt, &[])
}

/// Lower WITHOUT the liveness gate, materializing what a buggy runtime
/// actually delivers: every send is tagged with the epoch its transfer
/// really writes, while a hazard victim's recv keeps the epoch the schedule
/// *intended* it to read. The crossed tags never satisfy
/// `recv_of_send_identity`, so the recv stays opaque and refinement fails
/// at the first consumer inside the receiving stage. Returns the hazard
/// list alongside the lowered graph (empty = identical to [`lower_buffers`]).
pub fn lower_buffers_unchecked(
    gd: &Graph,
    sched: &Schedule,
    depth: usize,
) -> Result<(Graph, Vec<Hazard>)> {
    ensure!(depth >= 1, "buffer pool depth must be >= 1");
    ensure!(depth <= 1000, "buffer pool depth {depth} exceeds the tag budget");
    let chans = logical_channels(gd, sched)?;
    let tt = sched.timetable()?;
    let hz = sched.hazards(&tt, depth);
    let g = retag(gd, sched, depth, &chans, &tt, &hz)?;
    Ok((g, hz))
}

/// Intended-tag victims of a hazardous lowering — the channel tags the
/// slot-liveness side condition quarantines (`InferConfig`), so even a
/// lowering that stamps *both* sides with the occupant epoch cannot collapse
/// a hazardous boundary.
pub fn quarantined_channels(sched: &Schedule, depth: usize) -> Result<Vec<usize>> {
    ensure!(depth >= 1, "buffer pool depth must be >= 1");
    let tt = sched.timetable()?;
    let mut tags: Vec<usize> = sched
        .hazards(&tt, depth)
        .iter()
        .map(|h| buffer_tag(h.boundary, h.victim % depth, h.victim / depth))
        .collect();
    tags.sort_unstable();
    tags.dedup();
    Ok(tags)
}

/// Rebuild with buffer tags. For each hazard, the victim recv keeps its
/// intended `(slot, epoch)` tag while its matching send is stamped with the
/// same slot's *next* epoch — exactly the byte pattern the overwrite leaves
/// in the buffer at read time.
fn retag(
    gd: &Graph,
    sched: &Schedule,
    depth: usize,
    chans: &BTreeMap<(usize, usize), (NodeId, NodeId)>,
    tt: &Timetable,
    hz: &[Hazard],
) -> Result<Graph> {
    // node -> buffer tag, defaulting to the micro-batch's own assignment
    let mut send_tag: BTreeMap<NodeId, usize> = BTreeMap::new();
    let mut recv_tag: BTreeMap<NodeId, usize> = BTreeMap::new();
    for (&(b, m), &(snd, rcv)) in chans {
        let tag = buffer_tag(b, m % depth, m / depth);
        send_tag.insert(snd, tag);
        recv_tag.insert(rcv, tag);
    }
    // A victim's buffer actually holds the overwriting epoch when read; the
    // last writer at-or-before the read wins (writes on one slot are
    // time-ordered, so scanning upward and keeping the latest is exact).
    for h in hz {
        let (snd, _) = chans[&(h.boundary, h.victim)];
        let read = tt.fwd_tick(h.boundary + 1, h.victim);
        let mut occupant = h.victim;
        let mut m2 = h.victim + depth;
        while m2 < sched.micro && tt.fwd_tick(h.boundary, m2) <= read {
            occupant = m2;
            m2 += depth;
        }
        send_tag.insert(snd, buffer_tag(h.boundary, h.victim % depth, occupant / depth));
    }
    gd.rebuild_with(|nid, node, ins| match node.op {
        Op::Send { .. } => (Op::Send { chan: send_tag[&nid] }, ins.to_vec()),
        Op::Recv { .. } => (Op::Recv { chan: recv_tag[&nid] }, ins.to_vec()),
        _ => (node.op.clone(), ins.to_vec()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::pipeline_stage_split;

    fn chain(blocks: usize) -> Graph {
        let mut gs = Graph::new("chain");
        let mut x = gs.input("x", vec![8, 4]);
        for i in 0..blocks {
            let w = gs.input(&format!("w{i}"), vec![4, 4]);
            x = gs.matmul(&format!("b{i}_mm"), x, w);
        }
        gs.mark_output(x);
        gs
    }

    /// gpipe wavefront: stage s runs micro-batch m at tick s + m.
    #[test]
    fn gpipe_timetable_is_a_wavefront() {
        let sched = Schedule::gpipe(2, 4);
        let tt = sched.timetable().unwrap();
        for s in 0..2 {
            for m in 0..4 {
                assert_eq!(tt.fwd_tick(s, m), (s + m) as u64, "stage {s} micro {m}");
            }
        }
    }

    /// 1f1b: warmup wavefront, then backwards stretch the forward cadence
    /// to every other tick (hand-derived for S=2, M=4).
    #[test]
    fn one_f_one_b_timetable_matches_hand_simulation() {
        let sched = Schedule::one_f_one_b(2, 4);
        let tt = sched.timetable().unwrap();
        assert_eq!((0..4).map(|m| tt.fwd_tick(0, m)).collect::<Vec<_>>(), vec![0, 1, 4, 6]);
        assert_eq!((0..4).map(|m| tt.fwd_tick(1, m)).collect::<Vec<_>>(), vec![1, 3, 5, 7]);
    }

    #[test]
    fn interleaved_timetable_completes_and_respects_dependencies() {
        for (stages, micro) in [(2, 4), (2, 8), (4, 8)] {
            let sched = Schedule::interleaved(stages, micro, 2);
            let tt = sched.timetable().unwrap_or_else(|e| panic!("S={stages} M={micro}: {e}"));
            for c in 1..sched.chunks() {
                for m in 0..micro {
                    assert!(
                        tt.fwd_tick(c, m) > tt.fwd_tick(c - 1, m),
                        "chunk {c} micro {m} ran before its input arrived"
                    );
                }
            }
        }
    }

    #[test]
    fn single_buffer_pools_are_hazardous_and_double_buffers_safe() {
        for sched in [
            Schedule::gpipe(2, 4),
            Schedule::one_f_one_b(2, 4),
            Schedule::one_f_one_b(4, 8),
            Schedule::interleaved(2, 4, 2),
            Schedule::interleaved(2, 8, 2),
        ] {
            let tt = sched.timetable().unwrap();
            assert!(
                !sched.hazards(&tt, 1).is_empty(),
                "{:?}: depth 1 must race the wavefront",
                sched
            );
            assert!(sched.hazards(&tt, 2).is_empty(), "{:?}: double buffering suffices", sched);
            assert_eq!(sched.min_safe_depth().unwrap(), 2, "{:?}", sched);
        }
    }

    #[test]
    fn hazard_names_the_slot_and_both_micro_batches() {
        let sched = Schedule::gpipe(2, 4);
        let tt = sched.timetable().unwrap();
        let hz = sched.hazards(&tt, 1);
        assert!(hz.contains(&Hazard { boundary: 0, slot: 0, writer: 1, victim: 0 }), "{hz:?}");
    }

    #[test]
    fn schedule_validation_rejects_malformed_configs() {
        assert!(Schedule::gpipe(1, 4).validate().is_err(), "one stage has no boundary");
        assert!(Schedule::interleaved(2, 3, 2).validate().is_err(), "micro % stages != 0");
        assert!(Schedule::interleaved(2, 4, 1).validate().is_err(), "interleaving needs virt >= 2");
        assert!(
            Schedule { kind: SchedKind::GPipe, stages: 2, micro: 4, virt: 2 }.validate().is_err(),
            "gpipe has no virtual chunks"
        );
    }

    #[test]
    fn buffer_tag_roundtrip_and_logical_tags_decode_to_none() {
        for (b, s, e) in [(0, 0, 0), (2, 1, 3), (999, 999, 999)] {
            assert_eq!(decode_buffer_tag(buffer_tag(b, s, e)), Some((b, s, e)));
        }
        for chan in [0usize, 1, 7, 4095] {
            assert_eq!(decode_buffer_tag(chan), None, "logical chan {chan}");
        }
    }

    #[test]
    fn lowering_retags_every_boundary_pair_consistently() {
        let gs = chain(2);
        let (gd, _ri) = pipeline_stage_split(&gs, &[0], 4, "b2_out").unwrap();
        let sched = Schedule::one_f_one_b(2, 4);
        let low = lower_buffers(&gd, &sched, 2).unwrap();
        low.validate().unwrap();
        let mut seen = Vec::new();
        for nid in low.topo_order() {
            if let Op::Send { chan } = low.node(nid).op {
                let (b, slot, epoch) =
                    decode_buffer_tag(chan).expect("send must be buffer-tagged");
                assert_eq!(b, 0);
                seen.push((slot, epoch));
                // paired recv carries the identical tag
                let rcv = low.consumers(low.node(nid).output)[0];
                match low.node(rcv).op {
                    Op::Recv { chan: rc } => assert_eq!(rc, chan),
                    ref other => panic!("send feeds {other:?}"),
                }
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 0), (0, 1), (1, 0), (1, 1)], "round-robin slots x epochs");
    }

    #[test]
    fn undersized_pool_is_rejected_at_construction() {
        let gs = chain(2);
        let (gd, _ri) = pipeline_stage_split(&gs, &[0], 4, "b2_out").unwrap();
        let err = lower_buffers(&gd, &Schedule::gpipe(2, 4), 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unsafe"), "{msg}");
        assert!(msg.contains("smallest safe depth is 2"), "{msg}");
    }

    #[test]
    fn unchecked_lowering_crosses_victim_tags() {
        let gs = chain(2);
        let (gd, _ri) = pipeline_stage_split(&gs, &[0], 4, "b2_out").unwrap();
        let sched = Schedule::gpipe(2, 4);
        let (low, hz) = lower_buffers_unchecked(&gd, &sched, 1).unwrap();
        low.validate().unwrap();
        assert!(!hz.is_empty());
        let mut crossed = 0;
        for nid in low.topo_order() {
            if let Op::Recv { chan } = low.node(nid).op {
                let producer = low.producer(low.node(nid).inputs[0]).unwrap();
                let sc = match producer.op {
                    Op::Send { chan } => chan,
                    ref other => panic!("recv input feeds {other:?}"),
                };
                if sc != chan {
                    crossed += 1;
                    let (_, slot, re) = decode_buffer_tag(chan).unwrap();
                    let (_, sslot, se) = decode_buffer_tag(sc).unwrap();
                    assert_eq!(slot, sslot, "hazard stays within one physical buffer");
                    assert!(se > re, "the occupant epoch is newer than the intended one");
                }
            }
        }
        assert_eq!(crossed, hz.len(), "one crossed pair per hazard");
    }

    #[test]
    fn quarantine_lists_exactly_the_victim_tags() {
        let sched = Schedule::gpipe(2, 4);
        assert!(quarantined_channels(&sched, 2).unwrap().is_empty(), "safe pool: nothing");
        let q = quarantined_channels(&sched, 1).unwrap();
        // depth 1: victims are micro-batches 0..3 less the last writer
        assert_eq!(q, vec![buffer_tag(0, 0, 0), buffer_tag(0, 0, 1), buffer_tag(0, 0, 2)]);
    }

    #[test]
    fn channel_grid_validation_catches_wrong_dimensions() {
        let gs = chain(2);
        let (gd, _ri) = pipeline_stage_split(&gs, &[0], 4, "b2_out").unwrap();
        // schedule claims 2 micro-batches but the graph carries 4
        let err = lower_buffers(&gd, &Schedule::gpipe(2, 2), 2).unwrap_err();
        assert!(format!("{err:#}").contains("outside"), "{err:#}");
        // double lowering is rejected
        let low = lower_buffers(&gd, &Schedule::gpipe(2, 4), 2).unwrap();
        let err = lower_buffers(&low, &Schedule::gpipe(2, 4), 2).unwrap_err();
        assert!(format!("{err:#}").contains("already buffer-tagged"), "{err:#}");
    }
}
