//! Differential soundness oracle.
//!
//! For every fuzz case the oracle builds a clean `(G_s, G_d, R_i)` pair and
//! checks, against both the static checker and concrete execution:
//!
//! 1. **No false alarms.** The clean pair must pass verification,
//!    and the inferred `R_o` must replay numerically (`verify_numeric`).
//! 2. **No false proofs.** Any accepted graph's inferred relation must
//!    replay numerically on several random input draws — a proof whose own
//!    certificate fails is unsound.
//! 3. **Kills are localized.** A mutant whose concrete outputs differ from
//!    the clean implementation must be rejected, and the failing operator
//!    named by the `RefinementError` must lie in the mutated block or
//!    downstream of it (bug effects only flow forward).
//!
//! Any violation is shrunk to a minimal spec (suffix/prefix block removal
//! while the disagreement persists) and dumped as a replayable JSON
//! counterexample. Runs are fully deterministic per `--seed`: the same
//! seed reproduces byte-identical counterexample files.

use super::genmodel::{build_pair, sample_spec_for, Flavor, ModelSpec};
use super::journal::Journal;
use super::mutate::{
    applicable_sites, apply_mutation, apply_mutation_by_name, parse_block, Mutation, Site,
};
use crate::infer::{verify_numeric, EscalationPolicy, InferConfig, Verdict};
use crate::ir::Graph;
use crate::relation::Relation;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::schema;
use crate::verifier::Verifier;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Number of fuzz cases (models) to generate.
    pub seeds: u64,
    /// Base seed; case `i` derives its own seed from `(base, i)`.
    pub base_seed: u64,
    /// Parallel degree; 0 picks per-case from {2, 2, 2, 4}.
    pub ranks: usize,
    /// Max mutants attempted per model.
    pub mutants_per_model: usize,
    /// Directory for counterexample JSON files and the campaign journal.
    pub out_dir: PathBuf,
    /// Write counterexample files + journal (tests disable this).
    pub write_files: bool,
    /// Restrict the campaign to one strategy flavor (`--flavor`); the rng
    /// stream is consumed exactly as in mixed sampling, so per-seed block
    /// and shape draws stay comparable across campaigns.
    pub flavor: Option<Flavor>,
    /// Resume from `out_dir`'s journal: replay journaled seeds into the
    /// report without re-running them, then continue with the rest. The
    /// journal's config header must match this config.
    pub resume: bool,
    /// Crash drill: stop after journaling this many *newly processed*
    /// seeds, returning a report flagged `aborted` (simulates a mid-run
    /// `kill -9` at a deterministic point; used by the resume smoke test).
    pub abort_after: Option<u64>,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        FuzzConfig {
            seeds: 50,
            base_seed: 0,
            ranks: 0,
            mutants_per_model: 4,
            out_dir: PathBuf::from("fuzz_counterexamples"),
            write_files: true,
            flavor: None,
            resume: false,
            abort_after: None,
        }
    }
}

impl FuzzConfig {
    /// The journal `config` header pinning this campaign's identity.
    /// `base_seed` is a hex string (u64 does not fit losslessly in the
    /// JSON number type).
    pub fn journal_header(&self) -> Json {
        Json::obj(vec![
            ("schema_version", schema::version_field()),
            ("type", Json::str("config")),
            ("seeds", Json::num(self.seeds as f64)),
            ("base_seed", Json::str(format!("{:#x}", self.base_seed))),
            ("ranks", Json::num(self.ranks as f64)),
            ("mutants_per_model", Json::num(self.mutants_per_model as f64)),
            (
                "flavor",
                self.flavor.map(|f| Json::str(f.name())).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Reconstruct a resumable campaign config from the journal in `dir`
/// (the CLI's `fuzz --resume <dir>` entrypoint).
pub fn resume_config(dir: &Path) -> Result<FuzzConfig> {
    let (header, _, _) = Journal::open(dir)?;
    schema::check(&header, "fuzz journal")?;
    let field = |k: &str| -> Result<u64> {
        header
            .get(k)
            .as_usize()
            .map(|v| v as u64)
            .ok_or_else(|| anyhow!("journal header missing numeric field '{k}'"))
    };
    let base_seed_str = header
        .get("base_seed")
        .as_str()
        .ok_or_else(|| anyhow!("journal header missing 'base_seed'"))?;
    let base_seed = u64::from_str_radix(base_seed_str.trim_start_matches("0x"), 16)
        .map_err(|_| anyhow!("journal header: bad base_seed '{base_seed_str}'"))?;
    let flavor = match header.get("flavor") {
        Json::Null => None,
        f => {
            let name = f.as_str().ok_or_else(|| anyhow!("journal header: bad 'flavor'"))?;
            Some(
                Flavor::parse(name)
                    .ok_or_else(|| anyhow!("journal header: unknown flavor '{name}'"))?,
            )
        }
    };
    Ok(FuzzConfig {
        seeds: field("seeds")?,
        base_seed,
        ranks: field("ranks")? as usize,
        mutants_per_model: field("mutants_per_model")? as usize,
        out_dir: dir.to_path_buf(),
        write_files: true,
        flavor,
        resume: true,
        abort_after: None,
    })
}

/// splitmix-style per-case seed derivation (decorrelates nearby cases).
fn case_seed(base: u64, i: u64) -> u64 {
    crate::util::rng::mix64(base ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A copy of `j` with the `schema_version` stamp removed — what this
/// build's journal header looked like before versioning existed, for
/// comparing against v0 journals on resume.
fn without_schema_version(j: &Json) -> Json {
    match j {
        Json::Obj(map) => {
            let mut map = map.clone();
            map.remove("schema_version");
            Json::Obj(map)
        }
        other => other.clone(),
    }
}

/// What happened to one clean pair.
enum CleanOutcome {
    Verified,
    /// The checker rejected a correct-by-construction pair.
    FalseAlarm(String),
    /// Accepted, but the inferred relation fails numeric replay.
    CertFailure(String),
    /// Budgets ran out on a correct-by-construction pair at the oracle's
    /// (escalated) default budgets — a soundness-of-service violation
    /// distinct from a detection miss: the engine failed to do its job on
    /// a clean input. Counted against `FuzzReport::sound`.
    Inconclusive { reason: &'static str, detail: String },
}

/// What happened to one mutant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MutOutcome {
    /// Rejected; failing operator inside the mutated region.
    KilledInRegion,
    /// Rejected, but the reported locus precedes the mutated block.
    LocusMiss(String),
    /// Numerics changed but a certificate-valid relation still exists
    /// (semantically benign rearrangement — e.g. provably re-sliceable
    /// shard reorderings).
    BenignAccepted,
    /// No observable numeric change; accepted.
    SilentAccepted,
    /// No observable numeric change on sampled inputs; still rejected
    /// (possible checker incompleteness, not a soundness violation).
    SilentRejected,
    /// Numerics changed, checker accepted, and the certificate fails:
    /// a genuine soundness hole.
    FalseProof(String),
    /// Budgets ran out on the mutant. A coverage loss (the mutant's fate
    /// is unknown), not a soundness violation — unlike a clean-pair
    /// `Inconclusive`, nothing was asserted that might be false.
    Inconclusive(&'static str),
}

impl MutOutcome {
    fn tag(&self) -> &'static str {
        match self {
            MutOutcome::KilledInRegion => "killed_in_region",
            MutOutcome::LocusMiss(_) => "locus_miss",
            MutOutcome::BenignAccepted => "benign_accepted",
            MutOutcome::SilentAccepted => "silent_accepted",
            MutOutcome::SilentRejected => "silent_rejected",
            MutOutcome::FalseProof(_) => "false_proof",
            MutOutcome::Inconclusive(_) => "inconclusive",
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct OpStat {
    pub attempted: u64,
    pub stillborn: u64,
    pub eval_failure: u64,
    pub killed_in_region: u64,
    pub locus_miss: u64,
    pub benign_accepted: u64,
    pub silent_accepted: u64,
    pub silent_rejected: u64,
    pub false_proof: u64,
    pub inconclusive: u64,
    /// Rejected mutants the ShardFlow static analysis also flagged —
    /// lint triage, orthogonal to the verdict-level outcome columns.
    pub lint_flagged: u64,
    /// Rejected mutants only the e-graph caught (the lint stayed silent).
    pub lint_silent_refuted: u64,
}

#[derive(Debug, Clone)]
pub struct CexSummary {
    pub file: String,
    pub kind: String,
    pub case_seed: u64,
    pub detail: String,
}

#[derive(Debug, Clone, Default)]
pub struct FuzzReport {
    pub models: u64,
    pub clean_verified: u64,
    pub false_alarms: u64,
    pub clean_cert_failures: u64,
    /// Clean pairs on which the (escalated) default budgets ran out — a
    /// soundness-of-service violation, see [`FuzzReport::sound`].
    pub clean_inconclusive: u64,
    /// Clean pairs the ShardFlow static analysis flagged. The lint is
    /// specified to be silent on correct graphs, so any nonzero count is a
    /// soundness violation (see [`FuzzReport::sound`]).
    pub lint_false_alarms: u64,
    /// Per-mutation-operator outcome counts — the single source of truth
    /// for every mutant-level aggregate (see the derived methods below).
    pub per_op: BTreeMap<String, OpStat>,
    pub counterexamples: Vec<CexSummary>,
    /// Set when the campaign stopped early via `FuzzConfig::abort_after`
    /// (crash drill). Deliberately NOT serialized: an aborted report is
    /// never written as a final `FUZZ_REPORT.json`.
    pub aborted: bool,
}

impl FuzzReport {
    fn sum(&self, f: impl Fn(&OpStat) -> u64) -> u64 {
        self.per_op.values().map(f).sum()
    }
    pub fn mutants_attempted(&self) -> u64 {
        self.sum(|s| s.attempted)
    }
    pub fn stillborn(&self) -> u64 {
        self.sum(|s| s.stillborn)
    }
    /// A *validated* mutant failed concrete evaluation — a harness bug,
    /// never an expected outcome (unlike type-check stillborns).
    pub fn eval_failures(&self) -> u64 {
        self.sum(|s| s.eval_failure)
    }
    pub fn killed_in_region(&self) -> u64 {
        self.sum(|s| s.killed_in_region)
    }
    pub fn locus_misses(&self) -> u64 {
        self.sum(|s| s.locus_miss)
    }
    pub fn benign_accepted(&self) -> u64 {
        self.sum(|s| s.benign_accepted)
    }
    pub fn silent_accepted(&self) -> u64 {
        self.sum(|s| s.silent_accepted)
    }
    pub fn silent_rejected(&self) -> u64 {
        self.sum(|s| s.silent_rejected)
    }
    pub fn false_proofs(&self) -> u64 {
        self.sum(|s| s.false_proof)
    }
    /// Mutants whose verdict the budgets could not decide (coverage loss,
    /// not a soundness violation).
    pub fn mutants_inconclusive(&self) -> u64 {
        self.sum(|s| s.inconclusive)
    }
    /// Rejected mutants the static analysis also flagged (lint triage).
    pub fn lint_flagged(&self) -> u64 {
        self.sum(|s| s.lint_flagged)
    }
    /// Rejected mutants only the e-graph caught — expected for
    /// numerics-only bugs the placement lattice cannot see.
    pub fn lint_silent_refuted(&self) -> u64 {
        self.sum(|s| s.lint_silent_refuted)
    }

    /// Zero false proofs, zero false alarms, zero mislocalizations, no
    /// oracle-evaluation failures (a rebuilt, validated mutant that cannot
    /// be executed means the harness itself is broken), and no clean pair
    /// starved into `Inconclusive` at default budgets, and no lint finding
    /// on any clean pair (the static analysis must stay silent on correct
    /// graphs). Mutant-side `Inconclusive` is a coverage metric, not a
    /// soundness one, and `lint_silent_refuted` is expected triage noise.
    pub fn sound(&self) -> bool {
        self.false_alarms == 0
            && self.clean_cert_failures == 0
            && self.clean_inconclusive == 0
            && self.lint_false_alarms == 0
            && self.false_proofs() == 0
            && self.locus_misses() == 0
            && self.eval_failures() == 0
    }

    pub fn to_json(&self) -> Json {
        let per_op: BTreeMap<String, Json> = self
            .per_op
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("attempted", Json::num(s.attempted as f64)),
                        ("stillborn", Json::num(s.stillborn as f64)),
                        ("eval_failure", Json::num(s.eval_failure as f64)),
                        ("killed_in_region", Json::num(s.killed_in_region as f64)),
                        ("locus_miss", Json::num(s.locus_miss as f64)),
                        ("benign_accepted", Json::num(s.benign_accepted as f64)),
                        ("silent_accepted", Json::num(s.silent_accepted as f64)),
                        ("silent_rejected", Json::num(s.silent_rejected as f64)),
                        ("false_proof", Json::num(s.false_proof as f64)),
                        ("inconclusive", Json::num(s.inconclusive as f64)),
                        ("lint_flagged", Json::num(s.lint_flagged as f64)),
                        (
                            "lint_silent_refuted",
                            Json::num(s.lint_silent_refuted as f64),
                        ),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("schema_version", schema::version_field()),
            ("models", Json::num(self.models as f64)),
            ("clean_verified", Json::num(self.clean_verified as f64)),
            ("false_alarms", Json::num(self.false_alarms as f64)),
            ("clean_cert_failures", Json::num(self.clean_cert_failures as f64)),
            ("clean_inconclusive", Json::num(self.clean_inconclusive as f64)),
            ("lint_false_alarms", Json::num(self.lint_false_alarms as f64)),
            ("mutants_attempted", Json::num(self.mutants_attempted() as f64)),
            ("stillborn", Json::num(self.stillborn() as f64)),
            ("eval_failures", Json::num(self.eval_failures() as f64)),
            ("killed_in_region", Json::num(self.killed_in_region() as f64)),
            ("locus_misses", Json::num(self.locus_misses() as f64)),
            ("benign_accepted", Json::num(self.benign_accepted() as f64)),
            ("silent_accepted", Json::num(self.silent_accepted() as f64)),
            ("silent_rejected", Json::num(self.silent_rejected() as f64)),
            ("false_proofs", Json::num(self.false_proofs() as f64)),
            ("mutants_inconclusive", Json::num(self.mutants_inconclusive() as f64)),
            ("lint_flagged", Json::num(self.lint_flagged() as f64)),
            ("lint_silent_refuted", Json::num(self.lint_silent_refuted() as f64)),
            ("sound", Json::Bool(self.sound())),
            ("per_operator", Json::Obj(per_op)),
            (
                "counterexamples",
                Json::Arr(
                    self.counterexamples
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("file", Json::str(c.file.clone())),
                                ("kind", Json::str(c.kind.clone())),
                                ("case_seed", Json::str(format!("{:#018x}", c.case_seed))),
                                ("detail", Json::str(c.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable summary + per-operator detection table.
    pub fn table(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "fuzz: {} models | clean verified {} | false alarms {} | cert failures {} | \
             clean inconclusive {}\n",
            self.models,
            self.clean_verified,
            self.false_alarms,
            self.clean_cert_failures,
            self.clean_inconclusive
        ));
        s.push_str(&format!(
            "mutants: {} attempted | {} stillborn | {} eval-failures | {} killed-in-region | \
             {} locus-miss | {} benign | {} silent-accepted | {} silent-rejected | \
             {} inconclusive | {} FALSE PROOFS\n",
            self.mutants_attempted(),
            self.stillborn(),
            self.eval_failures(),
            self.killed_in_region(),
            self.locus_misses(),
            self.benign_accepted(),
            self.silent_accepted(),
            self.silent_rejected(),
            self.mutants_inconclusive(),
            self.false_proofs()
        ));
        s.push_str(&format!(
            "lint: {} false alarms on clean pairs | {} rejected mutants flagged | \
             {} silent-refuted (e-graph only)\n",
            self.lint_false_alarms,
            self.lint_flagged(),
            self.lint_silent_refuted()
        ));
        s.push_str(&format!(
            "{:<26} {:>6} {:>6} {:>6} {:>7} {:>6} {:>7} {:>7} {:>7} {:>6} {:>6}\n",
            "operator", "tried", "still", "evalx", "killed", "miss", "benign", "sil-ok",
            "sil-rej", "inconc", "false"
        ));
        for (name, st) in &self.per_op {
            s.push_str(&format!(
                "{:<26} {:>6} {:>6} {:>6} {:>7} {:>6} {:>7} {:>7} {:>7} {:>6} {:>6}\n",
                name,
                st.attempted,
                st.stillborn,
                st.eval_failure,
                st.killed_in_region,
                st.locus_miss,
                st.benign_accepted,
                st.silent_accepted,
                st.silent_rejected,
                st.inconclusive,
                st.false_proof
            ));
        }
        if !self.counterexamples.is_empty() {
            s.push_str("counterexamples:\n");
            for c in &self.counterexamples {
                s.push_str(&format!("  [{}] {} — {}\n", c.kind, c.file, c.detail));
            }
        }
        s
    }
}

/// Do the two graphs (same interface) produce different outputs on any of
/// `n_draws` random input draws? Shape mismatches count as different.
/// `Err` only on evaluation failure (treated as stillborn upstream).
fn outputs_differ(a: &Graph, b: &Graph, seed: u64, n_draws: u64) -> Result<bool> {
    use crate::expr::eval::{eval_graph, random_inputs};
    for d in 0..n_draws {
        let inputs = random_inputs(a, seed.wrapping_add(d));
        let va = eval_graph(a, &inputs)?;
        let vb = eval_graph(b, &inputs)?;
        for (&oa, &ob) in a.outputs.iter().zip(&b.outputs) {
            let (ta, tb) = (&va[oa as usize], &vb[ob as usize]);
            if ta.shape() != tb.shape() || !ta.allclose(tb, 1e-4, 1e-5) {
                return Ok(true);
            }
        }
    }
    Ok(false)
}

/// Replay an inferred relation's numeric certificate on several draws.
fn certificate_ok(gs: &Graph, gd: &Graph, ri: &Relation, ro: &Relation, seed: u64) -> bool {
    (0..3u64).all(|d| verify_numeric(gs, gd, ri, ro, seed.wrapping_add(d)).is_ok())
}

fn clean_outcome(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    seed: u64,
    icfg: &InferConfig,
) -> CleanOutcome {
    match Verifier::with_config(icfg.clone())
        .escalation(EscalationPolicy::default())
        .run(gs, gd, ri)
    {
        Verdict::Refuted(e) => CleanOutcome::FalseAlarm(format!("{e}")),
        Verdict::Inconclusive(i) => {
            CleanOutcome::Inconclusive { reason: i.reason.tag(), detail: format!("{i}") }
        }
        Verdict::Verified(out) => {
            if certificate_ok(gs, gd, ri, &out.relation, seed) {
                CleanOutcome::Verified
            } else {
                CleanOutcome::CertFailure(
                    "inferred relation fails numeric replay on a clean pair".into(),
                )
            }
        }
    }
}

/// Is the failure locus inside the mutated region? The region is the
/// mutated block plus everything downstream; the SP epilogue gather
/// (block index == blocks.len()) is attributed to the last real block,
/// since its breakage surfaces at the output filter of the final operator.
fn locus_in_region(err_node_name: &str, mutated_block: Option<usize>, n_blocks: usize) -> bool {
    let Some(mb) = mutated_block else { return false };
    let region_start = mb.min(n_blocks.saturating_sub(1));
    match parse_block(err_node_name) {
        Some(b) => b >= region_start,
        None => false,
    }
}

/// Classify one already-built mutant.
#[allow(clippy::too_many_arguments)]
fn classify_mutant(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    gd_mut: &Graph,
    mutation: &Mutation,
    n_blocks: usize,
    seed: u64,
    icfg: &InferConfig,
) -> Result<MutOutcome> {
    let differs = outputs_differ(gd, gd_mut, seed ^ 0xD1FF, 3)
        .context("evaluating mutant numerically")?;
    match Verifier::with_config(icfg.clone())
        .escalation(EscalationPolicy::default())
        .run(gs, gd_mut, ri)
    {
        Verdict::Verified(out) => {
            if certificate_ok(gs, gd_mut, ri, &out.relation, seed ^ 0xCE57) {
                Ok(if differs { MutOutcome::BenignAccepted } else { MutOutcome::SilentAccepted })
            } else {
                Ok(MutOutcome::FalseProof(format!(
                    "mutant '{}' ({}) accepted but its certificate fails numeric replay",
                    mutation.node_name,
                    mutation.kind.name()
                )))
            }
        }
        Verdict::Inconclusive(i) => Ok(MutOutcome::Inconclusive(i.reason.tag())),
        Verdict::Refuted(e) => {
            if !differs {
                return Ok(MutOutcome::SilentRejected);
            }
            if locus_in_region(&e.node_name, mutation.block, n_blocks) {
                Ok(MutOutcome::KilledInRegion)
            } else {
                Ok(MutOutcome::LocusMiss(format!(
                    "mutated '{}' (block {:?}) but failure localized at '{}' ({})",
                    mutation.node_name, mutation.block, e.node_name, e.op
                )))
            }
        }
    }
}

/// The badness classes the minimizer preserves while shrinking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BadKind {
    FalseAlarm,
    CertFailure,
    FalseProof,
    LocusMiss,
    /// A rebuilt, validated mutant failed concrete evaluation.
    EvalFailure,
    /// Default (escalated) budgets starved out on a clean pair.
    CleanInconclusive,
}

impl BadKind {
    fn name(self) -> &'static str {
        match self {
            BadKind::FalseAlarm => "false_alarm",
            BadKind::CertFailure => "clean_cert_failure",
            BadKind::FalseProof => "false_proof",
            BadKind::LocusMiss => "locus_miss",
            BadKind::EvalFailure => "eval_failure",
            BadKind::CleanInconclusive => "clean_inconclusive",
        }
    }
}

/// Re-evaluate a (spec, mutation?) candidate and report which badness it
/// exhibits, if any. Mutations are re-located by node name.
fn evaluate_candidate(
    spec: &ModelSpec,
    mutation: Option<&Mutation>,
    seed: u64,
    icfg: &InferConfig,
) -> Option<BadKind> {
    let (gs, gd, ri) = build_pair(spec).ok()?;
    match mutation {
        None => match clean_outcome(&gs, &gd, &ri, seed, icfg) {
            CleanOutcome::FalseAlarm(_) => Some(BadKind::FalseAlarm),
            CleanOutcome::CertFailure(_) => Some(BadKind::CertFailure),
            CleanOutcome::Inconclusive { .. } => Some(BadKind::CleanInconclusive),
            CleanOutcome::Verified => None,
        },
        Some(m) => {
            // the clean pair must still verify for the mutant verdict to
            // mean anything
            if !matches!(clean_outcome(&gs, &gd, &ri, seed, icfg), CleanOutcome::Verified) {
                return None;
            }
            let (gd_mut, m2) = apply_mutation_by_name(&gd, m.kind, &m.node_name).ok()?;
            match classify_mutant(&gs, &gd, &ri, &gd_mut, &m2, spec.blocks.len(), seed, icfg) {
                Err(_) => Some(BadKind::EvalFailure),
                Ok(MutOutcome::FalseProof(_)) => Some(BadKind::FalseProof),
                Ok(MutOutcome::LocusMiss(_)) => Some(BadKind::LocusMiss),
                Ok(_) => None,
            }
        }
    }
}

/// Fresh badness description for a (possibly shrunk) candidate, so the
/// dumped counterexample's `detail` names nodes that exist in its own
/// minimized spec/graphs. `None` when the class cannot be re-derived.
fn describe_candidate(
    spec: &ModelSpec,
    mutation: Option<&Mutation>,
    kind: BadKind,
    seed: u64,
    icfg: &InferConfig,
) -> Option<String> {
    let (gs, gd, ri) = build_pair(spec).ok()?;
    match mutation {
        None => match clean_outcome(&gs, &gd, &ri, seed, icfg) {
            CleanOutcome::FalseAlarm(d) if kind == BadKind::FalseAlarm => Some(d),
            CleanOutcome::CertFailure(d) if kind == BadKind::CertFailure => Some(d),
            CleanOutcome::Inconclusive { detail, .. } if kind == BadKind::CleanInconclusive => {
                Some(detail)
            }
            _ => None,
        },
        Some(m) => {
            let (gd_mut, m2) = apply_mutation_by_name(&gd, m.kind, &m.node_name).ok()?;
            match classify_mutant(&gs, &gd, &ri, &gd_mut, &m2, spec.blocks.len(), seed, icfg) {
                Err(e) if kind == BadKind::EvalFailure => Some(format!("{e:#}")),
                Ok(MutOutcome::FalseProof(d)) if kind == BadKind::FalseProof => Some(d),
                Ok(MutOutcome::LocusMiss(d)) if kind == BadKind::LocusMiss => Some(d),
                _ => None,
            }
        }
    }
}

/// Greedy structural shrink: drop suffix blocks, then prefix blocks, while
/// the same badness class persists and the mutation site (if any) survives.
fn minimize(
    spec: &ModelSpec,
    mutation: Option<&Mutation>,
    bad: BadKind,
    seed: u64,
    icfg: &InferConfig,
) -> (ModelSpec, Option<Mutation>) {
    let mut best = spec.clone();
    let mut best_mut = mutation.cloned();
    // 1. truncate blocks after the mutated block (or any suffix for clean
    //    badness)
    loop {
        if best.blocks.len() <= 1 {
            break;
        }
        let last = best.blocks.len() - 1;
        if let Some(m) = &best_mut {
            match m.block {
                // epilogue mutations (block == blocks.len()) are remapped
                // after truncation; a mutation in the block being removed
                // (or with no parseable block) stops the shrink
                Some(b) if b == last => break,
                None => break,
                _ => {}
            }
        }
        let mut cand = best.clone();
        cand.blocks.truncate(last);
        let cand_mut = best_mut.as_ref().map(|m| remap_epilogue(m, &best, &cand));
        if evaluate_candidate(&cand, cand_mut.as_ref(), seed, icfg) == Some(bad) {
            best = cand;
            best_mut = cand_mut;
        } else {
            break;
        }
    }
    // 2. drop leading blocks, renumbering the mutation site
    loop {
        if best.blocks.len() <= 1 {
            break;
        }
        if let Some(m) = &best_mut {
            if m.block == Some(0) {
                break;
            }
        }
        let mut cand = best.clone();
        cand.blocks.remove(0);
        let cand_mut = best_mut.as_ref().map(|m| shift_block(m, &best, &cand));
        if evaluate_candidate(&cand, cand_mut.as_ref(), seed, icfg) == Some(bad) {
            best = cand;
            best_mut = cand_mut;
        } else {
            break;
        }
    }
    (best, best_mut)
}

/// Keep an epilogue-gather mutation pointing at the (moved) epilogue when
/// blocks are truncated; other mutations are unchanged.
fn remap_epilogue(m: &Mutation, old: &ModelSpec, new: &ModelSpec) -> Mutation {
    if m.block == Some(old.blocks.len()) {
        let name = format!("b{}_out", new.blocks.len());
        Mutation { kind: m.kind, node_name: name, block: Some(new.blocks.len()) }
    } else {
        m.clone()
    }
}

/// Renumber a mutation after removing the leading block.
fn shift_block(m: &Mutation, old: &ModelSpec, new: &ModelSpec) -> Mutation {
    let Some(b) = m.block else { return m.clone() };
    if b == old.blocks.len() {
        // epilogue gather
        let name = format!("b{}_out", new.blocks.len());
        return Mutation { kind: m.kind, node_name: name, block: Some(new.blocks.len()) };
    }
    let nb = b - 1;
    let rest = m.node_name.split_once('_').map(|(_, r)| r).unwrap_or("");
    let name = format!("b{nb}_{rest}");
    Mutation { kind: m.kind, node_name: name, block: Some(nb) }
}

/// A fully-described counterexample, ready to serialize.
struct Counterexample {
    kind: BadKind,
    case_seed: u64,
    mut_index: usize,
    detail: String,
    spec: ModelSpec,
    mutation: Option<Mutation>,
}

impl Counterexample {
    fn file_name(&self) -> String {
        format!(
            "ce_{:016x}_{:02}_{}.json",
            self.case_seed,
            self.mut_index,
            self.kind.name()
        )
    }

    fn to_json(&self) -> Json {
        let graphs = build_pair(&self.spec).ok().map(|(gs, gd, ri)| {
            let gd_mut = self.mutation.as_ref().and_then(|m| {
                apply_mutation_by_name(&gd, m.kind, &m.node_name)
                    .ok()
                    .map(|(g, _)| crate::ir::json_io::to_json(&g))
            });
            (
                crate::ir::json_io::to_json(&gs),
                crate::ir::json_io::to_json(&gd),
                ri.to_json(&gs, &gd),
                gd_mut.unwrap_or(Json::Null),
            )
        });
        let nulls = (Json::Null, Json::Null, Json::Null, Json::Null);
        let (gs_j, gd_j, ri_j, gd_mut_j) = graphs.unwrap_or(nulls);
        Json::obj(vec![
            ("schema_version", schema::version_field()),
            ("kind", Json::str(self.kind.name())),
            ("case_seed", Json::str(format!("{:#018x}", self.case_seed))),
            ("detail", Json::str(self.detail.clone())),
            ("minimized", Json::Bool(true)),
            ("spec", self.spec.to_json()),
            (
                "mutation",
                self.mutation.as_ref().map(Mutation::to_json).unwrap_or(Json::Null),
            ),
            ("gs", gs_j),
            ("gd", gd_j),
            ("ri", ri_j),
            ("gd_mut", gd_mut_j),
        ])
    }
}

/// Run the fuzzer. Deterministic per config; returns the aggregate report.
///
/// Crash safety: with `write_files` on, every completed seed is journaled
/// durably before the next one starts, and `resume` replays the journal
/// instead of re-running those seeds. Because each case derives everything
/// from `case_seed(base_seed, i)` and seeds are processed in order, a
/// killed-and-resumed campaign produces a final report byte-identical to
/// an uninterrupted run's.
pub fn run_fuzz(cfg: &FuzzConfig) -> Result<FuzzReport> {
    let icfg = InferConfig::default();
    let mut report = FuzzReport::default();
    if cfg.resume && !cfg.write_files {
        bail!("fuzz resume needs the on-disk journal (write_files is off)");
    }
    let mut done: BTreeMap<u64, Json> = BTreeMap::new();
    let mut journal = if cfg.write_files {
        std::fs::create_dir_all(&cfg.out_dir)
            .with_context(|| format!("creating {}", cfg.out_dir.display()))?;
        if cfg.resume {
            let (header, recs, j) = Journal::open(&cfg.out_dir)?;
            // Explicit version mismatch fails here, naming both versions;
            // a version-less (v0) header is still resumable and compared
            // against this build's header minus the stamp.
            schema::check(&header, "fuzz journal")?;
            let want = match schema::declared_version(&header) {
                Some(_) => cfg.journal_header(),
                None => without_schema_version(&cfg.journal_header()),
            };
            if header.to_string() != want.to_string() {
                bail!(
                    "journal in {} belongs to a different campaign config\n  journal: {}\n  \
                     requested: {}\nrefusing to resume",
                    cfg.out_dir.display(),
                    header.to_string(),
                    want.to_string()
                );
            }
            done = recs;
            Some(j)
        } else {
            Some(Journal::create(&cfg.out_dir, &cfg.journal_header())?)
        }
    } else {
        None
    };

    let mut fresh = 0u64; // seeds newly processed (not replayed) this run
    for i in 0..cfg.seeds {
        if let Some(rec) = done.get(&i) {
            replay_seed_record(&mut report, rec)
                .with_context(|| format!("replaying journaled seed {i}"))?;
            continue;
        }
        if cfg.abort_after.is_some_and(|n| fresh >= n) {
            report.aborted = true;
            return Ok(report);
        }
        let record = run_seed(cfg, &icfg, i, &mut report)?;
        if let Some(j) = journal.as_mut() {
            j.append(&record)?;
        }
        fresh += 1;
    }
    Ok(report)
}

/// Process one fuzz case end-to-end, updating `report`, and return the
/// seed's journal record (clean verdict tag, per-mutant outcomes, and the
/// counterexample summaries it contributed).
fn run_seed(
    cfg: &FuzzConfig,
    icfg: &InferConfig,
    i: u64,
    report: &mut FuzzReport,
) -> Result<Json> {
    let cs = case_seed(cfg.base_seed, i);
    let cex_start = report.counterexamples.len();
    let mut rng = Rng::new(cs);
    let ranks =
        if cfg.ranks == 0 { [2usize, 2, 2, 4][rng.below(4) as usize] } else { cfg.ranks };
    let spec = sample_spec_for(&mut rng, ranks, cs, cfg.flavor);
    let (gs, gd, ri) =
        build_pair(&spec).with_context(|| format!("building case {i} (seed {cs:#x})"))?;
    report.models += 1;

    // ShardFlow triage, clean side: the static analysis is specified to be
    // silent on every correct pair, so a finding here is a soundness
    // violation regardless of what the e-graph later concludes.
    let clean_lint = crate::analysis::analyze(&gd, Some(&ri)).findings.len() as u64;
    if clean_lint > 0 {
        report.lint_false_alarms += 1;
    }

    let clean_tag: &'static str;
    let mut mutant_events: Vec<(&'static str, &'static str, Option<&'static str>)> = Vec::new();
    match clean_outcome(&gs, &gd, &ri, cs, icfg) {
        // mutant verdicts are meaningless on a bad clean pair, so every
        // non-Verified arm skips the mutant loop
        CleanOutcome::FalseAlarm(detail) => {
            report.false_alarms += 1;
            clean_tag = "false_alarm";
            record_cex(
                report,
                cfg,
                Counterexample {
                    kind: BadKind::FalseAlarm,
                    case_seed: cs,
                    mut_index: 0,
                    detail,
                    spec: spec.clone(),
                    mutation: None,
                },
                cs,
                icfg,
            )?;
        }
        CleanOutcome::CertFailure(detail) => {
            report.clean_cert_failures += 1;
            clean_tag = "cert_failure";
            record_cex(
                report,
                cfg,
                Counterexample {
                    kind: BadKind::CertFailure,
                    case_seed: cs,
                    mut_index: 0,
                    detail,
                    spec: spec.clone(),
                    mutation: None,
                },
                cs,
                icfg,
            )?;
        }
        CleanOutcome::Inconclusive { detail, .. } => {
            report.clean_inconclusive += 1;
            clean_tag = "inconclusive";
            record_cex(
                report,
                cfg,
                Counterexample {
                    kind: BadKind::CleanInconclusive,
                    case_seed: cs,
                    mut_index: 0,
                    detail,
                    spec: spec.clone(),
                    mutation: None,
                },
                cs,
                icfg,
            )?;
        }
        CleanOutcome::Verified => {
            report.clean_verified += 1;
            clean_tag = "verified";

            // pick up to `mutants_per_model` distinct sites (partial
            // Fisher-Yates on indices, deterministic in `rng`)
            let sites = applicable_sites(&gd);
            let take = cfg.mutants_per_model.min(sites.len());
            let mut idx: Vec<usize> = (0..sites.len()).collect();
            for k in 0..take {
                let j = k + rng.below((idx.len() - k) as u64) as usize;
                idx.swap(k, j);
            }

            for (mi, &si) in idx[..take].iter().enumerate() {
                let site: Site = sites[si];
                bump(&mut report.per_op, site.kind, |s| s.attempted += 1);
                let (gd_mut, mutation) = match apply_mutation(&gd, site) {
                    Ok(x) => x,
                    Err(_) => {
                        bump(&mut report.per_op, site.kind, |s| s.stillborn += 1);
                        mutant_events.push((site.kind.name(), "stillborn", None));
                        continue;
                    }
                };
                let outcome = match classify_mutant(
                    &gs,
                    &gd,
                    &ri,
                    &gd_mut,
                    &mutation,
                    spec.blocks.len(),
                    cs,
                    icfg,
                ) {
                    Ok(o) => o,
                    Err(err) => {
                        // a validated mutant that cannot be evaluated is a
                        // harness bug: tracked separately from type-check
                        // stillborns, counted against soundness, and dumped
                        // as a debuggable counterexample like any other
                        // violation
                        bump(&mut report.per_op, site.kind, |s| s.eval_failure += 1);
                        mutant_events.push((site.kind.name(), "eval_failure", None));
                        record_cex(
                            report,
                            cfg,
                            Counterexample {
                                kind: BadKind::EvalFailure,
                                case_seed: cs,
                                mut_index: mi + 1,
                                detail: format!("{err:#}"),
                                spec: spec.clone(),
                                mutation: Some(mutation.clone()),
                            },
                            cs,
                            icfg,
                        )?;
                        continue;
                    }
                };
                // ShardFlow triage, mutant side: partition the rejected
                // mutants into lint-flagged vs. e-graph-only catches.
                // Accepted / inconclusive mutants are not triaged — the
                // lint has nothing to agree or disagree with there.
                let lint_tag = match &outcome {
                    MutOutcome::KilledInRegion
                    | MutOutcome::SilentRejected
                    | MutOutcome::LocusMiss(_) => {
                        if crate::analysis::analyze(&gd_mut, Some(&ri)).is_clean() {
                            Some("lint_silent_refuted")
                        } else {
                            Some("lint_flagged")
                        }
                    }
                    _ => None,
                };
                match lint_tag {
                    Some("lint_flagged") => {
                        bump(&mut report.per_op, site.kind, |s| s.lint_flagged += 1);
                    }
                    Some("lint_silent_refuted") => {
                        bump(&mut report.per_op, site.kind, |s| s.lint_silent_refuted += 1);
                    }
                    _ => {}
                }
                mutant_events.push((site.kind.name(), outcome.tag(), lint_tag));
                match &outcome {
                    MutOutcome::KilledInRegion => {
                        bump(&mut report.per_op, site.kind, |s| s.killed_in_region += 1);
                    }
                    MutOutcome::BenignAccepted => {
                        bump(&mut report.per_op, site.kind, |s| s.benign_accepted += 1);
                    }
                    MutOutcome::SilentAccepted => {
                        bump(&mut report.per_op, site.kind, |s| s.silent_accepted += 1);
                    }
                    MutOutcome::SilentRejected => {
                        bump(&mut report.per_op, site.kind, |s| s.silent_rejected += 1);
                    }
                    MutOutcome::Inconclusive(_) => {
                        // unknown verdict = coverage loss, not a violation;
                        // no counterexample to dump
                        bump(&mut report.per_op, site.kind, |s| s.inconclusive += 1);
                    }
                    MutOutcome::LocusMiss(detail) => {
                        bump(&mut report.per_op, site.kind, |s| s.locus_miss += 1);
                        record_cex(
                            report,
                            cfg,
                            Counterexample {
                                kind: BadKind::LocusMiss,
                                case_seed: cs,
                                mut_index: mi + 1,
                                detail: detail.clone(),
                                spec: spec.clone(),
                                mutation: Some(mutation.clone()),
                            },
                            cs,
                            icfg,
                        )?;
                    }
                    MutOutcome::FalseProof(detail) => {
                        bump(&mut report.per_op, site.kind, |s| s.false_proof += 1);
                        record_cex(
                            report,
                            cfg,
                            Counterexample {
                                kind: BadKind::FalseProof,
                                case_seed: cs,
                                mut_index: mi + 1,
                                detail: detail.clone(),
                                spec: spec.clone(),
                                mutation: Some(mutation.clone()),
                            },
                            cs,
                            icfg,
                        )?;
                    }
                }
            }
        }
    }

    let cex: Vec<Json> = report.counterexamples[cex_start..]
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("file", Json::str(c.file.clone())),
                ("kind", Json::str(c.kind.clone())),
                ("case_seed", Json::str(format!("{:#018x}", c.case_seed))),
                ("detail", Json::str(c.detail.clone())),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("type", Json::str("seed")),
        ("index", Json::num(i as f64)),
        ("case_seed", Json::str(format!("{:#018x}", cs))),
        ("clean", Json::str(clean_tag)),
        ("clean_lint", Json::num(clean_lint as f64)),
        (
            "mutants",
            Json::Arr(
                mutant_events
                    .into_iter()
                    .map(|(op, outcome, lint)| {
                        let mut fields = vec![
                            ("op", Json::str(op)),
                            ("outcome", Json::str(outcome)),
                        ];
                        if let Some(l) = lint {
                            fields.push(("lint", Json::str(l)));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        ("cex", Json::Arr(cex)),
    ]))
}

/// Re-apply one journaled seed record to the report — the resume path's
/// replacement for `run_seed`. Must bump exactly the counters `run_seed`
/// bumps for the same outcomes, or a resumed report diverges from an
/// uninterrupted one.
fn replay_seed_record(report: &mut FuzzReport, rec: &Json) -> Result<()> {
    report.models += 1;
    let clean = rec
        .get("clean")
        .as_str()
        .ok_or_else(|| anyhow!("seed record missing 'clean' tag"))?;
    match clean {
        "verified" => report.clean_verified += 1,
        "false_alarm" => report.false_alarms += 1,
        "cert_failure" => report.clean_cert_failures += 1,
        "inconclusive" => report.clean_inconclusive += 1,
        other => bail!("seed record: unknown clean outcome '{other}'"),
    }
    // pre-lint journals (no "clean_lint" field) replay as lint-silent
    if rec.get("clean_lint").as_f64().is_some_and(|n| n > 0.0) {
        report.lint_false_alarms += 1;
    }
    for m in rec.get("mutants").as_arr().unwrap_or(&[]) {
        let op = m.get("op").as_str().ok_or_else(|| anyhow!("mutant event missing 'op'"))?;
        let outcome = m
            .get("outcome")
            .as_str()
            .ok_or_else(|| anyhow!("mutant event missing 'outcome'"))?;
        let st = report.per_op.entry(op.to_string()).or_default();
        st.attempted += 1;
        match outcome {
            "stillborn" => st.stillborn += 1,
            "eval_failure" => st.eval_failure += 1,
            "killed_in_region" => st.killed_in_region += 1,
            "locus_miss" => st.locus_miss += 1,
            "benign_accepted" => st.benign_accepted += 1,
            "silent_accepted" => st.silent_accepted += 1,
            "silent_rejected" => st.silent_rejected += 1,
            "false_proof" => st.false_proof += 1,
            "inconclusive" => st.inconclusive += 1,
            other => bail!("mutant event: unknown outcome '{other}'"),
        }
        match m.get("lint").as_str() {
            Some("lint_flagged") => st.lint_flagged += 1,
            Some("lint_silent_refuted") => st.lint_silent_refuted += 1,
            Some(other) => bail!("mutant event: unknown lint tag '{other}'"),
            None => {}
        }
    }
    for c in rec.get("cex").as_arr().unwrap_or(&[]) {
        let field = |k: &str| -> Result<&str> {
            c.get(k).as_str().ok_or_else(|| anyhow!("cex summary missing '{k}'"))
        };
        let seed_str = field("case_seed")?;
        let case_seed = u64::from_str_radix(seed_str.trim_start_matches("0x"), 16)
            .map_err(|_| anyhow!("cex summary: bad case_seed '{seed_str}'"))?;
        report.counterexamples.push(CexSummary {
            file: field("file")?.to_string(),
            kind: field("kind")?.to_string(),
            case_seed,
            detail: field("detail")?.to_string(),
        });
    }
    Ok(())
}

/// Per-operator stat update helper (keeps `run_fuzz` borrow-friendly).
fn bump(
    map: &mut BTreeMap<String, OpStat>,
    kind: super::mutate::MutKind,
    f: impl FnOnce(&mut OpStat),
) {
    f(map.entry(kind.name().to_string()).or_default())
}

/// Minimize, serialize and register one counterexample.
fn record_cex(
    report: &mut FuzzReport,
    cfg: &FuzzConfig,
    cex: Counterexample,
    seed: u64,
    icfg: &InferConfig,
) -> Result<()> {
    let (spec, mutation) = minimize(&cex.spec, cex.mutation.as_ref(), cex.kind, seed, icfg);
    // re-derive the description against the minimized spec so it names
    // nodes that exist in the shipped graphs
    let detail = describe_candidate(&spec, mutation.as_ref(), cex.kind, seed, icfg)
        .unwrap_or_else(|| cex.detail.clone());
    let min = Counterexample { spec, mutation, detail, ..cex };
    let file = min.file_name();
    if cfg.write_files {
        let path = cfg.out_dir.join(&file);
        std::fs::write(&path, min.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
    }
    report.counterexamples.push(CexSummary {
        file,
        kind: min.kind.name().to_string(),
        case_seed: min.case_seed,
        detail: min.detail.clone(),
    });
    Ok(())
}

/// Replay a counterexample JSON (as written by `record_cex`): rebuild the
/// pair from its spec, re-apply the mutation, and report the verdict.
pub fn replay_counterexample(j: &Json) -> Result<String> {
    schema::check(j, "counterexample")?;
    let spec = ModelSpec::from_json(j.get("spec"))?;
    let mutation = match j.get("mutation") {
        Json::Null => None,
        m => Some(Mutation::from_json(m)?),
    };
    let icfg = InferConfig::default();
    let seed_str = j
        .get("case_seed")
        .as_str()
        .ok_or_else(|| anyhow!("counterexample missing 'case_seed'"))?;
    let seed = u64::from_str_radix(seed_str.trim_start_matches("0x"), 16)
        .map_err(|_| anyhow!("bad case_seed '{seed_str}'"))?;
    let (gs, gd, ri) = build_pair(&spec)?;
    match &mutation {
        None => match clean_outcome(&gs, &gd, &ri, seed, &icfg) {
            CleanOutcome::Verified => {
                Ok("clean pair verifies (disagreement not reproduced)".into())
            }
            CleanOutcome::FalseAlarm(d) => Ok(format!("reproduced false alarm: {d}")),
            CleanOutcome::CertFailure(d) => Ok(format!("reproduced certificate failure: {d}")),
            CleanOutcome::Inconclusive { reason, detail } => {
                Ok(format!("reproduced clean-pair inconclusive ({reason}): {detail}"))
            }
        },
        Some(m) => {
            let (gd_mut, m2) = apply_mutation_by_name(&gd, m.kind, &m.node_name)?;
            let out =
                classify_mutant(&gs, &gd, &ri, &gd_mut, &m2, spec.blocks.len(), seed, &icfg)?;
            Ok(format!("mutant outcome: {}", out.tag()))
        }
    }
}

/// Static-analysis-only replay of a counterexample/fixture JSON: rebuild
/// the pair (applying the recorded mutation when present) and run ShardFlow
/// on `G_d` — no saturation, no numerics. Returns a display name and the
/// lint report. Backs `graphguard lint --fixture`.
pub fn lint_counterexample(j: &Json) -> Result<(String, crate::analysis::LintReport)> {
    schema::check(j, "fixture")?;
    let spec = ModelSpec::from_json(j.get("spec"))?;
    let mutation = match j.get("mutation") {
        Json::Null => None,
        m => Some(Mutation::from_json(m)?),
    };
    let (_gs, gd, ri) = build_pair(&spec)?;
    let (gd, name) = match &mutation {
        None => (gd, format!("{} (clean)", spec.flavor.name())),
        Some(m) => {
            let (gd_mut, m2) = apply_mutation_by_name(&gd, m.kind, &m.node_name)?;
            (gd_mut, format!("{} + {}@{}", spec.flavor.name(), m2.kind.name(), m2.node_name))
        }
    };
    Ok((name, crate::analysis::analyze(&gd, Some(&ri))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::genmodel::{Block, Flavor, NormKind, UnaryKind};
    use crate::fuzz::mutate::MutKind;

    #[test]
    fn case_seed_is_stable_and_spread() {
        assert_eq!(case_seed(0, 1), case_seed(0, 1));
        assert_ne!(case_seed(0, 1), case_seed(0, 2));
        assert_ne!(case_seed(0, 1), case_seed(1, 1));
    }

    #[test]
    fn locus_region_rules() {
        assert!(locus_in_region("b2_mm", Some(1), 4));
        assert!(locus_in_region("b1_mm", Some(1), 4));
        assert!(!locus_in_region("b0_mm", Some(1), 4));
        // epilogue mutation (block == n_blocks) accepts the last real block
        assert!(locus_in_region("b3_act", Some(4), 4));
        assert!(!locus_in_region("x_r0", Some(1), 4));
    }

    #[test]
    fn known_mutant_is_killed_in_region() {
        let spec = crate::fuzz::genmodel::ModelSpec {
            seed: 2,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Sp,
            blocks: vec![Block::Unary(UnaryKind::Tanh), Block::Norm(NormKind::Softmax)],
        };
        let (gs, gd, ri) = build_pair(&spec).unwrap();
        let icfg = InferConfig::default();
        assert!(matches!(clean_outcome(&gs, &gd, &ri, 2, &icfg), CleanOutcome::Verified));
        let (gd_mut, m) =
            apply_mutation_by_name(&gd, MutKind::SoftmaxDimSwap, "b1_sm_r0").unwrap();
        let out = classify_mutant(&gs, &gd, &ri, &gd_mut, &m, 2, 2, &icfg).unwrap();
        assert_eq!(out, MutOutcome::KilledInRegion, "{out:?}");
    }
}
