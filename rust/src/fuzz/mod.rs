//! Bug-injection mutation fuzzer (differential soundness harness).
//!
//! The §6.2 case studies exercise six hand-written bugs; this subsystem
//! generates an unbounded adversarial test bed in the same spirit as the
//! systematically-injected faults runtime checkers are validated against:
//!
//! 1. [`genmodel`] — seeded random sequential models (matmul / elementwise
//!    / reduction / attention / MoE blocks) plus *correct* distributed
//!    variants composed from `crate::strategies` (DP replication, SP
//!    sequence sharding, TP weight sharding incl. the Fig-1 reduce-scatter
//!    form, PP stage splits with micro-batched send/recv boundaries —
//!    logical or buffer-lowered under a GPipe/1F1B/interleaved schedule —
//!    FSDP/ZeRO parameter sharding with pre-use all-gathers, and
//!    expert-parallel MoE with per-rank partial combines).
//! 2. [`mutate`] — 23 single-node bug operators drawn from the §6.2
//!    taxonomy and the PP/ZeRO/MoE/schedule wiring-bug families (wrong
//!    collective, dropped aggregation, shifted slice offsets, wrong chunk
//!    index, mis-scaled reductions, shard re-wiring, wrong-axis softmax,
//!    crossed or dropped stage boundaries, stale parameter shards,
//!    off-by-one micro-batch rescales, wrong-expert dispatch, dropped
//!    token combines, unnormalized gate weights, silent capacity
//!    truncation, stale buffer reuse, double-buffer slot swaps, and
//!    interleaved virtual-stage misbinding).
//! 3. [`oracle`] — runs the [`crate::verifier::Verifier`] on each (clean,
//!    mutant) pair
//!    and cross-checks against concrete execution: clean pairs must verify
//!    with a replaying numeric certificate, numerics-changing mutants must
//!    be rejected with an in-region localization, and any accepted graph's
//!    certificate must replay. Disagreements are minimized and dumped as
//!    replayable JSON counterexamples, byte-identical per seed.
//!
//! Campaigns are crash-safe: every completed seed is appended durably to
//! an on-disk [`journal`] (`journal.jsonl` in the `--out` directory), and
//! `graphguard fuzz --resume DIR` replays the journal and continues with
//! the remaining seeds, reproducing the byte-identical final report of an
//! uninterrupted run.
//!
//! CLI: `graphguard fuzz --seeds N --seed S [--ranks R] [--mutants M]
//! [--out DIR] [--flavor F]`, plus `--replay FILE` for counterexample
//! files and `--resume DIR` for interrupted campaigns.

pub mod genmodel;
pub mod journal;
pub mod mutate;
pub mod oracle;

pub use genmodel::{
    build_pair, sample_spec, sample_spec_for, Block, Flavor, ModelSpec, NormKind, UnaryKind,
};
pub use mutate::{
    applicable_sites, apply_mutation, apply_mutation_by_name, parse_block, MutKind, Mutation,
    Site, MUT_KINDS,
};
pub use journal::Journal;
pub use oracle::{
    lint_counterexample, replay_counterexample, resume_config, run_fuzz, FuzzConfig, FuzzReport,
    MutOutcome, OpStat,
};
