//! Seeded random model generation for the bug-injection fuzzer.
//!
//! A [`ModelSpec`] is a small, JSON-serializable description of a sequential
//! model (a chain of matmul / elementwise / reduction / attention blocks)
//! plus one distribution flavor. [`build_pair`] deterministically turns a
//! spec into `(G_s, G_d, R_i)` where `G_d` is a *correct* distributed
//! implementation built with the `crate::strategies` helpers:
//!
//! - [`Flavor::Dp`]  — single-program replicated capture: every input is
//!   replicated and every operator mirrored one-to-one.
//! - [`Flavor::Sp`]  — the activation is sharded along the sequence dim;
//!   weights are replicated; attention all-gathers K/V; RoPE slices its
//!   tables per rank; a final all-gather reassembles the output.
//! - [`Flavor::Tp`]  — activations stay full; Linear blocks column-shard
//!   the weight (gather on the hidden dim), MLP blocks use the Megatron
//!   column+row pair with an all-reduce, and `LinearRs` uses the Fig-1
//!   inner-split with reduce-scatter + all-gather.
//! - [`Flavor::Pp`]  — two pipeline stages with `ranks` micro-batches:
//!   the chain is cut in half, each micro-batch crosses the boundary
//!   through its own send/recv channel
//!   (`strategies::pipeline_stage_split`), and the outputs are
//!   re-concatenated. Attention blocks are excluded (they mix rows across
//!   micro-batches).
//! - [`Flavor::Fsdp`] — compute replicated 1:1, but every parameter is
//!   stored 1/R-sharded along its leading dim and all-gathered before use
//!   (`strategies::fsdp_shard_params`).
//! - [`Flavor::Moe`] — expert parallelism over [`Block::Moe`] blocks
//!   (`strategies::moe_from_seq`): compute mirrored 1:1, every `combine`
//!   split into per-rank partial combines over disjoint expert column
//!   slices of the router weights, merged by an all-reduce. Routing is
//!   data-dependent (top-1 gating over `2·ranks` experts); verification
//!   relies on the router-conditioned relation language and the `routing`
//!   lemma family.
//! - [`Flavor::PpSched`] — schedule-aware pipeline parallelism: the Pp
//!   construction (2 stages, here with `2·ranks` micro-batches; 2 virtual
//!   chunks per stage when interleaved) followed by the buffer-assignment
//!   lowering (`crate::schedule::lower_buffers`), so every send/recv
//!   carries a physical `(boundary, slot, epoch)` buffer tag sized to the
//!   GPipe / 1F1B / interleaved schedule's minimum safe pool depth.
//!
//! Every construction is covered by lemmas in `crate::lemmas`
//! (matmul block splits, unary/softmax/rmsnorm over concat, collective
//! desugaring, rope_seq_split), so clean pairs must verify — a clean pair
//! that fails refinement is a checker bug, which is exactly what the
//! oracle is hunting for.
//!
//! Naming contract (used for mutation localization): every `G_s` node in
//! block `i` is named `b{i}_<role>`, every `G_d` node `b{i}_<role>` (DP/TP
//! replicated nodes) or `b{i}_<role>_r{rank}`; the SP epilogue gather is
//! `b{n}_out` where `n == blocks.len()`.

use crate::ir::{DType, Graph, Op, TensorId};
use crate::relation::Relation;
use crate::strategies::{
    chunks, col_shard_weight, fsdp_from_seq, moe_from_seq, pipeline_stage_split,
    replicate_input_typed, row_shard_weight, shard_input_typed, stage_ends, RiBuilder,
};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    /// Replicated (data-parallel single-program capture).
    Dp,
    /// Sequence parallelism: activations sharded along dim 0.
    Sp,
    /// Tensor parallelism: weights sharded, activations full.
    Tp,
    /// Pipeline parallelism: 2 stages, `ranks` micro-batches, send/recv
    /// boundary channels (schedule-agnostic logical wiring).
    Pp,
    /// ZeRO-3/FSDP: parameters 1/R-sharded, all-gathered before use.
    Fsdp,
    /// Expert parallelism: per-rank partial combines over disjoint expert
    /// slices, all-reduced (router-conditioned MoE).
    Moe,
    /// Schedule-aware pipeline parallelism: 2 stages (× 2 virtual chunks
    /// when interleaved), `2·ranks` micro-batches, logical channels lowered
    /// onto physical activation buffers at the schedule's minimum safe pool
    /// depth (`crate::schedule::lower_buffers`).
    PpSched(crate::schedule::SchedKind),
}

impl Flavor {
    pub fn name(self) -> &'static str {
        use crate::schedule::SchedKind;
        match self {
            Flavor::Dp => "dp",
            Flavor::Sp => "sp",
            Flavor::Tp => "tp",
            Flavor::Pp => "pp",
            Flavor::Fsdp => "fsdp",
            Flavor::Moe => "moe",
            Flavor::PpSched(SchedKind::GPipe) => "pp_sched_gpipe",
            Flavor::PpSched(SchedKind::OneFOneB) => "pp_sched_1f1b",
            Flavor::PpSched(SchedKind::Interleaved) => "pp_sched_interleaved",
        }
    }
    pub fn parse(s: &str) -> Option<Flavor> {
        use crate::schedule::SchedKind;
        match s {
            "dp" => Some(Flavor::Dp),
            "sp" => Some(Flavor::Sp),
            "tp" => Some(Flavor::Tp),
            "pp" => Some(Flavor::Pp),
            "fsdp" => Some(Flavor::Fsdp),
            "moe" => Some(Flavor::Moe),
            "pp_sched_gpipe" => Some(Flavor::PpSched(SchedKind::GPipe)),
            "pp_sched_1f1b" => Some(Flavor::PpSched(SchedKind::OneFOneB)),
            "pp_sched_interleaved" => Some(Flavor::PpSched(SchedKind::Interleaved)),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryKind {
    Gelu,
    Tanh,
    Silu,
    Relu,
    Sigmoid,
}

pub const UNARY_KINDS: [UnaryKind; 5] =
    [UnaryKind::Gelu, UnaryKind::Tanh, UnaryKind::Silu, UnaryKind::Relu, UnaryKind::Sigmoid];

impl UnaryKind {
    pub fn op(self) -> Op {
        match self {
            UnaryKind::Gelu => Op::Gelu,
            UnaryKind::Tanh => Op::Tanh,
            UnaryKind::Silu => Op::Silu,
            UnaryKind::Relu => Op::Relu,
            UnaryKind::Sigmoid => Op::Sigmoid,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            UnaryKind::Gelu => "gelu",
            UnaryKind::Tanh => "tanh",
            UnaryKind::Silu => "silu",
            UnaryKind::Relu => "relu",
            UnaryKind::Sigmoid => "sigmoid",
        }
    }
    pub fn parse(s: &str) -> Option<UnaryKind> {
        UNARY_KINDS.iter().copied().find(|k| k.name() == s)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    /// Row-wise softmax along dim 1.
    Softmax,
    /// RMSNorm over the hidden dim with a learned weight.
    RmsNorm,
}

impl NormKind {
    pub fn name(self) -> &'static str {
        match self {
            NormKind::Softmax => "softmax",
            NormKind::RmsNorm => "rmsnorm",
        }
    }
    pub fn parse(s: &str) -> Option<NormKind> {
        match s {
            "softmax" => Some(NormKind::Softmax),
            "rmsnorm" => Some(NormKind::RmsNorm),
            _ => None,
        }
    }
}

/// One shape-preserving `[S, H] -> [S, H]` block of the generated chain.
#[derive(Debug, Clone, PartialEq)]
pub enum Block {
    Unary(UnaryKind),
    Scale(f64),
    /// `x @ W` with `W: [H, H]`.
    Linear,
    /// `x @ W` distributed as inner-split + reduce-scatter + all-gather
    /// under TP (plain Linear under other flavors).
    LinearRs,
    /// `act(x @ W1) @ W2` — the Megatron column/row pair under TP.
    Mlp(UnaryKind),
    Norm(NormKind),
    /// Rotary embedding with `cos/sin: [S, H]` table inputs.
    Rope,
    /// Single-head self-attention (q/k/v projections, scaled scores,
    /// softmax, value mix).
    Attention,
    /// Switch-style top-1 MoE over `2·ranks` experts: router softmax,
    /// `topk` mask, normalized gate weights, per-expert dispatch + FFN,
    /// router-weighted combine. Only valid under [`Flavor::Moe`].
    Moe(UnaryKind),
}

impl Block {
    pub fn kind_name(&self) -> &'static str {
        match self {
            Block::Unary(_) => "unary",
            Block::Scale(_) => "scale",
            Block::Linear => "linear",
            Block::LinearRs => "linear_rs",
            Block::Mlp(_) => "mlp",
            Block::Norm(_) => "norm",
            Block::Rope => "rope",
            Block::Attention => "attention",
            Block::Moe(_) => "moe",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", Json::str(self.kind_name()))];
        match self {
            Block::Unary(k) | Block::Mlp(k) | Block::Moe(k) => {
                pairs.push(("op", Json::str(k.name())))
            }
            Block::Scale(c) => pairs.push(("c", Json::num(*c))),
            Block::Norm(n) => pairs.push(("norm", Json::str(n.name()))),
            _ => {}
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Block> {
        let kind = j.get("kind").as_str().ok_or_else(|| anyhow!("block missing 'kind'"))?;
        let unary = || -> Result<UnaryKind> {
            let s = j.get("op").as_str().ok_or_else(|| anyhow!("block missing 'op'"))?;
            UnaryKind::parse(s).ok_or_else(|| anyhow!("unknown unary '{s}'"))
        };
        Ok(match kind {
            "unary" => Block::Unary(unary()?),
            "scale" => Block::Scale(
                j.get("c").as_f64().ok_or_else(|| anyhow!("scale block missing 'c'"))?,
            ),
            "linear" => Block::Linear,
            "linear_rs" => Block::LinearRs,
            "mlp" => Block::Mlp(unary()?),
            "norm" => {
                let s = j.get("norm").as_str().ok_or_else(|| anyhow!("norm missing 'norm'"))?;
                Block::Norm(NormKind::parse(s).ok_or_else(|| anyhow!("unknown norm '{s}'"))?)
            }
            "rope" => Block::Rope,
            "attention" => Block::Attention,
            "moe" => Block::Moe(unary()?),
            other => bail!("unknown block kind '{other}'"),
        })
    }
}

/// Deterministic description of one fuzz model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Seed the spec was sampled from (provenance only — `build_pair` uses
    /// no randomness).
    pub seed: u64,
    pub ranks: usize,
    pub seq: i64,
    pub hidden: i64,
    pub flavor: Flavor,
    pub blocks: Vec<Block>,
}

impl ModelSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::str(format!("{:#018x}", self.seed))),
            ("ranks", Json::num(self.ranks as f64)),
            ("seq", Json::num(self.seq as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("flavor", Json::str(self.flavor.name())),
            ("blocks", Json::Arr(self.blocks.iter().map(Block::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelSpec> {
        let seed_str = j.get("seed").as_str().ok_or_else(|| anyhow!("spec missing 'seed'"))?;
        let seed = u64::from_str_radix(seed_str.trim_start_matches("0x"), 16)
            .map_err(|_| anyhow!("bad spec seed '{seed_str}'"))?;
        let flavor_str =
            j.get("flavor").as_str().ok_or_else(|| anyhow!("spec missing 'flavor'"))?;
        let blocks = j
            .get("blocks")
            .as_arr()
            .ok_or_else(|| anyhow!("spec missing 'blocks'"))?
            .iter()
            .map(Block::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelSpec {
            seed,
            ranks: j.get("ranks").as_usize().ok_or_else(|| anyhow!("spec missing 'ranks'"))?,
            seq: j.get("seq").as_i64().ok_or_else(|| anyhow!("spec missing 'seq'"))?,
            hidden: j.get("hidden").as_i64().ok_or_else(|| anyhow!("spec missing 'hidden'"))?,
            flavor: Flavor::parse(flavor_str)
                .ok_or_else(|| anyhow!("unknown flavor '{flavor_str}'"))?,
            blocks,
        })
    }

    /// The concrete schedule of a [`Flavor::PpSched`] spec: 2 physical
    /// stages, `2·ranks` micro-batches, 2 virtual chunks per stage when
    /// interleaved. `None` for every other flavor.
    pub fn sched(&self) -> Option<crate::schedule::Schedule> {
        use crate::schedule::{SchedKind, Schedule};
        let micro = 2 * self.ranks;
        match self.flavor {
            Flavor::PpSched(SchedKind::GPipe) => Some(Schedule::gpipe(2, micro)),
            Flavor::PpSched(SchedKind::OneFOneB) => Some(Schedule::one_f_one_b(2, micro)),
            Flavor::PpSched(SchedKind::Interleaved) => Some(Schedule::interleaved(2, micro, 2)),
            _ => None,
        }
    }

    /// Basic well-formedness used before building (also by replay).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.ranks >= 1, "ranks must be >= 1");
        anyhow::ensure!(!self.blocks.is_empty(), "spec needs at least one block");
        anyhow::ensure!(
            self.seq >= 1 && self.seq % self.ranks as i64 == 0,
            "seq {} must be a positive multiple of ranks {}",
            self.seq,
            self.ranks
        );
        anyhow::ensure!(
            self.hidden >= 2 && self.hidden % 2 == 0 && self.hidden % self.ranks as i64 == 0,
            "hidden {} must be even and divisible by ranks {}",
            self.hidden,
            self.ranks
        );
        if matches!(self.flavor, Flavor::Pp | Flavor::PpSched(_)) {
            anyhow::ensure!(
                !self.blocks.contains(&Block::Attention),
                "pipeline flavors cannot micro-batch attention (rows mix across micro-batches)"
            );
        }
        if self.flavor == Flavor::Pp {
            anyhow::ensure!(
                self.blocks.len() >= 2,
                "pp flavor needs at least 2 blocks (one per stage)"
            );
        }
        if let Some(sched) = self.sched() {
            sched.validate()?;
            anyhow::ensure!(
                self.blocks.len() >= sched.chunks(),
                "pp_sched flavor needs >= {} blocks (one per pipeline chunk), got {}",
                sched.chunks(),
                self.blocks.len()
            );
            anyhow::ensure!(
                self.seq % sched.micro as i64 == 0,
                "seq {} must divide into {} micro-batches",
                self.seq,
                sched.micro
            );
        }
        let has_moe = self.blocks.iter().any(|b| matches!(b, Block::Moe(_)));
        if has_moe {
            anyhow::ensure!(
                self.flavor == Flavor::Moe,
                "moe blocks are only distributable under the moe flavor"
            );
        }
        if self.flavor == Flavor::Moe {
            anyhow::ensure!(has_moe, "moe flavor needs at least one moe block");
            anyhow::ensure!(self.ranks >= 2, "expert parallelism needs at least 2 ranks");
        }
        Ok(())
    }

    /// Experts of every [`Block::Moe`] in this spec: two per rank, so the
    /// expert count always divides the parallel degree.
    pub fn moe_experts(&self) -> i64 {
        2 * self.ranks as i64
    }
}

/// Attention score scale — shared by the G_s and G_d builders so the
/// `Scale` attribute matches bit-for-bit.
fn attn_scale(hidden: i64) -> f64 {
    1.0 / (hidden as f64).sqrt()
}

const SCALE_CHOICES: [f64; 4] = [0.5, 2.0, 0.25, 1.5];

/// Sample a random spec. All shape parameters are kept divisible so every
/// strategy helper applies; block kinds are filtered per flavor so the
/// clean distributed variant is provable by the standard lemma library.
pub fn sample_spec(rng: &mut Rng, ranks: usize, seed: u64) -> ModelSpec {
    sample_spec_for(rng, ranks, seed, None)
}

/// [`sample_spec`] with an optional forced flavor (single-flavor fuzz
/// campaigns — `graphguard fuzz --flavor`). The rng stream is consumed
/// exactly as in the unforced sampler, then the flavor is overridden —
/// forcing never changes which blocks/shapes a seed draws beyond the
/// flavor's own constraints. Degenerate combinations fall back the same way
/// sampling does (EP at one rank becomes FSDP) — except a *forced*
/// interleaved campaign, where a chain too short for the 4-chunk layout is
/// padded with Linear blocks rather than silently demoted to 1F1B, so the
/// dedicated nightly run keeps every seed interleaved.
pub fn sample_spec_for(
    rng: &mut Rng,
    ranks: usize,
    seed: u64,
    forced: Option<Flavor>,
) -> ModelSpec {
    use crate::schedule::SchedKind;
    let mut seq = ranks as i64 * (1 + rng.below(3) as i64); // R, 2R or 3R rows
    let hidden = ranks as i64 * 2 * (1 + rng.below(2) as i64); // even, % ranks == 0
    let mut flavor = match rng.below(9) {
        0 => Flavor::Dp,
        1 | 2 => Flavor::Sp,
        3 | 4 => Flavor::Tp,
        5 => Flavor::Pp,
        6 => Flavor::Fsdp,
        // EP needs >= 2 ranks to place experts on; degenerate degrees fall
        // back to FSDP so every sampled spec stays buildable
        7 if ranks >= 2 => Flavor::Moe,
        7 => Flavor::Fsdp,
        _ => Flavor::PpSched(
            [SchedKind::GPipe, SchedKind::OneFOneB, SchedKind::Interleaved]
                [rng.below(3) as usize],
        ),
    };
    if let Some(f) = forced {
        flavor = match f {
            Flavor::Moe if ranks < 2 => Flavor::Fsdp,
            other => other,
        };
    }
    let n_blocks = 2 + rng.below(4) as usize; // 2..=5
    let forced_intlv = forced == Some(Flavor::PpSched(SchedKind::Interleaved));
    if flavor == Flavor::PpSched(SchedKind::Interleaved) && n_blocks < 4 && !forced_intlv {
        // 2 stages x 2 virtual chunks need 4 blocks; shorter sampled chains
        // run the plain 1F1B schedule instead. A *forced* interleaved
        // campaign must not silently halve its coverage this way — it pads
        // the chain below instead.
        flavor = Flavor::PpSched(SchedKind::OneFOneB);
    }
    let mut blocks = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let pick = rng.below(8);
        let block = match pick {
            0 => Block::Unary(UNARY_KINDS[rng.below(UNARY_KINDS.len() as u64) as usize]),
            1 => Block::Scale(SCALE_CHOICES[rng.below(SCALE_CHOICES.len() as u64) as usize]),
            2 => Block::Linear,
            3 => {
                // the reduce-scatter construction only exists under TP;
                // elsewhere it degenerates to a plain Linear anyway
                if flavor == Flavor::Tp {
                    Block::LinearRs
                } else {
                    Block::Linear
                }
            }
            4 => {
                let k = UNARY_KINDS[rng.below(UNARY_KINDS.len() as u64) as usize];
                // under EP the FFN block is the expert-parallel MoE block
                if flavor == Flavor::Moe {
                    Block::Moe(k)
                } else {
                    Block::Mlp(k)
                }
            }
            5 => Block::Norm(if rng.below(2) == 0 { NormKind::Softmax } else { NormKind::RmsNorm }),
            6 => Block::Rope,
            _ => {
                // micro-batching cannot split attention rows — the pipeline
                // flavors swap it for the (still weight-bearing) Linear block
                if matches!(flavor, Flavor::Pp | Flavor::PpSched(_)) {
                    Block::Linear
                } else {
                    Block::Attention
                }
            }
        };
        blocks.push(block);
    }
    if flavor == Flavor::Moe && !blocks.iter().any(|b| matches!(b, Block::Moe(_))) {
        // the EP flavor must expert-shard something: force one MoE block
        let last = blocks.len() - 1;
        blocks[last] = Block::Moe(UnaryKind::Silu);
    }
    if forced_intlv {
        // dedicated interleaved campaigns keep every seed interleaved:
        // short chains are padded to the 4 blocks the 2x2 layout needs
        while blocks.len() < 4 {
            blocks.push(Block::Linear);
        }
    }
    if matches!(flavor, Flavor::PpSched(_)) {
        // 2·ranks micro-batches at 2 rows each — divisible for every kind
        // (and micro % stages == 0, as interleaving requires)
        seq = 4 * ranks as i64;
    }
    ModelSpec { seed, ranks, seq, hidden, flavor, blocks }
}

/// Build the sequential graph `G_s` for a spec; also returns the activation
/// tensor at the end of every block (the PP flavor cuts at one of these).
fn build_gs(spec: &ModelSpec) -> (Graph, Vec<TensorId>) {
    let (s, h) = (spec.seq, spec.hidden);
    let mut gs = Graph::new(format!("fuzz_gs_{:016x}", spec.seed));
    let mut cur = gs.input("x", vec![s, h]);
    let mut block_ends = Vec::with_capacity(spec.blocks.len());
    for (i, block) in spec.blocks.iter().enumerate() {
        match block {
            Block::Unary(k) => {
                cur = gs.op(&format!("b{i}_act"), k.op(), vec![cur]);
            }
            Block::Scale(c) => {
                cur = gs.scale(&format!("b{i}_scale"), cur, *c);
            }
            Block::Linear | Block::LinearRs => {
                let w = gs.input(&format!("w{i}"), vec![h, h]);
                cur = gs.matmul(&format!("b{i}_mm"), cur, w);
            }
            Block::Mlp(k) => {
                let w1 = gs.input(&format!("w{i}a"), vec![h, h]);
                let w2 = gs.input(&format!("w{i}b"), vec![h, h]);
                let hid = gs.matmul(&format!("b{i}_mm1"), cur, w1);
                let a = gs.op(&format!("b{i}_mlpact"), k.op(), vec![hid]);
                cur = gs.matmul(&format!("b{i}_mm2"), a, w2);
            }
            Block::Norm(NormKind::Softmax) => {
                cur = gs.softmax(&format!("b{i}_sm"), cur, 1);
            }
            Block::Norm(NormKind::RmsNorm) => {
                let g = gs.input(&format!("g{i}"), vec![h]);
                cur = gs.op(&format!("b{i}_rn"), Op::RmsNorm { eps: c_eps() }, vec![cur, g]);
            }
            Block::Rope => {
                let cos = gs.input(&format!("cos{i}"), vec![s, h]);
                let sin = gs.input(&format!("sin{i}"), vec![s, h]);
                cur = gs.op(&format!("b{i}_rope"), Op::Rope, vec![cur, cos, sin]);
            }
            Block::Attention => {
                let wq = gs.input(&format!("wq{i}"), vec![h, h]);
                let wk = gs.input(&format!("wk{i}"), vec![h, h]);
                let wv = gs.input(&format!("wv{i}"), vec![h, h]);
                let q = gs.matmul(&format!("b{i}_q"), cur, wq);
                let k = gs.matmul(&format!("b{i}_k"), cur, wk);
                let v = gs.matmul(&format!("b{i}_v"), cur, wv);
                let kt = gs.transpose(&format!("b{i}_kt"), k, vec![1, 0]);
                let sc = gs.matmul(&format!("b{i}_sc"), q, kt);
                let ss = gs.scale(&format!("b{i}_ss"), sc, attn_scale(h));
                let p = gs.softmax(&format!("b{i}_p"), ss, 1);
                cur = gs.matmul(&format!("b{i}_o"), p, v);
            }
            Block::Moe(k) => {
                // switch-style top-1 MoE: softmax router, top-k mask,
                // normalized gate weights, per-expert dispatch + FFN,
                // router-weighted combine (capacity = full sequence)
                let e = spec.moe_experts();
                let wg = gs.input(&format!("wg{i}"), vec![h, e]);
                let scores = gs.matmul(&format!("b{i}_router"), cur, wg);
                let probs = gs.softmax(&format!("b{i}_probs"), scores, 1);
                let mask = gs.topk(&format!("b{i}_mask"), probs, 1);
                let wts = gs.mul2(&format!("b{i}_wts"), mask, probs);
                let denom =
                    gs.op(&format!("b{i}_denom"), Op::ReduceSum { dim: 1, keepdim: true }, vec![wts]);
                let gates = gs.op(&format!("b{i}_gates"), Op::Div, vec![wts, denom]);
                let mut ys = Vec::with_capacity(e as usize);
                for ex in 0..e as usize {
                    let w1 = gs.input(&format!("w{i}e{ex}a"), vec![h, h]);
                    let w2 = gs.input(&format!("w{i}e{ex}b"), vec![h, h]);
                    let d = gs.dispatch(&format!("b{i}_disp{ex}"), cur, mask, ex, s as usize);
                    let h1 = gs.matmul(&format!("b{i}_e{ex}_h1"), d, w1);
                    let a = gs.op(&format!("b{i}_e{ex}_act"), k.op(), vec![h1]);
                    ys.push(gs.matmul(&format!("b{i}_e{ex}_h2"), a, w2));
                }
                cur = gs.combine(&format!("b{i}_moe"), gates, ys);
            }
        }
        block_ends.push(cur);
    }
    gs.mark_output(cur);
    (gs, block_ends)
}

/// Shared RMSNorm epsilon so G_s and G_d attributes match bit-for-bit.
fn c_eps() -> crate::ir::FBits {
    crate::ir::FBits::new(1e-5)
}

/// Build `(G_s, G_d, R_i)` for a spec. Deterministic: no randomness, no
/// iteration over hash maps.
pub fn build_pair(spec: &ModelSpec) -> Result<(Graph, Graph, Relation)> {
    spec.validate()?;
    let (gs, block_ends) = build_gs(spec);
    let (s, h, r) = (spec.seq, spec.hidden, spec.ranks);

    if spec.flavor == Flavor::Pp {
        // 2 stages, boundary placed by the same helper the model-zoo PP
        // builders use, `ranks` micro-batches
        let cut_blk = stage_ends(spec.blocks.len(), 2)[0] - 1;
        let cut_node = gs
            .tensor(block_ends[cut_blk])
            .producer
            .ok_or_else(|| anyhow!("stage cut fell on a graph input"))?;
        let (gd, ri) = pipeline_stage_split(
            &gs,
            &[cut_node],
            r,
            &format!("b{}_out", spec.blocks.len()),
        )?;
        gs.validate()?;
        return Ok((gs, gd, ri));
    }

    if let Some(sched) = spec.sched() {
        // schedule-aware PP: cut at the chunk boundaries the same helper
        // the model-zoo builders use, split into sched.micro micro-batches,
        // then lower the logical channels onto physical buffers at the
        // schedule's minimum safe pool depth (buffer tags on every
        // send/recv; an undersized pool would be rejected at construction)
        let cut_blks = stage_ends(spec.blocks.len(), sched.chunks());
        let cuts = cut_blks
            .iter()
            .map(|&e| {
                gs.tensor(block_ends[e - 1])
                    .producer
                    .ok_or_else(|| anyhow!("stage cut fell on a graph input"))
            })
            .collect::<Result<Vec<_>>>()?;
        let depth = sched.min_safe_depth()?;
        let (gd, ri) = crate::strategies::pipeline_stage_split_scheduled(
            &gs,
            &cuts,
            &format!("b{}_out", spec.blocks.len()),
            &sched,
            depth,
        )?;
        gs.validate()?;
        return Ok((gs, gd, ri));
    }

    if spec.flavor == Flavor::Moe {
        // expert parallelism: compute mirrored 1:1, combines split into
        // per-rank partial combines over disjoint expert slices + all-reduce
        let (gd, ri) = moe_from_seq(&gs, r)?;
        gs.validate()?;
        return Ok((gs, gd, ri));
    }

    if spec.flavor == Flavor::Fsdp {
        // params are the w*/g* inputs; x and the rope cos/sin tables are
        // activations/buffers. Gather nodes are named b{i}_{name}_ag (block
        // index from the digits in the param name) so the oracle's locus
        // rules see the owning block.
        let (gd, ri) = fsdp_from_seq(
            &gs,
            r,
            &|name| name.starts_with('w') || name.starts_with('g'),
            &|name| {
                let block: String = name.chars().filter(|c| c.is_ascii_digit()).collect();
                format!("b{block}_{name}_ag")
            },
        )?;
        gs.validate()?;
        return Ok((gs, gd, ri));
    }

    let mut gd = Graph::new(format!("fuzz_gd_{}_{:016x}", spec.flavor.name(), spec.seed));
    let mut ri = RiBuilder::new();

    match spec.flavor {
        Flavor::Pp | Flavor::Fsdp | Flavor::Moe | Flavor::PpSched(_) => {
            unreachable!("handled above")
        }
        Flavor::Dp => {
            let mut cur = replicate_input_typed(&mut gd, &mut ri, "x", &[s, h], DType::F32);
            for (i, block) in spec.blocks.iter().enumerate() {
                cur = build_block_replicated(&mut gd, &mut ri, block, i, cur, s, h)?;
            }
            gd.mark_output(cur);
        }
        Flavor::Sp => {
            let mut shards =
                shard_input_typed(&mut gd, &mut ri, "x", &[s, h], 0, r, DType::F32)?;
            for (i, block) in spec.blocks.iter().enumerate() {
                shards = build_block_sp(&mut gd, &mut ri, block, i, shards, s, h)?;
            }
            let out = gd.all_gather(&format!("b{}_out", spec.blocks.len()), shards, 0);
            gd.mark_output(out);
        }
        Flavor::Tp => {
            let mut cur = replicate_input_typed(&mut gd, &mut ri, "x", &[s, h], DType::F32);
            for (i, block) in spec.blocks.iter().enumerate() {
                cur = build_block_tp(&mut gd, &mut ri, block, i, cur, s, h, r)?;
            }
            gd.mark_output(cur);
        }
    }

    let ri = ri.finish(&gs, &gd)?;
    gd.validate()?;
    gs.validate()?;
    Ok((gs, gd, ri))
}

/// DP (and the replicated parts of TP): mirror the sequential block 1:1.
fn build_block_replicated(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    block: &Block,
    i: usize,
    cur: TensorId,
    s: i64,
    h: i64,
) -> Result<TensorId> {
    Ok(match block {
        Block::Unary(k) => gd.op(&format!("b{i}_act"), k.op(), vec![cur]),
        Block::Scale(c) => gd.scale(&format!("b{i}_scale"), cur, *c),
        Block::Linear | Block::LinearRs => {
            let w = replicate_input_typed(gd, ri, &format!("w{i}"), &[h, h], DType::F32);
            gd.matmul(&format!("b{i}_mm"), cur, w)
        }
        Block::Mlp(k) => {
            let w1 = replicate_input_typed(gd, ri, &format!("w{i}a"), &[h, h], DType::F32);
            let w2 = replicate_input_typed(gd, ri, &format!("w{i}b"), &[h, h], DType::F32);
            let hid = gd.matmul(&format!("b{i}_mm1"), cur, w1);
            let a = gd.op(&format!("b{i}_mlpact"), k.op(), vec![hid]);
            gd.matmul(&format!("b{i}_mm2"), a, w2)
        }
        Block::Norm(NormKind::Softmax) => gd.softmax(&format!("b{i}_sm"), cur, 1),
        Block::Norm(NormKind::RmsNorm) => {
            let g = replicate_input_typed(gd, ri, &format!("g{i}"), &[h], DType::F32);
            gd.op(&format!("b{i}_rn"), Op::RmsNorm { eps: c_eps() }, vec![cur, g])
        }
        Block::Rope => {
            let cos = replicate_input_typed(gd, ri, &format!("cos{i}"), &[s, h], DType::F32);
            let sin = replicate_input_typed(gd, ri, &format!("sin{i}"), &[s, h], DType::F32);
            gd.op(&format!("b{i}_rope"), Op::Rope, vec![cur, cos, sin])
        }
        Block::Attention => {
            let wq = replicate_input_typed(gd, ri, &format!("wq{i}"), &[h, h], DType::F32);
            let wk = replicate_input_typed(gd, ri, &format!("wk{i}"), &[h, h], DType::F32);
            let wv = replicate_input_typed(gd, ri, &format!("wv{i}"), &[h, h], DType::F32);
            let q = gd.matmul(&format!("b{i}_q"), cur, wq);
            let k = gd.matmul(&format!("b{i}_k"), cur, wk);
            let v = gd.matmul(&format!("b{i}_v"), cur, wv);
            let kt = gd.transpose(&format!("b{i}_kt"), k, vec![1, 0]);
            let sc = gd.matmul(&format!("b{i}_sc"), q, kt);
            let ss = gd.scale(&format!("b{i}_ss"), sc, attn_scale(h));
            let p = gd.softmax(&format!("b{i}_p"), ss, 1);
            gd.matmul(&format!("b{i}_o"), p, v)
        }
        // validate() restricts Moe blocks to the Moe flavor, which never
        // reaches the per-block builders (moe_from_seq mirrors whole graphs)
        Block::Moe(_) => bail!("moe blocks only distribute under the moe flavor"),
    })
}

/// SP: every shard is `[S/R, H]`; weights replicated; attention gathers K/V.
fn build_block_sp(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    block: &Block,
    i: usize,
    shards: Vec<TensorId>,
    s: i64,
    h: i64,
) -> Result<Vec<TensorId>> {
    let r = shards.len();
    Ok(match block {
        Block::Unary(k) => shards
            .iter()
            .enumerate()
            .map(|(rk, &x)| gd.op(&format!("b{i}_act_r{rk}"), k.op(), vec![x]))
            .collect(),
        Block::Scale(c) => shards
            .iter()
            .enumerate()
            .map(|(rk, &x)| gd.scale(&format!("b{i}_scale_r{rk}"), x, *c))
            .collect(),
        Block::Linear | Block::LinearRs => {
            let w = replicate_input_typed(gd, ri, &format!("w{i}"), &[h, h], DType::F32);
            shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| gd.matmul(&format!("b{i}_mm_r{rk}"), x, w))
                .collect()
        }
        Block::Mlp(k) => {
            let w1 = replicate_input_typed(gd, ri, &format!("w{i}a"), &[h, h], DType::F32);
            let w2 = replicate_input_typed(gd, ri, &format!("w{i}b"), &[h, h], DType::F32);
            shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| {
                    let hid = gd.matmul(&format!("b{i}_mm1_r{rk}"), x, w1);
                    let a = gd.op(&format!("b{i}_mlpact_r{rk}"), k.op(), vec![hid]);
                    gd.matmul(&format!("b{i}_mm2_r{rk}"), a, w2)
                })
                .collect()
        }
        Block::Norm(NormKind::Softmax) => shards
            .iter()
            .enumerate()
            .map(|(rk, &x)| gd.softmax(&format!("b{i}_sm_r{rk}"), x, 1))
            .collect(),
        Block::Norm(NormKind::RmsNorm) => {
            let g = replicate_input_typed(gd, ri, &format!("g{i}"), &[h], DType::F32);
            shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| {
                    gd.op(&format!("b{i}_rn_r{rk}"), Op::RmsNorm { eps: c_eps() }, vec![x, g])
                })
                .collect()
        }
        Block::Rope => {
            let cos = replicate_input_typed(gd, ri, &format!("cos{i}"), &[s, h], DType::F32);
            let sin = replicate_input_typed(gd, ri, &format!("sin{i}"), &[s, h], DType::F32);
            let offs = chunks(s, r);
            shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| {
                    let (lo, hi) = offs[rk];
                    let cs = gd.slice(&format!("b{i}_cos_r{rk}"), cos, 0, lo, hi);
                    let sn = gd.slice(&format!("b{i}_sin_r{rk}"), sin, 0, lo, hi);
                    gd.op(&format!("b{i}_rope_r{rk}"), Op::Rope, vec![x, cs, sn])
                })
                .collect()
        }
        Block::Attention => {
            let wq = replicate_input_typed(gd, ri, &format!("wq{i}"), &[h, h], DType::F32);
            let wk = replicate_input_typed(gd, ri, &format!("wk{i}"), &[h, h], DType::F32);
            let wv = replicate_input_typed(gd, ri, &format!("wv{i}"), &[h, h], DType::F32);
            let qs: Vec<TensorId> = shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| gd.matmul(&format!("b{i}_q_r{rk}"), x, wq))
                .collect();
            let ks: Vec<TensorId> = shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| gd.matmul(&format!("b{i}_k_r{rk}"), x, wk))
                .collect();
            let vs: Vec<TensorId> = shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| gd.matmul(&format!("b{i}_v_r{rk}"), x, wv))
                .collect();
            let k_full = gd.all_gather(&format!("b{i}_kag"), ks, 0);
            let v_full = gd.all_gather(&format!("b{i}_vag"), vs, 0);
            let kt = gd.transpose(&format!("b{i}_kt"), k_full, vec![1, 0]);
            qs.iter()
                .enumerate()
                .map(|(rk, &q)| {
                    let sc = gd.matmul(&format!("b{i}_sc_r{rk}"), q, kt);
                    let ss = gd.scale(&format!("b{i}_ss_r{rk}"), sc, attn_scale(h));
                    let p = gd.softmax(&format!("b{i}_p_r{rk}"), ss, 1);
                    gd.matmul(&format!("b{i}_o_r{rk}"), p, v_full)
                })
                .collect()
        }
        // see build_block_replicated: unreachable by validate()
        Block::Moe(_) => bail!("moe blocks only distribute under the moe flavor"),
    })
}

/// TP: the activation stays full between blocks; Linear/Mlp/LinearRs are
/// weight-sharded, everything else is replicated compute.
#[allow(clippy::too_many_arguments)]
fn build_block_tp(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    block: &Block,
    i: usize,
    cur: TensorId,
    s: i64,
    h: i64,
    r: usize,
) -> Result<TensorId> {
    Ok(match block {
        Block::Linear => {
            // Megatron column-parallel linear: W = concat(W_r; dim 1)
            let ws = col_shard_weight(gd, ri, &format!("w{i}"), &[h, h], r)?;
            let parts: Vec<TensorId> = ws
                .iter()
                .enumerate()
                .map(|(rk, &w)| gd.matmul(&format!("b{i}_mm_r{rk}"), cur, w))
                .collect();
            gd.all_gather(&format!("b{i}_ag"), parts, 1)
        }
        Block::LinearRs => {
            // Fig-1 inner split: slice x on the hidden dim, row-shard W,
            // reduce-scatter the partial sums, gather the row chunks.
            let ws = row_shard_weight(gd, ri, &format!("w{i}"), &[h, h], r)?;
            let offs = chunks(h, r);
            let parts: Vec<TensorId> = ws
                .iter()
                .enumerate()
                .map(|(rk, &w)| {
                    let (lo, hi) = offs[rk];
                    let xs = gd.slice(&format!("b{i}_xs_r{rk}"), cur, 1, lo, hi);
                    gd.matmul(&format!("b{i}_mm_r{rk}"), xs, w)
                })
                .collect();
            let scats: Vec<TensorId> = (0..r)
                .map(|rk| {
                    gd.reduce_scatter(&format!("b{i}_rs_r{rk}"), parts.clone(), 0, rk)
                })
                .collect();
            gd.all_gather(&format!("b{i}_ag"), scats, 0)
        }
        Block::Mlp(k) => {
            // column-parallel W1, row-parallel W2, all-reduce the partials
            let w1s = col_shard_weight(gd, ri, &format!("w{i}a"), &[h, h], r)?;
            let w2s = row_shard_weight(gd, ri, &format!("w{i}b"), &[h, h], r)?;
            let parts: Vec<TensorId> = w1s
                .iter()
                .zip(&w2s)
                .enumerate()
                .map(|(rk, (&w1, &w2))| {
                    let hid = gd.matmul(&format!("b{i}_mm1_r{rk}"), cur, w1);
                    let a = gd.op(&format!("b{i}_mlpact_r{rk}"), k.op(), vec![hid]);
                    gd.matmul(&format!("b{i}_mm2_r{rk}"), a, w2)
                })
                .collect();
            gd.all_reduce(&format!("b{i}_ar"), parts)
        }
        other => build_block_replicated(gd, ri, other, i, cur, s, h)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        let mut rng = Rng::new(7);
        for case in 0..16u64 {
            let spec = sample_spec(&mut rng, if case % 4 == 0 { 4 } else { 2 }, case);
            let j = spec.to_json();
            let back = ModelSpec::from_json(&j).unwrap();
            assert_eq!(spec, back, "roundtrip {j:?}");
        }
    }

    #[test]
    fn sampled_specs_build_and_validate() {
        let mut rng = Rng::new(42);
        for case in 0..12u64 {
            let spec = sample_spec(&mut rng, 2, case);
            let (gs, gd, ri) = build_pair(&spec).unwrap_or_else(|e| {
                panic!("spec {:?} failed to build: {e:#}", spec.to_json().to_string())
            });
            gs.validate().unwrap();
            gd.validate().unwrap();
            ri.validate_shapes(&gs, &gd).unwrap();
            assert_eq!(gs.outputs.len(), 1);
            assert_eq!(gd.outputs.len(), 1);
            assert_eq!(gs.shape(gs.outputs[0]), &[spec.seq, spec.hidden]);
        }
    }

    #[test]
    fn deterministic_build() {
        let mut rng = Rng::new(5);
        let spec = sample_spec(&mut rng, 2, 5);
        let (gs1, gd1, _) = build_pair(&spec).unwrap();
        let (gs2, gd2, _) = build_pair(&spec).unwrap();
        assert_eq!(
            crate::ir::json_io::to_json(&gs1).to_string(),
            crate::ir::json_io::to_json(&gs2).to_string()
        );
        assert_eq!(
            crate::ir::json_io::to_json(&gd1).to_string(),
            crate::ir::json_io::to_json(&gd2).to_string()
        );
    }

    #[test]
    fn sampled_specs_cover_all_flavors() {
        let mut rng = Rng::new(3);
        let mut seen = std::collections::BTreeSet::new();
        for case in 0..64u64 {
            let spec = sample_spec(&mut rng, 2, case);
            seen.insert(spec.flavor.name());
            let (gs, gd, ri) = build_pair(&spec).unwrap_or_else(|e| {
                panic!("spec {} failed to build: {e:#}", spec.to_json().to_string())
            });
            gs.validate().unwrap();
            gd.validate().unwrap();
            ri.validate_shapes(&gs, &gd).unwrap();
        }
        for f in [
            "dp",
            "sp",
            "tp",
            "pp",
            "fsdp",
            "moe",
            "pp_sched_gpipe",
            "pp_sched_1f1b",
            "pp_sched_interleaved",
        ] {
            assert!(seen.contains(f), "sampler never produced flavor {f}: {seen:?}");
        }
    }

    #[test]
    fn degenerate_single_rank_sampling_never_draws_moe() {
        // EP needs >= 2 ranks; at ranks=1 the sampler must fall back so
        // every sampled spec stays buildable (a single unbuildable spec
        // would abort a whole `fuzz --ranks 1` campaign)
        let mut rng = Rng::new(9);
        for case in 0..32u64 {
            let spec = sample_spec(&mut rng, 1, case);
            assert_ne!(spec.flavor, Flavor::Moe, "case {case}: EP sampled at ranks=1");
            spec.validate().unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        }
    }

    #[test]
    fn moe_clean_pair_refines_with_conditional_relations() {
        let spec = ModelSpec {
            seed: 14,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Moe,
            blocks: vec![Block::Moe(UnaryKind::Silu), Block::Unary(UnaryKind::Gelu)],
        };
        let (gs, gd, ri) = build_pair(&spec).unwrap();
        assert!(
            gd.nodes().iter().any(|n| matches!(n.op, Op::Combine { experts: 2 })),
            "EP graph must carry per-rank partial combines"
        );
        assert!(
            gd.nodes().iter().any(|n| matches!(n.op, Op::AllReduce { .. })),
            "EP graph must all-reduce the partials"
        );
        let out = crate::verifier::Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("clean MoE pair must refine: {e}"));
        crate::infer::verify_numeric(&gs, &gd, &ri, &out.relation, 57).unwrap();
        assert!(
            !out.relation_full.conditional_tensors().is_empty(),
            "the MoE walk must produce router-conditioned relations"
        );
    }

    #[test]
    fn moe_blocks_require_moe_flavor() {
        let spec = ModelSpec {
            seed: 15,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Dp,
            blocks: vec![Block::Moe(UnaryKind::Silu)],
        };
        assert!(build_pair(&spec).is_err(), "moe blocks only distribute under EP");
        let no_moe = ModelSpec {
            seed: 16,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Moe,
            blocks: vec![Block::Linear],
        };
        assert!(build_pair(&no_moe).is_err(), "EP without a moe block is meaningless");
    }

    #[test]
    fn pp_clean_pair_refines_and_replays() {
        let spec = ModelSpec {
            seed: 11,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Pp,
            blocks: vec![
                Block::Linear,
                Block::Unary(UnaryKind::Gelu),
                Block::Norm(NormKind::Softmax),
            ],
        };
        let (gs, gd, ri) = build_pair(&spec).unwrap();
        assert!(
            gd.nodes().iter().any(|n| matches!(n.op, Op::Send { .. })),
            "pp graph must contain stage boundaries"
        );
        let out = crate::verifier::Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("clean PP pair must refine: {e}"));
        crate::infer::verify_numeric(&gs, &gd, &ri, &out.relation, 55).unwrap();
    }

    #[test]
    fn pp_sched_clean_pairs_refine_for_every_schedule_kind() {
        use crate::schedule::{decode_buffer_tag, SchedKind};
        for (kind, blocks) in [
            (SchedKind::GPipe, vec![Block::Linear, Block::Unary(UnaryKind::Gelu)]),
            (SchedKind::OneFOneB, vec![Block::Linear, Block::Mlp(UnaryKind::Silu)]),
            (
                SchedKind::Interleaved,
                vec![Block::Linear, Block::Unary(UnaryKind::Gelu), Block::Linear, Block::Linear],
            ),
        ] {
            let spec = ModelSpec {
                seed: 31,
                ranks: 2,
                seq: 8,
                hidden: 4,
                flavor: Flavor::PpSched(kind),
                blocks,
            };
            let (gs, gd, ri) = build_pair(&spec).unwrap_or_else(|e| panic!("{kind:?}: {e:#}"));
            // every boundary op is buffer-tagged
            for n in gd.nodes() {
                if let Op::Send { chan } | Op::Recv { chan } = n.op {
                    assert!(
                        decode_buffer_tag(chan).is_some(),
                        "{kind:?}: '{}' still carries logical channel {chan}",
                        n.name
                    );
                }
            }
            let out = crate::verifier::Verifier::new().expect(&gs, &gd, &ri)
                .unwrap_or_else(|e| panic!("clean {kind:?} pair must refine: {e}"));
            crate::infer::verify_numeric(&gs, &gd, &ri, &out.relation, 59).unwrap();
        }
    }

    #[test]
    fn pp_sched_spec_validation() {
        use crate::schedule::SchedKind;
        // interleaved needs one block per chunk (2 stages x 2 chunks)
        let spec = ModelSpec {
            seed: 32,
            ranks: 2,
            seq: 8,
            hidden: 4,
            flavor: Flavor::PpSched(SchedKind::Interleaved),
            blocks: vec![Block::Linear, Block::Linear],
        };
        assert!(build_pair(&spec).is_err());
        // seq must divide into 2*ranks micro-batches
        let spec = ModelSpec {
            seed: 33,
            ranks: 2,
            seq: 6,
            hidden: 4,
            flavor: Flavor::PpSched(SchedKind::OneFOneB),
            blocks: vec![Block::Linear, Block::Linear],
        };
        assert!(build_pair(&spec).is_err());
    }

    #[test]
    fn forced_flavor_sampling_is_deterministic_and_respects_fallbacks() {
        use crate::schedule::SchedKind;
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        let a = sample_spec_for(&mut r1, 2, 11, Some(Flavor::PpSched(SchedKind::OneFOneB)));
        let b = sample_spec_for(&mut r2, 2, 11, Some(Flavor::PpSched(SchedKind::OneFOneB)));
        assert_eq!(a, b);
        assert!(matches!(a.flavor, Flavor::PpSched(_)));
        assert_eq!(a.seq, 8, "pp_sched forces 4R rows");
        a.validate().unwrap();
        build_pair(&a).unwrap();
        // degenerate EP falls back exactly like unforced sampling
        let mut r = Rng::new(12);
        let m = sample_spec_for(&mut r, 1, 12, Some(Flavor::Moe));
        assert_eq!(m.flavor, Flavor::Fsdp);
        m.validate().unwrap();
        // a forced interleaved campaign never demotes: short chains are
        // padded to the 4 blocks the 2x2 chunk layout needs
        for seed in 0..32u64 {
            let mut r = Rng::new(seed);
            let s = sample_spec_for(&mut r, 2, seed, Some(Flavor::PpSched(SchedKind::Interleaved)));
            assert_eq!(s.flavor, Flavor::PpSched(SchedKind::Interleaved), "seed {seed}");
            assert!(s.blocks.len() >= 4, "seed {seed}: {} blocks", s.blocks.len());
            s.validate().unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
            build_pair(&s).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        }
    }

    #[test]
    fn fsdp_clean_pair_refines_and_replays() {
        let spec = ModelSpec {
            seed: 12,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Fsdp,
            blocks: vec![Block::Linear, Block::Mlp(UnaryKind::Silu)],
        };
        let (gs, gd, ri) = build_pair(&spec).unwrap();
        assert!(
            gd.nodes().iter().any(|n| matches!(n.op, Op::AllGather { .. })),
            "fsdp graph must re-gather its params"
        );
        let out = crate::verifier::Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("clean FSDP pair must refine: {e}"));
        crate::infer::verify_numeric(&gs, &gd, &ri, &out.relation, 56).unwrap();
    }

    #[test]
    fn pp_spec_with_attention_is_rejected() {
        let spec = ModelSpec {
            seed: 13,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Pp,
            blocks: vec![Block::Attention, Block::Linear],
        };
        assert!(build_pair(&spec).is_err());
    }

    #[test]
    fn sp_clean_pair_matches_numerically() {
        // numeric ground truth for the generator itself: evaluate G_s from
        // R_i-derived inputs and compare against the gathered G_d output
        let spec = ModelSpec {
            seed: 1,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Sp,
            blocks: vec![
                Block::Linear,
                Block::Unary(UnaryKind::Gelu),
                Block::Norm(NormKind::Softmax),
            ],
        };
        let (gs, gd, ri) = build_pair(&spec).unwrap();
        let out = crate::verifier::Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("clean SP pair must refine: {e}"));
        crate::infer::verify_numeric(&gs, &gd, &ri, &out.relation, 99).unwrap();
    }
}
