//! Bug-injection mutation operators.
//!
//! Each operator rewrites exactly one node of a distributed graph `G_d`
//! into a plausible distribution bug drawn from the §6.2 taxonomy (see
//! `crate::bugs::fuzz_operator_for` for the case ↔ operator bridge and the
//! wider defect classes catalogued by the distributed-DL bug studies):
//! wrong collective, dropped aggregation, mis-sliced shards, wrong chunk
//! index, mis-scaled reductions, reordered/duplicated shard wiring,
//! wrong-axis reductions, the pipeline/ZeRO wiring family (crossed or
//! dropped send/recv boundaries, stale parameter shards in a re-gather,
//! off-by-one micro-batch rescales), the MoE routing family (wrong
//! expert index, dropped token contributions at the combine, unnormalized
//! gate weights, silent capacity truncation), and the schedule/buffer
//! family on buffer-lowered pipeline graphs (stale buffer reuse across
//! epochs, double-buffer slot swaps, interleaved virtual-stage
//! misbinding).
//!
//! Mutations are applied as single-node [`GraphPatch`]es — the same
//! splice/validation path `graphguard reverify` runs, so every fuzz
//! mutant also exercises the incremental-verification machinery for
//! free. Output shapes are re-inferred during the patch rebuild and a
//! mutant that no longer type-checks is reported as stillborn
//! (`apply_mutation` returns `Err`) rather than silently kept;
//! `patched_matches_direct_rebuild` pins the patch route byte-identical
//! to a direct [`Graph::rebuild_with`].

use crate::ir::{FBits, Graph, GraphPatch, Node, NodeId, Op, OpTag, TensorId};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MutKind {
    /// Rotate the shard operands of an all-gather/concat (wrong rank order).
    GatherReorder,
    /// Replace an all-reduce with rank 0's unreduced contribution.
    DropAggregation,
    /// Swap an all-gather for a reduce-scatter (wrong collective).
    GatherToReduceScatter,
    /// Reduce-scatter keeps the wrong chunk (`index + 1 mod ranks`).
    ScatterIndexPerturb,
    /// Shift a slice window by one element (off-by-one shard offset).
    SliceShift,
    /// Slice along the wrong dimension with the same bounds.
    SliceDimSwap,
    /// Double a scalar rescale (wrong reduction divisor).
    ScalePerturb,
    /// Drop a scalar rescale entirely (missing `1/k`).
    ScaleDrop,
    /// Swap matmul operands.
    MatMulSwap,
    /// Replace a unary activation with a different one.
    WrongUnary,
    /// Wire the same shard into a collective twice (wrong shard pairing).
    DupShardInput,
    /// Softmax along the wrong axis.
    SoftmaxDimSwap,
    /// Rewire a `recv` to a different stage/micro-batch's `send` (crossed
    /// pipeline boundary).
    CrossedSendRecv,
    /// Rewire a `recv` to a raw graph input of the same shape — the
    /// boundary buffer was never written, the consumer reads stage input.
    DroppedBoundary,
    /// Swap one shard of a parameter all-gather for a same-shape input
    /// outside the gather (stale ZeRO/FSDP shard).
    StaleShardGather,
    /// Turn a `1/k` rescale (k ≥ 2 integer) into `1/(k+1)` — the
    /// off-by-one micro-batch/grad-accum divisor bug shape. In the sampled
    /// chains this fires on `Block::Scale(1/2, 1/4)` nodes and on integer
    /// `1/sqrt(h)` attention scales; the generated graphs contain no
    /// literal micro-batch combine node, so per-operator stats measure the
    /// divisor *family*, not a specific combine site.
    MicrobatchScaleOffby,
    /// Rotate a dispatch's expert index (`expert + 1 mod E`): tokens are
    /// scattered to the wrong expert while the combine still gathers under
    /// the original assignment.
    WrongExpertDispatch,
    /// Replace one expert's contribution to a combine with another
    /// expert's output — the tokens routed to that expert have their true
    /// results dropped from the gather.
    DroppedTokenCombine,
    /// Drop the router-gate normalization: the `div` by the top-k
    /// probability sum becomes an identity, so the combine runs on raw
    /// (unnormalized) gate weights.
    GateWeightUnnormalized,
    /// Shrink a dispatch's token capacity to 1: every expert silently
    /// drops all but its first assigned token (the classic
    /// capacity-overflow token-drop bug).
    CapacityTruncateSilent,
    /// Stale buffer reuse on a schedule-lowered pipeline graph: a recv
    /// whose physical buffer `(boundary, slot)` is recycled across epochs
    /// reads the slot one epoch too early — it picks up the *previous*
    /// occupant's activation (micro-batch `m - depth`) still sitting in the
    /// buffer. The recv keeps its intended `(slot, epoch)` tag, so the
    /// crossed tag stays opaque and refinement fails inside the receiving
    /// stage.
    BufferReuseEarly,
    /// Double-buffering index bug: a recv bound to the wrong slot of its
    /// boundary's buffer pool — it reads a pool-mate's buffer (same epoch,
    /// different slot), i.e. another micro-batch's activation.
    DoubleBufferSwap,
    /// Interleaved-virtual-stage misbinding: a recv bound to the analogous
    /// buffer `(slot, epoch)` of a *different* chunk boundary — the classic
    /// wrong-virtual-chunk wiring of interleaved 1F1B runtimes.
    VirtualStageMisbind,
}

pub const MUT_KINDS: [MutKind; 23] = [
    MutKind::GatherReorder,
    MutKind::DropAggregation,
    MutKind::GatherToReduceScatter,
    MutKind::ScatterIndexPerturb,
    MutKind::SliceShift,
    MutKind::SliceDimSwap,
    MutKind::ScalePerturb,
    MutKind::ScaleDrop,
    MutKind::MatMulSwap,
    MutKind::WrongUnary,
    MutKind::DupShardInput,
    MutKind::SoftmaxDimSwap,
    MutKind::CrossedSendRecv,
    MutKind::DroppedBoundary,
    MutKind::StaleShardGather,
    MutKind::MicrobatchScaleOffby,
    MutKind::WrongExpertDispatch,
    MutKind::DroppedTokenCombine,
    MutKind::GateWeightUnnormalized,
    MutKind::CapacityTruncateSilent,
    MutKind::BufferReuseEarly,
    MutKind::DoubleBufferSwap,
    MutKind::VirtualStageMisbind,
];

impl MutKind {
    pub fn name(self) -> &'static str {
        match self {
            MutKind::GatherReorder => "gather_reorder",
            MutKind::DropAggregation => "drop_aggregation",
            MutKind::GatherToReduceScatter => "gather_to_reduce_scatter",
            MutKind::ScatterIndexPerturb => "scatter_index_perturb",
            MutKind::SliceShift => "slice_shift",
            MutKind::SliceDimSwap => "slice_dim_swap",
            MutKind::ScalePerturb => "scale_perturb",
            MutKind::ScaleDrop => "scale_drop",
            MutKind::MatMulSwap => "matmul_swap",
            MutKind::WrongUnary => "wrong_unary",
            MutKind::DupShardInput => "dup_shard_input",
            MutKind::SoftmaxDimSwap => "softmax_dim_swap",
            MutKind::CrossedSendRecv => "crossed_send_recv",
            MutKind::DroppedBoundary => "dropped_boundary",
            MutKind::StaleShardGather => "stale_shard_gather",
            MutKind::MicrobatchScaleOffby => "microbatch_scale_offby",
            MutKind::WrongExpertDispatch => "wrong_expert_dispatch",
            MutKind::DroppedTokenCombine => "dropped_token_combine",
            MutKind::GateWeightUnnormalized => "gate_weight_unnormalized",
            MutKind::CapacityTruncateSilent => "capacity_truncate_silent",
            MutKind::BufferReuseEarly => "buffer_reuse_early",
            MutKind::DoubleBufferSwap => "double_buffer_swap",
            MutKind::VirtualStageMisbind => "virtual_stage_misbind",
        }
    }

    pub fn parse(s: &str) -> Option<MutKind> {
        MUT_KINDS.iter().copied().find(|k| k.name() == s)
    }
}

/// An applicable mutation site: one node × one operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Site {
    pub node: NodeId,
    pub kind: MutKind,
}

/// Serializable record of an applied mutation (counterexample replay).
#[derive(Debug, Clone, PartialEq)]
pub struct Mutation {
    pub kind: MutKind,
    /// Name of the mutated `G_d` node.
    pub node_name: String,
    /// Block index parsed from the `b{i}_...` naming contract.
    pub block: Option<usize>,
}

impl Mutation {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.name())),
            ("node", Json::str(self.node_name.clone())),
            (
                "block",
                self.block.map(|b| Json::num(b as f64)).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Mutation> {
        let kind_s = j.get("kind").as_str().ok_or_else(|| anyhow!("mutation missing 'kind'"))?;
        let kind = MutKind::parse(kind_s).ok_or_else(|| anyhow!("unknown mutation '{kind_s}'"))?;
        let node_name = j
            .get("node")
            .as_str()
            .ok_or_else(|| anyhow!("mutation missing 'node'"))?
            .to_string();
        let block = parse_block(&node_name);
        Ok(Mutation { kind, node_name, block })
    }
}

/// Parse the block index from a `b{i}_...` node name.
pub fn parse_block(name: &str) -> Option<usize> {
    let rest = name.strip_prefix('b')?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() || !rest[digits.len()..].starts_with('_') {
        return None;
    }
    digits.parse().ok()
}

/// The replacement `(op, inputs)` for `node` under `kind`, or `None` when
/// the operator does not apply to this node. `ins` are the (remapped)
/// input ids to build the replacement from; shapes are read from `g`,
/// whose upstream prefix is identical to the rebuilt graph's.
fn mutate_node(
    g: &Graph,
    node: &Node,
    kind: MutKind,
    ins: &[TensorId],
) -> Option<(Op, Vec<TensorId>)> {
    match kind {
        MutKind::GatherReorder => match node.op.tag() {
            OpTag::AllGather | OpTag::Concat if ins.len() >= 2 => {
                let mut rot = ins.to_vec();
                rot.rotate_left(1);
                if rot == ins {
                    return None;
                }
                Some((node.op.clone(), rot))
            }
            _ => None,
        },
        MutKind::DropAggregation => match node.op {
            Op::AllReduce { ranks } if ranks >= 2 => Some((Op::Identity, vec![ins[0]])),
            _ => None,
        },
        MutKind::GatherToReduceScatter => match node.op {
            Op::AllGather { dim, ranks } if ranks >= 2 => {
                Some((Op::ReduceScatter { dim, ranks, index: 0 }, ins.to_vec()))
            }
            _ => None,
        },
        MutKind::ScatterIndexPerturb => match node.op {
            Op::ReduceScatter { dim, ranks, index } if ranks >= 2 => {
                Some((Op::ReduceScatter { dim, ranks, index: (index + 1) % ranks }, ins.to_vec()))
            }
            _ => None,
        },
        MutKind::SliceShift => match &node.op {
            Op::Slice { dim, start, end } => {
                let (s, e) = (start.as_const()?, end.as_const()?);
                let size = g.shape(node.inputs[0])[*dim];
                let delta = if e < size {
                    1
                } else if s > 0 {
                    -1
                } else {
                    return None; // full-extent slice: nowhere to shift
                };
                Some((
                    Op::Slice { dim: *dim, start: (s + delta).into(), end: (e + delta).into() },
                    ins.to_vec(),
                ))
            }
            _ => None,
        },
        MutKind::SliceDimSwap => match &node.op {
            Op::Slice { dim, start, end } => {
                let (s, e) = (start.as_const()?, end.as_const()?);
                let shape = g.shape(node.inputs[0]);
                let d2 = (0..shape.len()).find(|&d| d != *dim && shape[d] >= e && e > s)?;
                Some((
                    Op::Slice { dim: d2, start: start.clone(), end: end.clone() },
                    ins.to_vec(),
                ))
            }
            _ => None,
        },
        MutKind::ScalePerturb => match node.op {
            Op::Scale { c } if c.get() != 0.0 => {
                Some((Op::Scale { c: FBits::new(c.get() * 2.0) }, ins.to_vec()))
            }
            _ => None,
        },
        MutKind::ScaleDrop => match node.op {
            Op::Scale { c } if c.get() != 1.0 => Some((Op::Identity, ins.to_vec())),
            _ => None,
        },
        MutKind::MatMulSwap => match node.op {
            Op::MatMul if ins[0] != ins[1] => Some((Op::MatMul, vec![ins[1], ins[0]])),
            _ => None,
        },
        MutKind::WrongUnary => {
            let repl = match node.op.tag() {
                OpTag::Gelu => Op::Relu,
                OpTag::Relu => Op::Tanh,
                OpTag::Tanh => Op::Silu,
                OpTag::Silu => Op::Sigmoid,
                OpTag::Sigmoid => Op::Gelu,
                _ => return None,
            };
            Some((repl, ins.to_vec()))
        }
        MutKind::DupShardInput => match node.op.tag() {
            OpTag::AllGather | OpTag::AllReduce | OpTag::Concat | OpTag::SumN
                if ins.len() >= 2 && ins[0] != ins[1] =>
            {
                let first = g.shape(node.inputs[0]);
                if node.inputs.iter().any(|&t| g.shape(t) != first) {
                    return None; // keep the output shape unchanged
                }
                let mut dup = ins.to_vec();
                dup[1] = dup[0];
                Some((node.op.clone(), dup))
            }
            _ => None,
        },
        MutKind::SoftmaxDimSwap => match node.op {
            Op::Softmax { dim } => {
                let rank = g.shape(node.inputs[0]).len();
                if rank < 2 {
                    return None;
                }
                Some((Op::Softmax { dim: (dim + 1) % rank }, ins.to_vec()))
            }
            _ => None,
        },
        // The stage-wiring operators below rewire a node to a tensor created
        // *earlier* in the graph (`id < node.output`). `rebuild_with`
        // recreates tensors in original id order, so those ids are stable
        // between the clean graph and the rebuilt mutant (asserted by
        // `rebuild_preserves_interleaved_tensor_ids`).
        MutKind::CrossedSendRecv => match node.op {
            Op::Recv { .. } => {
                let cur = node.inputs[0];
                let shape = g.shape(cur);
                let cand = (0..node.output).find(|&t| {
                    t != cur
                        && g.shape(t) == shape
                        && matches!(
                            g.producer(t).map(|n| n.op.tag()),
                            Some(OpTag::Send)
                        )
                })?;
                Some((node.op.clone(), vec![cand]))
            }
            _ => None,
        },
        MutKind::DroppedBoundary => match node.op {
            Op::Recv { .. } => {
                let cur = node.inputs[0];
                let shape = g.shape(cur);
                let dtype = g.tensor(cur).dtype;
                let cand = (0..node.output).find(|&t| {
                    g.is_input(t) && g.shape(t) == shape && g.tensor(t).dtype == dtype
                })?;
                Some((node.op.clone(), vec![cand]))
            }
            _ => None,
        },
        MutKind::StaleShardGather => match node.op.tag() {
            // a parameter re-gather: every operand is a stored shard (raw
            // graph input); swap shard 1 for a same-shape input outside the
            // gather — a stale chunk of some other parameter
            OpTag::AllGather
                if ins.len() >= 2 && node.inputs.iter().all(|&t| g.is_input(t)) =>
            {
                let shape = g.shape(node.inputs[1]);
                let dtype = g.tensor(node.inputs[1]).dtype;
                let cand = (0..node.output).find(|&t| {
                    g.is_input(t)
                        && g.shape(t) == shape
                        && g.tensor(t).dtype == dtype
                        && !node.inputs.contains(&t)
                })?;
                let mut swapped = ins.to_vec();
                swapped[1] = cand;
                Some((node.op.clone(), swapped))
            }
            _ => None,
        },
        MutKind::MicrobatchScaleOffby => match node.op {
            Op::Scale { c } => {
                let v = c.get();
                // only 1/k combine factors (k >= 2) — the micro-batch /
                // grad-accum divisor family
                if v <= 0.0 || v > 0.5 {
                    return None;
                }
                let k = (1.0 / v).round();
                if (1.0 / v - k).abs() > 1e-9 {
                    return None;
                }
                Some((Op::Scale { c: FBits::new(1.0 / (k + 1.0)) }, ins.to_vec()))
            }
            _ => None,
        },
        MutKind::WrongExpertDispatch => match node.op {
            Op::Dispatch { expert, capacity } => {
                let experts = g.shape(node.inputs[1])[1];
                if experts < 2 {
                    return None;
                }
                Some((
                    Op::Dispatch {
                        expert: (expert + 1) % experts as usize,
                        capacity,
                    },
                    ins.to_vec(),
                ))
            }
            _ => None,
        },
        MutKind::DroppedTokenCombine => match node.op {
            // drop the last expert's true contribution by wiring the first
            // expert's output into its slot (the gate weights still select
            // tokens for it — those tokens now receive the wrong results)
            Op::Combine { experts } if experts >= 2 && ins[1] != ins[experts] => {
                let mut swapped = ins.to_vec();
                swapped[experts] = swapped[1];
                Some((node.op.clone(), swapped))
            }
            _ => None,
        },
        MutKind::GateWeightUnnormalized => match node.op {
            // a gate-normalizing div: the denominator is a keepdim row
            // reduction of the numerator — dropping it leaves the combine
            // running on raw (unnormalized) top-k gate weights
            Op::Div => {
                let denom = g.producer(node.inputs[1])?;
                match denom.op {
                    Op::ReduceSum { keepdim: true, .. }
                        if denom.inputs.first() == Some(&node.inputs[0]) =>
                    {
                        Some((Op::Identity, vec![ins[0]]))
                    }
                    _ => None,
                }
            }
            _ => None,
        },
        MutKind::CapacityTruncateSilent => match node.op {
            Op::Dispatch { expert, capacity } if capacity > 1 => {
                Some((Op::Dispatch { expert, capacity: 1 }, ins.to_vec()))
            }
            _ => None,
        },
        // The buffer-hazard operators below only fire on schedule-lowered
        // graphs (decode_buffer_tag is None for logical channels) and, like
        // the stage-wiring family, only rewire to tensors created earlier
        // than the mutated node — rebuild_with's topological contract.
        MutKind::BufferReuseEarly => match node.op {
            Op::Recv { chan } => {
                let (b, slot, epoch) = crate::schedule::decode_buffer_tag(chan)?;
                // the previous occupant of this physical buffer
                let want = crate::schedule::buffer_tag(b, slot, epoch.checked_sub(1)?);
                let cand = earlier_send_with(g, node, |c| c == want)?;
                Some((node.op.clone(), vec![cand]))
            }
            _ => None,
        },
        MutKind::DoubleBufferSwap => match node.op {
            Op::Recv { chan } => {
                let (b, slot, epoch) = crate::schedule::decode_buffer_tag(chan)?;
                // a pool-mate: same boundary and epoch, different slot
                // (lower slots were built earlier)
                let cand = earlier_send_with(g, node, |c| {
                    matches!(
                        crate::schedule::decode_buffer_tag(c),
                        Some((b2, s2, e2)) if b2 == b && e2 == epoch && s2 != slot
                    )
                })?;
                Some((node.op.clone(), vec![cand]))
            }
            _ => None,
        },
        MutKind::VirtualStageMisbind => match node.op {
            Op::Recv { chan } => {
                let (b, slot, epoch) = crate::schedule::decode_buffer_tag(chan)?;
                // the analogous buffer of a different chunk boundary
                let cand = earlier_send_with(g, node, |c| {
                    matches!(
                        crate::schedule::decode_buffer_tag(c),
                        Some((b2, s2, e2)) if b2 != b && s2 == slot && e2 == epoch
                    )
                })?;
                Some((node.op.clone(), vec![cand]))
            }
            _ => None,
        },
    }
}

/// First tensor before `node`'s output that is produced by a `Send` whose
/// channel satisfies `want`, shape-compatible with the node's current
/// input. Shared by the buffer-hazard operators.
fn earlier_send_with(g: &Graph, node: &Node, want: impl Fn(usize) -> bool) -> Option<TensorId> {
    let cur = node.inputs[0];
    let shape = g.shape(cur);
    (0..node.output).find(|&t| {
        t != cur
            && g.shape(t) == shape
            && matches!(g.producer(t).map(|n| &n.op), Some(Op::Send { chan }) if want(*chan))
    })
}

/// Enumerate every applicable (node, operator) site, in deterministic
/// topological × operator order.
pub fn applicable_sites(g: &Graph) -> Vec<Site> {
    let mut out = Vec::new();
    for nid in g.topo_order() {
        let node = g.node(nid);
        for &kind in &MUT_KINDS {
            if mutate_node(g, node, kind, &node.inputs).is_some() {
                out.push(Site { node: nid, kind });
            }
        }
    }
    out
}

/// Express one mutation site as a single-node [`GraphPatch`]: every
/// operator replaces exactly one node's `(op, inputs)`, which is one
/// `replace` op with an explicit input list. Rewire targets are always
/// *earlier* tensors (the operators guarantee `id < node.output`), so the
/// patch's non-splice fast path — `rebuild_with` underneath — preserves
/// every `TensorId`, which is the stability contract the oracle depends
/// on (it reuses the clean graph's input environments and its
/// `TensorId`-keyed relation `R_i` against the mutant).
pub fn mutation_patch(g: &Graph, site: Site) -> Result<GraphPatch> {
    let target = g.node(site.node);
    let (op, ins) = mutate_node(g, target, site.kind, &target.inputs).ok_or_else(|| {
        anyhow!("mutation {} not applicable to '{}'", site.kind.name(), target.name)
    })?;
    let input_names = ins.iter().map(|&t| g.tensor(t).name.clone()).collect();
    Ok(GraphPatch::new(format!("mut_{}", site.kind.name()))
        .replace_wired(&g.tensor(target.output).name, op, input_names))
}

/// Apply one mutation site; `Err` means the mutant is stillborn (the
/// rewritten graph no longer type-checks) or the site is inapplicable.
/// Mutants are built by applying [`mutation_patch`], so output shapes are
/// re-inferred by the patch's strict validation.
pub fn apply_mutation(g: &Graph, site: Site) -> Result<(Graph, Mutation)> {
    let target = g.node(site.node);
    let mutated = mutation_patch(g, site)?.apply(g)?;
    let mutation = Mutation {
        kind: site.kind,
        node_name: target.name.clone(),
        block: parse_block(&target.name),
    };
    Ok((mutated, mutation))
}

/// Locate a mutation site by node name (counterexample replay / shrinking).
pub fn apply_mutation_by_name(
    g: &Graph,
    kind: MutKind,
    node_name: &str,
) -> Result<(Graph, Mutation)> {
    let nid = g
        .topo_order()
        .find(|&n| g.node(n).name == node_name)
        .ok_or_else(|| anyhow!("mutation site '{node_name}' not found"))?;
    apply_mutation(g, Site { node: nid, kind })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::genmodel::{build_pair, Block, Flavor, ModelSpec, NormKind, UnaryKind};

    fn sp_spec() -> ModelSpec {
        ModelSpec {
            seed: 3,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Sp,
            blocks: vec![
                Block::Linear,
                Block::Unary(UnaryKind::Gelu),
                Block::Norm(NormKind::Softmax),
            ],
        }
    }

    #[test]
    fn parse_block_follows_naming_contract() {
        assert_eq!(parse_block("b0_mm_r1"), Some(0));
        assert_eq!(parse_block("b12_act"), Some(12));
        assert_eq!(parse_block("x_r0"), None);
        assert_eq!(parse_block("b_act"), None);
        assert_eq!(parse_block("b3act"), None);
    }

    #[test]
    fn sites_are_found_and_deterministic() {
        let (_gs, gd, _ri) = build_pair(&sp_spec()).unwrap();
        let a = applicable_sites(&gd);
        let b = applicable_sites(&gd);
        assert_eq!(a, b);
        assert!(
            a.iter().any(|s| s.kind == MutKind::WrongUnary),
            "gelu site expected in {a:?}"
        );
        assert!(a.iter().any(|s| s.kind == MutKind::GatherReorder), "epilogue gather site");
    }

    #[test]
    fn wrong_unary_mutant_differs_and_rebuilds() {
        let (_gs, gd, _ri) = build_pair(&sp_spec()).unwrap();
        let site = applicable_sites(&gd)
            .into_iter()
            .find(|s| s.kind == MutKind::WrongUnary)
            .unwrap();
        let (gdm, m) = apply_mutation(&gd, site).unwrap();
        assert_eq!(m.kind, MutKind::WrongUnary);
        assert!(m.node_name.contains("_act"), "{}", m.node_name);
        assert_eq!(m.block, Some(1));
        gdm.validate().unwrap();
        assert_eq!(gdm.num_nodes(), gd.num_nodes());
        // same inputs, different outputs
        let inputs = crate::expr::eval::random_inputs(&gd, 11);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&gdm, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(!a[o].allclose(&b[o], 1e-4, 1e-5), "mutant must change numerics");
    }

    #[test]
    fn gather_to_reduce_scatter_changes_output_shape_or_dies() {
        let (_gs, gd, _ri) = build_pair(&sp_spec()).unwrap();
        let site = applicable_sites(&gd)
            .into_iter()
            .find(|s| s.kind == MutKind::GatherToReduceScatter)
            .unwrap();
        match apply_mutation(&gd, site) {
            Ok((gdm, _)) => {
                assert_ne!(gdm.shape(gdm.outputs[0]), gd.shape(gd.outputs[0]));
            }
            Err(_) => {} // stillborn is acceptable
        }
    }

    #[test]
    fn rebuild_preserves_interleaved_tensor_ids() {
        // two Linear blocks: the second weight input is declared AFTER the
        // first block's matmul outputs, so input/node tensor ids interleave.
        // An identity rebuild must keep every id, name and shape stable —
        // the oracle reuses gd-keyed inputs and R_i on rebuilt mutants.
        let spec = ModelSpec {
            seed: 8,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Sp,
            blocks: vec![Block::Linear, Block::Linear],
        };
        let (_gs, gd, _ri) = build_pair(&spec).unwrap();
        let rebuilt = gd.rebuild_with(|_n, node, ins| (node.op.clone(), ins.to_vec())).unwrap();
        assert_eq!(rebuilt.inputs, gd.inputs, "input ids must not renumber");
        assert_eq!(rebuilt.outputs, gd.outputs);
        assert_eq!(rebuilt.num_tensors(), gd.num_tensors());
        for t in 0..gd.num_tensors() as u32 {
            assert_eq!(rebuilt.tensor(t).name, gd.tensor(t).name, "tensor {t}");
            assert_eq!(rebuilt.tensor(t).shape, gd.tensor(t).shape, "tensor {t}");
        }
        // and the clean-input environment of gd evaluates the rebuild
        let inputs = crate::expr::eval::random_inputs(&gd, 23);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&rebuilt, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(a[o].allclose(&b[o], 0.0, 0.0), "identity rebuild must be exact");
    }

    fn pp_spec() -> ModelSpec {
        ModelSpec {
            seed: 21,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Pp,
            blocks: vec![Block::Linear, Block::Unary(UnaryKind::Tanh)],
        }
    }

    fn fsdp_spec() -> ModelSpec {
        ModelSpec {
            seed: 22,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Fsdp,
            blocks: vec![Block::Linear, Block::Mlp(UnaryKind::Gelu)],
        }
    }

    #[test]
    fn crossed_send_recv_rewires_and_changes_numerics() {
        let (_gs, gd, _ri) = build_pair(&pp_spec()).unwrap();
        let site = applicable_sites(&gd)
            .into_iter()
            .find(|s| s.kind == MutKind::CrossedSendRecv)
            .expect("pp graph must expose a crossed-boundary site");
        let (gdm, m) = apply_mutation(&gd, site).unwrap();
        assert!(m.node_name.contains("_recv"), "{}", m.node_name);
        assert!(m.block.is_some(), "boundary nodes carry block names: {}", m.node_name);
        gdm.validate().unwrap();
        let inputs = crate::expr::eval::random_inputs(&gd, 31);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&gdm, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(!a[o].allclose(&b[o], 1e-4, 1e-5), "crossed boundary must change numerics");
    }

    #[test]
    fn dropped_boundary_rewires_to_stage_input() {
        let (_gs, gd, _ri) = build_pair(&pp_spec()).unwrap();
        let site = applicable_sites(&gd)
            .into_iter()
            .find(|s| s.kind == MutKind::DroppedBoundary)
            .expect("pp graph must expose a dropped-boundary site");
        let (gdm, _m) = apply_mutation(&gd, site).unwrap();
        gdm.validate().unwrap();
        let target = gdm.node(site.node);
        assert!(gdm.is_input(target.inputs[0]), "recv must now read a raw input");
    }

    #[test]
    fn stale_shard_gather_swaps_one_shard() {
        let (_gs, gd, _ri) = build_pair(&fsdp_spec()).unwrap();
        let site = applicable_sites(&gd)
            .into_iter()
            .find(|s| s.kind == MutKind::StaleShardGather)
            .expect("fsdp graph must expose a stale-shard site");
        let (gdm, m) = apply_mutation(&gd, site).unwrap();
        gdm.validate().unwrap();
        assert_eq!(gdm.num_nodes(), gd.num_nodes());
        assert!(m.node_name.contains("ag"), "{}", m.node_name);
        let clean = gd.node(site.node);
        let muta = gdm.node(site.node);
        assert_ne!(clean.inputs, muta.inputs, "one shard operand must change");
        let inputs = crate::expr::eval::random_inputs(&gd, 33);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&gdm, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(!a[o].allclose(&b[o], 1e-4, 1e-5), "stale shard must change numerics");
    }

    #[test]
    fn microbatch_scale_offby_only_hits_inverse_integer_factors() {
        let mut g = crate::ir::Graph::new("t");
        let x = g.input("x", vec![4]);
        let half = g.scale("half", x, 0.5);
        let double = g.scale("double", half, 2.0);
        g.mark_output(double);
        let sites = applicable_sites(&g);
        let hits: Vec<_> = sites
            .iter()
            .filter(|s| s.kind == MutKind::MicrobatchScaleOffby)
            .collect();
        assert_eq!(hits.len(), 1, "only the 1/2 factor qualifies: {hits:?}");
        let (gm, _) = apply_mutation(&g, *hits[0]).unwrap();
        match &gm.node(hits[0].node).op {
            Op::Scale { c } => assert!((c.get() - 1.0 / 3.0).abs() < 1e-12, "{}", c.get()),
            other => panic!("{other:?}"),
        }
    }

    fn moe_spec() -> ModelSpec {
        ModelSpec {
            seed: 23,
            ranks: 2,
            seq: 4,
            hidden: 4,
            flavor: Flavor::Moe,
            blocks: vec![Block::Moe(UnaryKind::Silu), Block::Unary(UnaryKind::Gelu)],
        }
    }

    /// 1F1B at 4 micro-batches: depth-2 pool, epochs {0, 1} on each slot.
    fn pp_sched_spec() -> ModelSpec {
        ModelSpec {
            seed: 24,
            ranks: 2,
            seq: 8,
            hidden: 4,
            flavor: Flavor::PpSched(crate::schedule::SchedKind::OneFOneB),
            blocks: vec![Block::Linear, Block::Unary(UnaryKind::Tanh)],
        }
    }

    /// Interleaved 2x2: three chunk boundaries to misbind across.
    fn pp_intlv_spec() -> ModelSpec {
        ModelSpec {
            seed: 25,
            ranks: 2,
            seq: 8,
            hidden: 4,
            flavor: Flavor::PpSched(crate::schedule::SchedKind::Interleaved),
            blocks: vec![Block::Linear, Block::Linear, Block::Linear, Block::Linear],
        }
    }

    #[test]
    fn buffer_reuse_early_reads_the_previous_epoch_of_the_slot() {
        let (_gs, gd, _ri) = build_pair(&pp_sched_spec()).unwrap();
        // micro-batch 2 shares slot 0 with micro-batch 0 (depth 2)
        let (gdm, m) =
            apply_mutation_by_name(&gd, MutKind::BufferReuseEarly, "b0_mm_mb2_recv").unwrap();
        assert_eq!(m.block, Some(0));
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b0_mm_mb2_recv").unwrap();
        let stale = gd.tensor_by_name("b0_mm_mb0_send").unwrap();
        assert_eq!(gdm.node(site).inputs[0], stale, "recv must read micro-batch 0's buffer");
        let inputs = crate::expr::eval::random_inputs(&gd, 51);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&gdm, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(!a[o].allclose(&b[o], 1e-4, 1e-5), "stale buffer must change numerics");
    }

    #[test]
    fn double_buffer_swap_reads_the_pool_mate_slot() {
        let (_gs, gd, _ri) = build_pair(&pp_sched_spec()).unwrap();
        let (gdm, _m) =
            apply_mutation_by_name(&gd, MutKind::DoubleBufferSwap, "b0_mm_mb1_recv").unwrap();
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b0_mm_mb1_recv").unwrap();
        let mate = gd.tensor_by_name("b0_mm_mb0_send").unwrap();
        assert_eq!(gdm.node(site).inputs[0], mate, "recv must read slot 0's buffer");
        // epoch-0 slot-0 recv has no earlier pool-mate: not applicable
        assert!(
            apply_mutation_by_name(&gd, MutKind::DoubleBufferSwap, "b0_mm_mb0_recv").is_err()
        );
    }

    #[test]
    fn virtual_stage_misbind_crosses_chunk_boundaries() {
        let (_gs, gd, _ri) = build_pair(&pp_intlv_spec()).unwrap();
        let (gdm, m) =
            apply_mutation_by_name(&gd, MutKind::VirtualStageMisbind, "b1_mm_mb0_recv").unwrap();
        assert_eq!(m.block, Some(1));
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b1_mm_mb0_recv").unwrap();
        let other = gd.tensor_by_name("b0_mm_mb0_send").unwrap();
        assert_eq!(gdm.node(site).inputs[0], other, "recv must read boundary 0's buffer");
        let inputs = crate::expr::eval::random_inputs(&gd, 53);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&gdm, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(!a[o].allclose(&b[o], 1e-4, 1e-5), "misbound chunk must change numerics");
    }

    #[test]
    fn buffer_hazard_operators_skip_logical_pp_graphs() {
        // un-lowered Pp graphs carry logical channels — the buffer family
        // must not fire there (crossed_send_recv already covers them)
        let (_gs, gd, _ri) = build_pair(&pp_spec()).unwrap();
        let sites = applicable_sites(&gd);
        assert!(
            !sites.iter().any(|s| matches!(
                s.kind,
                MutKind::BufferReuseEarly
                    | MutKind::DoubleBufferSwap
                    | MutKind::VirtualStageMisbind
            )),
            "buffer operators fired on a logical-channel graph"
        );
        // and all three find sites on the lowered graphs
        let (_gs, gd, _ri) = build_pair(&pp_sched_spec()).unwrap();
        let sites = applicable_sites(&gd);
        for kind in [MutKind::BufferReuseEarly, MutKind::DoubleBufferSwap] {
            assert!(sites.iter().any(|s| s.kind == kind), "no {kind:?} site");
        }
        let (_gs, gd, _ri) = build_pair(&pp_intlv_spec()).unwrap();
        let sites = applicable_sites(&gd);
        assert!(
            sites.iter().any(|s| s.kind == MutKind::VirtualStageMisbind),
            "no VirtualStageMisbind site on the interleaved graph"
        );
    }

    #[test]
    fn routing_sites_exist_in_moe_graphs() {
        let (_gs, gd, _ri) = build_pair(&moe_spec()).unwrap();
        let sites = applicable_sites(&gd);
        for kind in [
            MutKind::WrongExpertDispatch,
            MutKind::DroppedTokenCombine,
            MutKind::GateWeightUnnormalized,
            MutKind::CapacityTruncateSilent,
        ] {
            assert!(
                sites.iter().any(|s| s.kind == kind),
                "moe graph must expose a {kind:?} site"
            );
        }
    }

    #[test]
    fn wrong_expert_dispatch_rotates_the_expert_index() {
        let (_gs, gd, _ri) = build_pair(&moe_spec()).unwrap();
        let (gdm, m) =
            apply_mutation_by_name(&gd, MutKind::WrongExpertDispatch, "b0_disp0").unwrap();
        assert_eq!(m.block, Some(0));
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b0_disp0").unwrap();
        match (&gd.node(site).op, &gdm.node(site).op) {
            (Op::Dispatch { expert: 0, .. }, Op::Dispatch { expert: 1, .. }) => {}
            other => panic!("expert must rotate: {other:?}"),
        }
    }

    #[test]
    fn capacity_truncate_shrinks_to_one() {
        let (_gs, gd, _ri) = build_pair(&moe_spec()).unwrap();
        let (gdm, _m) =
            apply_mutation_by_name(&gd, MutKind::CapacityTruncateSilent, "b0_disp1").unwrap();
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b0_disp1").unwrap();
        match gdm.node(site).op {
            Op::Dispatch { capacity: 1, .. } => {}
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn gate_weight_unnormalized_drops_the_div() {
        let (_gs, gd, _ri) = build_pair(&moe_spec()).unwrap();
        let (gdm, m) =
            apply_mutation_by_name(&gd, MutKind::GateWeightUnnormalized, "b0_gates").unwrap();
        assert_eq!(m.block, Some(0));
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b0_gates").unwrap();
        assert!(matches!(gdm.node(site).op, Op::Identity), "normalization dropped");
        // the combine now runs on raw masked probabilities — numerics change
        let inputs = crate::expr::eval::random_inputs(&gd, 41);
        let a = crate::expr::eval::eval_graph(&gd, &inputs).unwrap();
        let b = crate::expr::eval::eval_graph(&gdm, &inputs).unwrap();
        let o = gd.outputs[0] as usize;
        assert!(!a[o].allclose(&b[o], 1e-4, 1e-5), "unnormalized gates must change numerics");
    }

    #[test]
    fn dropped_token_combine_duplicates_an_expert_operand() {
        let (_gs, gd, _ri) = build_pair(&moe_spec()).unwrap();
        let (gdm, _m) =
            apply_mutation_by_name(&gd, MutKind::DroppedTokenCombine, "b0_moe_r0").unwrap();
        gdm.validate().unwrap();
        let site = gd.topo_order().find(|&n| gd.node(n).name == "b0_moe_r0").unwrap();
        let clean = gd.node(site);
        let muta = gdm.node(site);
        assert_eq!(muta.inputs[0], clean.inputs[0], "weights operand untouched");
        assert_eq!(muta.inputs[2], muta.inputs[1], "last expert slot now duplicates the first");
        assert_ne!(clean.inputs[2], clean.inputs[1]);
    }

    #[test]
    fn patched_matches_direct_rebuild() {
        // The GraphPatch route must produce byte-identical mutants to a
        // direct rebuild_with closure (the pre-patch implementation), for
        // every applicable site across every flavor family.
        let specs =
            [sp_spec(), pp_spec(), fsdp_spec(), moe_spec(), pp_sched_spec(), pp_intlv_spec()];
        let mut sites_checked = 0usize;
        for spec in specs {
            let (_gs, gd, _ri) = build_pair(&spec).unwrap();
            for site in applicable_sites(&gd) {
                let direct = gd.rebuild_with(|nid, node, mapped| {
                    if nid == site.node {
                        if let Some(repl) = mutate_node(&gd, node, site.kind, mapped) {
                            return repl;
                        }
                    }
                    (node.op.clone(), mapped.to_vec())
                });
                match (apply_mutation(&gd, site), direct) {
                    (Ok((via_patch, _)), Ok(d)) => {
                        assert_eq!(
                            crate::ir::json_io::to_json(&via_patch).to_string(),
                            crate::ir::json_io::to_json(&d).to_string(),
                            "{site:?} diverges between patch and direct rebuild"
                        );
                        sites_checked += 1;
                    }
                    (Err(_), Err(_)) => {} // stillborn either way
                    (p, d) => panic!(
                        "{site:?}: patch route ok={} but direct rebuild ok={}",
                        p.is_ok(),
                        d.is_ok()
                    ),
                }
            }
        }
        assert!(sites_checked > 20, "differential coverage too thin: {sites_checked}");
    }

    #[test]
    fn mutation_json_roundtrip() {
        let m = Mutation {
            kind: MutKind::SliceShift,
            node_name: "b2_cos_r1".into(),
            block: Some(2),
        };
        let back = Mutation::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
    }
}
