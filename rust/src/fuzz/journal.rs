//! Crash-safe fuzz-campaign journal.
//!
//! An append-only JSONL file (`journal.jsonl` in the campaign's `--out`
//! directory): line 0 is a `config` record pinning the campaign parameters,
//! every subsequent line is a `seed` record with the full per-seed outcome
//! (clean verdict, per-mutant outcomes, counterexample summaries). Because
//! per-seed sampling derives from `case_seed(base, i)` alone, a resumed
//! campaign that replays journaled records and re-runs only the missing
//! seeds reconstructs the *byte-identical* final `FUZZ_REPORT.json` of an
//! uninterrupted run.
//!
//! Durability: every append rewrites the whole journal to a temp file in
//! the same directory, fsyncs it, and atomically renames it over the
//! previous journal. A `kill -9` therefore leaves either the old or the
//! new journal, never a torn one; the loader still tolerates a truncated
//! trailing line (e.g. a journal produced by some other writer) by
//! dropping it.

// The journal is an untrusted input path (a resumed campaign parses
// whatever is on disk): parse errors must propagate as Results, never
// panic. Enforced via clippy.toml's disallowed-methods list.
#![deny(clippy::disallowed_methods)]

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

pub const JOURNAL_FILE: &str = "journal.jsonl";
const TMP_FILE: &str = ".journal.jsonl.tmp";

pub struct Journal {
    dir: PathBuf,
    /// Full journal contents (header + records), the rewrite buffer.
    lines: Vec<String>,
}

impl Journal {
    pub fn path_in(dir: &Path) -> PathBuf {
        dir.join(JOURNAL_FILE)
    }

    /// Start a fresh journal with the given campaign-config header,
    /// replacing any previous journal in `dir`.
    pub fn create(dir: &Path, header: &Json) -> Result<Journal> {
        let mut j = Journal { dir: dir.to_path_buf(), lines: vec![header.to_string()] };
        j.persist()?;
        Ok(j)
    }

    /// Load an existing journal: returns the config header, the journaled
    /// seed records keyed by seed index, and the journal handle positioned
    /// to append further records.
    pub fn open(dir: &Path) -> Result<(Json, BTreeMap<u64, Json>, Journal)> {
        let path = Journal::path_in(dir);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading fuzz journal {}", path.display()))?;
        let mut lines: Vec<String> = Vec::new();
        let mut header: Option<Json> = None;
        let mut records: BTreeMap<u64, Json> = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let Ok(j) = Json::parse(line) else {
                // torn tail from a non-atomic writer — drop it and
                // everything after (records are strictly sequential)
                break;
            };
            match j.get("type").as_str() {
                Some("config") if ln == 0 => {
                    header = Some(j);
                }
                Some("seed") => {
                    let Some(idx) = j.get("index").as_usize() else { break };
                    records.insert(idx as u64, j);
                }
                _ => bail!(
                    "{}: line {} is neither a config header nor a seed record",
                    path.display(),
                    ln + 1
                ),
            }
            lines.push(line.to_string());
        }
        let header = header
            .with_context(|| format!("{}: missing config header line", path.display()))?;
        Ok((header, records, Journal { dir: dir.to_path_buf(), lines }))
    }

    /// Append one seed record durably (write temp + fsync + atomic rename).
    pub fn append(&mut self, record: &Json) -> Result<()> {
        self.lines.push(record.to_string());
        self.persist()
    }

    fn persist(&self) -> Result<()> {
        let tmp = self.dir.join(TMP_FILE);
        let path = Journal::path_in(&self.dir);
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            for line in &self.lines {
                f.write_all(line.as_bytes())?;
                f.write_all(b"\n")?;
            }
            f.sync_all().context("fsyncing fuzz journal")?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic on failure by design
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gg_journal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn header() -> Json {
        Json::obj(vec![
            ("type", Json::str("config")),
            ("seeds", Json::num(4.0)),
            ("base_seed", Json::str("0x0")),
        ])
    }

    fn seed_rec(i: u64) -> Json {
        Json::obj(vec![
            ("type", Json::str("seed")),
            ("index", Json::num(i as f64)),
            ("clean", Json::str("verified")),
        ])
    }

    #[test]
    fn roundtrip_create_append_open() {
        let d = tmpdir("roundtrip");
        let mut j = Journal::create(&d, &header()).unwrap();
        j.append(&seed_rec(0)).unwrap();
        j.append(&seed_rec(1)).unwrap();
        let (h, recs, mut j2) = Journal::open(&d).unwrap();
        assert_eq!(h.get("type").as_str(), Some("config"));
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[&1].get("clean").as_str(), Some("verified"));
        // appending through the reopened handle keeps earlier records
        j2.append(&seed_rec(2)).unwrap();
        let (_, recs, _) = Journal::open(&d).unwrap();
        assert_eq!(recs.len(), 3);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let d = tmpdir("torn");
        let mut j = Journal::create(&d, &header()).unwrap();
        j.append(&seed_rec(0)).unwrap();
        // simulate a non-atomic writer dying mid-line
        let path = Journal::path_in(&d);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"type\":\"seed\",\"index\":1,\"clean\":\"ver");
        std::fs::write(&path, text).unwrap();
        let (_, recs, _) = Journal::open(&d).unwrap();
        assert_eq!(recs.len(), 1, "torn record dropped");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_journal_is_clean_error() {
        let d = tmpdir("missing");
        let err = Journal::open(&d).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("journal"), "{msg}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn garbage_header_is_clean_error() {
        let d = tmpdir("garbage");
        std::fs::write(Journal::path_in(&d), "{\"type\":\"seed\",\"index\":0}\n").unwrap();
        let err = Journal::open(&d).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("config header") || msg.contains("neither"), "{msg}");
        let _ = std::fs::remove_dir_all(&d);
    }
}
