//! Monolithic whole-graph equivalence checker — the Aerify/Tensat-style
//! baseline GraphGuard's iterative approach is compared against (§7).
//!
//! Instead of processing one `G_s` operator at a time in a fresh e-graph,
//! this checker builds a SINGLE e-graph containing all of `G_s`, all of
//! `G_d`'s definitional equalities, and the input relation, then saturates
//! globally and asks whether each `G_s` output class contains a clean
//! expression over `G_d` outputs. Sound, but the e-graph grows with the
//! whole model, so saturation cost explodes with graph size — the
//! scalability gap `benches/baseline_compare.rs` measures.

use crate::egraph::{extract_clean, saturate, EGraph, RewriteCtx, SatStats, SaturationLimits};
use crate::expr::{Side, TensorRef};
use crate::ir::Graph;
use crate::lemmas;
use crate::relation::Relation;
use anyhow::{bail, Result};

pub struct BaselineOutput {
    pub relation: Relation,
    pub stats: SatStats,
    pub egraph_nodes: usize,
}

pub fn check_refinement_monolithic(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    limits: SaturationLimits,
) -> Result<BaselineOutput> {
    let rules = lemmas::standard_rewrites();
    let ctx = RewriteCtx::default();
    let mut eg = EGraph::new();

    // all of G_s as expressions over S-leaves
    let mut s_class = vec![0u32; gs.num_tensors()];
    for &i in &gs.inputs {
        s_class[i as usize] = eg.add_leaf(TensorRef::s(i), gs.shape(i).to_vec());
    }
    for nid in gs.topo_order() {
        let node = gs.node(nid);
        let children = node.inputs.iter().map(|&t| s_class[t as usize]).collect();
        s_class[node.output as usize] = eg
            .add_op(node.op.clone(), children)
            .map_err(|e| anyhow::anyhow!("G_s node '{}': {e}", node.name))?;
    }
    // all of G_d's definitional equalities
    for &i in &gd.inputs {
        eg.add_leaf(TensorRef::d(i), gd.shape(i).to_vec());
    }
    for nid in gd.topo_order() {
        let node = gd.node(nid);
        let children = node
            .inputs
            .iter()
            .map(|&t| eg.add_leaf(TensorRef::d(t), gd.shape(t).to_vec()))
            .collect();
        let out = eg.add_leaf(TensorRef::d(node.output), gd.shape(node.output).to_vec());
        if let Ok(def) = eg.add_op(node.op.clone(), children) {
            let _ = eg.union(out, def);
        }
    }
    // input relation
    let gd_leaf_shape = |t: TensorRef| (t.side == Side::D).then(|| gd.shape(t.id).to_vec());
    for t in ri.tensors() {
        for cand in ri.get(t) {
            if let Ok(root) = eg.add_expr(&cand.expr, &gd_leaf_shape) {
                let _ = eg.union(s_class[t as usize], root);
            }
        }
    }
    eg.rebuild();

    // one global saturation
    let stats = saturate(&mut eg, &rules, &ctx, limits);

    // extract clean mappings for each G_s output
    let cands = extract_clean(&eg, &|t| t.side == Side::D);
    let mut rel = Relation::new();
    for &o in &gs.outputs {
        let class = eg.find(s_class[o as usize]);
        match cands.get(&class) {
            Some(cs) if !cs.is_empty() => rel.insert_all(o, cs.iter().cloned()),
            // Same soundness-of-reporting rule as `infer`: a budget-cut
            // saturation with no mapping is INCONCLUSIVE, not a refutation.
            _ if stats.exhausted.is_some() => bail!(
                "monolithic baseline: INCONCLUSIVE ({:?} budget exhausted) — no clean \
                 mapping found for output '{}' within limits; this is a resource \
                 verdict, not a refutation",
                stats.exhausted.unwrap(),
                gs.tensor(o).name
            ),
            _ => bail!(
                "monolithic baseline: no clean mapping for output '{}'",
                gs.tensor(o).name
            ),
        }
    }
    Ok(BaselineOutput { relation: rel, stats, egraph_nodes: eg.n_nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn baseline_agrees_on_running_example() {
        // same workload as infer::tests::running_example
        let mut gs = Graph::new("gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let e = gs.input("E", vec![4, 4]);
        let c = gs.matmul("C", a, b);
        let f = gs.sub2("F", c, e);
        gs.mark_output(f);

        let mut gd = Graph::new("gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let b2 = gd.input("B_2", vec![3, 4]);
        let e1 = gd.input("E_1", vec![2, 4]);
        let e2 = gd.input("E_2", vec![2, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        let c2 = gd.matmul("C_2", a2, b2);
        let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
        let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
        let f1 = gd.sub2("F_1", d1, e1);
        let f2 = gd.sub2("F_2", d2, e2);
        let ff = gd.all_gather("F_full", vec![f1, f2], 0);
        gd.mark_output(ff);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{"A": ["concat(A_1, A_2; dim=1)"],
                    "B": ["concat(B_1, B_2; dim=0)"],
                    "E": ["concat(E_1, E_2; dim=0)"]}"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let out = check_refinement_monolithic(
            &gs,
            &gd,
            &ri,
            SaturationLimits::new(12, 200_000),
        )
        .unwrap();
        assert!(out.relation.contains(gs.tensor_by_name("F").unwrap()));
        crate::infer::verify_numeric(&gs, &gd, &ri, &out.relation, 3).unwrap();
    }

    #[test]
    fn baseline_egraph_grows_with_whole_model() {
        // the structural reason the iterative approach wins: baseline node
        // count covers BOTH graphs at once.
        let mut gs = Graph::new("gs");
        let mut x = gs.input("x", vec![4, 4]);
        for i in 0..6 {
            x = gs.op(&format!("g{i}"), crate::ir::Op::Gelu, vec![x]);
        }
        gs.mark_output(x);
        let mut gd = Graph::new("gd");
        let mut y = gd.input("x_0", vec![4, 4]);
        for i in 0..6 {
            y = gd.op(&format!("g{i}_0"), crate::ir::Op::Gelu, vec![y]);
        }
        gd.mark_output(y);
        let ri =
            Relation::from_json(&Json::parse(r#"{"x": ["x_0"]}"#).unwrap(), &gs, &gd).unwrap();
        let out = check_refinement_monolithic(&gs, &gd, &ri, Default::default()).unwrap();
        assert!(out.egraph_nodes >= 12, "holds both graphs: {}", out.egraph_nodes);
    }
}
