//! Versioned request/response schema for `graphguard serve`.
//!
//! Wire format: newline-delimited JSON — one request object per line in,
//! one response object per line out (responses are compact single-line
//! JSON; the hand-rolled serializer never emits raw newlines). Schema
//! documented in EXPERIMENTS.md §Serve.
//!
//! Requests (`schema_version` optional, v0 = current layout):
//! - named workload: `{"id": "r1", "workload": "gpt_tp_sp_2", "ranks": 2}`
//!   (`ranks` bounded to 1..=[`MAX_RANKS`])
//! - inline pair:    `{"id": "r2", "gs": {…}, "gd": {…}, "ri": {…}}`
//! - patch (either payload + `"patch"`): incremental re-verification —
//!   the [`crate::ir::GraphPatch`] is applied to `G_d`, the impact
//!   analysis classifies the dirty cone, and only non-Clean regions
//!   re-saturate. A patch is *targeted* cache invalidation: edited
//!   regions miss on their new fingerprints naturally; the shared cache
//!   is never flushed.
//! - per-request overrides: `"jobs"`, `"deadline_ms"` (0 disables),
//!   `"no_cache"`, `"escalate"`, `"max_iters"`, `"max_nodes"`.
//!
//! Responses always carry `schema_version`, the echoed `id` (the client's
//! original JSON value, whatever its type), and a
//! `verdict` tag (`verified` / `refuted` / `inconclusive_*` / `error`);
//! verdict-specific fields are documented on [`verdict_response`].

// The request stream is an untrusted input path (arbitrary bytes from a
// client): parse errors must become structured error responses, never
// panics. Enforced via clippy.toml's disallowed-methods list.
#![deny(clippy::disallowed_methods)]

use crate::ir::{self, Graph};
use crate::relation::Relation;
use crate::util::json::Json;
use crate::util::schema;

/// What a request asks to verify.
#[derive(Debug)]
pub enum Payload {
    /// A named Table-2 workload (resolved by the serve loop), at `ranks`.
    Workload { name: String, ranks: usize },
    /// An inline `(G_s, G_d, R_i)` triple, already parsed and validated.
    Inline { gs: Box<Graph>, gd: Box<Graph>, ri: Relation },
}

/// Largest accepted `ranks` in a workload request: every Table-2 builder
/// tops out far below this, and the bound keeps a client from demanding
/// arbitrarily large graph builds (each distinct degree also occupies a
/// slot in the serve loop's bounded workload memo).
pub const MAX_RANKS: usize = 64;

/// One parsed request line.
#[derive(Debug)]
pub struct Request {
    /// Client-chosen correlation id — any JSON value, echoed verbatim
    /// (same type, not stringified) in the response.
    pub id: Option<Json>,
    pub payload: Payload,
    /// Per-request overrides of the server's base config.
    pub jobs: Option<usize>,
    /// `Some(0)` disables the per-region deadline.
    pub deadline_ms: Option<u64>,
    pub no_cache: bool,
    /// Run under the default escalation policy instead of a single
    /// isolated attempt.
    pub escalate: bool,
    pub max_iters: Option<usize>,
    pub max_nodes: Option<usize>,
    /// Incremental re-verification: apply this patch to the payload's
    /// `G_d` and verify the patched pair with warm certificates.
    pub patch: Option<ir::GraphPatch>,
}

/// A request that could not be parsed: the id when it was recoverable,
/// plus the message for the structured error response.
#[derive(Debug)]
pub struct BadRequest {
    pub id: Option<Json>,
    pub error: String,
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => v.as_usize().map(Some).ok_or_else(|| format!("field '{key}' must be a number")),
    }
}

fn opt_flag(j: &Json, key: &str) -> Result<bool, String> {
    match j.get(key) {
        Json::Null => Ok(false),
        v => v.as_bool().ok_or_else(|| format!("field '{key}' must be a boolean")),
    }
}

/// Parse one request line. Every failure carries the id when the line was
/// at least valid JSON with one, so the client can correlate the error.
pub fn parse_request(line: &str) -> Result<Request, BadRequest> {
    let j = Json::parse(line)
        .map_err(|e| BadRequest { id: None, error: format!("malformed request: {e}") })?;
    let id = match j.get("id") {
        Json::Null => None,
        v => Some(v.clone()),
    };
    let fail = |error: String| BadRequest { id: id.clone(), error };
    schema::check(&j, "serve request").map_err(|e| fail(format!("{e:#}")))?;

    let payload = match j.get("workload") {
        Json::Null => {
            let (gs_j, gd_j, ri_j) = (j.get("gs"), j.get("gd"), j.get("ri"));
            if matches!(gs_j, Json::Null)
                || matches!(gd_j, Json::Null)
                || matches!(ri_j, Json::Null)
            {
                return Err(fail(
                    "request needs either 'workload' or all of 'gs'/'gd'/'ri'".into(),
                ));
            }
            let gs = ir::json_io::from_json(gs_j)
                .map_err(|e| fail(format!("bad 'gs' graph: {e:#}")))?;
            let gd = ir::json_io::from_json(gd_j)
                .map_err(|e| fail(format!("bad 'gd' graph: {e:#}")))?;
            let ri = Relation::from_json(ri_j, &gs, &gd)
                .map_err(|e| fail(format!("bad 'ri' relation: {e:#}")))?;
            ri.validate_shapes(&gs, &gd)
                .map_err(|e| fail(format!("bad 'ri' relation: {e:#}")))?;
            Payload::Inline { gs: Box::new(gs), gd: Box::new(gd), ri }
        }
        w => {
            let name = w
                .as_str()
                .ok_or_else(|| fail("field 'workload' must be a string".into()))?
                .to_string();
            let ranks = opt_usize(&j, "ranks").map_err(&fail)?.unwrap_or(2);
            if !(1..=MAX_RANKS).contains(&ranks) {
                return Err(fail(format!(
                    "field 'ranks' must be between 1 and {MAX_RANKS}, got {ranks}"
                )));
            }
            Payload::Workload { name, ranks }
        }
    };

    // All override fields parse before `id` moves into the Request —
    // `fail` borrows `id` to echo it in error responses.
    let jobs = opt_usize(&j, "jobs").map_err(&fail)?;
    let deadline_ms = match j.get("deadline_ms") {
        Json::Null => None,
        v => Some(
            v.as_f64()
                .filter(|n| *n >= 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| fail("field 'deadline_ms' must be a number".into()))?,
        ),
    };
    let no_cache = opt_flag(&j, "no_cache").map_err(&fail)?;
    let escalate = opt_flag(&j, "escalate").map_err(&fail)?;
    let max_iters = opt_usize(&j, "max_iters").map_err(&fail)?;
    let max_nodes = opt_usize(&j, "max_nodes").map_err(&fail)?;
    let patch = match j.get("patch") {
        Json::Null => None,
        p => Some(
            ir::GraphPatch::from_json(p).map_err(|e| fail(format!("bad 'patch': {e:#}")))?,
        ),
    };
    Ok(Request { id, payload, jobs, deadline_ms, no_cache, escalate, max_iters, max_nodes, patch })
}

fn id_field(id: Option<&Json>) -> Json {
    id.cloned().unwrap_or(Json::Null)
}

/// Base response object: `schema_version`, echoed `id` (the client's
/// original JSON value — a number stays a number), `verdict` tag.
fn base(id: Option<&Json>, verdict: &str) -> Vec<(&'static str, Json)> {
    vec![
        ("schema_version", schema::version_field()),
        ("id", id_field(id)),
        ("verdict", Json::str(verdict)),
    ]
}

/// Structured error response (`verdict: "error"`): malformed JSON, unknown
/// workload, bad graphs. The loop answers these and keeps serving — a
/// request error must never exit the process.
pub fn error_response(id: Option<&Json>, error: &str) -> Json {
    let mut fields = base(id, "error");
    fields.push(("error", Json::str(error)));
    Json::obj(fields)
}

/// Verdict-carrying response. `canonical` drops the fields that vary run
/// to run (wall time, per-region micros, cache counters) so responses are
/// byte-stable for golden diffing; verdict/locus content is identical
/// either way and matches the one-shot CLI's output strings.
#[allow(clippy::too_many_arguments)] // wire-shape assembly, not an API surface
pub fn verdict_response(
    id: Option<&Json>,
    verdict: &crate::infer::Verdict,
    gs: &Graph,
    gd: &Graph,
    lint: &[crate::analysis::LintFinding],
    attempts: usize,
    wall_us: u64,
    canonical: bool,
    impact: Option<&crate::analysis::ImpactReport>,
) -> Json {
    use crate::infer::Verdict;
    let mut fields = base(id, verdict.tag());
    fields.push(("attempts", Json::num(attempts as f64)));
    fields.push(("lint", Json::Arr(lint.iter().map(|f| f.to_json()).collect())));
    if let Some(imp) = impact {
        // Deterministic (no timings) — present in canonical mode too, so
        // golden diffs pin the classification alongside the verdict.
        fields.push(("impact", imp.to_json()));
    }
    match verdict {
        Verdict::Verified(out) => {
            // Exactly the relation JSON `graphguard verify` prints.
            fields.push(("relation", out.relation.to_json(gs, gd)));
            fields.push(("mappings", Json::num(out.relation.len() as f64)));
            if !canonical {
                fields.push(("cache_hits", Json::num(out.cache_hits as f64)));
                fields.push(("cache_misses", Json::num(out.cache_misses as f64)));
                fields.push((
                    "per_region",
                    Json::Arr(
                        out.per_node
                            .iter()
                            .map(|t| {
                                Json::obj(vec![
                                    ("node", Json::str(t.node_name.clone())),
                                    ("micros", Json::num(t.micros as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
        }
        // The same Display strings the one-shot CLI prints — byte-identical
        // verdict/locus content between serve and `graphguard verify`.
        Verdict::Refuted(e) => {
            fields.push(("error", Json::str(format!("{e}"))));
            fields.push(("locus", Json::str(e.node_name.clone())));
            fields.push(("op", Json::str(e.op.clone())));
        }
        Verdict::Inconclusive(i) => {
            fields.push(("error", Json::str(format!("{i}"))));
            fields.push(("reason", Json::str(i.reason.tag())));
            fields.push(("region", Json::str(i.region.clone())));
        }
    }
    if !canonical {
        fields.push(("wall_us", Json::num(wall_us as f64)));
    }
    Json::obj(fields)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic on failure by design
mod tests {
    use super::*;

    #[test]
    fn workload_request_parses_with_defaults() {
        let r = parse_request(r#"{"id":"a","workload":"gpt_tp_sp_2"}"#).unwrap();
        assert_eq!(r.id, Some(Json::str("a")));
        let Payload::Workload { name, ranks } = r.payload else { panic!("workload") };
        assert_eq!((name.as_str(), ranks), ("gpt_tp_sp_2", 2));
        assert!(!r.no_cache && !r.escalate);
        assert!(r.jobs.is_none() && r.deadline_ms.is_none());
    }

    #[test]
    fn overrides_parse() {
        let r = parse_request(
            r#"{"workload":"x","ranks":4,"jobs":3,"deadline_ms":0,"no_cache":true,
                "escalate":true,"max_iters":5,"max_nodes":1000}"#,
        )
        .unwrap();
        assert_eq!(r.jobs, Some(3));
        assert_eq!(r.deadline_ms, Some(0));
        assert!(r.no_cache && r.escalate);
        assert_eq!((r.max_iters, r.max_nodes), (Some(5), Some(1000)));
    }

    #[test]
    fn malformed_json_reports_without_id() {
        let e = parse_request("not json").unwrap_err();
        assert!(e.id.is_none());
        assert!(e.error.contains("malformed"), "{}", e.error);
    }

    #[test]
    fn bad_field_recovers_the_id() {
        let e = parse_request(r#"{"id":"r9","workload":"w","jobs":"three"}"#).unwrap_err();
        assert_eq!(e.id, Some(Json::str("r9")));
        assert!(e.error.contains("jobs"), "{}", e.error);
    }

    #[test]
    fn non_string_id_round_trips_as_its_original_json_value() {
        let r = parse_request(r#"{"id":42,"workload":"w"}"#).unwrap();
        assert_eq!(r.id, Some(Json::num(42.0)), "id must keep the client's value type");
        let resp = error_response(r.id.as_ref(), "boom");
        assert_eq!(resp.get("id"), &Json::num(42.0));
        assert_eq!(resp.get("id").to_string(), "42", "serialized as a bare number, not \"42\"");
    }

    #[test]
    fn out_of_range_ranks_rejected_at_parse_time() {
        for bad in [r#"{"workload":"w","ranks":0}"#, r#"{"workload":"w","ranks":1000000}"#] {
            let e = parse_request(bad).unwrap_err();
            assert!(
                e.error.contains(&MAX_RANKS.to_string()),
                "ranks bound error names the limit: {}",
                e.error
            );
        }
        let r = parse_request(r#"{"workload":"w","ranks":64}"#).unwrap();
        let Payload::Workload { ranks, .. } = r.payload else { panic!("workload") };
        assert_eq!(ranks, MAX_RANKS);
    }

    #[test]
    fn patch_field_parses_and_rejects_malformed_patches() {
        let r = parse_request(
            r#"{"id":"p1","workload":"gpt_tp_sp_2",
                "patch":{"name":"edit","ops":[{"kind":"retag","node":"snd","chan":3}]}}"#,
        )
        .unwrap();
        let p = r.patch.expect("patch parsed");
        assert_eq!(p.name, "edit");
        assert_eq!(p.ops.len(), 1);

        let e = parse_request(
            r#"{"id":"p2","workload":"w","patch":{"ops":[{"kind":"frobnicate"}]}}"#,
        )
        .unwrap_err();
        assert_eq!(e.id, Some(Json::str("p2")));
        assert!(e.error.contains("patch"), "{}", e.error);
    }

    #[test]
    fn missing_payload_is_an_error() {
        let e = parse_request(r#"{"id":"x"}"#).unwrap_err();
        assert!(e.error.contains("workload"), "{}", e.error);
    }

    #[test]
    fn version_mismatch_names_both_versions() {
        let e = parse_request(r#"{"id":"v","workload":"w","schema_version":42}"#).unwrap_err();
        assert!(e.error.contains("42"), "{}", e.error);
        assert!(
            e.error.contains(&schema::SCHEMA_VERSION.to_string()),
            "{}",
            e.error
        );
    }

    #[test]
    fn error_response_shape() {
        let r = error_response(Some(&Json::str("q")), "boom");
        assert_eq!(r.get("verdict").as_str(), Some("error"));
        assert_eq!(r.get("id").as_str(), Some("q"));
        assert_eq!(r.get("error").as_str(), Some("boom"));
        assert_eq!(
            r.get("schema_version").as_usize(),
            Some(schema::SCHEMA_VERSION as usize)
        );
    }
}
