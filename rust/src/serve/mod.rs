//! `graphguard serve`: a long-lived verification service.
//!
//! Reads newline-delimited JSON requests from any [`BufRead`] (stdin by
//! default, or one Unix-socket connection at a time), answers each on the
//! paired [`Write`], and keeps a single [`FingerprintCache`] warm across
//! requests — the amortization a one-shot CLI run cannot get. Request and
//! response schema live in [`protocol`]; the versioning policy and the
//! determinism contract are documented in EXPERIMENTS.md §Serve.
//!
//! Failure containment: a malformed line, an unknown workload name, or a
//! bad inline graph produces a structured `verdict: "error"` response and
//! the loop moves on. Verification itself runs panic-isolated (or under
//! escalation when the request asks), so a crashing lemma applier yields
//! `inconclusive_panic`, not a dead server. Only transport errors (broken
//! pipe, unreadable socket) end the loop.

pub mod protocol;

use crate::analysis;
use crate::cache::FingerprintCache;
use crate::egraph::SaturationLimits;
use crate::infer::{EscalationPolicy, InferConfig, Verdict};
use crate::models::{self, Workload};
use crate::util::json::Json;
use crate::verifier::Verifier;
use anyhow::{Context, Result};
use protocol::{Payload, Request};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server-side knobs: the base [`InferConfig`] every request starts from,
/// the cache shared across requests, and whether responses are canonical
/// (run-varying fields dropped; see [`protocol::verdict_response`]).
pub struct ServeOptions {
    pub cfg: InferConfig,
    pub cache: Option<Arc<FingerprintCache>>,
    pub canonical: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            cfg: InferConfig::default(),
            cache: Some(Arc::new(FingerprintCache::new())),
            canonical: false,
        }
    }
}

/// What the loop did, for the operator summary on stderr (stdout is the
/// protocol stream and must carry nothing but responses).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: u64,
    pub verified: u64,
    pub refuted: u64,
    pub inconclusive: u64,
    pub errors: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// How many distinct `ranks` values keep their built workload table in
/// memory at once. Combined with the `ranks` bound in
/// [`protocol::parse_request`], this keeps a client sweeping `ranks`
/// values from growing server memory without limit.
const WORKLOAD_MEMO_CAP: usize = 4;

/// Named workloads are rebuilt per distinct `ranks`, then reused for the
/// rest of the session; the memo is bounded (FIFO eviction at
/// [`WORKLOAD_MEMO_CAP`] entries). A degree the model builders reject
/// (e.g. heads not divisible by `ranks`) is a request error, never a
/// panic — the client gets a structured response and the loop keeps
/// serving.
#[derive(Default)]
struct WorkloadTable {
    by_ranks: BTreeMap<usize, Vec<Workload>>,
    /// Insertion order of `by_ranks` keys, oldest first, for eviction.
    order: VecDeque<usize>,
}

impl WorkloadTable {
    fn find(&mut self, name: &str, ranks: usize) -> Result<&Workload, String> {
        if !self.by_ranks.contains_key(&ranks) {
            let table = models::try_table2_workloads(ranks)
                .map_err(|e| format!("cannot build workloads at ranks={ranks}: {e:#}"))?;
            if self.by_ranks.len() >= WORKLOAD_MEMO_CAP {
                if let Some(oldest) = self.order.pop_front() {
                    self.by_ranks.remove(&oldest);
                }
            }
            self.order.push_back(ranks);
            self.by_ranks.insert(ranks, table);
        }
        let table = &self.by_ranks[&ranks];
        match table.iter().position(|w| w.name == name) {
            Some(i) => Ok(&table[i]),
            None => {
                let known: Vec<&str> = table.iter().map(|w| w.name.as_str()).collect();
                Err(format!(
                    "unknown workload '{name}' at ranks={ranks}; known: {}",
                    known.join(", ")
                ))
            }
        }
    }
}

/// Per-request [`Verifier`]: the server's base config plus this request's
/// overrides. Default mode is a single panic-isolated attempt with the
/// shared cache — the same configuration `graphguard verify` runs, so
/// verdict and locus content are byte-identical to the one-shot CLI.
fn verifier_for(req: &Request, opts: &ServeOptions) -> Verifier {
    let mut cfg = opts.cfg.clone();
    cfg.cache = if req.no_cache { None } else { opts.cache.clone() };
    if let Some(jobs) = req.jobs {
        cfg.jobs = jobs.max(1);
    }
    if let Some(ms) = req.deadline_ms {
        cfg.region_deadline = if ms == 0 { None } else { Some(Duration::from_millis(ms)) };
    }
    if req.max_iters.is_some() || req.max_nodes.is_some() {
        cfg.limits = SaturationLimits::new(
            req.max_iters.unwrap_or(cfg.limits.max_iters),
            req.max_nodes.unwrap_or(cfg.limits.max_nodes),
        );
    }
    let v = Verifier::with_config(cfg);
    if req.escalate {
        v.escalation(EscalationPolicy::default())
    } else {
        v.isolated(true)
    }
}

fn answer(req: &Request, opts: &ServeOptions, workloads: &mut WorkloadTable) -> Json {
    let id = req.id.as_ref();
    let verifier = verifier_for(req, opts);
    let (gs, gd, ri) = match &req.payload {
        Payload::Inline { gs, gd, ri } => (gs.as_ref(), gd.as_ref(), ri),
        Payload::Workload { name, ranks } => match workloads.find(name, *ranks) {
            Ok(w) => (&w.gs, &w.gd, &w.ri),
            Err(msg) => return protocol::error_response(id, &msg),
        },
    };
    let started = Instant::now();
    if let Some(patch) = &req.patch {
        // Incremental path: a patch is *targeted* cache invalidation —
        // edited regions miss on their new fingerprints naturally, clean
        // regions replay certificates the earlier requests (or the warm-up
        // pass inside `reverify`) deposited. The shared cache is never
        // flushed. Structural failures (invalid patch, deleted relation
        // leaves) are request errors; the loop keeps serving.
        let rv = match verifier.reverify(gs, gd, ri, patch) {
            Ok(rv) => rv,
            Err(e) => return protocol::error_response(id, &format!("{e:#}")),
        };
        let wall_us = started.elapsed().as_micros() as u64;
        let lint = analysis::analyze(&rv.patched, Some(&rv.ri)).findings;
        return protocol::verdict_response(
            id,
            &rv.verdict,
            gs,
            &rv.patched,
            &lint,
            rv.attempts,
            wall_us,
            opts.canonical,
            Some(&rv.impact),
        );
    }
    let (verdict, attempts) = verifier.run_counted(gs, gd, ri);
    let wall_us = started.elapsed().as_micros() as u64;
    let lint = analysis::analyze(gd, Some(ri)).findings;
    protocol::verdict_response(
        id, &verdict, gs, gd, &lint, attempts, wall_us, opts.canonical, None,
    )
}

fn tally(stats: &mut ServeStats, response: &Json) {
    match response.get("verdict").as_str() {
        Some("verified") => stats.verified += 1,
        Some("refuted") => stats.refuted += 1,
        Some(tag) if tag.starts_with("inconclusive") => stats.inconclusive += 1,
        _ => stats.errors += 1,
    }
}

/// The request loop: one response line per request line, in order, flushed
/// after every response so pipelined clients never deadlock. Returns when
/// the reader reaches EOF. Transport failures are the only errors.
pub fn serve_loop<R: BufRead, W: Write>(
    reader: R,
    writer: &mut W,
    opts: &ServeOptions,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let mut workloads = WorkloadTable::default();
    for line in reader.lines() {
        let line = line.context("reading request stream")?;
        if line.trim().is_empty() {
            continue;
        }
        stats.requests += 1;
        let response = match protocol::parse_request(&line) {
            Ok(req) => answer(&req, opts, &mut workloads),
            Err(bad) => protocol::error_response(bad.id.as_ref(), &bad.error),
        };
        tally(&mut stats, &response);
        writeln!(writer, "{response}").context("writing response stream")?;
        writer.flush().context("flushing response stream")?;
    }
    if let Some(cache) = &opts.cache {
        let s = cache.stats();
        stats.cache_hits = s.hits;
        stats.cache_misses = s.misses;
    }
    Ok(stats)
}

/// Serve over stdin/stdout until EOF.
pub fn serve_stdio(opts: &ServeOptions) -> Result<ServeStats> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    serve_loop(stdin.lock(), &mut out, opts)
}

/// Serve over a Unix socket: accept connections sequentially, running the
/// request loop to EOF on each, sharing one cache across all of them.
/// A pre-existing socket file at `path` is replaced. Accepts forever —
/// the operator stops the server with a signal; per-connection stats go
/// to stderr. One client's transport failure (e.g. disconnecting before
/// reading its responses) only ends that connection — the next client is
/// accepted as usual. Only listener/accept failures are fatal.
#[cfg(unix)]
pub fn serve_unix(path: &std::path::Path, opts: &ServeOptions) -> Result<()> {
    use std::os::unix::net::UnixListener;
    if path.exists() {
        std::fs::remove_file(path)
            .with_context(|| format!("removing stale socket {}", path.display()))?;
    }
    let listener = UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    for conn in listener.incoming() {
        let conn = conn.context("accepting connection")?;
        let reader = match conn.try_clone() {
            Ok(c) => std::io::BufReader::new(c),
            Err(e) => {
                eprintln!("serve: dropping connection (cloning socket: {e})");
                continue;
            }
        };
        let mut writer = conn;
        match serve_loop(reader, &mut writer, opts) {
            Ok(stats) => eprintln!(
                "serve: connection closed after {} request(s) ({} verified, {} refuted, \
                 {} inconclusive, {} errors)",
                stats.requests, stats.verified, stats.refuted, stats.inconclusive, stats.errors
            ),
            Err(e) => eprintln!("serve: connection aborted ({e:#}); still accepting"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn run(lines: &str, opts: &ServeOptions) -> (Vec<Json>, ServeStats) {
        let mut out = Vec::new();
        let stats = serve_loop(Cursor::new(lines.as_bytes()), &mut out, opts).unwrap();
        let text = String::from_utf8(out).unwrap();
        let responses =
            text.lines().map(|l| Json::parse(l).expect("response is valid json")).collect();
        (responses, stats)
    }

    #[test]
    fn workload_request_round_trips() {
        let (rs, stats) = run(
            "{\"id\":\"w1\",\"workload\":\"gpt_tp_sp_2\",\"ranks\":2}\n",
            &ServeOptions::default(),
        );
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].get("id").as_str(), Some("w1"));
        assert_eq!(rs[0].get("verdict").as_str(), Some("verified"));
        assert_eq!(stats.verified, 1);
    }

    #[test]
    fn malformed_and_unknown_lines_do_not_stop_the_loop() {
        let input = "garbage\n\
                     {\"id\":\"u\",\"workload\":\"no_such_model\"}\n\
                     {\"id\":\"ok\",\"workload\":\"qwen2_tp_2\"}\n";
        let (rs, stats) = run(input, &ServeOptions::default());
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].get("verdict").as_str(), Some("error"));
        assert_eq!(rs[1].get("verdict").as_str(), Some("error"));
        assert!(
            rs[1].get("error").as_str().unwrap_or("").contains("qwen2_tp_2"),
            "unknown-workload error names the known workloads"
        );
        assert_eq!(rs[2].get("verdict").as_str(), Some("verified"));
        assert_eq!((stats.errors, stats.verified), (2, 1));
    }

    #[test]
    fn incompatible_ranks_is_a_request_error_not_a_crash() {
        // heads=4 % ranks=3 fails inside the gpt builder: the untrusted
        // request must get a structured error (id echoed) and the loop must
        // keep serving — this used to panic out of the whole process.
        let input = "{\"id\":3,\"workload\":\"gpt_tp_sp_3\",\"ranks\":3}\n\
                     {\"id\":\"after\",\"workload\":\"qwen2_tp_2\",\"ranks\":2}\n";
        let (rs, stats) = run(input, &ServeOptions::default());
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("verdict").as_str(), Some("error"));
        assert_eq!(rs[0].get("id"), &Json::num(3.0), "numeric id echoed as a number");
        let msg = rs[0].get("error").as_str().unwrap_or("");
        assert!(msg.contains("ranks=3"), "error names the degree: {msg}");
        assert_eq!(rs[1].get("verdict").as_str(), Some("verified"));
        assert_eq!((stats.errors, stats.verified), (1, 1));
    }

    #[test]
    fn workload_memo_stays_bounded_under_a_ranks_sweep() {
        let mut table = WorkloadTable::default();
        // degrees the builders reject never occupy a memo slot
        for ranks in 1..=16usize {
            let _ = table.find("no_such_workload", ranks);
        }
        assert!(
            table.by_ranks.len() <= WORKLOAD_MEMO_CAP,
            "memo holds {} entries, cap is {WORKLOAD_MEMO_CAP}",
            table.by_ranks.len()
        );
        // a full memo evicts its oldest entry instead of growing
        let mut table = WorkloadTable::default();
        for r in [7usize, 9, 11, 13] {
            table.order.push_back(r);
            table.by_ranks.insert(r, Vec::new());
        }
        table.find("no_such_workload", 2).expect_err("unknown workload");
        assert_eq!(table.by_ranks.len(), WORKLOAD_MEMO_CAP);
        assert!(!table.by_ranks.contains_key(&7), "oldest entry evicted");
        assert!(table.by_ranks.contains_key(&2), "fresh entry memoized");
    }

    #[test]
    fn canonical_mode_drops_run_varying_fields() {
        let opts = ServeOptions { canonical: true, ..ServeOptions::default() };
        let (rs, _) = run("{\"workload\":\"gpt_tp_sp_2\"}\n", &opts);
        assert!(matches!(rs[0].get("wall_us"), Json::Null));
        assert!(matches!(rs[0].get("cache_hits"), Json::Null));
        assert!(matches!(rs[0].get("per_region"), Json::Null));
        assert!(!matches!(rs[0].get("relation"), Json::Null));
    }

    #[test]
    fn patch_requests_reverify_and_report_impact() {
        let opts = ServeOptions { canonical: true, ..ServeOptions::default() };
        // warm the cache, then a noop patch: every region must classify
        // Clean and the verdict must match the plain request's
        let input = "{\"id\":1,\"workload\":\"gpt_tp_sp_2\"}\n\
                     {\"id\":2,\"workload\":\"gpt_tp_sp_2\",\
                      \"patch\":{\"name\":\"noop\",\"ops\":[]}}\n\
                     {\"id\":3,\"workload\":\"gpt_tp_sp_2\",\
                      \"patch\":{\"ops\":[{\"kind\":\"rewire\",\"node\":\"nope\",\
                      \"slot\":0,\"tensor\":\"x\"}]}}\n";
        let (rs, stats) = run(input, &opts);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].get("verdict").as_str(), Some("verified"));
        assert_eq!(rs[1].get("verdict").as_str(), Some("verified"));
        let impact = rs[1].get("impact");
        assert!(!matches!(impact, Json::Null), "patch response carries impact");
        assert_eq!(
            impact.get("dirty").as_usize(),
            Some(0),
            "noop patch dirties nothing: {impact}"
        );
        assert_eq!(
            rs[0].get("relation").to_string(),
            rs[1].get("relation").to_string(),
            "incremental relation must be byte-identical to the full run's"
        );
        // structural patch failure = request error, loop keeps serving
        assert_eq!(rs[2].get("verdict").as_str(), Some("error"));
        assert_eq!((stats.verified, stats.errors), (2, 1));
        assert!(stats.cache_hits > 0, "clean regions replayed certificates");
    }

    #[test]
    fn shared_cache_warms_across_requests() {
        let opts = ServeOptions::default();
        let line = "{\"workload\":\"gpt_tp_sp_2\"}\n";
        let (_, stats) = run(&line.repeat(3), &opts);
        assert_eq!(stats.requests, 3);
        assert!(stats.cache_hits > 0, "repeat requests must hit the shared cache");
    }
}
