//! Verification coordinator (L3 service layer).
//!
//! The paper's tool runs one verification per model; at ByteDance scale a
//! team verifies many model/strategy/degree combinations per CI run. The
//! coordinator owns that loop: a work queue of [`Workload`]s, a thread pool
//! of verification workers (each inference call is independent — fresh
//! e-graphs per operator), wall-clock metrics per job, and report rendering
//! used by the CLI and the benches.
//!
//! Fault tolerance: every job — whether submitted through [`run_one`] or
//! [`run_batch`] — goes through the same `execute_job` path, which runs a
//! panic-isolated [`crate::verifier::Verifier`] under the coordinator's
//! [`EscalationPolicy`]. A panicking lemma applier poisons only its own
//! job (per-call e-graph arenas are dropped on unwind) and surfaces as
//! `Inconclusive(Panic)` with the payload in [`JobResult::error`]; the
//! worker thread and the rest of the batch keep running.
//!
//! [`run_one`]: Coordinator::run_one
//! [`run_batch`]: Coordinator::run_batch

use crate::infer::{EscalationPolicy, InconclusiveReason, InferConfig, NodeTiming, Verdict};
use crate::models::Workload;
use crate::verifier::Verifier;
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Job-level verdict: [`crate::infer::Verdict`] flattened to the fields a
/// report needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobVerdict {
    Verified,
    Refuted,
    Inconclusive(InconclusiveReason),
}

impl JobVerdict {
    /// Stable string tag (matches [`crate::infer::Verdict::tag`]).
    pub fn tag(self) -> &'static str {
        match self {
            JobVerdict::Verified => "verified",
            JobVerdict::Refuted => "refuted",
            JobVerdict::Inconclusive(InconclusiveReason::Timeout) => "inconclusive_timeout",
            JobVerdict::Inconclusive(InconclusiveReason::NodeBudget) => {
                "inconclusive_node_budget"
            }
            JobVerdict::Inconclusive(InconclusiveReason::Panic) => "inconclusive_panic",
        }
    }
}

#[derive(Debug)]
pub struct JobResult {
    pub name: String,
    /// `verdict == Verified` (kept for the many existing callers).
    pub ok: bool,
    pub verdict: JobVerdict,
    /// Escalation attempts spent (≥ 1).
    pub attempts: usize,
    pub duration: Duration,
    pub gs_ops: usize,
    pub gd_ops: usize,
    pub mappings: usize,
    pub lemma_applications: u64,
    /// per-lemma application counts (Fig 7 raw data)
    pub lemma_counts: Vec<(&'static str, u64)>,
    pub per_node: Vec<NodeTiming>,
    /// Fingerprint-cache counters for the *final* escalation attempt (both
    /// zero when no cache is configured or the job did not verify).
    /// Deterministic for `jobs = 1`; see [`crate::infer::InferOutput`].
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// ShardFlow static-analysis findings on `G_d` ([`crate::analysis`]).
    /// Attached for *every* verdict (the pass is independent of
    /// saturation), rendered by [`report_table`] as a lint column, and
    /// deliberately excluded from [`canonical_report`] — findings are
    /// diagnostics, not part of the verdict determinism surface.
    pub lint: Vec<crate::analysis::LintFinding>,
    pub error: Option<String>,
}

pub struct Coordinator {
    pub threads: usize,
    pub cfg: InferConfig,
    pub escalation: EscalationPolicy,
}

impl Default for Coordinator {
    fn default() -> Self {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).max(1);
        Coordinator {
            threads,
            cfg: InferConfig::default(),
            escalation: EscalationPolicy::default(),
        }
    }
}

impl Coordinator {
    pub fn new(threads: usize, cfg: InferConfig) -> Self {
        Coordinator { threads: threads.max(1), cfg, escalation: EscalationPolicy::default() }
    }

    pub fn with_escalation(mut self, policy: EscalationPolicy) -> Self {
        self.escalation = policy;
        self
    }

    /// The single execution path both `run_one` and `run_batch` use:
    /// panic-isolated inference under the escalation policy, timed.
    fn execute_job(&self, w: &Workload) -> JobResult {
        let t0 = Instant::now();
        let (verdict, attempts) = Verifier::with_config(self.cfg.clone())
            .escalation(self.escalation.clone())
            .run_counted(&w.gs, &w.gd, &w.ri);
        let duration = t0.elapsed();
        // ShardFlow findings accompany every verdict: the pass is
        // independent of saturation, so Refuted/Inconclusive jobs still get
        // their diagnostics (that is the triage value).
        let lint = crate::analysis::analyze(&w.gd, Some(&w.ri)).findings;
        let base = |verdict, error, lint| JobResult {
            name: w.name.clone(),
            ok: verdict == JobVerdict::Verified,
            verdict,
            attempts,
            duration,
            gs_ops: w.gs.num_nodes(),
            gd_ops: w.gd.num_nodes(),
            mappings: 0,
            lemma_applications: 0,
            lemma_counts: vec![],
            per_node: vec![],
            cache_hits: 0,
            cache_misses: 0,
            lint,
            error,
        };
        match verdict {
            Verdict::Verified(o) => {
                let mut counts: Vec<(&'static str, u64)> =
                    o.stats.applied.iter().map(|(&k, &v)| (k, v)).collect();
                counts.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
                JobResult {
                    mappings: o.relation.len(),
                    lemma_applications: o.stats.total_applications(),
                    lemma_counts: counts,
                    per_node: o.per_node,
                    cache_hits: o.cache_hits,
                    cache_misses: o.cache_misses,
                    ..base(JobVerdict::Verified, None, lint)
                }
            }
            Verdict::Refuted(e) => base(JobVerdict::Refuted, Some(format!("{e}")), lint),
            Verdict::Inconclusive(i) => {
                base(JobVerdict::Inconclusive(i.reason), Some(format!("{i}")), lint)
            }
        }
    }

    /// Verify a single workload, timing it. Same isolation and budgets as
    /// the batch path.
    pub fn run_one(&self, w: &Workload) -> JobResult {
        self.execute_job(w)
    }

    /// Verify a batch of workloads across the thread pool; results come
    /// back in submission order. With `threads == 1` this degrades to a
    /// strictly sequential run with identical verdicts and order.
    pub fn run_batch(&self, jobs: Vec<Workload>) -> Vec<JobResult> {
        // Warm the shared lemma library before spawning workers so no job's
        // wall-clock absorbs the one-time construction cost.
        let _ = crate::lemmas::standard_rewrites();
        let n = jobs.len();
        let queue: Arc<Mutex<VecDeque<(usize, Workload)>>> =
            Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
        let (tx, rx) = mpsc::channel::<(usize, JobResult)>();
        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(n.max(1)) {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let cfg = self.cfg.clone();
                let threads = self.threads;
                let escalation = self.escalation.clone();
                scope.spawn(move || {
                    let me = Coordinator { threads, cfg, escalation };
                    loop {
                        let job = queue.lock().unwrap().pop_front();
                        let Some((idx, w)) = job else { break };
                        let result = me.execute_job(&w);
                        if tx.send((idx, result)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
            for (idx, res) in rx {
                out[idx] = Some(res);
            }
            out.into_iter().map(|r| r.expect("worker delivered result")).collect()
        })
    }
}

/// Render the Fig-4-style verification table.
pub fn report_table(results: &[JobResult]) -> String {
    let w = results.iter().map(|r| r.name.len()).max().unwrap_or(8).max(8);
    let mut s = format!(
        "{:<w$}  {:>7}  {:>7}  {:>9}  {:>9}  {:>8}  {:>4}  result\n",
        "model", "ops(Gs)", "ops(Gd)", "time", "lemmas", "mappings", "lint",
    );
    for r in results {
        s.push_str(&format!(
            "{:<w$}  {:>7}  {:>7}  {:>9}  {:>9}  {:>8}  {:>4}  {}\n",
            r.name,
            r.gs_ops,
            r.gd_ops,
            crate::bench::fmt_dur(r.duration),
            r.lemma_applications,
            r.mappings,
            r.lint.len(),
            match r.verdict {
                JobVerdict::Verified => "refines".to_string(),
                JobVerdict::Refuted => "BUG".to_string(),
                JobVerdict::Inconclusive(reason) => format!("INCONCLUSIVE({reason})"),
            },
        ));
        for f in &r.lint {
            s.push_str(&format!("    lint [{}] at '{}': {}\n", f.code, f.node, f.detail));
        }
    }
    s
}

/// Render the byte-stable suite report used by the `--jobs N` determinism
/// gate: everything verdict-relevant (names, op counts, lemma totals,
/// mapping counts, attempts, verdicts, full error text) and nothing
/// timing-dependent. Wall-clock durations and cache hit/miss splits vary
/// run to run and across `jobs`/cache configurations while the verification
/// *results* must not, so they are excluded; `diff`ing this report across
/// `--jobs 1` / `--jobs 4` / `--no-cache` runs must yield zero bytes.
pub fn canonical_report(results: &[JobResult]) -> String {
    let w = results.iter().map(|r| r.name.len()).max().unwrap_or(8).max(8);
    let mut s = format!(
        "{:<w$}  {:>7}  {:>7}  {:>9}  {:>8}  {:>8}  result\n",
        "model", "ops(Gs)", "ops(Gd)", "lemmas", "mappings", "attempts",
    );
    for r in results {
        s.push_str(&format!(
            "{:<w$}  {:>7}  {:>7}  {:>9}  {:>8}  {:>8}  {}\n",
            r.name,
            r.gs_ops,
            r.gd_ops,
            r.lemma_applications,
            r.mappings,
            r.attempts,
            r.verdict.tag(),
        ));
        if let Some(err) = &r.error {
            for line in err.lines() {
                s.push_str("    | ");
                s.push_str(line);
                s.push('\n');
            }
        }
    }
    s
}

/// One-line cache summary for non-canonical CLI output.
pub fn cache_summary(results: &[JobResult]) -> String {
    let hits: u64 = results.iter().map(|r| r.cache_hits).sum();
    let misses: u64 = results.iter().map(|r| r.cache_misses).sum();
    let total = hits + misses;
    if total == 0 {
        "cache: disabled (0 lookups)".to_string()
    } else {
        format!(
            "cache: {hits}/{total} region hits ({:.1}%)",
            100.0 * hits as f64 / total as f64
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_runs_all_table2_workloads_in_parallel() {
        let jobs = crate::models::table2_workloads(2);
        let n = jobs.len();
        let names: Vec<String> = jobs.iter().map(|w| w.name.clone()).collect();
        let coord = Coordinator::new(4, InferConfig::default());
        let results = coord.run_batch(jobs);
        assert_eq!(results.len(), n);
        for (r, name) in results.iter().zip(&names) {
            assert_eq!(&r.name, name, "order preserved");
            assert!(r.ok, "{}: {:?}", r.name, r.error);
            assert_eq!(r.verdict, JobVerdict::Verified);
            assert!(r.attempts >= 1);
            assert!(r.duration > Duration::ZERO);
            assert!(r.lemma_applications > 0);
        }
        let table = report_table(&results);
        assert!(table.contains("refines"));
    }

    #[test]
    fn failing_workload_reports_error() {
        let (gs, gd, ri) = crate::models::regression::grad_accum_buggy_pair(2).unwrap();
        let w = Workload {
            name: "buggy".into(),
            gs,
            gd,
            ri,
            strategies: vec!["grad_accum"],
        };
        let coord = Coordinator::default();
        let r = coord.run_one(&w);
        assert!(!r.ok);
        assert_eq!(r.verdict, JobVerdict::Refuted, "a genuine bug must refute, not starve");
        assert!(r.error.as_deref().unwrap_or("").contains("FAILED"));
    }

    #[test]
    fn starved_budget_yields_inconclusive_job_not_bug() {
        let (gs, gd, ri) = crate::models::regression::grad_accum_buggy_pair(2).unwrap();
        let w = Workload { name: "starved".into(), gs, gd, ri, strategies: vec![] };
        let cfg = InferConfig {
            limits: crate::egraph::SaturationLimits::new(8, 10),
            ..InferConfig::default()
        };
        // single-shot so the tiny budget is not escalated away
        let coord =
            Coordinator::new(1, cfg).with_escalation(EscalationPolicy::single_shot());
        let r = coord.run_one(&w);
        assert!(!r.ok);
        assert!(
            matches!(r.verdict, JobVerdict::Inconclusive(_)),
            "budget exhaustion must not read as a refutation: {:?}",
            r.verdict
        );
        let table = report_table(&[r]);
        assert!(table.contains("INCONCLUSIVE"), "{table}");
    }

    #[test]
    fn canonical_report_excludes_timing_and_cache_split() {
        let r = JobResult {
            name: "m".into(),
            ok: true,
            verdict: JobVerdict::Verified,
            attempts: 1,
            duration: Duration::from_millis(123_456),
            gs_ops: 3,
            gd_ops: 9,
            mappings: 1,
            lemma_applications: 42,
            lemma_counts: vec![],
            per_node: vec![],
            cache_hits: 5,
            cache_misses: 1,
            lint: vec![crate::analysis::LintFinding::new(
                "partial_no_reduce",
                "b1_act",
                "must not appear in the canonical report",
            )],
            error: Some("refinement FAILED at operator 'x'\nsecond line".into()),
        };
        let s = canonical_report(std::slice::from_ref(&r));
        assert!(s.contains("verified"), "{s}");
        assert!(!s.contains("123"), "durations must not leak into the canonical report: {s}");
        assert!(!s.contains("hits"), "cache split must not leak into the canonical report: {s}");
        assert!(
            !s.contains("partial_no_reduce"),
            "lint findings must not leak into the canonical report: {s}"
        );
        assert!(s.contains("    | refinement FAILED"), "{s}");
        assert!(s.contains("    | second line"), "{s}");
        assert!(cache_summary(&[r]).contains("83.3%"));
    }

    #[test]
    fn cache_summary_reports_disabled_without_lookups() {
        assert!(cache_summary(&[]).contains("disabled"));
    }
}
