//! Minimal JSON parser/serializer.
//!
//! Used for the graph-IR interchange with `python/compile/capture.py` and for
//! input-relation files. Supports the full JSON grammar except `\u` surrogate
//! pairs outside the BMP (not needed for our ASCII-ish payloads).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for artifact diffing in `make artifacts`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Field access on objects; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-print with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Compact single-line form, same bytes as [`Json::to_string`] — the serve
/// protocol writes responses with `writeln!("{response}")`.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.b[self.i..];
                    let len = utf8_len(rest[0]);
                    let chunk = rest.get(..len).ok_or_else(|| self.err("bad utf-8"))?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-17", "3.5", "\"hi\\nthere\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "roundtrip {src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str(), Some("éx"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::parse(r#"{"nodes":[{"op":"matmul","inputs":["a","b"]}]}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
