//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`]; `check` runs it across many
//! seeds and reports the first failing seed so failures are reproducible with
//! `Prop::replay`. Used for coordinator/e-graph/relation invariants.

use super::rng::Rng;

pub struct Prop {
    pub name: &'static str,
    pub cases: u64,
    pub base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        Prop { name, cases: 64, base_seed: 0xC0FFEE }
    }

    pub fn cases(mut self, n: u64) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }

    /// Run `f` across `cases` seeds; panic with the failing seed on error.
    pub fn check(&self, f: impl Fn(&mut Rng) -> Result<(), String>) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
            let mut rng = Rng::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property '{}' failed on case {} (replay seed {:#x}): {}",
                    self.name, case, seed, msg
                );
            }
        }
    }

    /// Re-run a single failing seed (debugging aid).
    pub fn replay(&self, seed: u64, f: impl Fn(&mut Rng) -> Result<(), String>) {
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{}' replay {:#x} failed: {}", self.name, seed, msg);
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new("add commutes").cases(32).check(|rng| {
            let a = rng.next_f32();
            let b = rng.next_f32();
            prop_assert!(a + b == b + a, "{} {}", a, b);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_seed() {
        Prop::new("always fails").cases(4).check(|_| Err("nope".into()));
    }
}
