//! Deterministic PRNG (splitmix64). The offline crate set has no `rand`;
//! everything that needs randomness — lemma validation, property tests,
//! cross-validation inputs — goes through this so runs are reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

/// The splitmix64 finalizer behind [`Rng`], shared so other
/// seed-derivation code (e.g. the fuzzer's per-case seeds) stays in sync
/// with the generator's mixing function.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix64(self.state)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }

    /// Uniform integer in [lo, hi].
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard-ish normal via sum of uniforms (Irwin–Hall, 12 terms).
    pub fn normal(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.next_f32();
        }
        s - 6.0
    }

    /// A fresh tensor-sized buffer of small values (keeps matmul chains tame).
    pub fn buf(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn normal_is_roughly_centered() {
        let mut r = Rng::new(11);
        let mean: f32 = (0..10_000).map(|_| r.normal()).sum::<f32>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean {}", mean);
    }
}
