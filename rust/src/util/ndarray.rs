//! Dense row-major f32 tensor.
//!
//! Backs the expression/graph evaluator (`expr::eval`) which is used to (a)
//! numerically validate every lemma in the library on random inputs, (b)
//! check that inferred output relations actually reconstruct `G_s`'s outputs
//! (the soundness certificate), and (c) cross-validate against PJRT-executed
//! HLO artifacts. Integer tensors (embedding ids) are stored as f32 with
//! integral values — every op that consumes ids rounds before use.

use anyhow::{bail, ensure, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct NdArray {
    shape: Vec<i64>,
    data: Vec<f32>,
}

impl NdArray {
    pub fn new(shape: Vec<i64>, data: Vec<f32>) -> Result<Self> {
        let n: i64 = shape.iter().product();
        ensure!(
            n as usize == data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Ok(NdArray { shape, data })
    }

    pub fn zeros(shape: Vec<i64>) -> Self {
        let n: i64 = shape.iter().product();
        NdArray { shape, data: vec![0.0; n as usize] }
    }

    pub fn full(shape: Vec<i64>, v: f32) -> Self {
        let n: i64 = shape.iter().product();
        NdArray { shape, data: vec![v; n as usize] }
    }

    pub fn scalar(v: f32) -> Self {
        NdArray { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[i64] {
        &self.shape
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides in elements.
    pub fn strides(&self) -> Vec<i64> {
        let mut s = vec![1i64; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn reshape(&self, shape: Vec<i64>) -> Result<NdArray> {
        let n: i64 = shape.iter().product();
        ensure!(n as usize == self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Ok(NdArray { shape, data: self.data.clone() })
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> NdArray {
        NdArray { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise zip with broadcasting (numpy rules).
    pub fn zip(&self, other: &NdArray, f: impl Fn(f32, f32) -> f32) -> Result<NdArray> {
        let shape = broadcast_shapes(&self.shape, &other.shape)?;
        let mut out = NdArray::zeros(shape.clone());
        let sa = bcast_strides(&self.shape, &shape);
        let sb = bcast_strides(&other.shape, &shape);
        let strides = out.strides();
        for (flat, slot) in out.data.iter_mut().enumerate() {
            let mut ia = 0i64;
            let mut ib = 0i64;
            let mut rem = flat as i64;
            for d in 0..shape.len() {
                let idx = rem / strides[d];
                rem %= strides[d];
                ia += idx * sa[d];
                ib += idx * sb[d];
            }
            *slot = f(self.data[ia as usize], other.data[ib as usize]);
        }
        Ok(out)
    }

    /// Batched matmul: [..., m, k] x [..., k, n] -> [..., m, n].
    /// Leading batch dims must match exactly or be absent on one side.
    pub fn matmul(&self, other: &NdArray) -> Result<NdArray> {
        ensure!(self.ndim() >= 2 && other.ndim() >= 2, "matmul needs >=2 dims");
        let (m, k1) = (self.shape[self.ndim() - 2], self.shape[self.ndim() - 1]);
        let (k2, n) = (other.shape[other.ndim() - 2], other.shape[other.ndim() - 1]);
        ensure!(k1 == k2, "matmul inner dims {} vs {}", k1, k2);
        let batch_a: i64 = self.shape[..self.ndim() - 2].iter().product();
        let batch_b: i64 = other.shape[..other.ndim() - 2].iter().product();
        ensure!(
            batch_a == batch_b || batch_a == 1 || batch_b == 1,
            "matmul batch mismatch {:?} x {:?}",
            self.shape,
            other.shape
        );
        let batch = batch_a.max(batch_b);
        let lead = if batch_a >= batch_b {
            self.shape[..self.ndim() - 2].to_vec()
        } else {
            other.shape[..other.ndim() - 2].to_vec()
        };
        let mut shape = lead;
        shape.push(m);
        shape.push(n);
        let mut out = NdArray::zeros(shape);
        let (m, k, n) = (m as usize, k1 as usize, n as usize);
        for b in 0..batch as usize {
            let a_off = if batch_a == 1 { 0 } else { b * m * k };
            let b_off = if batch_b == 1 { 0 } else { b * k * n };
            let o_off = b * m * n;
            for i in 0..m {
                for p in 0..k {
                    let a = self.data[a_off + i * k + p];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[b_off + p * n..b_off + (p + 1) * n];
                    let orow = &mut out.data[o_off + i * n..o_off + (i + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        Ok(out)
    }

    pub fn transpose(&self, perm: &[usize]) -> Result<NdArray> {
        ensure!(perm.len() == self.ndim(), "perm len {} vs ndim {}", perm.len(), self.ndim());
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            ensure!(p < perm.len() && !seen[p], "bad perm {:?}", perm);
            seen[p] = true;
        }
        let new_shape: Vec<i64> = perm.iter().map(|&p| self.shape[p]).collect();
        let mut out = NdArray::zeros(new_shape);
        let src_strides = self.strides();
        let dst_strides = out.strides();
        for (flat, slot) in out.data.iter_mut().enumerate() {
            let mut rem = flat as i64;
            let mut src = 0i64;
            for d in 0..perm.len() {
                let idx = rem / dst_strides[d];
                rem %= dst_strides[d];
                src += idx * src_strides[perm[d]];
            }
            *slot = self.data[src as usize];
        }
        Ok(out)
    }

    pub fn slice(&self, dim: usize, start: i64, end: i64) -> Result<NdArray> {
        ensure!(dim < self.ndim(), "slice dim {} ndim {}", dim, self.ndim());
        ensure!(
            0 <= start && start <= end && end <= self.shape[dim],
            "slice [{start}:{end}] of dim size {}",
            self.shape[dim]
        );
        let mut shape = self.shape.clone();
        shape[dim] = end - start;
        let mut out = NdArray::zeros(shape);
        let outer: i64 = self.shape[..dim].iter().product();
        let inner: i64 = self.shape[dim + 1..].iter().product();
        let d = self.shape[dim];
        for o in 0..outer {
            for j in 0..(end - start) {
                let src = ((o * d + start + j) * inner) as usize;
                let dst = ((o * (end - start) + j) * inner) as usize;
                out.data[dst..dst + inner as usize]
                    .copy_from_slice(&self.data[src..src + inner as usize]);
            }
        }
        Ok(out)
    }

    pub fn concat(parts: &[&NdArray], dim: usize) -> Result<NdArray> {
        ensure!(!parts.is_empty(), "concat of nothing");
        let nd = parts[0].ndim();
        ensure!(dim < nd, "concat dim {} ndim {}", dim, nd);
        for p in parts {
            ensure!(p.ndim() == nd, "concat rank mismatch");
            for d in 0..nd {
                if d != dim {
                    ensure!(p.shape[d] == parts[0].shape[d], "concat shape mismatch on dim {d}");
                }
            }
        }
        let mut shape = parts[0].shape.clone();
        shape[dim] = parts.iter().map(|p| p.shape[dim]).sum();
        let mut out = NdArray::zeros(shape.clone());
        let outer: i64 = shape[..dim].iter().product();
        let inner: i64 = shape[dim + 1..].iter().product();
        let total = shape[dim];
        let mut offset = 0i64;
        for p in parts {
            let d = p.shape[dim];
            for o in 0..outer {
                let src = (o * d * inner) as usize;
                let dst = ((o * total + offset) * inner) as usize;
                out.data[dst..dst + (d * inner) as usize]
                    .copy_from_slice(&p.data[src..src + (d * inner) as usize]);
            }
            offset += d;
        }
        Ok(out)
    }

    /// Pad `dim` with `value` before/after.
    pub fn pad(&self, dim: usize, before: i64, after: i64, value: f32) -> Result<NdArray> {
        ensure!(dim < self.ndim(), "pad dim");
        ensure!(before >= 0 && after >= 0, "negative pad");
        let mut shape = self.shape.clone();
        shape[dim] += before + after;
        let mut out = NdArray::full(shape.clone(), value);
        let outer: i64 = self.shape[..dim].iter().product();
        let inner: i64 = self.shape[dim + 1..].iter().product();
        let d = self.shape[dim];
        let dt = shape[dim];
        for o in 0..outer {
            for j in 0..d {
                let src = ((o * d + j) * inner) as usize;
                let dst = ((o * dt + before + j) * inner) as usize;
                out.data[dst..dst + inner as usize]
                    .copy_from_slice(&self.data[src..src + inner as usize]);
            }
        }
        Ok(out)
    }

    /// Reduce one dim with `f` and initial accumulator `init`.
    pub fn reduce(
        &self,
        dim: usize,
        keepdim: bool,
        init: f32,
        f: impl Fn(f32, f32) -> f32,
    ) -> Result<NdArray> {
        ensure!(dim < self.ndim(), "reduce dim {} of {:?}", dim, self.shape);
        let outer: i64 = self.shape[..dim].iter().product();
        let inner: i64 = self.shape[dim + 1..].iter().product();
        let d = self.shape[dim];
        let mut shape = self.shape.clone();
        if keepdim {
            shape[dim] = 1;
        } else {
            shape.remove(dim);
        }
        let mut out = NdArray::full(shape, init);
        for o in 0..outer {
            for j in 0..d {
                for i in 0..inner {
                    let src = ((o * d + j) * inner + i) as usize;
                    let dst = (o * inner + i) as usize;
                    out.data[dst] = f(out.data[dst], self.data[src]);
                }
            }
        }
        Ok(out)
    }

    pub fn sum_dim(&self, dim: usize, keepdim: bool) -> Result<NdArray> {
        self.reduce(dim, keepdim, 0.0, |a, b| a + b)
    }
    pub fn max_dim(&self, dim: usize, keepdim: bool) -> Result<NdArray> {
        self.reduce(dim, keepdim, f32::NEG_INFINITY, f32::max)
    }
    pub fn mean_dim(&self, dim: usize, keepdim: bool) -> Result<NdArray> {
        let n = self.shape[dim] as f32;
        Ok(self.sum_dim(dim, keepdim)?.map(|x| x / n))
    }

    /// Gather rows: self is [v, d] table, ids is any-shape of integral f32;
    /// output shape = ids.shape ++ [d].
    pub fn gather_rows(&self, ids: &NdArray) -> Result<NdArray> {
        ensure!(self.ndim() == 2, "gather table must be 2-d");
        let (v, d) = (self.shape[0], self.shape[1]);
        let mut shape = ids.shape.clone();
        shape.push(d);
        let mut out = NdArray::zeros(shape);
        for (i, &id) in ids.data.iter().enumerate() {
            let row = id.round() as i64;
            if row < 0 || row >= v {
                bail!("gather id {} out of range [0,{})", row, v);
            }
            let src = (row * d) as usize;
            out.data[i * d as usize..(i + 1) * d as usize]
                .copy_from_slice(&self.data[src..src + d as usize]);
        }
        Ok(out)
    }

    pub fn allclose(&self, other: &NdArray, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(&a, &b)| (a - b).abs() <= atol + rtol * b.abs().max(a.abs()))
    }

    pub fn max_abs_diff(&self, other: &NdArray) -> f32 {
        self.data.iter().zip(&other.data).map(|(&a, &b)| (a - b).abs()).fold(0.0, f32::max)
    }
}

/// NumPy broadcasting of two shapes.
pub fn broadcast_shapes(a: &[i64], b: &[i64]) -> Result<Vec<i64>> {
    let n = a.len().max(b.len());
    let mut out = vec![0i64; n];
    for i in 0..n {
        let da = if i < n - a.len() { 1 } else { a[i - (n - a.len())] };
        let db = if i < n - b.len() { 1 } else { b[i - (n - b.len())] };
        if da == db || da == 1 || db == 1 {
            out[i] = da.max(db);
        } else {
            bail!("cannot broadcast {:?} with {:?}", a, b);
        }
    }
    Ok(out)
}

/// Strides of `shape` viewed as broadcast to `target` (0 on broadcast dims).
fn bcast_strides(shape: &[i64], target: &[i64]) -> Vec<i64> {
    let mut strides = vec![0i64; target.len()];
    let offset = target.len() - shape.len();
    let mut acc = 1i64;
    for i in (0..shape.len()).rev() {
        if shape[i] != 1 {
            strides[offset + i] = acc;
        }
        acc *= shape[i];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arange(shape: Vec<i64>) -> NdArray {
        let n: i64 = shape.iter().product();
        NdArray::new(shape, (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn matmul_2x2() {
        let a = NdArray::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = NdArray::new(vec![2, 2], vec![1., 1., 1., 1.]).unwrap();
        assert_eq!(a.matmul(&b).unwrap().data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_block_decomposition() {
        // The block-matmul lemma numerically: A=[A1|A2], B=[B1;B2] =>
        // AB = A1B1 + A2B2. This is the core rewrite of the running example.
        let a = arange(vec![4, 6]);
        let b = arange(vec![6, 5]);
        let full = a.matmul(&b).unwrap();
        let a1 = a.slice(1, 0, 3).unwrap();
        let a2 = a.slice(1, 3, 6).unwrap();
        let b1 = b.slice(0, 0, 3).unwrap();
        let b2 = b.slice(0, 3, 6).unwrap();
        let sum = a1.matmul(&b1).unwrap().zip(&a2.matmul(&b2).unwrap(), |x, y| x + y).unwrap();
        assert!(full.allclose(&sum, 1e-5, 1e-5));
    }

    #[test]
    fn slice_concat_roundtrip() {
        let x = arange(vec![3, 8]);
        let l = x.slice(1, 0, 5).unwrap();
        let r = x.slice(1, 5, 8).unwrap();
        let back = NdArray::concat(&[&l, &r], 1).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn transpose_involution() {
        let x = arange(vec![2, 3, 4]);
        let t = x.transpose(&[2, 0, 1]).unwrap();
        assert_eq!(t.shape(), &[4, 2, 3]);
        let back = t.transpose(&[1, 2, 0]).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn broadcasting_zip() {
        let x = arange(vec![2, 3]);
        let row = arange(vec![3]);
        let out = x.zip(&row, |a, b| a + b).unwrap();
        assert_eq!(out.data(), &[0., 2., 4., 3., 5., 7.]);
        let col = arange(vec![2, 1]);
        let out = x.zip(&col, |a, b| a * b).unwrap();
        assert_eq!(out.data(), &[0., 0., 0., 3., 4., 5.]);
    }

    #[test]
    fn reduce_dims() {
        let x = arange(vec![2, 3]);
        assert_eq!(x.sum_dim(1, false).unwrap().data(), &[3., 12.]);
        assert_eq!(x.sum_dim(0, true).unwrap().shape(), &[1, 3]);
        assert_eq!(x.max_dim(1, false).unwrap().data(), &[2., 5.]);
        assert_eq!(x.mean_dim(1, false).unwrap().data(), &[1., 4.]);
    }

    #[test]
    fn pad_then_slice_identity() {
        let x = arange(vec![2, 3]);
        let padded = x.pad(1, 0, 2, 0.0).unwrap();
        assert_eq!(padded.shape(), &[2, 5]);
        assert_eq!(padded.slice(1, 0, 3).unwrap(), x);
    }

    #[test]
    fn gather_rows_basic() {
        let table = arange(vec![4, 2]);
        let ids = NdArray::new(vec![3], vec![2., 0., 3.]).unwrap();
        let out = table.gather_rows(&ids).unwrap();
        assert_eq!(out.shape(), &[3, 2]);
        assert_eq!(out.data(), &[4., 5., 0., 1., 6., 7.]);
    }

    #[test]
    fn batched_matmul() {
        let a = arange(vec![2, 2, 3]);
        let b = arange(vec![2, 3, 2]);
        let out = a.matmul(&b).unwrap();
        assert_eq!(out.shape(), &[2, 2, 2]);
        // spot check batch 1
        let a1 = a.slice(0, 1, 2).unwrap().reshape(vec![2, 3]).unwrap();
        let b1 = b.slice(0, 1, 2).unwrap().reshape(vec![3, 2]).unwrap();
        let expect = a1.matmul(&b1).unwrap();
        let got = out.slice(0, 1, 2).unwrap().reshape(vec![2, 2]).unwrap();
        assert!(expect.allclose(&got, 1e-6, 1e-6));
    }

    #[test]
    fn shape_errors() {
        let x = arange(vec![2, 3]);
        assert!(x.slice(1, 2, 9).is_err());
        assert!(x.transpose(&[0, 0]).is_err());
        assert!(x.matmul(&arange(vec![4, 2])).is_err());
        assert!(NdArray::new(vec![2, 2], vec![0.0]).is_err());
    }
}
