//! Foundational substrates built in-repo because the offline crate set has no
//! serde / rand / proptest: a JSON codec, a dense tensor, a PRNG, and a small
//! property-testing harness.

pub mod json;
pub mod ndarray;
pub mod proptest;
pub mod rng;
pub mod schema;
