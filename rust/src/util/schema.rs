//! Versioning for every JSON artifact the crate reads or writes.
//!
//! One shared `schema_version` field stamps FUZZ_REPORT.json, counterexample
//! / fixture JSON, the fuzz-campaign journal header, `BENCH_*.json`, and the
//! serve protocol (requests and responses). Readers of untrusted artifacts
//! call [`check`] first: a file carrying a *different* explicit version is
//! rejected with an error naming both versions, while a version-less file is
//! read as v0 for back-compat (everything written before the field existed).

use crate::util::json::Json;
use anyhow::{bail, Result};

/// Current artifact schema version. Bump on any incompatible change to the
/// JSON shapes listed in the module docs.
pub const SCHEMA_VERSION: u64 = 1;

/// The version an artifact declares: `None` for version-less (v0) files.
/// A non-numeric `schema_version` field reads as a declared-but-bogus
/// version and is reported by [`check`].
pub fn declared_version(j: &Json) -> Option<&Json> {
    match j.get("schema_version") {
        Json::Null => None,
        v => Some(v),
    }
}

/// Accept v0 (version-less) and the current version; reject anything else
/// with an error naming both the file's version and the supported one.
/// `what` names the artifact for the error message ("counterexample",
/// "fuzz journal", "serve request", …).
pub fn check(j: &Json, what: &str) -> Result<()> {
    match declared_version(j) {
        None => Ok(()), // v0 back-compat: files written before versioning
        Some(v) => match v.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 && n as u64 == SCHEMA_VERSION => Ok(()),
            Some(n) => bail!(
                "{what}: schema_version {n} does not match this build's \
                 schema_version {SCHEMA_VERSION} (version-less files read as v0)"
            ),
            None => bail!(
                "{what}: schema_version must be a number, got {} \
                 (this build supports schema_version {SCHEMA_VERSION})",
                v.to_string()
            ),
        },
    }
}

/// The stamp writers attach: `("schema_version", version_field())`.
pub fn version_field() -> Json {
    Json::num(SCHEMA_VERSION as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versionless_reads_as_v0() {
        let j = Json::parse(r#"{"kind":"x"}"#).unwrap();
        assert!(check(&j, "fixture").is_ok());
        assert!(declared_version(&j).is_none());
    }

    #[test]
    fn current_version_accepted() {
        let j = Json::obj(vec![("schema_version", version_field())]);
        assert!(check(&j, "fixture").is_ok());
    }

    #[test]
    fn mismatch_names_both_versions() {
        let j = Json::obj(vec![("schema_version", Json::num(99.0))]);
        let msg = format!("{:#}", check(&j, "counterexample").unwrap_err());
        assert!(msg.contains("99"), "{msg}");
        assert!(msg.contains(&SCHEMA_VERSION.to_string()), "{msg}");
        assert!(msg.contains("counterexample"), "{msg}");
    }

    #[test]
    fn non_numeric_version_rejected() {
        let j = Json::obj(vec![("schema_version", Json::str("one"))]);
        assert!(check(&j, "request").is_err());
    }
}
