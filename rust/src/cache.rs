//! Certificate fingerprint cache (ROADMAP "Verification-as-a-service",
//! layer b).
//!
//! A production model verifies the same transformer layer 32 times: every
//! layer is one *region* of the topological walk — a `G_s` operator, the
//! clean candidate mappings of its inputs, and the `G_d` cone reachable
//! from those mappings' leaves. Two regions that are isomorphic (identical
//! op attributes, shapes, candidate-expression structure, channel-tag
//! wiring, and quarantine membership, under a consistent renaming of
//! tensors and channels) drive the saturation engine through identical
//! event sequences and extract identical candidates up to that renaming —
//! the engine consults nothing else about the graphs (the condition-solver
//! starts empty on every walk, and `extract_clean` visits classes in
//! sorted-id order precisely so arena capacity history cannot influence
//! results). So the region's outcome can be memoized under a *canonical
//! serialization* of the region and replayed into any isomorphic region by
//! renaming the leaves back.
//!
//! Verdict-soundness rules (enforced in [`crate::infer`], tested in
//! `rust/tests/cache.rs` and `rust/tests/chaos.rs`):
//! - only *successful* regions whose saturation hit **no** hard budget
//!   (node cap / deadline) are stored — `Inconclusive` outcomes, refuted
//!   regions, and budget-clipped successes are never cached;
//! - the saturation limits and frontier cap are part of the key, so a
//!   result proven under one budget is never replayed under another (the
//!   per-region deadline is *not* in the key: a stored entry was produced
//!   by a deadline-untouched run, and replaying it cannot consume budget);
//! - the stored per-region `SatStats` delta is merged on replay, keeping
//!   lemma-application counts — and therefore reports — byte-identical
//!   between cold and warm runs;
//! - while any chaos fault is armed (`chaos` feature), the walk bypasses
//!   the cache entirely: an injected panic can neither poison an entry nor
//!   have its application accounting skewed by replayed regions.
//!
//! Collision-safety: the full canonical serialization string is the map
//! key (hash maps compare keys on collision), so two distinct regions can
//! never alias an entry — there is no 64-bit-fingerprint unsoundness to
//! argue about.

use crate::egraph::{CleanCand, SatStats, SaturationLimits};
use crate::expr::{Expr, TensorRef};
use crate::ir::{Graph, NodeId, Op, TensorId};
use crate::relation::Relation;
use rustc_hash::{FxHashMap, FxHashSet};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default entry cap for the process-global cache. Keys are a few KB of
/// canonical serialization each; 8192 entries bounds the cache to tens of
/// MB even under a long fuzz campaign. Inserts past the cap are dropped
/// (counted in [`CacheStats::rejected`]) — never evicted, so a replay
/// that hit once keeps hitting for the life of the process.
pub const DEFAULT_MAX_ENTRIES: usize = 8192;

/// Counters for hit-rate reporting (`BENCH_cache.json`, CLI summaries).
/// Exact whenever the cache stays below its entry cap; under capacity
/// pressure the hit/miss split of concurrent walks can vary by scheduling
/// (the *results* never do).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    /// Inserts dropped because the entry cap was reached.
    pub rejected: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A memoized region outcome: canonical candidates plus the bookkeeping a
/// replay needs to keep reports identical to a recomputation.
#[derive(Debug, Clone)]
pub struct RegionEntry {
    /// Clean candidates with leaves renamed to canonical indices.
    pub cands: Vec<CleanCand>,
    /// The region's saturation-stats delta, replayed into the walk total.
    pub stats: SatStats,
    pub egraph_nodes: usize,
    pub explored_gd: usize,
}

/// Shared, thread-safe fingerprint → [`RegionEntry`] map.
pub struct FingerprintCache {
    map: Mutex<FxHashMap<String, Arc<RegionEntry>>>,
    max_entries: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    rejected: AtomicU64,
}

impl Default for FingerprintCache {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for FingerprintCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("FingerprintCache")
            .field("entries", &self.len())
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .finish()
    }
}

impl FingerprintCache {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_MAX_ENTRIES)
    }

    pub fn with_capacity(max_entries: usize) -> Self {
        FingerprintCache {
            map: Mutex::new(FxHashMap::default()),
            max_entries: max_entries.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// The process-global cache instance the CLI wires into verify/suite
    /// runs. Library callers opt in per [`crate::infer::InferConfig`];
    /// tests use private instances for isolated counters.
    pub fn global() -> &'static Arc<FingerprintCache> {
        static GLOBAL: OnceLock<Arc<FingerprintCache>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(FingerprintCache::new()))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FxHashMap<String, Arc<RegionEntry>>> {
        // A panicking worker can only poison the lock between map
        // operations that keep the map consistent; recover the data.
        match self.map.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all entries (counters are kept; see [`Self::reset_stats`]).
    pub fn clear(&self) {
        self.lock().clear();
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
        }
    }

    pub fn reset_stats(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.inserts.store(0, Ordering::Relaxed);
        self.rejected.store(0, Ordering::Relaxed);
    }

    /// Look an entry up, counting a hit or miss.
    pub fn lookup(&self, key: &str) -> Option<Arc<RegionEntry>> {
        let found = self.lock().get(key).cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Store an entry unless the cap is reached. Racing inserts under the
    /// same key keep the first value — both producers computed the same
    /// deterministic result, so which one lands is immaterial.
    pub fn insert(&self, key: String, entry: RegionEntry) {
        let mut map = self.lock();
        if map.contains_key(&key) {
            return;
        }
        if map.len() >= self.max_entries {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        map.insert(key, Arc::new(entry));
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }
}

/// The canonical serialization of one region, plus the leaf renaming that
/// connects canonical indices back to this region's actual tensors.
pub struct RegionFingerprint {
    pub key: String,
    /// canonical index → actual tensor (for replaying a stored entry here).
    canon_to_actual: Vec<TensorRef>,
    /// actual tensor → canonical index (for storing this region's result).
    actual_to_canon: FxHashMap<TensorRef, u32>,
}

impl RegionFingerprint {
    fn canon_ref(&self, t: TensorRef) -> Option<TensorRef> {
        self.actual_to_canon.get(&t).map(|&i| TensorRef { side: t.side, id: i })
    }

    /// Rename a computed result's leaves to canonical indices for storage.
    /// Returns `None` if any leaf is outside the fingerprinted region — a
    /// would-be unsound entry that is skipped instead of stored (the
    /// forward-closure argument in [`fingerprint_region`] says this cannot
    /// happen; the `None` path is defense in depth).
    pub fn canonicalize(&self, cands: &[CleanCand]) -> Option<Vec<CleanCand>> {
        cands
            .iter()
            .map(|c| {
                if !c.expr.leaves_all(&|t| self.actual_to_canon.contains_key(&t)) {
                    return None;
                }
                let expr = c
                    .expr
                    .substitute(&|t| self.canon_ref(t).map(Expr::Leaf));
                let leaves = expr.leaves();
                Some(CleanCand { expr, cost: c.cost, leaves })
            })
            .collect()
    }

    /// Rename a stored entry's canonical leaves to this region's tensors.
    pub fn instantiate(&self, cands: &[CleanCand]) -> Vec<CleanCand> {
        cands
            .iter()
            .map(|c| {
                let expr = c.expr.substitute(&|t| {
                    self.canon_to_actual
                        .get(t.id as usize)
                        .map(|&actual| Expr::Leaf(actual))
                });
                let leaves = expr.leaves();
                CleanCand { expr, cost: c.cost, leaves }
            })
            .collect()
    }
}

/// Serialization state: first-appearance canonical renaming of tensors and
/// channel tags.
struct Canon {
    tensors: FxHashMap<TensorRef, u32>,
    order: Vec<TensorRef>,
    /// shape of each canonical tensor, recorded at first appearance
    shapes: Vec<Vec<i64>>,
    channels: FxHashMap<usize, u32>,
}

impl Canon {
    fn tensor(&mut self, t: TensorRef, shape: &[i64]) -> u32 {
        if let Some(&i) = self.tensors.get(&t) {
            return i;
        }
        let i = self.order.len() as u32;
        self.tensors.insert(t, i);
        self.order.push(t);
        self.shapes.push(shape.to_vec());
        i
    }

    fn channel(&mut self, c: usize) -> u32 {
        let next = self.channels.len() as u32;
        *self.channels.entry(c).or_insert(next)
    }
}

/// Serialize one op with channel tags canonically renamed and quarantine
/// membership made explicit. Every other attribute rides on the derived
/// `Debug` form, which is complete (unlike `Display`, which elides
/// attributes for several ops) and deterministic (`Scalar`/`LinExpr` hold
/// sorted term vectors, not hash maps).
fn push_op(out: &mut String, op: &Op, canon: &mut Canon, quarantined: &FxHashSet<usize>) {
    match op {
        Op::Send { chan } => {
            let c = canon.channel(*chan);
            let q = u8::from(quarantined.contains(chan));
            let _ = write!(out, "Send(c{c},q{q})");
        }
        Op::Recv { chan } => {
            let c = canon.channel(*chan);
            let q = u8::from(quarantined.contains(chan));
            let _ = write!(out, "Recv(c{c},q{q})");
        }
        _ => {
            let _ = write!(out, "{op:?}");
        }
    }
}

fn push_expr(
    out: &mut String,
    e: &Expr,
    canon: &mut Canon,
    quarantined: &FxHashSet<usize>,
    shape_of: &dyn Fn(TensorRef) -> Vec<i64>,
) {
    match e {
        Expr::Leaf(t) => {
            let side = if t.side == crate::expr::Side::S { 'S' } else { 'D' };
            let shape = shape_of(*t);
            let i = canon.tensor(*t, &shape);
            let _ = write!(out, "{side}{i}");
        }
        Expr::Op(op, args) => {
            out.push('(');
            push_op(out, op, canon, quarantined);
            for a in args {
                out.push(' ');
                push_expr(out, a, canon, quarantined, shape_of);
            }
            out.push(')');
        }
    }
}

/// Build the canonical fingerprint of the region rooted at `G_s` node
/// `nid`: the operator (attributes and shapes), its inputs' candidate
/// mappings, the saturation budgets, and the `G_d` cone the frontier loop
/// of [`crate::infer`] could ever explore.
///
/// The cone is the forward closure of the candidate leaves under "add a
/// node once all of its inputs are related", computed in one pass over
/// `G_d`'s topological order. It *over*-approximates the frontier the real
/// walk explores (the real `T_rel` grows by the same rule from the same
/// seeds, plus extraction-found leaves that are already in the closure), so
/// two regions with equal keys present the engine with
/// indistinguishable inputs — equal keys imply equal (canonical) results.
pub fn fingerprint_region(
    nid: NodeId,
    gs: &Graph,
    gd: &Graph,
    r: &Relation,
    limits: SaturationLimits,
    max_frontier_iters: usize,
    quarantined: &FxHashSet<usize>,
) -> RegionFingerprint {
    let node = gs.node(nid);
    let mut canon = Canon {
        tensors: FxHashMap::default(),
        order: Vec::new(),
        shapes: Vec::new(),
        channels: FxHashMap::default(),
    };
    let mut key = String::with_capacity(512);
    let _ = write!(
        key,
        "v1;lim={},{};fr={};op=",
        limits.max_iters, limits.max_nodes, max_frontier_iters
    );
    push_op(&mut key, &node.op, &mut canon, quarantined);
    let _ = write!(key, ";out={:?};", gs.shape(node.output));

    let shape_of = |t: TensorRef| -> Vec<i64> {
        match t.side {
            crate::expr::Side::S => gs.shape(t.id).to_vec(),
            crate::expr::Side::D => gd.shape(t.id).to_vec(),
        }
    };

    // Inputs: shape plus every candidate mapping, in the relation's
    // (cost-sorted, deterministic) order. The seeds of the region's
    // related-tensor set are exactly these candidates' leaves.
    let mut related: FxHashSet<TensorId> = FxHashSet::default();
    for &t in &node.inputs {
        let _ = write!(key, "in{:?}{{", gs.shape(t));
        for cand in r.get(t) {
            let _ = write!(key, "{}:", cand.cost);
            push_expr(&mut key, &cand.expr, &mut canon, quarantined, &shape_of);
            key.push(';');
            for &l in &cand.leaves {
                related.insert(l.id);
            }
        }
        key.push('}');
    }

    // G_d cone: forward closure in topological order. A single pass is the
    // fixpoint — a node's inputs are produced before it, so membership is
    // settled by the time the node is visited.
    key.push_str("gd[");
    for dnid in gd.topo_order() {
        let dnode = gd.node(dnid);
        if !dnode.inputs.iter().all(|t| related.contains(t)) {
            continue;
        }
        related.insert(dnode.output);
        push_op(&mut key, &dnode.op, &mut canon, quarantined);
        key.push('|');
        for &t in &dnode.inputs {
            let shape = gd.shape(t).to_vec();
            let i = canon.tensor(TensorRef::d(t), &shape);
            let _ = write!(key, "D{i},");
        }
        key.push('>');
        let oshape = gd.shape(dnode.output).to_vec();
        let o = canon.tensor(TensorRef::d(dnode.output), &oshape);
        let _ = write!(key, "D{o};");
    }
    key.push(']');

    // Leaf-shape table in canonical order: lemma applicability depends on
    // every subterm's shape, and all subterm shapes derive from leaf
    // shapes through the (serialized) ops.
    key.push_str("sh[");
    for s in &canon.shapes {
        let _ = write!(key, "{s:?};");
    }
    key.push(']');

    RegionFingerprint {
        key,
        canon_to_actual: canon.order,
        actual_to_canon: canon.tensors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    fn entry(cands: Vec<CleanCand>) -> RegionEntry {
        RegionEntry {
            cands,
            stats: SatStats { saturated: true, ..Default::default() },
            egraph_nodes: 1,
            explored_gd: 0,
        }
    }

    #[test]
    fn counters_track_lookups_and_inserts() {
        let c = FingerprintCache::new();
        assert!(c.lookup("k").is_none());
        c.insert("k".into(), entry(vec![]));
        assert!(c.lookup("k").is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn capacity_rejects_instead_of_evicting() {
        let c = FingerprintCache::with_capacity(1);
        c.insert("a".into(), entry(vec![]));
        c.insert("b".into(), entry(vec![]));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().rejected, 1);
        // the original entry still hits — no eviction
        assert!(c.lookup("a").is_some());
        assert!(c.lookup("b").is_none());
    }

    #[test]
    fn duplicate_insert_keeps_first() {
        let c = FingerprintCache::new();
        c.insert("k".into(), entry(vec![]));
        c.insert(
            "k".into(),
            RegionEntry {
                cands: vec![],
                stats: SatStats::default(),
                egraph_nodes: 99,
                explored_gd: 99,
            },
        );
        assert_eq!(c.lookup("k").unwrap().egraph_nodes, 1);
        assert_eq!(c.stats().inserts, 1);
    }

    /// Two isomorphic single-op regions (different tensor ids, same
    /// structure/shapes) must produce byte-identical keys, and a
    /// structurally different third region must not.
    #[test]
    fn isomorphic_regions_share_a_key() {
        let mut gs = Graph::new("gs");
        let a = gs.input("a", vec![4, 4]);
        let b = gs.input("b", vec![4, 4]);
        let x = gs.op("x", Op::Gelu, vec![a]);
        let y = gs.op("y", Op::Gelu, vec![b]);
        let z = gs.op("z", Op::Relu, vec![a]);
        gs.mark_output(x);
        gs.mark_output(y);
        gs.mark_output(z);

        let mut gd = Graph::new("gd");
        let a0 = gd.input("a0", vec![4, 4]);
        let b0 = gd.input("b0", vec![4, 4]);
        let _x0 = gd.op("x0", Op::Gelu, vec![a0]);
        let _y0 = gd.op("y0", Op::Gelu, vec![b0]);

        let ri = Relation::from_json(
            &crate::util::json::Json::parse(r#"{"a": ["a0"], "b": ["b0"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();

        let lim = SaturationLimits::new(8, 1000);
        let q = FxHashSet::default();
        let fx = fingerprint_region(0, &gs, &gd, &ri, lim, 12, &q);
        let fy = fingerprint_region(1, &gs, &gd, &ri, lim, 12, &q);
        let fz = fingerprint_region(2, &gs, &gd, &ri, lim, 12, &q);
        assert_eq!(fx.key, fy.key, "isomorphic regions must alias");
        assert_ne!(fx.key, fz.key, "different ops must not alias");

        // budgets are part of the key
        let f_other = fingerprint_region(0, &gs, &gd, &ri, SaturationLimits::new(9, 1000), 12, &q);
        assert_ne!(fx.key, f_other.key, "limits must namespace entries");
    }

    #[test]
    fn canonicalize_then_instantiate_roundtrips() {
        let mut gs = Graph::new("gs");
        let a = gs.input("a", vec![2, 2]);
        let x = gs.op("x", Op::Neg, vec![a]);
        gs.mark_output(x);
        let mut gd = Graph::new("gd");
        let a0 = gd.input("a0", vec![2, 2]);
        let x0 = gd.op("x0", Op::Neg, vec![a0]);
        gd.mark_output(x0);
        let ri = Relation::from_json(
            &crate::util::json::Json::parse(r#"{"a": ["a0"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let fp = fingerprint_region(
            0,
            &gs,
            &gd,
            &ri,
            SaturationLimits::new(8, 1000),
            12,
            &FxHashSet::default(),
        );
        let out = gd.tensor_by_name("x0").unwrap();
        let cand = CleanCand {
            expr: Expr::Leaf(TensorRef::d(out)),
            cost: 0,
            leaves: vec![TensorRef::d(out)],
        };
        let canonical = fp.canonicalize(std::slice::from_ref(&cand)).unwrap();
        assert_ne!(canonical[0].leaves, cand.leaves, "leaves renamed for storage");
        let back = fp.instantiate(&canonical);
        assert_eq!(back[0].expr, cand.expr, "replay restores the region's tensors");
        assert_eq!(back[0].leaves, cand.leaves);
        assert_eq!(back[0].cost, 0);
    }

    /// A leaf outside the fingerprinted cone must refuse canonicalization
    /// (defense in depth for the storage path).
    #[test]
    fn foreign_leaf_refuses_canonicalization() {
        let mut gs = Graph::new("gs");
        let a = gs.input("a", vec![2]);
        let x = gs.op("x", Op::Neg, vec![a]);
        gs.mark_output(x);
        let mut gd = Graph::new("gd");
        let a0 = gd.input("a0", vec![2]);
        let stray = gd.input("stray", vec![2]);
        let x0 = gd.op("x0", Op::Neg, vec![a0]);
        gd.mark_output(x0);
        let _ = stray;
        let ri = Relation::from_json(
            &crate::util::json::Json::parse(r#"{"a": ["a0"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let fp = fingerprint_region(
            0,
            &gs,
            &gd,
            &ri,
            SaturationLimits::new(8, 1000),
            12,
            &FxHashSet::default(),
        );
        let stray_id = gd.tensor_by_name("stray").unwrap();
        let cand = CleanCand {
            expr: Expr::Leaf(TensorRef::d(stray_id)),
            cost: 0,
            leaves: vec![TensorRef::d(stray_id)],
        };
        assert!(fp.canonicalize(&[cand]).is_none());
    }
}
