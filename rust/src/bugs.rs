//! The six real-world case-study bugs (§6.2), reproduced as graph pairs.
//!
//! Each injector builds `(G_s, G_d, R_i)` where `G_d` carries the bug, plus
//! the corresponding fixed variant, so tests assert both "fixed refines" and
//! "buggy is detected with the paper's localization". Bug 5 is special: per
//! the paper it does NOT fail refinement — the user spots it by reading the
//! inferred relation — and our reproduction returns the suspicious `R_o`.

use crate::ir::{Graph, Op};
use crate::relation::Relation;
use crate::strategies::{chunks, replicate_input, shard_input, RiBuilder};
use anyhow::Result;

pub struct BugCase {
    pub id: usize,
    pub name: &'static str,
    pub description: &'static str,
    pub gs: Graph,
    pub gd: Graph,
    pub ri: Relation,
    /// substring expected in the failing operator's name (None for bug 5,
    /// which passes refinement)
    pub expected_locus: Option<&'static str>,
}

impl BugCase {
    /// Run GraphGuard on the case; returns (detected, report text). A
    /// successful run also renders how `G_d` computes each of its outputs —
    /// the "inspect the relation/implementation" step of bug 5's workflow.
    pub fn run(&self) -> (bool, String) {
        match crate::verifier::Verifier::new().expect(&self.gs, &self.gd, &self.ri) {
            Ok(out) => {
                let ro = out.relation.to_json(&self.gs, &self.gd).to_string_pretty();
                let mut trace = String::new();
                for &o in &self.gd.outputs {
                    trace.push_str(&format!(
                        "  {} := {}\n",
                        self.gd.tensor(o).name,
                        trace_producer(&self.gd, o, 5)
                    ));
                }
                (false, format!("refinement HOLDS; R_o =\n{ro}\nG_d output computation:\n{trace}"))
            }
            Err(e) => (true, format!("{e}")),
        }
    }
}

/// Render the producing expression of a G_d tensor to bounded depth.
fn trace_producer(gd: &Graph, t: crate::ir::TensorId, depth: usize) -> String {
    match gd.producer(t) {
        None => gd.tensor(t).name.clone(),
        Some(_) if depth == 0 => gd.tensor(t).name.clone(),
        Some(node) => {
            let args: Vec<String> =
                node.inputs.iter().map(|&i| trace_producer(gd, i, depth - 1)).collect();
            format!("{}({})", node.op, args.join(", "))
        }
    }
}

/// Bug 1 — incorrect offset in RoPE with SP (found in a hand-written
/// `torch.autograd.Function.backward`): every rank slices the cos/sin
/// tables from offset 0 instead of its own sequence offset.
pub fn bug1_rope_offset(buggy: bool) -> Result<BugCase> {
    const SEQ: i64 = 8;
    const D: i64 = 4;
    let ranks = 2usize;
    let mut gs = Graph::new("rope_gs");
    let x = gs.input("x", vec![SEQ, D]);
    let cos = gs.input("full_cos", vec![SEQ, D]);
    let sin = gs.input("full_sin", vec![SEQ, D]);
    let r = gs.op("roped", Op::Rope, vec![x, cos, sin]);
    // a consumer after rope (the paper localizes at the RoPE operator when
    // inferring its output relation)
    let w = gs.input("w", vec![D, D]);
    let y = gs.matmul("y", r, w);
    gs.mark_output(y);

    let mut gd = Graph::new(if buggy { "rope_gd_buggy" } else { "rope_gd" });
    let mut ri = RiBuilder::new();
    let xs = shard_input(&mut gd, &mut ri, "x", &[SEQ, D], 0, ranks)?;
    let cos_d = replicate_input(&mut gd, &mut ri, "full_cos", &[SEQ, D]);
    let sin_d = replicate_input(&mut gd, &mut ri, "full_sin", &[SEQ, D]);
    let w_d = replicate_input(&mut gd, &mut ri, "w", &[D, D]);
    let mut parts = Vec::new();
    for (rk, &(lo, hi)) in chunks(SEQ, ranks).iter().enumerate() {
        // THE BUG: backward/forward slice offsets — buggy version always
        // slices [0, chunk) regardless of rank.
        let (slo, shi) = if buggy { (0, hi - lo) } else { (lo, hi) };
        let c = gd.slice(&format!("cos_r{rk}"), cos_d, 0, slo, shi);
        let s = gd.slice(&format!("sin_r{rk}"), sin_d, 0, slo, shi);
        let roped = gd.op(&format!("roped_r{rk}"), Op::Rope, vec![xs[rk], c, s]);
        parts.push(gd.matmul(&format!("y_r{rk}"), roped, w_d));
    }
    let y = gd.all_gather("y_ag", parts, 0);
    gd.mark_output(y);
    let ri = ri.finish(&gs, &gd)?;
    Ok(BugCase {
        id: 1,
        name: "rope_sp_offset",
        description: "RoPE under SP: cos/sin sliced at the wrong offset (backward pass)",
        gs,
        gd,
        ri,
        expected_locus: if buggy { Some("roped") } else { None },
    })
}

/// Bug 2 — auxiliary loss not scaled by TP size: the per-rank aux losses
/// are summed by the gradient all-reduce, so each rank must divide by T.
pub fn bug2_aux_loss_scaling(buggy: bool) -> Result<BugCase> {
    const S: i64 = 4;
    const H: i64 = 8;
    const E: i64 = 4;
    let ranks = 2usize;
    let mut gs = Graph::new("aux_gs");
    let x = gs.input("x", vec![S, H]);
    let wg = gs.input("router_w", vec![H, E]);
    let scores = gs.matmul("scores", x, wg);
    let gates = gs.softmax("gates", scores, 1);
    let sq = gs.op("aux_sq", Op::Square, vec![gates]);
    let m1 = gs.op("aux_m1", Op::ReduceMean { dim: 1, keepdim: false }, vec![sq]);
    let m0 = gs.op("aux_m0", Op::ReduceMean { dim: 0, keepdim: false }, vec![m1]);
    let aux = gs.scale("aux", m0, E as f64);
    gs.mark_output(aux);

    let mut gd = Graph::new(if buggy { "aux_gd_buggy" } else { "aux_gd" });
    let mut ri = RiBuilder::new();
    let x_d = replicate_input(&mut gd, &mut ri, "x", &[S, H]);
    let wg_d = replicate_input(&mut gd, &mut ri, "router_w", &[H, E]);
    let scores_d = gd.matmul("scores_d", x_d, wg_d);
    let gates_d = gd.softmax("gates_d", scores_d, 1);
    let sq_d = gd.op("aux_sq_d", Op::Square, vec![gates_d]);
    let m1_d = gd.op("aux_m1_d", Op::ReduceMean { dim: 1, keepdim: false }, vec![sq_d]);
    let m0_d = gd.op("aux_m0_d", Op::ReduceMean { dim: 0, keepdim: false }, vec![m1_d]);
    let full = gd.scale("aux_full", m0_d, E as f64);
    // each TP rank contributes its aux loss; a later reduce-scatter/all-
    // reduce on gradients SUMS the contributions, modeled here by the
    // all-reduce over the per-rank values. Correct code divides by T first.
    let per_rank: Vec<_> = (0..ranks)
        .map(|rk| {
            if buggy {
                gd.op(&format!("aux_r{rk}"), Op::Identity, vec![full]) // BUG: no 1/T
            } else {
                gd.scale(&format!("aux_r{rk}"), full, 1.0 / ranks as f64)
            }
        })
        .collect();
    let aux_out = gd.all_reduce("aux_ar", per_rank);
    gd.mark_output(aux_out);
    let ri = ri.finish(&gs, &gd)?;
    Ok(BugCase {
        id: 2,
        name: "aux_loss_tp_scaling",
        description: "MoE aux loss under TP must be divided by T before the gradient sum",
        gs,
        gd,
        ri,
        expected_locus: if buggy { Some("aux") } else { None },
    })
}

/// Bug 3 — mismatched padding and slicing around an all-gather: the pad
/// adds 2 elements at the back, but the slice drops 2 from the front.
pub fn bug3_pad_slice_mismatch(buggy: bool) -> Result<BugCase> {
    const SEQ: i64 = 6; // not divisible by 4 -> padding needed for gather
    const H: i64 = 4;
    let ranks = 2usize;
    let mut gs = Graph::new("pad_gs");
    let x = gs.input("x", vec![SEQ, H]);
    let w = gs.input("w", vec![H, H]);
    let gx = gs.op("act", Op::Gelu, vec![x]);
    let y = gs.matmul("y", gx, w);
    gs.mark_output(y);

    let mut gd = Graph::new(if buggy { "pad_gd_buggy" } else { "pad_gd" });
    let mut ri = RiBuilder::new();
    let xs = shard_input(&mut gd, &mut ri, "x", &[SEQ, H], 0, ranks)?;
    let w_d = replicate_input(&mut gd, &mut ri, "w", &[H, H]);
    // per-rank: pad the 3-row shard to 4 rows (all-gather wants equal
    // shapes), activation, gather, then drop the padding.
    let padded: Vec<_> = xs
        .iter()
        .enumerate()
        .map(|(rk, &xr)| {
            let p = gd.op(
                &format!("pad_r{rk}"),
                Op::Pad { dim: 0, before: 0.into(), after: 1.into(), value: crate::ir::FBits::new(0.0) },
                vec![xr],
            );
            gd.op(&format!("act_r{rk}"), Op::Gelu, vec![p])
        })
        .collect();
    let gathered = gd.all_gather("act_ag", padded, 0); // [8, H]
    // reassemble the 6 real rows: rows 0..3 from rank0, rows 4..7 hold
    // rank1's 3 rows + pad
    let part0 = gd.slice("unpad_0", gathered, 0, 0, 3);
    let part1 = if buggy {
        // BUG: off-by-one — drops a real row and keeps a padded one
        gd.slice("unpad_1", gathered, 0, 5, 8)
    } else {
        gd.slice("unpad_1", gathered, 0, 4, 7)
    };
    let act_full = gd.concat("act_full", vec![part0, part1], 0);
    let y = gd.matmul("y_d", act_full, w_d);
    gd.mark_output(y);
    let ri = ri.finish(&gs, &gd)?;
    Ok(BugCase {
        id: 3,
        name: "pad_slice_mismatch",
        description: "inconsistent pad/slice parameters around an all-gather drop real rows",
        gs,
        gd,
        ri,
        // detected at the operator whose shards lost a real row (the paper
        // reports its analog at the op consuming the mis-sliced tensor)
        expected_locus: if buggy { Some("act") } else { None },
    })
}

/// Bug 4 — incompatible configuration: switching MoE from TP to SP requires
/// replicating expert weights, but they remained sharded; the diagonal
/// blocks X₁A₂, X₂A₁ are never computed.
pub fn bug4_sharded_experts(buggy: bool) -> Result<BugCase> {
    const S: i64 = 8;
    const H: i64 = 8;
    const F: i64 = 8;
    let ranks = 2usize;
    let mut gs = Graph::new("moe_cfg_gs");
    let x = gs.input("x", vec![S, H]);
    let a = gs.input("a", vec![H, F]);
    let b = gs.input("b", vec![F, H]);
    let h1 = gs.matmul("h1", x, a);
    let y = gs.matmul("y", h1, b);
    gs.mark_output(y);

    let mut gd = Graph::new(if buggy { "moe_cfg_gd_buggy" } else { "moe_cfg_gd" });
    let mut ri = RiBuilder::new();
    let xs = shard_input(&mut gd, &mut ri, "x", &[S, H], 0, ranks)?; // SP
    let (a_parts, b_parts) = if buggy {
        // BUG: weights still sharded as under TP
        let a = crate::strategies::col_shard_weight(&mut gd, &mut ri, "a", &[H, F], ranks)?;
        let b = crate::strategies::row_shard_weight(&mut gd, &mut ri, "b", &[F, H], ranks)?;
        (a, b)
    } else {
        // correct SP: replicate the expert weights
        let a = replicate_input(&mut gd, &mut ri, "a", &[H, F]);
        let b = replicate_input(&mut gd, &mut ri, "b", &[F, H]);
        (vec![a; ranks], vec![b; ranks])
    };
    let parts: Vec<_> = (0..ranks)
        .map(|rk| {
            let h1 = gd.matmul(&format!("h1_r{rk}"), xs[rk], a_parts[rk]);
            gd.matmul(&format!("y_r{rk}"), h1, b_parts[rk])
        })
        .collect();
    // note: output shape matches G_s either way — the type checker cannot
    // catch this (paper §2.2)
    let y = gd.all_gather("y_ag", parts, 0);
    gd.mark_output(y);
    let ri = ri.finish(&gs, &gd)?;
    Ok(BugCase {
        id: 4,
        name: "sp_sharded_expert_weights",
        description: "SP requires replicated expert weights; sharding loses off-diagonal blocks",
        gs,
        gd,
        ri,
        expected_locus: if buggy { Some("h1") } else { None },
    })
}

/// Bug 5 — missing gradient aggregation for a layernorm weight: the weight
/// was never registered with the SP-group optimizer, so its per-rank
/// gradient is used directly instead of the all-reduced one. Refinement
/// SUCCEEDS (the per-rank value is a legitimate clean mapping under the
/// user-provided replication relation) — the bug shows up when the user
/// reads `R_o` and sees the update built from `g_ln_r0` instead of
/// `sum(g_ln_r0, g_ln_r1)`.
pub fn bug5_missing_aggregation(buggy: bool) -> Result<BugCase> {
    const H: i64 = 8;
    let mut gs = Graph::new("opt_gs");
    let w = gs.input("w_ln", vec![H]);
    let grad = gs.input("g_ln", vec![H]);
    let step = gs.scale("step", grad, 0.1);
    let w_new = gs.sub2("w_new", w, step);
    gs.mark_output(w_new);

    let mut gd = Graph::new(if buggy { "opt_gd_buggy" } else { "opt_gd" });
    let mut ri = RiBuilder::new();
    let w_d = replicate_input(&mut gd, &mut ri, "w_ln", &[H]);
    // per-rank partial gradients; the user ASSUMES they are identical
    // replicas and writes g_ln -> g_ln_r0 (that assumption is what hides
    // the bug from refinement checking).
    let g0 = gd.input("g_ln_r0", vec![H]);
    let g1 = gd.input("g_ln_r1", vec![H]);
    ri.map("g_ln", "g_ln_r0".into());
    ri.map("g_ln", "g_ln_r1".into());
    let grad_used = if buggy {
        g0 // BUG: not registered with the optimizer's all-reduce group
    } else {
        let ar = gd.all_reduce("g_ln_ar", vec![g0, g1]);
        gd.scale("g_ln_avg", ar, 0.5)
    };
    let step = gd.scale("step_d", grad_used, 0.1);
    let w_new = gd.sub2("w_new_d", w_d, step);
    gd.mark_output(w_new);
    let ri = ri.finish(&gs, &gd)?;
    Ok(BugCase {
        id: 5,
        name: "missing_layernorm_aggregation",
        description: "layernorm weight not registered for gradient all-reduce (R_o inspection)",
        gs,
        gd,
        ri,
        expected_locus: None, // refinement holds either way; see run_bug5()
    })
}

/// Bug 6 — wrong scaling in gradient accumulation (HF issue #14638/#2175):
/// delegated to the regression model builders.
pub fn bug6_grad_accum(buggy: bool) -> Result<BugCase> {
    let (gs, gd, ri) = if buggy {
        crate::models::regression::grad_accum_buggy_pair(2)?
    } else {
        crate::models::regression::grad_accum_pair(2)?
    };
    Ok(BugCase {
        id: 6,
        name: "grad_accum_scaling",
        description: "gradient-accumulation loss must be rescaled by 1/k (HF trainer bug)",
        gs,
        gd,
        ri,
        expected_locus: if buggy { Some("loss") } else { None },
    })
}

/// Bridge between the hand-written §6.2 cases and the fuzz mutation
/// operators generalizing them (`crate::fuzz::mutate::MutKind` names).
/// Bug 5 has no operator: it is invisible to refinement by design and is
/// caught by relation inspection, which the fuzzer does not model.
pub fn fuzz_operator_for(bug_id: usize) -> Option<&'static str> {
    match bug_id {
        1 => Some("slice_shift"),          // wrong RoPE table offset
        2 => Some("scale_drop"),           // missing 1/T before the sum
        3 => Some("slice_shift"),          // pad/slice off-by-one
        4 => Some("dup_shard_input"),      // wrong shard pairing
        6 => Some("scale_perturb"),        // wrong grad-accum rescale
        _ => None,
    }
}

/// All six cases, buggy or fixed.
pub fn all_cases(buggy: bool) -> Vec<BugCase> {
    vec![
        bug1_rope_offset(buggy).unwrap(),
        bug2_aux_loss_scaling(buggy).unwrap(),
        bug3_pad_slice_mismatch(buggy).unwrap(),
        bug4_sharded_experts(buggy).unwrap(),
        bug5_missing_aggregation(buggy).unwrap(),
        bug6_grad_accum(buggy).unwrap(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::verify_numeric;
    use crate::verifier::Verifier;

    #[test]
    fn fixed_variants_all_refine() {
        for case in all_cases(false) {
            let out = Verifier::new().expect(&case.gs, &case.gd, &case.ri)
                .unwrap_or_else(|e| panic!("fixed {} failed: {e}", case.name));
            if case.id != 5 {
                // bug 5's user-assumed replication relation is not
                // numerically faithful (partial grads differ in reality)
                verify_numeric(&case.gs, &case.gd, &case.ri, &out.relation, case.id as u64)
                    .unwrap_or_else(|e| panic!("fixed {} numeric: {e:#}", case.name));
            }
        }
    }

    #[test]
    fn buggy_variants_detected_with_localization() {
        for case in all_cases(true) {
            let (detected, report) = case.run();
            match case.expected_locus {
                Some(locus) => {
                    assert!(detected, "{} not detected; report:\n{report}", case.name);
                    assert!(
                        report.contains(locus),
                        "{}: locus '{locus}' not in report:\n{report}",
                        case.name
                    );
                }
                None => {
                    // bug 5: passes refinement; the report carries R_o for
                    // user inspection and must reveal the rank-0-only use
                    assert!(!detected, "{} unexpectedly failed:\n{report}", case.name);
                    assert!(
                        report.contains("g_ln_r0") && !report.contains("g_ln_ar"),
                        "bug-5 trace should expose the unaggregated gradient:\n{report}"
                    );
                }
            }
        }
    }

    #[test]
    fn bug5_fixed_relation_differs_visibly() {
        // the fixed variant's implementation trace shows the all-reduce;
        // the buggy one shows a bare rank-0 gradient — the diff the user
        // reviews per §6.2.
        let fixed = bug5_missing_aggregation(false).unwrap();
        let (detected, report_fixed) = fixed.run();
        assert!(!detected);
        assert!(report_fixed.contains("all_reduce"), "{report_fixed}");
        let buggy = bug5_missing_aggregation(true).unwrap();
        let (detected, report_buggy) = buggy.run();
        assert!(!detected);
        assert!(!report_buggy.contains("all_reduce"), "{report_buggy}");
    }
}
