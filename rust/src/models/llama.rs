//! Llama-3 style block (Transformers-NeuronX analog, Table 2): RMSNorm via
//! the **L1 Pallas kernel** (`pallas_rms_norm` custom op), per-head RoPE
//! attention via the **`pallas_attention`** kernel, SwiGLU MLP; distributed
//! with tensor parallelism. The default hidden size (16) is intentionally
//! not divisible by 6 — reproducing the missing parallelism-6 point in
//! Fig 5.

use crate::ir::{Graph, Op, TensorId};
use crate::relation::Relation;
use crate::strategies::{
    col_shard_weight, replicate_input, row_shard_weight, stage_boundary, RiBuilder,
};
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct LlamaConfig {
    pub seq: i64,
    pub heads: i64,
    pub head_dim: i64,
    pub ffn: i64,
}

impl Default for LlamaConfig {
    fn default() -> Self {
        LlamaConfig { seq: 8, heads: 4, head_dim: 4, ffn: 32 }
    }
}

impl LlamaConfig {
    pub fn hidden(&self) -> i64 {
        self.heads * self.head_dim
    }
}

fn rms(g: &mut Graph, name: &str, x: TensorId, w: TensorId) -> TensorId {
    g.op(name, Op::Custom { name: "pallas_rms_norm".into() }, vec![x, w])
}

/// Per-head RoPE attention using the Pallas attention kernel.
fn attention(
    g: &mut Graph,
    prefix: &str,
    q: TensorId,
    k: TensorId,
    v: TensorId,
    cos: TensorId,
    sin: TensorId,
    heads: i64,
    head_dim: i64,
) -> TensorId {
    let mut outs = Vec::with_capacity(heads as usize);
    for i in 0..heads {
        let (lo, hi) = (i * head_dim, (i + 1) * head_dim);
        let qi = g.slice(&format!("{prefix}_q{i}"), q, 1, lo, hi);
        let ki = g.slice(&format!("{prefix}_k{i}"), k, 1, lo, hi);
        let vi = g.slice(&format!("{prefix}_v{i}"), v, 1, lo, hi);
        let qr = g.op(&format!("{prefix}_qr{i}"), Op::Rope, vec![qi, cos, sin]);
        let kr = g.op(&format!("{prefix}_kr{i}"), Op::Rope, vec![ki, cos, sin]);
        outs.push(g.op(
            &format!("{prefix}_o{i}"),
            Op::Custom { name: "pallas_attention".into() },
            vec![qr, kr, vi],
        ));
    }
    g.concat(&format!("{prefix}_attn"), outs, 1)
}

pub fn seq(layers: usize, cfg: &LlamaConfig) -> Graph {
    let h = cfg.hidden();
    let mut g = Graph::new("llama_seq");
    let mut x = g.input("x", vec![cfg.seq, h]);
    let cos = g.input("cos", vec![cfg.seq, cfg.head_dim]);
    let sin = g.input("sin", vec![cfg.seq, cfg.head_dim]);
    for l in 0..layers {
        let p = format!("l{l}");
        let w_rms1 = g.input(&format!("{p}_rms1_w"), vec![h]);
        let wq = g.input(&format!("{p}_wq"), vec![h, h]);
        let wk = g.input(&format!("{p}_wk"), vec![h, h]);
        let wv = g.input(&format!("{p}_wv"), vec![h, h]);
        let wo = g.input(&format!("{p}_wo"), vec![h, h]);
        let w_rms2 = g.input(&format!("{p}_rms2_w"), vec![h]);
        let wg = g.input(&format!("{p}_wg"), vec![h, cfg.ffn]);
        let wu = g.input(&format!("{p}_wu"), vec![h, cfg.ffn]);
        let wd = g.input(&format!("{p}_wd"), vec![cfg.ffn, h]);

        let n1 = rms(&mut g, &format!("{p}_rms1"), x, w_rms1);
        let q = g.matmul(&format!("{p}_q"), n1, wq);
        let k = g.matmul(&format!("{p}_k"), n1, wk);
        let v = g.matmul(&format!("{p}_v"), n1, wv);
        let attn = attention(&mut g, &p, q, k, v, cos, sin, cfg.heads, cfg.head_dim);
        let proj = g.matmul(&format!("{p}_proj"), attn, wo);
        let x1 = g.add2(&format!("{p}_res1"), x, proj);
        let n2 = rms(&mut g, &format!("{p}_rms2"), x1, w_rms2);
        let gate = g.matmul(&format!("{p}_gate"), n2, wg);
        let up = g.matmul(&format!("{p}_up"), n2, wu);
        let sg = g.op(&format!("{p}_silu"), Op::Silu, vec![gate]);
        let act = g.mul2(&format!("{p}_act"), sg, up);
        let down = g.matmul(&format!("{p}_down"), act, wd);
        x = g.add2(&format!("{p}_res2"), x1, down);
    }
    g.mark_output(x);
    g
}

/// Tensor-parallel Llama (heads and FFN sharded, projections row-parallel).
pub fn tp_pair(ranks: usize, layers: usize, cfg: &LlamaConfig) -> Result<(Graph, Graph, Relation)> {
    tp_pp_dist(ranks, layers, cfg, 1)
}

/// Pipeline stages over contiguous layer groups with TP inside each stage.
pub fn pp_tp_pair(
    stages: usize,
    ranks: usize,
    layers: usize,
    cfg: &LlamaConfig,
) -> Result<(Graph, Graph, Relation)> {
    anyhow::ensure!(
        (1..=layers.max(1)).contains(&stages),
        "{stages} pipeline stages need 1..={layers} layers"
    );
    tp_pp_dist(ranks, layers, cfg, stages)
}

fn tp_pp_dist(
    ranks: usize,
    layers: usize,
    cfg: &LlamaConfig,
    pp_stages: usize,
) -> Result<(Graph, Graph, Relation)> {
    let gs = seq(layers, cfg);
    let h = cfg.hidden();
    let heads_per = cfg.heads / ranks as i64;
    anyhow::ensure!(
        cfg.heads % ranks as i64 == 0 && cfg.ffn % ranks as i64 == 0,
        "llama config not divisible by {ranks} ranks"
    );
    let stage_ends = crate::strategies::stage_ends(layers, pp_stages);
    let mut g = Graph::new(if pp_stages > 1 { "llama_pp_tp" } else { "llama_tp" });
    let mut ri = RiBuilder::new();
    let mut x = replicate_input(&mut g, &mut ri, "x", &[cfg.seq, h]);
    let cos = replicate_input(&mut g, &mut ri, "cos", &[cfg.seq, cfg.head_dim]);
    let sin = replicate_input(&mut g, &mut ri, "sin", &[cfg.seq, cfg.head_dim]);
    for l in 0..layers {
        let p = format!("l{l}");
        let w_rms1 = replicate_input(&mut g, &mut ri, &format!("{p}_rms1_w"), &[h]);
        let w_rms2 = replicate_input(&mut g, &mut ri, &format!("{p}_rms2_w"), &[h]);
        let wq = col_shard_weight(&mut g, &mut ri, &format!("{p}_wq"), &[h, h], ranks)?;
        let wk = col_shard_weight(&mut g, &mut ri, &format!("{p}_wk"), &[h, h], ranks)?;
        let wv = col_shard_weight(&mut g, &mut ri, &format!("{p}_wv"), &[h, h], ranks)?;
        let wo = row_shard_weight(&mut g, &mut ri, &format!("{p}_wo"), &[h, h], ranks)?;
        let wg = col_shard_weight(&mut g, &mut ri, &format!("{p}_wg"), &[h, cfg.ffn], ranks)?;
        let wu = col_shard_weight(&mut g, &mut ri, &format!("{p}_wu"), &[h, cfg.ffn], ranks)?;
        let wd = row_shard_weight(&mut g, &mut ri, &format!("{p}_wd"), &[cfg.ffn, h], ranks)?;

        let n1 = rms(&mut g, &format!("{p}_rms1"), x, w_rms1);
        let mut parts = Vec::with_capacity(ranks);
        for rk in 0..ranks {
            let q = g.matmul(&format!("{p}_q_r{rk}"), n1, wq[rk]);
            let k = g.matmul(&format!("{p}_k_r{rk}"), n1, wk[rk]);
            let v = g.matmul(&format!("{p}_v_r{rk}"), n1, wv[rk]);
            let attn = attention(
                &mut g,
                &format!("{p}_r{rk}"),
                q,
                k,
                v,
                cos,
                sin,
                heads_per,
                cfg.head_dim,
            );
            parts.push(g.matmul(&format!("{p}_part_r{rk}"), attn, wo[rk]));
        }
        let proj = g.all_reduce(&format!("{p}_proj_ar"), parts);
        let x1 = g.add2(&format!("{p}_res1"), x, proj);
        let n2 = rms(&mut g, &format!("{p}_rms2"), x1, w_rms2);
        let mut mlp_parts = Vec::with_capacity(ranks);
        for rk in 0..ranks {
            let gate = g.matmul(&format!("{p}_gate_r{rk}"), n2, wg[rk]);
            let up = g.matmul(&format!("{p}_up_r{rk}"), n2, wu[rk]);
            let sg = g.op(&format!("{p}_silu_r{rk}"), Op::Silu, vec![gate]);
            let act = g.mul2(&format!("{p}_act_r{rk}"), sg, up);
            mlp_parts.push(g.matmul(&format!("{p}_down_r{rk}"), act, wd[rk]));
        }
        let mlp = g.all_reduce(&format!("{p}_mlp_ar"), mlp_parts);
        x = g.add2(&format!("{p}_res2"), x1, mlp);

        // pipeline stage boundary: the full activation crosses once per
        // boundary (TP keeps activations replicated between layers)
        if let Some(b) = stage_ends.iter().position(|&e| e == l + 1) {
            x = stage_boundary(&mut g, &format!("pp{b}"), x, b);
        }
    }
    g.mark_output(x);
    let ri = ri.finish(&gs, &g)?;
    Ok((gs, g, ri))
}

/// ZeRO-3/FSDP Llama: every weight (RMSNorm gains included) stored
/// 1/R-sharded along its leading dim and all-gathered before use; compute
/// is mirrored node-for-node from the sequential graph by
/// `strategies::fsdp_from_seq`. RoPE tables are buffers, not parameters —
/// they stay replicated, like the activation input.
pub fn fsdp_pair(ranks: usize, layers: usize, cfg: &LlamaConfig) -> Result<(Graph, Graph, Relation)> {
    let gs = seq(layers, cfg);
    let (mut gd, ri) = crate::strategies::fsdp_from_seq(
        &gs,
        ranks,
        &|name| !matches!(name, "x" | "cos" | "sin"),
        &|name| format!("{name}_ag"),
    )?;
    gd.name = "llama_fsdp".into();
    Ok((gs, gd, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::verify_numeric;
    use crate::verifier::Verifier;

    #[test]
    fn llama_tp2_refines() {
        let (gs, gd, ri) = tp_pair(2, 1, &LlamaConfig::default()).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 23).unwrap();
    }

    #[test]
    fn llama_pp2_tp2_refines() {
        let (gs, gd, ri) = pp_tp_pair(2, 2, 2, &LlamaConfig::default()).unwrap();
        assert!(gd.nodes().iter().any(|n| matches!(n.op, Op::Recv { .. })));
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 37).unwrap();
    }

    #[test]
    fn llama_fsdp2_refines() {
        let (gs, gd, ri) = fsdp_pair(2, 1, &LlamaConfig::default()).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 41).unwrap();
    }

    #[test]
    fn llama_fsdp_rejects_degree_6() {
        assert!(fsdp_pair(6, 1, &LlamaConfig::default()).is_err());
    }

    #[test]
    fn llama_rejects_degree_6() {
        // Fig 5: "no data for parallelism size 6 — cannot be evenly
        // partitioned".
        assert!(tp_pair(6, 1, &LlamaConfig::default()).is_err());
    }
}
