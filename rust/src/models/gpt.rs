//! GPT (Megatron-LM style) — the paper's main scalability workload.
//!
//! The sequential model is a standard pre-LN transformer LM; attention is
//! expressed per-head via slices (heads are independent — exactly how
//! Megatron shards them), which keeps `G_s` and `G_d` within the paper's
//! same-op-structure assumption (§3.3).
//!
//! Distributed variants:
//! * `tp_pair`     — Megatron tensor parallelism (column/row-parallel
//!   linears + all-reduce), activations replicated.
//! * `tp_sp_pair`  — TP + sequence parallelism (LN on sequence shards,
//!   all-gather into the TP region, reduce-scatter out).
//! * `tp_sp_vp_pair` — additionally shards the LM head over the vocab
//!   (vocabulary parallelism), as used for the Fig-5 sweeps.
//! * `pp_tp_pair`  — pipeline stages over contiguous layer groups
//!   (send/recv boundaries between stages) with TP inside each stage.
//! * `fsdp_pair`   — ZeRO-3/FSDP: every parameter stored 1/R-sharded and
//!   all-gathered before use, compute replicated.

use crate::ir::{FBits, Graph, Op, TensorId};
use crate::relation::Relation;
use crate::strategies::{replicate_input, stage_boundary, RiBuilder};
use anyhow::{ensure, Result};

#[derive(Debug, Clone)]
pub struct GptConfig {
    pub seq: i64,
    pub heads: i64,
    pub head_dim: i64,
    pub ffn: i64,
    pub vocab: i64,
}

/// Small default used in tests: hidden 16, divisible by ranks {2,4,8}.
impl Default for GptConfig {
    fn default() -> Self {
        GptConfig { seq: 8, heads: 4, head_dim: 4, ffn: 32, vocab: 16 }
    }
}

impl GptConfig {
    pub fn hidden(&self) -> i64 {
        self.heads * self.head_dim
    }

    /// Fig-5 parallelism-sweep config (degrees {2,4}; degree 6 does not
    /// divide the head count — the same uneven-partition hole the paper
    /// shows for Llama-3. Wider-head configs that would admit 6/8 blow up
    /// per-layer op counts beyond this testbed's sweep budget; see
    /// EXPERIMENTS.md §Fig 5).
    pub fn sweep() -> Self {
        GptConfig { seq: 12, heads: 4, head_dim: 4, ffn: 24, vocab: 24 }
    }

    fn check(&self, ranks: usize) -> Result<()> {
        let r = ranks as i64;
        ensure!(self.heads % r == 0, "heads {} % ranks {}", self.heads, r);
        ensure!(self.seq % r == 0, "seq {} % ranks {}", self.seq, r);
        ensure!(self.ffn % r == 0, "ffn {} % ranks {}", self.ffn, r);
        ensure!(self.vocab % r == 0, "vocab {} % ranks {}", self.vocab, r);
        Ok(())
    }
}

const EPS: f64 = 1e-5;

fn ln(g: &mut Graph, name: &str, x: TensorId, w: TensorId, b: TensorId) -> TensorId {
    g.op(name, Op::LayerNorm { eps: FBits::new(EPS) }, vec![x, w, b])
}

/// Per-head attention over already-projected q/k/v `[s, h]`: slices heads,
/// runs scaled-dot-product per head, concatenates.
fn attention_heads(
    g: &mut Graph,
    prefix: &str,
    q: TensorId,
    k: TensorId,
    v: TensorId,
    heads: i64,
    head_dim: i64,
) -> TensorId {
    let scale = 1.0 / (head_dim as f64).sqrt();
    let mut outs = Vec::with_capacity(heads as usize);
    for i in 0..heads {
        let (lo, hi) = (i * head_dim, (i + 1) * head_dim);
        let qi = g.slice(&format!("{prefix}_q{i}"), q, 1, lo, hi);
        let ki = g.slice(&format!("{prefix}_k{i}"), k, 1, lo, hi);
        let vi = g.slice(&format!("{prefix}_v{i}"), v, 1, lo, hi);
        let kt = g.transpose(&format!("{prefix}_kt{i}"), ki, vec![1, 0]);
        let sc = g.matmul(&format!("{prefix}_sc{i}"), qi, kt);
        let scs = g.scale(&format!("{prefix}_scs{i}"), sc, scale);
        let pr = g.softmax(&format!("{prefix}_pr{i}"), scs, 1);
        outs.push(g.matmul(&format!("{prefix}_o{i}"), pr, vi));
    }
    g.concat(&format!("{prefix}_attn"), outs, 1)
}

/// Sequential GPT: embedding + `layers` transformer blocks + LM head.
pub fn seq(layers: usize, cfg: &GptConfig) -> Graph {
    let h = cfg.hidden();
    let mut g = Graph::new("gpt_seq");
    let table = g.input("wte", vec![cfg.vocab, h]);
    let ids = g.input_typed("ids", vec![cfg.seq], crate::ir::DType::I64);
    let mut x = g.op("emb", Op::Embedding, vec![table, ids]);
    for l in 0..layers {
        let p = format!("l{l}");
        let g1 = g.input(&format!("{p}_ln1_w"), vec![h]);
        let b1 = g.input(&format!("{p}_ln1_b"), vec![h]);
        let wq = g.input(&format!("{p}_wq"), vec![h, h]);
        let wk = g.input(&format!("{p}_wk"), vec![h, h]);
        let wv = g.input(&format!("{p}_wv"), vec![h, h]);
        let wo = g.input(&format!("{p}_wo"), vec![h, h]);
        let g2 = g.input(&format!("{p}_ln2_w"), vec![h]);
        let b2 = g.input(&format!("{p}_ln2_b"), vec![h]);
        let w1 = g.input(&format!("{p}_w1"), vec![h, cfg.ffn]);
        let w2 = g.input(&format!("{p}_w2"), vec![cfg.ffn, h]);

        let ln1 = ln(&mut g, &format!("{p}_ln1"), x, g1, b1);
        let q = g.matmul(&format!("{p}_q"), ln1, wq);
        let k = g.matmul(&format!("{p}_k"), ln1, wk);
        let v = g.matmul(&format!("{p}_v"), ln1, wv);
        let attn = attention_heads(&mut g, &p, q, k, v, cfg.heads, cfg.head_dim);
        let proj = g.matmul(&format!("{p}_proj"), attn, wo);
        let x1 = g.add2(&format!("{p}_res1"), x, proj);
        let ln2 = ln(&mut g, &format!("{p}_ln2"), x1, g2, b2);
        let h1 = g.matmul(&format!("{p}_h1"), ln2, w1);
        let act = g.op(&format!("{p}_gelu"), Op::Gelu, vec![h1]);
        let h2 = g.matmul(&format!("{p}_h2"), act, w2);
        x = g.add2(&format!("{p}_res2"), x1, h2);
    }
    let gf = g.input("lnf_w", vec![h]);
    let bf = g.input("lnf_b", vec![h]);
    let lnf = ln(&mut g, "lnf", x, gf, bf);
    let wlm = g.input("lm_head", vec![h, cfg.vocab]);
    let logits = g.matmul("logits", lnf, wlm);
    g.mark_output(logits);
    g
}

/// Options shared by the distributed builders.
struct DistOpts {
    sp: bool,
    vp: bool,
    /// Pipeline stages (1 = no pipeline). Layers are grouped into
    /// contiguous stages via `strategies::chunks`; every activation shard
    /// crosses a send/recv boundary between stages.
    pp_stages: usize,
}

impl DistOpts {
    fn tp_only() -> Self {
        DistOpts { sp: false, vp: false, pp_stages: 1 }
    }
}

/// Megatron TP (optionally +SP, +VP, +PP stages) distributed GPT.
fn dist(ranks: usize, layers: usize, cfg: &GptConfig, opts: DistOpts) -> Result<(Graph, Relation)> {
    cfg.check(ranks)?;
    ensure!(opts.pp_stages >= 1, "at least one pipeline stage");
    ensure!(
        opts.pp_stages <= layers.max(1),
        "{} pipeline stages need at least as many layers (got {layers})",
        opts.pp_stages
    );
    let stage_ends = crate::strategies::stage_ends(layers, opts.pp_stages);
    let gs = seq(layers, cfg); // used for R_i name resolution at the end
    let h = cfg.hidden();
    let r = ranks as i64;
    let heads_per = cfg.heads / r;
    let name = match (opts.pp_stages > 1, opts.sp) {
        (true, _) => "gpt_pp_tp",
        (false, true) => "gpt_tp_sp",
        (false, false) => "gpt_tp",
    };
    let mut g = Graph::new(name);
    let mut ri = RiBuilder::new();

    // embedding: table replicated; ids sharded under SP else replicated
    let table = replicate_input(&mut g, &mut ri, "wte", &[cfg.vocab, h]);
    let mut x_shards: Vec<TensorId>; // SP: per-rank [s/R, h]; TP: single full
    if opts.sp {
        let id_shards = crate::strategies::shard_input_ids(
            &mut g,
            &mut ri,
            "ids",
            &[cfg.seq],
            0,
            ranks,
        )?;
        x_shards = id_shards
            .iter()
            .enumerate()
            .map(|(rk, &ids)| g.op(&format!("emb_r{rk}"), Op::Embedding, vec![table, ids]))
            .collect();
    } else {
        let ids = crate::strategies::replicate_input_typed(
            &mut g,
            &mut ri,
            "ids",
            &[cfg.seq],
            crate::ir::DType::I64,
        );
        x_shards = vec![g.op("emb", Op::Embedding, vec![table, ids])];
    }

    for l in 0..layers {
        let p = format!("l{l}");
        // replicated norm params
        let g1 = replicate_input(&mut g, &mut ri, &format!("{p}_ln1_w"), &[h]);
        let b1 = replicate_input(&mut g, &mut ri, &format!("{p}_ln1_b"), &[h]);
        let g2 = replicate_input(&mut g, &mut ri, &format!("{p}_ln2_w"), &[h]);
        let b2 = replicate_input(&mut g, &mut ri, &format!("{p}_ln2_b"), &[h]);
        // column-sharded qkv, row-sharded proj
        let wq = crate::strategies::col_shard_weight(&mut g, &mut ri, &format!("{p}_wq"), &[h, h], ranks)?;
        let wk = crate::strategies::col_shard_weight(&mut g, &mut ri, &format!("{p}_wk"), &[h, h], ranks)?;
        let wv = crate::strategies::col_shard_weight(&mut g, &mut ri, &format!("{p}_wv"), &[h, h], ranks)?;
        let wo = crate::strategies::row_shard_weight(&mut g, &mut ri, &format!("{p}_wo"), &[h, h], ranks)?;
        let w1 = crate::strategies::col_shard_weight(&mut g, &mut ri, &format!("{p}_w1"), &[h, cfg.ffn], ranks)?;
        let w2 = crate::strategies::row_shard_weight(&mut g, &mut ri, &format!("{p}_w2"), &[cfg.ffn, h], ranks)?;

        // --- attention sub-block ---
        // SP: per-rank LN then all-gather; TP: LN on the full tensor.
        let ln1_full = if opts.sp {
            let shards: Vec<TensorId> = x_shards
                .iter()
                .enumerate()
                .map(|(rk, &xr)| ln(&mut g, &format!("{p}_ln1_r{rk}"), xr, g1, b1))
                .collect();
            g.all_gather(&format!("{p}_ln1_ag"), shards, 0)
        } else {
            ln(&mut g, &format!("{p}_ln1"), x_shards[0], g1, b1)
        };
        let mut parts = Vec::with_capacity(ranks);
        for rk in 0..ranks {
            let q = g.matmul(&format!("{p}_q_r{rk}"), ln1_full, wq[rk]);
            let k = g.matmul(&format!("{p}_k_r{rk}"), ln1_full, wk[rk]);
            let v = g.matmul(&format!("{p}_v_r{rk}"), ln1_full, wv[rk]);
            let attn = attention_heads(
                &mut g,
                &format!("{p}_r{rk}"),
                q,
                k,
                v,
                heads_per,
                cfg.head_dim,
            );
            parts.push(g.matmul(&format!("{p}_part_r{rk}"), attn, wo[rk]));
        }
        // combine partials: SP -> reduce-scatter along seq; TP -> all-reduce
        let res1: Vec<TensorId> = if opts.sp {
            (0..ranks)
                .map(|rk| {
                    let rs = g.reduce_scatter(&format!("{p}_rs1_r{rk}"), parts.clone(), 0, rk);
                    g.add2(&format!("{p}_res1_r{rk}"), x_shards[rk], rs)
                })
                .collect()
        } else {
            let proj = g.all_reduce(&format!("{p}_proj_ar"), parts);
            vec![g.add2(&format!("{p}_res1"), x_shards[0], proj)]
        };

        // --- MLP sub-block ---
        let ln2_full = if opts.sp {
            let shards: Vec<TensorId> = res1
                .iter()
                .enumerate()
                .map(|(rk, &xr)| ln(&mut g, &format!("{p}_ln2_r{rk}"), xr, g2, b2))
                .collect();
            g.all_gather(&format!("{p}_ln2_ag"), shards, 0)
        } else {
            ln(&mut g, &format!("{p}_ln2"), res1[0], g2, b2)
        };
        let mut mlp_parts = Vec::with_capacity(ranks);
        for rk in 0..ranks {
            let h1 = g.matmul(&format!("{p}_h1_r{rk}"), ln2_full, w1[rk]);
            let act = g.op(&format!("{p}_gelu_r{rk}"), Op::Gelu, vec![h1]);
            mlp_parts.push(g.matmul(&format!("{p}_h2_r{rk}"), act, w2[rk]));
        }
        x_shards = if opts.sp {
            (0..ranks)
                .map(|rk| {
                    let rs = g.reduce_scatter(&format!("{p}_rs2_r{rk}"), mlp_parts.clone(), 0, rk);
                    g.add2(&format!("{p}_res2_r{rk}"), res1[rk], rs)
                })
                .collect()
        } else {
            let mlp = g.all_reduce(&format!("{p}_mlp_ar"), mlp_parts);
            vec![g.add2(&format!("{p}_res2"), res1[0], mlp)]
        };

        // pipeline stage boundary after this layer: each activation shard
        // crosses on its own channel (boundary-major numbering)
        if let Some(b) = stage_ends.iter().position(|&e| e == l + 1) {
            x_shards = x_shards
                .iter()
                .enumerate()
                .map(|(rk, &x)| {
                    stage_boundary(&mut g, &format!("pp{b}_r{rk}"), x, b * ranks + rk)
                })
                .collect();
        }
    }

    // final LN + LM head
    let gf = replicate_input(&mut g, &mut ri, "lnf_w", &[h]);
    let bf = replicate_input(&mut g, &mut ri, "lnf_b", &[h]);
    let lnf_full = if opts.sp {
        let shards: Vec<TensorId> = x_shards
            .iter()
            .enumerate()
            .map(|(rk, &xr)| ln(&mut g, &format!("lnf_r{rk}"), xr, gf, bf))
            .collect();
        g.all_gather("lnf_ag", shards, 0)
    } else {
        ln(&mut g, "lnf", x_shards[0], gf, bf)
    };
    let logits = if opts.vp {
        let wlm = crate::strategies::col_shard_weight(&mut g, &mut ri, "lm_head", &[h, cfg.vocab], ranks)?;
        let parts: Vec<TensorId> = (0..ranks)
            .map(|rk| g.matmul(&format!("logits_r{rk}"), lnf_full, wlm[rk]))
            .collect();
        g.all_gather("logits_ag", parts, 1)
    } else {
        let wlm = replicate_input(&mut g, &mut ri, "lm_head", &[h, cfg.vocab]);
        g.matmul("logits_rep", lnf_full, wlm)
    };
    g.mark_output(logits);

    let rel = ri.finish(&gs, &g)?;
    Ok((g, rel))
}

pub fn tp_pair(ranks: usize, layers: usize) -> (Graph, Graph, Relation) {
    let cfg = GptConfig::default();
    let gs = seq(layers, &cfg);
    let (gd, ri) = dist(ranks, layers, &cfg, DistOpts::tp_only()).unwrap();
    (gs, gd, ri)
}

pub fn tp_sp_pair(ranks: usize, layers: usize, cfg: &GptConfig) -> Result<(Graph, Graph, Relation)> {
    let gs = seq(layers, cfg);
    let (gd, ri) = dist(ranks, layers, cfg, DistOpts { sp: true, vp: false, pp_stages: 1 })?;
    Ok((gs, gd, ri))
}

/// TP + SP + VP at the same degree — the Fig-5 GPT configuration.
pub fn tp_sp_vp_pair(
    ranks: usize,
    layers: usize,
    cfg: &GptConfig,
) -> Result<(Graph, Graph, Relation)> {
    let gs = seq(layers, cfg);
    let (gd, ri) = dist(ranks, layers, cfg, DistOpts { sp: true, vp: true, pp_stages: 1 })?;
    Ok((gs, gd, ri))
}

/// Pipeline parallelism over contiguous layer groups composed with tensor
/// parallelism inside each stage — the PP×TP composition real Megatron
/// deployments run. `stages` must not exceed `layers`.
pub fn pp_tp_pair(stages: usize, ranks: usize, layers: usize) -> Result<(Graph, Graph, Relation)> {
    let cfg = GptConfig::default();
    let gs = seq(layers, &cfg);
    let (gd, ri) =
        dist(ranks, layers, &cfg, DistOpts { sp: false, vp: false, pp_stages: stages })?;
    Ok((gs, gd, ri))
}

/// ZeRO-3/FSDP: every parameter (embeddings, norms, attention and MLP
/// weights, LM head) is stored 1/R-sharded along its leading dim and
/// all-gathered immediately before use; compute is mirrored node-for-node
/// from the sequential graph by `strategies::fsdp_from_seq`, so this
/// variant cannot drift from `seq`.
pub fn fsdp_pair(ranks: usize, layers: usize) -> Result<(Graph, Graph, Relation)> {
    let cfg = GptConfig::default();
    let gs = seq(layers, &cfg);
    let (mut gd, ri) = crate::strategies::fsdp_from_seq(
        &gs,
        ranks,
        &|name| name != "ids", // every input except the token ids is a param
        &|name| format!("{name}_ag"),
    )?;
    gd.name = "gpt_fsdp".into();
    Ok((gs, gd, ri))
}

/// Sequential attention-free GPT: embedding + pre-LN MLP blocks + final LN
/// and LM head. Built for micro-batched pipeline schedules — every operator
/// is row-decomposable, so `pipeline_stage_split` accepts the whole chain.
/// (Micro-batching *attention* needs the causal/blockwise decomposition
/// lemma family, a separate ROADMAP item; the Table-2 schedule-aware PP
/// entries run this MLP-transformer variant instead.) The token ids are
/// declared first because `pipeline_stage_split` micro-batches `inputs[0]`
/// along dim 0.
pub fn mlp_seq(layers: usize, cfg: &GptConfig) -> Graph {
    let h = cfg.hidden();
    let mut g = Graph::new("gpt_mlp_seq");
    let ids = g.input_typed("ids", vec![cfg.seq], crate::ir::DType::I64);
    let table = g.input("wte", vec![cfg.vocab, h]);
    let mut x = g.op("emb", Op::Embedding, vec![table, ids]);
    for l in 0..layers {
        let p = format!("l{l}");
        let gw = g.input(&format!("{p}_ln_w"), vec![h]);
        let gb = g.input(&format!("{p}_ln_b"), vec![h]);
        let w1 = g.input(&format!("{p}_w1"), vec![h, cfg.ffn]);
        let w2 = g.input(&format!("{p}_w2"), vec![cfg.ffn, h]);
        let lnv = ln(&mut g, &format!("{p}_ln"), x, gw, gb);
        let h1 = g.matmul(&format!("{p}_h1"), lnv, w1);
        let act = g.op(&format!("{p}_gelu"), Op::Gelu, vec![h1]);
        let h2 = g.matmul(&format!("{p}_h2"), act, w2);
        x = g.add2(&format!("{p}_res"), x, h2);
    }
    let gf = g.input("lnf_w", vec![h]);
    let bf = g.input("lnf_b", vec![h]);
    let lnf = ln(&mut g, "lnf", x, gf, bf);
    let wlm = g.input("lm_head", vec![h, cfg.vocab]);
    let logits = g.matmul("logits", lnf, wlm);
    g.mark_output(logits);
    g
}

/// Schedule-aware pipeline parallelism over [`mlp_seq`]: layer groups
/// become pipeline chunks (one per physical stage, or `stages × virt` under
/// interleaving), `pipeline_stage_split` unrolls `sched.micro`
/// micro-batches, and the logical boundary channels are lowered onto
/// per-boundary pools of physical activation buffers — sized to the
/// schedule's minimum safe depth — whose `(boundary, slot, epoch)` tags the
/// verifier checks pairwise (`schedule::lower_buffers`).
pub fn pp_sched_pair(
    sched: &crate::schedule::Schedule,
    layers: usize,
) -> Result<(Graph, Graph, Relation)> {
    sched.validate()?;
    let cfg = GptConfig::default();
    ensure!(
        cfg.seq % sched.micro as i64 == 0,
        "seq {} not divisible by {} micro-batches",
        cfg.seq,
        sched.micro
    );
    let chunks = sched.chunks();
    ensure!(
        layers >= chunks,
        "{chunks} pipeline chunks need at least as many layers (got {layers})"
    );
    let gs = mlp_seq(layers, &cfg);
    // cut after the last residual of each non-final chunk's layer group
    let cuts: Vec<crate::ir::NodeId> = crate::strategies::stage_ends(layers, chunks)
        .iter()
        .map(|&e| {
            let t = gs.tensor_by_name(&format!("l{}_res", e - 1)).expect("layer residual");
            gs.tensor(t).producer.expect("residual is computed")
        })
        .collect();
    let depth = sched.min_safe_depth()?;
    let (mut gd, ri) = crate::strategies::pipeline_stage_split_scheduled(
        &gs,
        &cuts,
        "logits_pp",
        sched,
        depth,
    )?;
    gd.name = format!("gpt_pp_{}", sched.kind.name());
    Ok((gs, gd, ri))
}

/// Experts in the switch-style MoE MLP of [`moe_seq`].
pub const MOE_EXPERTS: usize = 4;
/// Top-k of the router gate (k = 2: each token is served by two experts,
/// with gate weights normalized over the selected pair).
pub const MOE_TOPK: usize = 2;

/// Sequential GPT whose MLP is a switch-style top-k MoE: a learned router
/// scores every token (`softmax` probabilities), `topk` picks the serving
/// experts (0/1 mask), gate weights are the selected probabilities
/// re-normalized over the top-k, each expert runs its FFN on the tokens
/// `dispatch` assigns it (capacity = full sequence — no silent drops in
/// the clean model), and `combine` gathers the expert outputs back,
/// weighted by the gates.
pub fn moe_seq(layers: usize, cfg: &GptConfig) -> Graph {
    let h = cfg.hidden();
    let e = MOE_EXPERTS as i64;
    let mut g = Graph::new("gpt_moe_seq");
    let table = g.input("wte", vec![cfg.vocab, h]);
    let ids = g.input_typed("ids", vec![cfg.seq], crate::ir::DType::I64);
    let mut x = g.op("emb", Op::Embedding, vec![table, ids]);
    for l in 0..layers {
        let p = format!("l{l}");
        let g1 = g.input(&format!("{p}_ln1_w"), vec![h]);
        let b1 = g.input(&format!("{p}_ln1_b"), vec![h]);
        let wq = g.input(&format!("{p}_wq"), vec![h, h]);
        let wk = g.input(&format!("{p}_wk"), vec![h, h]);
        let wv = g.input(&format!("{p}_wv"), vec![h, h]);
        let wo = g.input(&format!("{p}_wo"), vec![h, h]);
        let g2 = g.input(&format!("{p}_ln2_w"), vec![h]);
        let b2 = g.input(&format!("{p}_ln2_b"), vec![h]);
        let wg = g.input(&format!("{p}_router_w"), vec![h, e]);
        let w1s: Vec<TensorId> = (0..MOE_EXPERTS)
            .map(|ex| g.input(&format!("{p}_e{ex}_w1"), vec![h, cfg.ffn]))
            .collect();
        let w2s: Vec<TensorId> = (0..MOE_EXPERTS)
            .map(|ex| g.input(&format!("{p}_e{ex}_w2"), vec![cfg.ffn, h]))
            .collect();

        let ln1 = ln(&mut g, &format!("{p}_ln1"), x, g1, b1);
        let q = g.matmul(&format!("{p}_q"), ln1, wq);
        let k = g.matmul(&format!("{p}_k"), ln1, wk);
        let v = g.matmul(&format!("{p}_v"), ln1, wv);
        let attn = attention_heads(&mut g, &p, q, k, v, cfg.heads, cfg.head_dim);
        let proj = g.matmul(&format!("{p}_proj"), attn, wo);
        let x1 = g.add2(&format!("{p}_res1"), x, proj);
        let ln2 = ln(&mut g, &format!("{p}_ln2"), x1, g2, b2);

        // router: probabilities -> top-k mask -> normalized gate weights
        let scores = g.matmul(&format!("{p}_scores"), ln2, wg);
        let probs = g.softmax(&format!("{p}_probs"), scores, 1);
        let mask = g.topk(&format!("{p}_mask"), probs, MOE_TOPK);
        let wts = g.mul2(&format!("{p}_wts"), mask, probs);
        let denom = g.op(&format!("{p}_denom"), Op::ReduceSum { dim: 1, keepdim: true }, vec![wts]);
        let gates = g.op(&format!("{p}_gates"), Op::Div, vec![wts, denom]);
        // experts: dispatch -> FFN -> combine
        let mut ys = Vec::with_capacity(MOE_EXPERTS);
        for ex in 0..MOE_EXPERTS {
            let d = g.dispatch(&format!("{p}_disp{ex}"), ln2, mask, ex, cfg.seq as usize);
            let h1 = g.matmul(&format!("{p}_e{ex}_h1"), d, w1s[ex]);
            let act = g.op(&format!("{p}_e{ex}_gelu"), Op::Gelu, vec![h1]);
            ys.push(g.matmul(&format!("{p}_e{ex}_h2"), act, w2s[ex]));
        }
        let moe = g.combine(&format!("{p}_moe"), gates, ys);
        x = g.add2(&format!("{p}_res2"), x1, moe);
    }
    let gf = g.input("lnf_w", vec![h]);
    let bf = g.input("lnf_b", vec![h]);
    let lnf = ln(&mut g, "lnf", x, gf, bf);
    let wlm = g.input("lm_head", vec![h, cfg.vocab]);
    let logits = g.matmul("logits", lnf, wlm);
    g.mark_output(logits);
    g
}

/// Expert parallelism over the MoE block: experts are placed on ranks and
/// the combine is split into per-rank partial combines merged by an
/// all-reduce (`strategies::moe_from_seq` — derived node-for-node from
/// [`moe_seq`], so the EP variant cannot drift from the sequential model).
/// The router is data-dependent: verification goes through the
/// router-conditioned relation language, not a capture-time-fixed
/// expert assignment.
pub fn moe_ep_pair(ranks: usize, layers: usize) -> Result<(Graph, Graph, Relation)> {
    ensure!(
        MOE_EXPERTS % ranks == 0,
        "{MOE_EXPERTS} experts not divisible by {ranks} ranks"
    );
    let cfg = GptConfig::default();
    let gs = moe_seq(layers, &cfg);
    let (mut gd, ri) = crate::strategies::moe_from_seq(&gs, ranks)?;
    gd.name = "gpt_moe_ep".into();
    Ok((gs, gd, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{verify_numeric, InferConfig};
    use crate::verifier::Verifier;

    #[test]
    fn seq_graph_shape() {
        let g = seq(2, &GptConfig::default());
        g.validate().unwrap();
        let logits = g.outputs[0];
        assert_eq!(g.shape(logits), &[8, 16]);
    }

    #[test]
    fn gpt_tp2_refines() {
        let (gs, gd, ri) = tp_pair(2, 1);
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 11).unwrap();
    }

    #[test]
    fn gpt_tp_sp2_refines() {
        let (gs, gd, ri) = tp_sp_pair(2, 1, &GptConfig::default()).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 13).unwrap();
    }

    #[test]
    fn gpt_tp_sp_vp2_refines() {
        let (gs, gd, ri) = tp_sp_vp_pair(2, 1, &GptConfig::default()).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 17).unwrap();
    }

    #[test]
    fn gpt_pp2_tp2_refines() {
        let (gs, gd, ri) = pp_tp_pair(2, 2, 2).unwrap();
        assert!(
            gd.nodes().iter().any(|n| matches!(n.op, crate::ir::Op::Send { .. })),
            "stage boundary must appear in G_d"
        );
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 29).unwrap();
    }

    #[test]
    fn gpt_pp_rejects_more_stages_than_layers() {
        assert!(pp_tp_pair(3, 2, 2).is_err());
    }

    #[test]
    fn gpt_mlp_seq_is_row_decomposable_end_to_end() {
        let g = mlp_seq(2, &GptConfig::default());
        g.validate().unwrap();
        assert_eq!(g.shape(g.outputs[0]), &[8, 16]);
        // ids must be the primary (first) input — pipeline_stage_split
        // micro-batches inputs[0]
        assert_eq!(g.tensor(g.inputs[0]).name, "ids");
    }

    #[test]
    fn gpt_pp2_1f1b_refines_with_buffer_tags() {
        let sched = crate::schedule::Schedule::one_f_one_b(2, 4);
        let (gs, gd, ri) = pp_sched_pair(&sched, 2).unwrap();
        // every boundary op carries a physical-buffer tag, none logical
        let mut sends = 0;
        for n in gd.nodes() {
            if let crate::ir::Op::Send { chan } = n.op {
                assert!(
                    crate::schedule::decode_buffer_tag(chan).is_some(),
                    "'{}' still carries a logical channel",
                    n.name
                );
                sends += 1;
            }
        }
        assert_eq!(sends, 4, "one boundary x 4 micro-batches");
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 37).unwrap();
    }

    #[test]
    fn gpt_pp2x2_interleaved_refines_across_three_boundaries() {
        let sched = crate::schedule::Schedule::interleaved(2, 4, 2);
        let (gs, gd, ri) = pp_sched_pair(&sched, 4).unwrap();
        let sends = gd
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, crate::ir::Op::Send { .. }))
            .count();
        assert_eq!(sends, 12, "3 boundaries x 4 micro-batches");
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 41).unwrap();
    }

    #[test]
    fn quarantined_channels_fail_refinement_despite_matched_tags() {
        // the slot-liveness side condition end-to-end: quarantining a
        // boundary channel (as an external schedule audit would for a
        // lowering that stamped both sides with the occupant epoch) must
        // flip the verdict even though every tag pair matches
        let sched = crate::schedule::Schedule::one_f_one_b(2, 4);
        let (gs, gd, ri) = pp_sched_pair(&sched, 2).unwrap();
        let mut cfg = InferConfig::default();
        for n in gd.nodes() {
            if let crate::ir::Op::Recv { chan } = n.op {
                cfg.quarantined_channels.push(chan);
            }
        }
        assert!(
            Verifier::with_config(cfg).expect(&gs, &gd, &ri).is_err(),
            "quarantined boundaries must not verify"
        );
        // and the same pair verifies with an empty quarantine
        Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
    }

    #[test]
    fn gpt_pp_sched_rejects_indivisible_micro_batching() {
        // seq = 8 does not split into 3 micro-batches
        let sched = crate::schedule::Schedule::one_f_one_b(2, 3);
        assert!(pp_sched_pair(&sched, 2).is_err());
        // fewer layers than chunks
        let sched = crate::schedule::Schedule::interleaved(2, 4, 2);
        assert!(pp_sched_pair(&sched, 3).is_err());
    }

    #[test]
    fn gpt_fsdp2_refines() {
        let (gs, gd, ri) = fsdp_pair(2, 1).unwrap();
        let gathers = gd
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, crate::ir::Op::AllGather { .. }))
            .count();
        assert!(gathers >= 12, "every param must be re-gathered, saw {gathers}");
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 31).unwrap();
    }

    #[test]
    fn moe_seq_graph_shape() {
        let g = moe_seq(1, &GptConfig::default());
        g.validate().unwrap();
        assert_eq!(g.shape(g.outputs[0]), &[8, 16]);
        assert!(
            g.nodes().iter().any(|n| matches!(n.op, crate::ir::Op::TopK { k: MOE_TOPK })),
            "top-k router must appear in the sequential MoE graph"
        );
    }

    #[test]
    fn gpt_moe_ep2_refines_with_conditional_relations() {
        let (gs, gd, ri) = moe_ep_pair(2, 1).unwrap();
        assert!(
            gd.nodes().iter().any(|n| matches!(n.op, crate::ir::Op::Combine { experts: 2 })),
            "EP variant must carry per-rank partial combines"
        );
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 61).unwrap();
        // the walk must have crossed the MoE block through router-guarded
        // (conditional) mappings
        assert!(
            !out.relation_full.conditional_tensors().is_empty(),
            "expected router-conditioned relations in the full relation"
        );
    }

    #[test]
    fn gpt_moe_ep_rejects_indivisible_expert_count() {
        assert!(moe_ep_pair(3, 1).is_err());
    }

    #[test]
    fn sweep_config_degrees() {
        let cfg = GptConfig::sweep();
        let (gs, gd, ri) = tp_sp_vp_pair(4, 1, &cfg).unwrap();
        gs.validate().unwrap();
        gd.validate().unwrap();
        ri.validate_shapes(&gs, &gd).unwrap();
        // degree 6 does not divide the head count (Fig-5 hole)
        assert!(tp_sp_vp_pair(6, 1, &cfg).is_err());
    }
}
