//! HuggingFace-transformers-style regression with MSE loss (Table 2) —
//! the gradient-accumulation workload of §6.2 Bug 6.
//!
//! `G_s` is a linear model trained on the full batch; `G_d` splits the
//! batch into `k` microbatches. The **correct** implementation rescales
//! each microbatch loss by `1/k` before accumulating; the buggy one (see
//! `crate::bugs`) omits the rescale, so the accumulated loss relates to the
//! sequential loss only through a division — not a clean expression — and
//! refinement fails at the MSE operator.
//!
//! Both graphs carry their backward pass (built by `ir::autodiff`, the
//! analog of the HF trainer's autograd), so the verified relation covers
//! loss AND gradients. Shapes are powers of two so the `2/N · 1/k = 2/(N·k)`
//! scale folding is exact in f64.

use crate::ir::autodiff::append_backward;
use crate::ir::{Graph, Op};
use crate::relation::Relation;
use crate::strategies::{replicate_input, shard_input, RiBuilder};
use anyhow::Result;

pub const BATCH: i64 = 8;
pub const IN_DIM: i64 = 4;
pub const OUT_DIM: i64 = 2;

/// Sequential: pred = x·w + b, loss = mse(pred, y); outputs loss, ∂w, ∂b.
pub fn seq() -> Graph {
    let mut g = Graph::new("regression_seq");
    let x = g.input("x", vec![BATCH, IN_DIM]);
    let y = g.input("y", vec![BATCH, OUT_DIM]);
    let w = g.input("w", vec![IN_DIM, OUT_DIM]);
    let b = g.input("b", vec![OUT_DIM]);
    let mm = g.matmul("mm", x, w);
    let pred = g.add2("pred", mm, b);
    let loss = g.op("loss", Op::MseLoss, vec![pred, y]);
    g.mark_output(loss);
    append_backward(&mut g, loss, &[w, b]).expect("regression backward");
    g.eliminate_dead_code()
}

/// Gradient accumulation over `k` microbatches. `scaled` selects the
/// correct (`true`) or buggy (`false`, §6.2 bug 6) loss scaling.
pub fn grad_accum(k: usize, scaled: bool) -> Result<(Graph, RiBuilder)> {
    anyhow::ensure!(BATCH % k as i64 == 0, "batch {} % microbatches {}", BATCH, k);
    let mut g = Graph::new(if scaled { "regression_ga" } else { "regression_ga_buggy" });
    let mut ri = RiBuilder::new();
    let xs = shard_input(&mut g, &mut ri, "x", &[BATCH, IN_DIM], 0, k)?;
    let ys = shard_input(&mut g, &mut ri, "y", &[BATCH, OUT_DIM], 0, k)?;
    let w = replicate_input(&mut g, &mut ri, "w", &[IN_DIM, OUT_DIM]);
    let b = replicate_input(&mut g, &mut ri, "b", &[OUT_DIM]);
    let mut parts = Vec::with_capacity(k);
    for i in 0..k {
        let mm = g.matmul(&format!("mm_{i}"), xs[i], w);
        let pred = g.add2(&format!("pred_{i}"), mm, b);
        let li = g.op(&format!("loss_{i}"), Op::MseLoss, vec![pred, ys[i]]);
        parts.push(if scaled {
            g.scale(&format!("scaled_{i}"), li, 1.0 / k as f64)
        } else {
            li // BUG: accumulate unscaled microbatch losses
        });
    }
    let total = g.op("loss_acc", Op::SumN, parts);
    g.mark_output(total);
    append_backward(&mut g, total, &[w, b]).expect("grad-accum backward");
    Ok((g.eliminate_dead_code(), ri))
}

pub fn grad_accum_pair(k: usize) -> Result<(Graph, Graph, Relation)> {
    let gs = seq();
    let (gd, ri) = grad_accum(k, true)?;
    let ri = ri.finish(&gs, &gd)?;
    Ok((gs, gd, ri))
}

pub fn grad_accum_buggy_pair(k: usize) -> Result<(Graph, Graph, Relation)> {
    let gs = seq();
    let (gd, ri) = grad_accum(k, false)?;
    let ri = ri.finish(&gs, &gd)?;
    Ok((gs, gd, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::verify_numeric;
    use crate::verifier::Verifier;

    #[test]
    fn correct_grad_accum_refines_including_gradients() {
        let (gs, gd, ri) = grad_accum_pair(2).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        // loss AND both gradients must be mapped
        for name in ["loss", "grad_w", "grad_b"] {
            let t = gs.tensor_by_name(name).unwrap();
            assert!(out.relation.contains(t), "{name} unmapped");
        }
        verify_numeric(&gs, &gd, &ri, &out.relation, 31).unwrap();
    }

    #[test]
    fn buggy_grad_accum_fails_at_loss() {
        let (gs, gd, ri) = grad_accum_buggy_pair(2).unwrap();
        let err = Verifier::new().expect(&gs, &gd, &ri).unwrap_err();
        // §6.2 bug 6: "the accumulated loss cannot cleanly represent the
        // loss in G_s" — inference stops at the MSE (or a gradient op fed by
        // it); the operator name localizes the problem.
        assert!(
            err.node_name.contains("loss") || err.node_name.contains("grad"),
            "unexpected localization: {}",
            err.node_name
        );
    }

    #[test]
    fn four_microbatches_also_refine() {
        let (gs, gd, ri) = grad_accum_pair(4).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 37).unwrap();
    }
}
