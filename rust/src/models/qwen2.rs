//! Qwen2 block under a vLLM-style runtime (Table 2): like Llama but with
//! the framework's fused `fused_silu_mul` custom kernel on the MLP path —
//! the "v"-group custom ops of Figures 6/7. Distributed with TP.

use crate::ir::{Graph, Op, TensorId};
use crate::relation::Relation;
use crate::strategies::{col_shard_weight, replicate_input, row_shard_weight, RiBuilder};
use anyhow::Result;

const SEQ: i64 = 8;
const HEADS: i64 = 4;
const HEAD_DIM: i64 = 4;
const FFN: i64 = 32;

fn hidden() -> i64 {
    HEADS * HEAD_DIM
}

fn rms(g: &mut Graph, name: &str, x: TensorId, w: TensorId) -> TensorId {
    g.op(name, Op::RmsNorm { eps: crate::ir::FBits::new(1e-6) }, vec![x, w])
}

fn attention(
    g: &mut Graph,
    prefix: &str,
    q: TensorId,
    k: TensorId,
    v: TensorId,
    heads: i64,
) -> TensorId {
    let mut outs = Vec::with_capacity(heads as usize);
    for i in 0..heads {
        let (lo, hi) = (i * HEAD_DIM, (i + 1) * HEAD_DIM);
        let qi = g.slice(&format!("{prefix}_q{i}"), q, 1, lo, hi);
        let ki = g.slice(&format!("{prefix}_k{i}"), k, 1, lo, hi);
        let vi = g.slice(&format!("{prefix}_v{i}"), v, 1, lo, hi);
        outs.push(g.op(
            &format!("{prefix}_o{i}"),
            Op::Custom { name: "pallas_attention".into() },
            vec![qi, ki, vi],
        ));
    }
    g.concat(&format!("{prefix}_attn"), outs, 1)
}

pub fn seq(layers: usize) -> Graph {
    let h = hidden();
    let mut g = Graph::new("qwen2_seq");
    let mut x = g.input("x", vec![SEQ, h]);
    for l in 0..layers {
        let p = format!("l{l}");
        let w_rms1 = g.input(&format!("{p}_rms1_w"), vec![h]);
        let wq = g.input(&format!("{p}_wq"), vec![h, h]);
        let wk = g.input(&format!("{p}_wk"), vec![h, h]);
        let wv = g.input(&format!("{p}_wv"), vec![h, h]);
        let wo = g.input(&format!("{p}_wo"), vec![h, h]);
        let w_rms2 = g.input(&format!("{p}_rms2_w"), vec![h]);
        let wg = g.input(&format!("{p}_wg"), vec![h, FFN]);
        let wu = g.input(&format!("{p}_wu"), vec![h, FFN]);
        let wd = g.input(&format!("{p}_wd"), vec![FFN, h]);

        let n1 = rms(&mut g, &format!("{p}_rms1"), x, w_rms1);
        let q = g.matmul(&format!("{p}_q"), n1, wq);
        let k = g.matmul(&format!("{p}_k"), n1, wk);
        let v = g.matmul(&format!("{p}_v"), n1, wv);
        let attn = attention(&mut g, &p, q, k, v, HEADS);
        let proj = g.matmul(&format!("{p}_proj"), attn, wo);
        let x1 = g.add2(&format!("{p}_res1"), x, proj);
        let n2 = rms(&mut g, &format!("{p}_rms2"), x1, w_rms2);
        let gate = g.matmul(&format!("{p}_gate"), n2, wg);
        let up = g.matmul(&format!("{p}_up"), n2, wu);
        // vLLM's fused SwiGLU kernel
        let act = g.op(
            &format!("{p}_act"),
            Op::Custom { name: "fused_silu_mul".into() },
            vec![gate, up],
        );
        let down = g.matmul(&format!("{p}_down"), act, wd);
        x = g.add2(&format!("{p}_res2"), x1, down);
    }
    g.mark_output(x);
    g
}

pub fn tp_pair(ranks: usize, layers: usize) -> Result<(Graph, Graph, Relation)> {
    let gs = seq(layers);
    let h = hidden();
    anyhow::ensure!(
        HEADS % ranks as i64 == 0 && FFN % ranks as i64 == 0,
        "qwen2 config not divisible by {ranks}"
    );
    let heads_per = HEADS / ranks as i64;
    let mut g = Graph::new("qwen2_tp");
    let mut ri = RiBuilder::new();
    let mut x = replicate_input(&mut g, &mut ri, "x", &[SEQ, h]);
    for l in 0..layers {
        let p = format!("l{l}");
        let w_rms1 = replicate_input(&mut g, &mut ri, &format!("{p}_rms1_w"), &[h]);
        let w_rms2 = replicate_input(&mut g, &mut ri, &format!("{p}_rms2_w"), &[h]);
        let wq = col_shard_weight(&mut g, &mut ri, &format!("{p}_wq"), &[h, h], ranks)?;
        let wk = col_shard_weight(&mut g, &mut ri, &format!("{p}_wk"), &[h, h], ranks)?;
        let wv = col_shard_weight(&mut g, &mut ri, &format!("{p}_wv"), &[h, h], ranks)?;
        let wo = row_shard_weight(&mut g, &mut ri, &format!("{p}_wo"), &[h, h], ranks)?;
        let wg = col_shard_weight(&mut g, &mut ri, &format!("{p}_wg"), &[h, FFN], ranks)?;
        let wu = col_shard_weight(&mut g, &mut ri, &format!("{p}_wu"), &[h, FFN], ranks)?;
        let wd = row_shard_weight(&mut g, &mut ri, &format!("{p}_wd"), &[FFN, h], ranks)?;

        let n1 = rms(&mut g, &format!("{p}_rms1"), x, w_rms1);
        let mut parts = Vec::with_capacity(ranks);
        for rk in 0..ranks {
            let q = g.matmul(&format!("{p}_q_r{rk}"), n1, wq[rk]);
            let k = g.matmul(&format!("{p}_k_r{rk}"), n1, wk[rk]);
            let v = g.matmul(&format!("{p}_v_r{rk}"), n1, wv[rk]);
            let attn = attention(&mut g, &format!("{p}_r{rk}"), q, k, v, heads_per);
            parts.push(g.matmul(&format!("{p}_part_r{rk}"), attn, wo[rk]));
        }
        let proj = g.all_reduce(&format!("{p}_proj_ar"), parts);
        let x1 = g.add2(&format!("{p}_res1"), x, proj);
        let n2 = rms(&mut g, &format!("{p}_rms2"), x1, w_rms2);
        let mut mlp_parts = Vec::with_capacity(ranks);
        for rk in 0..ranks {
            let gate = g.matmul(&format!("{p}_gate_r{rk}"), n2, wg[rk]);
            let up = g.matmul(&format!("{p}_up_r{rk}"), n2, wu[rk]);
            let act = g.op(
                &format!("{p}_act_r{rk}"),
                Op::Custom { name: "fused_silu_mul".into() },
                vec![gate, up],
            );
            mlp_parts.push(g.matmul(&format!("{p}_down_r{rk}"), act, wd[rk]));
        }
        let mlp = g.all_reduce(&format!("{p}_mlp_ar"), mlp_parts);
        x = g.add2(&format!("{p}_res2"), x1, mlp);
    }
    g.mark_output(x);
    let ri = ri.finish(&gs, &g)?;
    Ok((gs, g, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::verify_numeric;
    use crate::verifier::Verifier;

    #[test]
    fn qwen2_tp2_refines() {
        let (gs, gd, ri) = tp_pair(2, 1).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 29).unwrap();
    }
}
