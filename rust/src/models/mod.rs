//! The evaluated model zoo (paper Table 2).
//!
//! | Framework analog      | Module         | Strategies          |
//! |-----------------------|----------------|---------------------|
//! | Megatron-LM GPT       | [`gpt`]        | TP, SP, VP, PP (incl. 1F1B/interleaved buffer schedules), FSDP, EP (switch-MoE) |
//! | vLLM Qwen2            | [`qwen2`]      | TP (fused kernels)  |
//! | HF regression + MSE   | [`regression`] | gradient accumulation (fwd+bwd) |
//! | Neuron Llama-3        | [`llama`]      | TP, PP, FSDP (via HLO frontend too) |
//! | ByteDance internal    | [`bytedance`]  | TP, SP, EP (fwd+bwd) |
//!
//! Each module exposes `seq(cfg)` building `G_s` and `*_pair(...)` builders
//! returning `(G_s, G_d, R_i)`. Builders construct the distributed graph the
//! way a Megatron/vLLM implementer would — per-rank shards plus collectives —
//! using `crate::strategies` primitives, so `R_i` is assembled alongside.

pub mod bytedance;
pub mod gpt;
pub mod llama;
pub mod qwen2;
pub mod regression;

use crate::ir::Graph;
use crate::relation::Relation;
use anyhow::{Context, Result};

/// A ready-to-verify workload.
#[derive(Debug)]
pub struct Workload {
    pub name: String,
    pub gs: Graph,
    pub gd: Graph,
    pub ri: Relation,
    /// strategies applied, for reports
    pub strategies: Vec<&'static str>,
}

/// All Table-2 workloads at a given parallelism degree (1 layer each).
/// Fails — instead of panicking — when a builder rejects the degree (e.g.
/// attention heads not divisible by `ranks`), naming the workload that
/// failed, so untrusted input paths (the serve request loop, CLI flags)
/// can turn an incompatible degree into a structured error.
pub fn try_table2_workloads(ranks: usize) -> Result<Vec<Workload>> {
    let mut v = Vec::new();
    {
        let (gs, gd, ri) = gpt::tp_sp_pair(ranks, 1, &gpt::GptConfig::default())
            .with_context(|| format!("building gpt_tp_sp_{ranks}"))?;
        v.push(Workload { name: format!("gpt_tp_sp_{ranks}"), gs, gd, ri, strategies: vec!["tp", "sp"] });
    }
    {
        let (gs, gd, ri) =
            qwen2::tp_pair(ranks, 1).with_context(|| format!("building qwen2_tp_{ranks}"))?;
        v.push(Workload { name: format!("qwen2_tp_{ranks}"), gs, gd, ri, strategies: vec!["tp"] });
    }
    {
        let (gs, gd, ri) = regression::grad_accum_pair(ranks.max(2))
            .with_context(|| format!("building regression_ga_{}", ranks.max(2)))?;
        v.push(Workload {
            name: format!("regression_ga_{}", ranks.max(2)),
            gs,
            gd,
            ri,
            strategies: vec!["grad_accum"],
        });
    }
    {
        let (gs, gd, ri) = llama::tp_pair(ranks, 1, &llama::LlamaConfig::default())
            .with_context(|| format!("building llama3_tp_{ranks}"))?;
        v.push(Workload { name: format!("llama3_tp_{ranks}"), gs, gd, ri, strategies: vec!["tp"] });
    }
    {
        let (gs, gd, ri) = bytedance::tp_sp_ep_pair(ranks, 1)
            .with_context(|| format!("building bytedance_tp_sp_ep_{ranks}"))?;
        v.push(Workload {
            name: format!("bytedance_tp_sp_ep_{ranks}"),
            gs,
            gd,
            ri,
            strategies: vec!["tp", "sp", "ep"],
        });
    }
    {
        // 2 pipeline stages over 2 layers, TP inside each stage
        let (gs, gd, ri) = gpt::pp_tp_pair(2, ranks, 2)
            .with_context(|| format!("building gpt_pp2_tp_{ranks}"))?;
        v.push(Workload {
            name: format!("gpt_pp2_tp_{ranks}"),
            gs,
            gd,
            ri,
            strategies: vec!["pp", "tp"],
        });
    }
    {
        let (gs, gd, ri) =
            gpt::fsdp_pair(ranks, 1).with_context(|| format!("building gpt_fsdp_{ranks}"))?;
        v.push(Workload { name: format!("gpt_fsdp_{ranks}"), gs, gd, ri, strategies: vec!["fsdp"] });
    }
    {
        let (gs, gd, ri) = llama::fsdp_pair(ranks, 1, &llama::LlamaConfig::default())
            .with_context(|| format!("building llama3_fsdp_{ranks}"))?;
        v.push(Workload {
            name: format!("llama3_fsdp_{ranks}"),
            gs,
            gd,
            ri,
            strategies: vec!["fsdp"],
        });
    }
    // switch-style top-k MoE with expert parallelism (router-conditioned
    // relations; data-dependent token-to-expert assignment). Only at degrees
    // that divide the fixed expert count — the other workloads still run at
    // e.g. ranks 8 or 1, where EP over 4 experts is undefined.
    if ranks >= 2 && gpt::MOE_EXPERTS % ranks == 0 {
        let (gs, gd, ri) =
            gpt::moe_ep_pair(ranks, 1).with_context(|| format!("building gpt_moe_ep_{ranks}"))?;
        v.push(Workload { name: format!("gpt_moe_ep_{ranks}"), gs, gd, ri, strategies: vec!["ep"] });
    }
    // schedule-aware pipeline parallelism (buffer-tagged 1F1B and
    // interleaved-virtual-stage lowerings) over the attention-free
    // MLP-transformer chain — micro-batched attention is a separate ROADMAP
    // item. The 2R micro-batches must divide the fixed seq length; other
    // degrees skip, like the MoE entry.
    let micro = 2 * ranks;
    if micro >= 2 && gpt::GptConfig::default().seq % micro as i64 == 0 {
        let sched = crate::schedule::Schedule::one_f_one_b(2, micro);
        let (gs, gd, ri) = gpt::pp_sched_pair(&sched, 2)
            .with_context(|| format!("building gpt_pp2_1f1b_{ranks}"))?;
        v.push(Workload {
            name: format!("gpt_pp2_1f1b_{ranks}"),
            gs,
            gd,
            ri,
            strategies: vec!["pp", "1f1b"],
        });
        let sched = crate::schedule::Schedule::interleaved(2, micro, 2);
        let (gs, gd, ri) = gpt::pp_sched_pair(&sched, 4)
            .with_context(|| format!("building gpt_pp2x2_intlv_{ranks}"))?;
        v.push(Workload {
            name: format!("gpt_pp2x2_intlv_{ranks}"),
            gs,
            gd,
            ri,
            strategies: vec!["pp", "interleaved"],
        });
    }
    Ok(v)
}

/// Infallible convenience for trusted callers (tests, benches, examples)
/// running at known-good degrees. Panics when a builder rejects `ranks`;
/// untrusted input paths must use [`try_table2_workloads`] instead.
pub fn table2_workloads(ranks: usize) -> Vec<Workload> {
    try_table2_workloads(ranks)
        .unwrap_or_else(|e| panic!("table2 workloads at ranks={ranks}: {e:#}"))
}

#[cfg(test)]
mod tests {
    #[test]
    fn moe_workload_gated_on_compatible_degrees() {
        let names = |ranks: usize| -> Vec<String> {
            super::table2_workloads(ranks).into_iter().map(|w| w.name).collect()
        };
        assert!(names(2).iter().any(|n| n == "gpt_moe_ep_2"));
        assert!(names(4).iter().any(|n| n == "gpt_moe_ep_4"));
        // a degenerate degree skips EP instead of panicking the whole suite
        assert!(!names(1).iter().any(|n| n.starts_with("gpt_moe_ep")));
    }

    #[test]
    fn pp_sched_workloads_gated_on_divisible_micro_batching() {
        let names = |ranks: usize| -> Vec<String> {
            super::table2_workloads(ranks).into_iter().map(|w| w.name).collect()
        };
        // micro = 2R divides seq = 8 at every degree the suite runs
        for r in [1usize, 2, 4] {
            assert!(names(r).iter().any(|n| n == &format!("gpt_pp2_1f1b_{r}")), "ranks {r}");
            assert!(names(r).iter().any(|n| n == &format!("gpt_pp2x2_intlv_{r}")), "ranks {r}");
        }
    }

    #[test]
    fn incompatible_degree_is_an_error_not_a_panic() {
        // heads=4 is not divisible by 3: the fallible builder must report
        // which workload rejected the degree instead of unwinding (the serve
        // loop turns this into a structured error response).
        let e = super::try_table2_workloads(3).expect_err("ranks=3 must not build");
        let msg = format!("{e:#}");
        assert!(msg.contains("gpt_tp_sp_3"), "error names the workload: {msg}");
    }

    #[test]
    fn all_table2_workloads_build_and_validate() {
        for w in super::table2_workloads(2) {
            w.gs.validate().unwrap_or_else(|e| panic!("{}: gs: {e}", w.name));
            w.gd.validate().unwrap_or_else(|e| panic!("{}: gd: {e}", w.name));
            w.ri.validate_shapes(&w.gs, &w.gd).unwrap_or_else(|e| panic!("{}: ri: {e}", w.name));
            assert!(!w.gs.outputs.is_empty());
        }
    }
}
