//! Stand-in for the ByteDance proprietary internal model (Table 2): an MoE
//! transformer block with RoPE attention, explicit RMS-norm composition
//! (autodiff-able), dense-gated experts and an auxiliary load-balancing
//! loss — the op mix the five internal §6.2 bugs live in. Distributed with
//! TP (attention heads + expert matmuls), SP (sequence-sharded activations,
//! sliced RoPE tables — Bug 1's structure) and EP (experts across ranks).
//!
//! The forward graph is verified as `bytedance_fwd`; a norm+MoE sub-block
//! with its autodiff backward is `bytedance_bwd` (the paper instruments
//! fwd, bwd and optimizer graphs of its internal model).

use crate::ir::autodiff::append_backward;
use crate::ir::{FBits, Graph, Op, TensorId};
use crate::relation::Relation;
use crate::strategies::{chunks, col_shard_weight, replicate_input, row_shard_weight, shard_input, RiBuilder};
use anyhow::Result;

pub const SEQ: i64 = 8;
pub const HEADS: i64 = 4;
pub const HEAD_DIM: i64 = 4;
pub const EXPERTS: i64 = 4;
pub const EXPERT_FFN: i64 = 16;

pub fn hidden() -> i64 {
    HEADS * HEAD_DIM
}

/// Explicit RMS-norm composition: x · rsqrt(mean(x², last)+eps) · w.
/// Written out op-by-op so `ir::autodiff` can differentiate it (the fused
/// `rms_norm`/Pallas form is used on the inference-only models).
fn rms_explicit(g: &mut Graph, p: &str, x: TensorId, w: TensorId) -> TensorId {
    let last = g.shape(x).len() - 1;
    let sq = g.op(&format!("{p}_sq"), Op::Square, vec![x]);
    let ms = g.op(&format!("{p}_ms"), Op::ReduceMean { dim: last, keepdim: true }, vec![sq]);
    let eps = g.op(&format!("{p}_eps"), Op::AddScalar { c: FBits::new(1e-6) }, vec![ms]);
    let inv = g.op(&format!("{p}_inv"), Op::Rsqrt, vec![eps]);
    let n = g.mul2(&format!("{p}_n"), x, inv);
    g.mul2(&format!("{p}_out"), n, w)
}

/// Dense-gated MoE: out = Σ_e gate_e ⊙ (silu(x·W1ₑ)·W2ₑ), gates from a
/// softmax router; plus the auxiliary load-balancing loss
/// aux = mean(gate²)·E (a Switch-style proxy that the strategies must
/// scale correctly — §6.2 Bug 2's home).
fn moe(
    g: &mut Graph,
    p: &str,
    x: TensorId,
    wg: TensorId,
    w1: &[TensorId],
    w2: &[TensorId],
) -> (TensorId, TensorId) {
    moe_impl(g, p, x, wg, w1, w2, true)
}

fn moe_no_aux(
    g: &mut Graph,
    p: &str,
    x: TensorId,
    wg: TensorId,
    w1: &[TensorId],
    w2: &[TensorId],
) -> TensorId {
    moe_impl(g, p, x, wg, w1, w2, false).0
}

fn moe_impl(
    g: &mut Graph,
    p: &str,
    x: TensorId,
    wg: TensorId,
    w1: &[TensorId],
    w2: &[TensorId],
    with_aux: bool,
) -> (TensorId, TensorId) {
    let scores = g.matmul(&format!("{p}_router"), x, wg);
    let gates = g.softmax(&format!("{p}_gates"), scores, 1); // [s, E]
    let mut terms = Vec::with_capacity(w1.len());
    for e in 0..w1.len() {
        let ge = g.slice(&format!("{p}_g{e}"), gates, 1, e as i64, e as i64 + 1); // [s,1]
        let h1 = g.matmul(&format!("{p}_e{e}_h1"), x, w1[e]);
        let act = g.op(&format!("{p}_e{e}_act"), Op::Silu, vec![h1]);
        let h2 = g.matmul(&format!("{p}_e{e}_h2"), act, w2[e]);
        terms.push(g.mul2(&format!("{p}_e{e}_w"), ge, h2));
    }
    let out = g.op(&format!("{p}_moe"), Op::SumN, terms);
    if !with_aux {
        return (out, out);
    }
    // aux loss: E · mean(gates²)
    let g2 = g.op(&format!("{p}_aux_sq"), Op::Square, vec![gates]);
    let m1 = g.op(&format!("{p}_aux_m1"), Op::ReduceMean { dim: 1, keepdim: false }, vec![g2]);
    let m0 = g.op(&format!("{p}_aux_m0"), Op::ReduceMean { dim: 0, keepdim: false }, vec![m1]);
    let aux = g.scale(&format!("{p}_aux"), m0, EXPERTS as f64);
    (out, aux)
}

/// Sequential forward block: RoPE attention + MoE with aux loss.
pub fn seq_fwd() -> Graph {
    let h = hidden();
    let mut g = Graph::new("bytedance_seq");
    let x = g.input("x", vec![SEQ, h]);
    let cos = g.input("cos", vec![SEQ, HEAD_DIM]);
    let sin = g.input("sin", vec![SEQ, HEAD_DIM]);
    let w_rms1 = g.input("rms1_w", vec![h]);
    let wq = g.input("wq", vec![h, h]);
    let wk = g.input("wk", vec![h, h]);
    let wv = g.input("wv", vec![h, h]);
    let wo = g.input("wo", vec![h, h]);
    let w_rms2 = g.input("rms2_w", vec![h]);
    let wg = g.input("router_w", vec![h, EXPERTS]);
    let w1: Vec<TensorId> =
        (0..EXPERTS).map(|e| g.input(&format!("e{e}_w1"), vec![h, EXPERT_FFN])).collect();
    let w2: Vec<TensorId> =
        (0..EXPERTS).map(|e| g.input(&format!("e{e}_w2"), vec![EXPERT_FFN, h])).collect();

    let n1 = rms_explicit(&mut g, "rms1", x, w_rms1);
    let q = g.matmul("q", n1, wq);
    let k = g.matmul("k", n1, wk);
    let v = g.matmul("v", n1, wv);
    let mut outs = Vec::new();
    for i in 0..HEADS {
        let (lo, hi) = (i * HEAD_DIM, (i + 1) * HEAD_DIM);
        let qi = g.slice(&format!("q{i}"), q, 1, lo, hi);
        let ki = g.slice(&format!("k{i}"), k, 1, lo, hi);
        let vi = g.slice(&format!("v{i}"), v, 1, lo, hi);
        let qr = g.op(&format!("qr{i}"), Op::Rope, vec![qi, cos, sin]);
        let kr = g.op(&format!("kr{i}"), Op::Rope, vec![ki, cos, sin]);
        outs.push(g.op(
            &format!("o{i}"),
            Op::Custom { name: "pallas_attention".into() },
            vec![qr, kr, vi],
        ));
    }
    let attn = g.concat("attn", outs, 1);
    let proj = g.matmul("proj", attn, wo);
    let x1 = g.add2("res1", x, proj);
    let n2 = rms_explicit(&mut g, "rms2", x1, w_rms2);
    let (moe_out, aux) = moe(&mut g, "moe", n2, wg, &w1, &w2);
    let y = g.add2("y", x1, moe_out);
    g.mark_output(y);
    g.mark_output(aux);
    g
}

/// TP+SP+EP distributed forward. SP shards activations on the sequence dim
/// (RoPE tables sliced per rank — the Bug-1 structure); TP shards attention
/// heads; EP places experts on ranks (router replicated).
pub fn tp_sp_ep_pair(ranks: usize, _layers: usize) -> Result<(Graph, Graph, Relation)> {
    let gs = seq_fwd();
    let h = hidden();
    let r = ranks as i64;
    anyhow::ensure!(HEADS % r == 0 && SEQ % r == 0 && EXPERTS % r == 0, "not divisible by {ranks}");
    let heads_per = HEADS / r;
    let experts_per = (EXPERTS / r) as usize;
    let mut g = Graph::new("bytedance_tp_sp_ep");
    let mut ri = RiBuilder::new();

    // SP: activations sequence-sharded
    let xs = shard_input(&mut g, &mut ri, "x", &[SEQ, h], 0, ranks)?;
    let cos = replicate_input(&mut g, &mut ri, "cos", &[SEQ, HEAD_DIM]);
    let sin = replicate_input(&mut g, &mut ri, "sin", &[SEQ, HEAD_DIM]);
    let w_rms1 = replicate_input(&mut g, &mut ri, "rms1_w", &[h]);
    let w_rms2 = replicate_input(&mut g, &mut ri, "rms2_w", &[h]);
    let wq = col_shard_weight(&mut g, &mut ri, "wq", &[h, h], ranks)?;
    let wk = col_shard_weight(&mut g, &mut ri, "wk", &[h, h], ranks)?;
    let wv = col_shard_weight(&mut g, &mut ri, "wv", &[h, h], ranks)?;
    let wo = row_shard_weight(&mut g, &mut ri, "wo", &[h, h], ranks)?;
    let wg = replicate_input(&mut g, &mut ri, "router_w", &[h, EXPERTS]);
    // EP: each expert's weights live on one rank, replicated there (not
    // sharded — sharding them under SP is exactly §6.2 Bug 4)
    let w1: Vec<TensorId> = (0..EXPERTS)
        .map(|e| replicate_input(&mut g, &mut ri, &format!("e{e}_w1"), &[h, EXPERT_FFN]))
        .collect();
    let w2: Vec<TensorId> = (0..EXPERTS)
        .map(|e| replicate_input(&mut g, &mut ri, &format!("e{e}_w2"), &[EXPERT_FFN, h]))
        .collect();

    // per-rank RMS norm on sequence shards, then all-gather into TP region
    let n1s: Vec<TensorId> = xs
        .iter()
        .enumerate()
        .map(|(rk, &xr)| rms_explicit(&mut g, &format!("rms1_r{rk}"), xr, w_rms1))
        .collect();
    let n1 = g.all_gather("rms1_ag", n1s, 0);

    // TP attention over gathered activations; RoPE uses FULL tables here
    // because q/k cover the full sequence after the gather.
    let mut parts = Vec::with_capacity(ranks);
    for rk in 0..ranks {
        let q = g.matmul(&format!("q_r{rk}"), n1, wq[rk]);
        let k = g.matmul(&format!("k_r{rk}"), n1, wk[rk]);
        let v = g.matmul(&format!("v_r{rk}"), n1, wv[rk]);
        let mut outs = Vec::new();
        for i in 0..heads_per {
            let (lo, hi) = (i * HEAD_DIM, (i + 1) * HEAD_DIM);
            let qi = g.slice(&format!("q_r{rk}_{i}"), q, 1, lo, hi);
            let ki = g.slice(&format!("k_r{rk}_{i}"), k, 1, lo, hi);
            let vi = g.slice(&format!("v_r{rk}_{i}"), v, 1, lo, hi);
            let qr = g.op(&format!("qr_r{rk}_{i}"), Op::Rope, vec![qi, cos, sin]);
            let kr = g.op(&format!("kr_r{rk}_{i}"), Op::Rope, vec![ki, cos, sin]);
            outs.push(g.op(
                &format!("o_r{rk}_{i}"),
                Op::Custom { name: "pallas_attention".into() },
                vec![qr, kr, vi],
            ));
        }
        let attn = g.concat(&format!("attn_r{rk}"), outs, 1);
        parts.push(g.matmul(&format!("part_r{rk}"), attn, wo[rk]));
    }
    // reduce-scatter back to sequence shards + residual
    let res1: Vec<TensorId> = (0..ranks)
        .map(|rk| {
            let rs = g.reduce_scatter(&format!("rs1_r{rk}"), parts.clone(), 0, rk);
            g.add2(&format!("res1_r{rk}"), xs[rk], rs)
        })
        .collect();

    // MoE region: per-rank norm on sequence shards; EP experts applied to
    // the all-gathered activations, partial expert sums all-reduced.
    let n2s: Vec<TensorId> = res1
        .iter()
        .enumerate()
        .map(|(rk, &xr)| rms_explicit(&mut g, &format!("rms2_r{rk}"), xr, w_rms2))
        .collect();
    let n2 = g.all_gather("rms2_ag", n2s, 0);
    let scores = g.matmul("router", n2, wg);
    let gates = g.softmax("gates", scores, 1);
    let mut rank_terms: Vec<TensorId> = Vec::with_capacity(ranks);
    for rk in 0..ranks {
        let mut local = Vec::with_capacity(experts_per);
        for j in 0..experts_per {
            let e = rk * experts_per + j;
            let ge = g.slice(&format!("g_r{rk}_{j}"), gates, 1, e as i64, e as i64 + 1);
            let h1 = g.matmul(&format!("e{e}_h1_d"), n2, w1[e]);
            let act = g.op(&format!("e{e}_act_d"), Op::Silu, vec![h1]);
            let h2 = g.matmul(&format!("e{e}_h2_d"), act, w2[e]);
            local.push(g.mul2(&format!("e{e}_w_d"), ge, h2));
        }
        rank_terms.push(g.op(&format!("moe_local_r{rk}"), Op::SumN, local));
    }
    let moe_out = g.all_reduce("moe_ar", rank_terms);
    // aux loss computed from the replicated gates (correctly unscaled here;
    // the TP aux-loss bug variant lives in crate::bugs)
    let g2 = g.op("aux_sq_d", Op::Square, vec![gates]);
    let m1 = g.op("aux_m1_d", Op::ReduceMean { dim: 1, keepdim: false }, vec![g2]);
    let m0 = g.op("aux_m0_d", Op::ReduceMean { dim: 0, keepdim: false }, vec![m1]);
    let aux = g.scale("aux_d", m0, EXPERTS as f64);

    // final residual on sequence shards, gathered for output
    let ys: Vec<TensorId> = (0..ranks)
        .map(|rk| {
            let (lo, hi) = chunks(SEQ, ranks)[rk];
            let piece = g.slice(&format!("moe_piece_r{rk}"), moe_out, 0, lo, hi);
            g.add2(&format!("y_r{rk}"), res1[rk], piece)
        })
        .collect();
    let y = g.all_gather("y_ag", ys, 0);
    g.mark_output(y);
    g.mark_output(aux);

    let ri = ri.finish(&gs, &g)?;
    Ok((gs, g, ri))
}

/// Backward workload: norm + MoE sub-block with autodiff gradients, in a
/// sequential and a TP-expert variant (the paper's "Bwd" graphs).
pub fn bwd_pair(ranks: usize) -> Result<(Graph, Graph, Relation)> {
    let h = hidden();
    // sequential: loss = mse(moe(rms(x)), target) + aux
    let mut gs = Graph::new("bytedance_bwd_seq");
    let x = gs.input("x", vec![SEQ, h]);
    let w_rms = gs.input("rms_w", vec![h]);
    let wg = gs.input("router_w", vec![h, EXPERTS]);
    let w1: Vec<TensorId> =
        (0..EXPERTS).map(|e| gs.input(&format!("e{e}_w1"), vec![h, EXPERT_FFN])).collect();
    let w2: Vec<TensorId> =
        (0..EXPERTS).map(|e| gs.input(&format!("e{e}_w2"), vec![EXPERT_FFN, h])).collect();
    let target = gs.input("target", vec![SEQ, h]);
    let n = rms_explicit(&mut gs, "rms", x, w_rms);
    let out = moe_no_aux(&mut gs, "moe", n, wg, &w1, &w2);
    let loss = gs.op("loss", Op::MseLoss, vec![out, target]);
    gs.mark_output(loss);
    append_backward(&mut gs, loss, &[x])?;
    let gs = gs.eliminate_dead_code();

    // distributed: EP over experts (same sequence, replicated activations)
    anyhow::ensure!(EXPERTS % ranks as i64 == 0, "experts % ranks");
    let experts_per = (EXPERTS / ranks as i64) as usize;
    let mut gd = Graph::new("bytedance_bwd_ep");
    let mut ri = RiBuilder::new();
    let xd = replicate_input(&mut gd, &mut ri, "x", &[SEQ, h]);
    let w_rms_d = replicate_input(&mut gd, &mut ri, "rms_w", &[h]);
    let wg_d = replicate_input(&mut gd, &mut ri, "router_w", &[h, EXPERTS]);
    let w1d: Vec<TensorId> = (0..EXPERTS)
        .map(|e| replicate_input(&mut gd, &mut ri, &format!("e{e}_w1"), &[h, EXPERT_FFN]))
        .collect();
    let w2d: Vec<TensorId> = (0..EXPERTS)
        .map(|e| replicate_input(&mut gd, &mut ri, &format!("e{e}_w2"), &[EXPERT_FFN, h]))
        .collect();
    let target_d = replicate_input(&mut gd, &mut ri, "target", &[SEQ, h]);
    let nd = rms_explicit(&mut gd, "rms", xd, w_rms_d);
    let scores = gd.matmul("router", nd, wg_d);
    let gates = gd.softmax("gates", scores, 1);
    let mut rank_terms = Vec::with_capacity(ranks);
    for rk in 0..ranks {
        let mut local = Vec::with_capacity(experts_per);
        for j in 0..experts_per {
            let e = rk * experts_per + j;
            let ge = gd.slice(&format!("g_r{rk}_{j}"), gates, 1, e as i64, e as i64 + 1);
            let h1 = gd.matmul(&format!("e{e}_h1"), nd, w1d[e]);
            let act = gd.op(&format!("e{e}_act"), Op::Silu, vec![h1]);
            let h2 = gd.matmul(&format!("e{e}_h2"), act, w2d[e]);
            local.push(gd.mul2(&format!("e{e}_w"), ge, h2));
        }
        rank_terms.push(gd.op(&format!("moe_local_r{rk}"), Op::SumN, local));
    }
    let out_d = gd.all_reduce("moe_ar", rank_terms);
    let loss_d = gd.op("loss", Op::MseLoss, vec![out_d, target_d]);
    gd.mark_output(loss_d);
    append_backward(&mut gd, loss_d, &[xd])?;
    let gd = gd.eliminate_dead_code();

    let ri = ri.finish(&gs, &gd)?;
    Ok((gs, gd, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::verify_numeric;
    use crate::verifier::Verifier;

    #[test]
    fn seq_fwd_builds() {
        let g = seq_fwd();
        g.validate().unwrap();
        assert_eq!(g.outputs.len(), 2);
    }

    #[test]
    fn bytedance_fwd_tp_sp_ep2_refines() {
        let (gs, gd, ri) = tp_sp_ep_pair(2, 1).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 41).unwrap();
    }

    #[test]
    fn bytedance_bwd_ep2_refines() {
        let (gs, gd, ri) = bwd_pair(2).unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri)
            .unwrap_or_else(|e| panic!("{e}"));
        verify_numeric(&gs, &gd, &ri, &out.relation, 43).unwrap();
    }
}
