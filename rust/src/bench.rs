//! Mini benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup, timed
//! iterations, mean/p50/p95, and aligned table output matching the rows and
//! series the paper's tables/figures report.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Single-shot measurement (for long-running end-to-end verifications).
pub fn measure<T>(name: &str, mut f: impl FnMut() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let d = t0.elapsed();
    (out, BenchResult { name: name.to_string(), iters: 1, mean: d, p50: d, p95: d })
}

/// Render results as an aligned table.
pub fn table(title: &str, results: &[BenchResult]) -> String {
    let mut s = format!("== {title} ==\n");
    let w = results.iter().map(|r| r.name.len()).max().unwrap_or(10).max(10);
    s.push_str(&format!(
        "{:<w$}  {:>10}  {:>10}  {:>10}  {:>6}\n",
        "case", "mean", "p50", "p95", "iters",
    ));
    for r in results {
        s.push_str(&format!(
            "{:<w$}  {:>10}  {:>10}  {:>10}  {:>6}\n",
            r.name,
            fmt_dur(r.mean),
            fmt_dur(r.p50),
            fmt_dur(r.p95),
            r.iters,
        ));
    }
    s
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop", 2, 16, || 1 + 1);
        assert_eq!(r.iters, 16);
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn table_renders() {
        let r = bench("x", 0, 4, || std::thread::sleep(Duration::from_micros(50)));
        let t = table("demo", &[r]);
        assert!(t.contains("demo") && t.contains("x"));
    }

    #[test]
    fn measure_returns_value() {
        let (v, r) = measure("calc", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }
}
