//! Mini benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup, timed
//! iterations, mean/p50/p95, and aligned table output matching the rows and
//! series the paper's tables/figures report.

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// One machine-readable benchmark row, serialized into `BENCH_<name>.json`
/// so the perf trajectory is tracked across PRs (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub workload: String,
    /// total operator count of the measured workload (0 if not applicable)
    pub ops: usize,
    pub wall_ns: u128,
    pub lemma_applications: u64,
    /// Three-valued verdict tag ("verified" / "refuted" /
    /// "inconclusive_*") so a budget-starved bench row is distinguishable
    /// from a fast one in the tracked perf series.
    pub verdict: &'static str,
    /// Fingerprint-cache counters for the measured run (both 0 when the
    /// cache was disabled; `BENCH_cache.json` is the primary consumer).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl BenchRecord {
    pub fn new(
        workload: impl Into<String>,
        ops: usize,
        wall: Duration,
        lemma_applications: u64,
    ) -> Self {
        BenchRecord {
            workload: workload.into(),
            ops,
            wall_ns: wall.as_nanos(),
            lemma_applications,
            verdict: "verified",
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    pub fn with_verdict(mut self, verdict: &'static str) -> Self {
        self.verdict = verdict;
        self
    }

    pub fn with_cache(mut self, hits: u64, misses: u64) -> Self {
        self.cache_hits = hits;
        self.cache_misses = misses;
        self
    }
}

/// Write `BENCH_<name>.json` in the working directory, alongside the
/// printed table. Returns the path written.
pub fn write_bench_json(
    name: &str,
    records: &[BenchRecord],
) -> std::io::Result<std::path::PathBuf> {
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("workload", Json::str(r.workload.clone())),
                ("ops", Json::num(r.ops as f64)),
                ("wall_ns", Json::num(r.wall_ns as f64)),
                ("lemma_applications", Json::num(r.lemma_applications as f64)),
                ("verdict", Json::str(r.verdict)),
                ("cache_hits", Json::num(r.cache_hits as f64)),
                ("cache_misses", Json::num(r.cache_misses as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema_version", crate::util::schema::version_field()),
        ("bench", Json::str(name)),
        ("results", Json::arr(rows)),
    ]);
    let path = std::path::PathBuf::from(format!("BENCH_{name}.json"));
    std::fs::write(&path, doc.to_string_pretty())?;
    Ok(path)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Single-shot measurement (for long-running end-to-end verifications).
pub fn measure<T>(name: &str, mut f: impl FnMut() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let d = t0.elapsed();
    (out, BenchResult { name: name.to_string(), iters: 1, mean: d, p50: d, p95: d })
}

/// Render results as an aligned table.
pub fn table(title: &str, results: &[BenchResult]) -> String {
    let mut s = format!("== {title} ==\n");
    let w = results.iter().map(|r| r.name.len()).max().unwrap_or(10).max(10);
    s.push_str(&format!(
        "{:<w$}  {:>10}  {:>10}  {:>10}  {:>6}\n",
        "case", "mean", "p50", "p95", "iters",
    ));
    for r in results {
        s.push_str(&format!(
            "{:<w$}  {:>10}  {:>10}  {:>10}  {:>6}\n",
            r.name,
            fmt_dur(r.mean),
            fmt_dur(r.p50),
            fmt_dur(r.p95),
            r.iters,
        ));
    }
    s
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop", 2, 16, || 1 + 1);
        assert_eq!(r.iters, 16);
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn table_renders() {
        let r = bench("x", 0, 4, || std::thread::sleep(Duration::from_micros(50)));
        let t = table("demo", &[r]);
        assert!(t.contains("demo") && t.contains("x"));
    }

    #[test]
    fn measure_returns_value() {
        let (v, r) = measure("calc", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn bench_json_roundtrips() {
        let rec = BenchRecord::new("toy", 7, Duration::from_micros(1500), 42).with_cache(9, 3);
        let path = write_bench_json("unittest_scratch", &[rec]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").as_str(), Some("unittest_scratch"));
        let rows = doc.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("workload").as_str(), Some("toy"));
        assert_eq!(rows[0].get("ops").as_usize(), Some(7));
        assert_eq!(rows[0].get("wall_ns").as_f64(), Some(1_500_000.0));
        assert_eq!(rows[0].get("lemma_applications").as_usize(), Some(42));
        assert_eq!(rows[0].get("verdict").as_str(), Some("verified"));
        assert_eq!(rows[0].get("cache_hits").as_usize(), Some(9));
        assert_eq!(rows[0].get("cache_misses").as_usize(), Some(3));
    }
}
