//! GraphGuard CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   verify  --gs <graph.json> --gd <graph.json> --ri <relation.json>
//!   reverify --gs g_s.json --gd g_d.json --ri relation.json --patch p.json
//!           incremental re-verification: apply a GraphPatch, classify the
//!           dirty cone statically, reuse certificates for Clean regions
//!   patch   --gd g_d.json --patch p.json    apply a patch, print the graph
//!   serve   [--socket PATH] [--canonical]     long-lived verification
//!           service: newline-delimited JSON requests on stdin (or a Unix
//!           socket), one response per line, shared warm cache
//!   suite   [--ranks N] [--threads N]      run the Table-2 workload suite
//!   bugs                                    run the §6.2 case studies
//!   fuzz    [--seeds N] [--seed S] [--flavor F] ...  bug-injection fuzzer
//!   lint    [--ranks N] [--json] [--fixture ce.json]  ShardFlow static
//!           analysis only (no saturation): Table-2 sweep or one fixture
//!   lemmas                                  list the lemma library
//!   hlo     --file <module.hlo.txt>         parse an HLO-text module
//!
//! Options shared across subcommands (`--ranks`, `--jobs`, `--no-cache`,
//! `--canonical`, `--deadline-ms`) are parsed once by [`CommonOpts`];
//! `<subcommand> --help` prints per-command usage plus the exit-code
//! contract. Exit codes mirror the three-valued verdict plus two
//! operational states:
//!   0  verified / sound (for `lint`: zero findings)
//!   1  refuted (a genuine refinement bug, an unsound fuzz campaign, or —
//!      for `lint` — one or more findings)
//!   2  operational error (bad arguments, I/O, malformed inputs)
//!   3  inconclusive (resource budgets exhausted before a verdict)
//!   4  fuzz campaign aborted early (crash drill via --abort-after)
//!
//! (Hand-rolled argument parsing — no clap in the offline crate set.)

// stdout is this target's product (CLI output / bench tables) — opt back in.
#![allow(clippy::print_stdout)]

use anyhow::{anyhow, Context, Result};
use graphguard::coordinator::JobVerdict;
use graphguard::infer::Verdict;
use graphguard::{
    bugs, coordinator, fuzz, hlo, infer, ir, lemmas, models, relation, serve, Verifier,
};
use std::time::Duration;

const EXIT_OK: i32 = 0;
const EXIT_REFUTED: i32 = 1;
const EXIT_ERROR: i32 = 2;
const EXIT_INCONCLUSIVE: i32 = 3;
const EXIT_ABORTED: i32 = 4;

/// The contract every `--help` screen repeats, verbatim.
const EXIT_CONTRACT: &str = "exit codes:\n\
    \x20 0  verified / sound (for lint: zero findings)\n\
    \x20 1  refuted / unsound campaign / lint findings\n\
    \x20 2  operational error (bad arguments, I/O, malformed inputs)\n\
    \x20 3  inconclusive (resource budgets exhausted before a verdict)\n\
    \x20 4  fuzz campaign aborted early (--abort-after crash drill)";

fn main() {
    let code = match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            EXIT_ERROR
        }
    };
    std::process::exit(code);
}

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// Flags shared by every subcommand, parsed in one place. `ranks` has no
/// hard default here because subcommands disagree (suite/lint default to
/// 2, fuzz defaults to per-case choice) — use [`CommonOpts::ranks_or`].
struct CommonOpts {
    ranks: Option<usize>,
    jobs: Option<usize>,
    /// `Some(0)` disables the per-region deadline entirely.
    deadline_ms: Option<u64>,
    no_cache: bool,
    canonical: bool,
}

impl CommonOpts {
    fn parse(args: &[String]) -> Result<Self> {
        let num = |key: &str| -> Result<Option<usize>> {
            arg_value(args, key)
                .map(|v| v.parse().with_context(|| format!("bad {key} '{v}'")))
                .transpose()
        };
        Ok(CommonOpts {
            ranks: num("--ranks")?,
            jobs: num("--jobs")?,
            deadline_ms: arg_value(args, "--deadline-ms")
                .map(|v| v.parse().with_context(|| format!("bad --deadline-ms '{v}'")))
                .transpose()?,
            no_cache: args.iter().any(|a| a == "--no-cache"),
            canonical: args.iter().any(|a| a == "--canonical"),
        })
    }

    fn ranks_or(&self, default: usize) -> usize {
        self.ranks.unwrap_or(default)
    }

    /// Budget/throughput flags → inference config. `--deadline-ms 0`
    /// disables the per-region wall-clock deadline entirely; `--jobs N`
    /// runs the region walk on N workers (default 1); the certificate
    /// fingerprint cache is on unless `--no-cache` is given (fuzz builds
    /// its own configs and stays uncached — the differential oracle is the
    /// soundness net and must exercise the full engine every time).
    fn infer_cfg(&self) -> infer::InferConfig {
        let mut cfg = infer::InferConfig::default();
        if let Some(ms) = self.deadline_ms {
            cfg.region_deadline = (ms > 0).then(|| Duration::from_millis(ms));
        }
        if let Some(jobs) = self.jobs {
            cfg.jobs = jobs.max(1);
        }
        if !self.no_cache {
            cfg.cache = Some(graphguard::cache::FingerprintCache::global().clone());
        }
        cfg
    }
}

/// Per-subcommand usage; every screen ends with [`EXIT_CONTRACT`].
fn help_for(cmd: &str) -> String {
    let body = match cmd {
        "verify" => {
            "usage: graphguard verify --gs g_s.json --gd g_d.json --ri relation.json\n\
             \x20               [--deadline-ms N] [--jobs N] [--no-cache] [--check-numeric]\n\
             \x20               [--canonical]\n\
             \n\
             One-shot refinement check: infer a clean output relation for the\n\
             inline (G_s, G_d, R_i) triple, or localize where inference stops.\n\
             --canonical drops run-varying output (cache counters) for\n\
             byte-stable diffing against `reverify`."
        }
        "reverify" => {
            "usage: graphguard reverify --gs g_s.json --gd g_d.json --ri relation.json\n\
             \x20               --patch p.json [--impact-only] [--deadline-ms N] [--jobs N]\n\
             \x20               [--no-cache] [--check-numeric] [--canonical]\n\
             \n\
             Incremental re-verification of a patched implementation. Applies\n\
             the GraphPatch to G_d, statically classifies every region\n\
             Clean | Dirty | BoundaryShifted (impact summary on stderr), then\n\
             verifies the patched pair with certificates warmed on the old\n\
             pair — Clean regions replay instead of re-saturating. stdout is\n\
             byte-identical under --canonical to `verify` on the patched\n\
             files. --impact-only prints the impact report as JSON and skips\n\
             verification entirely. Patch schema: EXPERIMENTS.md\n\
             §Incremental re-verification."
        }
        "patch" => {
            "usage: graphguard patch --gd g_d.json --patch p.json\n\
             \n\
             Apply a GraphPatch to a graph and print the patched graph JSON\n\
             (strict validation: dangling inputs, id collisions, or failed\n\
             shape re-inference of the spliced region exit 2)."
        }
        "serve" => {
            "usage: graphguard serve [--socket PATH] [--canonical] [--deadline-ms N]\n\
             \x20               [--jobs N] [--no-cache]\n\
             \n\
             Long-lived verification service. Reads one JSON request per line on\n\
             stdin (or sequential connections on --socket PATH), answers each on\n\
             stdout with one JSON response per line, and shares a warm\n\
             fingerprint cache across requests. Malformed requests produce\n\
             structured error responses, never a process exit; the exit code\n\
             reflects only transport health (0 on EOF, 2 on I/O failure).\n\
             --canonical drops run-varying response fields (wall time, cache\n\
             counters) for byte-stable golden diffing. Request/response schema:\n\
             EXPERIMENTS.md §Serve."
        }
        "suite" => {
            "usage: graphguard suite [--ranks N] [--threads N] [--deadline-ms N]\n\
             \x20               [--jobs N] [--no-cache] [--canonical]\n\
             \n\
             Run the Table-2 workload suite through the coordinator.\n\
             --canonical prints the byte-stable report used by the determinism\n\
             CI gates (no durations, no cache counters)."
        }
        "bugs" => "usage: graphguard bugs\n\nRun the §6.2 case studies (buggy variants).",
        "fuzz" => {
            "usage: graphguard fuzz [--seeds N] [--seed S] [--ranks R] [--mutants M]\n\
             \x20               [--out DIR] [--flavor F] [--replay ce.json]\n\
             \x20               [--resume DIR] [--abort-after N]\n\
             \n\
             Bug-injection mutation fuzzer with a differential soundness oracle.\n\
             Artifacts (journal, FUZZ_REPORT.json, counterexamples) carry a\n\
             schema_version; --replay/--resume reject files written by a\n\
             different schema version (version-less files read as v0)."
        }
        "lint" => {
            "usage: graphguard lint [--ranks N] [--json] [--fixture ce.json]\n\
             \n\
             ShardFlow static analysis only (no saturation): Table-2 sweep or a\n\
             single replayable counterexample fixture."
        }
        "lemmas" => "usage: graphguard lemmas\n\nList the rewrite-lemma library.",
        "hlo" => {
            "usage: graphguard hlo --file module.hlo.txt\n\
             \n\
             Parse an HLO-text module and print its graph JSON."
        }
        _ => USAGE,
    };
    format!("{body}\n\n{EXIT_CONTRACT}")
}

const USAGE: &str =
    "usage: graphguard <verify|reverify|patch|serve|suite|bugs|fuzz|lint|lemmas|hlo> [options]\n\
     \n  verify --gs g_s.json --gd g_d.json --ri relation.json [--deadline-ms N]\
     \n         [--jobs N] [--no-cache] [--check-numeric] [--canonical]\
     \n  reverify --gs g_s.json --gd g_d.json --ri relation.json --patch p.json\
     \n         [--impact-only] [--deadline-ms N] [--jobs N] [--no-cache]\
     \n         [--check-numeric] [--canonical]\
     \n  patch  --gd g_d.json --patch p.json\
     \n  serve  [--socket PATH] [--canonical] [--deadline-ms N] [--jobs N] [--no-cache]\
     \n  suite  [--ranks N] [--threads N] [--deadline-ms N] [--jobs N]\
     \n         [--no-cache] [--canonical]\
     \n  bugs\
     \n  fuzz   [--seeds N] [--seed S] [--ranks R] [--mutants M] [--out DIR]\
     \n         [--flavor F] [--replay ce.json] [--resume DIR] [--abort-after N]\
     \n  lint   [--ranks N] [--json] [--fixture ce.json]\
     \n  lemmas\
     \n  hlo --file module.hlo.txt\
     \n\
     \nrun '<subcommand> --help' for details and the exit-code contract\
     \nexit codes: 0 verified/sound/lint-clean, 1 refuted/unsound/lint-findings,\
     \n            2 error, 3 inconclusive (budgets exhausted), 4 fuzz aborted";

fn run() -> Result<i32> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(cmd) = args.first().map(String::as_str) {
        if args.iter().skip(1).any(|a| a == "--help" || a == "-h") {
            println!("{}", help_for(cmd));
            return Ok(EXIT_OK);
        }
    }
    match args.first().map(String::as_str) {
        Some("verify") => cmd_verify(&args[1..]),
        Some("reverify") => cmd_reverify(&args[1..]),
        Some("patch") => cmd_patch(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("suite") => cmd_suite(&args[1..]),
        Some("bugs") => cmd_bugs(),
        Some("fuzz") => cmd_fuzz(&args[1..]),
        Some("lint") => cmd_lint(&args[1..]),
        Some("lemmas") => cmd_lemmas(),
        Some("hlo") => cmd_hlo(&args[1..]),
        _ => {
            eprintln!("{USAGE}");
            Ok(EXIT_OK)
        }
    }
}

fn load_graph(path: &str) -> Result<ir::Graph> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = graphguard::util::json::Json::parse(&text)
        .map_err(|e| anyhow!("{path}: {e}"))?;
    ir::json_io::from_json(&json).with_context(|| format!("building graph from {path}"))
}

/// Parse `--ri` against an already-loaded graph pair.
fn load_relation(args: &[String], gs: &ir::Graph, gd: &ir::Graph) -> Result<relation::Relation> {
    let ri_path = arg_value(args, "--ri").ok_or_else(|| anyhow!("--ri required"))?;
    let ri_text =
        std::fs::read_to_string(&ri_path).with_context(|| format!("reading {ri_path}"))?;
    let ri_json = graphguard::util::json::Json::parse(&ri_text)
        .map_err(|e| anyhow!("{ri_path}: {e}"))?;
    let ri = relation::Relation::from_json(&ri_json, gs, gd)?;
    ri.validate_shapes(gs, gd)?;
    Ok(ri)
}

fn load_patch(args: &[String]) -> Result<ir::GraphPatch> {
    let path = arg_value(args, "--patch").ok_or_else(|| anyhow!("--patch required"))?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let j = graphguard::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    ir::GraphPatch::from_json(&j).with_context(|| format!("parsing patch {path}"))
}

/// Shared verdict reporting for `verify` and `reverify` — both print the
/// *same bytes* for the same (gs, gd, ri) outcome, which is what the CI
/// incremental-determinism gate diffs. The cache line is run-varying and
/// suppressed under `--canonical`.
fn report_verdict(
    verdict: Verdict,
    gs: &ir::Graph,
    gd: &ir::Graph,
    ri: &relation::Relation,
    canonical: bool,
    check_numeric: bool,
) -> Result<i32> {
    match verdict {
        Verdict::Verified(out) => {
            println!("refinement HOLDS — R_o:");
            println!("{}", out.relation.to_json(gs, gd).to_string_pretty());
            if !canonical && out.cache_hits + out.cache_misses > 0 {
                println!(
                    "cache: {}/{} region hits",
                    out.cache_hits,
                    out.cache_hits + out.cache_misses
                );
            }
            if check_numeric {
                infer::verify_numeric(gs, gd, ri, &out.relation, 7)?;
                println!("numeric certificate: OK");
            }
            Ok(EXIT_OK)
        }
        Verdict::Refuted(e) => {
            println!("{e}");
            eprintln!("model refinement does not hold");
            Ok(EXIT_REFUTED)
        }
        Verdict::Inconclusive(i) => {
            println!("{i}");
            eprintln!(
                "verification INCONCLUSIVE — not a refutation; raise the budgets \
                 (--deadline-ms, larger node limits) and retry"
            );
            Ok(EXIT_INCONCLUSIVE)
        }
    }
}

fn cmd_verify(args: &[String]) -> Result<i32> {
    let opts = CommonOpts::parse(args)?;
    let gs = load_graph(&arg_value(args, "--gs").ok_or_else(|| anyhow!("--gs required"))?)?;
    let gd = load_graph(&arg_value(args, "--gd").ok_or_else(|| anyhow!("--gd required"))?)?;
    let ri = load_relation(args, &gs, &gd)?;
    let verdict = Verifier::with_config(opts.infer_cfg()).isolated(true).run(&gs, &gd, &ri);
    report_verdict(
        verdict,
        &gs,
        &gd,
        &ri,
        opts.canonical,
        args.iter().any(|a| a == "--check-numeric"),
    )
}

/// Incremental re-verification: `verify` semantics on the patched pair,
/// with certificates warmed on the old pair and the static impact
/// classification on stderr (stdout stays byte-comparable to `verify`).
fn cmd_reverify(args: &[String]) -> Result<i32> {
    let opts = CommonOpts::parse(args)?;
    let gs = load_graph(&arg_value(args, "--gs").ok_or_else(|| anyhow!("--gs required"))?)?;
    let gd = load_graph(&arg_value(args, "--gd").ok_or_else(|| anyhow!("--gd required"))?)?;
    let ri = load_relation(args, &gs, &gd)?;
    let patch = load_patch(args)?;
    let rv = Verifier::with_config(opts.infer_cfg())
        .isolated(true)
        .reverify(&gs, &gd, &ri, &patch)?;
    if args.iter().any(|a| a == "--impact-only") {
        println!("{}", rv.impact.to_json().to_string_pretty());
        return Ok(EXIT_OK);
    }
    eprint!("{}", rv.impact.render());
    report_verdict(
        rv.verdict,
        &gs,
        &rv.patched,
        &rv.ri,
        opts.canonical,
        args.iter().any(|a| a == "--check-numeric"),
    )
}

/// Apply a patch and print the resulting graph JSON (no verification) —
/// the tool the CI determinism gate uses to produce the "full verify"
/// side of the diff.
fn cmd_patch(args: &[String]) -> Result<i32> {
    let gd = load_graph(&arg_value(args, "--gd").ok_or_else(|| anyhow!("--gd required"))?)?;
    let patch = load_patch(args)?;
    let patched = patch.apply(&gd)?;
    println!("{}", ir::json_io::to_json(&patched).to_string_pretty());
    Ok(EXIT_OK)
}

/// The long-lived service. Exit code reflects transport health only —
/// per-request verdicts travel in the responses, not the exit code.
fn cmd_serve(args: &[String]) -> Result<i32> {
    let opts = CommonOpts::parse(args)?;
    let mut cfg = infer::InferConfig::default();
    if let Some(ms) = opts.deadline_ms {
        cfg.region_deadline = (ms > 0).then(|| Duration::from_millis(ms));
    }
    if let Some(jobs) = opts.jobs {
        cfg.jobs = jobs.max(1);
    }
    let sopts = serve::ServeOptions {
        cfg,
        cache: (!opts.no_cache)
            .then(|| graphguard::cache::FingerprintCache::global().clone()),
        canonical: opts.canonical,
    };
    if let Some(path) = arg_value(args, "--socket") {
        #[cfg(unix)]
        {
            serve::serve_unix(std::path::Path::new(&path), &sopts)?;
            return Ok(EXIT_OK);
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            anyhow::bail!("--socket requires a Unix platform; use stdin/stdout instead");
        }
    }
    let stats = serve::serve_stdio(&sopts)?;
    eprintln!(
        "serve: {} request(s) — {} verified, {} refuted, {} inconclusive, {} errors; \
         cache {}/{} hits",
        stats.requests,
        stats.verified,
        stats.refuted,
        stats.inconclusive,
        stats.errors,
        stats.cache_hits,
        stats.cache_hits + stats.cache_misses
    );
    Ok(EXIT_OK)
}

fn cmd_suite(args: &[String]) -> Result<i32> {
    let opts = CommonOpts::parse(args)?;
    let ranks = opts.ranks_or(2);
    let threads: usize =
        arg_value(args, "--threads").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let cfg = opts.infer_cfg();
    let coord = if threads > 0 {
        coordinator::Coordinator::new(threads, cfg)
    } else {
        coordinator::Coordinator { cfg, ..coordinator::Coordinator::default() }
    };
    let results = coord.run_batch(models::try_table2_workloads(ranks)?);
    if opts.canonical {
        // Byte-stable report for the jobs/cache determinism gate: no
        // durations, no cache counters (see coordinator::canonical_report).
        print!("{}", coordinator::canonical_report(&results));
    } else {
        print!("{}", coordinator::report_table(&results));
        println!("{}", coordinator::cache_summary(&results));
    }
    if results.iter().any(|r| r.verdict == JobVerdict::Refuted) {
        eprintln!("some workloads failed refinement");
        return Ok(EXIT_REFUTED);
    }
    if results.iter().any(|r| matches!(r.verdict, JobVerdict::Inconclusive(_))) {
        eprintln!("some workloads were inconclusive (budgets exhausted) — not refuted");
        return Ok(EXIT_INCONCLUSIVE);
    }
    Ok(EXIT_OK)
}

fn cmd_bugs() -> Result<i32> {
    println!("§6.2 case studies (buggy variants):\n");
    for case in bugs::all_cases(true) {
        let (detected, report) = case.run();
        println!("[bug {}] {} — {}", case.id, case.name, case.description);
        println!(
            "  expected: {}",
            match case.expected_locus {
                Some(l) => format!("detected near '{l}'"),
                None => "passes; inspect R_o / implementation trace".to_string(),
            }
        );
        println!("  outcome: {}", if detected { "DETECTED" } else { "refines" });
        for line in report.lines() {
            println!("    {line}");
        }
        println!();
    }
    Ok(EXIT_OK)
}

fn cmd_fuzz(args: &[String]) -> Result<i32> {
    let opts = CommonOpts::parse(args)?;
    if let Some(path) = arg_value(args, "--replay") {
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let j = graphguard::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        println!("{}", fuzz::replay_counterexample(&j)?);
        return Ok(EXIT_OK);
    }
    let abort_after = arg_value(args, "--abort-after")
        .map(|v| v.parse::<u64>().with_context(|| format!("bad --abort-after '{v}'")))
        .transpose()?;
    if let Some(dir) = arg_value(args, "--resume") {
        let mut cfg = fuzz::resume_config(std::path::Path::new(&dir))
            .with_context(|| format!("resuming fuzz campaign from {dir}"))?;
        cfg.abort_after = abort_after;
        println!(
            "resuming campaign from {} (seeds={}, base_seed={:#x})",
            dir, cfg.seeds, cfg.base_seed
        );
        return run_fuzz_and_report(&cfg);
    }
    let d = fuzz::FuzzConfig::default();
    let cfg = fuzz::FuzzConfig {
        seeds: arg_value(args, "--seeds").map(|v| v.parse()).transpose()?.unwrap_or(d.seeds),
        base_seed: arg_value(args, "--seed")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(d.base_seed),
        ranks: opts.ranks_or(d.ranks),
        mutants_per_model: arg_value(args, "--mutants")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(d.mutants_per_model),
        out_dir: arg_value(args, "--out").map(Into::into).unwrap_or(d.out_dir),
        write_files: true,
        flavor: arg_value(args, "--flavor")
            .map(|v| {
                fuzz::Flavor::parse(&v).ok_or_else(|| {
                    anyhow!(
                        "unknown flavor '{v}' (dp, sp, tp, pp, fsdp, moe, pp_sched_gpipe, \
                         pp_sched_1f1b, pp_sched_interleaved)"
                    )
                })
            })
            .transpose()?,
        resume: false,
        abort_after,
    };
    run_fuzz_and_report(&cfg)
}

fn run_fuzz_and_report(cfg: &fuzz::FuzzConfig) -> Result<i32> {
    let report = fuzz::run_fuzz(cfg)?;
    if report.aborted {
        println!(
            "fuzz campaign ABORTED by --abort-after with {} of {} seeds journaled in {}\n\
             resume with: graphguard fuzz --resume {}",
            report.models,
            cfg.seeds,
            cfg.out_dir.display(),
            cfg.out_dir.display()
        );
        return Ok(EXIT_ABORTED);
    }
    print!("{}", report.table());
    let json_path = "FUZZ_REPORT.json";
    std::fs::write(json_path, report.to_json().to_string_pretty())
        .with_context(|| format!("writing {json_path}"))?;
    println!("report written to {json_path}");
    if !report.sound() {
        eprintln!(
            "fuzz found {} counterexample(s): {} false alarms, {} cert failures, \
             {} clean-pair inconclusives, {} false proofs, {} localization misses, \
             {} oracle eval failures (see {})",
            report.counterexamples.len(),
            report.false_alarms,
            report.clean_cert_failures,
            report.clean_inconclusive,
            report.false_proofs(),
            report.locus_misses(),
            report.eval_failures(),
            cfg.out_dir.display()
        );
        return Ok(EXIT_REFUTED);
    }
    Ok(EXIT_OK)
}

/// ShardFlow static analysis, standalone: sweep the Table-2 workloads (or a
/// single replayable counterexample via `--fixture`) and report findings —
/// no e-graph saturation, no verdicts. Exit 0 when every graph is clean,
/// 1 when any finding fires; the JSON shape (sorted by node/code/detail)
/// is byte-stable for CI gates.
fn cmd_lint(args: &[String]) -> Result<i32> {
    use graphguard::util::json::Json;
    let opts = CommonOpts::parse(args)?;
    let as_json = args.iter().any(|a| a == "--json");
    let entries: Vec<(String, graphguard::analysis::LintReport)> =
        if let Some(path) = arg_value(args, "--fixture") {
            let text =
                std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            vec![fuzz::lint_counterexample(&j).with_context(|| format!("linting {path}"))?]
        } else {
            models::try_table2_workloads(opts.ranks_or(2))?
                .iter()
                .map(|w| (w.name.clone(), graphguard::analysis::analyze(&w.gd, Some(&w.ri))))
                .collect()
        };
    let total: usize = entries.iter().map(|(_, r)| r.findings.len()).sum();
    if as_json {
        let graphs: Vec<Json> = entries
            .iter()
            .map(|(name, r)| {
                Json::obj(vec![
                    ("graph", Json::str(name.clone())),
                    ("count", Json::num(r.findings.len() as f64)),
                    ("findings", Json::Arr(r.findings.iter().map(|f| f.to_json()).collect())),
                ])
            })
            .collect();
        println!(
            "{}",
            Json::obj(vec![
                ("total", Json::num(total as f64)),
                ("graphs", Json::Arr(graphs)),
            ])
            .to_string_pretty()
        );
    } else {
        for (name, r) in &entries {
            print!("{name}: {}", r.render());
        }
        println!("total: {total} finding(s) across {} graph(s)", entries.len());
    }
    Ok(if total == 0 { EXIT_OK } else { EXIT_REFUTED })
}

fn cmd_lemmas() -> Result<i32> {
    let lib = lemmas::metadata();
    println!("{} lemmas:", lib.len());
    println!("{:<36} {:>6} {:>11} {:>5}", "name", "group", "complexity", "loc");
    for m in &lib {
        println!("{:<36} {:>6} {:>11} {:>5}", m.name, m.group, m.complexity, m.loc);
    }
    Ok(EXIT_OK)
}

fn cmd_hlo(args: &[String]) -> Result<i32> {
    let path = arg_value(args, "--file").ok_or_else(|| anyhow!("--file required"))?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let g = hlo::parse_hlo_text(&text, &path)?;
    println!(
        "parsed '{}': {} inputs, {} nodes, {} outputs",
        path,
        g.inputs.len(),
        g.num_nodes(),
        g.outputs.len()
    );
    println!("{}", ir::json_io::to_json(&g).to_string_pretty());
    Ok(EXIT_OK)
}
