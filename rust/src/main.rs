//! GraphGuard CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   verify  --gs <graph.json> --gd <graph.json> --ri <relation.json>
//!   suite   [--ranks N] [--threads N]      run the Table-2 workload suite
//!   bugs                                    run the §6.2 case studies
//!   fuzz    [--seeds N] [--seed S] [--flavor F] ...  bug-injection fuzzer
//!   lemmas                                  list the lemma library
//!   hlo     --file <module.hlo.txt>         parse an HLO-text module
//!
//! (Hand-rolled argument parsing — no clap in the offline crate set.)

use anyhow::{anyhow, bail, Context, Result};
use graphguard::{bugs, coordinator, fuzz, hlo, infer, ir, lemmas, models, relation};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("verify") => cmd_verify(&args[1..]),
        Some("suite") => cmd_suite(&args[1..]),
        Some("bugs") => cmd_bugs(),
        Some("fuzz") => cmd_fuzz(&args[1..]),
        Some("lemmas") => cmd_lemmas(),
        Some("hlo") => cmd_hlo(&args[1..]),
        _ => {
            eprintln!(
                "usage: graphguard <verify|suite|bugs|fuzz|lemmas|hlo> [options]\n\
                 \n  verify --gs g_s.json --gd g_d.json --ri relation.json\
                 \n  suite  [--ranks N] [--threads N]\
                 \n  bugs\
                 \n  fuzz   [--seeds N] [--seed S] [--ranks R] [--mutants M] [--out DIR]\
                 \n         [--flavor F] [--replay ce.json]\
                 \n  lemmas\
                 \n  hlo --file module.hlo.txt"
            );
            Ok(())
        }
    }
}

fn load_graph(path: &str) -> Result<ir::Graph> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = graphguard::util::json::Json::parse(&text)
        .map_err(|e| anyhow!("{path}: {e}"))?;
    ir::json_io::from_json(&json).with_context(|| format!("building graph from {path}"))
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let gs = load_graph(&arg_value(args, "--gs").ok_or_else(|| anyhow!("--gs required"))?)?;
    let gd = load_graph(&arg_value(args, "--gd").ok_or_else(|| anyhow!("--gd required"))?)?;
    let ri_path = arg_value(args, "--ri").ok_or_else(|| anyhow!("--ri required"))?;
    let ri_text = std::fs::read_to_string(&ri_path)?;
    let ri_json = graphguard::util::json::Json::parse(&ri_text)
        .map_err(|e| anyhow!("{ri_path}: {e}"))?;
    let ri = relation::Relation::from_json(&ri_json, &gs, &gd)?;
    ri.validate_shapes(&gs, &gd)?;
    match infer::check_refinement(&gs, &gd, &ri, &infer::InferConfig::default()) {
        Ok(out) => {
            println!("refinement HOLDS — R_o:");
            println!("{}", out.relation.to_json(&gs, &gd).to_string_pretty());
            if arg_value(args, "--check-numeric").is_some()
                || args.iter().any(|a| a == "--check-numeric")
            {
                infer::verify_numeric(&gs, &gd, &ri, &out.relation, 7)?;
                println!("numeric certificate: OK");
            }
            Ok(())
        }
        Err(e) => {
            println!("{e}");
            bail!("model refinement does not hold")
        }
    }
}

fn cmd_suite(args: &[String]) -> Result<()> {
    let ranks: usize = arg_value(args, "--ranks").map(|v| v.parse()).transpose()?.unwrap_or(2);
    let threads: usize =
        arg_value(args, "--threads").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let coord = if threads > 0 {
        coordinator::Coordinator::new(threads, infer::InferConfig::default())
    } else {
        coordinator::Coordinator::default()
    };
    let results = coord.run_batch(models::table2_workloads(ranks));
    print!("{}", coordinator::report_table(&results));
    if results.iter().any(|r| !r.ok) {
        bail!("some workloads failed refinement");
    }
    Ok(())
}

fn cmd_bugs() -> Result<()> {
    println!("§6.2 case studies (buggy variants):\n");
    for case in bugs::all_cases(true) {
        let (detected, report) = case.run();
        println!("[bug {}] {} — {}", case.id, case.name, case.description);
        println!(
            "  expected: {}",
            match case.expected_locus {
                Some(l) => format!("detected near '{l}'"),
                None => "passes; inspect R_o / implementation trace".to_string(),
            }
        );
        println!("  outcome: {}", if detected { "DETECTED" } else { "refines" });
        for line in report.lines() {
            println!("    {line}");
        }
        println!();
    }
    Ok(())
}

fn cmd_fuzz(args: &[String]) -> Result<()> {
    if let Some(path) = arg_value(args, "--replay") {
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let j = graphguard::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        println!("{}", fuzz::replay_counterexample(&j)?);
        return Ok(());
    }
    let d = fuzz::FuzzConfig::default();
    let cfg = fuzz::FuzzConfig {
        seeds: arg_value(args, "--seeds").map(|v| v.parse()).transpose()?.unwrap_or(d.seeds),
        base_seed: arg_value(args, "--seed")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(d.base_seed),
        ranks: arg_value(args, "--ranks").map(|v| v.parse()).transpose()?.unwrap_or(d.ranks),
        mutants_per_model: arg_value(args, "--mutants")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(d.mutants_per_model),
        out_dir: arg_value(args, "--out").map(Into::into).unwrap_or(d.out_dir),
        write_files: true,
        flavor: arg_value(args, "--flavor")
            .map(|v| {
                fuzz::Flavor::parse(&v).ok_or_else(|| {
                    anyhow!(
                        "unknown flavor '{v}' (dp, sp, tp, pp, fsdp, moe, pp_sched_gpipe, \
                         pp_sched_1f1b, pp_sched_interleaved)"
                    )
                })
            })
            .transpose()?,
    };
    let report = fuzz::run_fuzz(&cfg)?;
    print!("{}", report.table());
    let json_path = "FUZZ_REPORT.json";
    std::fs::write(json_path, report.to_json().to_string_pretty())
        .with_context(|| format!("writing {json_path}"))?;
    println!("report written to {json_path}");
    if !report.sound() {
        bail!(
            "fuzz found {} counterexample(s): {} false alarms, {} cert failures, \
             {} false proofs, {} localization misses, {} oracle eval failures (see {})",
            report.counterexamples.len(),
            report.false_alarms,
            report.clean_cert_failures,
            report.false_proofs(),
            report.locus_misses(),
            report.eval_failures(),
            cfg.out_dir.display()
        );
    }
    Ok(())
}

fn cmd_lemmas() -> Result<()> {
    let lib = lemmas::metadata();
    println!("{} lemmas:", lib.len());
    println!("{:<36} {:>6} {:>11} {:>5}", "name", "group", "complexity", "loc");
    for m in &lib {
        println!("{:<36} {:>6} {:>11} {:>5}", m.name, m.group, m.complexity, m.loc);
    }
    Ok(())
}

fn cmd_hlo(args: &[String]) -> Result<()> {
    let path = arg_value(args, "--file").ok_or_else(|| anyhow!("--file required"))?;
    let text = std::fs::read_to_string(&path)?;
    let g = hlo::parse_hlo_text(&text, &path)?;
    println!(
        "parsed '{}': {} inputs, {} nodes, {} outputs",
        path,
        g.inputs.len(),
        g.num_nodes(),
        g.outputs.len()
    );
    println!("{}", ir::json_io::to_json(&g).to_string_pretty());
    Ok(())
}
