//! # GraphGuard
//!
//! Library reproduction of *"Verify Distributed Deep Learning Model
//! Implementation Refinement with Iterative Relation Inference"* (ByteDance
//! Seed / NYU, 2025).
//!
//! GraphGuard statically checks **model refinement**: given a sequential
//! model `G_s`, a distributed implementation `G_d`, and a clean input
//! relation `R_i : I(G_s) → I(G_d)`, it infers — by iterative, per-operator
//! equality-saturation rewriting — a complete *clean* output relation
//! `R_o : O(G_s) → O(G_d)`. Failure to find one indicates a distribution
//! bug, and the operator where inference stopped localizes it.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`ir`] — computation-graph IR (+ reverse-mode autodiff used to build
//!   backward graphs for the fwd+bwd workloads).
//! - [`expr`] — the relation expression language ρ, clean classifier,
//!   numeric evaluator.
//! - [`symbolic`] — linear-integer symbolic scalars (the SMT-LIB role).
//! - [`egraph`] — equality-saturation engine (the egg role).
//! - [`lemmas`] — the rewrite-lemma library (+ per-model custom-op lemmas).
//! - [`relation`] / [`infer`] — the paper's core algorithm (Listings 1–3).
//! - [`analysis`] — ShardFlow pre-saturation static analysis: distribution-
//!   lattice dataflow + channel-wiring/deadlock lints (diagnostics only;
//!   the e-graph stays the verdict oracle).
//! - [`baseline`] — monolithic whole-graph checker for scalability
//!   comparisons.
//! - [`strategies`] / [`models`] / [`bugs`] — workload generation: TP/SP/EP/
//!   VP/grad-accum graph builders and the six §6.2 bug injectors.
//! - [`schedule`] — pipeline execution schedules (GPipe / 1F1B / interleaved
//!   virtual stages): buffer-assignment lowering of logical send/recv
//!   channels with slot-liveness auditing.
//! - [`fuzz`] — bug-injection mutation fuzzer: random model + strategy
//!   composition, 23 mutation operators, differential soundness oracle.
//! - [`hlo`] — HLO-text frontend (XLA/JAX capture path).
//! - [`verifier`] — the unified [`Verifier`] builder every consumer goes
//!   through (CLI, serve loop, coordinator, fuzz oracle).
//! - [`serve`] — long-lived verification service: newline-delimited JSON
//!   requests over stdin/stdout or a Unix socket, shared warm cache.
//! - [`coordinator`] — multi-threaded verification service + reports.
//! - [`cache`] — certificate fingerprint cache: canonical region
//!   serialization + memoized saturation results for repeated layers.
//! - [`runtime`] — PJRT execution of AOT artifacts for cross-validation.
//! - [`bench`] — mini benchmark harness used by `cargo bench`.
//! - [`chaos`] — test-only fault-injection hooks (feature `chaos`).

pub mod analysis;
pub mod baseline;
pub mod bench;
pub mod bugs;
pub mod cache;
pub mod chaos;
pub mod coordinator;
pub mod egraph;
pub mod expr;
pub mod fuzz;
pub mod hlo;
pub mod infer;
pub mod ir;
pub mod lemmas;
pub mod models;
pub mod relation;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod strategies;
pub mod symbolic;
pub mod util;
pub mod verifier;

pub use verifier::Verifier;
