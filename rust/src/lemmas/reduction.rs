//! Reduction lemmas: reduce_sum / reduce_mean / reduce_max / softmax over
//! concatenated shards, plus the mean/scale identities that gradient
//! accumulation (§6.2 bug 6) hinges on.

use super::structural::try_add;
use super::Lemma;
use crate::egraph::{Id, POp, Pat, Rewrite};
use crate::ir::{FBits, Op, OpTag};

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // reduce_sum(concat(xs, d); d) = sum(reduce_sum(xi; d))
    v.push(Lemma::new(
        Rewrite::new(
            "reducesum_concat_same_dim",
            Pat::node(
                POp::Bind { tag: OpTag::ReduceSum, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let (rdim, keepdim) = match s.op(0) {
                    Some(Op::ReduceSum { dim, keepdim }) => (*dim, *keepdim),
                    _ => return vec![],
                };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                if rdim != cdim {
                    return vec![];
                }
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(Op::ReduceSum { dim: rdim, keepdim }, vec![p]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::SumN, parts)
            },
        ),
        "core",
        3,
        18,
    ));

    // reduce_{sum,mean,max}(concat(xs, d); d') with d' != d distributes as
    // a concat over the (possibly shifted) dim.
    for (name, tag) in [
        ("reducesum_concat_other_dim", OpTag::ReduceSum),
        ("reducemean_concat_other_dim", OpTag::ReduceMean),
        ("reducemax_concat_other_dim", OpTag::ReduceMax),
    ] {
        v.push(Lemma::new(
            Rewrite::new(
                name,
                Pat::node(
                    POp::Bind { tag, slot: 0 },
                    vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
                ),
                |eg, s, _| {
                    let Some(red) = s.op(0).cloned() else { return vec![] };
                    let (rdim, keepdim) = match &red {
                        Op::ReduceSum { dim, keepdim }
                        | Op::ReduceMean { dim, keepdim }
                        | Op::ReduceMax { dim, keepdim } => (*dim, *keepdim),
                        _ => return vec![],
                    };
                    let cdim = match s.op(1) {
                        Some(Op::Concat { dim }) => *dim,
                        _ => return vec![],
                    };
                    if rdim == cdim {
                        return vec![];
                    }
                    let Some(list0) = s.list(0) else { return vec![] };
                    let parts: Option<Vec<Id>> = list0
                        .iter()
                        .map(|&p| eg.add_op(red.clone(), vec![p]).ok())
                        .collect();
                    let Some(parts) = parts else { return vec![] };
                    let new_dim =
                        if !keepdim && rdim < cdim { cdim - 1 } else { cdim };
                    try_add(eg, Op::Concat { dim: new_dim }, parts)
                },
            ),
            "core",
            3,
            26,
        ));
    }

    // reduce_max(concat(xs, d); d) = pairwise maximum of the shard maxima
    v.push(Lemma::new(
        Rewrite::new(
            "reducemax_concat_same_dim",
            Pat::node(
                POp::Bind { tag: OpTag::ReduceMax, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let (rdim, keepdim) = match s.op(0) {
                    Some(Op::ReduceMax { dim, keepdim }) => (*dim, *keepdim),
                    _ => return vec![],
                };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                if rdim != cdim {
                    return vec![];
                }
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(Op::ReduceMax { dim: rdim, keepdim }, vec![p]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                let mut acc = parts[0];
                for &p in &parts[1..] {
                    match eg.add_op(Op::Maximum, vec![acc, p]) {
                        Ok(m) => acc = m,
                        Err(_) => return vec![],
                    }
                }
                vec![acc]
            },
        ),
        "core",
        4,
        27,
    ));

    // reduce_mean(concat(xs, d); d) = scale(sum(reduce_mean(xi; d)), 1/k)
    // for equal-size parts. The RHS contains a Scale — NOT clean — which is
    // precisely why an unscaled gradient-accumulation loss (bug 6) fails to
    // map cleanly while a correctly rescaled one succeeds.
    v.push(Lemma::new(
        Rewrite::new(
            "reducemean_concat_same_dim",
            Pat::node(
                POp::Bind { tag: OpTag::ReduceMean, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let (rdim, keepdim) = match s.op(0) {
                    Some(Op::ReduceMean { dim, keepdim }) => (*dim, *keepdim),
                    _ => return vec![],
                };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                if rdim != cdim {
                    return vec![];
                }
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let k = parts.len();
                let first = eg.shape(parts[0]).map(|v| v.to_vec());
                if parts.iter().any(|&p| eg.shape(p).map(|v| v.to_vec()) != first) {
                    return vec![];
                }
                let means: Option<Vec<Id>> = parts
                    .iter()
                    .map(|&p| eg.add_op(Op::ReduceMean { dim: rdim, keepdim }, vec![p]).ok())
                    .collect();
                let Some(means) = means else { return vec![] };
                let Ok(total) = eg.add_op(Op::SumN, means) else { return vec![] };
                try_add(eg, Op::Scale { c: FBits::new(1.0 / k as f64) }, vec![total])
            },
        ),
        "core",
        4,
        30,
    ));

    // mse_loss(concat(ps,0), concat(ts,0)) = scale(sum(mse(pi,ti)), 1/k)
    // equal microbatches — the gradient-accumulation loss lemma.
    v.push(Lemma::new(
        Rewrite::new(
            "mse_microbatch",
            Pat::node(
                POp::Exact(Op::MseLoss),
                vec![
                    Pat::bind_variadic(OpTag::Concat, 0, 0),
                    Pat::bind_variadic(OpTag::Concat, 1, 1),
                ],
            ),
            |eg, s, _| {
                let (d1, d2) = match (s.op(0), s.op(1)) {
                    (Some(Op::Concat { dim: a }), Some(Op::Concat { dim: b })) => (*a, *b),
                    _ => return vec![],
                };
                let (Some(preds), Some(tgts)) = (s.list(0), s.list(1)) else { return vec![] };
                if d1 != 0 || d2 != 0 || preds.len() != tgts.len() {
                    return vec![];
                }
                let (preds, tgts) = (preds.to_vec(), tgts.to_vec());
                let k = preds.len();
                let first = eg.shape(preds[0]).map(|v| v.to_vec());
                for &p in preds.iter().chain(&tgts) {
                    if eg.shape(p).map(|v| v.to_vec()) != first {
                        return vec![];
                    }
                }
                let losses: Option<Vec<Id>> = preds
                    .iter()
                    .zip(&tgts)
                    .map(|(&p, &t)| eg.add_op(Op::MseLoss, vec![p, t]).ok())
                    .collect();
                let Some(losses) = losses else { return vec![] };
                let Ok(total) = eg.add_op(Op::SumN, losses) else { return vec![] };
                try_add(eg, Op::Scale { c: FBits::new(1.0 / k as f64) }, vec![total])
            },
        ),
        "core",
        5,
        32,
    ));

    // softmax(concat(xs, d); d') = concat(softmax(xi; d'), d) for d != d' —
    // the sequence-parallel softmax (each row normalized independently).
    v.push(Lemma::new(
        Rewrite::new(
            "softmax_concat_other_dim",
            Pat::node(
                POp::Bind { tag: OpTag::Softmax, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let sdim = match s.op(0) {
                    Some(Op::Softmax { dim }) => *dim,
                    _ => return vec![],
                };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                if sdim == cdim {
                    return vec![];
                }
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(Op::Softmax { dim: sdim }, vec![p]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::Concat { dim: cdim }, parts)
            },
        ),
        "core",
        3,
        20,
    ));

    // linearity: reduce_{sum,mean}(sum(xs); d) = sum(reduce(xi; d))
    for (name, is_mean) in
        [("reducesum_over_sum", false), ("reducemean_over_sum", true)]
    {
        let tag = if is_mean { OpTag::ReduceMean } else { OpTag::ReduceSum };
        v.push(Lemma::new(
            Rewrite::new(
                name,
                Pat::node(
                    POp::Bind { tag, slot: 0 },
                    vec![Pat::bind_variadic(OpTag::SumN, 1, 0)],
                ),
                |eg, s, _| {
                    let (Some(red), Some(list0)) = (s.op(0).cloned(), s.list(0)) else {
                        return vec![];
                    };
                    let parts: Option<Vec<Id>> = list0
                        .iter()
                        .map(|&p| eg.add_op(red.clone(), vec![p]).ok())
                        .collect();
                    let Some(parts) = parts else { return vec![] };
                    try_add(eg, Op::SumN, parts)
                },
            ),
            "core",
            3,
            14,
        ));
    }

    // reduce over slice: reduce_sum(slice(x; d', a, b); d) commutes when
    // d != d' — lets reductions pass through sequence shards.
    v.push(Lemma::new(
        Rewrite::new(
            "reducesum_over_slice",
            Pat::node(
                POp::Bind { tag: OpTag::ReduceSum, slot: 0 },
                vec![Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)])],
            ),
            |eg, s, _| {
                let (rdim, keepdim) = match s.op(0) {
                    Some(Op::ReduceSum { dim, keepdim }) => (*dim, *keepdim),
                    _ => return vec![],
                };
                let (sdim, a, b) = match s.op(1) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                if rdim == sdim {
                    return vec![];
                }
                let Some(x) = s.var(0) else { return vec![] };
                let Ok(red) = eg.add_op(Op::ReduceSum { dim: rdim, keepdim }, vec![x]) else {
                    return vec![];
                };
                let new_sdim = if !keepdim && rdim < sdim { sdim - 1 } else { sdim };
                try_add(eg, Op::Slice { dim: new_sdim, start: a, end: b }, vec![red])
            },
        ),
        "core",
        3,
        24,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn reducesum_same_dim_becomes_shard_sum() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let red = eg.add_op(Op::ReduceSum { dim: 0, keepdim: false }, vec![cat]).unwrap();
        run(&mut eg);
        let ra = eg.lookup(&Op::ReduceSum { dim: 0, keepdim: false }, &[a]).unwrap();
        let rb = eg.lookup(&Op::ReduceSum { dim: 0, keepdim: false }, &[b]).unwrap();
        let sum = eg.lookup(&Op::SumN, &[ra, rb]).unwrap();
        assert!(eg.same(red, sum));
    }

    #[test]
    fn reducesum_other_dim_shifts_concat_dim() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let cat = eg.add_op(Op::Concat { dim: 1 }, vec![a, b]).unwrap();
        // reduce dim 0 (without keepdim) -> concat dim shifts 1 -> 0
        let red = eg.add_op(Op::ReduceSum { dim: 0, keepdim: false }, vec![cat]).unwrap();
        run(&mut eg);
        let ra = eg.lookup(&Op::ReduceSum { dim: 0, keepdim: false }, &[a]).unwrap();
        let rb = eg.lookup(&Op::ReduceSum { dim: 0, keepdim: false }, &[b]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[ra, rb]).unwrap();
        assert!(eg.same(red, expect));
    }

    #[test]
    fn mean_same_dim_needs_scale() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let mean = eg.add_op(Op::ReduceMean { dim: 0, keepdim: false }, vec![cat]).unwrap();
        run(&mut eg);
        let ma = eg.lookup(&Op::ReduceMean { dim: 0, keepdim: false }, &[a]).unwrap();
        let mb = eg.lookup(&Op::ReduceMean { dim: 0, keepdim: false }, &[b]).unwrap();
        let sum = eg.lookup(&Op::SumN, &[ma, mb]).unwrap();
        let scaled = eg.lookup(&Op::Scale { c: FBits::new(0.5) }, &[sum]).unwrap();
        assert!(eg.same(mean, scaled));
        // and crucially the UNSCALED sum is NOT equivalent
        assert!(!eg.same(mean, sum), "unscaled accumulation differs (bug 6)");
    }

    #[test]
    fn mse_microbatch_lemma() {
        let mut eg = EGraph::new();
        let p1 = eg.add_leaf(t(0), vec![2, 3]);
        let p2 = eg.add_leaf(t(1), vec![2, 3]);
        let t1 = eg.add_leaf(t(2), vec![2, 3]);
        let t2 = eg.add_leaf(t(3), vec![2, 3]);
        let cp = eg.add_op(Op::Concat { dim: 0 }, vec![p1, p2]).unwrap();
        let ct = eg.add_op(Op::Concat { dim: 0 }, vec![t1, t2]).unwrap();
        let loss = eg.add_op(Op::MseLoss, vec![cp, ct]).unwrap();
        run(&mut eg);
        let l1 = eg.lookup(&Op::MseLoss, &[p1, t1]).unwrap();
        let l2 = eg.lookup(&Op::MseLoss, &[p2, t2]).unwrap();
        let sum = eg.lookup(&Op::SumN, &[l1, l2]).unwrap();
        let scaled = eg.lookup(&Op::Scale { c: FBits::new(0.5) }, &[sum]).unwrap();
        assert!(eg.same(loss, scaled));
        assert!(!eg.same(loss, sum));
    }

    #[test]
    fn softmax_distributes_over_row_shards() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let sm = eg.add_op(Op::Softmax { dim: 1 }, vec![cat]).unwrap();
        run(&mut eg);
        let sa = eg.lookup(&Op::Softmax { dim: 1 }, &[a]).unwrap();
        let sb = eg.lookup(&Op::Softmax { dim: 1 }, &[b]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[sa, sb]).unwrap();
        assert!(eg.same(sm, expect));
    }
}
