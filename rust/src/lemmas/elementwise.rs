//! Elementwise-op lemmas: pointwise ops commute with the rearrangement ops
//! (concat / slice / transpose). These let per-rank pointwise computation in
//! `G_d` collapse into the sequential op applied to the gathered tensor.

use super::structural::try_add;
use super::Lemma;
use crate::egraph::{Id, POp, Pat, Rewrite};
use crate::ir::{Op, OpTag};

/// The named pure unary ops, each of which gets its own `<op>_over_concat`,
/// `<op>_over_slice` and `<op>_over_transpose` lemma — the paper counts
/// per-operator lemmas, and Fig 7's heatmap distinguishes them.
const UNARY_OPS: &[(&str, Op, [&str; 3])] = &[
    ("neg", Op::Neg, ["neg_over_concat", "neg_over_slice", "neg_over_transpose"]),
    ("exp", Op::Exp, ["exp_over_concat", "exp_over_slice", "exp_over_transpose"]),
    ("log", Op::Log, ["log_over_concat", "log_over_slice", "log_over_transpose"]),
    ("sqrt", Op::Sqrt, ["sqrt_over_concat", "sqrt_over_slice", "sqrt_over_transpose"]),
    ("rsqrt", Op::Rsqrt, ["rsqrt_over_concat", "rsqrt_over_slice", "rsqrt_over_transpose"]),
    ("square", Op::Square, ["square_over_concat", "square_over_slice", "square_over_transpose"]),
    ("tanh", Op::Tanh, ["tanh_over_concat", "tanh_over_slice", "tanh_over_transpose"]),
    ("gelu", Op::Gelu, ["gelu_over_concat", "gelu_over_slice", "gelu_over_transpose"]),
    ("silu", Op::Silu, ["silu_over_concat", "silu_over_slice", "silu_over_transpose"]),
    ("sigmoid", Op::Sigmoid, ["sigmoid_over_concat", "sigmoid_over_slice", "sigmoid_over_transpose"]),
    ("relu", Op::Relu, ["relu_over_concat", "relu_over_slice", "relu_over_transpose"]),
];

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // <op>(concat(xs, d)) = concat(<op>(x), d) — one lemma per unary op.
    for (_, op, names) in UNARY_OPS {
        let f = op.clone();
        v.push(Lemma::new(
            Rewrite::new(
                names[0],
                Pat::node(POp::Exact(op.clone()), vec![Pat::bind_variadic(OpTag::Concat, 1, 0)]),
                move |eg, s, _| {
                    let dim = match s.op(1) {
                        Some(Op::Concat { dim }) => *dim,
                        _ => return vec![],
                    };
                    let Some(list0) = s.list(0) else { return vec![] };
                    let parts: Option<Vec<Id>> = list0
                        .iter()
                        .map(|&p| eg.add_op(f.clone(), vec![p]).ok())
                        .collect();
                    let Some(parts) = parts else { return vec![] };
                    try_add(eg, Op::Concat { dim }, parts)
                },
            ),
            "core",
            3,
            15,
        ));
        // <op>(slice(x)) = slice(<op>(x))
        let f = op.clone();
        v.push(Lemma::new(
            Rewrite::new(
                names[1],
                Pat::node(
                    POp::Exact(op.clone()),
                    vec![Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)])],
                ),
                move |eg, s, _| {
                    let (Some(sl), Some(x)) = (s.op(1).cloned(), s.var(0)) else { return vec![] };
                    let Ok(fx) = eg.add_op(f.clone(), vec![x]) else { return vec![] };
                    try_add(eg, sl, vec![fx])
                },
            ),
            "core",
            3,
            12,
        ));
        // <op>(transpose(x, p)) = transpose(<op>(x), p)
        let f = op.clone();
        v.push(Lemma::new(
            Rewrite::new(
                names[2],
                Pat::node(
                    POp::Exact(op.clone()),
                    vec![Pat::bind(OpTag::Transpose, 1, vec![Pat::var(0)])],
                ),
                move |eg, s, _| {
                    let (Some(tp), Some(x)) = (s.op(1).cloned(), s.var(0)) else { return vec![] };
                    let Ok(fx) = eg.add_op(f.clone(), vec![x]) else { return vec![] };
                    try_add(eg, tp, vec![fx])
                },
            ),
            "core",
            3,
            12,
        ));
    }

    // scale/add_scalar (attr-carrying unary ops) use tag-binding patterns.
    v.push(Lemma::new(
        Rewrite::new(
            "scale_over_concat",
            Pat::node(
                POp::Bind { tag: OpTag::Scale, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let Some(f) = s.op(0).cloned() else { return vec![] };
                let dim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(f.clone(), vec![p]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::Concat { dim }, parts)
            },
        ),
        "core",
        3,
        15,
    ));
    v.push(Lemma::new(
        Rewrite::new(
            "scale_over_slice",
            Pat::node(
                POp::Bind { tag: OpTag::Scale, slot: 0 },
                vec![Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)])],
            ),
            |eg, s, _| {
                let (Some(f), Some(sl), Some(x)) = (s.op(0).cloned(), s.op(1).cloned(), s.var(0))
                else {
                    return vec![];
                };
                let Ok(fx) = eg.add_op(f, vec![x]) else { return vec![] };
                try_add(eg, sl, vec![fx])
            },
        ),
        "core",
        3,
        12,
    ));
    v.push(Lemma::new(
        Rewrite::new(
            "add_scalar_over_concat",
            Pat::node(
                POp::Bind { tag: OpTag::AddScalar, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let Some(f) = s.op(0).cloned() else { return vec![] };
                let dim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(f.clone(), vec![p]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::Concat { dim }, parts)
            },
        ),
        "core",
        3,
        15,
    ));

    // concat(f(x1), f(x2), ...) = f(concat(xs)) — the trigger in the other
    // direction: a concat whose parts all apply the same unary op.
    v.push(Lemma::new(
        Rewrite::new(
            "concat_of_unary",
            Pat::bind_variadic(OpTag::Concat, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                if parts.len() < 2 {
                    return vec![];
                }
                // all parts must expose the same unary elementwise op
                let mut common: Option<(Op, Vec<Id>)> = None;
                'outer: for cand in eg.class(parts[0]).nodes.clone() {
                    let crate::egraph::ELang::Op(op) = &cand.lang else { continue };
                    if !op.is_unary_elementwise() || matches!(op, Op::Identity) {
                        continue;
                    }
                    let mut inners = vec![cand.children[0]];
                    for &p in &parts[1..] {
                        let mut found = None;
                        for n in &eg.class(p).nodes {
                            if let crate::egraph::ELang::Op(o2) = &n.lang {
                                if o2 == op {
                                    found = Some(n.children[0]);
                                    break;
                                }
                            }
                        }
                        match found {
                            Some(inner) => inners.push(inner),
                            None => continue 'outer,
                        }
                    }
                    common = Some((op.clone(), inners));
                    break;
                }
                let Some((op, inners)) = common else { return vec![] };
                let Ok(cat) = eg.add_op(Op::Concat { dim }, inners) else { return vec![] };
                try_add(eg, op, vec![cat])
            },
        ),
        "core",
        3,
        34,
    ));

    // f(slice(x)) = slice(f(x)) for unary elementwise f
    v.push(Lemma::new(
        Rewrite::new(
            "unary_over_slice",
            Pat::node(
                POp::AnyUnaryEltwise { slot: 0 },
                vec![Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)])],
            ),
            |eg, s, _| {
                let (Some(f), Some(sl), Some(x)) = (s.op(0).cloned(), s.op(1).cloned(), s.var(0))
                else {
                    return vec![];
                };
                let Ok(fx) = eg.add_op(f, vec![x]) else { return vec![] };
                try_add(eg, sl, vec![fx])
            },
        ),
        "core",
        3,
        12,
    ));

    // slice(f(x)) = f(slice(x)) — reverse trigger
    v.push(Lemma::new(
        Rewrite::new(
            "slice_over_unary",
            Pat::node(
                POp::Bind { tag: OpTag::Slice, slot: 0 },
                vec![Pat::node(POp::AnyUnaryEltwise { slot: 1 }, vec![Pat::var(0)])],
            ),
            |eg, s, _| {
                let (Some(sl), Some(f), Some(x)) = (s.op(0).cloned(), s.op(1).cloned(), s.var(0))
                else {
                    return vec![];
                };
                let Ok(sx) = eg.add_op(sl, vec![x]) else { return vec![] };
                try_add(eg, f, vec![sx])
            },
        ),
        "core",
        3,
        12,
    ));

    // f(transpose(x, p)) = transpose(f(x), p)
    v.push(Lemma::new(
        Rewrite::new(
            "unary_over_transpose",
            Pat::node(
                POp::AnyUnaryEltwise { slot: 0 },
                vec![Pat::bind(OpTag::Transpose, 1, vec![Pat::var(0)])],
            ),
            |eg, s, _| {
                let (Some(f), Some(tp), Some(x)) = (s.op(0).cloned(), s.op(1).cloned(), s.var(0))
                else {
                    return vec![];
                };
                let Ok(fx) = eg.add_op(f, vec![x]) else { return vec![] };
                try_add(eg, tp, vec![fx])
            },
        ),
        "core",
        3,
        12,
    ));

    // g(concat(xs,d), concat(ys,d)) = concat(g(xi,yi), d) for binary
    // elementwise g, when the parts align shape-wise.
    v.push(Lemma::new(
        Rewrite::new(
            "binary_over_concat",
            Pat::node(
                POp::AnyBinaryEltwise { slot: 0 },
                vec![
                    Pat::bind_variadic(OpTag::Concat, 1, 0),
                    Pat::bind_variadic(OpTag::Concat, 2, 1),
                ],
            ),
            |eg, s, _| {
                let Some(g) = s.op(0).cloned() else { return vec![] };
                let (d1, d2) = match (s.op(1), s.op(2)) {
                    (Some(Op::Concat { dim: a }), Some(Op::Concat { dim: b })) => (*a, *b),
                    _ => return vec![],
                };
                let (Some(xs), Some(ys)) = (s.list(0), s.list(1)) else { return vec![] };
                if d1 != d2 || xs.len() != ys.len() {
                    return vec![];
                }
                let (xs, ys) = (xs.to_vec(), ys.to_vec());
                let pieces: Option<Vec<Id>> = xs
                    .iter()
                    .zip(&ys)
                    .map(|(&a, &b)| {
                        // pieces may broadcast against each other (e.g.
                        // [s,h] ⊙ [s,1] rms scaling), but must align on the
                        // concat dim and have equal rank so the zip is the
                        // same decomposition as the whole-tensor op
                        let (sa, sb) = (eg.shape(a)?, eg.shape(b)?);
                        if sa.len() != sb.len() || sa.get(d1) != sb.get(d1) {
                            return None;
                        }
                        eg.add_op(g.clone(), vec![a, b]).ok()
                    })
                    .collect();
                let Some(pieces) = pieces else { return vec![] };
                try_add(eg, Op::Concat { dim: d1 }, pieces)
            },
        ),
        "core",
        4,
        26,
    ));

    // g(concat(xs,d), w) = concat(g(xi,w), d) when w broadcasts and the
    // concat dim is not covered by w (e.g. norm weights [h] with seq concat).
    v.push(Lemma::new(
        Rewrite::new(
            "binary_bcast_over_concat",
            Pat::node(
                POp::AnyBinaryEltwise { slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0), Pat::var(0)],
            ),
            |eg, s, _| {
                let Some(g) = s.op(0).cloned() else { return vec![] };
                let dim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let (Some(w), Some(parts)) = (s.var(0), s.list(0).map(|l| l.to_vec())) else {
                    return vec![];
                };
                let (Some(wshape), Some(xshape)) =
                    (eg.shape(w).map(|v| v.to_vec()), eg.shape(parts[0]).map(|v| v.to_vec()))
                else {
                    return vec![];
                };
                // w must not span the concat dim: either lower rank that
                // doesn't reach it, or size-1 there.
                let offset = xshape.len().saturating_sub(wshape.len());
                let covered = dim >= offset && wshape.get(dim - offset).copied().unwrap_or(1) != 1;
                if covered {
                    return vec![];
                }
                let pieces: Option<Vec<Id>> = parts
                    .iter()
                    .map(|&p| eg.add_op(g.clone(), vec![p, w]).ok())
                    .collect();
                let Some(pieces) = pieces else { return vec![] };
                try_add(eg, Op::Concat { dim }, pieces)
            },
        ),
        "core",
        3,
        30,
    ));

    // same, broadcast operand on the left: g(w, concat(xs,d))
    v.push(Lemma::new(
        Rewrite::new(
            "binary_bcast_over_concat_left",
            Pat::node(
                POp::AnyBinaryEltwise { slot: 0 },
                vec![Pat::var(0), Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let Some(g) = s.op(0).cloned() else { return vec![] };
                let dim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let (Some(w), Some(parts)) = (s.var(0), s.list(0).map(|l| l.to_vec())) else {
                    return vec![];
                };
                let (Some(wshape), Some(xshape)) =
                    (eg.shape(w).map(|v| v.to_vec()), eg.shape(parts[0]).map(|v| v.to_vec()))
                else {
                    return vec![];
                };
                let offset = xshape.len().saturating_sub(wshape.len());
                let covered = dim >= offset && wshape.get(dim - offset).copied().unwrap_or(1) != 1;
                if covered {
                    return vec![];
                }
                let pieces: Option<Vec<Id>> = parts
                    .iter()
                    .map(|&p| eg.add_op(g.clone(), vec![w, p]).ok())
                    .collect();
                let Some(pieces) = pieces else { return vec![] };
                try_add(eg, Op::Concat { dim }, pieces)
            },
        ),
        "core",
        3,
        30,
    ));

    // g(slice(x,r), slice(y,r)) = slice(g(x,y), r) — same range both sides
    v.push(Lemma::new(
        Rewrite::new(
            "binary_over_slice",
            Pat::node(
                POp::AnyBinaryEltwise { slot: 0 },
                vec![
                    Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)]),
                    Pat::bind(OpTag::Slice, 2, vec![Pat::var(1)]),
                ],
            ),
            |eg, s, _| {
                let Some(g) = s.op(0).cloned() else { return vec![] };
                if s.op(1).is_none() || s.op(1) != s.op(2) {
                    return vec![];
                }
                let Some(sl) = s.op(1).cloned() else { return vec![] };
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                if eg.shape(x) != eg.shape(y) {
                    return vec![];
                }
                let Ok(gxy) = eg.add_op(g, vec![x, y]) else { return vec![] };
                try_add(eg, sl, vec![gxy])
            },
        ),
        "core",
        4,
        16,
    ));

    // mul/add commutativity
    v.push(Lemma::new(
        Rewrite::new(
            "mul_commut",
            Pat::exact(Op::Mul, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                try_add(eg, Op::Mul, vec![y, x])
            },
        ),
        "core",
        2,
        6,
    ));
    v.push(Lemma::new(
        Rewrite::new(
            "maximum_commut",
            Pat::exact(Op::Maximum, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                try_add(eg, Op::Maximum, vec![y, x])
            },
        ),
        "core",
        2,
        6,
    ));

    // scale(scale(x, a), b) = scale(x, a·b)
    v.push(Lemma::new(
        Rewrite::new(
            "scale_fuse",
            Pat::node(
                POp::Bind { tag: OpTag::Scale, slot: 0 },
                vec![Pat::node(POp::Bind { tag: OpTag::Scale, slot: 1 }, vec![Pat::var(0)])],
            ),
            |eg, s, _| {
                let (a, b) = match (s.op(0), s.op(1)) {
                    (Some(Op::Scale { c: a }), Some(Op::Scale { c: b })) => (a.get(), b.get()),
                    _ => return vec![],
                };
                let Some(x) = s.var(0) else { return vec![] };
                try_add(eg, Op::Scale { c: crate::ir::FBits::new(a * b) }, vec![x])
            },
        ),
        "core",
        2,
        11,
    ));

    // scale(x, 1.0) = x
    v.push(Lemma::new(
        Rewrite::new(
            "scale_one_identity",
            Pat::bind(OpTag::Scale, 0, vec![Pat::var(0)]),
            |_eg, s, _| match s.op(0) {
                Some(Op::Scale { c }) if c.get() == 1.0 => s.var(0).into_iter().collect(),
                _ => vec![],
            },
        ),
        "core",
        1,
        7,
    ));

    // neg(neg(x)) = x
    v.push(Lemma::new(
        Rewrite::new(
            "neg_involution",
            Pat::exact(Op::Neg, vec![Pat::exact(Op::Neg, vec![Pat::var(0)])]),
            |_eg, s, _| s.var(0).into_iter().collect(),
        ),
        "core",
        2,
        5,
    ));

    // sub(x, y) = sum(x, neg(y)) — lets subtraction participate in the
    // shard-combine algebra (matsub in the running example).
    v.push(Lemma::new(
        Rewrite::new(
            "sub_to_sum_neg",
            Pat::exact(Op::Sub, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                let Ok(ny) = eg.add_op(Op::Neg, vec![y]) else { return vec![] };
                try_add(eg, Op::SumN, vec![x, ny])
            },
        ),
        "core",
        3,
        8,
    ));

    // scale distributes over sum: scale(sum(xs), c) = sum(scale(xi, c))
    v.push(Lemma::new(
        Rewrite::new(
            "scale_over_sum",
            Pat::node(
                POp::Bind { tag: OpTag::Scale, slot: 0 },
                vec![Pat::bind_variadic(OpTag::SumN, 1, 0)],
            ),
            |eg, s, _| {
                let (Some(sc), Some(list0)) = (s.op(0).cloned(), s.list(0)) else {
                    return vec![];
                };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(sc.clone(), vec![p]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::SumN, parts)
            },
        ),
        "core",
        3,
        13,
    ));

    // scale(x, 0) = scale(y, 0) for same-shaped x, y — all zeros. Unions
    // the G_s and G_d gradient-seed zero nodes (autodiff builds the seed as
    // add_scalar(scale(loss, 0), 1)).
    v.push(Lemma::new(
        Rewrite::new(
            "scale_zero_eq",
            Pat::bind(OpTag::Scale, 0, vec![Pat::var(0)]),
            |eg, s, _| {
                match s.op(0) {
                    Some(Op::Scale { c }) if c.get() == 0.0 => {}
                    _ => return vec![],
                }
                let Some(x) = s.var(0) else { return vec![] };
                let shape = eg.shape(x).map(|v| v.to_vec());
                // union with every other scale-zero node of the same shape
                let mut out = Vec::new();
                for id in eg.class_ids() {
                    for node in &eg.class(id).nodes.clone() {
                        if let crate::egraph::ELang::Op(Op::Scale { c }) = &node.lang {
                            if c.get() == 0.0
                                && eg.shape(node.children[0]).map(|v| v.to_vec()) == shape
                            {
                                out.push(id);
                            }
                        }
                    }
                }
                out
            },
        ),
        "core",
        1,
        22,
    ));

    // ---- gradient-seed lemmas (backward graphs) ----
    // The autodiff seed is the literal ONE built as add_scalar(scale(t,0),1)
    // — its value is independent of t. Multiplying by it is the identity,
    // and multiplying by scale(ONE, c) is Scale{c}. These two lemmas are
    // what let backward graphs (HF gradient accumulation, ByteDance bwd)
    // relate across the loss-rescaling boundary.
    {
        fn is_seed_one(eg: &crate::egraph::EGraph, id: crate::egraph::Id) -> bool {
            for node in &eg.class(id).nodes {
                if let crate::egraph::ELang::Op(Op::AddScalar { c }) = &node.lang {
                    if c.get() == 1.0 {
                        let inner = node.children[0];
                        for n2 in &eg.class(inner).nodes {
                            if let crate::egraph::ELang::Op(Op::Scale { c }) = &n2.lang {
                                if c.get() == 0.0 {
                                    return true;
                                }
                            }
                        }
                    }
                }
            }
            false
        }
        v.push(Lemma::new(
            Rewrite::new(
                "mul_by_seed_one",
                Pat::exact(Op::Mul, vec![Pat::var(0), Pat::var(1)]),
                |eg, s, _| {
                    let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                    // seed is scalar-shaped; broadcast multiply by ONE = x
                    if is_seed_one(eg, y) && eg.shape(y).is_some_and(|sh| sh.is_empty()) {
                        vec![x]
                    } else if is_seed_one(eg, x) && eg.shape(x).is_some_and(|sh| sh.is_empty()) {
                        vec![y]
                    } else {
                        vec![]
                    }
                },
            ),
            "core",
            2,
            18,
        ));
        v.push(Lemma::new(
            Rewrite::new(
                "mul_by_scaled_seed",
                Pat::node(
                    POp::Exact(Op::Mul),
                    vec![
                        Pat::var(0),
                        Pat::node(POp::Bind { tag: OpTag::Scale, slot: 0 }, vec![Pat::var(1)]),
                    ],
                ),
                |eg, s, _| {
                    let (Some(sc), Some(x), Some(inner)) =
                        (s.op(0).cloned(), s.var(0), s.var(1))
                    else {
                        return vec![];
                    };
                    if is_seed_one(eg, inner) && eg.shape(inner).is_some_and(|sh| sh.is_empty()) {
                        try_add(eg, sc, vec![x])
                    } else {
                        vec![]
                    }
                },
            ),
            "core",
            3,
            20,
        ));
    }

    // mul distributes over sum (left): mul(sum(xs), y) = sum(mul(xi, y))
    v.push(Lemma::new(
        Rewrite::new(
            "mul_over_sum",
            Pat::node(
                POp::Exact(Op::Mul),
                vec![Pat::bind_variadic(OpTag::SumN, 0, 0), Pat::var(0)],
            ),
            |eg, s, _| {
                let (Some(y), Some(list0)) = (s.var(0), s.list(0)) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| eg.add_op(Op::Mul, vec![p, y]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::SumN, parts)
            },
        ),
        "core",
        3,
        13,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn gelu_over_concat() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let g = eg.add_op(Op::Gelu, vec![cat]).unwrap();
        run(&mut eg);
        let ga = eg.lookup(&Op::Gelu, &[a]).unwrap();
        let gb = eg.lookup(&Op::Gelu, &[b]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[ga, gb]).unwrap();
        assert!(eg.same(g, expect));
    }

    #[test]
    fn concat_of_unary_reverse_direction() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let ga = eg.add_op(Op::Silu, vec![a]).unwrap();
        let gb = eg.add_op(Op::Silu, vec![b]).unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![ga, gb]).unwrap();
        run(&mut eg);
        let inner = eg.lookup(&Op::Concat { dim: 0 }, &[a, b]).expect("inner concat built");
        let expect = eg.lookup(&Op::Silu, &[inner]).unwrap();
        assert!(eg.same(cat, expect));
    }

    #[test]
    fn weight_broadcast_over_seq_concat() {
        // mul(concat(x1,x2; dim=0), w[h]) = concat(mul(x1,w), mul(x2,w))
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 4]);
        let x2 = eg.add_leaf(t(1), vec![2, 4]);
        let w = eg.add_leaf(t(2), vec![4]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![x1, x2]).unwrap();
        let m = eg.add_op(Op::Mul, vec![cat, w]).unwrap();
        run(&mut eg);
        let m1 = eg.lookup(&Op::Mul, &[x1, w]).unwrap();
        let m2 = eg.lookup(&Op::Mul, &[x2, w]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[m1, m2]).unwrap();
        assert!(eg.same(m, expect));
    }

    #[test]
    fn weight_concat_dim_blocks_distribution() {
        // concat along the LAST dim with weight [h_total]: w spans the dim,
        // so the broadcast lemma must NOT fire.
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 2]);
        let x2 = eg.add_leaf(t(1), vec![2, 2]);
        let w = eg.add_leaf(t(2), vec![4]);
        let cat = eg.add_op(Op::Concat { dim: 1 }, vec![x1, x2]).unwrap();
        let m = eg.add_op(Op::Mul, vec![cat, w]).unwrap();
        run(&mut eg);
        // mul(x1, w) would be ill-shaped anyway; make sure m kept its class
        // without bogus equivalents of concat form
        assert!(eg.lookup(&Op::Mul, &[x1, w]).is_none());
        let _ = m;
    }

    #[test]
    fn sub_participates_in_sum_algebra() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let sub = eg.add_op(Op::Sub, vec![a, b]).unwrap();
        run(&mut eg);
        let nb = eg.lookup(&Op::Neg, &[b]).unwrap();
        let sum = eg.lookup(&Op::SumN, &[a, nb]).unwrap();
        assert!(eg.same(sub, sum));
    }

    #[test]
    fn scale_fusion() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![4]);
        let s1 = eg.add_op(Op::Scale { c: crate::ir::FBits::new(2.0) }, vec![x]).unwrap();
        let s2 = eg.add_op(Op::Scale { c: crate::ir::FBits::new(0.5) }, vec![s1]).unwrap();
        run(&mut eg);
        assert!(eg.same(s2, x), "scale(scale(x,2),0.5) = scale(x,1) = x");
    }
}
