//! User-provided lemmas for custom operators (paper §6.5).
//!
//! Our L1 Pallas kernels (`pallas_rms_norm`, `pallas_attention`) and the
//! vLLM-style fused op (`fused_silu_mul`) appear in captured graphs as
//! `Op::Custom`. Each needs lemmas tying it to its compositional semantics
//! so the standard library can reason through it. This module is the
//! reproduction of the "adding operators and lemmas" workflow whose effort
//! Figure 6 quantifies — the `loc` numbers below are the real line counts
//! of these definitions.

use super::structural::try_add;
use super::Lemma;
use crate::egraph::{Id, POp, Pat, Rewrite};
use crate::ir::{FBits, Op, OpTag};

fn custom(name: &str) -> Op {
    Op::Custom { name: name.to_string() }
}

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // pallas_rms_norm(x, w) = rms_norm(x, w; eps=1e-6)
    v.push(Lemma::new(
        Rewrite::new(
            "pallas_rmsnorm_semantics",
            Pat::exact(custom("pallas_rms_norm"), vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(x), Some(w)) = (s.var(0), s.var(1)) else { return vec![] };
                try_add(eg, Op::RmsNorm { eps: FBits::new(1e-6) }, vec![x, w])
            },
        ),
        "pallas",
        2,
        10,
    ));
    // ... and the reverse trigger so sequential rms_norm also reaches the
    // kernel form when eps matches.
    v.push(Lemma::new(
        Rewrite::new(
            "rmsnorm_to_pallas",
            Pat::bind(OpTag::RmsNorm, 0, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| match (s.op(0), s.var(0), s.var(1)) {
                (Some(Op::RmsNorm { eps }), Some(x), Some(w)) if eps.get() == 1e-6 => {
                    try_add(eg, custom("pallas_rms_norm"), vec![x, w])
                }
                _ => vec![],
            },
        ),
        "pallas",
        2,
        11,
    ));

    // pallas_attention(q, k, v) = matmul(softmax(scale(matmul(q, kᵀ))), v)
    v.push(Lemma::new(
        Rewrite::new(
            "pallas_attention_semantics",
            Pat::exact(
                custom("pallas_attention"),
                vec![Pat::var(0), Pat::var(1), Pat::var(2)],
            ),
            |eg, s, _| {
                let (Some(q), Some(k), Some(vv)) = (s.var(0), s.var(1), s.var(2)) else {
                    return vec![];
                };
                let Some(shape) = eg.shape(q).map(|v| v.to_vec()) else { return vec![] };
                let rank = shape.len();
                let d = shape[rank - 1] as f64;
                let mut perm: Vec<usize> = (0..rank).collect();
                perm.swap(rank - 1, rank - 2);
                let Ok(kt) = eg.add_op(Op::Transpose { perm }, vec![k]) else { return vec![] };
                let Ok(scores) = eg.add_op(Op::MatMul, vec![q, kt]) else { return vec![] };
                let Ok(scaled) =
                    eg.add_op(Op::Scale { c: FBits::new(1.0 / d.sqrt()) }, vec![scores])
                else {
                    return vec![];
                };
                let Some(srank) = eg.shape(scaled).map(|v| v.len()) else { return vec![] };
                let Ok(probs) = eg.add_op(Op::Softmax { dim: srank - 1 }, vec![scaled]) else {
                    return vec![];
                };
                try_add(eg, Op::MatMul, vec![probs, vv])
            },
        ),
        "pallas",
        5,
        27,
    ));

    // pallas_attention with head-split K/V (TP over heads happens on the
    // batch dim; handled by generic matmul lemmas once desugared).

    // fused_silu_mul(a, b) = mul(silu(a), b)
    v.push(Lemma::new(
        Rewrite::new(
            "fused_silu_mul_semantics",
            Pat::exact(custom("fused_silu_mul"), vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(a), Some(b)) = (s.var(0), s.var(1)) else { return vec![] };
                let Ok(si) = eg.add_op(Op::Silu, vec![a]) else { return vec![] };
                try_add(eg, Op::Mul, vec![si, b])
            },
        ),
        "v",
        3,
        9,
    ));
    // reverse trigger
    v.push(Lemma::new(
        Rewrite::new(
            "silu_mul_to_fused",
            Pat::exact(
                Op::Mul,
                vec![Pat::exact(Op::Silu, vec![Pat::var(0)]), Pat::var(1)],
            ),
            |eg, s, _| {
                let (Some(a), Some(b)) = (s.var(0), s.var(1)) else { return vec![] };
                try_add(eg, custom("fused_silu_mul"), vec![a, b])
            },
        ),
        "v",
        3,
        8,
    ));

    // fused_silu_mul distributes over aligned concats (vLLM TP pattern):
    // fused(concat(as,d), concat(bs,d)) = concat(fused(ai,bi), d)
    v.push(Lemma::new(
        Rewrite::new(
            "fused_silu_mul_over_concat",
            Pat::node(
                POp::Exact(custom("fused_silu_mul")),
                vec![
                    Pat::bind_variadic(OpTag::Concat, 0, 0),
                    Pat::bind_variadic(OpTag::Concat, 1, 1),
                ],
            ),
            |eg, s, _| {
                let (d1, d2) = match (s.op(0), s.op(1)) {
                    (Some(Op::Concat { dim: a }), Some(Op::Concat { dim: b })) => (*a, *b),
                    _ => return vec![],
                };
                let (Some(xs), Some(ys)) = (s.list(0), s.list(1)) else { return vec![] };
                if d1 != d2 || xs.len() != ys.len() {
                    return vec![];
                }
                let (xs, ys) = (xs.to_vec(), ys.to_vec());
                let parts: Option<Vec<Id>> = xs
                    .iter()
                    .zip(&ys)
                    .map(|(&a, &b)| {
                        if eg.shape(a) != eg.shape(b) {
                            return None;
                        }
                        eg.add_op(custom("fused_silu_mul"), vec![a, b]).ok()
                    })
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::Concat { dim: d1 }, parts)
            },
        ),
        "v",
        4,
        24,
    ));

    // HLO-frontend lemmas ("h" group): HLO spells some ATen ops differently;
    // the frontend maps most directly, but two composite forms need lemmas.
    // hlo_dot_general with batched lhs = matmul (frontend emits custom for
    // exotic dimension_numbers; the common case maps to MatMul directly).
    v.push(Lemma::new(
        Rewrite::new(
            "hlo_dot_is_matmul",
            Pat::exact(custom("hlo_dot"), vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(a), Some(b)) = (s.var(0), s.var(1)) else { return vec![] };
                try_add(eg, Op::MatMul, vec![a, b])
            },
        ),
        "h",
        2,
        7,
    ));
    // hlo_dynamic_slice with static bounds = slice (dim 0 convention from
    // our frontend lowering).
    v.push(Lemma::new(
        Rewrite::new(
            "hlo_dynamic_slice_is_slice",
            Pat::bind(OpTag::Custom, 0, vec![Pat::var(0)]),
            |_eg, _s, _| vec![], // placeholder trigger; the frontend lowers
                                  // static dynamic-slices to Op::Slice before
                                  // inference, so this never needs to fire.
        ),
        "h",
        2,
        6,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn pallas_rmsnorm_bridges_to_builtin() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 8]);
        let w = eg.add_leaf(t(1), vec![8]);
        let kernel = eg.add_op(custom("pallas_rms_norm"), vec![x, w]).unwrap();
        let builtin = eg.add_op(Op::RmsNorm { eps: FBits::new(1e-6) }, vec![x, w]).unwrap();
        run(&mut eg);
        assert!(eg.same(kernel, builtin));
    }

    #[test]
    fn pallas_attention_decomposes() {
        let mut eg = EGraph::new();
        let q = eg.add_leaf(t(0), vec![4, 8]);
        let k = eg.add_leaf(t(1), vec![4, 8]);
        let vv = eg.add_leaf(t(2), vec![4, 8]);
        let att = eg.add_op(custom("pallas_attention"), vec![q, k, vv]).unwrap();
        run(&mut eg);
        // the composition must now be in the same class
        let kt = eg.lookup(&Op::Transpose { perm: vec![1, 0] }, &[k]).unwrap();
        let scores = eg.lookup(&Op::MatMul, &[q, kt]).unwrap();
        let scaled = eg
            .lookup(&Op::Scale { c: FBits::new(1.0 / (8f64).sqrt()) }, &[scores])
            .unwrap();
        let probs = eg.lookup(&Op::Softmax { dim: 1 }, &[scaled]).unwrap();
        let out = eg.lookup(&Op::MatMul, &[probs, vv]).unwrap();
        assert!(eg.same(att, out));
    }

    #[test]
    fn fused_silu_mul_bridges_and_distributes() {
        let mut eg = EGraph::new();
        let a1 = eg.add_leaf(t(0), vec![2, 4]);
        let a2 = eg.add_leaf(t(1), vec![2, 4]);
        let b1 = eg.add_leaf(t(2), vec![2, 4]);
        let b2 = eg.add_leaf(t(3), vec![2, 4]);
        let ca = eg.add_op(Op::Concat { dim: 1 }, vec![a1, a2]).unwrap();
        let cb = eg.add_op(Op::Concat { dim: 1 }, vec![b1, b2]).unwrap();
        let fused = eg.add_op(custom("fused_silu_mul"), vec![ca, cb]).unwrap();
        run(&mut eg);
        let f1 = eg.lookup(&custom("fused_silu_mul"), &[a1, b1]).unwrap();
        let f2 = eg.lookup(&custom("fused_silu_mul"), &[a2, b2]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 1 }, &[f1, f2]).unwrap();
        assert!(eg.same(fused, expect));
    }
}
