//! Matmul lemmas — the block-matrix algebra at the heart of tensor
//! parallelism (and of the paper's running example, Fig 2):
//!
//! * inner-dim split:  `A·B = Σᵢ Aᵢ·Bᵢ`   (column-parallel × row-parallel)
//! * row split:        `[A₁;A₂]·B = [A₁·B; A₂·B]`   (sequence parallelism)
//! * column split:     `A·[B₁|B₂] = [A·B₁ | A·B₂]`  (column parallelism)
//! plus linearity (`·` distributes over shard sums) and scale/transpose
//! commutation. All are rank-generic: the split dims are computed from the
//! operand ranks so batched matmuls (attention) are covered.

use super::structural::try_add;
use super::Lemma;
use crate::egraph::{EGraph, Id, POp, Pat, Rewrite};
use crate::ir::{Op, OpTag};

fn rank(eg: &EGraph, id: Id) -> Option<usize> {
    eg.shape(id).map(|s| s.len())
}

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // matmul(concat(As, k-dim), concat(Bs, k-row-dim)) = sum(matmul(Ai, Bi))
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_block_inner",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![
                    Pat::bind_variadic(OpTag::Concat, 0, 0),
                    Pat::bind_variadic(OpTag::Concat, 1, 1),
                ],
            ),
            |eg, s, _| {
                let (da, db) = match (s.op(0), s.op(1)) {
                    (Some(Op::Concat { dim: a }), Some(Op::Concat { dim: b })) => (*a, *b),
                    _ => return vec![],
                };
                let (Some(a_parts), Some(b_parts)) = (
                    s.list(0).map(|l| l.to_vec()),
                    s.list(1).map(|l| l.to_vec()),
                ) else {
                    return vec![];
                };
                if a_parts.len() != b_parts.len() {
                    return vec![];
                }
                let (Some(ra), Some(rb)) = (rank(eg, a_parts[0]), rank(eg, b_parts[0])) else {
                    return vec![];
                };
                // inner dim of A = last; row dim of B = second-to-last
                if da != ra - 1 || db != rb - 2 {
                    return vec![];
                }
                // split sizes must match pairwise
                for (&a, &b) in a_parts.iter().zip(&b_parts) {
                    let (Some(sa), Some(sb)) = (eg.shape(a), eg.shape(b)) else { return vec![] };
                    if sa[ra - 1] != sb[rb - 2] {
                        return vec![];
                    }
                }
                let prods: Option<Vec<Id>> = a_parts
                    .iter()
                    .zip(&b_parts)
                    .map(|(&a, &b)| eg.add_op(Op::MatMul, vec![a, b]).ok())
                    .collect();
                let Some(prods) = prods else { return vec![] };
                try_add(eg, Op::SumN, prods)
            },
        ),
        "core",
        4,
        32,
    ));

    // matmul(concat(As, row-dim), B) = concat(matmul(Ai, B), row-dim)
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_block_rows",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![Pat::bind_variadic(OpTag::Concat, 0, 0), Pat::var(0)],
            ),
            |eg, s, _| {
                let da = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let (Some(a_parts), Some(b)) = (s.list(0).map(|l| l.to_vec()), s.var(0)) else {
                    return vec![];
                };
                let Some(ra) = rank(eg, a_parts[0]) else { return vec![] };
                if da != ra - 2 {
                    return vec![];
                }
                let prods: Option<Vec<Id>> = a_parts
                    .iter()
                    .map(|&a| eg.add_op(Op::MatMul, vec![a, b]).ok())
                    .collect();
                let Some(prods) = prods else { return vec![] };
                // output row dim = out_rank - 2
                let Some(ro) = rank(eg, prods[0]) else { return vec![] };
                try_add(eg, Op::Concat { dim: ro - 2 }, prods)
            },
        ),
        "core",
        3,
        24,
    ));

    // matmul(A, concat(Bs, col-dim)) = concat(matmul(A, Bi), col-dim)
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_block_cols",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![Pat::var(0), Pat::bind_variadic(OpTag::Concat, 0, 0)],
            ),
            |eg, s, _| {
                let db = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let (Some(b_parts), Some(a)) = (s.list(0).map(|l| l.to_vec()), s.var(0)) else {
                    return vec![];
                };
                let Some(rb) = rank(eg, b_parts[0]) else { return vec![] };
                if db != rb - 1 {
                    return vec![];
                }
                let prods: Option<Vec<Id>> = b_parts
                    .iter()
                    .map(|&b| eg.add_op(Op::MatMul, vec![a, b]).ok())
                    .collect();
                let Some(prods) = prods else { return vec![] };
                let Some(ro) = rank(eg, prods[0]) else { return vec![] };
                try_add(eg, Op::Concat { dim: ro - 1 }, prods)
            },
        ),
        "core",
        3,
        24,
    ));

    // concat(matmul(A1,B), matmul(A2,B), ...; row-dim) = matmul(concat(As), B)
    // — reverse trigger of matmul_block_rows: per-rank products already in
    // G_d get recombined into the sequential matmul.
    v.push(Lemma::new(
        Rewrite::new(
            "concat_of_matmuls_rows",
            Pat::bind_variadic(OpTag::Concat, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                if parts.len() < 2 {
                    return vec![];
                }
                let Some(ro) = rank(eg, parts[0]) else { return vec![] };
                if dim != ro.saturating_sub(2) {
                    return vec![];
                }
                // all parts matmul with the same B?
                let mut a_list = Vec::new();
                let mut b_common: Option<Id> = None;
                for &p in &parts {
                    let mut found = None;
                    for n in &eg.class(p).nodes {
                        if let crate::egraph::ELang::Op(Op::MatMul) = &n.lang {
                            let (a, b) = (n.children[0], n.children[1]);
                            match b_common {
                                None => {
                                    b_common = Some(eg.find(b));
                                    found = Some(a);
                                    break;
                                }
                                Some(bc) if eg.find(b) == bc => {
                                    found = Some(a);
                                    break;
                                }
                                _ => {}
                            }
                        }
                    }
                    match found {
                        Some(a) => a_list.push(a),
                        None => return vec![],
                    }
                }
                let Some(b) = b_common else { return vec![] };
                let Some(ra) = rank(eg, a_list[0]) else { return vec![] };
                let Ok(cat) = eg.add_op(Op::Concat { dim: ra - 2 }, a_list) else {
                    return vec![];
                };
                try_add(eg, Op::MatMul, vec![cat, b])
            },
        ),
        "core",
        4,
        40,
    ));

    // matmul(sum(As), B) = sum(matmul(Ai, B))  (left linearity)
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_sum_left",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![Pat::bind_variadic(OpTag::SumN, 0, 0), Pat::var(0)],
            ),
            |eg, s, _| {
                let (Some(b), Some(list0)) = (s.var(0), s.list(0)) else { return vec![] };
                let prods: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&a| eg.add_op(Op::MatMul, vec![a, b]).ok())
                    .collect();
                let Some(prods) = prods else { return vec![] };
                try_add(eg, Op::SumN, prods)
            },
        ),
        "core",
        3,
        14,
    ));

    // matmul(A, sum(Bs)) = sum(matmul(A, Bi))  (right linearity)
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_sum_right",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![Pat::var(0), Pat::bind_variadic(OpTag::SumN, 0, 0)],
            ),
            |eg, s, _| {
                let (Some(a), Some(list0)) = (s.var(0), s.list(0)) else { return vec![] };
                let prods: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&b| eg.add_op(Op::MatMul, vec![a, b]).ok())
                    .collect();
                let Some(prods) = prods else { return vec![] };
                try_add(eg, Op::SumN, prods)
            },
        ),
        "core",
        3,
        14,
    ));

    // sum(matmul(A1,B1), matmul(A2,B2), ...) = matmul(concat(As,k),
    // concat(Bs,k-row)) — reverse trigger of matmul_block_inner.
    v.push(Lemma::new(
        Rewrite::new(
            "sum_of_matmuls_inner",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |eg, s, _| {
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                if parts.len() < 2 {
                    return vec![];
                }
                let mut a_list = Vec::new();
                let mut b_list = Vec::new();
                for &p in &parts {
                    let mut found = None;
                    for n in &eg.class(p).nodes {
                        if let crate::egraph::ELang::Op(Op::MatMul) = &n.lang {
                            found = Some((n.children[0], n.children[1]));
                            break;
                        }
                    }
                    match found {
                        Some((a, b)) => {
                            a_list.push(a);
                            b_list.push(b);
                        }
                        None => return vec![],
                    }
                }
                let (Some(ra), Some(rb)) = (rank(eg, a_list[0]), rank(eg, b_list[0])) else {
                    return vec![];
                };
                let Ok(ca) = eg.add_op(Op::Concat { dim: ra - 1 }, a_list) else { return vec![] };
                let Ok(cb) = eg.add_op(Op::Concat { dim: rb - 2 }, b_list) else { return vec![] };
                try_add(eg, Op::MatMul, vec![ca, cb])
            },
        ),
        "core",
        4,
        33,
    ));

    // slice(matmul(A,B); row-dim, a, b) = matmul(slice(A; row-dim, a, b), B)
    v.push(Lemma::new(
        Rewrite::new(
            "slice_of_matmul_rows",
            Pat::node(
                POp::Bind { tag: OpTag::Slice, slot: 0 },
                vec![Pat::exact(Op::MatMul, vec![Pat::var(0), Pat::var(1)])],
            ),
            |eg, s, _| {
                let (dim, a, b) = match s.op(0) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                let Some(rx) = rank(eg, x) else { return vec![] };
                let Some(ro) = rank(eg, y).map(|ry| rx.max(ry)) else { return vec![] };
                if dim != ro - 2 {
                    return vec![];
                }
                let Ok(sx) = eg.add_op(Op::Slice { dim: rx - 2, start: a, end: b }, vec![x]) else {
                    return vec![];
                };
                try_add(eg, Op::MatMul, vec![sx, y])
            },
        ),
        "core",
        3,
        20,
    ));

    // slice(matmul(A,B); col-dim, a, b) = matmul(A, slice(B; col-dim, a, b))
    v.push(Lemma::new(
        Rewrite::new(
            "slice_of_matmul_cols",
            Pat::node(
                POp::Bind { tag: OpTag::Slice, slot: 0 },
                vec![Pat::exact(Op::MatMul, vec![Pat::var(0), Pat::var(1)])],
            ),
            |eg, s, _| {
                let (dim, a, b) = match s.op(0) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                let Some(ry) = rank(eg, y) else { return vec![] };
                let Some(ro) = rank(eg, x).map(|rx| rx.max(ry)) else { return vec![] };
                if dim != ro - 1 {
                    return vec![];
                }
                let Ok(sy) = eg.add_op(Op::Slice { dim: ry - 1, start: a, end: b }, vec![y]) else {
                    return vec![];
                };
                try_add(eg, Op::MatMul, vec![x, sy])
            },
        ),
        "core",
        3,
        20,
    ));

    // matmul(scale(A,c), B) = scale(matmul(A,B), c) (and right operand)
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_scale_left",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![
                    Pat::node(POp::Bind { tag: OpTag::Scale, slot: 0 }, vec![Pat::var(0)]),
                    Pat::var(1),
                ],
            ),
            |eg, s, _| {
                let (Some(sc), Some(x), Some(y)) = (s.op(0).cloned(), s.var(0), s.var(1))
                else {
                    return vec![];
                };
                let Ok(mm) = eg.add_op(Op::MatMul, vec![x, y]) else {
                    return vec![];
                };
                try_add(eg, sc, vec![mm])
            },
        ),
        "core",
        3,
        13,
    ));
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_scale_right",
            Pat::node(
                POp::Exact(Op::MatMul),
                vec![
                    Pat::var(0),
                    Pat::node(POp::Bind { tag: OpTag::Scale, slot: 0 }, vec![Pat::var(1)]),
                ],
            ),
            |eg, s, _| {
                let (Some(sc), Some(x), Some(y)) = (s.op(0).cloned(), s.var(0), s.var(1))
                else {
                    return vec![];
                };
                let Ok(mm) = eg.add_op(Op::MatMul, vec![x, y]) else {
                    return vec![];
                };
                try_add(eg, sc, vec![mm])
            },
        ),
        "core",
        3,
        13,
    ));
    // scale(matmul(A,B), c) = matmul(scale(A,c), B) — reverse trigger
    v.push(Lemma::new(
        Rewrite::new(
            "scale_of_matmul",
            Pat::node(
                POp::Bind { tag: OpTag::Scale, slot: 0 },
                vec![Pat::exact(Op::MatMul, vec![Pat::var(0), Pat::var(1)])],
            ),
            |eg, s, _| {
                let (Some(sc), Some(x), Some(y)) = (s.op(0).cloned(), s.var(0), s.var(1))
                else {
                    return vec![];
                };
                let Ok(sa) = eg.add_op(sc, vec![x]) else { return vec![] };
                try_add(eg, Op::MatMul, vec![sa, y])
            },
        ),
        "core",
        3,
        13,
    ));

    // transpose(matmul(A,B)) = matmul(transpose(B), transpose(A)) (last-2)
    v.push(Lemma::new(
        Rewrite::new(
            "matmul_transpose",
            Pat::node(
                POp::Bind { tag: OpTag::Transpose, slot: 0 },
                vec![Pat::exact(Op::MatMul, vec![Pat::var(0), Pat::var(1)])],
            ),
            |eg, s, _| {
                let perm = match s.op(0) {
                    Some(Op::Transpose { perm }) => perm.clone(),
                    _ => return vec![],
                };
                // only the swap-last-two permutation
                let n = perm.len();
                if n < 2 {
                    return vec![];
                }
                let mut want: Vec<usize> = (0..n).collect();
                want.swap(n - 1, n - 2);
                if perm != want {
                    return vec![];
                }
                let (Some(a), Some(b)) = (s.var(0), s.var(1)) else { return vec![] };
                let (Some(ra), Some(rb)) = (rank(eg, a), rank(eg, b)) else { return vec![] };
                let mut pa: Vec<usize> = (0..ra).collect();
                pa.swap(ra - 1, ra - 2);
                let mut pb: Vec<usize> = (0..rb).collect();
                pb.swap(rb - 1, rb - 2);
                let Ok(tb) = eg.add_op(Op::Transpose { perm: pb }, vec![b]) else {
                    return vec![];
                };
                let Ok(ta) = eg.add_op(Op::Transpose { perm: pa }, vec![a]) else {
                    return vec![];
                };
                try_add(eg, Op::MatMul, vec![tb, ta])
            },
        ),
        "core",
        4,
        27,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn running_example_block_inner() {
        // matmul(concat(A1,A2; dim=1), concat(B1,B2; dim=0)) = sum(M1, M2)
        let mut eg = EGraph::new();
        let a1 = eg.add_leaf(t(0), vec![4, 3]);
        let a2 = eg.add_leaf(t(1), vec![4, 3]);
        let b1 = eg.add_leaf(t(2), vec![3, 5]);
        let b2 = eg.add_leaf(t(3), vec![3, 5]);
        let ca = eg.add_op(Op::Concat { dim: 1 }, vec![a1, a2]).unwrap();
        let cb = eg.add_op(Op::Concat { dim: 0 }, vec![b1, b2]).unwrap();
        let mm = eg.add_op(Op::MatMul, vec![ca, cb]).unwrap();
        run(&mut eg);
        let m1 = eg.lookup(&Op::MatMul, &[a1, b1]).unwrap();
        let m2 = eg.lookup(&Op::MatMul, &[a2, b2]).unwrap();
        let sum = eg.lookup(&Op::SumN, &[m1, m2]).unwrap();
        assert!(eg.same(mm, sum), "block matmul lemma (Fig 2)");
    }

    #[test]
    fn row_split_concat() {
        let mut eg = EGraph::new();
        let a1 = eg.add_leaf(t(0), vec![2, 3]);
        let a2 = eg.add_leaf(t(1), vec![2, 3]);
        let b = eg.add_leaf(t(2), vec![3, 5]);
        let ca = eg.add_op(Op::Concat { dim: 0 }, vec![a1, a2]).unwrap();
        let mm = eg.add_op(Op::MatMul, vec![ca, b]).unwrap();
        run(&mut eg);
        let m1 = eg.lookup(&Op::MatMul, &[a1, b]).unwrap();
        let m2 = eg.lookup(&Op::MatMul, &[a2, b]).unwrap();
        let cat = eg.lookup(&Op::Concat { dim: 0 }, &[m1, m2]).unwrap();
        assert!(eg.same(mm, cat));
    }

    #[test]
    fn col_split_concat() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 3]);
        let b1 = eg.add_leaf(t(1), vec![3, 2]);
        let b2 = eg.add_leaf(t(2), vec![3, 2]);
        let cb = eg.add_op(Op::Concat { dim: 1 }, vec![b1, b2]).unwrap();
        let mm = eg.add_op(Op::MatMul, vec![a, cb]).unwrap();
        run(&mut eg);
        let m1 = eg.lookup(&Op::MatMul, &[a, b1]).unwrap();
        let m2 = eg.lookup(&Op::MatMul, &[a, b2]).unwrap();
        let cat = eg.lookup(&Op::Concat { dim: 1 }, &[m1, m2]).unwrap();
        assert!(eg.same(mm, cat));
    }

    #[test]
    fn batched_row_split() {
        // rank-3: concat along dim 1 (= row dim of rank-3 matmul)
        let mut eg = EGraph::new();
        let a1 = eg.add_leaf(t(0), vec![2, 3, 4]);
        let a2 = eg.add_leaf(t(1), vec![2, 3, 4]);
        let b = eg.add_leaf(t(2), vec![2, 4, 5]);
        let ca = eg.add_op(Op::Concat { dim: 1 }, vec![a1, a2]).unwrap();
        let mm = eg.add_op(Op::MatMul, vec![ca, b]).unwrap();
        run(&mut eg);
        let m1 = eg.lookup(&Op::MatMul, &[a1, b]).unwrap();
        let m2 = eg.lookup(&Op::MatMul, &[a2, b]).unwrap();
        let cat = eg.lookup(&Op::Concat { dim: 1 }, &[m1, m2]).unwrap();
        assert!(eg.same(mm, cat));
    }

    #[test]
    fn mismatched_inner_split_does_not_fire() {
        // A split [4,3]+[4,3] but B split [2,5]+[4,5]: pairwise inner dims
        // disagree (3 vs 2) — the bug-4 situation. No sum form may appear.
        let mut eg = EGraph::new();
        let a1 = eg.add_leaf(t(0), vec![4, 3]);
        let a2 = eg.add_leaf(t(1), vec![4, 3]);
        let b1 = eg.add_leaf(t(2), vec![2, 5]);
        let b2 = eg.add_leaf(t(3), vec![4, 5]);
        let ca = eg.add_op(Op::Concat { dim: 1 }, vec![a1, a2]).unwrap();
        let cb = eg.add_op(Op::Concat { dim: 0 }, vec![b1, b2]).unwrap();
        let mm = eg.add_op(Op::MatMul, vec![ca, cb]).unwrap();
        run(&mut eg);
        assert!(eg.lookup(&Op::MatMul, &[a1, b1]).is_none());
        let _ = mm;
    }

    #[test]
    fn scale_commutes_through_matmul() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 3]);
        let b = eg.add_leaf(t(1), vec![3, 2]);
        let sa = eg.add_op(Op::Scale { c: crate::ir::FBits::new(0.5) }, vec![a]).unwrap();
        let mm = eg.add_op(Op::MatMul, vec![sa, b]).unwrap();
        run(&mut eg);
        let plain = eg.lookup(&Op::MatMul, &[a, b]).unwrap();
        let scaled = eg.lookup(&Op::Scale { c: crate::ir::FBits::new(0.5) }, &[plain]).unwrap();
        assert!(eg.same(mm, scaled));
    }
}
