//! Collective-op lemmas: desugar single-program collectives into their
//! structural semantics (all-gather = concat, all-reduce = shard-sum,
//! reduce-scatter = slice-of-sum). These give `G_d`'s communication nodes
//! definitional equalities the rest of the library can chew on.

use super::structural::try_add;
use super::Lemma;
use crate::egraph::{Pat, Rewrite};
use crate::ir::{Op, OpTag};
use crate::symbolic::Scalar;

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // all_gather(xs; dim) = concat(xs; dim)
    v.push(Lemma::new(
        Rewrite::new(
            "allgather_is_concat",
            Pat::bind_variadic(OpTag::AllGather, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::AllGather { dim, .. }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                try_add(eg, Op::Concat { dim }, parts)
            },
        ),
        "c",
        2,
        8,
    ));

    // all_reduce(xs) = sum(xs)
    v.push(Lemma::new(
        Rewrite::new(
            "allreduce_is_sum",
            Pat::bind_variadic(OpTag::AllReduce, 0, 0),
            |eg, s, _| {
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                try_add(eg, Op::SumN, parts)
            },
        ),
        "c",
        2,
        6,
    ));

    // reduce_scatter(xs; dim, k, i) = slice(sum(xs); dim, i·c, (i+1)·c)
    v.push(Lemma::new(
        Rewrite::new(
            "reducescatter_is_slice_of_sum",
            Pat::bind_variadic(OpTag::ReduceScatter, 0, 0),
            |eg, s, _| {
                let (dim, ranks, index) = match s.op(0) {
                    Some(Op::ReduceScatter { dim, ranks, index }) => (*dim, *ranks, *index),
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let Some(shape) = eg.shape(parts[0]).map(|v| v.to_vec()) else { return vec![] };
                if shape[dim] % ranks as i64 != 0 {
                    return vec![];
                }
                let chunk = shape[dim] / ranks as i64;
                let Ok(sum) = eg.add_op(Op::SumN, parts) else { return vec![] };
                try_add(
                    eg,
                    Op::Slice {
                        dim,
                        start: Scalar::constant(index as i64 * chunk),
                        end: Scalar::constant((index as i64 + 1) * chunk),
                    },
                    vec![sum],
                )
            },
        ),
        "c",
        3,
        22,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn allgather_desugars() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let ag = eg.add_op(Op::AllGather { dim: 0, ranks: 2 }, vec![a, b]).unwrap();
        run(&mut eg);
        let cat = eg.lookup(&Op::Concat { dim: 0 }, &[a, b]).unwrap();
        assert!(eg.same(ag, cat));
    }

    #[test]
    fn allreduce_desugars() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let ar = eg.add_op(Op::AllReduce { ranks: 2 }, vec![a, b]).unwrap();
        run(&mut eg);
        let sum = eg.lookup(&Op::SumN, &[a, b]).unwrap();
        assert!(eg.same(ar, sum));
    }

    #[test]
    fn reduce_scatter_desugars_and_reassembles() {
        // concat(rs_0, rs_1) over both indices must equal sum(xs) — the full
        // reduce-scatter → all-gather roundtrip of the running example.
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4, 4]);
        let b = eg.add_leaf(t(1), vec![4, 4]);
        let d0 = eg.add_op(Op::ReduceScatter { dim: 0, ranks: 2, index: 0 }, vec![a, b]).unwrap();
        let d1 = eg.add_op(Op::ReduceScatter { dim: 0, ranks: 2, index: 1 }, vec![a, b]).unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![d0, d1]).unwrap();
        run(&mut eg);
        let sum = eg.lookup(&Op::SumN, &[a, b]).unwrap();
        assert!(eg.same(cat, sum), "concat of reduce-scatter chunks = shard sum");
    }
}
