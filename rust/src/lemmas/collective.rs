//! Collective-op lemmas: desugar single-program collectives into their
//! structural semantics (all-gather = concat, all-reduce = shard-sum,
//! reduce-scatter = slice-of-sum), plus the point-to-point stage-boundary
//! pair (recv∘send = identity when the channels match) and the ZeRO/FSDP
//! re-gather fact (all-gather of contiguous chunks of x = x). These give
//! `G_d`'s communication nodes definitional equalities the rest of the
//! library can chew on.

use super::structural::{chunked_slices_source, try_add};
use super::Lemma;
use crate::egraph::{Pat, Rewrite};
use crate::ir::{Op, OpTag};
use crate::symbolic::Scalar;

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // all_gather(xs; dim) = concat(xs; dim)
    v.push(Lemma::new(
        Rewrite::new(
            "allgather_is_concat",
            Pat::bind_variadic(OpTag::AllGather, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::AllGather { dim, .. }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                try_add(eg, Op::Concat { dim }, parts)
            },
        ),
        "c",
        2,
        8,
    ));

    // all_reduce(xs) = sum(xs)
    v.push(Lemma::new(
        Rewrite::new(
            "allreduce_is_sum",
            Pat::bind_variadic(OpTag::AllReduce, 0, 0),
            |eg, s, _| {
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                try_add(eg, Op::SumN, parts)
            },
        ),
        "c",
        2,
        6,
    ));

    // reduce_scatter(xs; dim, k, i) = slice(sum(xs); dim, i·c, (i+1)·c)
    v.push(Lemma::new(
        Rewrite::new(
            "reducescatter_is_slice_of_sum",
            Pat::bind_variadic(OpTag::ReduceScatter, 0, 0),
            |eg, s, _| {
                let (dim, ranks, index) = match s.op(0) {
                    Some(Op::ReduceScatter { dim, ranks, index }) => (*dim, *ranks, *index),
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let Some(shape) = eg.shape(parts[0]).map(|v| v.to_vec()) else { return vec![] };
                if shape[dim] % ranks as i64 != 0 {
                    return vec![];
                }
                let chunk = shape[dim] / ranks as i64;
                let Ok(sum) = eg.add_op(Op::SumN, parts) else { return vec![] };
                try_add(
                    eg,
                    Op::Slice {
                        dim,
                        start: Scalar::constant(index as i64 * chunk),
                        end: Scalar::constant((index as i64 + 1) * chunk),
                    },
                    vec![sum],
                )
            },
        ),
        "c",
        3,
        22,
    ));

    // recv(send(x; chan=c); chan=c) = x — a matched pipeline stage boundary
    // is transparent. The channel-equality condition is the whole point: a
    // crossed or stale boundary (recv wired to a different send) keeps its
    // Recv opaque, so nothing downstream of the wrong wiring maps cleanly
    // and refinement fails at the first consumer. Slot-liveness side
    // condition: a channel quarantined by the schedule's buffer audit
    // (`RewriteCtx::channel_quarantined`) never collapses even with equal
    // tags — its physical buffer is overwritten before the read completes,
    // so the matched pair does not deliver `x` at run time.
    v.push(Lemma::new(
        Rewrite::new(
            "recv_of_send_identity",
            Pat::bind(OpTag::Recv, 0, vec![Pat::bind(OpTag::Send, 1, vec![Pat::var(0)])]),
            |_eg, s, ctx| {
                let (Some(Op::Recv { chan: rc }), Some(Op::Send { chan: sc }), Some(x)) =
                    (s.op(0), s.op(1), s.var(0))
                else {
                    return vec![];
                };
                if rc == sc && !ctx.channel_quarantined(*rc) {
                    vec![x]
                } else {
                    vec![]
                }
            },
        ),
        "c",
        2,
        12,
    ));

    // all_gather(slice(x,0,c1), slice(x,c1,c2), ..; dim) = x — re-gathering
    // a chunk-sharded parameter (ZeRO/FSDP) reconstructs it exactly. Also a
    // one-step shortcut for the Fig-1 reduce-scatter → all-gather roundtrip
    // (each reduce_scatter output is a slice of the shard sum).
    v.push(Lemma::new(
        Rewrite::new(
            "allgather_of_chunks_identity",
            Pat::bind_variadic(OpTag::AllGather, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::AllGather { dim, .. }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                chunked_slices_source(eg, &parts, dim).into_iter().collect()
            },
        ),
        "c",
        3,
        16,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn allgather_desugars() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let ag = eg.add_op(Op::AllGather { dim: 0, ranks: 2 }, vec![a, b]).unwrap();
        run(&mut eg);
        let cat = eg.lookup(&Op::Concat { dim: 0 }, &[a, b]).unwrap();
        assert!(eg.same(ag, cat));
    }

    #[test]
    fn allreduce_desugars() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let ar = eg.add_op(Op::AllReduce { ranks: 2 }, vec![a, b]).unwrap();
        run(&mut eg);
        let sum = eg.lookup(&Op::SumN, &[a, b]).unwrap();
        assert!(eg.same(ar, sum));
    }

    #[test]
    fn matched_send_recv_is_transparent() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 4]);
        let sent = eg.add_op(Op::Send { chan: 7 }, vec![x]).unwrap();
        let recvd = eg.add_op(Op::Recv { chan: 7 }, vec![sent]).unwrap();
        run(&mut eg);
        assert!(eg.same(recvd, x), "matched boundary pair collapses");
    }

    #[test]
    fn quarantined_channel_stays_opaque_despite_matching_tags() {
        // slot-liveness side condition: the schedule audit flagged channel 7
        // as a buffer-reuse victim — even the tag-matched pair must not
        // collapse (its buffer does not hold x at read time)
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 4]);
        let sent = eg.add_op(Op::Send { chan: 7 }, vec![x]).unwrap();
        let recvd = eg.add_op(Op::Recv { chan: 7 }, vec![sent]).unwrap();
        let mut ctx = RewriteCtx::default();
        ctx.quarantine_channels([7usize]);
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(&mut eg, &rules, &ctx, SaturationLimits::default());
        assert!(!eg.same(recvd, x), "quarantined boundary must stay opaque");
    }

    #[test]
    fn crossed_send_recv_stays_opaque() {
        // recv on channel 1 wired to channel 0's send — the §6-style crossed
        // stage wiring must NOT simplify to either sent value.
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 4]);
        let sent0 = eg.add_op(Op::Send { chan: 0 }, vec![x]).unwrap();
        let crossed = eg.add_op(Op::Recv { chan: 1 }, vec![sent0]).unwrap();
        run(&mut eg);
        assert!(!eg.same(crossed, x), "crossed boundary must stay opaque");
    }

    #[test]
    fn allgather_of_chunk_slices_is_identity() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![6, 4]);
        let parts: Vec<_> = [(0i64, 2i64), (2, 4), (4, 6)]
            .iter()
            .map(|&(a, b)| {
                eg.add_op(Op::Slice { dim: 0, start: a.into(), end: b.into() }, vec![x]).unwrap()
            })
            .collect();
        let ag = eg.add_op(Op::AllGather { dim: 0, ranks: 3 }, parts).unwrap();
        run(&mut eg);
        assert!(eg.same(ag, x), "re-gathered chunked param = param");
    }

    #[test]
    fn allgather_of_partial_chunks_is_not_identity() {
        // missing the tail chunk: must NOT collapse to x
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![6, 4]);
        let a = eg.add_op(Op::Slice { dim: 0, start: 0.into(), end: 2.into() }, vec![x]).unwrap();
        let b = eg.add_op(Op::Slice { dim: 0, start: 2.into(), end: 4.into() }, vec![x]).unwrap();
        let ag = eg.add_op(Op::AllGather { dim: 0, ranks: 2 }, vec![a, b]).unwrap();
        run(&mut eg);
        assert!(!eg.same(ag, x), "partial coverage must stay a strict sub-tensor");
    }

    #[test]
    fn reduce_scatter_desugars_and_reassembles() {
        // concat(rs_0, rs_1) over both indices must equal sum(xs) — the full
        // reduce-scatter → all-gather roundtrip of the running example.
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4, 4]);
        let b = eg.add_leaf(t(1), vec![4, 4]);
        let d0 = eg.add_op(Op::ReduceScatter { dim: 0, ranks: 2, index: 0 }, vec![a, b]).unwrap();
        let d1 = eg.add_op(Op::ReduceScatter { dim: 0, ranks: 2, index: 1 }, vec![a, b]).unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![d0, d1]).unwrap();
        run(&mut eg);
        let sum = eg.lookup(&Op::SumN, &[a, b]).unwrap();
        assert!(eg.same(cat, sum), "concat of reduce-scatter chunks = shard sum");
    }
}
