//! Lemmas over the clean/structural ops: slice, concat, transpose, reshape,
//! pad, sum. These are the "c"-group lemmas that Figure 7 shows dominating
//! every verification run.

use super::Lemma;
use crate::egraph::{EGraph, Id, Pat, Rewrite, RewriteCtx, Subst};
use crate::ir::{Op, OpTag};
use crate::symbolic::{Scalar, Truth};

/// `add_op` that swallows shape errors (a rewrite that would build an
/// ill-shaped term simply does not fire).
pub(crate) fn try_add(eg: &mut EGraph, op: Op, children: Vec<Id>) -> Vec<Id> {
    eg.add_op(op, children).into_iter().collect()
}

/// Solver-aware scalar equality (concrete fast path; symbolic queries go
/// through the context's memoizing condition cache).
pub(crate) fn s_eq(ctx: &RewriteCtx, a: &Scalar, b: &Scalar) -> bool {
    if let (Some(x), Some(y)) = (a.as_const(), b.as_const()) {
        return x == y;
    }
    ctx.check_eq(&a.0, &b.0) == Truth::True
}

fn slice_attrs(op: &Op) -> (usize, Scalar, Scalar) {
    match op {
        Op::Slice { dim, start, end } => (*dim, start.clone(), end.clone()),
        _ => unreachable!("slice op expected"),
    }
}

/// If every class in `parts` contains a concrete `slice(x; dim, ·, ·)` of a
/// common source `x`, contiguous from 0 and covering `x`'s full extent along
/// `dim`, return `x`. Shared by `concat_chunks_collapse` and the collective
/// `allgather_of_chunks_identity` lemma — the ZeRO/FSDP "re-gather of a
/// chunked parameter is the parameter" fact.
pub(crate) fn chunked_slices_source(eg: &EGraph, parts: &[Id], dim: usize) -> Option<Id> {
    if parts.len() < 2 {
        return None;
    }
    'cand: for node in &eg.class(parts[0]).nodes {
        let crate::egraph::ELang::Op(Op::Slice { dim: d0, start, end }) = &node.lang else {
            continue;
        };
        if *d0 != dim || start.as_const() != Some(0) {
            continue;
        }
        let Some(&child) = node.children.first() else { continue };
        let x = eg.find(child);
        let Some(xshape) = eg.shape(x) else { continue };
        if dim >= xshape.len() {
            continue;
        }
        let total = xshape[dim];
        let Some(mut cursor) = end.as_const() else { continue };
        for &p in &parts[1..] {
            let mut advanced = None;
            for n2 in &eg.class(p).nodes {
                if let crate::egraph::ELang::Op(Op::Slice { dim: d2, start: s2, end: e2 }) =
                    &n2.lang
                {
                    if *d2 == dim
                        && n2.children.first().map(|&c| eg.find(c)) == Some(x)
                        && s2.as_const() == Some(cursor)
                    {
                        if let Some(e) = e2.as_const() {
                            advanced = Some(e);
                            break;
                        }
                    }
                }
            }
            match advanced {
                Some(e) => cursor = e,
                None => continue 'cand,
            }
        }
        if cursor == total {
            return Some(x);
        }
    }
    None
}

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // ---- slice algebra ----

    // slice(x, 0, len(x)) = x
    v.push(Lemma::new(
        Rewrite::new(
            "slice_full_identity",
            Pat::bind(OpTag::Slice, 0, vec![Pat::var(0)]),
            |eg: &mut EGraph, s: &Subst, ctx: &RewriteCtx| {
                let (Some(op0), Some(x)) = (s.op(0), s.var(0)) else { return vec![] };
                let (dim, start, end) = slice_attrs(op0);
                let Some(shape) = eg.shape(x) else { return vec![] };
                if dim < shape.len()
                    && s_eq(ctx, &start, &0.into())
                    && s_eq(ctx, &end, &shape[dim].into())
                {
                    vec![x]
                } else {
                    vec![]
                }
            },
        ),
        "c",
        1,
        14,
    ));

    // slice(slice(x, a, b), c, d) = slice(x, a+c, a+d)   [same dim]
    v.push(Lemma::new(
        Rewrite::new(
            "slice_of_slice",
            Pat::bind(OpTag::Slice, 0, vec![Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)])]),
            |eg, s, _ctx| {
                let (Some(op0), Some(op1), Some(x)) = (s.op(0), s.op(1), s.var(0)) else {
                    return vec![];
                };
                let (d_out, c, d) = slice_attrs(op0);
                let (d_in, a, _b) = slice_attrs(op1);
                if d_out != d_in {
                    return vec![];
                }
                try_add(
                    eg,
                    Op::Slice { dim: d_in, start: a.add(&c), end: a.add(&d) },
                    vec![x],
                )
            },
        ),
        "c",
        2,
        13,
    ));

    // CONSTRAINED (§4.3.2): adjacent slices of the same class merge —
    //   concat(slice(x,a,b), slice(x,b,c)) = slice(x,a,c),
    // and when [a,c) covers x entirely, = x. Triggered from a slice enode;
    // the sibling slice must ALREADY exist (we scan x's parents), which is
    // exactly the paper's ENode-existence constraint.
    v.push(Lemma::new(
        Rewrite::new(
            "adjacent_slices_concat",
            Pat::bind(OpTag::Slice, 0, vec![Pat::var(0)]),
            |eg, s, ctx| {
                let (Some(op0), Some(x)) = (s.op(0), s.var(0)) else { return vec![] };
                let (dim, a, b) = slice_attrs(op0);
                let Some(xshape) = eg.shape(x).map(|s| s.to_vec()) else { return vec![] };
                let this = match eg.lookup(op0, &[x]) {
                    Some(id) => id,
                    None => return vec![],
                };
                // find sibling slices slice(x, b, c) among x's parents
                let mut siblings: Vec<(Id, Scalar)> = Vec::new();
                for (node, pid) in &eg.class(x).parents {
                    if let crate::egraph::ELang::Op(Op::Slice { dim: d2, start: s2, end: e2 }) =
                        &node.lang
                    {
                        if *d2 == dim
                            && node.children.first().map(|&c| eg.find(c)) == Some(eg.find(x))
                            && s_eq(ctx, s2, &b)
                        {
                            siblings.push((eg.find(*pid), e2.clone()));
                        }
                    }
                }
                let mut out = Vec::new();
                for (sib, c_end) in siblings {
                    let Ok(cat) = eg.add_op(Op::Concat { dim }, vec![this, sib]) else {
                        continue;
                    };
                    // concat = slice(x, a, c)
                    if let Ok(merged) = eg.add_op(
                        Op::Slice { dim, start: a.clone(), end: c_end.clone() },
                        vec![x],
                    ) {
                        let _ = eg.union(cat, merged);
                    }
                    if s_eq(ctx, &a, &0.into()) && s_eq(ctx, &c_end, &xshape[dim].into()) {
                        let _ = eg.union(cat, x);
                    }
                    out.push(cat);
                }
                // `out` ids are equivalents of... nothing relative to root
                // (root is the small slice); unions already recorded above.
                let _ = out;
                vec![]
            },
        ),
        "c",
        3,
        40,
    ));

    // slice(concat(xs, d), a, b) over the SAME dim: if [a,b) falls inside
    // exactly one part, or exactly covers a contiguous run of parts, rewrite
    // to that part-slice / concat of parts.
    v.push(Lemma::new(
        Rewrite::new(
            "slice_of_concat",
            Pat::node(
                crate::egraph::POp::Bind { tag: OpTag::Slice, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, ctx| {
                let (Some(op0), Some(list0)) = (s.op(0), s.list(0)) else { return vec![] };
                let (sdim, a, b) = slice_attrs(op0);
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let parts: Vec<Id> = list0.to_vec();
                if sdim != cdim {
                    // different dim: slice each part
                    let sliced: Option<Vec<Id>> = parts
                        .iter()
                        .map(|&p| {
                            eg.add_op(
                                Op::Slice { dim: sdim, start: a.clone(), end: b.clone() },
                                vec![p],
                            )
                            .ok()
                        })
                        .collect();
                    let Some(sliced) = sliced else { return vec![] };
                    return try_add(eg, Op::Concat { dim: cdim }, sliced);
                }
                // same dim: compute part offsets (concrete shapes only)
                let (Some(a), Some(b)) = (a.as_const(), b.as_const()) else { return vec![] };
                let mut offsets = vec![0i64];
                for &p in &parts {
                    let Some(shape) = eg.shape(p) else { return vec![] };
                    if cdim >= shape.len() {
                        return vec![];
                    }
                    offsets.push(offsets.last().unwrap() + shape[cdim]);
                }
                // inside a single part?
                for (i, &p) in parts.iter().enumerate() {
                    if offsets[i] <= a && b <= offsets[i + 1] {
                        return try_add(
                            eg,
                            Op::Slice {
                                dim: cdim,
                                start: (a - offsets[i]).into(),
                                end: (b - offsets[i]).into(),
                            },
                            vec![p],
                        );
                    }
                }
                // aligned run of whole parts?
                if let (Some(lo), Some(hi)) = (
                    offsets.iter().position(|&o| o == a),
                    offsets.iter().position(|&o| o == b),
                ) {
                    if hi > lo {
                        let run: Vec<Id> = parts[lo..hi].to_vec();
                        if run.len() == 1 {
                            return vec![run[0]];
                        }
                        return try_add(eg, Op::Concat { dim: cdim }, run);
                    }
                }
                let _ = ctx;
                vec![]
            },
        ),
        "c",
        3,
        55,
    ));

    // concat(slice(x,0,c1), slice(x,c1,c2), .., slice(x,ck,len)) = x — the
    // n-ary chunk reassembly in one step (adjacent_slices_concat covers the
    // pairwise case; this closes R-way FSDP/ZeRO chunk gathers directly).
    v.push(Lemma::new(
        Rewrite::new(
            "concat_chunks_collapse",
            Pat::bind_variadic(OpTag::Concat, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                chunked_slices_source(eg, &parts, dim).into_iter().collect()
            },
        ),
        "c",
        2,
        16,
    ));

    // concat(x) = x  (singleton)
    v.push(Lemma::new(
        Rewrite::new(
            "concat_singleton",
            Pat::bind_variadic(OpTag::Concat, 0, 0),
            |_eg, s, _| {
                match s.list(0) {
                    Some(parts) if parts.len() == 1 => vec![parts[0]],
                    _ => vec![],
                }
            },
        ),
        "c",
        1,
        8,
    ));

    // concat(.., concat(ys, d), .., d) flattens
    v.push(Lemma::new(
        Rewrite::new(
            "concat_flatten",
            Pat::bind_variadic(OpTag::Concat, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                // find a part that is itself a concat along the same dim
                let mut flat: Vec<Id> = Vec::new();
                let mut changed = false;
                for &p in &parts {
                    let mut inlined = false;
                    if !changed {
                        for node in &eg.class(p).nodes {
                            if let crate::egraph::ELang::Op(Op::Concat { dim: d2 }) = &node.lang {
                                if *d2 == dim {
                                    flat.extend(node.children.iter().copied());
                                    inlined = true;
                                    changed = true;
                                    break;
                                }
                            }
                        }
                    }
                    if !inlined {
                        flat.push(p);
                    }
                }
                if !changed {
                    return vec![];
                }
                try_add(eg, Op::Concat { dim }, flat)
            },
        ),
        "c",
        2,
        28,
    ));

    // CONSTRAINED: group a flat concat around an existing sub-concat —
    //   concat(a, b, c, d; dim) = concat(concat(a,b), concat(c,d); dim)
    // fires only when a contiguous run already exists as a concat e-node
    // (e.g. G_d's per-rank `attn_r = concat(heads of rank r)`), so flat
    // per-head concats in G_s regroup into per-rank shards.
    v.push(Lemma::new(
        Rewrite::new(
            "concat_group",
            Pat::bind_variadic(OpTag::Concat, 0, 0),
            |eg, s, _| {
                let dim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let n = parts.len();
                if n < 3 {
                    return vec![];
                }
                // Greedy longest-match partition: walk left to right,
                // replacing the longest run that already exists as a concat
                // e-node. One grouping per match keeps this linear — the
                // exhaustive O(n²) sub-run enumeration explodes on wide
                // per-head concats (see EXPERIMENTS.md §Perf iteration 2).
                let mut grouped: Vec<Id> = Vec::with_capacity(n);
                let mut i = 0usize;
                let mut changed = false;
                while i < n {
                    let mut matched = None;
                    let mut j = n.min(i + 16);
                    while j >= i + 2 {
                        if j - i < n {
                            if let Some(group) = eg.lookup(&Op::Concat { dim }, &parts[i..j]) {
                                matched = Some((group, j));
                                break;
                            }
                        }
                        j -= 1;
                    }
                    match matched {
                        Some((group, j)) => {
                            grouped.push(group);
                            changed = true;
                            i = j;
                        }
                        None => {
                            grouped.push(parts[i]);
                            i += 1;
                        }
                    }
                }
                if !changed || grouped.len() < 2 {
                    return vec![];
                }
                try_add(eg, Op::Concat { dim }, grouped)
            },
        ),
        "c",
        2,
        30,
    ));

    // CONSTRAINED: group a flat sum around an existing sub-sum (EP expert
    // partials: all_reduce of per-rank sums of expert terms).
    v.push(Lemma::new(
        Rewrite::new(
            "sum_group",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |eg, s, _| {
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let n = parts.len();
                if n < 3 {
                    return vec![];
                }
                // greedy longest-match partition, as in concat_group
                let mut grouped: Vec<Id> = Vec::with_capacity(n);
                let mut i = 0usize;
                let mut changed = false;
                while i < n {
                    let mut matched = None;
                    let mut j = n.min(i + 16);
                    while j >= i + 2 {
                        if j - i < n {
                            if let Some(group) = eg.lookup(&Op::SumN, &parts[i..j]) {
                                matched = Some((group, j));
                                break;
                            }
                        }
                        j -= 1;
                    }
                    match matched {
                        Some((group, j)) => {
                            grouped.push(group);
                            changed = true;
                            i = j;
                        }
                        None => {
                            grouped.push(parts[i]);
                            i += 1;
                        }
                    }
                }
                if !changed || grouped.len() < 2 {
                    return vec![];
                }
                try_add(eg, Op::SumN, grouped)
            },
        ),
        "c",
        2,
        28,
    ));

    // transpose(transpose(x, p1), p2) = x when p2∘p1 = id, else fused perm
    v.push(Lemma::new(
        Rewrite::new(
            "transpose_fuse",
            Pat::bind(OpTag::Transpose, 0, vec![Pat::bind(OpTag::Transpose, 1, vec![Pat::var(0)])]),
            |eg, s, _| {
                let (p2, p1) = match (s.op(0), s.op(1)) {
                    (Some(Op::Transpose { perm: p2 }), Some(Op::Transpose { perm: p1 })) => {
                        (p2.clone(), p1.clone())
                    }
                    _ => return vec![],
                };
                if p1.len() != p2.len() {
                    return vec![];
                }
                let fused: Vec<usize> = p2.iter().map(|&j| p1[j]).collect();
                let Some(x) = s.var(0) else { return vec![] };
                if fused.iter().enumerate().all(|(i, &p)| i == p) {
                    vec![x]
                } else {
                    try_add(eg, Op::Transpose { perm: fused }, vec![x])
                }
            },
        ),
        "c",
        2,
        18,
    ));

    // transpose(concat(xs, d), p) = concat(transpose(x, p)s, p⁻¹(d))
    v.push(Lemma::new(
        Rewrite::new(
            "transpose_of_concat",
            Pat::node(
                crate::egraph::POp::Bind { tag: OpTag::Transpose, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let perm = match s.op(0) {
                    Some(Op::Transpose { perm }) => perm.clone(),
                    _ => return vec![],
                };
                let dim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                // output dim index j such that perm[j] == dim
                let Some(new_dim) = perm.iter().position(|&p| p == dim) else { return vec![] };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let tps: Option<Vec<Id>> = parts
                    .iter()
                    .map(|&p| eg.add_op(Op::Transpose { perm: perm.clone() }, vec![p]).ok())
                    .collect();
                let Some(tps) = tps else { return vec![] };
                try_add(eg, Op::Concat { dim: new_dim }, tps)
            },
        ),
        "c",
        3,
        24,
    ));

    // transpose(slice(x; d,a,b), p) = slice(transpose(x,p); p⁻¹(d),a,b)
    v.push(Lemma::new(
        Rewrite::new(
            "transpose_of_slice",
            Pat::bind(OpTag::Transpose, 0, vec![Pat::bind(OpTag::Slice, 1, vec![Pat::var(0)])]),
            |eg, s, _| {
                let perm = match s.op(0) {
                    Some(Op::Transpose { perm }) => perm.clone(),
                    _ => return vec![],
                };
                let (Some(op1), Some(x)) = (s.op(1), s.var(0)) else { return vec![] };
                let (dim, a, b) = slice_attrs(op1);
                let Some(new_dim) = perm.iter().position(|&p| p == dim) else { return vec![] };
                let Ok(tp) = eg.add_op(Op::Transpose { perm: perm.clone() }, vec![x]) else {
                    return vec![];
                };
                try_add(eg, Op::Slice { dim: new_dim, start: a, end: b }, vec![tp])
            },
        ),
        "c",
        3,
        17,
    ));

    // pad(x; d, 0, 0) = x
    v.push(Lemma::new(
        Rewrite::new(
            "pad_zero_identity",
            Pat::bind(OpTag::Pad, 0, vec![Pat::var(0)]),
            |_eg, s, ctx| {
                if let Some(Op::Pad { before, after, .. }) = s.op(0) {
                    if s_eq(ctx, before, &0.into()) && s_eq(ctx, after, &0.into()) {
                        return s.var(0).into_iter().collect();
                    }
                }
                vec![]
            },
        ),
        "c",
        1,
        9,
    ));

    // slice(pad(x; d, b, a); d, b, b+len(x,d)) = x  — the pad/slice pair of
    // §6.2 Bug 3; a *mismatched* pair fails this lemma's condition and the
    // implementation stops mapping cleanly.
    v.push(Lemma::new(
        Rewrite::new(
            "slice_of_pad",
            Pat::bind(OpTag::Slice, 0, vec![Pat::bind(OpTag::Pad, 1, vec![Pat::var(0)])]),
            |eg, s, ctx| {
                let (Some(op0), Some(x)) = (s.op(0), s.var(0)) else { return vec![] };
                let (sdim, st, en) = slice_attrs(op0);
                let (pdim, before) = match s.op(1) {
                    Some(Op::Pad { dim, before, .. }) => (*dim, before.clone()),
                    _ => return vec![],
                };
                let Some(shape) = eg.shape(x).map(|s| s.to_vec()) else { return vec![] };
                if sdim == pdim
                    && s_eq(ctx, &st, &before)
                    && s_eq(ctx, &en, &before.add(&shape[sdim].into()))
                {
                    vec![x]
                } else {
                    vec![]
                }
            },
        ),
        "c",
        2,
        20,
    ));

    // pad(concat(xs,d), d2≠d, b, a) = concat(pad(x,d2,b,a)s, d)
    v.push(Lemma::new(
        Rewrite::new(
            "pad_over_concat",
            Pat::node(
                crate::egraph::POp::Bind { tag: OpTag::Pad, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0)],
            ),
            |eg, s, _| {
                let (pdim, before, after, value) = match s.op(0) {
                    Some(Op::Pad { dim, before, after, value }) => {
                        (*dim, before.clone(), after.clone(), *value)
                    }
                    _ => return vec![],
                };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                if pdim == cdim {
                    return vec![];
                }
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&p| {
                        eg.add_op(
                            Op::Pad {
                                dim: pdim,
                                before: before.clone(),
                                after: after.clone(),
                                value,
                            },
                            vec![p],
                        )
                        .ok()
                    })
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::Concat { dim: cdim }, parts)
            },
        ),
        "c",
        3,
        26,
    ));

    // ---- sum (shard-combine) algebra ----

    // add(x, y) = sum(x, y): normalization into the n-ary combine form
    v.push(Lemma::new(
        Rewrite::new(
            "add_to_sum",
            Pat::exact(Op::Add, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                try_add(eg, Op::SumN, vec![x, y])
            },
        ),
        "c",
        2,
        6,
    ));

    // sum is commutative: canonical sorted order
    v.push(Lemma::new(
        Rewrite::new(
            "sum_commut",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |eg, s, _| {
                let Some(list0) = s.list(0) else { return vec![] };
                let mut parts: Vec<Id> = list0.iter().map(|&c| eg.find(c)).collect();
                let orig = parts.clone();
                parts.sort_unstable();
                if parts == orig {
                    return vec![];
                }
                try_add(eg, Op::SumN, parts)
            },
        ),
        "c",
        1,
        10,
    ));

    // sum(x, x, ..., x) = scale(x, n) — replicated contributions summed by
    // an all-reduce (the aux-loss/optimizer-aggregation pattern).
    v.push(Lemma::new(
        Rewrite::new(
            "sum_identical_scale",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |eg, s, _| {
                let Some(list0) = s.list(0) else { return vec![] };
                let parts: Vec<Id> = list0.iter().map(|&c| eg.find(c)).collect();
                if parts.len() < 2 || !parts.iter().all(|&p| p == parts[0]) {
                    return vec![];
                }
                try_add(
                    eg,
                    Op::Scale { c: crate::ir::FBits::new(parts.len() as f64) },
                    vec![parts[0]],
                )
            },
        ),
        "c",
        2,
        12,
    ));

    // sum(x) = x
    v.push(Lemma::new(
        Rewrite::new(
            "sum_singleton",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |_eg, s, _| {
                match s.list(0) {
                    Some(parts) if parts.len() == 1 => vec![parts[0]],
                    _ => vec![],
                }
            },
        ),
        "c",
        1,
        8,
    ));

    // sum(.., sum(ys), ..) flattens
    v.push(Lemma::new(
        Rewrite::new(
            "sum_flatten",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |eg, s, _| {
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let mut flat: Vec<Id> = Vec::new();
                let mut changed = false;
                for &p in &parts {
                    let mut inlined = false;
                    if !changed {
                        for node in &eg.class(p).nodes {
                            if matches!(&node.lang, crate::egraph::ELang::Op(Op::SumN)) {
                                flat.extend(node.children.iter().copied());
                                inlined = true;
                                changed = true;
                                break;
                            }
                        }
                    }
                    if !inlined {
                        flat.push(p);
                    }
                }
                if !changed {
                    return vec![];
                }
                try_add(eg, Op::SumN, flat)
            },
        ),
        "c",
        2,
        24,
    ));

    // sum(concat(xs,d), concat(ys,d)) = concat(sum(xi,yi), d) when aligned
    v.push(Lemma::new(
        Rewrite::new(
            "sum_of_concats",
            Pat::node(
                crate::egraph::POp::Exact(Op::SumN),
                vec![
                    Pat::bind_variadic(OpTag::Concat, 0, 0),
                    Pat::bind_variadic(OpTag::Concat, 1, 1),
                ],
            ),
            |eg, s, _| {
                let (d1, d2) = match (s.op(0), s.op(1)) {
                    (Some(Op::Concat { dim: a }), Some(Op::Concat { dim: b })) => (*a, *b),
                    _ => return vec![],
                };
                let (Some(xs), Some(ys)) = (s.list(0), s.list(1)) else { return vec![] };
                if d1 != d2 || xs.len() != ys.len() {
                    return vec![];
                }
                let (xs, ys) = (xs.to_vec(), ys.to_vec());
                let pieces: Option<Vec<Id>> = xs
                    .iter()
                    .zip(&ys)
                    .map(|(&a, &b)| {
                        if eg.shape(a) != eg.shape(b) {
                            return None;
                        }
                        eg.add_op(Op::SumN, vec![a, b]).ok()
                    })
                    .collect();
                let Some(pieces) = pieces else { return vec![] };
                try_add(eg, Op::Concat { dim: d1 }, pieces)
            },
        ),
        "c",
        4,
        27,
    ));

    // identity(x) = x
    v.push(Lemma::new(
        Rewrite::new(
            "identity_elim",
            Pat::exact(Op::Identity, vec![Pat::var(0)]),
            |_eg, s, _| s.var(0).into_iter().collect(),
        ),
        "c",
        1,
        5,
    ));

    // reshape(reshape(x, s1), s2) = reshape(x, s2); reshape to own shape = x
    v.push(Lemma::new(
        Rewrite::new(
            "reshape_fuse",
            Pat::bind(OpTag::Reshape, 0, vec![Pat::var(0)]),
            |eg, s, _| {
                let shape = match s.op(0) {
                    Some(Op::Reshape { shape }) => shape.clone(),
                    _ => return vec![],
                };
                let Some(x) = s.var(0) else { return vec![] };
                let Some(xshape) = eg.shape(x).map(|s| s.to_vec()) else { return vec![] };
                let target: Option<Vec<i64>> = shape.iter().map(|d| d.as_const()).collect();
                let mut out = Vec::new();
                if target.as_deref() == Some(&xshape[..]) {
                    out.push(x);
                }
                // fuse through an inner reshape
                for node in &eg.class(x).nodes.clone() {
                    if let crate::egraph::ELang::Op(Op::Reshape { .. }) = &node.lang {
                        let inner = node.children[0];
                        out.extend(try_add(eg, Op::Reshape { shape: shape.clone() }, vec![inner]));
                    }
                }
                out
            },
        ),
        "c",
        2,
        22,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn rules() -> Vec<crate::egraph::Rewrite> {
        lemmas().into_iter().map(|l| l.rewrite).collect()
    }

    fn run(eg: &mut EGraph) {
        let ctx = RewriteCtx::default();
        saturate(eg, &rules(), &ctx, SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn adjacent_slices_merge_to_whole() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![8, 4]);
        let l = eg.add_op(Op::Slice { dim: 0, start: 0.into(), end: 4.into() }, vec![x]).unwrap();
        let r = eg.add_op(Op::Slice { dim: 0, start: 4.into(), end: 8.into() }, vec![x]).unwrap();
        run(&mut eg);
        let cat = eg.lookup(&Op::Concat { dim: 0 }, &[l, r]).expect("concat created");
        assert!(eg.same(cat, x), "concat of adjacent full slices = x");
    }

    #[test]
    fn nary_chunk_concat_collapses() {
        // three uneven contiguous chunks — beyond what pairwise
        // adjacent_slices_concat alone would need to chain
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 8]);
        let parts: Vec<_> = [(0i64, 3i64), (3, 4), (4, 8)]
            .iter()
            .map(|&(a, b)| {
                eg.add_op(Op::Slice { dim: 1, start: a.into(), end: b.into() }, vec![x]).unwrap()
            })
            .collect();
        let cat = eg.add_op(Op::Concat { dim: 1 }, parts).unwrap();
        run(&mut eg);
        assert!(eg.same(cat, x), "n-ary chunk concat = x");
    }

    #[test]
    fn slice_of_concat_single_part() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4, 4]);
        let b = eg.add_leaf(t(1), vec![4, 4]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let sl = eg
            .add_op(Op::Slice { dim: 0, start: 4.into(), end: 8.into() }, vec![cat])
            .unwrap();
        run(&mut eg);
        assert!(eg.same(sl, b), "slice selecting the second part collapses to it");
    }

    #[test]
    fn slice_of_concat_other_dim() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4, 6]);
        let b = eg.add_leaf(t(1), vec![4, 6]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let sl = eg
            .add_op(Op::Slice { dim: 1, start: 0.into(), end: 3.into() }, vec![cat])
            .unwrap();
        run(&mut eg);
        // = concat(slice(a), slice(b))
        let sa = eg.lookup(&Op::Slice { dim: 1, start: 0.into(), end: 3.into() }, &[a]).unwrap();
        let sb = eg.lookup(&Op::Slice { dim: 1, start: 0.into(), end: 3.into() }, &[b]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[sa, sb]).unwrap();
        assert!(eg.same(sl, expect));
    }

    #[test]
    fn transpose_involution() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 3]);
        let t1 = eg.add_op(Op::Transpose { perm: vec![1, 0] }, vec![x]).unwrap();
        let t2 = eg.add_op(Op::Transpose { perm: vec![1, 0] }, vec![t1]).unwrap();
        run(&mut eg);
        assert!(eg.same(t2, x));
    }

    #[test]
    fn add_sum_normalization_and_flatten() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let c = eg.add_leaf(t(2), vec![4]);
        let ab = eg.add_op(Op::Add, vec![a, b]).unwrap();
        let abc = eg.add_op(Op::Add, vec![ab, c]).unwrap();
        run(&mut eg);
        let flat = eg.lookup(&Op::SumN, &[a, b, c]).expect("flattened n-ary sum exists");
        assert!(eg.same(abc, flat));
    }

    #[test]
    fn sum_commutativity() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let ab = eg.add_op(Op::SumN, vec![a, b]).unwrap();
        let ba = eg.add_op(Op::SumN, vec![b, a]).unwrap();
        run(&mut eg);
        assert!(eg.same(ab, ba));
    }

    #[test]
    fn pad_slice_roundtrip() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![5]);
        let padded = eg
            .add_op(
                Op::Pad { dim: 0, before: 2.into(), after: 1.into(), value: crate::ir::FBits::new(0.0) },
                vec![x],
            )
            .unwrap();
        let back = eg
            .add_op(Op::Slice { dim: 0, start: 2.into(), end: 7.into() }, vec![padded])
            .unwrap();
        run(&mut eg);
        assert!(eg.same(back, x));
    }

    #[test]
    fn mismatched_pad_slice_does_not_merge() {
        // Bug-3 shape: pad 2 before but slice from 1 — must NOT be x.
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![5]);
        let padded = eg
            .add_op(
                Op::Pad { dim: 0, before: 2.into(), after: 1.into(), value: crate::ir::FBits::new(0.0) },
                vec![x],
            )
            .unwrap();
        let off = eg
            .add_op(Op::Slice { dim: 0, start: 1.into(), end: 6.into() }, vec![padded])
            .unwrap();
        run(&mut eg);
        assert!(!eg.same(off, x), "mismatched pad/slice must not collapse");
    }

    #[test]
    fn sum_of_concats_zips() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 4]);
        let b = eg.add_leaf(t(1), vec![2, 4]);
        let c = eg.add_leaf(t(2), vec![2, 4]);
        let d = eg.add_leaf(t(3), vec![2, 4]);
        let ab = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let cd = eg.add_op(Op::Concat { dim: 0 }, vec![c, d]).unwrap();
        let s = eg.add_op(Op::SumN, vec![ab, cd]).unwrap();
        run(&mut eg);
        let ac = eg.lookup(&Op::SumN, &[a, c]).unwrap();
        let bd = eg.lookup(&Op::SumN, &[b, d]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[ac, bd]).unwrap();
        assert!(eg.same(s, expect));
    }

    #[test]
    fn reshape_identity() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![2, 3]);
        let r = eg
            .add_op(Op::Reshape { shape: vec![2.into(), 3.into()] }, vec![x])
            .unwrap();
        run(&mut eg);
        assert!(eg.same(r, x));
    }
}
