//! Custom-operator registry (paper §6.5).
//!
//! Models that use optimized kernels — our L1 Pallas RMSNorm and fused
//! attention kernels, vLLM-style fused ops, HLO-only ops — appear in
//! captured graphs as `Op::Custom { name }`. GraphGuard has no built-in
//! lemmas for them, so users register, per op: a shape function, a numeric
//! reference (used by lemma validation and cross-validation), and one or
//! more rewrite lemmas. Registration effort is what Figure 6 quantifies.

use crate::util::ndarray::NdArray;
use anyhow::{bail, Result};
use once_cell::sync::Lazy;
use rustc_hash::FxHashMap;
use std::sync::RwLock;

type ShapeFn = fn(&[&[i64]]) -> Result<Vec<i64>>;
type EvalFn = fn(&[&NdArray]) -> Result<NdArray>;

pub struct CustomOp {
    pub name: &'static str,
    /// Which model/framework required it (Fig 6 groups by this).
    pub origin: &'static str,
    pub shape: ShapeFn,
    pub eval: EvalFn,
    /// Lines of code the user wrote for this op's lemmas (Fig 6b CDF).
    pub lemma_loc: usize,
}

static REGISTRY: Lazy<RwLock<FxHashMap<&'static str, CustomOp>>> = Lazy::new(|| {
    let mut m = FxHashMap::default();
    for op in builtin_customs() {
        m.insert(op.name, op);
    }
    RwLock::new(m)
});

pub fn register(op: CustomOp) {
    REGISTRY.write().unwrap().insert(op.name, op);
}

pub fn registry_infer_shape(name: &str, ins: &[&[i64]]) -> Result<Vec<i64>> {
    let reg = REGISTRY.read().unwrap();
    match reg.get(name) {
        Some(op) => (op.shape)(ins),
        None => bail!("unknown custom op '{name}' — register it (see §6.5)"),
    }
}

pub fn registry_eval(name: &str, args: &[&NdArray]) -> Result<NdArray> {
    let reg = REGISTRY.read().unwrap();
    match reg.get(name) {
        Some(op) => (op.eval)(args),
        None => bail!("unknown custom op '{name}'"),
    }
}

pub fn registered_ops() -> Vec<(&'static str, &'static str, usize)> {
    REGISTRY.read().unwrap().values().map(|o| (o.name, o.origin, o.lemma_loc)).collect()
}

/// The custom ops our evaluated models need — mirrors Table 2's model set.
fn builtin_customs() -> Vec<CustomOp> {
    vec![
        // L1 Pallas fused RMSNorm (llama & bytedance models). Semantics
        // identical to Op::RmsNorm; the separate registration reproduces the
        // paper's "optimized kernel needs user lemmas" workflow.
        CustomOp {
            name: "pallas_rms_norm",
            origin: "llama3",
            shape: |ins| {
                anyhow::ensure!(ins.len() == 2, "pallas_rms_norm wants (x, w)");
                Ok(ins[0].to_vec())
            },
            eval: |args| {
                crate::expr::eval::eval_op(
                    &crate::ir::Op::RmsNorm { eps: crate::ir::FBits::new(1e-6) },
                    args,
                )
            },
            lemma_loc: 22,
        },
        // L1 Pallas row-blocked attention core: softmax(QKᵀ·scale)·V.
        CustomOp {
            name: "pallas_attention",
            origin: "bytedance",
            shape: |ins| {
                anyhow::ensure!(ins.len() == 3, "pallas_attention wants (q, k, v)");
                let (q, v) = (ins[0], ins[2]);
                let mut out = q.to_vec();
                *out.last_mut().unwrap() = *v.last().unwrap();
                Ok(out)
            },
            eval: |args| {
                use crate::ir::Op;
                let (q, k, v) = (args[0], args[1], args[2]);
                let d = *q.shape().last().unwrap() as f64;
                let kt_perm: Vec<usize> = {
                    let n = k.ndim();
                    let mut p: Vec<usize> = (0..n).collect();
                    p.swap(n - 1, n - 2);
                    p
                };
                let kt = k.transpose(&kt_perm)?;
                let scores = q.matmul(&kt)?;
                let scaled = crate::expr::eval::eval_op(
                    &Op::Scale { c: crate::ir::FBits::new(1.0 / d.sqrt()) },
                    &[&scores],
                )?;
                let ndim = scaled.ndim();
                let probs =
                    crate::expr::eval::eval_op(&Op::Softmax { dim: ndim - 1 }, &[&scaled])?;
                probs.matmul(v)
            },
            lemma_loc: 41,
        },
        // vLLM-style fused SwiGLU MLP gate: silu(a) * b.
        CustomOp {
            name: "fused_silu_mul",
            origin: "qwen2",
            shape: |ins| {
                anyhow::ensure!(ins.len() == 2 && ins[0] == ins[1], "fused_silu_mul shapes");
                Ok(ins[0].to_vec())
            },
            eval: |args| {
                let s = crate::expr::eval::eval_op(&crate::ir::Op::Silu, &[args[0]])?;
                s.zip(args[1], |a, b| a * b)
            },
            lemma_loc: 12,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_shapes() {
        assert_eq!(registry_infer_shape("pallas_rms_norm", &[&[2, 8], &[8]]).unwrap(), vec![2, 8]);
        assert_eq!(
            registry_infer_shape("pallas_attention", &[&[4, 8], &[4, 8], &[4, 8]]).unwrap(),
            vec![4, 8]
        );
        assert!(registry_infer_shape("no_such_op", &[&[1]]).is_err());
    }

    #[test]
    fn pallas_rms_matches_builtin_rmsnorm() {
        use crate::util::ndarray::NdArray;
        let x = NdArray::new(vec![2, 4], (0..8).map(|i| i as f32 * 0.3 - 1.0).collect()).unwrap();
        let w = NdArray::full(vec![4], 1.1);
        let custom = registry_eval("pallas_rms_norm", &[&x, &w]).unwrap();
        let builtin = crate::expr::eval::eval_op(
            &crate::ir::Op::RmsNorm { eps: crate::ir::FBits::new(1e-6) },
            &[&x, &w],
        )
        .unwrap();
        assert!(custom.allclose(&builtin, 1e-6, 1e-6));
    }

    #[test]
    fn fused_silu_mul_semantics() {
        use crate::util::ndarray::NdArray;
        let a = NdArray::new(vec![3], vec![-1., 0., 2.]).unwrap();
        let b = NdArray::new(vec![3], vec![2., 2., 2.]).unwrap();
        let out = registry_eval("fused_silu_mul", &[&a, &b]).unwrap();
        let silu = |x: f32| x / (1.0 + (-x).exp());
        for (i, &v) in out.data().iter().enumerate() {
            assert!((v - silu(a.data()[i]) * 2.0).abs() < 1e-6);
        }
    }
}
