//! The rewrite-lemma library (paper §4.2.1, §5).
//!
//! A lemma `ρ_m(T_m) --C--> ρ_n(T_n)` states that two expressions are
//! equivalent under condition `C`. Here each lemma is a [`Rewrite`]: an LHS
//! pattern plus an applier closure that checks the condition (consulting the
//! symbolic solver for non-concrete scalars, §5.2) and constructs the
//! equivalent term(s). Because applications *union* e-classes, every lemma
//! is effectively bidirectional once its trigger side matches — matching the
//! paper's note that each lemma's converse is derivable.
//!
//! The library covers the ATen-style ops our evaluated models use, the
//! collectives distribution strategies insert, and per-model custom ops
//! (§6.5) — our L1 Pallas kernels among them. Every lemma carries metadata
//! ([`LemmaMeta`]) feeding the Figure 6 (effort) and Figure 7 (usage
//! heatmap) reproductions, and every lemma is numerically validated in
//! `validate.rs`.

pub mod collective;
pub mod custom;
pub mod custom_lemmas;
pub mod elementwise;
pub mod matmul;
pub mod nn;
pub mod reduction;
pub mod routing;
pub mod structural;
pub mod validate;

use crate::egraph::Rewrite;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Metadata per lemma for the effort/usage analyses (Fig 6, Fig 7).
#[derive(Debug, Clone)]
pub struct LemmaMeta {
    pub name: &'static str,
    /// Grouping used on the Fig 7 x-axis: "c" = clean-expression ops,
    /// "core" = ATen-style compute ops, "v" = vLLM-style custom, "h" =
    /// HLO-frontend, "pallas" = our L1 kernels.
    pub group: &'static str,
    /// #operators appearing in the lemma (paper's complexity measure, §6.5).
    pub complexity: u32,
    /// Lines of code of the lemma definition (Fig 6b CDF).
    pub loc: u32,
}

pub struct Lemma {
    pub rewrite: Rewrite,
    pub meta: LemmaMeta,
}

impl Lemma {
    pub fn new(rewrite: Rewrite, group: &'static str, complexity: u32, loc: u32) -> Self {
        let name = rewrite.name;
        Lemma { rewrite, meta: LemmaMeta { name, group, complexity, loc } }
    }
}

/// The full standard library: every built-in lemma.
pub fn standard_library() -> Vec<Lemma> {
    let mut all = Vec::new();
    all.extend(structural::lemmas());
    all.extend(elementwise::lemmas());
    all.extend(matmul::lemmas());
    all.extend(reduction::lemmas());
    all.extend(nn::lemmas());
    all.extend(collective::lemmas());
    all.extend(routing::lemmas());
    all.extend(custom_lemmas::lemmas());
    all
}

static REWRITES: OnceLock<Arc<[Rewrite]>> = OnceLock::new();
static REWRITE_BUILDS: AtomicUsize = AtomicUsize::new(0);

/// Engine-facing view: the shared, built-once rewrite library. Every
/// operator, workload, and coordinator worker thread clones the same `Arc`,
/// so the ~100 boxed applier closures are constructed once per process
/// instead of once per verification run.
pub fn standard_rewrites() -> Arc<[Rewrite]> {
    Arc::clone(REWRITES.get_or_init(|| {
        REWRITE_BUILDS.fetch_add(1, Ordering::Relaxed);
        standard_library().into_iter().map(|l| l.rewrite).collect()
    }))
}

/// How many times the shared rewrite library has been constructed in this
/// process — must never exceed 1 (asserted by tests).
pub fn rewrite_library_builds() -> usize {
    REWRITE_BUILDS.load(Ordering::Relaxed)
}

/// Metadata-facing view (benches, reports).
pub fn metadata() -> Vec<LemmaMeta> {
    standard_library().into_iter().map(|l| l.meta).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use rustc_hash::FxHashSet;

    #[test]
    fn library_size_matches_paper_scale() {
        let n = standard_library().len();
        assert!(n >= 80, "paper ships 92 lemmas; we have {n}");
    }

    #[test]
    fn lemma_names_unique() {
        let mut seen = FxHashSet::default();
        for l in standard_library() {
            assert!(seen.insert(l.meta.name), "duplicate lemma '{}'", l.meta.name);
        }
    }

    #[test]
    fn groups_are_known() {
        for l in standard_library() {
            assert!(
                matches!(l.meta.group, "c" | "core" | "v" | "h" | "pallas"),
                "unknown group {} for {}",
                l.meta.group,
                l.meta.name
            );
        }
    }

    #[test]
    fn rewrite_library_is_built_at_most_once() {
        let a = standard_rewrites();
        let b = standard_rewrites();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "same shared allocation");
        assert_eq!(a.len(), standard_library().len());
        assert_eq!(rewrite_library_builds(), 1, "constructed exactly once");
    }

    #[test]
    fn complexity_positive() {
        for l in standard_library() {
            assert!(l.meta.complexity >= 1, "{}", l.meta.name);
            assert!(l.meta.loc >= 1, "{}", l.meta.name);
        }
    }
}
