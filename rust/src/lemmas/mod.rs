//! The rewrite-lemma library (paper §4.2.1, §5).
//!
//! A lemma `ρ_m(T_m) --C--> ρ_n(T_n)` states that two expressions are
//! equivalent under condition `C`. Here each lemma is a [`Rewrite`]: an LHS
//! pattern plus an applier closure that checks the condition (consulting the
//! symbolic solver for non-concrete scalars, §5.2) and constructs the
//! equivalent term(s). Because applications *union* e-classes, every lemma
//! is effectively bidirectional once its trigger side matches — matching the
//! paper's note that each lemma's converse is derivable.
//!
//! The library covers the ATen-style ops our evaluated models use, the
//! collectives distribution strategies insert, and per-model custom ops
//! (§6.5) — our L1 Pallas kernels among them. Every lemma carries metadata
//! ([`LemmaMeta`]) feeding the Figure 6 (effort) and Figure 7 (usage
//! heatmap) reproductions, and every lemma is numerically validated in
//! `validate.rs`.

pub mod collective;
pub mod custom;
pub mod custom_lemmas;
pub mod elementwise;
pub mod matmul;
pub mod nn;
pub mod reduction;
pub mod structural;
pub mod validate;

use crate::egraph::Rewrite;

/// Metadata per lemma for the effort/usage analyses (Fig 6, Fig 7).
#[derive(Debug, Clone)]
pub struct LemmaMeta {
    pub name: &'static str,
    /// Grouping used on the Fig 7 x-axis: "c" = clean-expression ops,
    /// "core" = ATen-style compute ops, "v" = vLLM-style custom, "h" =
    /// HLO-frontend, "pallas" = our L1 kernels.
    pub group: &'static str,
    /// #operators appearing in the lemma (paper's complexity measure, §6.5).
    pub complexity: u32,
    /// Lines of code of the lemma definition (Fig 6b CDF).
    pub loc: u32,
}

pub struct Lemma {
    pub rewrite: Rewrite,
    pub meta: LemmaMeta,
}

impl Lemma {
    pub fn new(rewrite: Rewrite, group: &'static str, complexity: u32, loc: u32) -> Self {
        let name = rewrite.name;
        Lemma { rewrite, meta: LemmaMeta { name, group, complexity, loc } }
    }
}

/// The full standard library: every built-in lemma.
pub fn standard_library() -> Vec<Lemma> {
    let mut all = Vec::new();
    all.extend(structural::lemmas());
    all.extend(elementwise::lemmas());
    all.extend(matmul::lemmas());
    all.extend(reduction::lemmas());
    all.extend(nn::lemmas());
    all.extend(collective::lemmas());
    all.extend(custom_lemmas::lemmas());
    all
}

/// Engine-facing view: just the rewrites.
pub fn standard_rewrites() -> Vec<Rewrite> {
    standard_library().into_iter().map(|l| l.rewrite).collect()
}

/// Metadata-facing view (benches, reports).
pub fn metadata() -> Vec<LemmaMeta> {
    standard_library().into_iter().map(|l| l.meta).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use rustc_hash::FxHashSet;

    #[test]
    fn library_size_matches_paper_scale() {
        let n = standard_library().len();
        assert!(n >= 80, "paper ships 92 lemmas; we have {n}");
    }

    #[test]
    fn lemma_names_unique() {
        let mut seen = FxHashSet::default();
        for l in standard_library() {
            assert!(seen.insert(l.meta.name), "duplicate lemma '{}'", l.meta.name);
        }
    }

    #[test]
    fn groups_are_known() {
        for l in standard_library() {
            assert!(
                matches!(l.meta.group, "c" | "core" | "v" | "h" | "pallas"),
                "unknown group {} for {}",
                l.meta.group,
                l.meta.name
            );
        }
    }

    #[test]
    fn complexity_positive() {
        for l in standard_library() {
            assert!(l.meta.complexity >= 1, "{}", l.meta.name);
            assert!(l.meta.loc >= 1, "{}", l.meta.name);
        }
    }
}
