//! Routing lemmas: the MoE expert-parallel family.
//!
//! These give the router-keyed ops (`topk` / `dispatch` / `combine`) their
//! conditional semantics. Every lemma is *guarded by router identity*: it
//! only fires when the router operands involved are provably the same
//! e-class — the "matching router tags" condition. A mutant that dispatches
//! with the wrong expert index, truncates capacity, or combines under a
//! different weight tensor never satisfies the guard, stays opaque, and
//! fails refinement at the first consumer.
//!
//! The capacity attribute threads through every lemma as a side-condition:
//! rewrites only apply when `capacity >= rows`, i.e. when the silent
//! token-drop behavior of a capacity-bound dispatch can never trigger.

use super::structural::try_add;
use super::Lemma;
use crate::egraph::{EGraph, Id, Pat, Rewrite};
use crate::ir::{FBits, Op, OpTag};
use crate::symbolic::Scalar;

/// First dim of a class's shape, if known.
fn rows_of(eg: &EGraph, id: Id) -> Option<i64> {
    eg.shape(id).and_then(|s| s.first().copied())
}

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // dispatch(x, r; e, cap) = mul(slice(r; dim=1, e, e+1), x) when the
    // capacity can never bind (cap >= rows) — the definitional desugar that
    // connects dispatch-based MoE graphs with dense-mask formulations. A
    // capacity-truncated dispatch does NOT desugar and stays opaque.
    v.push(Lemma::new(
        Rewrite::new(
            "dispatch_is_masked_mul",
            Pat::bind(OpTag::Dispatch, 0, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(Op::Dispatch { expert, capacity }), Some(x), Some(r)) =
                    (s.op(0), s.var(0), s.var(1))
                else {
                    return vec![];
                };
                let (expert, capacity) = (*expert, *capacity);
                let Some(xshape) = eg.shape(x).map(|s| s.to_vec()) else { return vec![] };
                // exactly rank 2: the [rows,1] column broadcast is only
                // row-aligned there (higher ranks would broadcast the
                // column down the wrong axis)
                if xshape.len() != 2 || (capacity as i64) < xshape[0] {
                    return vec![];
                }
                let Ok(col) = eg.add_op(
                    Op::Slice {
                        dim: 1,
                        start: Scalar::constant(expert as i64),
                        end: Scalar::constant(expert as i64 + 1),
                    },
                    vec![r],
                ) else {
                    return vec![];
                };
                try_add(eg, Op::Mul, vec![col, x])
            },
        ),
        "c",
        3,
        24,
    ));

    // combine(w, y_0, .., y_{E-1}) = sum_e mul(slice(w; 1, e, e+1), y_e):
    // the definitional desugar into the dense-gated form (the ByteDance MoE
    // workload's formulation), through which combine inherits the whole
    // concat/sum lemma family.
    v.push(Lemma::new(
        Rewrite::new(
            "combine_is_weighted_sum",
            Pat::bind_variadic(OpTag::Combine, 0, 0),
            |eg, s, _| {
                let Some(Op::Combine { experts }) = s.op(0) else { return vec![] };
                let experts = *experts;
                let Some(list) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                if experts < 1 || list.len() != experts + 1 {
                    return vec![];
                }
                // exactly rank 2 (see dispatch_is_masked_mul): the column
                // broadcast is only row-aligned for matrix-shaped experts
                if eg.shape(list[1]).map_or(true, |sh| sh.len() != 2) {
                    return vec![];
                }
                let w = list[0];
                let mut terms = Vec::with_capacity(experts);
                for (e, &y) in list[1..].iter().enumerate() {
                    let Ok(col) = eg.add_op(
                        Op::Slice {
                            dim: 1,
                            start: Scalar::constant(e as i64),
                            end: Scalar::constant(e as i64 + 1),
                        },
                        vec![w],
                    ) else {
                        return vec![];
                    };
                    let Ok(t) = eg.add_op(Op::Mul, vec![col, y]) else { return vec![] };
                    terms.push(t);
                }
                if terms.len() == 1 {
                    return terms;
                }
                try_add(eg, Op::SumN, terms)
            },
        ),
        "c",
        4,
        32,
    ));

    // combine(m, dispatch(x, m; 0), .., dispatch(x, m; E-1)) = scale(x, k)
    // (= x for top-1) when m is a top-k mask and *all* router tags match:
    // every dispatch must be keyed by the combine's own weight class, every
    // capacity must be non-binding, and the dispatched inputs must agree. A
    // crossed router tag — a dispatch keyed by a different mask — never
    // satisfies the guard.
    v.push(Lemma::new(
        Rewrite::new(
            "dispatch_combine_identity",
            Pat::bind_variadic(OpTag::Combine, 0, 0),
            |eg, s, _| {
                let Some(Op::Combine { experts }) = s.op(0) else { return vec![] };
                let experts = *experts;
                let Some(list) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                if list.len() != experts + 1 {
                    return vec![];
                }
                let w = eg.find(list[0]);
                // the weights must be a 0/1 top-k routing mask
                let Some(k) = eg.class(w).nodes.iter().find_map(|n| match &n.lang {
                    crate::egraph::ELang::Op(Op::TopK { k }) => Some(*k),
                    _ => None,
                }) else {
                    return vec![];
                };
                let mut x_common: Option<Id> = None;
                for (e, &y) in list[1..].iter().enumerate() {
                    let mut found = false;
                    for n in &eg.class(y).nodes {
                        let crate::egraph::ELang::Op(Op::Dispatch { expert, capacity }) = &n.lang
                        else {
                            continue;
                        };
                        if *expert != e || n.children.len() != 2 {
                            continue;
                        }
                        if eg.find(n.children[1]) != w {
                            continue; // crossed router tag — guard fails
                        }
                        let xc = eg.find(n.children[0]);
                        let Some(rows) = rows_of(eg, xc) else { continue };
                        if (*capacity as i64) < rows {
                            continue; // truncation may bind
                        }
                        if let Some(prev) = x_common {
                            if prev != xc {
                                continue;
                            }
                        }
                        x_common = Some(xc);
                        found = true;
                        break;
                    }
                    if !found {
                        return vec![];
                    }
                }
                let Some(x) = x_common else { return vec![] };
                if k == 1 {
                    vec![x]
                } else {
                    try_add(eg, Op::Scale { c: FBits::new(k as f64) }, vec![x])
                }
            },
        ),
        "c",
        4,
        40,
    ));

    // sum(combine(slice(w; 1, 0, c), y_0..), combine(slice(w; 1, c, E), ..))
    // = combine(w, y_0, .., y_{E-1}) — partial combines over *disjoint,
    // covering* expert column-slices of one router tensor collapse into the
    // full combine. This is the expert-parallel re-combine fact: each rank's
    // local combine covers its expert slice, the all-reduce sums them, and
    // the sum equals the sequential combine (mirrors
    // `allgather_of_chunks_identity` for the routing family).
    v.push(Lemma::new(
        Rewrite::new(
            "combine_of_disjoint_expert_slices",
            Pat::bind_variadic(OpTag::SumN, 0, 0),
            |eg, s, _| {
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                if parts.len() < 2 {
                    return vec![];
                }
                let mut src: Option<Id> = None;
                let mut cursor: i64 = 0;
                let mut ys: Vec<Id> = Vec::new();
                for &p in &parts {
                    let mut advanced: Option<(i64, Vec<Id>)> = None;
                    'nodes: for n in &eg.class(p).nodes {
                        let crate::egraph::ELang::Op(Op::Combine { experts }) = &n.lang else {
                            continue;
                        };
                        if n.children.len() != *experts + 1 {
                            continue;
                        }
                        let wc = eg.find(n.children[0]);
                        for wn in &eg.class(wc).nodes {
                            let crate::egraph::ELang::Op(Op::Slice { dim, start, end }) = &wn.lang
                            else {
                                continue;
                            };
                            if *dim != 1 || start.as_const() != Some(cursor) {
                                continue;
                            }
                            let Some(e_end) = end.as_const() else { continue };
                            if e_end - cursor != *experts as i64 {
                                continue;
                            }
                            let Some(&sc) = wn.children.first() else { continue };
                            let sc = eg.find(sc);
                            if let Some(prev) = src {
                                if prev != sc {
                                    continue;
                                }
                            }
                            src = Some(sc);
                            advanced = Some((e_end, n.children[1..].to_vec()));
                            break 'nodes;
                        }
                    }
                    let Some((e_end, mut local)) = advanced else { return vec![] };
                    cursor = e_end;
                    ys.append(&mut local);
                }
                let Some(src) = src else { return vec![] };
                let Some(total) = eg.shape(src).and_then(|sh| sh.get(1).copied()) else {
                    return vec![];
                };
                if cursor != total {
                    return vec![]; // partial expert coverage must stay opaque
                }
                let mut args = Vec::with_capacity(ys.len() + 1);
                args.push(src);
                args.extend(ys);
                try_add(eg, Op::Combine { experts: total as usize }, args)
            },
        ),
        "c",
        5,
        48,
    ));

    // dispatch(concat(x_i; 0), concat(r_i; 0); e, cap) =
    //   concat(dispatch(x_i, r_i; e, cap_i); 0) — dispatch is row-local, so
    // it distributes over aligned row-concats (SP×EP composition). This is
    // the capacity-respecting decomposition: it is only valid because
    // `cap >= rows` means the global assigned-token counter can never
    // saturate, so re-partitioning the rows cannot change which tokens
    // survive; per-piece capacities are set to the piece's own row count.
    v.push(Lemma::new(
        Rewrite::new(
            "dispatch_over_row_concat",
            Pat::bind(OpTag::Dispatch, 0, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(Op::Dispatch { expert, capacity }), Some(x), Some(r)) =
                    (s.op(0), s.var(0), s.var(1))
                else {
                    return vec![];
                };
                let (expert, capacity) = (*expert, *capacity);
                let Some(total) = rows_of(eg, x) else { return vec![] };
                if (capacity as i64) < total {
                    return vec![];
                }
                let (x, r) = (eg.find(x), eg.find(r));
                let row_concats = |eg: &EGraph, id: Id| -> Vec<Vec<Id>> {
                    eg.class(id)
                        .nodes
                        .iter()
                        .filter_map(|n| match &n.lang {
                            crate::egraph::ELang::Op(Op::Concat { dim: 0 }) => {
                                Some(n.children.clone())
                            }
                            _ => None,
                        })
                        .collect()
                };
                let xparts = row_concats(eg, x);
                let rparts = row_concats(eg, r);
                for xs in &xparts {
                    for rs in &rparts {
                        if xs.len() != rs.len() || xs.len() < 2 {
                            continue;
                        }
                        let aligned = xs.iter().zip(rs).all(|(&a, &b)| {
                            matches!(
                                (rows_of(eg, a), rows_of(eg, b)),
                                (Some(ra), Some(rb)) if ra == rb
                            )
                        });
                        if !aligned {
                            continue;
                        }
                        let pieces: Option<Vec<Id>> = xs
                            .iter()
                            .zip(rs)
                            .map(|(&a, &b)| {
                                let cap = rows_of(eg, a)?.max(1) as usize;
                                eg.add_op(Op::Dispatch { expert, capacity: cap }, vec![a, b]).ok()
                            })
                            .collect();
                        if let Some(pieces) = pieces {
                            if let Ok(cat) = eg.add_op(Op::Concat { dim: 0 }, pieces) {
                                return vec![cat];
                            }
                        }
                    }
                }
                vec![]
            },
        ),
        "c",
        4,
        44,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn dispatch_combine_identity_under_matching_tags() {
        let mut eg = EGraph::new();
        let scores = eg.add_leaf(t(0), vec![4, 2]);
        let x = eg.add_leaf(t(1), vec![4, 8]);
        let m = eg.add_op(Op::TopK { k: 1 }, vec![scores]).unwrap();
        let d0 = eg.add_op(Op::Dispatch { expert: 0, capacity: 4 }, vec![x, m]).unwrap();
        let d1 = eg.add_op(Op::Dispatch { expert: 1, capacity: 4 }, vec![x, m]).unwrap();
        let c = eg.add_op(Op::Combine { experts: 2 }, vec![m, d0, d1]).unwrap();
        run(&mut eg);
        assert!(eg.same(c, x), "top-1 dispatch/combine roundtrip collapses to x");
    }

    #[test]
    fn dispatch_combine_topk2_scales() {
        let mut eg = EGraph::new();
        let scores = eg.add_leaf(t(0), vec![4, 3]);
        let x = eg.add_leaf(t(1), vec![4, 8]);
        let m = eg.add_op(Op::TopK { k: 2 }, vec![scores]).unwrap();
        let ds: Vec<_> = (0..3)
            .map(|e| eg.add_op(Op::Dispatch { expert: e, capacity: 4 }, vec![x, m]).unwrap())
            .collect();
        let mut args = vec![m];
        args.extend(ds);
        let c = eg.add_op(Op::Combine { experts: 3 }, args).unwrap();
        run(&mut eg);
        let scaled = eg.lookup(&Op::Scale { c: FBits::new(2.0) }, &[x]).expect("scale built");
        assert!(eg.same(c, scaled), "top-2 roundtrip = 2·x");
        assert!(!eg.same(c, x), "and must NOT collapse to x itself");
    }

    #[test]
    fn crossed_router_tag_stays_opaque() {
        // the combine is keyed by a DIFFERENT mask than the dispatches —
        // the wrong-router wiring must not collapse
        let mut eg = EGraph::new();
        let s1 = eg.add_leaf(t(0), vec![4, 2]);
        let s2 = eg.add_leaf(t(1), vec![4, 2]);
        let x = eg.add_leaf(t(2), vec![4, 8]);
        let m1 = eg.add_op(Op::TopK { k: 1 }, vec![s1]).unwrap();
        let m2 = eg.add_op(Op::TopK { k: 1 }, vec![s2]).unwrap();
        let d0 = eg.add_op(Op::Dispatch { expert: 0, capacity: 4 }, vec![x, m1]).unwrap();
        let d1 = eg.add_op(Op::Dispatch { expert: 1, capacity: 4 }, vec![x, m1]).unwrap();
        let c = eg.add_op(Op::Combine { experts: 2 }, vec![m2, d0, d1]).unwrap();
        run(&mut eg);
        assert!(!eg.same(c, x), "crossed router tags must stay opaque");
    }

    #[test]
    fn capacity_truncated_dispatch_does_not_desugar() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![4, 8]);
        let r = eg.add_leaf(t(1), vec![4, 2]);
        let full = eg.add_op(Op::Dispatch { expert: 0, capacity: 4 }, vec![x, r]).unwrap();
        let trunc = eg.add_op(Op::Dispatch { expert: 0, capacity: 1 }, vec![x, r]).unwrap();
        run(&mut eg);
        // the non-binding dispatch desugars to mul(slice(r;1,0,1), x)
        let col = eg
            .lookup(&Op::Slice { dim: 1, start: 0.into(), end: 1.into() }, &[r])
            .expect("column slice built");
        let mul = eg.lookup(&Op::Mul, &[col, x]).expect("masked mul built");
        assert!(eg.same(full, mul), "cap >= rows dispatch = masked mul");
        // the truncated one keeps its silent-token-drop semantics opaque
        assert!(!eg.same(trunc, mul), "capacity-truncated dispatch must stay opaque");
        assert!(!eg.same(trunc, full));
    }

    #[test]
    fn disjoint_expert_slices_collapse_to_full_combine() {
        // sum of per-rank partial combines (EP) = the sequential combine
        let mut eg = EGraph::new();
        let w = eg.add_leaf(t(0), vec![4, 4]);
        let ys: Vec<_> = (1..=4).map(|i| eg.add_leaf(t(i), vec![4, 8])).collect();
        let s0 = eg.add_op(Op::Slice { dim: 1, start: 0.into(), end: 2.into() }, vec![w]).unwrap();
        let s1 = eg.add_op(Op::Slice { dim: 1, start: 2.into(), end: 4.into() }, vec![w]).unwrap();
        let c0 = eg.add_op(Op::Combine { experts: 2 }, vec![s0, ys[0], ys[1]]).unwrap();
        let c1 = eg.add_op(Op::Combine { experts: 2 }, vec![s1, ys[2], ys[3]]).unwrap();
        let sum = eg.add_op(Op::SumN, vec![c0, c1]).unwrap();
        let full = eg
            .add_op(Op::Combine { experts: 4 }, vec![w, ys[0], ys[1], ys[2], ys[3]])
            .unwrap();
        run(&mut eg);
        assert!(eg.same(sum, full), "partial combines over disjoint slices collapse");
    }

    #[test]
    fn partial_expert_coverage_does_not_collapse() {
        // missing the tail expert slice: must NOT equal the full combine
        let mut eg = EGraph::new();
        let w = eg.add_leaf(t(0), vec![4, 4]);
        let ys: Vec<_> = (1..=4).map(|i| eg.add_leaf(t(i), vec![4, 8])).collect();
        let s0 = eg.add_op(Op::Slice { dim: 1, start: 0.into(), end: 2.into() }, vec![w]).unwrap();
        let s1 = eg.add_op(Op::Slice { dim: 1, start: 2.into(), end: 3.into() }, vec![w]).unwrap();
        let c0 = eg.add_op(Op::Combine { experts: 2 }, vec![s0, ys[0], ys[1]]).unwrap();
        let c1 = eg.add_op(Op::Combine { experts: 1 }, vec![s1, ys[2]]).unwrap();
        let sum = eg.add_op(Op::SumN, vec![c0, c1]).unwrap();
        let full = eg
            .add_op(Op::Combine { experts: 4 }, vec![w, ys[0], ys[1], ys[2], ys[3]])
            .unwrap();
        run(&mut eg);
        assert!(!eg.same(sum, full), "uncovered expert columns must stay opaque");
    }

    #[test]
    fn dispatch_distributes_over_aligned_row_concats() {
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 8]);
        let x2 = eg.add_leaf(t(1), vec![2, 8]);
        let r1 = eg.add_leaf(t(2), vec![2, 2]);
        let r2 = eg.add_leaf(t(3), vec![2, 2]);
        let x = eg.add_op(Op::Concat { dim: 0 }, vec![x1, x2]).unwrap();
        let r = eg.add_op(Op::Concat { dim: 0 }, vec![r1, r2]).unwrap();
        let d = eg.add_op(Op::Dispatch { expert: 1, capacity: 4 }, vec![x, r]).unwrap();
        run(&mut eg);
        let d1 = eg
            .lookup(&Op::Dispatch { expert: 1, capacity: 2 }, &[x1, r1])
            .expect("piece dispatch built");
        let d2 = eg.lookup(&Op::Dispatch { expert: 1, capacity: 2 }, &[x2, r2]).unwrap();
        let cat = eg.lookup(&Op::Concat { dim: 0 }, &[d1, d2]).unwrap();
        assert!(eg.same(d, cat), "row-local dispatch splits over row concats");
    }
}
