//! NN compound-op lemmas: RMSNorm / LayerNorm / RoPE / Embedding sharding.
//! These include the paper's worked §6.5 example (RMSNorm over a sequence
//! concat) and the constrained RoPE lemma whose failure localizes Bug 1.

use super::structural::{s_eq, try_add};
use super::Lemma;
use crate::egraph::{ELang, Id, POp, Pat, Rewrite};
use crate::ir::{Op, OpTag};

pub fn lemmas() -> Vec<Lemma> {
    let mut v: Vec<Lemma> = Vec::new();

    // RMSNorm(concat(xs, d), W) = concat(RMSNorm(xi, W), d) when d is not
    // the normalized (last) dim — the paper's §6.5 example lemma.
    v.push(Lemma::new(
        Rewrite::new(
            "rmsnorm_row_split",
            Pat::node(
                POp::Bind { tag: OpTag::RmsNorm, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0), Pat::var(0)],
            ),
            |eg, s, _| {
                let Some(norm) = s.op(0).cloned() else { return vec![] };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let (Some(w), Some(parts)) = (s.var(0), s.list(0).map(|l| l.to_vec())) else {
                    return vec![];
                };
                let Some(rank) = eg.shape(parts[0]).map(|s| s.len()) else { return vec![] };
                if cdim == rank - 1 {
                    return vec![]; // splitting the normalized dim is NOT valid
                }
                let normed: Option<Vec<Id>> = parts
                    .iter()
                    .map(|&p| eg.add_op(norm.clone(), vec![p, w]).ok())
                    .collect();
                let Some(normed) = normed else { return vec![] };
                try_add(eg, Op::Concat { dim: cdim }, normed)
            },
        ),
        "core",
        3,
        22,
    ));

    // LayerNorm(concat(xs, d), W, B) likewise.
    v.push(Lemma::new(
        Rewrite::new(
            "layernorm_row_split",
            Pat::node(
                POp::Bind { tag: OpTag::LayerNorm, slot: 0 },
                vec![Pat::bind_variadic(OpTag::Concat, 1, 0), Pat::var(0), Pat::var(1)],
            ),
            |eg, s, _| {
                let Some(norm) = s.op(0).cloned() else { return vec![] };
                let cdim = match s.op(1) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let (Some(w), Some(b)) = (s.var(0), s.var(1)) else { return vec![] };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let Some(rank) = eg.shape(parts[0]).map(|s| s.len()) else { return vec![] };
                if cdim == rank - 1 {
                    return vec![];
                }
                let normed: Option<Vec<Id>> = parts
                    .iter()
                    .map(|&p| eg.add_op(norm.clone(), vec![p, w, b]).ok())
                    .collect();
                let Some(normed) = normed else { return vec![] };
                try_add(eg, Op::Concat { dim: cdim }, normed)
            },
        ),
        "core",
        3,
        22,
    ));

    // CONSTRAINED RoPE sequence-split (Bug 1's lemma):
    //   rope(concat(xs, seq_dim), cos, sin)
    //     = concat(rope(xi, slice(cos, offᵢ..offᵢ₊₁), slice(sin, ...)), seq)
    // The cos/sin slices must already exist as e-nodes (they are what the
    // distributed implementation computes); we search the cos/sin classes'
    // parents for slices at exactly the partition offsets. A wrong offset in
    // the implementation means the needed slice doesn't exist ⇒ lemma can't
    // fire ⇒ no clean mapping for the RoPE output.
    v.push(Lemma::new(
        Rewrite::new(
            "rope_seq_split",
            Pat::node(
                POp::Exact(Op::Rope),
                vec![Pat::bind_variadic(OpTag::Concat, 0, 0), Pat::var(0), Pat::var(1)],
            ),
            |eg, s, ctx| {
                let cdim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                let Some(parts) = s.list(0).map(|l| l.to_vec()) else { return vec![] };
                let (Some(cos), Some(sin)) = (s.var(0), s.var(1)) else { return vec![] };
                let Some(rank) = eg.shape(parts[0]).map(|v| v.len()) else { return vec![] };
                // rope rotates over (seq, head) = last two dims; the split
                // must be along seq = rank-2
                if cdim != rank - 2 {
                    return vec![];
                }
                // partition offsets along seq
                let mut offs = vec![0i64];
                for &p in &parts {
                    let Some(sh) = eg.shape(p) else { return vec![] };
                    offs.push(offs.last().unwrap() + sh[cdim]);
                }
                // find slice(cos, 0, off_i..off_{i+1}) among cos's parents
                let find_slice = |eg: &crate::egraph::EGraph, tbl: Id, lo: i64, hi: i64| {
                    for (node, pid) in &eg.class(tbl).parents {
                        if let ELang::Op(Op::Slice { dim: 0, start, end }) = &node.lang {
                            if node.children.first().map(|&c| eg.find(c)) == Some(eg.find(tbl))
                                && s_eq(ctx, start, &lo.into())
                                && s_eq(ctx, end, &hi.into())
                            {
                                return Some(eg.find(*pid));
                            }
                        }
                    }
                    None
                };
                let mut roped = Vec::with_capacity(parts.len());
                for (i, &p) in parts.iter().enumerate() {
                    let (lo, hi) = (offs[i], offs[i + 1]);
                    let (Some(cs), Some(ss)) =
                        (find_slice(eg, cos, lo, hi), find_slice(eg, sin, lo, hi))
                    else {
                        return vec![]; // required table slice missing
                    };
                    match eg.add_op(Op::Rope, vec![p, cs, ss]) {
                        Ok(r) => roped.push(r),
                        Err(_) => return vec![],
                    }
                }
                try_add(eg, Op::Concat { dim: cdim }, roped)
            },
        ),
        "core",
        4,
        48,
    ));

    // embedding(table, concat(ids, 0)) = concat(embedding(table, ids_i), 0)
    v.push(Lemma::new(
        Rewrite::new(
            "embedding_seq_split",
            Pat::node(
                POp::Exact(Op::Embedding),
                vec![Pat::var(0), Pat::bind_variadic(OpTag::Concat, 0, 0)],
            ),
            |eg, s, _| {
                let cdim = match s.op(0) {
                    Some(Op::Concat { dim }) => *dim,
                    _ => return vec![],
                };
                if cdim != 0 {
                    return vec![];
                }
                let (Some(table), Some(list0)) = (s.var(0), s.list(0)) else { return vec![] };
                let parts: Option<Vec<Id>> = list0
                    .iter()
                    .map(|&ids| eg.add_op(Op::Embedding, vec![table, ids]).ok())
                    .collect();
                let Some(parts) = parts else { return vec![] };
                try_add(eg, Op::Concat { dim: 0 }, parts)
            },
        ),
        "core",
        3,
        18,
    ));

    // rope(slice(x; seq, a, b), slice(cos; 0, a, b), slice(sin; 0, a, b))
    //   = slice(rope(x, cos, sin); seq, a, b) — the per-rank direction.
    v.push(Lemma::new(
        Rewrite::new(
            "rope_of_slices",
            Pat::node(
                POp::Exact(Op::Rope),
                vec![
                    Pat::bind(OpTag::Slice, 0, vec![Pat::var(0)]),
                    Pat::bind(OpTag::Slice, 1, vec![Pat::var(1)]),
                    Pat::bind(OpTag::Slice, 2, vec![Pat::var(2)]),
                ],
            ),
            |eg, s, ctx| {
                let (xd, xa, xb) = match s.op(0) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                let (cd, ca, cb) = match s.op(1) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                let (sd, sa, sb) = match s.op(2) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                let (Some(x), Some(cos), Some(sin)) = (s.var(0), s.var(1), s.var(2)) else {
                    return vec![];
                };
                let Some(rank) = eg.shape(x).map(|v| v.len()) else { return vec![] };
                // x sliced along seq (rank-2); cos/sin along their dim 0
                if xd != rank - 2 || cd != 0 || sd != 0 {
                    return vec![];
                }
                if !(s_eq(ctx, &xa, &ca)
                    && s_eq(ctx, &xb, &cb)
                    && s_eq(ctx, &xa, &sa)
                    && s_eq(ctx, &xb, &sb))
                {
                    return vec![];
                }
                let Ok(full) = eg.add_op(Op::Rope, vec![x, cos, sin]) else { return vec![] };
                try_add(eg, Op::Slice { dim: xd, start: xa, end: xb }, vec![full])
            },
        ),
        "core",
        5,
        38,
    ));

    // softmax(pad(x; last, 0, k, -inf); last) restricted back = softmax(x):
    // -inf padding contributes zero probability mass.
    v.push(Lemma::new(
        Rewrite::new(
            "softmax_neg_inf_pad",
            Pat::node(
                POp::Bind { tag: OpTag::Slice, slot: 0 },
                vec![Pat::node(
                    POp::Bind { tag: OpTag::Softmax, slot: 1 },
                    vec![Pat::bind(OpTag::Pad, 2, vec![Pat::var(0)])],
                )],
            ),
            |eg, s, ctx| {
                let (sdim, a, b) = match s.op(0) {
                    Some(Op::Slice { dim, start, end }) => (*dim, start.clone(), end.clone()),
                    _ => return vec![],
                };
                let smdim = match s.op(1) {
                    Some(Op::Softmax { dim }) => *dim,
                    _ => return vec![],
                };
                let (pdim, before, value) = match s.op(2) {
                    Some(Op::Pad { dim, before, value, .. }) => (*dim, before.clone(), *value),
                    _ => return vec![],
                };
                let Some(x) = s.var(0) else { return vec![] };
                let Some(shape) = eg.shape(x).map(|v| v.to_vec()) else { return vec![] };
                if sdim != smdim || pdim != smdim || value.get() != f64::NEG_INFINITY {
                    return vec![];
                }
                // slice must exactly undo the pad
                if !(s_eq(ctx, &a, &before)
                    && s_eq(ctx, &b, &before.add(&shape[pdim].into())))
                {
                    return vec![];
                }
                try_add(eg, Op::Softmax { dim: smdim }, vec![x])
            },
        ),
        "core",
        4,
        33,
    ));

    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{saturate, EGraph, RewriteCtx, SaturationLimits};
    use crate::expr::TensorRef;
    use crate::ir::FBits;

    fn run(eg: &mut EGraph) {
        let rules: Vec<Rewrite> =
            super::super::standard_library().into_iter().map(|l| l.rewrite).collect();
        saturate(eg, &rules, &RewriteCtx::default(), SaturationLimits::default());
    }

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn rmsnorm_splits_over_sequence() {
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 8]);
        let x2 = eg.add_leaf(t(1), vec![2, 8]);
        let w = eg.add_leaf(t(2), vec![8]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![x1, x2]).unwrap();
        let eps = FBits::new(1e-6);
        let norm = eg.add_op(Op::RmsNorm { eps }, vec![cat, w]).unwrap();
        run(&mut eg);
        let n1 = eg.lookup(&Op::RmsNorm { eps }, &[x1, w]).unwrap();
        let n2 = eg.lookup(&Op::RmsNorm { eps }, &[x2, w]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[n1, n2]).unwrap();
        assert!(eg.same(norm, expect));
    }

    #[test]
    fn rmsnorm_must_not_split_hidden_dim() {
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 4]);
        let x2 = eg.add_leaf(t(1), vec![2, 4]);
        let w = eg.add_leaf(t(2), vec![8]);
        let cat = eg.add_op(Op::Concat { dim: 1 }, vec![x1, x2]).unwrap();
        let eps = FBits::new(1e-6);
        let _norm = eg.add_op(Op::RmsNorm { eps }, vec![cat, w]).unwrap();
        run(&mut eg);
        // splitting the normalized dim changes semantics; must not fire
        assert!(eg.lookup(&Op::RmsNorm { eps }, &[x1, w]).is_none());
    }

    #[test]
    fn rope_seq_split_with_correct_offsets() {
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 4]); // [seq=2, d=4]
        let x2 = eg.add_leaf(t(1), vec![2, 4]);
        let cos = eg.add_leaf(t(2), vec![4, 4]);
        let sin = eg.add_leaf(t(3), vec![4, 4]);
        // the distributed implementation computes the CORRECT table slices
        let c1 = eg.add_op(Op::Slice { dim: 0, start: 0.into(), end: 2.into() }, vec![cos]).unwrap();
        let c2 = eg.add_op(Op::Slice { dim: 0, start: 2.into(), end: 4.into() }, vec![cos]).unwrap();
        let s1 = eg.add_op(Op::Slice { dim: 0, start: 0.into(), end: 2.into() }, vec![sin]).unwrap();
        let s2 = eg.add_op(Op::Slice { dim: 0, start: 2.into(), end: 4.into() }, vec![sin]).unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![x1, x2]).unwrap();
        let full = eg.add_op(Op::Rope, vec![cat, cos, sin]).unwrap();
        run(&mut eg);
        let r1 = eg.lookup(&Op::Rope, &[x1, c1, s1]).expect("per-rank rope exists");
        let r2 = eg.lookup(&Op::Rope, &[x2, c2, s2]).expect("per-rank rope exists");
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[r1, r2]).unwrap();
        assert!(eg.same(full, expect));
    }

    #[test]
    fn rope_seq_split_blocked_by_wrong_offset() {
        // Bug 1: backward slices start at 0 for BOTH ranks. The rank-1 slice
        // [2,4) doesn't exist, so the lemma cannot fire.
        let mut eg = EGraph::new();
        let x1 = eg.add_leaf(t(0), vec![2, 4]);
        let x2 = eg.add_leaf(t(1), vec![2, 4]);
        let cos = eg.add_leaf(t(2), vec![4, 4]);
        let sin = eg.add_leaf(t(3), vec![4, 4]);
        // BUGGY: both ranks slice [0,2)
        let _c1 = eg.add_op(Op::Slice { dim: 0, start: 0.into(), end: 2.into() }, vec![cos]).unwrap();
        let _s1 = eg.add_op(Op::Slice { dim: 0, start: 0.into(), end: 2.into() }, vec![sin]).unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![x1, x2]).unwrap();
        let full = eg.add_op(Op::Rope, vec![cat, cos, sin]).unwrap();
        run(&mut eg);
        // no per-rank decomposition of `full` may exist
        for node in &eg.class(full).nodes {
            assert!(
                !matches!(node.lang, ELang::Op(Op::Concat { .. })),
                "buggy offsets must not produce a concat form"
            );
        }
    }

    #[test]
    fn embedding_splits_ids() {
        let mut eg = EGraph::new();
        let table = eg.add_leaf(t(0), vec![16, 4]);
        let i1 = eg.add_leaf(t(1), vec![3]);
        let i2 = eg.add_leaf(t(2), vec![3]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![i1, i2]).unwrap();
        let emb = eg.add_op(Op::Embedding, vec![table, cat]).unwrap();
        run(&mut eg);
        let e1 = eg.lookup(&Op::Embedding, &[table, i1]).unwrap();
        let e2 = eg.lookup(&Op::Embedding, &[table, i2]).unwrap();
        let expect = eg.lookup(&Op::Concat { dim: 0 }, &[e1, e2]).unwrap();
        assert!(eg.same(emb, expect));
    }
}
