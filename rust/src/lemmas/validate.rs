//! Numeric lemma validation.
//!
//! The paper devotes ~4,100 lines of its Rust to specifying lemmas *and
//! validating them* (shape/type checks). Our equivalent: every lemma family
//! has an identity table entry — a pair of textual expressions over leaf
//! tensors with declared shapes — and `validate_identity` checks the two
//! sides agree numerically on random inputs. An unsound lemma (one that
//! unions non-equal terms) would poison every verification downstream, so
//! this is the first thing `cargo test` exercises after the unit tests.

use crate::expr::eval::{eval_expr, Env};
use crate::expr::{parse, Expr, TensorRef};
use crate::util::ndarray::NdArray;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use rustc_hash::FxHashMap;

/// A lemma identity: `lhs == rhs` for all values of the declared leaves.
pub struct Identity {
    pub lemma: &'static str,
    pub lhs: &'static str,
    pub rhs: &'static str,
    /// (leaf name, shape); names resolve in both expressions.
    pub leaves: &'static [(&'static str, &'static [i64])],
    /// Force non-negative leaf values (for log/sqrt identities).
    pub positive: bool,
}

fn leaf_env(id: &Identity, seed: u64) -> (FxHashMap<String, TensorRef>, Env) {
    let mut rng = Rng::new(seed);
    let mut names = FxHashMap::default();
    let mut env = Env::default();
    for (i, (name, shape)) in id.leaves.iter().enumerate() {
        let t = TensorRef::d(i as u32);
        names.insert(name.to_string(), t);
        let n: i64 = shape.iter().product();
        let data: Vec<f32> = (0..n)
            .map(|_| {
                let v = rng.normal() * 0.5;
                if id.positive {
                    v.abs() + 0.1
                } else {
                    v
                }
            })
            .collect();
        env.insert(t, NdArray::new(shape.to_vec(), data).unwrap());
    }
    (names, env)
}

/// Validate one identity over `trials` random input draws.
pub fn validate_identity(id: &Identity, trials: u64) -> Result<()> {
    for trial in 0..trials {
        let (names, env) = leaf_env(id, 0x5EED + trial * 7919);
        let resolve = |n: &str| names.get(n).copied();
        let lhs: Expr = parse::parse(id.lhs, &resolve)
            .with_context(|| format!("lemma {}: parsing lhs", id.lemma))?;
        let rhs: Expr = parse::parse(id.rhs, &resolve)
            .with_context(|| format!("lemma {}: parsing rhs", id.lemma))?;
        let lv = eval_expr(&lhs, &env).with_context(|| format!("lemma {}: lhs eval", id.lemma))?;
        let rv = eval_expr(&rhs, &env).with_context(|| format!("lemma {}: rhs eval", id.lemma))?;
        ensure!(
            lv.allclose(&rv, 1e-4, 1e-5),
            "lemma '{}' identity violated (trial {}): max |Δ| = {}",
            id.lemma,
            trial,
            lv.max_abs_diff(&rv)
        );
    }
    Ok(())
}

/// The identity table. One entry per lemma family (parametric families list
/// a representative instantiation; the e-graph tests cover the rest).
pub fn identities() -> Vec<Identity> {
    const S44: &[i64] = &[4, 4];
    const S24: &[i64] = &[2, 4];
    const S42: &[i64] = &[4, 2];
    const S4: &[i64] = &[4];
    const S8: &[i64] = &[8];
    vec![
        Identity {
            lemma: "adjacent_slices_concat",
            lhs: "concat(slice(x; dim=0, start=0, end=2), slice(x; dim=0, start=2, end=4); dim=0)",
            rhs: "x",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "slice_of_slice",
            lhs: "slice(slice(x; dim=1, start=1, end=4); dim=1, start=1, end=3)",
            rhs: "slice(x; dim=1, start=2, end=4)",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "slice_of_concat",
            lhs: "slice(concat(a, b; dim=0); dim=0, start=2, end=4)",
            rhs: "b",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "transpose_fuse",
            lhs: "transpose(transpose(x; perm=[1,0]); perm=[1,0])",
            rhs: "x",
            leaves: &[("x", S42)],
            positive: false,
        },
        Identity {
            lemma: "transpose_of_concat",
            lhs: "transpose(concat(a, b; dim=0); perm=[1,0])",
            rhs: "concat(transpose(a; perm=[1,0]), transpose(b; perm=[1,0]); dim=1)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "slice_of_pad",
            lhs: "slice(pad(x; dim=0, before=2, after=1, value=0.0); dim=0, start=2, end=6)",
            rhs: "x",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "pad_over_concat",
            lhs: "pad(concat(a, b; dim=0); dim=1, before=1, after=0, value=0.0)",
            rhs: "concat(pad(a; dim=1, before=1, after=0, value=0.0), pad(b; dim=1, before=1, after=0, value=0.0); dim=0)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "add_to_sum",
            lhs: "add(a, b)",
            rhs: "sum(a, b)",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "sum_flatten",
            lhs: "sum(sum(a, b), c)",
            rhs: "sum(a, b, c)",
            leaves: &[("a", S4), ("b", S4), ("c", S4)],
            positive: false,
        },
        Identity {
            lemma: "sum_of_concats",
            lhs: "sum(concat(a, b; dim=0), concat(c, d; dim=0))",
            rhs: "concat(sum(a, c), sum(b, d); dim=0)",
            leaves: &[("a", S24), ("b", S24), ("c", S24), ("d", S24)],
            positive: false,
        },
        Identity {
            lemma: "matmul_block_inner",
            lhs: "matmul(concat(a1, a2; dim=1), concat(b1, b2; dim=0))",
            rhs: "sum(matmul(a1, b1), matmul(a2, b2))",
            leaves: &[("a1", S42), ("a2", S42), ("b1", S24), ("b2", S24)],
            positive: false,
        },
        Identity {
            lemma: "matmul_block_rows",
            lhs: "matmul(concat(a1, a2; dim=0), b)",
            rhs: "concat(matmul(a1, b), matmul(a2, b); dim=0)",
            leaves: &[("a1", S24), ("a2", S24), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "matmul_block_cols",
            lhs: "matmul(a, concat(b1, b2; dim=1))",
            rhs: "concat(matmul(a, b1), matmul(a, b2); dim=1)",
            leaves: &[("a", S44), ("b1", S42), ("b2", S42)],
            positive: false,
        },
        Identity {
            lemma: "matmul_sum_left",
            lhs: "matmul(sum(a1, a2), b)",
            rhs: "sum(matmul(a1, b), matmul(a2, b))",
            leaves: &[("a1", S44), ("a2", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "slice_of_matmul_rows",
            lhs: "slice(matmul(a, b); dim=0, start=1, end=3)",
            rhs: "matmul(slice(a; dim=0, start=1, end=3), b)",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "slice_of_matmul_cols",
            lhs: "slice(matmul(a, b); dim=1, start=0, end=2)",
            rhs: "matmul(a, slice(b; dim=1, start=0, end=2))",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "matmul_scale_left",
            lhs: "matmul(scale(a; c=0.25), b)",
            rhs: "scale(matmul(a, b); c=0.25)",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "matmul_transpose",
            lhs: "transpose(matmul(a, b); perm=[1,0])",
            rhs: "matmul(transpose(b; perm=[1,0]), transpose(a; perm=[1,0]))",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "gelu_over_concat",
            lhs: "gelu(concat(a, b; dim=0))",
            rhs: "concat(gelu(a), gelu(b); dim=0)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "silu_over_slice",
            lhs: "silu(slice(x; dim=0, start=1, end=3))",
            rhs: "slice(silu(x); dim=0, start=1, end=3)",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "log_over_concat",
            lhs: "log(concat(a, b; dim=0))",
            rhs: "concat(log(a), log(b); dim=0)",
            leaves: &[("a", S24), ("b", S24)],
            positive: true,
        },
        Identity {
            lemma: "rsqrt_over_transpose",
            lhs: "rsqrt(transpose(x; perm=[1,0]))",
            rhs: "transpose(rsqrt(x); perm=[1,0])",
            leaves: &[("x", S44)],
            positive: true,
        },
        Identity {
            lemma: "binary_over_concat",
            lhs: "mul(concat(a, b; dim=0), concat(c, d; dim=0))",
            rhs: "concat(mul(a, c), mul(b, d); dim=0)",
            leaves: &[("a", S24), ("b", S24), ("c", S24), ("d", S24)],
            positive: false,
        },
        Identity {
            lemma: "binary_bcast_over_concat",
            lhs: "mul(concat(a, b; dim=0), w)",
            rhs: "concat(mul(a, w), mul(b, w); dim=0)",
            leaves: &[("a", S24), ("b", S24), ("w", S4)],
            positive: false,
        },
        Identity {
            lemma: "sub_to_sum_neg",
            lhs: "sub(a, b)",
            rhs: "sum(a, neg(b))",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "scale_fuse",
            lhs: "scale(scale(x; c=2.0); c=0.5)",
            rhs: "x",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "scale_over_sum",
            lhs: "scale(sum(a, b); c=0.5)",
            rhs: "sum(scale(a; c=0.5), scale(b; c=0.5))",
            leaves: &[("a", S4), ("b", S4)],
            positive: false,
        },
        Identity {
            lemma: "mul_over_sum",
            lhs: "mul(sum(a, b), y)",
            rhs: "sum(mul(a, y), mul(b, y))",
            leaves: &[("a", S4), ("b", S4), ("y", S4)],
            positive: false,
        },
        Identity {
            lemma: "reducesum_concat_same_dim",
            lhs: "reduce_sum(concat(a, b; dim=0); dim=0)",
            rhs: "sum(reduce_sum(a; dim=0), reduce_sum(b; dim=0))",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "reducesum_concat_other_dim",
            lhs: "reduce_sum(concat(a, b; dim=1); dim=0)",
            rhs: "concat(reduce_sum(a; dim=0), reduce_sum(b; dim=0); dim=0)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "reducemax_concat_same_dim",
            lhs: "reduce_max(concat(a, b; dim=0); dim=0)",
            rhs: "maximum(reduce_max(a; dim=0), reduce_max(b; dim=0))",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "reducemean_concat_same_dim",
            lhs: "reduce_mean(concat(a, b; dim=0); dim=0)",
            rhs: "scale(sum(reduce_mean(a; dim=0), reduce_mean(b; dim=0)); c=0.5)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "mse_microbatch",
            lhs: "mse_loss(concat(p1, p2; dim=0), concat(t1, t2; dim=0))",
            rhs: "scale(sum(mse_loss(p1, t1), mse_loss(p2, t2)); c=0.5)",
            leaves: &[("p1", S24), ("p2", S24), ("t1", S24), ("t2", S24)],
            positive: false,
        },
        Identity {
            lemma: "softmax_concat_other_dim",
            lhs: "softmax(concat(a, b; dim=0); dim=1)",
            rhs: "concat(softmax(a; dim=1), softmax(b; dim=1); dim=0)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "reducesum_over_slice",
            lhs: "reduce_sum(slice(x; dim=1, start=0, end=2); dim=0)",
            rhs: "slice(reduce_sum(x; dim=0); dim=0, start=0, end=2)",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "rmsnorm_row_split",
            lhs: "rms_norm(concat(a, b; dim=0), w; eps=1e-6)",
            rhs: "concat(rms_norm(a, w; eps=1e-6), rms_norm(b, w; eps=1e-6); dim=0)",
            leaves: &[("a", S24), ("b", S24), ("w", S4)],
            positive: false,
        },
        Identity {
            lemma: "layernorm_row_split",
            lhs: "layer_norm(concat(a, b; dim=0), w, c; eps=1e-5)",
            rhs: "concat(layer_norm(a, w, c; eps=1e-5), layer_norm(b, w, c; eps=1e-5); dim=0)",
            leaves: &[("a", S24), ("b", S24), ("w", S4), ("c", S4)],
            positive: false,
        },
        Identity {
            lemma: "rope_seq_split",
            lhs: "rope(concat(x1, x2; dim=0), cos, sin)",
            rhs: "concat(rope(x1, slice(cos; dim=0, start=0, end=2), slice(sin; dim=0, start=0, end=2)), rope(x2, slice(cos; dim=0, start=2, end=4), slice(sin; dim=0, start=2, end=4)); dim=0)",
            leaves: &[("x1", S24), ("x2", S24), ("cos", S44), ("sin", S44)],
            positive: false,
        },
        Identity {
            lemma: "embedding_seq_split",
            lhs: "embedding(tbl, concat(i1, i2; dim=0))",
            rhs: "concat(embedding(tbl, i1), embedding(tbl, i2); dim=0)",
            leaves: &[("tbl", S44), ("i1", &[2]), ("i2", &[2])],
            positive: true, // ids must be valid rows (handled by |v|+0.1 < 4)
        },
        Identity {
            lemma: "recv_of_send_identity",
            lhs: "recv(send(x; chan=3); chan=3)",
            rhs: "x",
            leaves: &[("x", S24)],
            positive: false,
        },
        Identity {
            lemma: "allgather_of_chunks_identity",
            lhs: "all_gather(slice(x; dim=0, start=0, end=2), slice(x; dim=0, start=2, end=4); dim=0, ranks=2)",
            rhs: "x",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "concat_chunks_collapse",
            lhs: "concat(slice(x; dim=1, start=0, end=1), slice(x; dim=1, start=1, end=3), slice(x; dim=1, start=3, end=4); dim=1)",
            rhs: "x",
            leaves: &[("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "allgather_is_concat",
            lhs: "all_gather(a, b; dim=0, ranks=2)",
            rhs: "concat(a, b; dim=0)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "allreduce_is_sum",
            lhs: "all_reduce(a, b; ranks=2)",
            rhs: "sum(a, b)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "reducescatter_is_slice_of_sum",
            lhs: "reduce_scatter(a, b; dim=0, ranks=2, index=1)",
            rhs: "slice(sum(a, b); dim=0, start=2, end=4)",
            leaves: &[("a", S44), ("b", S44)],
            positive: false,
        },
        Identity {
            lemma: "dispatch_is_masked_mul",
            lhs: "dispatch(x, r; expert=1, capacity=4)",
            rhs: "mul(slice(r; dim=1, start=1, end=2), x)",
            leaves: &[("x", S44), ("r", S42)],
            positive: false,
        },
        Identity {
            lemma: "combine_is_weighted_sum",
            lhs: "combine(w, y0, y1; experts=2)",
            rhs: "sum(mul(slice(w; dim=1, start=0, end=1), y0), mul(slice(w; dim=1, start=1, end=2), y1))",
            leaves: &[("w", S42), ("y0", S44), ("y1", S44)],
            positive: false,
        },
        Identity {
            lemma: "dispatch_combine_identity",
            lhs: "combine(topk(s; k=1), dispatch(x, topk(s; k=1); expert=0, capacity=4), dispatch(x, topk(s; k=1); expert=1, capacity=4); experts=2)",
            rhs: "x",
            leaves: &[("s", S42), ("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "dispatch_combine_identity_topk2",
            lhs: "combine(topk(s; k=2), dispatch(x, topk(s; k=2); expert=0, capacity=4), dispatch(x, topk(s; k=2); expert=1, capacity=4); experts=2)",
            rhs: "scale(x; c=2.0)",
            leaves: &[("s", S42), ("x", S44)],
            positive: false,
        },
        Identity {
            lemma: "combine_of_disjoint_expert_slices",
            lhs: "sum(combine(slice(w; dim=1, start=0, end=1), y0; experts=1), combine(slice(w; dim=1, start=1, end=2), y1; experts=1))",
            rhs: "combine(w, y0, y1; experts=2)",
            leaves: &[("w", S42), ("y0", S44), ("y1", S44)],
            positive: false,
        },
        Identity {
            lemma: "dispatch_over_row_concat",
            lhs: "dispatch(concat(x1, x2; dim=0), concat(r1, r2; dim=0); expert=0, capacity=4)",
            rhs: "concat(dispatch(x1, r1; expert=0, capacity=2), dispatch(x2, r2; expert=0, capacity=2); dim=0)",
            leaves: &[("x1", S24), ("x2", S24), ("r1", &[2, 2]), ("r2", &[2, 2])],
            positive: false,
        },
        Identity {
            lemma: "pallas_rmsnorm_semantics",
            lhs: "pallas_rms_norm(x, w)",
            rhs: "rms_norm(x, w; eps=1e-6)",
            leaves: &[("x", S24), ("w", S4)],
            positive: false,
        },
        Identity {
            lemma: "pallas_attention_semantics",
            lhs: "pallas_attention(q, k, v)",
            rhs: "matmul(softmax(scale(matmul(q, transpose(k; perm=[1,0])); c=0.5); dim=1), v)",
            leaves: &[("q", S44), ("k", S44), ("v", S44)],
            positive: false,
        },
        Identity {
            lemma: "fused_silu_mul_semantics",
            lhs: "fused_silu_mul(a, b)",
            rhs: "mul(silu(a), b)",
            leaves: &[("a", S24), ("b", S24)],
            positive: false,
        },
        Identity {
            lemma: "rope_of_slices",
            lhs: "rope(slice(x; dim=0, start=1, end=3), slice(cos; dim=0, start=1, end=3), slice(sin; dim=0, start=1, end=3))",
            rhs: "slice(rope(x, cos, sin); dim=0, start=1, end=3)",
            leaves: &[("x", S44), ("cos", S44), ("sin", S44)],
            positive: false,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_ids_in_range() {
        // gather ids come from the positive path: |normal*0.5|+0.1 ∈ (0.1, ~2.6),
        // rounding to rows 0..3 of a 4-row table — always valid.
        let id = identities().into_iter().find(|i| i.lemma == "embedding_seq_split").unwrap();
        validate_identity(&id, 16).unwrap();
    }

    #[test]
    fn all_identities_hold() {
        for id in identities() {
            validate_identity(&id, 8).unwrap_or_else(|e| panic!("{e:#}"));
        }
    }

    #[test]
    fn identity_table_covers_core_lemma_families() {
        let names: Vec<&str> = identities().iter().map(|i| i.lemma).collect();
        for must in [
            "matmul_block_inner",
            "rmsnorm_row_split",
            "rope_seq_split",
            "mse_microbatch",
            "reducescatter_is_slice_of_sum",
            "pallas_attention_semantics",
            "recv_of_send_identity",
            "allgather_of_chunks_identity",
            "dispatch_is_masked_mul",
            "combine_is_weighted_sum",
            "dispatch_combine_identity",
            "combine_of_disjoint_expert_slices",
        ] {
            assert!(names.contains(&must), "identity table missing {must}");
        }
    }

    #[test]
    fn catches_a_wrong_identity() {
        // sanity: the validator actually detects inequality
        let bad = Identity {
            lemma: "bogus",
            lhs: "scale(x; c=2.0)",
            rhs: "x",
            leaves: &[("x", &[4])],
            positive: false,
        };
        assert!(validate_identity(&bad, 4).is_err());
    }
}
