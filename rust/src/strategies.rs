//! Distribution-strategy primitives (paper §2.1).
//!
//! Model builders (`crate::models`) compose these helpers to produce the
//! distributed implementation `G_d` and its clean input relation `R_i` from
//! the same configuration that builds `G_s` — mirroring how Megatron/vLLM
//! implementers apply TP/SP/VP/EP/gradient-accumulation by hand. The
//! helpers keep `R_i` construction honest: every sharded or replicated
//! input records exactly the mapping a user of GraphGuard would write.

use crate::ir::{Graph, TensorId};
use crate::relation::Relation;
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// Which strategies a distributed variant applies (Table 2's third column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Tensor parallelism: shard weight matrices, all-reduce partials.
    TP,
    /// Sequence parallelism: shard activations along the sequence dim.
    SP,
    /// Vocabulary parallelism: shard the LM head over the vocab dim.
    VP,
    /// Expert parallelism: shard MoE experts across ranks.
    EP,
    /// Gradient accumulation: split the batch into microbatches.
    GradAccum,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::TP => "tp",
            Strategy::SP => "sp",
            Strategy::VP => "vp",
            Strategy::EP => "ep",
            Strategy::GradAccum => "grad_accum",
        }
    }
}

/// Collects the clean input relation while the distributed graph is built.
#[derive(Debug, Default)]
pub struct RiBuilder {
    entries: BTreeMap<String, Vec<String>>,
}

impl RiBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn map(&mut self, gs_name: &str, expr: String) {
        self.entries.entry(gs_name.to_string()).or_default().push(expr);
    }

    pub fn finish(self, gs: &Graph, gd: &Graph) -> Result<Relation> {
        let obj = Json::Obj(
            self.entries
                .into_iter()
                .map(|(k, v)| (k, Json::Arr(v.into_iter().map(Json::Str).collect())))
                .collect(),
        );
        let rel = Relation::from_json(&obj, gs, gd)?;
        rel.validate_shapes(gs, gd)?;
        Ok(rel)
    }
}

/// Declare a `G_s` input sharded along `dim` across `ranks`; returns the
/// per-rank `G_d` input ids and records `name = concat(name_r0.., dim)`.
pub fn shard_input(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dim: usize,
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input_typed(gd, ri, name, shape, dim, ranks, crate::ir::DType::F32)
}

#[allow(clippy::too_many_arguments)]
pub fn shard_input_typed(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dim: usize,
    ranks: usize,
    dtype: crate::ir::DType,
) -> Result<Vec<TensorId>> {
    ensure!(dim < shape.len(), "shard dim {dim} of {shape:?}");
    ensure!(
        shape[dim] % ranks as i64 == 0,
        "dim {} of '{}' ({}) not divisible by {} ranks",
        dim,
        name,
        shape[dim],
        ranks
    );
    let mut part = shape.to_vec();
    part[dim] /= ranks as i64;
    let mut ids = Vec::with_capacity(ranks);
    let mut names = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let rname = format!("{name}_r{r}");
        ids.push(gd.input_typed(&rname, part.clone(), dtype));
        names.push(rname);
    }
    ri.map(name, format!("concat({}; dim={dim})", names.join(", ")));
    Ok(ids)
}

/// Declare a `G_s` input replicated on every rank. In single-program
/// capture replicas are one tensor; we declare one `G_d` input and record
/// the identity mapping.
pub fn replicate_input(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
) -> TensorId {
    replicate_input_typed(gd, ri, name, shape, crate::ir::DType::F32)
}

pub fn replicate_input_typed(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dtype: crate::ir::DType,
) -> TensorId {
    let rname = format!("{name}_rep");
    let id = gd.input_typed(&rname, shape.to_vec(), dtype);
    ri.map(name, rname);
    id
}

/// Integer-typed shard (token ids under sequence parallelism).
pub fn shard_input_ids(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dim: usize,
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input_typed(gd, ri, name, shape, dim, ranks, crate::ir::DType::I64)
}

/// Column-shard a weight `W: [in, out]` across ranks (Megatron
/// column-parallel linear). Records `W = concat(W_r; dim=1)`.
pub fn col_shard_weight(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input(gd, ri, name, shape, shape.len() - 1, ranks)
}

/// Row-shard a weight `W: [in, out]` (row-parallel linear feeding an
/// all-reduce). Records `W = concat(W_r; dim=0)`.
pub fn row_shard_weight(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input(gd, ri, name, shape, shape.len() - 2, ranks)
}

/// Partition `[0, total)` into `ranks` balanced chunks; (start, end) per
/// rank. For uneven divisors the first `total % ranks` chunks are one
/// element longer, so the partition always covers `[0, total)` exactly,
/// without gaps or overlap (degenerate cases: `ranks > total` yields empty
/// trailing chunks; `total == 0` yields all-empty chunks).
pub fn chunks(total: i64, ranks: usize) -> Vec<(i64, i64)> {
    let r = ranks.max(1) as i64;
    let base = total / r;
    let rem = total % r;
    (0..r)
        .map(|i| {
            let lo = i * base + i.min(rem);
            let hi = lo + base + i64::from(i < rem);
            (lo, hi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_records_concat_mapping() {
        let mut gs = Graph::new("gs");
        gs.input("X", vec![8, 4]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let ids = shard_input(&mut gd, &mut ri, "X", &[8, 4], 0, 2).unwrap();
        assert_eq!(ids.len(), 2);
        assert_eq!(gd.shape(ids[0]), &[4, 4]);
        let rel = ri.finish(&gs, &gd).unwrap();
        assert!(rel.contains(gs.tensor_by_name("X").unwrap()));
    }

    #[test]
    fn uneven_shard_rejected() {
        // the Fig-5 "no size-6 for Llama-3" case
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        assert!(shard_input(&mut gd, &mut ri, "X", &[8, 4], 0, 6).is_err());
    }

    #[test]
    fn replicate_records_identity() {
        let mut gs = Graph::new("gs");
        gs.input("W", vec![4, 4]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        replicate_input(&mut gd, &mut ri, "W", &[4, 4]);
        let rel = ri.finish(&gs, &gd).unwrap();
        assert_eq!(rel.get(gs.tensor_by_name("W").unwrap()).len(), 1);
    }

    #[test]
    fn chunk_partition() {
        assert_eq!(chunks(8, 2), vec![(0, 4), (4, 8)]);
        assert_eq!(chunks(12, 3), vec![(0, 4), (4, 8), (8, 12)]);
    }

    #[test]
    fn chunk_partition_uneven_and_degenerate() {
        // uneven divisor: remainder spread over the leading chunks,
        // still covering [0, total)
        assert_eq!(chunks(7, 2), vec![(0, 4), (4, 7)]);
        assert_eq!(chunks(5, 3), vec![(0, 2), (2, 4), (4, 5)]);
        // single rank
        assert_eq!(chunks(9, 1), vec![(0, 9)]);
        // more ranks than elements: trailing chunks empty, no overlap
        assert_eq!(chunks(2, 4), vec![(0, 1), (1, 2), (2, 2), (2, 2)]);
        // empty range
        assert_eq!(chunks(0, 3), vec![(0, 0), (0, 0), (0, 0)]);
        // ranks == 0 is clamped to one chunk instead of dividing by zero
        assert_eq!(chunks(4, 0), vec![(0, 4)]);
    }
}
