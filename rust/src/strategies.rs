//! Distribution-strategy primitives (paper §2.1).
//!
//! Model builders (`crate::models`) compose these helpers to produce the
//! distributed implementation `G_d` and its clean input relation `R_i` from
//! the same configuration that builds `G_s` — mirroring how Megatron/vLLM
//! implementers apply TP/SP/VP/EP/gradient-accumulation by hand. The
//! helpers keep `R_i` construction honest: every sharded or replicated
//! input records exactly the mapping a user of GraphGuard would write.

use crate::ir::{Graph, NodeId, Op, TensorId};
use crate::relation::Relation;
use crate::util::json::Json;
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// Which strategies a distributed variant applies (Table 2's third column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Tensor parallelism: shard weight matrices, all-reduce partials.
    TP,
    /// Sequence parallelism: shard activations along the sequence dim.
    SP,
    /// Vocabulary parallelism: shard the LM head over the vocab dim.
    VP,
    /// Expert parallelism: shard MoE experts across ranks.
    EP,
    /// Gradient accumulation: split the batch into microbatches.
    GradAccum,
    /// Pipeline parallelism: stage-split the layer chain with send/recv
    /// boundaries and micro-batch loop unrolling.
    PP,
    /// ZeRO-3/FSDP: parameters stored 1/R-sharded, all-gathered before use.
    FSDP,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::TP => "tp",
            Strategy::SP => "sp",
            Strategy::VP => "vp",
            Strategy::EP => "ep",
            Strategy::GradAccum => "grad_accum",
            Strategy::PP => "pp",
            Strategy::FSDP => "fsdp",
        }
    }
}

/// Collects the clean input relation while the distributed graph is built.
#[derive(Debug, Default)]
pub struct RiBuilder {
    entries: BTreeMap<String, Vec<String>>,
}

impl RiBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn map(&mut self, gs_name: &str, expr: String) {
        self.entries.entry(gs_name.to_string()).or_default().push(expr);
    }

    pub fn finish(self, gs: &Graph, gd: &Graph) -> Result<Relation> {
        let obj = Json::Obj(
            self.entries
                .into_iter()
                .map(|(k, v)| (k, Json::Arr(v.into_iter().map(Json::Str).collect())))
                .collect(),
        );
        let rel = Relation::from_json(&obj, gs, gd)?;
        rel.validate_shapes(gs, gd)?;
        Ok(rel)
    }
}

/// Declare a `G_s` input sharded along `dim` across `ranks`; returns the
/// per-rank `G_d` input ids and records `name = concat(name_r0.., dim)`.
pub fn shard_input(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dim: usize,
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input_typed(gd, ri, name, shape, dim, ranks, crate::ir::DType::F32)
}

#[allow(clippy::too_many_arguments)]
pub fn shard_input_typed(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dim: usize,
    ranks: usize,
    dtype: crate::ir::DType,
) -> Result<Vec<TensorId>> {
    ensure!(dim < shape.len(), "shard dim {dim} of {shape:?}");
    ensure!(
        shape[dim] % ranks as i64 == 0,
        "dim {} of '{}' ({}) not divisible by {} ranks",
        dim,
        name,
        shape[dim],
        ranks
    );
    let mut part = shape.to_vec();
    part[dim] /= ranks as i64;
    let mut ids = Vec::with_capacity(ranks);
    let mut names = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let rname = format!("{name}_r{r}");
        ids.push(gd.input_typed(&rname, part.clone(), dtype));
        names.push(rname);
    }
    ri.map(name, format!("concat({}; dim={dim})", names.join(", ")));
    Ok(ids)
}

/// Declare a `G_s` input replicated on every rank. In single-program
/// capture replicas are one tensor; we declare one `G_d` input and record
/// the identity mapping.
pub fn replicate_input(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
) -> TensorId {
    replicate_input_typed(gd, ri, name, shape, crate::ir::DType::F32)
}

pub fn replicate_input_typed(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dtype: crate::ir::DType,
) -> TensorId {
    let rname = format!("{name}_rep");
    let id = gd.input_typed(&rname, shape.to_vec(), dtype);
    ri.map(name, rname);
    id
}

/// Integer-typed shard (token ids under sequence parallelism).
pub fn shard_input_ids(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    dim: usize,
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input_typed(gd, ri, name, shape, dim, ranks, crate::ir::DType::I64)
}

/// Column-shard a weight `W: [in, out]` across ranks (Megatron
/// column-parallel linear). Records `W = concat(W_r; dim=1)`.
pub fn col_shard_weight(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input(gd, ri, name, shape, shape.len() - 1, ranks)
}

/// Row-shard a weight `W: [in, out]` (row-parallel linear feeding an
/// all-reduce). Records `W = concat(W_r; dim=0)`.
pub fn row_shard_weight(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    shape: &[i64],
    ranks: usize,
) -> Result<Vec<TensorId>> {
    shard_input(gd, ri, name, shape, shape.len() - 2, ranks)
}

/// Layer indices after which a pipeline stage boundary falls: the
/// exclusive ends of every stage's contiguous layer group except the last
/// (a `chunks` partition of the layer range). Shared by the GPT and Llama
/// PP builders so boundary placement cannot drift between models.
pub fn stage_ends(layers: usize, stages: usize) -> Vec<usize> {
    chunks(layers as i64, stages)
        .iter()
        .take(stages.saturating_sub(1))
        .map(|&(_, hi)| hi as usize)
        .collect()
}

/// Insert a pipeline stage boundary: `send` then `recv` on channel `chan`.
/// Node names are `{base}_send` / `{base}_recv`. Returns the received
/// tensor — semantically the value unchanged, but only provably so when the
/// two channel tags match (`recv_of_send_identity`).
pub fn stage_boundary(g: &mut Graph, base: &str, x: TensorId, chan: usize) -> TensorId {
    let sent = g.op(&format!("{base}_send"), Op::Send { chan }, vec![x]);
    g.op(&format!("{base}_recv"), Op::Recv { chan }, vec![sent])
}

/// ZeRO-3/FSDP parameter: stored 1/R-sharded along dim 0 (per-rank inputs
/// `{name}_r{r}`, `R_i` records `name = concat(...; dim=0)`), all-gathered
/// into the full weight before use. Returns the gathered tensor; the
/// `{gather_name}` node is the site stale-shard bugs corrupt.
pub fn fsdp_shard_params(
    gd: &mut Graph,
    ri: &mut RiBuilder,
    name: &str,
    gather_name: &str,
    shape: &[i64],
    ranks: usize,
) -> Result<TensorId> {
    ensure!(!shape.is_empty(), "cannot FSDP-shard scalar param '{name}'");
    let shards = shard_input(gd, ri, name, shape, 0, ranks)?;
    Ok(gd.all_gather(gather_name, shards, 0))
}

/// Derive a ZeRO-3/FSDP implementation from a sequential graph: every
/// input `is_param` classifies as a parameter is stored 1/R-sharded along
/// dim 0 and re-gathered before use (the gather node is named by
/// `gather_name`), every other input is replicated, and all compute is
/// mirrored node-for-node — so the FSDP variant can never drift from the
/// sequential builder it derives from.
pub fn fsdp_from_seq(
    gs: &Graph,
    ranks: usize,
    is_param: &dyn Fn(&str) -> bool,
    gather_name: &dyn Fn(&str) -> String,
) -> Result<(Graph, Relation)> {
    let mut gd = Graph::new(format!("{}_fsdp", gs.name));
    let mut ri = RiBuilder::new();
    let mut val: Vec<Option<TensorId>> = vec![None; gs.num_tensors()];
    // Two passes: declare every stored shard first, then add the gather
    // nodes. With gathers interleaved into the declaration loop, the
    // *first* parameter's gather would precede every other shard and the
    // stale-shard bug family could never target it.
    let mut pending_gathers: Vec<(TensorId, String, Vec<TensorId>)> = Vec::new();
    for &i in &gs.inputs {
        let t = gs.tensor(i);
        if is_param(&t.name) {
            ensure!(
                !t.shape.is_empty(),
                "cannot FSDP-shard scalar param '{}'",
                t.name
            );
            let shards = shard_input(&mut gd, &mut ri, &t.name, &t.shape, 0, ranks)?;
            pending_gathers.push((i, gather_name(&t.name), shards));
        } else {
            val[i as usize] =
                Some(replicate_input_typed(&mut gd, &mut ri, &t.name, &t.shape, t.dtype));
        }
    }
    for (i, name, shards) in pending_gathers {
        val[i as usize] = Some(gd.all_gather(&name, shards, 0));
    }
    for nid in gs.topo_order() {
        let node = gs.node(nid);
        let ins: Vec<TensorId> =
            node.inputs.iter().map(|&t| val[t as usize].expect("topo order")).collect();
        let out = gd.add(&node.name, node.op.clone(), ins)?;
        val[node.output as usize] = Some(out);
    }
    for &o in &gs.outputs {
        gd.mark_output(val[o as usize].expect("outputs computed"));
    }
    let rel = ri.finish(gs, &gd)?;
    gd.validate()?;
    Ok((gd, rel))
}

/// Derive an expert-parallel (EP) implementation from a sequential MoE
/// graph: every input is replicated (in single-program capture each
/// expert's weights simply live on their owning rank), all compute is
/// mirrored node-for-node, and every `combine` node is split into per-rank
/// *partial combines* — rank `r` combines its own contiguous expert slice
/// of the router weights (`slice(w; dim=1, r·E/R, (r+1)·E/R)`, node
/// `{name}_w_r{r}`) with its local experts' outputs (`{name}_r{r}`), and an
/// all-reduce (`{name}_ar`) merges the partials. Verification closes the
/// loop through `allreduce_is_sum` + `combine_of_disjoint_expert_slices`:
/// the sum of partial combines over disjoint, covering expert slices *is*
/// the sequential combine, conditioned on the shared router tensor.
pub fn moe_from_seq(gs: &Graph, ranks: usize) -> Result<(Graph, Relation)> {
    ensure!(ranks >= 2, "expert parallelism needs at least 2 ranks");
    let mut gd = Graph::new(format!("{}_ep", gs.name));
    let mut ri = RiBuilder::new();
    let mut val: Vec<Option<TensorId>> = vec![None; gs.num_tensors()];
    for &i in &gs.inputs {
        let t = gs.tensor(i);
        val[i as usize] =
            Some(replicate_input_typed(&mut gd, &mut ri, &t.name, &t.shape, t.dtype));
    }
    let mut any_combine = false;
    for nid in gs.topo_order() {
        let node = gs.node(nid);
        let ins: Vec<TensorId> =
            node.inputs.iter().map(|&t| val[t as usize].expect("topo order")).collect();
        let out = match &node.op {
            Op::Combine { experts } => {
                ensure!(
                    experts % ranks == 0,
                    "combine '{}': {} experts not divisible by {} ranks",
                    node.name,
                    experts,
                    ranks
                );
                any_combine = true;
                let epr = experts / ranks;
                let w = ins[0];
                let mut partials = Vec::with_capacity(ranks);
                for r in 0..ranks {
                    let wr = gd.slice(
                        &format!("{}_w_r{r}", node.name),
                        w,
                        1,
                        (r * epr) as i64,
                        ((r + 1) * epr) as i64,
                    );
                    let mut args = Vec::with_capacity(epr + 1);
                    args.push(wr);
                    args.extend_from_slice(&ins[1 + r * epr..1 + (r + 1) * epr]);
                    partials.push(gd.add(
                        &format!("{}_r{r}", node.name),
                        Op::Combine { experts: epr },
                        args,
                    )?);
                }
                gd.all_reduce(&format!("{}_ar", node.name), partials)
            }
            _ => gd.add(&node.name, node.op.clone(), ins)?,
        };
        val[node.output as usize] = Some(out);
    }
    ensure!(any_combine, "moe_from_seq: sequential graph has no combine node to expert-shard");
    for &o in &gs.outputs {
        gd.mark_output(val[o as usize].expect("outputs computed"));
    }
    let rel = ri.finish(gs, &gd)?;
    gd.validate()?;
    Ok((gd, rel))
}

/// Cut a sequential chain into pipeline stages with micro-batch loop
/// unrolling: the primary input (`gs.inputs[0]`) is split into `micro`
/// micro-batches along dim 0, every other input is replicated as a
/// parameter, each `G_s` operator is unrolled once per micro-batch, and the
/// output of every node in `cuts` crosses a stage boundary through a
/// send/recv pair on its own channel (one channel per boundary ×
/// micro-batch — exactly the wiring a 1F1B schedule's buffers realize).
///
/// Per-micro-batch node names are `{orig}_mb{m}`; the final gather is
/// `out_name`. Only row-decomposable operators are supported (elementwise,
/// matmul against replicated weights, row-wise softmax, RMS/LayerNorm,
/// RoPE with tables sliced per micro-batch, embedding of micro-batched ids
/// against a replicated table); anything that mixes rows across
/// micro-batches (attention, transposes, reductions over dim 0) is
/// rejected rather than silently mis-split.
pub fn pipeline_stage_split(
    gs: &Graph,
    cuts: &[NodeId],
    micro: usize,
    out_name: &str,
) -> Result<(Graph, Relation)> {
    ensure!(micro >= 1, "micro-batch count must be >= 1");
    ensure!(gs.outputs.len() == 1, "pipeline split expects a single-output chain");
    let primary = *gs
        .inputs
        .first()
        .ok_or_else(|| anyhow::anyhow!("pipeline split needs a primary input"))?;
    let full = gs.shape(primary).to_vec();
    ensure!(!full.is_empty(), "primary input '{}' is scalar", gs.tensor(primary).name);
    ensure!(
        full[0] % micro as i64 == 0,
        "batch dim {} of '{}' not divisible by {} micro-batches",
        full[0],
        gs.tensor(primary).name,
        micro
    );
    for &c in cuts {
        ensure!((c as usize) < gs.num_nodes(), "stage cut at nonexistent node {c}");
    }
    let offs = chunks(full[0], micro);

    let mut gd = Graph::new(format!("{}_pp", gs.name));
    let mut ri = RiBuilder::new();
    // primary input micro-batched; every other input replicated up front
    let prim_name = gs.tensor(primary).name.clone();
    let mb_inputs = shard_input_typed(
        &mut gd,
        &mut ri,
        &prim_name,
        &full,
        0,
        micro,
        gs.tensor(primary).dtype,
    )?;
    let mut rep_val: Vec<Option<TensorId>> = vec![None; gs.num_tensors()];
    for &i in &gs.inputs {
        if i == primary {
            continue;
        }
        let t = gs.tensor(i);
        rep_val[i as usize] =
            Some(replicate_input_typed(&mut gd, &mut ri, &t.name, &t.shape, t.dtype));
    }

    let mut outs = Vec::with_capacity(micro);
    for m in 0..micro {
        // per-micro-batch values of microbatched gs tensors
        let mut mb_val: Vec<Option<TensorId>> = vec![None; gs.num_tensors()];
        mb_val[primary as usize] = Some(mb_inputs[m]);
        for nid in gs.topo_order() {
            let node = gs.node(nid);
            let name = format!("{}_mb{m}", node.name);
            let any_mb = node.inputs.iter().any(|&t| mb_val[t as usize].is_some());
            let out = if !any_mb {
                // a cut here would silently emit no boundary — reject it
                ensure!(
                    !cuts.contains(&nid),
                    "stage cut at '{}', which is not micro-batched (pure parameter compute)",
                    node.name
                );
                // pure parameter compute: shared across micro-batches
                if m == 0 {
                    let ins: Vec<TensorId> = node
                        .inputs
                        .iter()
                        .map(|&t| rep_val[t as usize].expect("topo order"))
                        .collect();
                    let o = gd.add(&node.name, node.op.clone(), ins)?;
                    rep_val[node.output as usize] = Some(o);
                }
                continue;
            } else {
                build_pp_node(&mut gd, gs, node, &name, m, &mb_val, &rep_val, &offs, &full)?
            };
            // stage boundary after this node?
            let out = if let Some(boundary) = cuts.iter().position(|&c| c == nid) {
                stage_boundary(&mut gd, &name, out, boundary * micro + m)
            } else {
                out
            };
            mb_val[node.output as usize] = Some(out);
        }
        let o = gs.outputs[0];
        let Some(mb_out) = mb_val[o as usize] else {
            bail!(
                "pipeline split: output '{}' is not micro-batched (pure parameter chain)",
                gs.tensor(o).name
            );
        };
        outs.push(mb_out);
    }
    let gathered = gd.concat(out_name, outs, 0);
    gd.mark_output(gathered);
    let rel = ri.finish(gs, &gd)?;
    gd.validate()?;
    Ok((gd, rel))
}

/// Build one micro-batched copy of a `G_s` node. `mb_val` holds this
/// micro-batch's values, `rep_val` the replicated (shared) tensors.
#[allow(clippy::too_many_arguments)]
fn build_pp_node(
    gd: &mut Graph,
    gs: &Graph,
    node: &crate::ir::Node,
    name: &str,
    m: usize,
    mb_val: &[Option<TensorId>],
    rep_val: &[Option<TensorId>],
    offs: &[(i64, i64)],
    full: &[i64],
) -> Result<TensorId> {
    let mb = |t: TensorId| mb_val[t as usize];
    let rep = |t: TensorId| -> Result<TensorId> {
        rep_val[t as usize]
            .ok_or_else(|| anyhow::anyhow!("tensor '{}' unavailable", gs.tensor(t).name))
    };
    let (lo, hi) = offs[m];
    let op = &node.op;
    if op.is_unary_elementwise() {
        let x = mb(node.inputs[0])
            .ok_or_else(|| anyhow::anyhow!("unary '{}' on non-micro-batched input", node.name))?;
        return gd.add(name, op.clone(), vec![x]);
    }
    if op.is_binary_elementwise() {
        let out_shape = gs.shape(node.output);
        let mut ins = Vec::with_capacity(2);
        for (j, &t) in node.inputs.iter().enumerate() {
            let v = match mb(t) {
                Some(v) => v,
                None => {
                    let r = rep(t)?;
                    if gs.shape(t) == out_shape {
                        // row-aligned operand: slice this micro-batch's rows
                        gd.slice(&format!("{name}_in{j}"), r, 0, lo, hi)
                    } else if gs.shape(t).first() == Some(&full[0]) {
                        bail!(
                            "pipeline split: operand '{}' of '{}' is row-aligned but not \
                             shape-aligned — unsupported broadcast",
                            gs.tensor(t).name,
                            node.name
                        );
                    } else {
                        r // trailing-dim broadcast is row-independent
                    }
                }
            };
            ins.push(v);
        }
        return gd.add(name, op.clone(), ins);
    }
    match op {
        Op::MatMul => {
            let x = mb(node.inputs[0]).ok_or_else(|| {
                anyhow::anyhow!("matmul '{}' LHS must be micro-batched", node.name)
            })?;
            ensure!(
                mb(node.inputs[1]).is_none(),
                "pipeline split: matmul '{}' with micro-batched RHS mixes rows",
                node.name
            );
            let w = rep(node.inputs[1])?;
            gd.add(name, Op::MatMul, vec![x, w])
        }
        Op::Softmax { dim } if *dim != 0 => {
            let x = mb(node.inputs[0])
                .ok_or_else(|| anyhow::anyhow!("softmax '{}' input not micro-batched", node.name))?;
            gd.add(name, op.clone(), vec![x])
        }
        Op::RmsNorm { .. } | Op::LayerNorm { .. } => {
            let x = mb(node.inputs[0])
                .ok_or_else(|| anyhow::anyhow!("norm '{}' input not micro-batched", node.name))?;
            let mut ins = vec![x];
            for &t in &node.inputs[1..] {
                ins.push(rep(t)?);
            }
            gd.add(name, op.clone(), ins)
        }
        Op::Rope => {
            let x = mb(node.inputs[0])
                .ok_or_else(|| anyhow::anyhow!("rope '{}' input not micro-batched", node.name))?;
            let cos = rep(node.inputs[1])?;
            let sin = rep(node.inputs[2])?;
            let cs = gd.slice(&format!("{name}_cos"), cos, 0, lo, hi);
            let sn = gd.slice(&format!("{name}_sin"), sin, 0, lo, hi);
            gd.add(name, Op::Rope, vec![x, cs, sn])
        }
        Op::Embedding => {
            // row gather: output rows track the ids rows, so micro-batching
            // the ids (against a replicated table) is row-exact
            ensure!(
                mb(node.inputs[0]).is_none(),
                "pipeline split: embedding '{}' with micro-batched table mixes rows",
                node.name
            );
            let table = rep(node.inputs[0])?;
            let ids = mb(node.inputs[1]).ok_or_else(|| {
                anyhow::anyhow!("embedding '{}' ids must be micro-batched", node.name)
            })?;
            gd.add(name, Op::Embedding, vec![table, ids])
        }
        other => bail!(
            "pipeline split: operator '{}' ({other}) mixes rows across micro-batches",
            node.name
        ),
    }
}

/// [`pipeline_stage_split`] composed with the schedule-aware buffer
/// lowering: cut the chain, then re-tag every per-(boundary × micro-batch)
/// logical channel with its `(boundary, slot, epoch)` physical-buffer tag
/// under `sched` and a per-boundary pool of `depth` activation buffers. A
/// (schedule, depth) combination with a slot-liveness hazard is rejected at
/// construction (see `crate::schedule::lower_buffers`). The relation is
/// untouched: lowering only renames channels, never tensors.
pub fn pipeline_stage_split_scheduled(
    gs: &Graph,
    cuts: &[NodeId],
    out_name: &str,
    sched: &crate::schedule::Schedule,
    depth: usize,
) -> Result<(Graph, Relation)> {
    ensure!(
        cuts.len() == sched.boundaries(),
        "schedule expects {} stage boundaries ({} chunks), got {} cuts",
        sched.boundaries(),
        sched.chunks(),
        cuts.len()
    );
    let (gd, ri) = pipeline_stage_split(gs, cuts, sched.micro, out_name)?;
    let lowered = crate::schedule::lower_buffers(&gd, sched, depth)?;
    Ok((lowered, ri))
}

/// Partition `[0, total)` into `ranks` balanced chunks; (start, end) per
/// rank. For uneven divisors the first `total % ranks` chunks are one
/// element longer, so the partition always covers `[0, total)` exactly,
/// without gaps or overlap (degenerate cases: `ranks > total` yields empty
/// trailing chunks; `total == 0` yields all-empty chunks).
pub fn chunks(total: i64, ranks: usize) -> Vec<(i64, i64)> {
    let r = ranks.max(1) as i64;
    let base = total / r;
    let rem = total % r;
    (0..r)
        .map(|i| {
            let lo = i * base + i.min(rem);
            let hi = lo + base + i64::from(i < rem);
            (lo, hi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_records_concat_mapping() {
        let mut gs = Graph::new("gs");
        gs.input("X", vec![8, 4]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let ids = shard_input(&mut gd, &mut ri, "X", &[8, 4], 0, 2).unwrap();
        assert_eq!(ids.len(), 2);
        assert_eq!(gd.shape(ids[0]), &[4, 4]);
        let rel = ri.finish(&gs, &gd).unwrap();
        assert!(rel.contains(gs.tensor_by_name("X").unwrap()));
    }

    #[test]
    fn uneven_shard_rejected() {
        // the Fig-5 "no size-6 for Llama-3" case
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        assert!(shard_input(&mut gd, &mut ri, "X", &[8, 4], 0, 6).is_err());
    }

    #[test]
    fn replicate_records_identity() {
        let mut gs = Graph::new("gs");
        gs.input("W", vec![4, 4]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        replicate_input(&mut gd, &mut ri, "W", &[4, 4]);
        let rel = ri.finish(&gs, &gd).unwrap();
        assert_eq!(rel.get(gs.tensor_by_name("W").unwrap()).len(), 1);
    }

    fn pp_chain() -> Graph {
        let mut gs = Graph::new("chain");
        let x = gs.input("x", vec![4, 4]);
        let w = gs.input("w", vec![4, 4]);
        let mm = gs.matmul("b0_mm", x, w);
        let act = gs.op("b1_act", Op::Gelu, vec![mm]);
        gs.mark_output(act);
        gs
    }

    #[test]
    fn pipeline_split_builds_boundaries_and_matches_numerically() {
        let gs = pp_chain();
        // cut after the matmul (node 0), 2 micro-batches
        let (gd, ri) = pipeline_stage_split(&gs, &[0], 2, "b2_out").unwrap();
        gd.validate().unwrap();
        ri.validate_shapes(&gs, &gd).unwrap();
        let sends: Vec<_> = gd
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::Send { .. }))
            .map(|n| n.name.clone())
            .collect();
        assert_eq!(sends, vec!["b0_mm_mb0_send", "b0_mm_mb1_send"]);
        // distinct channel per (boundary, micro-batch)
        let chans: Vec<usize> = gd
            .nodes()
            .iter()
            .filter_map(|n| match n.op {
                Op::Send { chan } => Some(chan),
                _ => None,
            })
            .collect();
        assert_eq!(chans, vec![0, 1]);

        // numeric: gathered G_d output == G_s output on R_i-consistent inputs
        use crate::expr::eval::eval_graph;
        use crate::util::ndarray::NdArray;
        let mut rng = crate::util::rng::Rng::new(9);
        let full = NdArray::new(vec![4, 4], rng.buf(16, 1.0)).unwrap();
        let w = NdArray::new(vec![4, 4], rng.buf(16, 1.0)).unwrap();
        let mut gs_in = rustc_hash::FxHashMap::default();
        gs_in.insert(gs.tensor_by_name("x").unwrap(), full.clone());
        gs_in.insert(gs.tensor_by_name("w").unwrap(), w.clone());
        let mut gd_in = rustc_hash::FxHashMap::default();
        gd_in.insert(gd.tensor_by_name("x_r0").unwrap(), full.slice(0, 0, 2).unwrap());
        gd_in.insert(gd.tensor_by_name("x_r1").unwrap(), full.slice(0, 2, 4).unwrap());
        gd_in.insert(gd.tensor_by_name("w_rep").unwrap(), w);
        let a = eval_graph(&gs, &gs_in).unwrap();
        let b = eval_graph(&gd, &gd_in).unwrap();
        assert!(a[gs.outputs[0] as usize].allclose(&b[gd.outputs[0] as usize], 1e-5, 1e-6));
    }

    #[test]
    fn pipeline_split_micro_batches_embedding_ids() {
        // embedding = row gather: ids micro-batch, table replicated
        let mut gs = Graph::new("emb_chain");
        let ids = gs.input_typed("ids", vec![4], crate::ir::DType::I64);
        let table = gs.input("wte", vec![16, 4]);
        let emb = gs.op("b0_emb", Op::Embedding, vec![table, ids]);
        let act = gs.op("b1_act", Op::Gelu, vec![emb]);
        gs.mark_output(act);
        let (gd, ri) = pipeline_stage_split(&gs, &[0], 2, "b2_out").unwrap();
        gd.validate().unwrap();
        ri.validate_shapes(&gs, &gd).unwrap();
        assert!(gd.tensor_by_name("b0_emb_mb0").is_some());
        assert_eq!(gd.shape(gd.tensor_by_name("b0_emb_mb1").unwrap()), &[2, 4]);
    }

    #[test]
    fn scheduled_split_checks_boundary_count() {
        let gs = pp_chain();
        // 1 cut but an interleaved 2x2 schedule expects 3 boundaries
        let sched = crate::schedule::Schedule::interleaved(2, 2, 2);
        assert!(pipeline_stage_split_scheduled(&gs, &[0], "out", &sched, 2).is_err());
        // matching dimensions lower cleanly and stay numerics-identical
        let sched = crate::schedule::Schedule::gpipe(2, 2);
        let (gd, _ri) = pipeline_stage_split_scheduled(&gs, &[0], "b2_out", &sched, 2).unwrap();
        gd.validate().unwrap();
        assert!(gd.nodes().iter().all(|n| match n.op {
            Op::Send { chan } | Op::Recv { chan } =>
                crate::schedule::decode_buffer_tag(chan).is_some(),
            _ => true,
        }));
    }

    #[test]
    fn pipeline_split_rejects_row_mixing_ops() {
        // transpose mixes rows across micro-batches — must be rejected
        let mut gs = Graph::new("bad");
        let x = gs.input("x", vec![4, 4]);
        let t = gs.transpose("t", x, vec![1, 0]);
        gs.mark_output(t);
        assert!(pipeline_stage_split(&gs, &[], 2, "out").is_err());
    }

    #[test]
    fn pipeline_split_rejects_indivisible_microbatching() {
        let gs = pp_chain();
        assert!(pipeline_stage_split(&gs, &[0], 3, "out").is_err());
    }

    #[test]
    fn fsdp_param_gathers_to_full_shape() {
        let mut gs = Graph::new("gs");
        gs.input("W", vec![8, 4]);
        let mut gd = Graph::new("gd");
        let mut ri = RiBuilder::new();
        let w = fsdp_shard_params(&mut gd, &mut ri, "W", "W_ag", &[8, 4], 4).unwrap();
        assert_eq!(gd.shape(w), &[8, 4]);
        assert_eq!(gd.inputs.len(), 4);
        let rel = ri.finish(&gs, &gd).unwrap();
        assert!(rel.contains(gs.tensor_by_name("W").unwrap()));
        // indivisible storage dim rejected (the Fig-5 hole, FSDP flavor)
        let mut gd2 = Graph::new("gd2");
        let mut ri2 = RiBuilder::new();
        assert!(fsdp_shard_params(&mut gd2, &mut ri2, "W", "W_ag", &[9, 4], 4).is_err());
    }

    fn moe_chain() -> Graph {
        // x -> router -> top-1 mask -> per-expert dispatch/identity -> combine
        let mut gs = Graph::new("moe");
        let x = gs.input("x", vec![4, 4]);
        let wg = gs.input("wg", vec![4, 4]);
        let scores = gs.matmul("b0_router", x, wg);
        let mask = gs.topk("b0_mask", scores, 1);
        let mut ys = Vec::new();
        for e in 0..4usize {
            let d = gs.dispatch(&format!("b0_disp{e}"), x, mask, e, 4);
            ys.push(gs.op(&format!("b0_e{e}_act"), Op::Gelu, vec![d]));
        }
        let out = gs.combine("b0_moe", mask, ys);
        gs.mark_output(out);
        gs
    }

    #[test]
    fn moe_from_seq_splits_combines_and_matches_numerically() {
        let gs = moe_chain();
        let (gd, ri) = moe_from_seq(&gs, 2).unwrap();
        gd.validate().unwrap();
        ri.validate_shapes(&gs, &gd).unwrap();
        // combine split into 2 partial combines + an all-reduce
        let partials = gd
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::Combine { experts: 2 }))
            .count();
        assert_eq!(partials, 2, "one partial combine per rank");
        assert!(gd.tensor_by_name("b0_moe_ar").is_some(), "all-reduce merges the partials");
        // numeric: replicated G_d inputs drive both graphs to equal outputs
        use crate::expr::eval::eval_graph;
        let gd_in = crate::expr::eval::random_inputs(&gd, 17);
        let mut gs_in = rustc_hash::FxHashMap::default();
        for &i in &gs.inputs {
            let name = format!("{}_rep", gs.tensor(i).name);
            let did = gd.tensor_by_name(&name).unwrap();
            gs_in.insert(i, gd_in[&did].clone());
        }
        let a = eval_graph(&gs, &gs_in).unwrap();
        let b = eval_graph(&gd, &gd_in).unwrap();
        assert!(
            a[gs.outputs[0] as usize].allclose(&b[gd.outputs[0] as usize], 1e-5, 1e-6),
            "partial-combine sum must equal the sequential combine"
        );
    }

    #[test]
    fn moe_from_seq_rejects_indivisible_or_combineless() {
        let gs = moe_chain();
        assert!(moe_from_seq(&gs, 3).is_err(), "4 experts % 3 ranks");
        assert!(moe_from_seq(&gs, 1).is_err(), "EP needs >= 2 ranks");
        let mut plain = Graph::new("plain");
        let x = plain.input("x", vec![4, 4]);
        let y = plain.op("y", Op::Gelu, vec![x]);
        plain.mark_output(y);
        assert!(moe_from_seq(&plain, 2).is_err(), "no combine to shard");
    }

    #[test]
    fn chunk_partition() {
        assert_eq!(chunks(8, 2), vec![(0, 4), (4, 8)]);
        assert_eq!(chunks(12, 3), vec![(0, 4), (4, 8), (8, 12)]);
    }

    #[test]
    fn chunk_partition_uneven_and_degenerate() {
        // uneven divisor: remainder spread over the leading chunks,
        // still covering [0, total)
        assert_eq!(chunks(7, 2), vec![(0, 4), (4, 7)]);
        assert_eq!(chunks(5, 3), vec![(0, 2), (2, 4), (4, 5)]);
        // single rank
        assert_eq!(chunks(9, 1), vec![(0, 9)]);
        // more ranks than elements: trailing chunks empty, no overlap
        assert_eq!(chunks(2, 4), vec![(0, 1), (1, 2), (2, 2), (2, 2)]);
        // empty range
        assert_eq!(chunks(0, 3), vec![(0, 0), (0, 0), (0, 0)]);
        // ranks == 0 is clamped to one chunk instead of dividing by zero
        assert_eq!(chunks(4, 0), vec![(0, 4)]);
    }
}
