//! Reverse-mode autodiff over the graph IR.
//!
//! The paper's ByteDance workload verifies forward *and backward* graphs
//! (§6.1); gradient-accumulation (bug 6) is likewise a backward-pass
//! property. This module mechanically extends a forward graph with its
//! gradient computation, mirroring what `jax.grad` does on the L2 side, so
//! Rust-built and Python-captured backward workloads agree.
//!
//! Supported op set covers the models that need backward graphs (regression,
//! transformer blocks with explicit norm composition). RoPE's VJP is
//! rotation by the negated angle — the exact structure in which §6.2's Bug 1
//! (wrong offset in a hand-written `backward`) lives.

use super::graph::{Graph, NodeId, TensorId};
use super::ops::{FBits, Op};
use anyhow::{bail, Result};
use rustc_hash::FxHashMap;

/// Extend `g` with gradient nodes of scalar `loss` w.r.t. `wrt`; the grads
/// are marked as extra outputs named `grad_<tensor>`. Returns the ids of the
/// gradient tensors, in `wrt` order.
pub fn append_backward(g: &mut Graph, loss: TensorId, wrt: &[TensorId]) -> Result<Vec<TensorId>> {
    if !g.shape(loss).is_empty() {
        bail!("loss '{}' must be scalar, got {:?}", g.tensor(loss).name, g.shape(loss));
    }
    // grad accumulators per tensor
    let mut grads: FxHashMap<TensorId, TensorId> = FxHashMap::default();
    let zero = g.scale("zero_seed", loss, 0.0);
    let seed = g.op("grad_seed", Op::AddScalar { c: FBits::new(1.0) }, vec![zero]);
    grads.insert(loss, seed);

    // walk forward nodes in reverse topological order
    let node_ids: Vec<NodeId> = g.topo_order().collect();
    for &nid in node_ids.iter().rev() {
        let node = g.node(nid).clone();
        let Some(&dz) = grads.get(&node.output) else { continue };
        let contribs = vjp(g, &node, dz)?;
        for (input, contrib) in node.inputs.iter().zip(contribs) {
            let Some(contrib) = contrib else { continue };
            // Broadcast-aware: reduce contribution back to the input's shape.
            let reduced = reduce_to_shape(g, contrib, &g.shape(*input).to_vec());
            match grads.get(&(*input)) {
                Some(&acc) => {
                    let name = format!("acc_grad_{}", g.tensor(*input).name);
                    let summed = g.op(&name, Op::SumN, vec![acc, reduced]);
                    grads.insert(*input, summed);
                }
                None => {
                    grads.insert(*input, reduced);
                }
            }
        }
    }

    let mut out = Vec::with_capacity(wrt.len());
    for &w in wrt {
        let gid = match grads.get(&w) {
            Some(&gid) => gid,
            None => bail!("no gradient path from loss to '{}'", g.tensor(w).name),
        };
        // name the gradient tensor for report readability
        let named = g.op(&format!("grad_{}", g.tensor(w).name), Op::Identity, vec![gid]);
        g.mark_output(named);
        out.push(named);
    }
    Ok(out)
}

/// Per-op vector-Jacobian products. Returns one optional gradient
/// contribution per input (None = not differentiable / no path, e.g. the
/// cos/sin tables of RoPE).
fn vjp(g: &mut Graph, node: &super::graph::Node, dz: TensorId) -> Result<Vec<Option<TensorId>>> {
    let x = |i: usize| node.inputs[i];
    let y = node.output;
    let n = &node.name;
    Ok(match &node.op {
        // stage-boundary transfers are identities; the backward pass sends
        // the gradient across the same boundary unchanged
        Op::Identity | Op::Send { .. } | Op::Recv { .. } => vec![Some(dz)],
        Op::Neg => vec![Some(g.op(&format!("d{n}"), Op::Neg, vec![dz]))],
        Op::Exp => vec![Some(g.mul2(&format!("d{n}"), dz, y))],
        Op::Log => vec![Some(g.op(&format!("d{n}"), Op::Div, vec![dz, x(0)]))],
        Op::Sqrt => {
            // d/dx sqrt(x) = 1/(2 sqrt(x)) = 0.5 / y
            let dy = g.op(&format!("d{n}_div"), Op::Div, vec![dz, y]);
            vec![Some(g.scale(&format!("d{n}"), dy, 0.5))]
        }
        Op::Rsqrt => {
            // d/dx x^{-1/2} = -0.5 x^{-3/2} = -0.5 y³
            let y2 = g.mul2(&format!("d{n}_y2"), y, y);
            let y3 = g.mul2(&format!("d{n}_y3"), y2, y);
            let t = g.mul2(&format!("d{n}_t"), dz, y3);
            vec![Some(g.scale(&format!("d{n}"), t, -0.5))]
        }
        Op::Square => {
            let t = g.mul2(&format!("d{n}_t"), dz, x(0));
            vec![Some(g.scale(&format!("d{n}"), t, 2.0))]
        }
        Op::Tanh => {
            // 1 - y²
            let y2 = g.mul2(&format!("d{n}_y2"), y, y);
            let ny2 = g.op(&format!("d{n}_ny2"), Op::Neg, vec![y2]);
            let one_m = g.op(&format!("d{n}_1m"), Op::AddScalar { c: FBits::new(1.0) }, vec![ny2]);
            vec![Some(g.mul2(&format!("d{n}"), dz, one_m))]
        }
        Op::Sigmoid => {
            // y (1 - y)
            let ny = g.op(&format!("d{n}_ny"), Op::Neg, vec![y]);
            let om = g.op(&format!("d{n}_om"), Op::AddScalar { c: FBits::new(1.0) }, vec![ny]);
            let t = g.mul2(&format!("d{n}_t"), y, om);
            vec![Some(g.mul2(&format!("d{n}"), dz, t))]
        }
        Op::Silu => {
            // d silu = sigmoid(x) (1 + x (1 - sigmoid(x)))
            let s = g.op(&format!("d{n}_s"), Op::Sigmoid, vec![x(0)]);
            let ns = g.op(&format!("d{n}_ns"), Op::Neg, vec![s]);
            let om = g.op(&format!("d{n}_om"), Op::AddScalar { c: FBits::new(1.0) }, vec![ns]);
            let xom = g.mul2(&format!("d{n}_xom"), x(0), om);
            let inner = g.op(&format!("d{n}_in"), Op::AddScalar { c: FBits::new(1.0) }, vec![xom]);
            let t = g.mul2(&format!("d{n}_t"), s, inner);
            vec![Some(g.mul2(&format!("d{n}"), dz, t))]
        }
        Op::Scale { c } => vec![Some(g.scale(&format!("d{n}"), dz, c.get()))],
        Op::AddScalar { .. } => vec![Some(dz)],
        Op::Add => vec![Some(dz), Some(dz)],
        Op::Sub => vec![Some(dz), Some(g.op(&format!("d{n}_neg"), Op::Neg, vec![dz]))],
        Op::Mul => vec![
            Some(g.mul2(&format!("d{n}_a"), dz, x(1))),
            Some(g.mul2(&format!("d{n}_b"), dz, x(0))),
        ],
        Op::Div => {
            let da = g.op(&format!("d{n}_a"), Op::Div, vec![dz, x(1)]);
            let q = g.op(&format!("d{n}_q"), Op::Div, vec![y, x(1)]);
            let t = g.mul2(&format!("d{n}_t"), dz, q);
            let db = g.op(&format!("d{n}_b"), Op::Neg, vec![t]);
            vec![Some(da), Some(db)]
        }
        Op::SumN => vec![Some(dz); node.inputs.len()],
        Op::MatMul => {
            // da = dz @ bᵀ ; db = aᵀ @ dz  (transpose of last two dims)
            let bt = transpose_last2(g, &format!("d{n}_bt"), x(1));
            let at = transpose_last2(g, &format!("d{n}_at"), x(0));
            vec![
                Some(g.matmul(&format!("d{n}_a"), dz, bt)),
                Some(g.matmul(&format!("d{n}_b"), at, dz)),
            ]
        }
        Op::Transpose { perm } => {
            let mut inv = vec![0usize; perm.len()];
            for (i, &p) in perm.iter().enumerate() {
                inv[p] = i;
            }
            vec![Some(g.transpose(&format!("d{n}"), dz, inv))]
        }
        Op::Slice { dim, start, end } => {
            let size = g.shape(x(0))[*dim];
            let (s, e) = (start.expect_const(), end.expect_const());
            let padded = g.op(
                &format!("d{n}"),
                Op::Pad { dim: *dim, before: s.into(), after: (size - e).into(), value: FBits::new(0.0) },
                vec![dz],
            );
            vec![Some(padded)]
        }
        Op::Concat { dim } => {
            let mut offset = 0i64;
            let mut out = Vec::new();
            for &inp in &node.inputs {
                let len = g.shape(inp)[*dim];
                out.push(Some(g.slice(&format!("d{n}_part"), dz, *dim, offset, offset + len)));
                offset += len;
            }
            out
        }
        Op::Pad { dim, before, after, .. } => {
            let padded_len = g.shape(y)[*dim];
            let (b, a) = (before.expect_const(), after.expect_const());
            vec![Some(g.slice(&format!("d{n}"), dz, *dim, b, padded_len - a))]
        }
        Op::ReduceSum { dim, keepdim } => {
            vec![Some(expand_reduced(g, &format!("d{n}"), dz, x(0), *dim, *keepdim))]
        }
        Op::ReduceMean { dim, keepdim } => {
            let nelem = g.shape(x(0))[*dim] as f64;
            let e = expand_reduced(g, &format!("d{n}_e"), dz, x(0), *dim, *keepdim);
            vec![Some(g.scale(&format!("d{n}"), e, 1.0 / nelem))]
        }
        Op::Softmax { dim } => {
            // dx = (dz - sum(dz*y, dim, keep)) * y
            let dzy = g.mul2(&format!("d{n}_dzy"), dz, y);
            let s = g.op(&format!("d{n}_s"), Op::ReduceSum { dim: *dim, keepdim: true }, vec![dzy]);
            let diff = g.sub2(&format!("d{n}_diff"), dz, s);
            vec![Some(g.mul2(&format!("d{n}"), diff, y))]
        }
        Op::MseLoss => {
            // d/dp mean((p-t)²) = 2 (p - t)/N · dz. The 2/N factor is folded
            // into the (scalar) upstream gradient, not the diff tensor, so
            // the diff intermediate is identical between a full-batch graph
            // and its microbatched refinement (gradient accumulation): the
            // per-graph N and the loss rescaling meet in one scalar chain
            // that scale-fusion lemmas canonicalize.
            let nelem: i64 = g.shape(x(0)).iter().product();
            let diff = g.sub2(&format!("d{n}_diff"), x(0), x(1));
            let dzc = g.scale(&format!("d{n}_dzc"), dz, 2.0 / nelem as f64);
            let dp = g.mul2(&format!("d{n}_p"), diff, dzc);
            let dt = g.op(&format!("d{n}_t"), Op::Neg, vec![dp]);
            vec![Some(dp), Some(dt)]
        }
        Op::Rope => {
            // out = x·cos + rot(x)·sin with rot(v) = (-v₂, v₁). The adjoint
            // of rot is rotᵀ(u) = (u₂, -u₁), so dx = dz·cos + rotᵀ(dz·sin).
            let last = g.shape(y).len() - 1;
            let d = *g.shape(y).last().unwrap();
            let m = g.mul2(&format!("d{n}_m"), dz, x(2));
            let m1 = g.slice(&format!("d{n}_m1"), m, last, 0, d / 2);
            let m2 = g.slice(&format!("d{n}_m2"), m, last, d / 2, d);
            let nm1 = g.op(&format!("d{n}_nm1"), Op::Neg, vec![m1]);
            let rt = g.concat(&format!("d{n}_rt"), vec![m2, nm1], last);
            let c = g.mul2(&format!("d{n}_c"), dz, x(1));
            let dx = g.add2(&format!("d{n}"), c, rt);
            vec![Some(dx), None, None]
        }
        Op::AllReduce { .. } => vec![Some(dz); node.inputs.len()],
        // The routing mask is piecewise-constant: no gradient flows into the
        // scores (matching the straight-through-free treatment of hard
        // routing), so TopK contributes nothing.
        Op::TopK { .. } => vec![None],
        Op::Dispatch { expert, capacity } => {
            // masking by the router column is self-adjoint: dx is the same
            // dispatch applied to dz; the router gets no gradient (0/1 mask)
            let dx = g.op(
                &format!("d{n}"),
                Op::Dispatch { expert: *expert, capacity: *capacity },
                vec![dz, x(1)],
            );
            vec![Some(dx), None]
        }
        Op::Combine { experts } => {
            // out[t] = Σ_e w[t,e]·y_e[t] is linear in both operand groups:
            // d y_e = w[:, e] ⊙ dz, and d w[:, e] = Σ_j dz[t,j]·y_e[t,j] —
            // the gate weights carry a real (smooth) gradient, and they are
            // the only path through which the router parameters learn.
            let mut cols = Vec::with_capacity(*experts);
            for e in 0..*experts {
                let prod = g.mul2(&format!("d{n}_p{e}"), dz, x(1 + e));
                cols.push(g.op(
                    &format!("d{n}_c{e}"),
                    Op::ReduceSum { dim: 1, keepdim: true },
                    vec![prod],
                ));
            }
            let dw = g.concat(&format!("d{n}_w"), cols, 1);
            let mut out: Vec<Option<TensorId>> = vec![Some(dw)];
            for e in 0..*experts {
                let col = g.slice(&format!("d{n}_w{e}"), x(0), 1, e as i64, e as i64 + 1);
                out.push(Some(g.mul2(&format!("d{n}_y{e}"), col, dz)));
            }
            out
        }
        Op::AllGather { dim, .. } => {
            // same as concat
            let mut offset = 0i64;
            let mut out = Vec::new();
            for &inp in &node.inputs {
                let len = g.shape(inp)[*dim];
                out.push(Some(g.slice(&format!("d{n}_part"), dz, *dim, offset, offset + len)));
                offset += len;
            }
            out
        }
        other => bail!("autodiff: unsupported op {} in node '{}'", other, n),
    })
}

fn transpose_last2(g: &mut Graph, name: &str, t: TensorId) -> TensorId {
    let rank = g.shape(t).len();
    let mut perm: Vec<usize> = (0..rank).collect();
    perm.swap(rank - 1, rank - 2);
    g.transpose(name, t, perm)
}

/// Expand a reduced gradient back to the pre-reduction shape by stacking
/// copies along the reduced dim (concat of n copies — uses only existing
/// clean ops, no broadcast-constant needed).
fn expand_reduced(
    g: &mut Graph,
    name: &str,
    dz: TensorId,
    pre: TensorId,
    dim: usize,
    keepdim: bool,
) -> TensorId {
    let n = g.shape(pre)[dim];
    let dz_keep = if keepdim {
        dz
    } else {
        let mut shape = g.shape(dz).to_vec();
        shape.insert(dim, 1);
        g.reshape(&format!("{name}_keep"), dz, shape)
    };
    if n == 1 {
        return dz_keep;
    }
    g.concat(&format!("{name}_expand"), vec![dz_keep; n as usize], dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::eval::{eval_graph, random_inputs};
    use crate::util::ndarray::NdArray;

    /// Finite-difference check: ∂loss/∂input[j] ≈ (L(x+h) - L(x-h)) / 2h.
    fn check_grads(g: &Graph, loss: TensorId, wrt: TensorId, grad: TensorId, seed: u64) {
        let base = random_inputs(g, seed);
        let vals = eval_graph(g, &base).unwrap();
        let analytic = &vals[grad as usize];
        let h = 1e-3f32;
        let x0 = base[&wrt].clone();
        let mut max_err = 0.0f32;
        for j in 0..x0.len() {
            let mut run = |delta: f32| -> f32 {
                let mut env = base.clone();
                let mut xt = x0.clone();
                xt.data_mut()[j] += delta;
                env.insert(wrt, xt);
                eval_graph(g, &env).unwrap()[loss as usize].data()[0]
            };
            let fd = (run(h) - run(-h)) / (2.0 * h);
            let err = (fd - analytic.data()[j]).abs() / (1.0 + fd.abs());
            max_err = max_err.max(err);
        }
        assert!(max_err < 2e-2, "finite-diff mismatch: {max_err}");
    }

    #[test]
    fn regression_gradients() {
        // loss = mse(x @ w + b, target)
        let mut g = Graph::new("reg");
        let x = g.input("x", vec![4, 3]);
        let w = g.input("w", vec![3, 2]);
        let b = g.input("b", vec![2]);
        let t = g.input("t", vec![4, 2]);
        let mm = g.matmul("mm", x, w);
        let pred = g.add2("pred", mm, b);
        let loss = g.op("loss", Op::MseLoss, vec![pred, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[w, b]).unwrap();
        g.validate().unwrap();
        check_grads(&g, loss, w, grads[0], 7);
        check_grads(&g, loss, b, grads[1], 8);
    }

    #[test]
    fn softmax_gradients() {
        let mut g = Graph::new("sm");
        let x = g.input("x", vec![2, 3]);
        let t = g.input("t", vec![2, 3]);
        let s = g.softmax("s", x, 1);
        let loss = g.op("loss", Op::MseLoss, vec![s, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[x]).unwrap();
        check_grads(&g, loss, x, grads[0], 11);
    }

    #[test]
    fn rope_and_norm_composition_gradients() {
        // explicit rms-norm composition: x * rsqrt(mean(x²)+eps) then rope
        let mut g = Graph::new("block");
        let x = g.input("x", vec![2, 4]);
        let cos = g.input("cos", vec![2, 4]);
        let sin = g.input("sin", vec![2, 4]);
        let t = g.input("t", vec![2, 4]);
        let sq = g.op("sq", Op::Square, vec![x]);
        let ms = g.op("ms", Op::ReduceMean { dim: 1, keepdim: true }, vec![sq]);
        let eps = g.op("eps", Op::AddScalar { c: FBits::new(1e-5) }, vec![ms]);
        let inv = g.op("inv", Op::Rsqrt, vec![eps]);
        let normed = g.mul2("normed", x, inv);
        let roped = g.op("roped", Op::Rope, vec![normed, cos, sin]);
        let loss = g.op("loss", Op::MseLoss, vec![roped, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[x]).unwrap();
        g.validate().unwrap();
        check_grads(&g, loss, x, grads[0], 13);
    }

    #[test]
    fn slice_concat_reduce_gradients() {
        let mut g = Graph::new("sc");
        let x = g.input("x", vec![4, 4]);
        let t = g.input("t", vec![2, 4]);
        let a = g.slice("a", x, 0, 0, 2);
        let b = g.slice("b", x, 0, 2, 4);
        let s = g.add2("s", a, b);
        let loss = g.op("loss", Op::MseLoss, vec![s, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[x]).unwrap();
        check_grads(&g, loss, x, grads[0], 17);
    }

    #[test]
    fn combine_weight_and_expert_gradients() {
        // combine is bilinear: both the gate-weights slot and the expert
        // slots must carry exact gradients (the weights slot is the only
        // path through which router parameters learn)
        let mut g = Graph::new("cmb");
        let w = g.input("w", vec![3, 2]);
        let y0 = g.input("y0", vec![3, 4]);
        let y1 = g.input("y1", vec![3, 4]);
        let t = g.input("t", vec![3, 4]);
        let out = g.combine("out", w, vec![y0, y1]);
        let loss = g.op("loss", Op::MseLoss, vec![out, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[w, y0]).unwrap();
        g.validate().unwrap();
        assert_eq!(g.shape(grads[0]), &[3, 2], "dw matches the weights shape");
        check_grads(&g, loss, w, grads[0], 19);
        check_grads(&g, loss, y0, grads[1], 20);
    }

    #[test]
    fn dispatch_gradients_flow_through_tokens() {
        // dispatch with non-binding capacity is row masking: self-adjoint
        let mut g = Graph::new("disp");
        let x = g.input("x", vec![3, 4]);
        let r = g.input("r", vec![3, 2]);
        let t = g.input("t", vec![3, 4]);
        let d = g.dispatch("d", x, r, 1, 3);
        let loss = g.op("loss", Op::MseLoss, vec![d, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[x]).unwrap();
        g.validate().unwrap();
        check_grads(&g, loss, x, grads[0], 21);
    }

    #[test]
    fn unused_input_errors() {
        let mut g = Graph::new("u");
        let x = g.input("x", vec![2]);
        let z = g.input("z", vec![2]);
        let t = g.input("t", vec![2]);
        let loss = g.op("loss", Op::MseLoss, vec![x, t]);
        g.mark_output(loss);
        let err = append_backward(&mut g, loss, &[z]);
        assert!(err.is_err(), "no path from z to loss");
    }

    #[test]
    fn matmul_broadcast_bias_grad_shape() {
        // bias [2] broadcast over [4,2] — grad must reduce back to [2]
        let mut g = Graph::new("bias");
        let x = g.input("x", vec![4, 2]);
        let b = g.input("b", vec![2]);
        let t = g.input("t", vec![4, 2]);
        let s = g.add2("s", x, b);
        let loss = g.op("loss", Op::MseLoss, vec![s, t]);
        g.mark_output(loss);
        let grads = append_backward(&mut g, loss, &[b]).unwrap();
        assert_eq!(g.shape(grads[0]), &[2]);
        let env = random_inputs(&g, 3);
        let vals = eval_graph(&g, &env).unwrap();
        assert_eq!(vals[grads[0] as usize].shape(), &[2]);
    }
}

/// Reduce `grad` (shape = broadcast of input) back to `target` shape by
/// summing over broadcast dimensions. Public within the crate for
/// hand-written backward builders.
pub(crate) fn reduce_to_shape(g: &mut Graph, grad: TensorId, target: &[i64]) -> TensorId {
    let mut cur = grad;
    // drop leading dims
    while g.shape(cur).len() > target.len() {
        cur = g.op("rshape_lead", Op::ReduceSum { dim: 0, keepdim: false }, vec![cur]);
    }
    // sum dims where target is 1 but grad is larger
    let rank = target.len();
    for d in 0..rank {
        if target[d] == 1 && g.shape(cur)[d] != 1 {
            cur = g.op("rshape_keep", Op::ReduceSum { dim: d, keepdim: true }, vec![cur]);
        }
    }
    debug_assert_eq!(g.shape(cur), target);
    cur
}
