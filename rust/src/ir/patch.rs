//! Serializable graph edits — the `GraphPatch` (modeled on tract's
//! `ModelPatch`): a reviewable, replayable description of how a deployed
//! implementation graph was edited, applied via [`GraphPatch::apply`] to
//! produce the patched [`Graph`] without rebuilding it from scratch.
//!
//! Patches address nodes by their **output tensor name** (unique by
//! construction, and the name `json_io` serializes nodes under), so a patch
//! file survives graph re-serialization. Five edit kinds compose:
//!
//! | kind      | effect                                                    |
//! |-----------|-----------------------------------------------------------|
//! | `replace` | swap a node's operator (and optionally its input list)    |
//! | `rewire`  | point one input slot of a node at another tensor          |
//! | `retag`   | change the channel of a `Send`/`Recv` node                |
//! | `add`     | splice in a new node consuming existing (or added) tensors|
//! | `remove`  | drop a node, shunting its consumers to a replacement      |
//!
//! Validation is strict and *total*: dangling tensor references, name/id
//! collisions, conflicting edits on one node, rewires that would break
//! topological order, and shape re-inference failures in the spliced
//! region are all reported as structured errors — never panics — because
//! patches arrive from untrusted inputs (CLI files, serve requests).
//!
//! Patches without `add`/`remove` are applied through
//! [`Graph::rebuild_with`], which preserves **every** `TensorId` (tensors
//! are recreated in original id order). The fuzzer's oracle and the patch
//! impact analysis ([`crate::analysis::impact`]) rely on this: a
//! replace/rewire/retag patch leaves the old and patched graphs id-aligned.
//! Splicing patches shift ids after the insertion point; consumers must
//! re-align by tensor *name* (names persist — see
//! [`crate::analysis::impact::remap_relation`]).

// Patch JSON arrives from untrusted inputs (CLI files, serve requests):
// parsing and application must propagate errors, never panic.
#![deny(clippy::disallowed_methods)]

use super::graph::{Graph, NodeId, TensorId};
use super::json_io::{op_attrs_json, op_from_json};
use super::ops::Op;
use crate::util::json::Json;
use crate::util::schema;
use anyhow::{anyhow, bail, ensure, Context, Result};
use rustc_hash::FxHashMap;

/// One edit. Nodes are addressed by output tensor name; `tensor` operands
/// name any tensor of the (patched) graph.
#[derive(Debug, Clone, PartialEq)]
pub enum PatchOp {
    /// Swap the operator of `node`; `inputs: None` keeps its input list.
    Replace { node: String, op: Op, inputs: Option<Vec<String>> },
    /// Point input slot `slot` of `node` at `tensor`.
    Rewire { node: String, slot: usize, tensor: String },
    /// Change the channel of a `Send`/`Recv` node.
    Retag { node: String, chan: usize },
    /// Splice in a new node `name = op(inputs…)`. The node is inserted at
    /// the earliest point where all its inputs exist, so later `rewire`
    /// ops may target it.
    Add { name: String, op: Op, inputs: Vec<String> },
    /// Drop `node`, shunting every consumer of its output (and any graph
    /// output it fed) to `replacement`, which must be shape-compatible and
    /// live before the removal site.
    Remove { node: String, replacement: String },
}

impl PatchOp {
    fn kind(&self) -> &'static str {
        match self {
            PatchOp::Replace { .. } => "replace",
            PatchOp::Rewire { .. } => "rewire",
            PatchOp::Retag { .. } => "retag",
            PatchOp::Add { .. } => "add",
            PatchOp::Remove { .. } => "remove",
        }
    }
}

/// A named, serializable sequence of edits. An empty patch is the identity.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GraphPatch {
    /// Free-form label carried through reports (defaults to `"patch"`).
    pub name: String,
    pub ops: Vec<PatchOp>,
}

impl GraphPatch {
    pub fn new(name: impl Into<String>) -> Self {
        GraphPatch { name: name.into(), ops: Vec::new() }
    }

    // ---- builders (the fuzzer and tests construct patches in code) ----

    pub fn replace(mut self, node: impl Into<String>, op: Op) -> Self {
        self.ops.push(PatchOp::Replace { node: node.into(), op, inputs: None });
        self
    }

    /// Replace both the operator and the input list of `node` in one op —
    /// exactly the shape of a fuzz mutation.
    pub fn replace_wired(
        mut self,
        node: impl Into<String>,
        op: Op,
        inputs: Vec<String>,
    ) -> Self {
        self.ops.push(PatchOp::Replace { node: node.into(), op, inputs: Some(inputs) });
        self
    }

    pub fn rewire(
        mut self,
        node: impl Into<String>,
        slot: usize,
        tensor: impl Into<String>,
    ) -> Self {
        self.ops.push(PatchOp::Rewire { node: node.into(), slot, tensor: tensor.into() });
        self
    }

    pub fn retag(mut self, node: impl Into<String>, chan: usize) -> Self {
        self.ops.push(PatchOp::Retag { node: node.into(), chan });
        self
    }

    pub fn add(mut self, name: impl Into<String>, op: Op, inputs: Vec<String>) -> Self {
        self.ops.push(PatchOp::Add { name: name.into(), op, inputs });
        self
    }

    pub fn remove(mut self, node: impl Into<String>, replacement: impl Into<String>) -> Self {
        self.ops.push(PatchOp::Remove { node: node.into(), replacement: replacement.into() });
        self
    }

    /// Does this patch add or remove nodes? Splicing patches shift
    /// `TensorId`s after the insertion point; pure replace/rewire/retag
    /// patches keep the old and patched graphs id-aligned.
    pub fn is_splice(&self) -> bool {
        self.ops.iter().any(|o| matches!(o, PatchOp::Add { .. } | PatchOp::Remove { .. }))
    }

    // ---- application ----

    /// Apply the patch, returning the patched graph. Every malformed edit
    /// is a structured error naming the offending op.
    pub fn apply(&self, g: &Graph) -> Result<Graph> {
        let plan = Plan::build(self, g)?;
        let out = if self.is_splice() { plan.splice(g) } else { plan.fast(g) }?;
        out.validate().context("patched graph fails validation")?;
        Ok(out)
    }

    // ---- JSON interchange ----

    /// `{"schema_version": 1, "name": …, "ops": [{"kind": …, …}, …]}`.
    /// Operator encodings reuse the graph-JSON `op`/`attrs` fields.
    pub fn to_json(&self) -> Json {
        let ops: Vec<Json> = self
            .ops
            .iter()
            .map(|op| {
                let mut fields = vec![("kind", Json::str(op.kind()))];
                match op {
                    PatchOp::Replace { node, op, inputs } => {
                        fields.push(("node", Json::str(node.clone())));
                        fields.push(("op", Json::str(op.name().to_string())));
                        push_attrs(&mut fields, op);
                        if let Some(ins) = inputs {
                            fields.push((
                                "inputs",
                                Json::arr(ins.iter().map(|i| Json::str(i.clone())).collect()),
                            ));
                        }
                    }
                    PatchOp::Rewire { node, slot, tensor } => {
                        fields.push(("node", Json::str(node.clone())));
                        fields.push(("slot", Json::num(*slot as f64)));
                        fields.push(("tensor", Json::str(tensor.clone())));
                    }
                    PatchOp::Retag { node, chan } => {
                        fields.push(("node", Json::str(node.clone())));
                        fields.push(("chan", Json::num(*chan as f64)));
                    }
                    PatchOp::Add { name, op, inputs } => {
                        fields.push(("name", Json::str(name.clone())));
                        fields.push(("op", Json::str(op.name().to_string())));
                        push_attrs(&mut fields, op);
                        fields.push((
                            "inputs",
                            Json::arr(inputs.iter().map(|i| Json::str(i.clone())).collect()),
                        ));
                    }
                    PatchOp::Remove { node, replacement } => {
                        fields.push(("node", Json::str(node.clone())));
                        fields.push(("replacement", Json::str(replacement.clone())));
                    }
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("schema_version", schema::version_field()),
            ("name", Json::str(&self.name)),
            ("ops", Json::arr(ops)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GraphPatch> {
        schema::check(j, "graph patch")?;
        let name = j.get("name").as_str().unwrap_or("patch").to_string();
        let arr = j.get("ops").as_arr().ok_or_else(|| anyhow!("patch without 'ops' array"))?;
        let mut ops = Vec::with_capacity(arr.len());
        for (i, o) in arr.iter().enumerate() {
            ops.push(patch_op_from_json(o).with_context(|| format!("patch op #{i}"))?);
        }
        Ok(GraphPatch { name, ops })
    }
}

fn push_attrs(fields: &mut Vec<(&str, Json)>, op: &Op) {
    let attrs = op_attrs_json(op);
    if let Json::Obj(ref o) = attrs {
        if !o.is_empty() {
            fields.push(("attrs", attrs));
        }
    }
}

fn patch_op_from_json(o: &Json) -> Result<PatchOp> {
    let s = |k: &str| -> Result<String> {
        o.get(k)
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("missing string field '{k}'"))
    };
    let kind = s("kind")?;
    Ok(match kind.as_str() {
        "replace" => {
            let op = op_from_json(&s("op")?, o.get("attrs"))?;
            let inputs = match o.get("inputs") {
                Json::Null => None,
                v => Some(
                    v.as_arr()
                        .ok_or_else(|| anyhow!("'inputs' must be an array"))?
                        .iter()
                        .map(|i| {
                            i.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow!("non-string input name"))
                        })
                        .collect::<Result<Vec<_>>>()?,
                ),
            };
            PatchOp::Replace { node: s("node")?, op, inputs }
        }
        "rewire" => PatchOp::Rewire {
            node: s("node")?,
            slot: o.get("slot").as_usize().ok_or_else(|| anyhow!("missing 'slot'"))?,
            tensor: s("tensor")?,
        },
        "retag" => PatchOp::Retag {
            node: s("node")?,
            chan: o.get("chan").as_usize().ok_or_else(|| anyhow!("missing 'chan'"))?,
        },
        "add" => PatchOp::Add {
            name: s("name")?,
            op: op_from_json(&s("op")?, o.get("attrs"))?,
            inputs: o
                .get("inputs")
                .as_arr()
                .ok_or_else(|| anyhow!("'add' needs an 'inputs' array"))?
                .iter()
                .map(|i| {
                    i.as_str().map(str::to_string).ok_or_else(|| anyhow!("non-string input name"))
                })
                .collect::<Result<Vec<_>>>()?,
        },
        "remove" => PatchOp::Remove { node: s("node")?, replacement: s("replacement")? },
        other => bail!("unknown patch op kind '{other}'"),
    })
}

// ---- resolved edit plan ----

/// Per-node edits resolved and cross-validated against the target graph.
struct Plan {
    /// node → (new op, new input names); both optional (keep).
    edits: FxHashMap<NodeId, NodeEdit>,
    /// node → replacement tensor name.
    removed: FxHashMap<NodeId, String>,
    /// spliced-in nodes, in patch order.
    added: Vec<(String, Op, Vec<String>)>,
    splice: bool,
}

#[derive(Default)]
struct NodeEdit {
    op: Option<Op>,
    /// full input-list override (from `replace … inputs`)
    inputs: Option<Vec<String>>,
    /// per-slot rewires (slot → tensor name)
    rewires: Vec<(usize, String)>,
}

impl Plan {
    fn build(patch: &GraphPatch, g: &Graph) -> Result<Plan> {
        let mut plan = Plan {
            edits: FxHashMap::default(),
            removed: FxHashMap::default(),
            added: Vec::new(),
            splice: patch.is_splice(),
        };
        let mut added_names: Vec<&str> = Vec::new();
        let resolve_node = |name: &str| -> Result<NodeId> {
            let t = g
                .tensor_by_name(name)
                .ok_or_else(|| anyhow!("targets unknown node '{name}'"))?;
            g.tensor(t)
                .producer
                .ok_or_else(|| anyhow!("targets graph input '{name}', not a node"))
        };
        for (i, op) in patch.ops.iter().enumerate() {
            let ctx = || format!("patch op #{i} ({})", op.kind());
            match op {
                PatchOp::Replace { node, op: new_op, inputs } => {
                    let nid = resolve_node(node).with_context(ctx)?;
                    ensure!(
                        !plan.removed.contains_key(&nid),
                        "{}: node '{node}' is also removed by this patch",
                        ctx()
                    );
                    let e = plan.edits.entry(nid).or_default();
                    ensure!(
                        e.op.is_none(),
                        "{}: conflicting replace/retag on node '{node}'",
                        ctx()
                    );
                    e.op = Some(new_op.clone());
                    if inputs.is_some() {
                        ensure!(
                            e.rewires.is_empty() && e.inputs.is_none(),
                            "{}: input list for '{node}' conflicts with other rewires",
                            ctx()
                        );
                        e.inputs = inputs.clone();
                    }
                }
                PatchOp::Rewire { node, slot, tensor } => {
                    let nid = resolve_node(node).with_context(ctx)?;
                    ensure!(
                        !plan.removed.contains_key(&nid),
                        "{}: node '{node}' is also removed by this patch",
                        ctx()
                    );
                    ensure!(
                        *slot < g.node(nid).inputs.len(),
                        "{}: node '{node}' has {} input slot(s), no slot {slot}",
                        ctx(),
                        g.node(nid).inputs.len()
                    );
                    let e = plan.edits.entry(nid).or_default();
                    ensure!(
                        e.inputs.is_none(),
                        "{}: rewire of '{node}' conflicts with a full input-list replace",
                        ctx()
                    );
                    ensure!(
                        e.rewires.iter().all(|(s, _)| s != slot),
                        "{}: slot {slot} of '{node}' rewired twice",
                        ctx()
                    );
                    e.rewires.push((*slot, tensor.clone()));
                }
                PatchOp::Retag { node, chan } => {
                    let nid = resolve_node(node).with_context(ctx)?;
                    ensure!(
                        !plan.removed.contains_key(&nid),
                        "{}: node '{node}' is also removed by this patch",
                        ctx()
                    );
                    let retagged = match g.node(nid).op {
                        Op::Send { .. } => Op::Send { chan: *chan },
                        Op::Recv { .. } => Op::Recv { chan: *chan },
                        ref other => bail!(
                            "{}: node '{node}' is {other}, not a Send/Recv",
                            ctx()
                        ),
                    };
                    let e = plan.edits.entry(nid).or_default();
                    ensure!(
                        e.op.is_none(),
                        "{}: conflicting replace/retag on node '{node}'",
                        ctx()
                    );
                    e.op = Some(retagged);
                }
                PatchOp::Add { name, op: new_op, inputs } => {
                    ensure!(
                        g.tensor_by_name(name).is_none(),
                        "{}: name '{name}' collides with an existing tensor",
                        ctx()
                    );
                    ensure!(
                        !added_names.contains(&name.as_str()),
                        "{}: name '{name}' added twice",
                        ctx()
                    );
                    added_names.push(name);
                    plan.added.push((name.clone(), new_op.clone(), inputs.clone()));
                }
                PatchOp::Remove { node, replacement } => {
                    let nid = resolve_node(node).with_context(ctx)?;
                    ensure!(
                        !plan.edits.contains_key(&nid),
                        "{}: node '{node}' is also edited by this patch",
                        ctx()
                    );
                    ensure!(
                        replacement != node,
                        "{}: '{node}' cannot replace itself",
                        ctx()
                    );
                    ensure!(
                        plan.removed.insert(nid, replacement.clone()).is_none(),
                        "{}: node '{node}' removed twice",
                        ctx()
                    );
                }
            }
        }
        // Input names referenced by edits must exist somewhere — in the old
        // graph or among the added nodes. (Splice-time ordering is checked
        // during application; here we reject plainly dangling names.)
        let known = |name: &str| {
            g.tensor_by_name(name).is_some() || added_names.contains(&name)
        };
        for e in plan.edits.values() {
            for name in e
                .inputs
                .iter()
                .flatten()
                .chain(e.rewires.iter().map(|(_, t)| t))
            {
                ensure!(known(name), "patch references unknown tensor '{name}'");
            }
        }
        for (added, _, inputs) in &plan.added {
            for name in inputs {
                ensure!(
                    known(name),
                    "added node '{added}' references unknown tensor '{name}'"
                );
            }
        }
        for (nid, repl) in &plan.removed {
            ensure!(
                known(repl),
                "removal of '{}' shunts to unknown tensor '{repl}'",
                g.tensor(g.node(*nid).output).name
            );
        }
        Ok(plan)
    }

    /// The edited `(op, inputs)` for node `nid`, with input names resolved
    /// through `lookup` (old-graph ids in the fast path, patched-graph ids
    /// in the splice path). `current` is the node's default input list.
    fn edited_node(
        &self,
        g: &Graph,
        nid: NodeId,
        current: &[TensorId],
        lookup: impl Fn(&str) -> Option<TensorId>,
    ) -> Result<(Op, Vec<TensorId>)> {
        let node = g.node(nid);
        let node_name = &g.tensor(node.output).name;
        let Some(e) = self.edits.get(&nid) else {
            return Ok((node.op.clone(), current.to_vec()));
        };
        let op = e.op.clone().unwrap_or_else(|| node.op.clone());
        let resolve = |name: &str| {
            lookup(name).ok_or_else(|| {
                anyhow!(
                    "patch rewires '{node_name}' to '{name}', which does not exist \
                     before it — dangling or non-topological"
                )
            })
        };
        let ins = match &e.inputs {
            Some(names) => names.iter().map(|n| resolve(n)).collect::<Result<Vec<_>>>()?,
            None => {
                let mut ins = current.to_vec();
                for (slot, name) in &e.rewires {
                    ins[*slot] = resolve(name)?;
                }
                ins
            }
        };
        Ok((op, ins))
    }

    /// Fast path: no adds/removes — splice through [`Graph::rebuild_with`],
    /// preserving every `TensorId`. Name resolution happens *before* the
    /// rebuild (against the old graph, whose ids the rebuild preserves) so
    /// a dangling or non-topological rewire is an error, not a panic.
    fn fast(&self, g: &Graph) -> Result<Graph> {
        let mut resolved: FxHashMap<NodeId, (Op, Vec<TensorId>)> = FxHashMap::default();
        for &nid in self.edits.keys() {
            let node = g.node(nid);
            // only earlier tensors keep the rebuild topological
            let lookup = |name: &str| {
                g.tensor_by_name(name).filter(|&t| t < node.output)
            };
            resolved.insert(nid, self.edited_node(g, nid, &node.inputs, lookup)?);
        }
        g.rebuild_with(|nid, node, mapped| match resolved.get(&nid) {
            Some((op, ins)) => (op.clone(), ins.clone()),
            None => (node.op.clone(), mapped.to_vec()),
        })
        .context("splicing patched region (shape re-inference failed)")
    }

    /// Splice path: adds and removes present. Walk old tensors in id order
    /// (like `rebuild_with`); removed nodes shunt their consumers to the
    /// replacement; added nodes are inserted as soon as all their inputs
    /// exist in the output graph.
    fn splice(&self, g: &Graph) -> Result<Graph> {
        let mut out = Graph::new(g.name.clone());
        let mut remap: Vec<Option<TensorId>> = vec![None; g.num_tensors()];
        let mut pending: Vec<Option<(String, Op, Vec<String>)>> =
            self.added.iter().cloned().map(Some).collect();
        // Insert every pending added node whose inputs all resolve; repeat
        // until a full sweep adds nothing (added nodes may feed each other).
        fn flush(out: &mut Graph, pending: &mut [Option<(String, Op, Vec<String>)>]) -> Result<()> {
            loop {
                let mut progressed = false;
                for slot in pending.iter_mut() {
                    let ready = match slot {
                        Some((_, _, inputs)) => {
                            inputs.iter().all(|n| out.tensor_by_name(n).is_some())
                        }
                        None => false,
                    };
                    if !ready {
                        continue;
                    }
                    if let Some((name, op, inputs)) = slot.take() {
                        let ins: Vec<TensorId> = inputs
                            .iter()
                            .filter_map(|n| out.tensor_by_name(n))
                            .collect();
                        out.add(&name, op, ins)
                            .with_context(|| format!("splicing added node '{name}'"))?;
                        progressed = true;
                    }
                }
                if !progressed {
                    return Ok(());
                }
            }
        }
        for tid in 0..g.num_tensors() as TensorId {
            let t = g.tensor(tid);
            match t.producer {
                None => {
                    remap[tid as usize] = Some(out.input_typed(&t.name, t.shape.clone(), t.dtype));
                }
                Some(nid) if self.removed.contains_key(&nid) => {
                    let repl = &self.removed[&nid];
                    let new_id = out.tensor_by_name(repl).ok_or_else(|| {
                        anyhow!(
                            "removal of '{}' shunts to '{repl}', which does not exist \
                             before it — dangling or non-topological",
                            t.name
                        )
                    })?;
                    ensure!(
                        out.shape(new_id) == t.shape.as_slice(),
                        "removal of '{}' shunts to '{repl}' of shape {:?}, expected {:?}",
                        t.name,
                        out.shape(new_id),
                        t.shape
                    );
                    remap[tid as usize] = Some(new_id);
                }
                Some(nid) => {
                    let node = g.node(nid);
                    let current: Vec<TensorId> = node
                        .inputs
                        .iter()
                        .map(|&x| {
                            remap[x as usize].ok_or_else(|| {
                                anyhow!("internal: input of '{}' not yet rebuilt", t.name)
                            })
                        })
                        .collect::<Result<_>>()?;
                    let (op, ins) =
                        self.edited_node(g, nid, &current, |name| out.tensor_by_name(name))?;
                    let new_out = out
                        .add(&t.name, op, ins)
                        .with_context(|| format!("splicing patched node '{}'", t.name))?;
                    remap[tid as usize] = Some(new_out);
                }
            }
            flush(&mut out, &mut pending)?;
        }
        for slot in &pending {
            if let Some((name, _, inputs)) = slot {
                bail!(
                    "added node '{name}' has dangling inputs {:?} — never became insertable",
                    inputs
                        .iter()
                        .filter(|n| out.tensor_by_name(n).is_none())
                        .collect::<Vec<_>>()
                );
            }
        }
        for &o in &g.outputs {
            let mapped = remap[o as usize]
                .ok_or_else(|| anyhow!("internal: output tensor not rebuilt"))?;
            out.mark_output(mapped);
        }
        Ok(out)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests assert on trusted fixtures
mod tests {
    use super::*;
    use crate::ir::json_io;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let a = g.input("A", vec![4, 6]);
        let b = g.input("B", vec![6, 4]);
        let c = g.matmul("C", a, b);
        let e = g.input("E", vec![4, 4]);
        let f = g.sub2("F", c, e);
        g.mark_output(f);
        g
    }

    #[test]
    fn replace_preserves_tensor_ids() {
        let g = tiny();
        let p = GraphPatch::new("swap").replace("F", Op::Add);
        let g2 = p.apply(&g).unwrap();
        assert_eq!(g2.num_tensors(), g.num_tensors());
        for tid in 0..g.num_tensors() as TensorId {
            assert_eq!(g2.tensor(tid).name, g.tensor(tid).name, "id-aligned");
        }
        let f = g2.tensor_by_name("F").unwrap();
        assert!(matches!(g2.producer(f).unwrap().op, Op::Add));
    }

    #[test]
    fn rewire_changes_one_slot() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let s = g.add2("s", a, b);
        g.mark_output(s);
        let g2 = GraphPatch::new("w").rewire("s", 1, "a").apply(&g).unwrap();
        let s2 = g2.tensor_by_name("s").unwrap();
        let node = g2.producer(s2).unwrap();
        assert_eq!(node.inputs, vec![a, a]);
    }

    #[test]
    fn retag_only_applies_to_channels() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        let s = g.op("snd", Op::Send { chan: 1 }, vec![a]);
        let r = g.op("rcv", Op::Recv { chan: 1 }, vec![s]);
        g.mark_output(r);
        let g2 = GraphPatch::new("c").retag("snd", 7).retag("rcv", 7).apply(&g).unwrap();
        let snd = g2.producer(g2.tensor_by_name("snd").unwrap()).unwrap();
        assert!(matches!(snd.op, Op::Send { chan: 7 }));
        let e = GraphPatch::new("c").retag("a", 7).apply(&g).unwrap_err();
        assert!(format!("{e:#}").contains("graph input"), "{e:#}");
        let e = GraphPatch::new("c").retag("r", 7).apply(&g).unwrap_err();
        assert!(format!("{e:#}").contains("unknown node"), "{e:#}");
    }

    #[test]
    fn add_splices_and_rewires_consumers() {
        let g = tiny();
        let p = GraphPatch::new("id")
            .add("C_id", Op::Identity, vec!["C".into()])
            .rewire("F", 0, "C_id");
        let g2 = p.apply(&g).unwrap();
        assert_eq!(g2.num_nodes(), g.num_nodes() + 1);
        let f = g2.tensor_by_name("F").unwrap();
        let cid = g2.tensor_by_name("C_id").unwrap();
        assert_eq!(g2.producer(f).unwrap().inputs[0], cid);
        g2.validate().unwrap();
    }

    #[test]
    fn remove_shunts_consumers_and_outputs() {
        let g = tiny();
        // splice an identity in, then remove it again: round-trips to the
        // original wiring (names and structure; ids shift and return)
        let with_id = GraphPatch::new("id")
            .add("C_id", Op::Identity, vec!["C".into()])
            .rewire("F", 0, "C_id")
            .apply(&g)
            .unwrap();
        let back = GraphPatch::new("rm").remove("C_id", "C").apply(&with_id).unwrap();
        assert_eq!(
            json_io::to_json(&back).to_string(),
            json_io::to_json(&g).to_string(),
            "remove(add(g)) == g"
        );
    }

    #[test]
    fn strict_validation_is_errors_not_panics() {
        let g = tiny();
        // dangling rewire target
        let e = GraphPatch::new("x").rewire("F", 0, "nope").apply(&g).unwrap_err();
        assert!(format!("{e:#}").contains("unknown tensor 'nope'"), "{e:#}");
        // rewire to a later tensor breaks topological order
        let e = GraphPatch::new("x").rewire("C", 0, "F").apply(&g).unwrap_err();
        assert!(format!("{e:#}").contains("does not exist before"), "{e:#}");
        // name collision on add
        let e = GraphPatch::new("x")
            .add("C", Op::Identity, vec!["A".into()])
            .apply(&g)
            .unwrap_err();
        assert!(format!("{e:#}").contains("collides"), "{e:#}");
        // shape re-inference failure in the spliced region
        let e = GraphPatch::new("x").replace("C", Op::Add).apply(&g).unwrap_err();
        assert!(format!("{e:#}").contains("shape"), "{e:#}");
        // bad slot
        let e = GraphPatch::new("x").rewire("F", 9, "C").apply(&g).unwrap_err();
        assert!(format!("{e:#}").contains("no slot 9"), "{e:#}");
        // conflicting edits
        let e = GraphPatch::new("x")
            .replace("F", Op::Add)
            .remove("F", "C")
            .apply(&g)
            .unwrap_err();
        assert!(format!("{e:#}").contains("also edited"), "{e:#}");
    }

    #[test]
    fn empty_patch_is_identity() {
        let g = tiny();
        let g2 = GraphPatch::new("noop").apply(&g).unwrap();
        assert_eq!(json_io::to_json(&g2).to_string(), json_io::to_json(&g).to_string());
    }

    #[test]
    fn json_roundtrip() {
        let p = GraphPatch::new("rt")
            .replace_wired("F", Op::Add, vec!["C".into(), "E".into()])
            .rewire("F", 1, "E")
            .retag("snd", 3)
            .add("n", Op::Scale { c: crate::ir::FBits::new(2.0) }, vec!["C".into()])
            .remove("old", "C");
        let j = p.to_json();
        let p2 = GraphPatch::from_json(&j).unwrap();
        assert_eq!(p2, p);
        assert_eq!(p2.to_json().to_string(), j.to_string());
        // version mismatch is rejected
        let bad = Json::parse(r#"{"schema_version": 99, "ops": []}"#).unwrap();
        assert!(GraphPatch::from_json(&bad).is_err());
        // unknown kind is rejected
        let bad = Json::parse(r#"{"ops": [{"kind": "frobnicate"}]}"#).unwrap();
        assert!(GraphPatch::from_json(&bad).is_err());
    }
}
