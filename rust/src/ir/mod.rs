//! Computation-graph IR.
//!
//! Both the sequential specification `G_s` and the distributed implementation
//! `G_d` are DAGs whose vertices are operators and whose edges are tensors
//! (paper §3.2). Graphs arrive here from three frontends: the Python jaxpr
//! capture (`ir::json_io`), the HLO-text parser (`crate::hlo`), and the
//! in-repo model builders (`crate::models`).

pub mod autodiff;
pub mod graph;
pub mod json_io;
pub mod ops;
pub mod patch;

pub use graph::{DType, Graph, Node, NodeId, Tensor, TensorId};
pub use ops::{FBits, Op, OpTag};
pub use patch::{GraphPatch, PatchOp};
