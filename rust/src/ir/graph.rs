//! Graph, node and tensor types plus a fluent builder API used by the
//! in-repo model definitions (`crate::models`) and strategy transformers.

use super::ops::Op;
use anyhow::{ensure, Context, Result};
use rustc_hash::FxHashMap;

pub type TensorId = u32;
pub type NodeId = u32;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I64,
}

impl DType {
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I64 => "i64",
        }
    }
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" | "float32" | "bf16" | "bfloat16" | "f16" => Some(DType::F32),
            "i64" | "int64" | "i32" | "int32" => Some(DType::I64),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: DType,
    /// Node that produces this tensor; `None` for graph inputs.
    pub producer: Option<NodeId>,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
}

/// A computation graph: DAG of single-output operators over tensors.
/// Nodes are stored in insertion order, which is a topological order by
/// construction (a node may only consume already-existing tensors).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    tensors: Vec<Tensor>,
    nodes: Vec<Node>,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
    by_name: FxHashMap<String, TensorId>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Graph { name: name.into(), ..Default::default() }
    }

    // ---- accessors ----

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }
    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id as usize]
    }
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }
    pub fn num_tensors(&self) -> usize {
        self.tensors.len()
    }
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
    pub fn tensor_by_name(&self, name: &str) -> Option<TensorId> {
        self.by_name.get(name).copied()
    }
    pub fn shape(&self, id: TensorId) -> &[i64] {
        &self.tensors[id as usize].shape
    }

    /// Nodes in topological order (insertion order, verified by `validate`).
    pub fn topo_order(&self) -> impl Iterator<Item = NodeId> + '_ {
        0..self.nodes.len() as NodeId
    }

    pub fn is_input(&self, id: TensorId) -> bool {
        self.tensors[id as usize].producer.is_none()
    }

    pub fn is_output(&self, id: TensorId) -> bool {
        self.outputs.contains(&id)
    }

    // ---- construction ----

    fn fresh_name(&self, base: &str) -> String {
        if !self.by_name.contains_key(base) {
            return base.to_string();
        }
        let mut i = 1;
        loop {
            let name = format!("{base}.{i}");
            if !self.by_name.contains_key(&name) {
                return name;
            }
            i += 1;
        }
    }

    fn push_tensor(&mut self, name: String, shape: Vec<i64>, dtype: DType, producer: Option<NodeId>) -> TensorId {
        let id = self.tensors.len() as TensorId;
        self.by_name.insert(name.clone(), id);
        self.tensors.push(Tensor { name, shape, dtype, producer });
        id
    }

    /// Declare a graph input tensor.
    pub fn input(&mut self, name: &str, shape: Vec<i64>) -> TensorId {
        self.input_typed(name, shape, DType::F32)
    }

    pub fn input_typed(&mut self, name: &str, shape: Vec<i64>, dtype: DType) -> TensorId {
        let name = self.fresh_name(name);
        let id = self.push_tensor(name, shape, dtype, None);
        self.inputs.push(id);
        id
    }

    /// Add an operator node; infers the output shape. The output tensor is
    /// named `name` (uniquified if taken).
    pub fn add(&mut self, name: &str, op: Op, inputs: Vec<TensorId>) -> Result<TensorId> {
        let in_shapes: Vec<&[i64]> =
            inputs.iter().map(|&t| self.tensors[t as usize].shape.as_slice()).collect();
        let out_shape = op
            .infer_shape(&in_shapes, None)
            .with_context(|| format!("adding node '{name}' ({op})"))?;
        let dtype = match op {
            Op::Embedding => DType::F32,
            _ => self
                .tensors
                .get(*inputs.first().unwrap_or(&0) as usize)
                .map(|t| t.dtype)
                .unwrap_or(DType::F32),
        };
        let node_id = self.nodes.len() as NodeId;
        let tname = self.fresh_name(name);
        let out = self.push_tensor(tname, out_shape, dtype, Some(node_id));
        self.nodes.push(Node { name: name.to_string(), op, inputs, output: out });
        Ok(out)
    }

    /// Convenience: `add` that panics — for model builders where shapes are
    /// static and a failure is a builder bug.
    pub fn op(&mut self, name: &str, op: Op, inputs: Vec<TensorId>) -> TensorId {
        self.add(name, op, inputs).unwrap()
    }

    pub fn mark_output(&mut self, id: TensorId) {
        if !self.outputs.contains(&id) {
            self.outputs.push(id);
        }
    }

    // ---- fluent op helpers (keep model builders readable) ----

    pub fn matmul(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.op(name, Op::MatMul, vec![a, b])
    }
    pub fn add2(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.op(name, Op::Add, vec![a, b])
    }
    pub fn sub2(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.op(name, Op::Sub, vec![a, b])
    }
    pub fn mul2(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.op(name, Op::Mul, vec![a, b])
    }
    pub fn concat(&mut self, name: &str, parts: Vec<TensorId>, dim: usize) -> TensorId {
        self.op(name, Op::Concat { dim }, parts)
    }
    pub fn slice(&mut self, name: &str, x: TensorId, dim: usize, start: i64, end: i64) -> TensorId {
        self.op(name, Op::Slice { dim, start: start.into(), end: end.into() }, vec![x])
    }
    pub fn transpose(&mut self, name: &str, x: TensorId, perm: Vec<usize>) -> TensorId {
        self.op(name, Op::Transpose { perm }, vec![x])
    }
    pub fn reshape(&mut self, name: &str, x: TensorId, shape: Vec<i64>) -> TensorId {
        self.op(
            name,
            Op::Reshape { shape: shape.into_iter().map(Into::into).collect() },
            vec![x],
        )
    }
    pub fn scale(&mut self, name: &str, x: TensorId, c: f64) -> TensorId {
        self.op(name, Op::Scale { c: super::ops::FBits::new(c) }, vec![x])
    }
    pub fn softmax(&mut self, name: &str, x: TensorId, dim: usize) -> TensorId {
        self.op(name, Op::Softmax { dim }, vec![x])
    }
    pub fn all_reduce(&mut self, name: &str, shards: Vec<TensorId>) -> TensorId {
        let ranks = shards.len();
        self.op(name, Op::AllReduce { ranks }, shards)
    }
    pub fn all_gather(&mut self, name: &str, shards: Vec<TensorId>, dim: usize) -> TensorId {
        let ranks = shards.len();
        self.op(name, Op::AllGather { dim, ranks }, shards)
    }
    pub fn reduce_scatter(
        &mut self,
        name: &str,
        shards: Vec<TensorId>,
        dim: usize,
        index: usize,
    ) -> TensorId {
        let ranks = shards.len();
        self.op(name, Op::ReduceScatter { dim, ranks, index }, shards)
    }
    pub fn topk(&mut self, name: &str, scores: TensorId, k: usize) -> TensorId {
        self.op(name, Op::TopK { k }, vec![scores])
    }
    pub fn dispatch(
        &mut self,
        name: &str,
        x: TensorId,
        router: TensorId,
        expert: usize,
        capacity: usize,
    ) -> TensorId {
        self.op(name, Op::Dispatch { expert, capacity }, vec![x, router])
    }
    /// `combine(weights, experts)`: token gather keyed by the router tensor.
    pub fn combine(&mut self, name: &str, weights: TensorId, experts: Vec<TensorId>) -> TensorId {
        let n = experts.len();
        let mut ins = Vec::with_capacity(n + 1);
        ins.push(weights);
        ins.extend(experts);
        self.op(name, Op::Combine { experts: n }, ins)
    }

    // ---- validation ----

    /// Check DAG/topological invariants and per-node shape consistency.
    pub fn validate(&self) -> Result<()> {
        for (i, node) in self.nodes.iter().enumerate() {
            for &t in &node.inputs {
                ensure!((t as usize) < self.tensors.len(), "node {} input out of range", node.name);
                if let Some(p) = self.tensors[t as usize].producer {
                    ensure!(
                        (p as usize) < i,
                        "node '{}' consumes tensor produced later — not topological",
                        node.name
                    );
                }
            }
            let in_shapes: Vec<&[i64]> =
                node.inputs.iter().map(|&t| self.tensors[t as usize].shape.as_slice()).collect();
            let expect = node.op.infer_shape(&in_shapes, None)?;
            ensure!(
                expect == self.tensors[node.output as usize].shape,
                "node '{}' output shape {:?} != inferred {:?}",
                node.name,
                self.tensors[node.output as usize].shape,
                expect
            );
        }
        for &o in &self.outputs {
            ensure!((o as usize) < self.tensors.len(), "output id out of range");
        }
        Ok(())
    }

    /// Dead-code elimination: rebuild the graph keeping only nodes whose
    /// results reach an output. Inputs are all preserved (they are part of
    /// the model's interface and of `R_i`). Applied identically to `G_s`
    /// and `G_d` it respects the same-optimizations assumption (§3.3).
    pub fn eliminate_dead_code(&self) -> Graph {
        let mut live = vec![false; self.tensors.len()];
        let mut stack: Vec<TensorId> = self.outputs.clone();
        while let Some(t) = stack.pop() {
            if std::mem::replace(&mut live[t as usize], true) {
                continue;
            }
            if let Some(p) = self.tensors[t as usize].producer {
                for &i in &self.nodes[p as usize].inputs {
                    stack.push(i);
                }
            }
        }
        let mut g = Graph::new(self.name.clone());
        let mut remap: FxHashMap<TensorId, TensorId> = FxHashMap::default();
        for &i in &self.inputs {
            let t = &self.tensors[i as usize];
            remap.insert(i, g.input_typed(&t.name, t.shape.clone(), t.dtype));
        }
        for node in &self.nodes {
            if !live[node.output as usize] {
                continue;
            }
            let inputs: Vec<TensorId> = node.inputs.iter().map(|t| remap[t]).collect();
            let out = g
                .add(&self.tensors[node.output as usize].name, node.op.clone(), inputs)
                .expect("DCE preserves well-formedness");
            remap.insert(node.output, out);
        }
        for &o in &self.outputs {
            g.mark_output(remap[&o]);
        }
        g
    }

    /// Rebuild the graph with `edit` applied to every node. Shapes are
    /// re-inferred; an edit that breaks shape inference fails the whole
    /// rebuild.
    ///
    /// Tensors are recreated in original id order — inputs *interleaved*
    /// with node outputs, exactly as the model builders declare them
    /// (weights are registered lazily per block). This keeps every
    /// `TensorId` stable, which both the fuzzer's oracle (it reuses the
    /// clean graph's input environments and `TensorId`-keyed `R_i` against
    /// mutants) and the schedule lowering (it re-tags Send/Recv under an
    /// unchanged relation) depend on. An edit may therefore only rewire a
    /// node to tensors created *earlier* than its output.
    pub fn rebuild_with(
        &self,
        edit: impl Fn(NodeId, &Node, &[TensorId]) -> (Op, Vec<TensorId>),
    ) -> Result<Graph> {
        let mut out = Graph::new(self.name.clone());
        let mut remap: Vec<TensorId> = vec![0; self.num_tensors()];
        for tid in 0..self.num_tensors() as TensorId {
            let t = self.tensor(tid);
            match t.producer {
                None => {
                    remap[tid as usize] = out.input_typed(&t.name, t.shape.clone(), t.dtype);
                }
                Some(nid) => {
                    let node = self.node(nid);
                    debug_assert_eq!(node.output, tid, "one output tensor per node");
                    let mapped: Vec<TensorId> =
                        node.inputs.iter().map(|&x| remap[x as usize]).collect();
                    let (op, ins) = edit(nid, node, &mapped);
                    remap[tid as usize] = out.add(&node.name, op, ins)?;
                }
            }
        }
        for &o in &self.outputs {
            out.mark_output(remap[o as usize]);
        }
        out.validate()?;
        Ok(out)
    }

    /// Producer node of a tensor, if any.
    pub fn producer(&self, t: TensorId) -> Option<&Node> {
        self.tensors[t as usize].producer.map(|n| &self.nodes[n as usize])
    }

    /// All node ids whose inputs include `t`.
    pub fn consumers(&self, t: TensorId) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.contains(&t))
            .map(|(i, _)| i as NodeId)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_validate() {
        let mut g = Graph::new("tiny");
        let a = g.input("A", vec![4, 6]);
        let b = g.input("B", vec![6, 3]);
        let c = g.matmul("C", a, b);
        let d = g.scale("D", c, 2.0);
        g.mark_output(d);
        assert_eq!(g.shape(c), &[4, 3]);
        assert_eq!(g.num_nodes(), 2);
        g.validate().unwrap();
        assert!(g.is_input(a));
        assert!(!g.is_input(c));
        assert!(g.is_output(d));
        assert_eq!(g.producer(c).unwrap().name, "C");
        assert_eq!(g.consumers(c), vec![1]);
    }

    #[test]
    fn name_uniquification() {
        let mut g = Graph::new("t");
        let a = g.input("x", vec![2]);
        let b = g.input("x", vec![2]);
        assert_ne!(g.tensor(a).name, g.tensor(b).name);
        assert_eq!(g.tensor_by_name("x"), Some(a));
        assert_eq!(g.tensor_by_name("x.1"), Some(b));
    }

    #[test]
    fn add_rejects_bad_shapes() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 3]);
        let b = g.input("b", vec![2, 3]);
        assert!(g.add("bad", Op::MatMul, vec![a, b]).is_err());
    }

    #[test]
    fn collectives_helpers() {
        let mut g = Graph::new("t");
        let a = g.input("a0", vec![2, 4]);
        let b = g.input("a1", vec![2, 4]);
        let gathered = g.all_gather("ag", vec![a, b], 0);
        assert_eq!(g.shape(gathered), &[4, 4]);
        let rs = g.reduce_scatter("rs", vec![gathered, gathered], 0, 1);
        assert_eq!(g.shape(rs), &[2, 4]);
        g.validate().unwrap();
    }
}
