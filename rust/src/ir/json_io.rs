//! Graph ⇄ JSON interchange with `python/compile/capture.py`.
//!
//! Schema:
//! ```json
//! {
//!   "name": "gpt_seq",
//!   "inputs":  [{"name": "A", "shape": [4, 4], "dtype": "f32"}],
//!   "nodes":   [{"op": "matmul", "name": "C", "inputs": ["A", "B"],
//!                "attrs": {"dim": 0}}],
//!   "outputs": ["F"]
//! }
//! ```
//! Node outputs are named by the node's `name`. Attrs mirror
//! `expr::print::attr_string` keys.

use super::graph::{DType, Graph, TensorId};
use super::ops::{FBits, Op};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

pub fn to_json(g: &Graph) -> Json {
    let inputs: Vec<Json> = g
        .inputs
        .iter()
        .map(|&i| {
            let t = g.tensor(i);
            Json::obj(vec![
                ("name", Json::str(&t.name)),
                ("shape", Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect())),
                ("dtype", Json::str(t.dtype.name())),
            ])
        })
        .collect();
    let nodes: Vec<Json> = g
        .nodes()
        .iter()
        .map(|n| {
            let mut fields = vec![
                ("op", Json::str(n.op.name().to_string())),
                ("name", Json::str(&g.tensor(n.output).name)),
                (
                    "inputs",
                    Json::arr(
                        n.inputs.iter().map(|&t| Json::str(&g.tensor(t).name)).collect(),
                    ),
                ),
            ];
            let attrs = op_attrs_json(&n.op);
            if let Json::Obj(ref o) = attrs {
                if !o.is_empty() {
                    fields.push(("attrs", attrs));
                }
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("name", Json::str(&g.name)),
        ("inputs", Json::arr(inputs)),
        ("nodes", Json::arr(nodes)),
        ("outputs", Json::arr(g.outputs.iter().map(|&t| Json::str(&g.tensor(t).name)).collect())),
    ])
}

pub(crate) fn op_attrs_json(op: &Op) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    match op {
        Op::Slice { dim, start, end } => {
            pairs.push(("dim", Json::num(*dim as f64)));
            pairs.push(("start", Json::num(start.expect_const() as f64)));
            pairs.push(("end", Json::num(end.expect_const() as f64)));
        }
        Op::Concat { dim } | Op::Softmax { dim } => pairs.push(("dim", Json::num(*dim as f64))),
        Op::Transpose { perm } => pairs
            .push(("perm", Json::arr(perm.iter().map(|&p| Json::num(p as f64)).collect()))),
        Op::Reshape { shape } => pairs.push((
            "shape",
            Json::arr(shape.iter().map(|s| Json::num(s.expect_const() as f64)).collect()),
        )),
        Op::Pad { dim, before, after, value } => {
            pairs.push(("dim", Json::num(*dim as f64)));
            pairs.push(("before", Json::num(before.expect_const() as f64)));
            pairs.push(("after", Json::num(after.expect_const() as f64)));
            pairs.push(("value", Json::num(value.get())));
        }
        Op::Scale { c } | Op::AddScalar { c } => pairs.push(("c", Json::num(c.get()))),
        Op::ReduceSum { dim, keepdim }
        | Op::ReduceMean { dim, keepdim }
        | Op::ReduceMax { dim, keepdim } => {
            pairs.push(("dim", Json::num(*dim as f64)));
            pairs.push(("keepdim", Json::Bool(*keepdim)));
        }
        Op::RmsNorm { eps } | Op::LayerNorm { eps } => pairs.push(("eps", Json::num(eps.get()))),
        Op::AllReduce { ranks } => pairs.push(("ranks", Json::num(*ranks as f64))),
        Op::AllGather { dim, ranks } => {
            pairs.push(("dim", Json::num(*dim as f64)));
            pairs.push(("ranks", Json::num(*ranks as f64)));
        }
        Op::ReduceScatter { dim, ranks, index } => {
            pairs.push(("dim", Json::num(*dim as f64)));
            pairs.push(("ranks", Json::num(*ranks as f64)));
            pairs.push(("index", Json::num(*index as f64)));
        }
        Op::Send { chan } | Op::Recv { chan } => pairs.push(("chan", Json::num(*chan as f64))),
        Op::TopK { k } => pairs.push(("k", Json::num(*k as f64))),
        Op::Dispatch { expert, capacity } => {
            pairs.push(("expert", Json::num(*expert as f64)));
            pairs.push(("capacity", Json::num(*capacity as f64)));
        }
        Op::Combine { experts } => pairs.push(("experts", Json::num(*experts as f64))),
        Op::Custom { name } => pairs.push(("custom_name", Json::str(name.clone()))),
        _ => {}
    }
    Json::obj(pairs)
}

pub fn from_json(j: &Json) -> Result<Graph> {
    let name = j.get("name").as_str().unwrap_or("anonymous");
    let mut g = Graph::new(name);
    for inp in j.get("inputs").as_arr().ok_or_else(|| anyhow!("missing 'inputs'"))? {
        let tname = inp.get("name").as_str().ok_or_else(|| anyhow!("input without name"))?;
        let shape: Vec<i64> = inp
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("input '{tname}' without shape"))?
            .iter()
            .map(|d| d.as_i64().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?;
        let dtype = inp
            .get("dtype")
            .as_str()
            .and_then(DType::parse)
            .unwrap_or(DType::F32);
        g.input_typed(tname, shape, dtype);
    }
    for node in j.get("nodes").as_arr().ok_or_else(|| anyhow!("missing 'nodes'"))? {
        let op_name = node.get("op").as_str().ok_or_else(|| anyhow!("node without op"))?;
        let out_name = node.get("name").as_str().ok_or_else(|| anyhow!("node without name"))?;
        let inputs: Vec<TensorId> = node
            .get("inputs")
            .as_arr()
            .ok_or_else(|| anyhow!("node '{out_name}' without inputs"))?
            .iter()
            .map(|n| {
                let nm = n.as_str().ok_or_else(|| anyhow!("non-string input"))?;
                g.tensor_by_name(nm)
                    .ok_or_else(|| anyhow!("node '{out_name}' references unknown tensor '{nm}'"))
            })
            .collect::<Result<_>>()?;
        let op = op_from_json(op_name, node.get("attrs"))
            .with_context(|| format!("node '{out_name}'"))?;
        g.add(out_name, op, inputs)?;
    }
    for out in j.get("outputs").as_arr().ok_or_else(|| anyhow!("missing 'outputs'"))? {
        let nm = out.as_str().ok_or_else(|| anyhow!("non-string output"))?;
        let id = g.tensor_by_name(nm).ok_or_else(|| anyhow!("unknown output tensor '{nm}'"))?;
        g.mark_output(id);
    }
    g.validate()?;
    Ok(g)
}

pub(crate) fn op_from_json(name: &str, attrs: &Json) -> Result<Op> {
    let dim = || attrs.get("dim").as_usize().ok_or_else(|| anyhow!("op '{name}' needs 'dim'"));
    let int = |k: &str| attrs.get(k).as_i64().ok_or_else(|| anyhow!("op '{name}' needs '{k}'"));
    let flt = |k: &str| attrs.get(k).as_f64().ok_or_else(|| anyhow!("op '{name}' needs '{k}'"));
    let keepdim = attrs.get("keepdim").as_bool().unwrap_or(false);
    Ok(match name {
        "identity" => Op::Identity,
        "slice" => Op::Slice { dim: dim()?, start: int("start")?.into(), end: int("end")?.into() },
        "concat" => Op::Concat { dim: dim()? },
        "transpose" => Op::Transpose {
            perm: attrs
                .get("perm")
                .as_arr()
                .ok_or_else(|| anyhow!("transpose needs perm"))?
                .iter()
                .map(|p| p.as_usize().ok_or_else(|| anyhow!("bad perm")))
                .collect::<Result<_>>()?,
        },
        "reshape" => Op::Reshape {
            shape: attrs
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("reshape needs shape"))?
                .iter()
                .map(|d| d.as_i64().map(Into::into).ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        },
        "pad" => Op::Pad {
            dim: dim()?,
            before: int("before")?.into(),
            after: int("after")?.into(),
            value: FBits::new(attrs.get("value").as_f64().unwrap_or(0.0)),
        },
        "sum" => Op::SumN,
        "add" => Op::Add,
        "sub" => Op::Sub,
        "mul" => Op::Mul,
        "div" => Op::Div,
        "maximum" => Op::Maximum,
        "neg" => Op::Neg,
        "exp" => Op::Exp,
        "log" => Op::Log,
        "sqrt" => Op::Sqrt,
        "rsqrt" => Op::Rsqrt,
        "square" => Op::Square,
        "tanh" => Op::Tanh,
        "gelu" => Op::Gelu,
        "silu" => Op::Silu,
        "sigmoid" => Op::Sigmoid,
        "relu" => Op::Relu,
        "scale" => Op::Scale { c: FBits::new(flt("c")?) },
        "add_scalar" => Op::AddScalar { c: FBits::new(flt("c")?) },
        "matmul" => Op::MatMul,
        "reduce_sum" => Op::ReduceSum { dim: dim()?, keepdim },
        "reduce_mean" => Op::ReduceMean { dim: dim()?, keepdim },
        "reduce_max" => Op::ReduceMax { dim: dim()?, keepdim },
        "softmax" => Op::Softmax { dim: dim()? },
        "rms_norm" => Op::RmsNorm { eps: FBits::new(attrs.get("eps").as_f64().unwrap_or(1e-5)) },
        "layer_norm" => Op::LayerNorm { eps: FBits::new(attrs.get("eps").as_f64().unwrap_or(1e-5)) },
        "rope" => Op::Rope,
        "embedding" => Op::Embedding,
        "mse_loss" => Op::MseLoss,
        "all_reduce" => Op::AllReduce { ranks: int("ranks")? as usize },
        "all_gather" => Op::AllGather { dim: dim()?, ranks: int("ranks")? as usize },
        "reduce_scatter" => Op::ReduceScatter {
            dim: dim()?,
            ranks: int("ranks")? as usize,
            index: int("index")? as usize,
        },
        "send" => Op::Send { chan: int("chan")? as usize },
        "recv" => Op::Recv { chan: int("chan")? as usize },
        "topk" => Op::TopK { k: int("k")? as usize },
        "dispatch" => Op::Dispatch {
            expert: int("expert")? as usize,
            capacity: int("capacity")? as usize,
        },
        "combine" => Op::Combine { experts: int("experts")? as usize },
        "custom" => Op::Custom {
            name: attrs
                .get("custom_name")
                .as_str()
                .ok_or_else(|| anyhow!("custom op needs 'custom_name'"))?
                .to_string(),
        },
        other => {
            // Unknown op names from capture map to Custom so users can
            // attach lemmas (§6.5) without editing the enum.
            if other.is_empty() {
                bail!("empty op name");
            }
            Op::Custom { name: other.to_string() }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Graph {
        let mut g = Graph::new("fig1");
        let a = g.input("A", vec![4, 6]);
        let b = g.input("B", vec![6, 4]);
        let c = g.matmul("C", a, b);
        let e = g.input("E", vec![4, 4]);
        let f = g.sub2("F", c, e);
        g.mark_output(f);
        g
    }

    #[test]
    fn roundtrip() {
        let g = sample();
        let j = to_json(&g);
        let g2 = from_json(&j).unwrap();
        assert_eq!(g2.name, g.name);
        assert_eq!(g2.num_nodes(), g.num_nodes());
        assert_eq!(g2.num_tensors(), g.num_tensors());
        assert_eq!(to_json(&g2).to_string(), j.to_string());
    }

    #[test]
    fn roundtrip_attrs() {
        let mut g = Graph::new("attrs");
        let x = g.input("x", vec![4, 8]);
        let s = g.slice("s", x, 1, 2, 6);
        let t = g.transpose("t", s, vec![1, 0]);
        let p = g.op(
            "p",
            Op::Pad { dim: 0, before: 1.into(), after: 1.into(), value: FBits::new(0.0) },
            vec![t],
        );
        let r = g.op("r", Op::ReduceSum { dim: 1, keepdim: true }, vec![p]);
        g.mark_output(r);
        let g2 = from_json(&to_json(&g)).unwrap();
        assert_eq!(g2.shape(g2.tensor_by_name("r").unwrap()), g.shape(r));
    }

    #[test]
    fn unknown_op_maps_to_custom() {
        let j = Json::parse(
            r#"{"name":"t","inputs":[{"name":"x","shape":[4],"dtype":"f32"}],
               "nodes":[],"outputs":["x"]}"#,
        )
        .unwrap();
        let g = from_json(&j).unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert!(op_from_json("fused_magic", &Json::Null).unwrap().tag() == crate::ir::OpTag::Custom);
    }

    #[test]
    fn rejects_dangling_references() {
        let j = Json::parse(
            r#"{"name":"t","inputs":[],"nodes":[{"op":"neg","name":"y","inputs":["nope"]}],
               "outputs":[]}"#,
        )
        .unwrap();
        assert!(from_json(&j).is_err());
    }
}
